//! Gradient-accuracy study on the paper's toy problem (Eq. 27–29), pure
//! Rust (no artifacts needed): compares naive / adjoint / ACA against the
//! analytic gradient across solvers and tolerances — a richer version of
//! the paper's Fig 6.
//!
//!     cargo run --release --offline --example gradient_error

use anyhow::Result;

use nodal::grad::{self, Method};
use nodal::ode::analytic::Linear;
use nodal::ode::{integrate, tableau, IntegrateOpts};

fn main() -> Result<()> {
    let z0 = 1.0f32;
    let k = 0.5f32;
    let t_end = 5.0;
    let f = Linear::new(k, 1);
    let exact_z = f.exact_dl_dz0(z0, t_end);
    let exact_k = f.exact_dl_dk(z0, t_end);
    println!("dz/dt = {k}·z, T = {t_end};  dL/dz0 = {exact_z:.4}, dL/dk = {exact_k:.4}\n");

    println!(
        "{:<10} {:<9} {:>12} {:>12} {:>9} {:>7}",
        "solver", "tol", "rel err dz0", "rel err dk", "method", "NFE"
    );
    for tab in [tableau::heun_euler(), tableau::rk23(), tableau::dopri5()] {
        for tol in [1e-3, 1e-5, 1e-7] {
            for method in Method::all() {
                let opts = IntegrateOpts {
                    record_trials: true,
                    ..IntegrateOpts::with_tol(tol, tol * 1e-2)
                };
                let traj = integrate(&f, 0.0, t_end, &[z0], tab, &opts)?;
                let zt = traj.last().unwrap()[0];
                let g = grad::backward(&f, tab, &traj, &[2.0 * zt], method, &opts)?;
                let rz = ((g.dl_dz0[0] as f64 - exact_z) / exact_z).abs();
                let rk = ((g.dl_dtheta[0] as f64 - exact_k) / exact_k).abs();
                println!(
                    "{:<10} {:<9.0e} {:>12.3e} {:>12.3e} {:>9} {:>7}",
                    tab.name,
                    tol,
                    rz,
                    rk,
                    method.name(),
                    g.meter.nfe_forward + g.meter.nfe_backward,
                );
            }
        }
        println!();
    }
    println!("note the naive method's h-chain washing out dL/dk (vanishing gradient,");
    println!("paper Sec 3.3) and the adjoint method's drift growing with tolerance.");
    Ok(())
}
