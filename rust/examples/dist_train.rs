//! Real two-process distributed training smoke (a CI hard gate): rank 0
//! re-execs this binary as rank 1, runs one data-parallel step over
//! loopback TCP, and asserts the reduced gradient is **bit-identical**
//! to the single-process `grad_accum_reference` fold.
//!
//!     cargo run --release --offline --example dist_train
//!
//! Run manually as a worker with
//! `NODAL_DIST_RANK=1 NODAL_DIST_WORLD_SIZE=2 NODAL_DIST_PORT=<p>`.

use anyhow::Result;

use nodal::dist::{
    grad_accum_reference, run_root, run_worker, DistConfig, RootOpts, StepSpec, TransportOpts,
};
use nodal::ode::analytic::Linear;
use nodal::ode::{tableau, IntegrateOpts};
use nodal::util::Pcg64;
use std::net::TcpListener;
use std::process::Command;

/// The identical workload every rank derives from the same seed: one
/// mini-batch of per-sample adaptive spans over a linear flow.
fn spec(f: &Linear) -> StepSpec<'_> {
    let (b, d) = (32usize, 4usize);
    let mut rng = Pcg64::seed(0x51e);
    StepSpec {
        f,
        tab: tableau::by_name("rk45").unwrap(),
        opts: IntegrateOpts::with_tol(1e-5, 1e-7),
        t0s: vec![0.0; b],
        t1s: (0..b).map(|_| rng.range(0.5, 1.5)).collect(),
        z0: (0..b * d).map(|_| rng.uniform_f32() - 0.5).collect(),
        lam: vec![1.0; b * d],
    }
}

fn main() -> Result<()> {
    let cfg = DistConfig::from_env();
    let f = Linear::new(-0.6, 4);
    let s = spec(&f);

    if cfg.rank != 0 {
        // Child process: work one step against the parent's coordinator.
        let g = run_worker(&cfg.root_addr(), cfg.rank, &s, &TransportOpts::default())?;
        println!("rank {}: members {:?} nfe {}", cfg.rank, g.members, g.nfe);
        return Ok(());
    }

    // Parent: bind an ephemeral port, spawn rank 1 as a real process, and
    // coordinate the step.
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let port = listener.local_addr()?.port();
    let exe = std::env::current_exe()?;
    let mut child = Command::new(exe)
        .env("NODAL_DIST_RANK", "1")
        .env("NODAL_DIST_WORLD_SIZE", "2")
        .env("NODAL_DIST_PORT", port.to_string())
        .spawn()?;

    let got = run_root(&listener, 2, &s, &RootOpts::default())?;
    let status = child.wait()?;
    assert!(status.success(), "worker process failed: {status}");
    assert_eq!(got.members, vec![0, 1], "both processes must participate");
    assert_eq!(got.attempts, 1);

    let want = grad_accum_reference(&s, 2)?;
    let got_bits: Vec<u32> = got.dl_dtheta().iter().map(|x| x.to_bits()).collect();
    let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
    assert_eq!(got_bits, want_bits, "2-process gradient must match the reference bit for bit");

    println!(
        "2-process step OK: members {:?} attempts {} nfe {} dl_dtheta {:?}",
        got.members,
        got.attempts,
        got.nfe,
        got.dl_dtheta()
    );
    Ok(())
}
