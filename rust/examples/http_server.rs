//! The HTTP front door end-to-end, over a real loopback socket: spawn the
//! wire front end on an ephemeral port, then act as a plain HTTP/1.1 client
//! — liveness probe, forward solve, gradient solve, dense-output grid, and
//! the metrics route — asserting the served answers bit-identical to direct
//! engine calls. Everything a curl user would see, checked from Rust.
//!
//!     cargo run --release --offline --example http_server
//!
//! Against a long-running deployment the same traffic is plain curl:
//!
//!     NODAL_HTTP_PORT=7118 cargo run --release --example http_server &
//!     curl -s localhost:7118/healthz
//!     curl -s -X POST localhost:7118/v1/solve -d @request.json

use anyhow::{anyhow, Context, Result};

use nodal::ckpt::CkptPolicy;
use nodal::grad::aca_backward;
use nodal::ode::analytic::VanDerPol;
use nodal::ode::dense::DenseOutput;
use nodal::ode::integrate;
use nodal::serve::{HttpConfig, HttpServer, SolveRequest, SolveResponse, SolveServer};
use nodal::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// One round trip as a raw HTTP/1.1 client: write the request, parse the
/// status line, headers, and `content-length`-framed body.
fn round_trip(addr: &str, method: &str, path: &str, body: &str) -> Result<(u16, String)> {
    let (status, _, body) = round_trip_with(addr, method, path, &[], body)?;
    Ok((status, body))
}

/// Like [`round_trip`], with extra request headers; also returns the
/// response headers, lower-cased.
fn round_trip_with(
    addr: &str,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &str,
) -> Result<(u16, Vec<(String, String)>, String)> {
    let mut s = TcpStream::connect(addr).context("connect to front door")?;
    let mut req = format!("{method} {path} HTTP/1.1\r\ncontent-length: {}\r\n", body.len());
    for (k, v) in headers {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str("\r\n");
    req.push_str(body);
    s.write_all(req.as_bytes()).context("write request")?;
    let mut r = BufReader::new(s);
    let mut line = String::new();
    r.read_line(&mut line).context("read status line")?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow!("malformed status line: {line:?}"))?
        .parse()
        .context("parse status code")?;
    let mut resp_headers = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).context("read header")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            let (k, v) = (k.trim().to_ascii_lowercase(), v.trim().to_string());
            if k == "content-length" {
                len = v.parse().context("parse content-length")?;
            }
            resp_headers.push((k, v));
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).context("read body")?;
    Ok((status, resp_headers, String::from_utf8(body).context("utf8 body")?))
}

fn solve(addr: &str, req: &SolveRequest) -> Result<SolveResponse> {
    let (status, body) = round_trip(addr, "POST", "/v1/solve", &req.to_json().to_string())?;
    if status != 200 {
        return Err(anyhow!("solve returned {status}: {body}"));
    }
    SolveResponse::from_json(&Json::parse(&body)?)
}

fn main() -> Result<()> {
    // Ephemeral port so the example never collides with a real deployment;
    // production binds NODAL_HTTP_PORT via the same `from_env` defaults.
    // `from_env` also picks up `NODAL_TRACE_SAMPLE_N` / `NODAL_TRACE_DIR`,
    // so CI's traced smoke leaves its JSONL export under results/trace.
    let server = Arc::new(SolveServer::builder().register("vdp", VanDerPol::paper()).start());
    let cfg = HttpConfig::from_env();
    let trace_dir = cfg.trace.dir.clone();
    let mut http = HttpServer::spawn_at(server, "127.0.0.1:0", cfg)?;
    let addr = http.addr().to_string();
    println!("http front door listening on {addr}");

    let (status, body) = round_trip(&addr, "GET", "/healthz", "")?;
    println!("GET /healthz -> {status} {body}");
    assert_eq!((status, body.as_str()), (200, "{\"ok\":true}"));

    // Forward solve over the wire, checked bit-for-bit against the direct
    // engine call (f32 payloads travel as u32 bit patterns, so this holds
    // exactly, not approximately).
    let req = SolveRequest::fixed("vdp", 0.0, 5.0, vec![2.0, 0.0], 0.05)?;
    let resp = solve(&addr, &req)?;
    let vdp = VanDerPol::paper();
    let mut opts = req.opts();
    opts.ckpt = CkptPolicy::from_budget(0);
    let traj = integrate(&vdp, 0.0, 5.0, &req.z0, req.tab, &opts)?;
    assert_eq!(resp.z_t1(), traj.last().expect("nonempty trajectory"));
    println!("POST /v1/solve (forward) -> z(T) bit-identical to direct integrate");

    // Gradient request: the adjoint results ride the same response.
    let lam = vec![1.0f32, 0.0];
    let resp = solve(&addr, &req.clone().with_grad(lam.clone()))?;
    let g = resp.grad().expect("gradient payload");
    let direct = aca_backward(&vdp, req.tab, &traj, &lam);
    assert_eq!(g.dl_dz0, direct.dl_dz0);
    assert_eq!(g.dl_dtheta, direct.dl_dtheta);
    println!("POST /v1/solve (gradient) -> dL/dz0, dL/dθ bit-identical to aca_backward");

    // Dense-output grid: one solve, five interpolated observations.
    let grid = vec![0.0, 1.25, 2.5, 3.75, 5.0];
    let oreq = SolveRequest::builder("vdp")
        .span(0.0, 5.0)
        .state(vec![2.0, 0.0])
        .fixed(0.05)
        .observe_at(grid.clone())
        .build()?;
    let resp = solve(&addr, &oreq)?;
    let dense = DenseOutput::new(&vdp, &traj);
    let zs = resp.observations().expect("observation grid requested");
    println!("POST /v1/solve (observe_at {} points):", grid.len());
    for (&t, z) in grid.iter().zip(zs) {
        assert_eq!(z, &dense.eval(t), "observation at t={t} must match DenseOutput::eval");
        println!("  z({t:>5.2}) = [{:>8.4}, {:>8.4}]", z[0], z[1]);
    }

    let (status, body) = round_trip(&addr, "GET", "/v1/metrics", "")?;
    assert_eq!(status, 200);
    let m = Json::parse(&body)?;
    println!(
        "GET /v1/metrics -> {} submitted, {} completed",
        m.get("submitted")?.as_usize()?,
        m.get("completed")?.as_usize()?
    );

    // Prometheus exposition of the same snapshot, for scrape-based setups.
    let (status, _, prom) =
        round_trip_with(&addr, "GET", "/v1/metrics?format=prometheus", &[], "")?;
    assert_eq!(status, 200);
    assert!(prom.contains("nodal_requests_completed_total"), "prometheus body:\n{prom}");
    println!(
        "GET /v1/metrics?format=prometheus -> {} lines of text exposition",
        prom.lines().count()
    );

    // Traced solve: an `x-nodal-trace` header turns on tracing for that one
    // request, the id echoes back, and the stitched span tree is queryable
    // (and exported as JSONL under the configured trace dir).
    let id = "00000000000000e5";
    let (status, headers, _) = round_trip_with(
        &addr,
        "POST",
        "/v1/solve",
        &[("x-nodal-trace", id)],
        &req.to_json().to_string(),
    )?;
    assert_eq!(status, 200);
    let echoed = headers.iter().find(|(k, _)| k == "x-nodal-trace").map(|(_, v)| v.as_str());
    assert_eq!(echoed, Some(id), "trace id must echo on the response");
    let (status, _, body) = round_trip_with(&addr, "GET", &format!("/v1/trace/{id}"), &[], "")?;
    assert_eq!(status, 200, "trace route: {body}");
    let spans = Json::parse(&body)?.get("spans")?.as_arr().context("spans array")?.len();
    assert!(spans >= 4, "expected at least http/admission/queue/solve spans, got {spans}");
    let exported = trace_dir.join(format!("{id}.jsonl"));
    assert!(exported.is_file(), "JSONL export missing at {}", exported.display());
    println!("traced solve {id} -> {spans} spans via /v1/trace, JSONL at {}", exported.display());

    http.shutdown();
    println!("front door down; all wire answers matched the engine bit-for-bit");
    Ok(())
}
