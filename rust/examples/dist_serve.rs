//! Sharded solve service walkthrough: two `SolveServer` shards behind
//! TCP endpoints, one dispatcher routing by batch key, answers checked
//! bit-for-bit against direct local solves — then one shard is killed
//! mid-run and traffic keeps flowing on the survivor.
//!
//!     cargo run --release --offline --example dist_serve

use anyhow::Result;

use nodal::dist::{Dispatcher, DispatcherConfig, ShardServer};
use nodal::ode::analytic::{Linear, VanDerPol};
use nodal::ode::{integrate, IntegrateOpts};
use nodal::serve::{SolveRequest, SolveServer};
use nodal::util::Pcg64;

fn build_server() -> SolveServer {
    SolveServer::builder()
        .register("vdp", VanDerPol::new(0.5))
        .register("linear", Linear::new(-0.7, 3))
        .start()
}

fn request(rng: &mut Pcg64, i: usize) -> SolveRequest {
    match i % 3 {
        0 => SolveRequest::adaptive(
            "vdp",
            0.0,
            5.0,
            vec![rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32],
            1e-6,
            1e-8,
        )
        .unwrap(),
        1 => SolveRequest::adaptive(
            "linear",
            0.0,
            2.0,
            (0..3).map(|_| rng.uniform_f32()).collect(),
            1e-5,
            1e-7,
        )
        .unwrap(),
        _ => SolveRequest::fixed("linear", 0.0, 1.0, vec![1.0, -0.5, 0.25], 0.05).unwrap(),
    }
}

/// The ground truth a request must match: a direct scalar solve.
fn direct(req: &SolveRequest) -> Result<Vec<f32>> {
    let opts = match req.tol {
        nodal::serve::Tolerance::Adaptive { rtol, atol } => IntegrateOpts::with_tol(rtol, atol),
        nodal::serve::Tolerance::Fixed { h } => IntegrateOpts::fixed(h),
    };
    let f: Box<dyn nodal::ode::OdeFunc> = match req.dynamics.as_str() {
        "vdp" => Box::new(VanDerPol::new(0.5)),
        _ => Box::new(Linear::new(-0.7, 3)),
    };
    let traj = integrate(f.as_ref(), req.t0, req.t1, &req.z0, req.tab, &opts)?;
    Ok(traj.last().expect("nonempty trajectory").to_vec())
}

fn main() -> Result<()> {
    let shard_a = ShardServer::spawn(build_server(), "127.0.0.1:0")?;
    let mut shard_b = ShardServer::spawn(build_server(), "127.0.0.1:0")?;
    println!("shards: {} and {}", shard_a.addr(), shard_b.addr());

    let addrs = vec![shard_a.addr().to_string(), shard_b.addr().to_string()];
    let dispatcher = Dispatcher::connect(&addrs, &DispatcherConfig::default())?;

    // Burst one: mixed keys across both shards, verified bit-for-bit.
    let mut rng = Pcg64::seed(99);
    let reqs: Vec<SolveRequest> = (0..48).map(|i| request(&mut rng, i)).collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| dispatcher.submit(r.clone()).expect("submit"))
        .collect();
    for (req, h) in reqs.iter().zip(handles) {
        let resp = h.wait().expect("response");
        assert_eq!(resp.z_t1(), direct(req)?, "served answer drifted from the direct solve");
    }
    println!("burst 1: 48/48 answers bit-identical to direct solves");
    println!("{}", dispatcher.metrics()?);

    // Kill shard A without draining — a process crash, as seen from the
    // dispatcher — and keep submitting. Failover re-routes everything to
    // the survivor; answers stay bit-exact.
    shard_a.abort();
    let reqs: Vec<SolveRequest> = (0..24).map(|i| request(&mut rng, i)).collect();
    let handles: Vec<_> = reqs
        .iter()
        .map(|r| dispatcher.submit(r.clone()).expect("submit after crash"))
        .collect();
    for (req, h) in reqs.iter().zip(handles) {
        let resp = h.wait().expect("response after failover");
        assert_eq!(resp.z_t1(), direct(req)?, "failover answer drifted");
    }
    println!(
        "burst 2 (shard A dead): 24/24 served by the survivor, {} healthy shard(s)",
        dispatcher.healthy_shards()
    );

    dispatcher.shutdown();
    shard_b.shutdown();
    Ok(())
}
