//! Quickstart: train a small Neural ODE classifier on the two-spirals task
//! with the Adaptive Checkpoint Adjoint method, end to end through the
//! Rust→PJRT stack.
//!
//!     make artifacts && cargo run --release --offline --example quickstart

use anyhow::Result;

use nodal::data::SpiralDataset;
use nodal::grad::Method;
use nodal::models::NodeSystem;
use nodal::ode::tableau;
use nodal::ode::OdeFunc;
use nodal::runtime::{Engine, HloModel};
use nodal::train::{Optimizer, Sgd};

fn main() -> Result<()> {
    // 1. Load the AOT-compiled spiral NODE (built once by `make artifacts`).
    let mut engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let dir = nodal::runtime::artifact_root().join("spiral");
    let mut model = HloModel::load(&mut engine, &dir)?;
    model.init_params(0)?;
    let batch = model.manifest.batch;

    // 2. Wrap it in a NodeSystem: HeunEuler adaptive solver + ACA gradients.
    let system = NodeSystem::new(model, tableau::heun_euler(), Method::Aca);

    // 3. Synthetic two-spirals data.
    let data = SpiralDataset::generate(1024, 256, 0.03, 7);

    // 4. Plain SGD training loop over the public API.
    let mut system = system;
    let mut opt = Sgd::new(0.1, 0.9, 1e-4);
    let mut rng = nodal::util::Pcg64::seed(1);
    for epoch in 0..8 {
        let order = rng.permutation(data.len());
        let mut loss_sum = 0.0;
        let mut batches = 0;
        for chunk in order.chunks(batch) {
            if chunk.len() < batch {
                continue;
            }
            let (x, y) = data.gather(chunk);
            let (loss, grad, _meter) = system.loss_grad(&x, &y)?;
            let mut params = system.model.params().to_vec();
            opt.step(&mut params, &grad);
            system.model.set_params(&params);
            loss_sum += loss;
            batches += 1;
        }

        // Evaluate.
        let mut correct = 0;
        let mut total = 0;
        let mut idx = 0;
        while idx + batch <= data.test_len() {
            let ids: Vec<usize> = (idx..idx + batch).collect();
            let (x, y) = data.gather_test(&ids);
            let (_, pred) = system.predict(&x, &y)?;
            if let nodal::runtime::hlo_model::Target::Classes(truth) = &y {
                let hats = HloModel::argmax_classes(&pred, 2);
                correct += hats.iter().zip(truth).filter(|(h, t)| **h == **t as usize).count();
                total += truth.len();
            }
            idx += batch;
        }
        println!(
            "epoch {epoch}: train loss {:.4}  test acc {:.3}",
            loss_sum / batches as f64,
            correct as f64 / total as f64
        );
    }
    println!("done — see examples/image_classification.rs for the full driver");
    Ok(())
}
