//! Three-body problem (paper Sec 4.4): learn the three unknown masses of a
//! simulated planetary system by gradient descent *through the ODE solver*
//! with ACA, and compare against the continuous adjoint. Pure Rust dynamics
//! (no artifacts needed).
//!
//!     cargo run --release --offline --example three_body

use anyhow::Result;

use nodal::data::ThreeBodyDataset;
use nodal::grad::{self, Method};
use nodal::ode::analytic::ThreeBody;
use nodal::ode::{integrate, tableau, IntegrateOpts, OdeFunc, Trajectory};
use nodal::train::{Adam, Optimizer};

/// Mean position MSE over the training year + its mass gradient.
fn loss_grad(
    f: &ThreeBody,
    ds: &ThreeBodyDataset,
    method: Method,
) -> Result<(f64, Vec<f32>)> {
    let tab = tableau::dopri5();
    let opts = IntegrateOpts {
        record_trials: method == Method::Naive,
        ..IntegrateOpts::with_tol(1e-5, 1e-5)
    };
    let end = ds.train_end();
    let mut z = ds.states[0].clone();
    let mut segs: Vec<Trajectory> = Vec::new();
    let mut jumps: Vec<Vec<f32>> = Vec::new();
    let mut loss = 0.0;
    for k in 1..=end {
        let traj = integrate(f, ds.times[k - 1], ds.times[k], &z, tab, &opts)?;
        z = traj.last().unwrap().to_vec();
        let target = ds.positions(k);
        let mut lam = vec![0.0f32; 18];
        for j in 0..9 {
            let d = z[j] - target[j];
            loss += (d as f64).powi(2) / 9.0;
            lam[j] = 2.0 * d / 9.0;
        }
        segs.push(traj);
        jumps.push(lam);
    }
    let mut lam = vec![0.0f32; 18];
    let mut dm = vec![0.0f32; 3];
    let n = end as f32;
    for k in (0..end).rev() {
        for (l, j) in lam.iter_mut().zip(&jumps[k]) {
            *l += j / n;
        }
        let g = grad::backward(f, tab, &segs[k], &lam, method, &opts)?;
        lam = g.dl_dz0;
        for (d, s) in dm.iter_mut().zip(&g.dl_dtheta) {
            *d += s;
        }
    }
    Ok((loss / end as f64, dm))
}

fn main() -> Result<()> {
    let ds = ThreeBodyDataset::generate(3, 100);
    println!("true masses: {:?}", ds.masses);

    for method in [Method::Aca, Method::Adjoint] {
        let mut f = ThreeBody::new([0.6, 0.6, 0.6]);
        let mut opt = Adam::new(0.05);
        println!("\n== learning masses with {} ==", method.name());
        for epoch in 0..60 {
            opt.set_lr(0.05 * 0.99f64.powi(epoch));
            let (loss, grad) = loss_grad(&f, &ds, method)?;
            let mut m = f.params().to_vec();
            opt.step(&mut m, &grad);
            for v in m.iter_mut() {
                *v = v.max(1e-3);
            }
            f.set_params(&m);
            if epoch % 10 == 0 {
                println!("  epoch {epoch:>3}: loss {loss:.3e}  masses {:?}", f.masses());
            }
        }
        let err: f32 = f
            .masses()
            .iter()
            .zip(&ds.masses)
            .map(|(a, b)| (a - b).abs())
            .sum::<f32>()
            / 3.0;
        println!("  final masses {:?}  (mean abs error {err:.4})", f.masses());
    }
    Ok(())
}
