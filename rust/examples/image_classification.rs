//! End-to-end driver (the repository's full-system validation run):
//! train the convolutional Neural ODE on the procedural 16×16 image
//! dataset for a few hundred optimizer steps with **all three gradient
//! methods**, logging per-epoch loss/accuracy curves and the measured
//! solver costs — all layers composing: Pallas kernels → JAX model → HLO
//! artifacts → PJRT runtime → Rust adaptive solver + ACA → trainer.
//!
//!     make artifacts && cargo run --release --offline --example image_classification
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use anyhow::Result;

use nodal::data::ImageDataset;
use nodal::grad::Method;
use nodal::ode::tableau;
use nodal::runtime::{Engine, HloModel};
use nodal::train::{LrSchedule, TrainConfig, Trainer};

fn main() -> Result<()> {
    let epochs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);
    let data = ImageDataset::generate(960, 320, 0.05, 0);
    println!(
        "dataset: {} train / {} test, 10 classes, 16x16\n",
        data.len(),
        data.test_len()
    );

    for method in [Method::Aca, Method::Adjoint, Method::Naive] {
        println!("=== training with {} ===", method.name());
        let mut engine = Engine::cpu()?;
        let dir = nodal::runtime::artifact_root().join("img");
        let mut model = HloModel::load(&mut engine, &dir)?;
        model.init_params(0)?;

        let cfg = TrainConfig {
            method,
            epochs,
            lr: LrSchedule::Step {
                initial: 0.05,
                factor: 0.1,
                milestones: vec![epochs * 2 / 3, epochs * 9 / 10],
            },
            rtol: 1e-2,
            atol: 1e-2,
            verbose: true,
            ..Default::default()
        };
        let mut trainer = Trainer::new(cfg);
        trainer.fit(&mut model, tableau::heun_euler(), &data)?;

        let last = trainer.history.last().unwrap();
        println!(
            "--> {}: final err {:.2}%  total {:.1}s  ({} PJRT dispatches)\n",
            method.name(),
            100.0 * (1.0 - last.test_acc),
            last.wall_s,
            model.dispatches(),
        );
    }
    Ok(())
}
