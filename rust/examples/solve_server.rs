//! Walkthrough of the dynamic micro-batching solve server: register
//! dynamics, submit a burst of mixed traffic (forward + gradient requests),
//! and read back per-request stats plus the server's aggregate metrics.
//! Pure Rust dynamics — no artifacts needed.
//!
//!     cargo run --release --offline --example solve_server

use anyhow::Result;

use nodal::ode::analytic::{ConvFlow, Linear, VanDerPol};
use nodal::ode::{integrate, tableau, IntegrateOpts};
use nodal::serve::{ServeConfig, SolveRequest, SolveServer};
use nodal::util::Pcg64;
use std::time::Duration;

fn main() -> Result<()> {
    // Tighter-than-default batching knobs so the walkthrough shows real
    // coalescing; production deployments tune these via NODAL_SERVE_*.
    let cfg = ServeConfig {
        max_batch_size: 8,
        max_queue_delay: Duration::from_micros(300),
        ..ServeConfig::from_env()
    };
    println!(
        "serve config: max_batch={} max_delay={:?} queue_cap={} workers={}",
        cfg.max_batch_size, cfg.max_queue_delay, cfg.queue_capacity, cfg.workers
    );
    let server = SolveServer::builder()
        .register("vdp", VanDerPol::paper())
        .register("linear", Linear::new(-0.7, 8))
        .register("conv", ConvFlow::random(6, 6, 3, 0.4))
        .config(cfg)
        .start();

    // A burst of mixed traffic: three dynamics, heterogeneous initial
    // conditions (so per-request nfe differs), every fourth request asking
    // for gradients too.
    let mut rng = Pcg64::seed(33);
    let mut handles = Vec::new();
    for i in 0..24 {
        let req = match i % 3 {
            0 => SolveRequest::adaptive(
                "vdp",
                0.0,
                10.0,
                vec![rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32],
                1e-6,
                1e-8,
            )?,
            1 => SolveRequest::fixed(
                "linear",
                0.0,
                1.0,
                (0..8).map(|_| rng.normal_f32()).collect(),
                0.02,
            )?,
            _ => SolveRequest::adaptive(
                "conv",
                0.0,
                2.0,
                (0..36).map(|_| rng.normal_f32() * 0.5).collect(),
                1e-5,
                1e-7,
            )?,
        };
        let req = if i % 4 == 3 {
            let dim = req.z0.len();
            let mut lam = vec![0.0f32; dim];
            lam[0] = 1.0;
            req.with_grad(lam)
        } else {
            req
        };
        handles.push((i, server.submit(req)?));
    }

    // Flush partial batches and wait for everything in flight.
    server.drain();

    println!(
        "\n{:>3} {:>7} {:>6} {:>6} {:>6} {:>10} {:>10} {:>6}",
        "req", "dyn", "steps", "nfe", "batch", "wait_us", "svc_us", "grad"
    );
    for (i, h) in handles {
        let resp = h.wait().map_err(|e| anyhow::anyhow!("request {i}: {e}"))?;
        println!(
            "{i:>3} {:>7} {:>6} {:>6} {:>6} {:>10} {:>10} {:>6}",
            ["vdp", "linear", "conv"][i % 3],
            resp.stats.steps,
            resp.stats.nfe,
            resp.stats.batch_size,
            resp.stats.queue_wait.as_micros(),
            resp.stats.service.as_micros(),
            if resp.grad().is_some() { "yes" } else { "-" },
        );
    }

    println!("\naggregate metrics:\n{}", server.metrics());

    // The serving layer never changes an answer: spot-check one request
    // class against the direct engine call.
    let z0 = vec![2.0f32, 0.0];
    let h = server.submit(SolveRequest::fixed("vdp", 0.0, 5.0, z0.clone(), 0.05)?)?;
    let served = h.wait().map_err(|e| anyhow::anyhow!("{e}"))?;
    let direct =
        integrate(&VanDerPol::paper(), 0.0, 5.0, &z0, tableau::rk4(), &IntegrateOpts::fixed(0.05))?;
    assert_eq!(served.z_t1(), direct.last().unwrap(), "served result must be bit-identical");
    println!("\nequivalence check: served z(T) == direct integrate z(T) (bit-exact)");

    // Dense-output serving: the typed builder attaches an observation grid
    // and the response carries the trajectory sampled at those times (each
    // point bit-equal to `DenseOutput::eval` on a direct solve).
    let req = SolveRequest::builder("vdp")
        .span(0.0, 5.0)
        .state(vec![2.0, 0.0])
        .fixed(0.05)
        .observe_at(vec![0.0, 1.25, 2.5, 3.75, 5.0])
        .build()?;
    let h = server.submit(req)?;
    let resp = h.wait().map_err(|e| anyhow::anyhow!("{e}"))?;
    let obs = resp.observations().expect("observation grid requested");
    println!("\ndense-output observations of the vdp limit cycle:");
    for (t, z) in [0.0, 1.25, 2.5, 3.75, 5.0].iter().zip(obs) {
        println!("  z({t:>5.2}) = [{:>8.4}, {:>8.4}]", z[0], z[1]);
    }

    server.shutdown();
    Ok(())
}
