//! Batched gradient estimation: solve a mini-batch of B independent van der
//! Pol initial states through one `integrate_batch` call, run the batched
//! ACA backward pass — a shared-stage reverse sweep: one
//! `eval_batch`/`vjp_batch` dispatch per stage per reverse round across all
//! live samples — and verify per-sample equivalence with the scalar path.
//! Pure Rust dynamics (no artifacts needed).
//!
//!     cargo run --release --offline --example batched_gradients

use anyhow::Result;

use nodal::grad::{aca_backward, aca_backward_batch};
use nodal::ode::analytic::VanDerPol;
use nodal::ode::{integrate, integrate_batch, tableau, IntegrateOpts};
use nodal::util::{Pcg64, Timer};

fn main() -> Result<()> {
    const B: usize = 8;
    const DIM: usize = 2;
    let f = VanDerPol::new(0.5);
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
    let (t0, t1) = (0.0, 5.0);

    let mut rng = Pcg64::seed(17);
    let z0: Vec<f32> = (0..B * DIM).map(|_| rng.range(-2.0, 2.0) as f32).collect();
    let lam: Vec<f32> = (0..B * DIM).map(|_| rng.normal_f32()).collect();

    // Batched forward + backward.
    let timer = Timer::new();
    let bt = integrate_batch(&f, t0, t1, &z0, tab, &opts)?;
    let grads = aca_backward_batch(&f, tab, &bt, &lam);
    let batched_ms = timer.elapsed_ms();

    println!("batched solve of {B} van der Pol samples over [{t0}, {t1}]:");
    println!(
        "{:>6} {:>8} {:>6} {:>6} {:>8} {:>12} {:>12}",
        "sample", "steps", "rej", "avg_m", "nfe", "ckpt bytes", "dL/dz0[0]"
    );
    for i in 0..B {
        let tr = &bt.tracks[i];
        println!(
            "{i:>6} {:>8} {:>6} {:>6.2} {:>8} {:>12} {:>12.5}",
            tr.steps(),
            tr.n_rejected,
            tr.avg_m(),
            tr.nfe,
            bt.checkpoint_bytes(i),
            grads[i].dl_dz0[0],
        );
    }

    // Per-sample reference: the scalar path must agree exactly.
    let timer = Timer::new();
    let mut max_dev = 0.0f32;
    for i in 0..B {
        let traj = integrate(&f, t0, t1, &z0[i * DIM..(i + 1) * DIM], tab, &opts)?;
        let g = aca_backward(&f, tab, &traj, &lam[i * DIM..(i + 1) * DIM]);
        assert_eq!(traj.len(), bt.steps(i), "sample {i}: step counts must match");
        for (a, b) in g.dl_dz0.iter().zip(&grads[i].dl_dz0) {
            max_dev = max_dev.max((a - b).abs());
        }
    }
    let loop_ms = timer.elapsed_ms();

    println!("\nmax |batched − per-sample| gradient deviation: {max_dev:e}");
    println!("wall: batched {batched_ms:.2} ms vs per-sample loop {loop_ms:.2} ms");
    println!("total checkpoint bytes: {}", bt.checkpoint_bytes_total());
    Ok(())
}
