//! Irregularly-sampled time-series interpolation (paper Sec 4.3): train the
//! latent NODE on coupled-oscillator sequences with arbitrary observation
//! gaps, through the segmented-integration training path.
//!
//!     make artifacts && cargo run --release --offline --example time_series

use anyhow::Result;

use nodal::data::timeseries::{Group, TimeSeriesDataset};
use nodal::grad::Method;
use nodal::ode::{tableau, IntegrateOpts, OdeFunc};
use nodal::runtime::hlo_model::Target;
use nodal::runtime::{Engine, HloModel};
use nodal::train::segmented::{segmented_eval, segmented_loss_grad};
use nodal::train::{Adam, Optimizer};

fn targets_of(g: &Group) -> Vec<Target> {
    (0..g.n_targets()).map(|k| Target::Values(g.target_at(k))).collect()
}

fn main() -> Result<()> {
    let data = TimeSeriesDataset::generate(4, 2, 32, 5.0, 11);
    let mut engine = Engine::cpu()?;
    let dir = nodal::runtime::artifact_root().join("ts");
    let mut model = HloModel::load(&mut engine, &dir)?;
    model.init_params(1)?;

    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(1e-3, 1e-4);
    let mut opt = Adam::new(0.01);

    for epoch in 0..20 {
        let mut train_loss = 0.0;
        for g in &data.train {
            let z0 = model.encode(&g.encoder_input())?;
            let sg = segmented_loss_grad(
                &model,
                tab,
                &opts,
                Method::Aca,
                &z0,
                g.target_times(),
                &targets_of(g),
            )?;
            let mut dtheta = sg.dtheta;
            model.encode_vjp_accum(&g.encoder_input(), &sg.dl_dz0, &mut dtheta)?;
            let mut params = model.params().to_vec();
            opt.step(&mut params, &dtheta);
            model.set_params(&params);
            train_loss += sg.loss;
        }
        let mut test_mse = 0.0;
        for g in &data.test {
            let z0 = model.encode(&g.encoder_input())?;
            let (mse, _) =
                segmented_eval(&model, tab, &opts, &z0, g.target_times(), &targets_of(g))?;
            test_mse += mse;
        }
        println!(
            "epoch {epoch:>2}: train mse {:.4}  test mse {:.4}",
            train_loss / data.train.len() as f64,
            test_mse / data.test.len() as f64
        );
    }
    Ok(())
}
