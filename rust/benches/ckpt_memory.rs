//! Checkpoint-memory workload: peak checkpoint bytes and replay-NFE
//! overhead of a **budgeted** store versus the dense store, plus backward
//! wall time for both, over long-horizon batched solves.
//!
//! Before timing anything, every workload asserts the ckpt guarantee on the
//! actual bench trajectories: grids, finals and gradients from the
//! budgeted store are **bit-identical** to the dense store, the budget
//! holds at its mid-solve peak, and the byte reduction is ≥ 4× (the
//! acceptance bar; the budget is dense/8, so ~8× is expected).
//!
//! `--smoke` shrinks spans and the sampling budget for CI: the bench still
//! runs end-to-end and appends its rows — peak bytes, reduction ratio,
//! replay-NFE overhead, timings — to `results/bench/ckpt_memory.jsonl`
//! (via `bench::Runner`), so the memory trajectory accumulates per commit
//! alongside `grad_backward.jsonl` and `serve_load.jsonl`.

use nodal::bench::Runner;
use nodal::ckpt::CkptPolicy;
use nodal::grad::aca_backward_batch;
use nodal::ode::analytic::{Linear, VanDerPol};
use nodal::ode::{integrate_batch, tableau, IntegrateOpts, OdeFunc, Tableau};
use nodal::util::Pcg64;

#[allow(clippy::too_many_arguments)]
fn bench_workload<F: OdeFunc>(
    r: &mut Runner,
    name: &str,
    f: &F,
    b: usize,
    t1: f64,
    tab: &'static Tableau,
    base: &IntegrateOpts,
    rng: &mut Pcg64,
) {
    let d = f.dim();
    let z0: Vec<f32> = (0..b * d).map(|_| rng.normal_f32() * 0.8).collect();
    let lam: Vec<f32> = (0..b * d).map(|_| rng.normal_f32()).collect();

    let dense = integrate_batch(f, 0.0, t1, &z0, tab, base).unwrap();
    let dense_peak: usize = (0..b).map(|i| dense.peak_state_bytes(i)).max().unwrap();
    // Budget: 1/8 of the *smallest* sample's dense state footprint, so the
    // ≥4× reduction bar holds per sample with slack.
    let budget = (0..b).map(|i| dense.state_bytes(i)).min().unwrap() / 8;
    let opts = IntegrateOpts { ckpt: CkptPolicy::Budgeted(budget), ..base.clone() };
    let thin = integrate_batch(f, 0.0, t1, &z0, tab, &opts).unwrap();

    // ---- bit-equality + budget assertions BEFORE timing ----
    let gd = aca_backward_batch(f, tab, &dense, &lam);
    let gt = aca_backward_batch(f, tab, &thin, &lam);
    let mut replay_nfe = 0usize;
    let mut forward_nfe = 0usize;
    for i in 0..b {
        assert_eq!(thin.tracks[i].ts, dense.tracks[i].ts, "{name} sample {i}: grid");
        assert_eq!(thin.last(i), dense.last(i), "{name} sample {i}: final");
        assert_eq!(gt[i].dl_dz0, gd[i].dl_dz0, "{name} sample {i}: dl_dz0");
        assert_eq!(gt[i].dl_dtheta, gd[i].dl_dtheta, "{name} sample {i}: dl_dtheta");
        assert!(
            thin.peak_state_bytes(i) <= budget,
            "{name} sample {i}: peak {} over budget {budget}",
            thin.peak_state_bytes(i)
        );
        assert!(
            thin.peak_state_bytes(i) * 4 <= dense.peak_state_bytes(i),
            "{name} sample {i}: byte reduction below 4x"
        );
        replay_nfe += gt[i].meter.nfe_replay;
        forward_nfe += gt[i].meter.nfe_forward;
        assert!(gt[i].meter.nfe_replay > 0, "{name} sample {i}: budget never replayed");
        assert_eq!(gd[i].meter.nfe_replay, 0, "{name} sample {i}: dense replayed");
    }
    let thin_peak: usize = (0..b).map(|i| thin.peak_state_bytes(i)).max().unwrap();
    let steps: usize = (0..b).map(|i| dense.steps(i)).sum();
    println!(
        "  [{name}] B={b} d={d} steps {steps}: peak {dense_peak} B dense -> {thin_peak} B \
         budgeted ({:.1}x), replay {replay_nfe} evals ({:.1}% of forward)",
        dense_peak as f64 / thin_peak as f64,
        100.0 * replay_nfe as f64 / forward_nfe.max(1) as f64
    );

    // Persisted rows: the memory trajectory + the recompute overhead.
    r.record(&format!("{name}_peak_bytes_dense"), dense_peak as f64);
    r.record(&format!("{name}_peak_bytes_budgeted"), thin_peak as f64);
    r.record(&format!("{name}_bytes_reduction"), dense_peak as f64 / thin_peak as f64);
    r.record(
        &format!("{name}_replay_nfe_overhead"),
        replay_nfe as f64 / forward_nfe.max(1) as f64,
    );
    // The backward pass's transient segment buffer — the memory the budget
    // trades against (resident anchors down, one replayed segment up).
    let replay_peak = (0..b).map(|i| gt[i].meter.replay_peak_bytes).max().unwrap();
    r.record(&format!("{name}_replay_peak_bytes"), replay_peak as f64);

    // Timings: the price of replay on the backward pass, dense vs budgeted.
    r.bench(&format!("{name}_backward_dense"), || {
        let g = aca_backward_batch(f, tab, &dense, &lam);
        std::hint::black_box(g[0].dl_dz0[0]);
    });
    r.bench(&format!("{name}_backward_budgeted"), || {
        let g = aca_backward_batch(f, tab, &thin, &lam);
        std::hint::black_box(g[0].dl_dz0[0]);
    });
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut r = Runner::new("ckpt_memory");
    if smoke {
        r.set_target_s(0.05);
    }
    let mut rng = Pcg64::seed(47);
    // Long horizons are exactly the workloads a budget exists for; smoke
    // keeps both variants but shrinks span and batch.
    let (b, span) = if smoke { (2usize, 6.0) } else { (8usize, 20.0) };

    // Adaptive oscillator: many accepted steps, per-sample step counts vary.
    let f = VanDerPol::new(0.5);
    let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
    bench_workload(
        &mut r,
        &format!("vdp_b{b}"),
        &f,
        b,
        span,
        tableau::dopri5(),
        &opts,
        &mut rng,
    );

    // Wide fixed-step linear system: state bytes dominate the footprint.
    let f = Linear::new(-0.9, 64);
    let opts = IntegrateOpts::fixed(0.005);
    bench_workload(
        &mut r,
        &format!("linear64_b{}", b.max(2) / 2),
        &f,
        b.max(2) / 2,
        span / 4.0,
        tableau::rk4(),
        &opts,
        &mut rng,
    );
}
