//! Fig 7 workload: one full optimizer step (encode + solve + loss + backward
//! + SGD) of the image NODE per gradient method — the end-to-end hot path of
//! the training experiments.
//!
//! The first group needs no artifacts: it pits the batched engine
//! (`integrate_batch` + `aca_backward_batch`) against the per-sample loop on
//! a B=8 mini-batch of analytic stand-in dynamics, isolating the solver-side
//! win (shared stage sweeps, arena checkpoints, no per-step allocation).

use nodal::bench::Runner;
use nodal::data::ImageDataset;
use nodal::grad::{aca_backward, aca_backward_batch, Method};
use nodal::ode::analytic::{ConvFlow, Linear};
use nodal::ode::{integrate, integrate_batch, tableau, IntegrateOpts, OdeFunc};
use nodal::runtime::{Engine, HloModel};
use nodal::train::{TrainConfig, Trainer};
use nodal::util::Pcg64;

/// fwd+bwd of B independent samples: per-sample loop vs the batch engine.
fn bench_batched_vs_loop(r: &mut Runner) {
    const B: usize = 8;
    let tab = tableau::dopri5();

    // Conv-flow dynamics (256-d state — the image-NODE stand-in).
    let f = ConvFlow::random(16, 16, 9, 0.4);
    let dim = f.dim();
    let mut rng = Pcg64::seed(4);
    let z0: Vec<f32> = (0..B * dim).map(|_| rng.normal_f32() * 0.5).collect();
    let lam: Vec<f32> = (0..B * dim).map(|_| rng.normal_f32()).collect();
    let opts = IntegrateOpts::with_tol(1e-5, 1e-7);
    r.bench("convflow_b8_fwd_bwd_per_sample_loop", || {
        for i in 0..B {
            let traj = integrate(&f, 0.0, 1.0, &z0[i * dim..(i + 1) * dim], tab, &opts).unwrap();
            let g = aca_backward(&f, tab, &traj, &lam[i * dim..(i + 1) * dim]);
            std::hint::black_box(g.dl_dz0[0]);
        }
    });
    r.bench("convflow_b8_fwd_bwd_batched", || {
        let bt = integrate_batch(&f, 0.0, 1.0, &z0, tab, &opts).unwrap();
        let gs = aca_backward_batch(&f, tab, &bt, &lam);
        std::hint::black_box(gs[0].dl_dz0[0]);
    });

    // Cheap element-wise dynamics at a small fixed step: many accepted steps,
    // so the forward pass is dominated by per-step bookkeeping — the case the
    // checkpoint arena + flat buffers target.
    let f = Linear::new(-0.9, 64);
    let dim = f.dim();
    let z0: Vec<f32> = (0..B * dim).map(|_| rng.normal_f32()).collect();
    let opts = IntegrateOpts::fixed(1e-3);
    r.bench("linear64_b8_fixed1k_steps_per_sample_loop", || {
        for i in 0..B {
            let traj =
                integrate(&f, 0.0, 1.0, &z0[i * dim..(i + 1) * dim], tableau::rk4(), &opts)
                    .unwrap();
            std::hint::black_box(traj.last().unwrap()[0]);
        }
    });
    r.bench("linear64_b8_fixed1k_steps_batched", || {
        let bt = integrate_batch(&f, 0.0, 1.0, &z0, tableau::rk4(), &opts).unwrap();
        std::hint::black_box(bt.last(0)[0]);
    });
}

fn main() {
    let mut r = Runner::new("fig7_train_step");
    bench_batched_vs_loop(&mut r);

    if !std::path::Path::new("artifacts/img/manifest.json").exists() {
        println!("skipping PJRT train-step benches: run `make artifacts` first");
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("img")).unwrap();
    model.init_params(0).unwrap();
    let data = ImageDataset::generate(model.manifest.batch, 0, 0.05, 3);
    let ids: Vec<usize> = (0..model.manifest.batch).collect();
    let (x, y) = data.gather(&ids);
    let tab = tableau::heun_euler();

    for method in [Method::Aca, Method::Adjoint, Method::Naive] {
        let cfg = TrainConfig { method, ..Default::default() };
        let trainer = Trainer::new(cfg);
        r.bench(&format!("train_step_{}", method.name()), || {
            let (loss, dtheta, _) = trainer.loss_grad(&model, tab, &x, &y).unwrap();
            // apply the update so consecutive iterations stay realistic
            let params: Vec<f32> = model
                .params()
                .iter()
                .zip(&dtheta)
                .map(|(p, g)| p - 1e-3 * g)
                .collect();
            model.set_params(&params);
            std::hint::black_box(loss);
        });
    }
}
