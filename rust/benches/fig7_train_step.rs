//! Fig 7 workload: one full optimizer step (encode + solve + loss + backward
//! + SGD) of the image NODE per gradient method — the end-to-end hot path of
//! the training experiments.

use nodal::bench::Runner;
use nodal::data::ImageDataset;
use nodal::grad::Method;
use nodal::ode::{tableau, OdeFunc};
use nodal::runtime::{Engine, HloModel};
use nodal::train::{TrainConfig, Trainer};

fn main() {
    if !std::path::Path::new("artifacts/img/manifest.json").exists() {
        println!("skipping fig7_train_step: run `make artifacts` first");
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("img")).unwrap();
    model.init_params(0).unwrap();
    let data = ImageDataset::generate(model.manifest.batch, 0, 0.05, 3);
    let ids: Vec<usize> = (0..model.manifest.batch).collect();
    let (x, y) = data.gather(&ids);
    let tab = tableau::heun_euler();

    let mut r = Runner::new("fig7_train_step");
    for method in [Method::Aca, Method::Adjoint, Method::Naive] {
        let cfg = TrainConfig { method, ..Default::default() };
        let trainer = Trainer::new(cfg);
        r.bench(&format!("train_step_{}", method.name()), || {
            let (loss, dtheta, _) = trainer.loss_grad(&model, tab, &x, &y).unwrap();
            // apply the update so consecutive iterations stay realistic
            let params: Vec<f32> = model
                .params()
                .iter()
                .zip(&dtheta)
                .map(|(p, g)| p - 1e-3 * g)
                .collect();
            model.set_params(&params);
            std::hint::black_box(loss);
        });
    }
}
