//! Backward-pass workload: shared-stage batched reverse sweep
//! (`aca_backward_batch` → `step_vjp_batch`, one `eval_batch`/`vjp_batch`
//! dispatch per stage per reverse round) versus the per-sample replay it
//! replaced (one scalar `step_vjp` per sample per step, reading the same
//! shared checkpoint arena). Both paths produce bit-identical per-sample
//! gradients — the comparison isolates the dispatch/allocation amortization,
//! which is the entire point of the shared sweep.
//!
//! `--smoke` shrinks workloads and the sampling budget for CI: the bench
//! still runs end-to-end and appends its JSON lines to
//! `results/bench/grad_backward.jsonl` (via `bench::Runner::save`), so the
//! perf trajectory accumulates on every pipeline run.

use nodal::bench::Runner;
use nodal::grad::{aca_backward_batch, step_vjp, GradResult};
use nodal::ode::analytic::{ConvFlow, Linear, ThreeBody, VanDerPol};
use nodal::ode::{integrate_batch, tableau, BatchTrajectory, IntegrateOpts, OdeFunc, Tableau};
use nodal::util::Pcg64;

/// The pre-shared-stage backward: replay every sample's reverse sweep
/// independently, one scalar `step_vjp` per step, straight out of the shared
/// arena — exactly what `aca_backward_batch` used to do.
fn per_sample_replay<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    traj: &BatchTrajectory,
    lam_t1: &[f32],
) -> Vec<GradResult> {
    let d = f.dim();
    (0..traj.batch)
        .map(|i| {
            let tr = &traj.tracks[i];
            let n = tr.steps();
            let mut lam = lam_t1[i * d..(i + 1) * d].to_vec();
            let mut dtheta = vec![0.0f32; f.n_params()];
            let mut meter = nodal::grad::CostMeter::default();
            for k in (0..n).rev() {
                let out =
                    step_vjp(f, tab, tr.ts[k], tr.hs[k], traj.z(i, k), &lam, &mut dtheta, false);
                lam = out.dz;
                meter.nfe_backward += out.nfe;
                meter.vjp_calls += out.nvjp;
            }
            GradResult { dl_dz0: lam, dl_dtheta: dtheta, meter }
        })
        .collect()
}

/// Forward-solve once, then bench shared-stage vs per-sample replay over the
/// same recorded trajectory. Returns (replay_ms, shared_ms).
#[allow(clippy::too_many_arguments)]
fn bench_pair<F: OdeFunc>(
    r: &mut Runner,
    name: &str,
    f: &F,
    b: usize,
    t1: f64,
    tab: &'static Tableau,
    opts: &IntegrateOpts,
    rng: &mut Pcg64,
    z_scale: f32,
) -> (f64, f64) {
    let d = f.dim();
    let z0: Vec<f32> = (0..b * d).map(|_| rng.normal_f32() * z_scale).collect();
    let lam: Vec<f32> = (0..b * d).map(|_| rng.normal_f32()).collect();
    let bt = integrate_batch(f, 0.0, t1, &z0, tab, opts).unwrap();
    let steps: usize = (0..b).map(|i| bt.steps(i)).sum();
    println!("  [{name}] B={b} d={d} total accepted steps {steps}");

    // Sanity: both paths must agree bit-for-bit before we time them.
    let gs = aca_backward_batch(f, tab, &bt, &lam);
    let gr = per_sample_replay(f, tab, &bt, &lam);
    for (s, p) in gs.iter().zip(&gr) {
        assert_eq!(s.dl_dz0, p.dl_dz0, "{name}: shared-stage diverged from replay");
        assert_eq!(s.dl_dtheta, p.dl_dtheta, "{name}: dθ diverged");
    }

    let replay = r
        .bench(&format!("{name}_backward_replay"), || {
            let g = per_sample_replay(f, tab, &bt, &lam);
            std::hint::black_box(g[0].dl_dz0[0]);
        })
        .mean_ms;
    let shared = r
        .bench(&format!("{name}_backward_shared"), || {
            let g = aca_backward_batch(f, tab, &bt, &lam);
            std::hint::black_box(g[0].dl_dz0[0]);
        })
        .mean_ms;
    (replay, shared)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut r = Runner::new("grad_backward");
    if smoke {
        r.set_target_s(0.05);
    }
    let mut rng = Pcg64::seed(31);
    // Scale knobs: smoke keeps every variant but shrinks batch and span.
    let (b_small, b_large, span) = if smoke { (2, 4, 1.0) } else { (8, 32, 3.0) };

    let mut pairs: Vec<(String, f64, f64)> = Vec::new();

    // Labels carry the *actual* batch size so smoke rows in the persisted
    // jsonl are never confused with full-size runs of the same workload.

    // Small-state oscillator: dispatch-bound — the case the shared sweep
    // targets hardest (per-sample replay pays one dynamic call per 2 floats).
    let f = VanDerPol::new(0.5);
    let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
    let name = format!("vdp_b{b_large}");
    let (rp, sh) =
        bench_pair(&mut r, &name, &f, b_large, span, tableau::dopri5(), &opts, &mut rng, 1.0);
    pairs.push((name, rp, sh));

    // Element-wise linear at a fixed step: many steps, parameterful (dθ
    // accumulation rides the shared sweep too).
    let f = Linear::new(-0.9, 64);
    let opts = IntegrateOpts::fixed(0.01);
    let name = format!("linear64_b{}", b_large / 2);
    let (rp, sh) =
        bench_pair(&mut r, &name, &f, b_large / 2, 1.0, tableau::rk4(), &opts, &mut rng, 1.0);
    pairs.push((name, rp, sh));

    // Image-sized state: compute-heavier per stage, so the win shifts from
    // dispatch amortization toward allocation reuse.
    let f = ConvFlow::random(16, 16, 9, 0.4);
    let opts = IntegrateOpts::with_tol(1e-5, 1e-7);
    let name = format!("convflow256_b{b_small}");
    let (rp, sh) =
        bench_pair(&mut r, &name, &f, b_small, 1.0, tableau::dopri5(), &opts, &mut rng, 0.5);
    pairs.push((name, rp, sh));

    // Three-body with trainable masses: FD-heavy vjp — per-sample cost
    // dominates, the shared sweep should at least break even.
    let f = ThreeBody::new([1e-3, 8e-4, 1.2e-3]);
    let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
    let name = format!("threebody_b{b_small}");
    let (rp, sh) =
        bench_pair(&mut r, &name, &f, b_small, 0.5, tableau::dopri5(), &opts, &mut rng, 0.6);
    pairs.push((name, rp, sh));

    println!("-- shared-stage speedup over per-sample replay --");
    for (name, replay, shared) in &pairs {
        println!(
            "  {:<20} {:>6.2}x  ({:.4} ms -> {:.4} ms)",
            name,
            replay / shared,
            replay,
            shared
        );
    }
}
