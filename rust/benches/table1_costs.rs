//! Table 1 workload: full fwd+bwd gradient pass of the image NODE per
//! method, end to end through PJRT (requires `make artifacts`).

use nodal::bench::Runner;
use nodal::data::ImageDataset;
use nodal::grad::{self, Method};
use nodal::ode::{integrate, tableau, IntegrateOpts, OdeFunc};
use nodal::runtime::{Engine, HloModel};

fn main() {
    if !std::path::Path::new("artifacts/img/manifest.json").exists() {
        println!("skipping table1_costs: run `make artifacts` first");
        return;
    }
    let mut engine = Engine::cpu().unwrap();
    let mut model =
        HloModel::load(&mut engine, &nodal::runtime::artifact_root().join("img")).unwrap();
    model.init_params(0).unwrap();
    let data = ImageDataset::generate(model.manifest.batch, 0, 0.05, 3);
    let ids: Vec<usize> = (0..model.manifest.batch).collect();
    let (x, y) = data.gather(&ids);
    let tab = tableau::dopri5();

    let mut r = Runner::new("table1_costs");
    for method in [Method::Aca, Method::Adjoint, Method::Naive] {
        let opts = IntegrateOpts {
            record_trials: method == Method::Naive,
            ..IntegrateOpts::with_tol(1e-3, 1e-5)
        };
        r.bench(&format!("fwd_bwd_{}", method.name()), || {
            let z0 = model.encode(&x).unwrap();
            let traj = integrate(&model, 0.0, 1.0, &z0, tab, &opts).unwrap();
            let mut dtheta = vec![0.0f32; model.n_params()];
            let zt = traj.last().unwrap();
            let (lam, _) = model.decode_loss_vjp(zt, &y, &mut dtheta).unwrap();
            let g = grad::backward(&model, tab, &traj, &lam, method, &opts).unwrap();
            std::hint::black_box(g.dl_dtheta[0]);
        });
    }
}
