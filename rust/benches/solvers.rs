//! Solver-core throughput: adaptive integration per tableau on analytic
//! dynamics (supports the Table 2/6/7 solver sweeps).

use nodal::bench::Runner;
use nodal::ode::analytic::VanDerPol;
use nodal::ode::{integrate, tableau, IntegrateOpts};

fn main() {
    let mut r = Runner::new("solvers");
    let f = VanDerPol::new(0.15);
    let z0 = [2.0f32, 0.0];
    for tab in [
        tableau::euler(),
        tableau::rk2(),
        tableau::rk4(),
        tableau::heun_euler(),
        tableau::rk23(),
        tableau::dopri5(),
    ] {
        let opts = if tab.adaptive() {
            IntegrateOpts::with_tol(1e-6, 1e-8)
        } else {
            IntegrateOpts::fixed(0.01)
        };
        r.bench(&format!("vdp_t25_{}", tab.name), || {
            let traj = integrate(&f, 0.0, 25.0, &z0, tab, &opts).unwrap();
            std::hint::black_box(traj.len());
        });
    }

    // Dimension scaling of the stepper arithmetic (conv flow: 256-d state).
    let cf = nodal::ode::analytic::ConvFlow::random(16, 16, 1, 0.4);
    let z: Vec<f32> = (0..256).map(|i| (i as f32 * 0.1).sin()).collect();
    r.bench("convflow_256d_dopri5_t5", || {
        let traj = integrate(
            &cf,
            0.0,
            5.0,
            &z,
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-6, 1e-8),
        )
        .unwrap();
        std::hint::black_box(traj.nfe);
    });
}
