//! Fig 6 workload: gradient estimation on the toy problem for each method —
//! the computation-cost column of Table 1 on the smallest system.

use nodal::bench::Runner;
use nodal::grad::{self, Method};
use nodal::ode::analytic::Linear;
use nodal::ode::{integrate, tableau, IntegrateOpts};

fn main() {
    let mut r = Runner::new("fig6_toy_grad");
    let f = Linear::new(-0.5, 1);
    let tab = tableau::dopri5();
    let opts = IntegrateOpts {
        record_trials: true,
        ..IntegrateOpts::with_tol(1e-5, 1e-8)
    };
    let traj = integrate(&f, 0.0, 10.0, &[1.0], tab, &opts).unwrap();
    let zt = traj.last().unwrap()[0];
    let lam = [2.0 * zt];

    for method in Method::all() {
        r.bench(&format!("backward_{}", method.name()), || {
            let g = grad::backward(&f, tab, &traj, &lam, method, &opts).unwrap();
            std::hint::black_box(g.dl_dz0[0]);
        });
    }
    r.bench("forward_only", || {
        let t = integrate(&f, 0.0, 10.0, &[1.0], tab, &opts).unwrap();
        std::hint::black_box(t.nfe);
    });
}
