//! PJRT dispatch overhead: latency of the individual AOT executables
//! (f_eval / f_vjp / encode / loss head) — the L3↔XLA boundary the perf
//! pass optimizes against.

use nodal::bench::Runner;
use nodal::ode::OdeFunc;
use nodal::runtime::hlo_model::Target;
use nodal::runtime::{Engine, HloModel};

fn main() {
    if !std::path::Path::new("artifacts/spiral/manifest.json").exists() {
        println!("skipping runtime_dispatch: run `make artifacts` first");
        return;
    }
    let mut r = Runner::new("runtime_dispatch");
    let mut engine = Engine::cpu().unwrap();

    for name in ["spiral", "img"] {
        let mut model =
            HloModel::load(&mut engine, &nodal::runtime::artifact_root().join(name)).unwrap();
        model.init_params(0).unwrap();
        let n = model.dim();
        let z: Vec<f32> = (0..n).map(|i| (i as f32 * 0.01).sin()).collect();
        let mut dz = vec![0.0f32; n];
        r.bench(&format!("{name}_f_eval"), || {
            model.eval(0.5, &z, &mut dz);
            std::hint::black_box(dz[0]);
        });
        let w = z.clone();
        let mut wjz = vec![0.0f32; n];
        let mut wjp = vec![0.0f32; model.n_params()];
        r.bench(&format!("{name}_f_vjp"), || {
            model.vjp(0.5, &z, &w, &mut wjz, &mut wjp);
            std::hint::black_box(wjz[0]);
        });
        let x = vec![0.1f32; model.manifest.batch * model.manifest.dim_in];
        r.bench(&format!("{name}_encode"), || {
            std::hint::black_box(model.encode(&x).unwrap()[0]);
        });
        let y = Target::Classes(vec![0; model.manifest.batch]);
        r.bench(&format!("{name}_decode_loss"), || {
            std::hint::black_box(model.decode_loss(&z, &y).unwrap().0);
        });
    }
}
