//! Closed-loop load test of the solve server: C client threads each keep
//! one request outstanding (submit → wait → submit …) against a 64-request
//! mixed workload, versus the sequential one-request-at-a-time baseline the
//! server replaces. Reports throughput for both and the server's batching
//! metrics; the batched server must sustain ≥ the sequential baseline.

use nodal::bench::Runner;
use nodal::grad::aca_backward;
use nodal::ode::analytic::{ConvFlow, Linear, VanDerPol};
use nodal::ode::{integrate, tableau, IntegrateOpts};
use nodal::serve::{ServeConfig, SolveRequest, SolveServer};
use nodal::util::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const TOTAL: usize = 64;
const CLIENTS: usize = 8;

/// The 64-request mixed workload: three dynamics, adaptive and fixed-step
/// tolerance classes, and a sprinkle of gradient requests — per-request cost
/// is deliberately heterogeneous (nfe varies per initial condition).
fn workload() -> Vec<SolveRequest> {
    let mut rng = Pcg64::seed(20);
    (0..TOTAL)
        .map(|i| match i % 4 {
            0 => SolveRequest::adaptive(
                "vdp",
                0.0,
                5.0,
                vec![rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32],
                1e-6,
                1e-8,
            ),
            1 => SolveRequest::fixed(
                "linear",
                0.0,
                1.0,
                (0..16).map(|_| rng.normal_f32()).collect(),
                0.01,
            ),
            2 => SolveRequest::adaptive(
                "conv",
                0.0,
                2.0,
                (0..64).map(|_| rng.normal_f32() * 0.5).collect(),
                1e-5,
                1e-7,
            ),
            _ => SolveRequest::adaptive(
                "vdp",
                0.0,
                5.0,
                vec![rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32],
                1e-6,
                1e-8,
            )
            .with_grad(vec![1.0, 0.0]),
        })
        .collect()
}

fn register(b: nodal::serve::SolveServerBuilder) -> nodal::serve::SolveServerBuilder {
    b.register("vdp", VanDerPol::new(0.5))
        .register("linear", Linear::new(-0.9, 16))
        .register("conv", ConvFlow::random(8, 8, 11, 0.4))
}

/// Closed-loop: each client thread owns a slice of the workload and keeps
/// exactly one request in flight.
fn run_server_closed_loop(server: &Arc<SolveServer>, reqs: &[SolveRequest]) {
    std::thread::scope(|scope| {
        for chunk in reqs.chunks(TOTAL / CLIENTS) {
            let server = server.clone();
            scope.spawn(move || {
                for req in chunk {
                    let h = server.submit(req.clone()).expect("admission");
                    h.wait().expect("solve");
                }
            });
        }
    });
}

/// Baseline: the same requests solved directly, one at a time.
fn run_sequential(reqs: &[SolveRequest]) {
    let vdp = VanDerPol::new(0.5);
    let lin = Linear::new(-0.9, 16);
    let conv = ConvFlow::random(8, 8, 11, 0.4);
    for req in reqs {
        let f: &dyn nodal::ode::OdeFunc = match req.dynamics.as_str() {
            "vdp" => &vdp,
            "linear" => &lin,
            _ => &conv,
        };
        let traj = integrate(f, req.t0, req.t1, &req.z0, req.tab, &req.opts()).unwrap();
        if let Some(lam) = &req.grad {
            let g = aca_backward(f, req.tab, &traj, lam);
            std::hint::black_box(g.dl_dz0[0]);
        }
        std::hint::black_box(traj.last()[0]);
    }
}

fn main() {
    let reqs = workload();
    let mut r = Runner::new("serve_load");

    let seq = r.bench("sequential_64req_mixed", || run_sequential(&reqs)).clone();

    let cfg = ServeConfig {
        max_batch_size: 16,
        max_queue_delay: Duration::from_micros(200),
        queue_capacity: 1024,
        workers: nodal::coordinator::pool::default_workers(),
    };
    let server = Arc::new(register(SolveServer::builder()).config(cfg).start());
    let srv = r
        .bench("server_closed_loop_8clients_64req", || run_server_closed_loop(&server, &reqs))
        .clone();

    let m = server.metrics();
    println!("\nserver metrics over the whole bench run:\n{m}");
    let seq_rps = TOTAL as f64 / (seq.mean_ms * 1e-3);
    let srv_rps = TOTAL as f64 / (srv.mean_ms * 1e-3);
    println!(
        "\nthroughput: sequential {seq_rps:.0} req/s vs batched server {srv_rps:.0} req/s \
         ({:.2}x)",
        srv_rps / seq_rps
    );
    if srv_rps < seq_rps {
        println!("WARNING: batched server below the sequential baseline on this host");
    }
    server.shutdown();
}
