//! Closed-loop load test of the solve server: C client threads each keep
//! one request outstanding (submit → wait → submit …) against a mixed
//! workload, versus the sequential one-request-at-a-time baseline the
//! server replaces. The workload is heterogeneous on every axis the former
//! can coalesce: three dynamics, adaptive and fixed-step tolerance classes,
//! a sprinkle of gradient requests, and — since `BatchKey` stopped pinning
//! `t1` — **mixed integration spans** inside each class, so the
//! batch-occupancy numbers show the cross-request span alignment win.
//!
//! Reports throughput for both paths and the server's batching metrics, and
//! persists them (req/s, speedup, mean batch occupancy) via
//! [`Runner::record`] + `Runner::save` to `results/bench/serve_load.jsonl`.
//!
//! `--smoke` shrinks the workload and the sampling budget for CI: the bench
//! still runs end-to-end and appends its JSON lines, so the serve perf
//! trajectory accumulates on every pipeline run alongside the backward
//! pass's (`grad_backward.jsonl`).

use nodal::bench::Runner;
use nodal::grad::aca_backward;
use nodal::ode::analytic::{ConvFlow, Linear, VanDerPol};
use nodal::ode::integrate;
use nodal::serve::{Lane, ServeConfig, SolveRequest, SolveServer};
use nodal::util::Pcg64;
use std::sync::Arc;
use std::time::Duration;

const CLIENTS: usize = 8;

/// The mixed workload: three dynamics, adaptive and fixed-step tolerance
/// classes, a sprinkle of gradient requests — and per-request spans drawn
/// from a small set inside each class, so co-batchable traffic differs in
/// `t1` (the axis the former coalesces across since `BatchKey` dropped it).
/// Per-request cost is deliberately heterogeneous (nfe varies per initial
/// condition *and* per span).
fn workload(total: usize) -> Vec<SolveRequest> {
    let mut rng = Pcg64::seed(20);
    let vdp_spans = [4.0f64, 5.0, 6.0];
    let conv_spans = [1.5f64, 2.0];
    (0..total)
        .map(|i| match i % 4 {
            0 => SolveRequest::adaptive(
                "vdp",
                0.0,
                vdp_spans[i % vdp_spans.len()],
                vec![rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32],
                1e-6,
                1e-8,
            )
            .unwrap(),
            1 => SolveRequest::fixed(
                "linear",
                0.0,
                1.0 + 0.5 * (i % 3) as f64,
                (0..16).map(|_| rng.normal_f32()).collect(),
                0.01,
            )
            .unwrap(),
            2 => SolveRequest::adaptive(
                "conv",
                0.0,
                // (i / 4), not i: class-2 indices are all even, so `i % 2`
                // would alias every conv request to the same span.
                conv_spans[(i / 4) % conv_spans.len()],
                (0..64).map(|_| rng.normal_f32() * 0.5).collect(),
                1e-5,
                1e-7,
            )
            .unwrap(),
            _ => SolveRequest::adaptive(
                "vdp",
                0.0,
                vdp_spans[(i / 4) % vdp_spans.len()],
                vec![rng.range(-2.0, 2.0) as f32, rng.range(-2.0, 2.0) as f32],
                1e-6,
                1e-8,
            )
            .unwrap()
            .with_grad(vec![1.0, 0.0]),
        })
        .collect()
}

fn register(b: nodal::serve::SolveServerBuilder) -> nodal::serve::SolveServerBuilder {
    b.register("vdp", VanDerPol::new(0.5))
        .register("linear", Linear::new(-0.9, 16))
        .register("conv", ConvFlow::random(8, 8, 11, 0.4))
}

/// Closed-loop: each client thread owns a slice of the workload and keeps
/// exactly one request in flight.
fn run_server_closed_loop(server: &Arc<SolveServer>, reqs: &[SolveRequest]) {
    std::thread::scope(|scope| {
        for chunk in reqs.chunks(reqs.len().div_ceil(CLIENTS)) {
            let server = server.clone();
            scope.spawn(move || {
                for req in chunk {
                    let h = server.submit(req.clone()).expect("admission");
                    h.wait().expect("solve");
                }
            });
        }
    });
}

/// Baseline: the same requests solved directly, one at a time.
fn run_sequential(reqs: &[SolveRequest]) {
    let vdp = VanDerPol::new(0.5);
    let lin = Linear::new(-0.9, 16);
    let conv = ConvFlow::random(8, 8, 11, 0.4);
    for req in reqs {
        let f: &dyn nodal::ode::OdeFunc = match req.dynamics.as_str() {
            "vdp" => &vdp,
            "linear" => &lin,
            _ => &conv,
        };
        let traj = integrate(f, req.t0, req.t1, &req.z0, req.tab, &req.opts()).unwrap();
        if let Some(lam) = &req.grad {
            let g = aca_backward(f, req.tab, &traj, lam);
            std::hint::black_box(g.dl_dz0[0]);
        }
        std::hint::black_box(traj.last().unwrap()[0]);
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let total = if smoke { 16 } else { 64 };
    let reqs = workload(total);
    let mut r = Runner::new("serve_load");
    if smoke {
        r.set_target_s(0.05);
    }

    // Labels carry the actual request count so smoke rows in the persisted
    // jsonl are never confused with full-size runs.
    let seq = r.bench(&format!("sequential_{total}req_mixed"), || run_sequential(&reqs)).clone();

    let cfg = ServeConfig {
        max_batch_size: 16,
        max_queue_delay: Duration::from_micros(200),
        queue_capacity: 1024,
        workers: nodal::coordinator::pool::default_workers(),
        ckpt_budget_bytes: 0,
        mem_budget_bytes: 0,
        quota_quantum: 32,
        quota_max_deficit: 128,
    };
    let server = Arc::new(register(SolveServer::builder()).config(cfg).start());
    let srv = r
        .bench(&format!("server_closed_loop_{CLIENTS}clients_{total}req"), || {
            run_server_closed_loop(&server, &reqs)
        })
        .clone();

    let m = server.metrics();
    println!("\nserver metrics over the whole bench run:\n{m}");
    let seq_rps = total as f64 / (seq.mean_ms * 1e-3);
    let srv_rps = total as f64 / (srv.mean_ms * 1e-3);
    println!(
        "\nthroughput: sequential {seq_rps:.0} req/s vs batched server {srv_rps:.0} req/s \
         ({:.2}x)  |  mean batch occupancy {:.2}",
        srv_rps / seq_rps,
        m.mean_batch_size
    );
    if srv_rps < seq_rps {
        println!("WARNING: batched server below the sequential baseline on this host");
    }
    // Persist the serving trajectory: raw timings are already in the result
    // rows; add the derived req/s and the occupancy the span alignment is
    // supposed to move.
    r.record(&format!("sequential_{total}req_rps"), seq_rps);
    r.record(&format!("server_{total}req_rps"), srv_rps);
    r.record("server_speedup_x", srv_rps / seq_rps);
    r.record("mean_batch_occupancy", m.mean_batch_size);
    server.shutdown();

    // QoS phase: the same mixed multi-tenant traffic with explicit
    // priorities — the heavyweight conv sweeps ride the batch lane while
    // vdp/linear stay interactive — against a tight DRR quantum, so no
    // tenant can monopolize emission. Persists the fairness surface the
    // scheduler is supposed to move: per-tenant p99 queue wait + req/s.
    let qos_reqs: Vec<SolveRequest> = workload(total)
        .into_iter()
        .map(|mut req| {
            if req.dynamics == "conv" {
                req.lane = Lane::Batch;
            }
            req
        })
        .collect();
    let qos_cfg = ServeConfig {
        max_batch_size: 16,
        max_queue_delay: Duration::from_micros(200),
        queue_capacity: 1024,
        workers: nodal::coordinator::pool::default_workers(),
        ckpt_budget_bytes: 0,
        mem_budget_bytes: 0,
        quota_quantum: 4,
        quota_max_deficit: 16,
    };
    let qos_server = Arc::new(register(SolveServer::builder()).config(qos_cfg).start());
    let qos = r
        .bench(&format!("server_qos_{CLIENTS}clients_{total}req_mixed_priority"), || {
            run_server_closed_loop(&qos_server, &qos_reqs)
        })
        .clone();
    let qm = qos_server.metrics();
    let qos_rps = total as f64 / (qos.mean_ms * 1e-3);
    println!("\nQoS phase (mixed priority, quantum 4): {qos_rps:.0} req/s");
    for (key, lat) in &qm.per_key_queue_wait {
        println!("  [{key}] queue-wait p99 {:.3} ms (n={})", lat.p99_ms, lat.count);
    }
    r.record(&format!("server_qos_{total}req_rps"), qos_rps);
    for (key, lat) in &qm.per_key_queue_wait {
        r.record(&format!("qos_queue_wait_p99_ms_{key}"), lat.p99_ms);
    }
    qos_server.shutdown();
}
