//! Table 5 workload: one training epoch of the analytic three-body ODE
//! (segmented fwd+bwd over the training year) per gradient method.

use nodal::bench::Runner;
use nodal::data::ThreeBodyDataset;
use nodal::grad::{self, Method};
use nodal::ode::analytic::ThreeBody;
use nodal::ode::{integrate, tableau, IntegrateOpts};

fn main() {
    let ds = ThreeBodyDataset::generate(1, 100);
    let f = ThreeBody::new([0.6, 0.6, 0.6]);
    let tab = tableau::dopri5();
    let mut r = Runner::new("table5_threebody");

    for method in Method::all() {
        let opts = IntegrateOpts {
            record_trials: method == Method::Naive,
            ..IntegrateOpts::with_tol(1e-5, 1e-5)
        };
        r.bench(&format!("epoch_{}", method.name()), || {
            let end = ds.train_end();
            let mut z = ds.states[0].clone();
            let mut segs = Vec::new();
            let mut jumps = Vec::new();
            for k in 1..=end {
                let traj = integrate(&f, ds.times[k - 1], ds.times[k], &z, tab, &opts).unwrap();
                z = traj.last().unwrap().to_vec();
                let target = ds.positions(k);
                let mut lam = vec![0.0f32; 18];
                for j in 0..9 {
                    lam[j] = 2.0 * (z[j] - target[j]) / 9.0;
                }
                segs.push(traj);
                jumps.push(lam);
            }
            let mut lam = vec![0.0f32; 18];
            let mut dm = vec![0.0f32; 3];
            for k in (0..end).rev() {
                for (l, j) in lam.iter_mut().zip(&jumps[k]) {
                    *l += j / end as f32;
                }
                let g = grad::backward(&f, tab, &segs[k], &lam, method, &opts).unwrap();
                lam = g.dl_dz0;
                for (d, s) in dm.iter_mut().zip(&g.dl_dtheta) {
                    *d += s;
                }
            }
            std::hint::black_box(dm[0]);
        });
    }

    r.bench("ground_truth_simulation_2yr", || {
        let t = integrate(
            &ThreeBody::new(ds.masses),
            0.0,
            2.0,
            &ds.z0,
            tab,
            &IntegrateOpts::with_tol(1e-9, 1e-9),
        )
        .unwrap();
        std::hint::black_box(t.nfe);
    });
}
