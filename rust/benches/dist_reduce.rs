//! Distributed gradient-reduction workload: throughput of the fixed
//! adjacent-pairwise tree combine versus the flat sequential fold, and
//! the wire-payload savings of grouped leaf bucketing versus one payload
//! per leaf.
//!
//! Before timing anything the bench asserts the reduction contract on
//! the actual bench inputs: the tree combine is bit-identical across
//! repeats, bit-identical to an independently written power-of-two
//! recursive-halving reference, and the flat fold matches its own
//! sequential reference — determinism is a precondition of the numbers
//! meaning anything.
//!
//! `--smoke` shrinks the timing target for CI; rows append to
//! `results/bench/dist_reduce.jsonl` via `bench::Runner`.

use nodal::bench::Runner;
use nodal::dist::reduce::{
    bucket_leaves, flat_combine, tree_combine, GradLeaf, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES,
};
use nodal::util::Pcg64;

/// Independent reference: recursive halving, which for a power-of-two
/// world is the same association as `tree_combine`'s round-based sweep.
fn halving_reference(partials: &[Vec<f32>]) -> Vec<f32> {
    assert!(partials.len().is_power_of_two());
    if partials.len() == 1 {
        return partials[0].clone();
    }
    let mid = partials.len() / 2;
    let mut left = halving_reference(&partials[..mid]);
    let right = halving_reference(&partials[mid..]);
    for (a, r) in left.iter_mut().zip(&right) {
        *a += *r;
    }
    left
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn partials(world: usize, n: usize, rng: &mut Pcg64) -> Vec<Vec<f32>> {
    (0..world)
        .map(|_| (0..n).map(|_| rng.normal_f32()).collect())
        .collect()
}

fn bench_world(r: &mut Runner, world: usize, n: usize, rng: &mut Pcg64) {
    let p = partials(world, n, rng);

    // ---- determinism assertions BEFORE timing ----
    let tree = tree_combine(&p);
    assert_eq!(bits(&tree), bits(&tree_combine(&p)), "tree must be bit-stable across runs");
    assert_eq!(
        bits(&tree),
        bits(&halving_reference(&p)),
        "tree association must equal recursive halving for a power-of-two world"
    );
    let flat = flat_combine(&p);
    let mut seq = p[0].clone();
    for q in &p[1..] {
        for (a, b) in seq.iter_mut().zip(q) {
            *a += *b;
        }
    }
    assert_eq!(bits(&flat), bits(&seq), "flat fold must equal the sequential reference");

    r.bench(&format!("tree_combine_w{world}_n{n}"), || {
        std::hint::black_box(tree_combine(&p)[0]);
    });
    r.bench(&format!("flat_combine_w{world}_n{n}"), || {
        std::hint::black_box(flat_combine(&p)[0]);
    });
    r.record(&format!("elements_per_reduce_w{world}_n{n}"), (world * n) as f64);
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut r = Runner::new("dist_reduce");
    if smoke {
        r.set_target_s(0.05);
    }
    let mut rng = Pcg64::seed(7);

    // A small model's flattened gradient and a large one's.
    bench_world(&mut r, 8, 1 << 14, &mut rng);
    bench_world(&mut r, 8, 1 << 18, &mut rng);

    // Payload counts: many small leaves plus a couple of large tensors —
    // the shape grouped bucketing exists for.
    let mut leaves: Vec<GradLeaf> = Vec::new();
    for i in 0..24 {
        let n = 64 << (i % 6); // 64..=2048 floats, all under the threshold
        leaves.push(GradLeaf::new(&format!("small{i}"), (0..n).map(|j| j as f32).collect()));
    }
    for i in 0..2 {
        leaves.push(GradLeaf::new(&format!("large{i}"), vec![1.0; 32 * 1024]));
    }
    let grouped = bucket_leaves(&leaves, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES).len();
    assert!(grouped < leaves.len(), "bucketing must merge the small leaves");
    println!(
        "payloads: {} per-leaf -> {} grouped (threshold {} KiB)",
        leaves.len(),
        grouped,
        DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES / 1024
    );
    r.record("payloads_per_leaf", leaves.len() as f64);
    r.record("payloads_grouped", grouped as f64);
    // Runner::drop saves results/bench/dist_reduce.jsonl.
}
