//! Fig 4 workload: forward + reverse van der Pol integration (the
//! trajectory-reconstruction experiment) at the paper's tolerances.

use nodal::bench::Runner;
use nodal::ode::analytic::VanDerPol;
use nodal::ode::{integrate, tableau, IntegrateOpts};

fn main() {
    let mut r = Runner::new("fig4_reverse");
    let f = VanDerPol::new(0.15);
    let z0 = [2.0f32, 0.0];
    for (name, rtol, atol) in [("loose_1e-3", 1e-3, 1e-6), ("tight_1e-9", 1e-9, 1e-12)] {
        let opts = IntegrateOpts::with_tol(rtol, atol);
        r.bench(&format!("fwd_rev_t25_{name}"), || {
            let fwd = integrate(&f, 0.0, 25.0, &z0, tableau::dopri5(), &opts).unwrap();
            let zt = fwd.last().unwrap();
            let rev = integrate(&f, 25.0, 0.0, zt, tableau::dopri5(), &opts).unwrap();
            std::hint::black_box(rev.last().unwrap()[0]);
        });
    }
}
