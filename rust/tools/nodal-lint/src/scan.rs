//! Brace-aware region scanner and the per-file half of the rule engine.
//!
//! The scanner walks the token stream once, maintaining a stack of region
//! contexts (fn / impl / mod / block). A region inherits its parent's
//! context — test-ness, hot-ness, enclosing `OdeFunc` impl target — so a
//! check at any token only needs the top of the stack.
//!
//! Region classification reads the "header": the tokens accumulated since
//! the last `{`, `}`, or statement-level `;`. `fn` is checked before
//! `impl` so `impl Trait` in a signature does not misclassify a function
//! as an impl block.

use std::collections::BTreeSet;

use crate::graph::{AcqFact, AllocFact, CallFact, FnFact};
use crate::lexer::{lex, Tok, TokKind};
use crate::{Diagnostic, RULES, R_DET, R_DIRECTIVE, R_ENV, R_HOT, R_PANIC, R_WIRE};

/// A `// nodal-lint: allow(<rule>) <reason>` span. Covers the directive's
/// own line and the next one, so it works both trailing and stand-alone.
#[derive(Debug, Clone)]
pub struct AllowSpan {
    pub rule: String,
    pub lo: u32,
    pub hi: u32,
}

/// Everything the cross-file pass needs from one file.
#[derive(Debug, Default)]
pub struct FileFacts {
    /// Local diagnostics, already filtered through this file's allows.
    pub diags: Vec<Diagnostic>,
    /// Count of locally suppressed diagnostics.
    pub suppressed: usize,
    /// Allow spans, kept for cross-file rules (parity, knob table).
    pub allows: Vec<AllowSpan>,
    /// Non-test `OdeFunc` impls overriding `eval_batch`/`vjp_batch`:
    /// (target type name, line of the overriding fn).
    pub overriders: Vec<(String, u32)>,
    /// Identifiers appearing inside bit-equality test functions.
    pub bit_idents: BTreeSet<String>,
    /// `NODAL_*` names found in string literals: (name, line).
    pub knob_lits: Vec<(String, u32)>,
    /// Per-function facts (calls, lock acquisitions, allocation sites)
    /// consumed by the interprocedural pass in `graph`.
    pub fns: Vec<FnFact>,
}

/// Designated parse-and-clamp helpers: the only non-test places allowed to
/// read the environment. Matched as (`/`-anchored path suffix, fn name).
const ENV_HELPERS: &[(&str, &str)] = &[
    ("pool.rs", "default_workers"),
    ("report.rs", "results_dir"),
    ("runtime/mod.rs", "artifact_root"),
    ("ckpt/mod.rs", "parse_budget_env"),
    ("ckpt/mod.rs", "env_budget_bytes"),
    ("serve/mod.rs", "env_clamped"),
    ("serve/http.rs", "env_clamped"),
    ("obs/mod.rs", "trace_env"),
    ("dist/env.rs", "from_env"),
    ("dist/env.rs", "env_usize"),
];

/// Methods whose `.unwrap()` propagates poison rather than encoding a
/// fallible assumption — the one panic idiom `serve/` is allowed.
const POISON_METHODS: &[&str] =
    &["lock", "read", "write", "wait", "wait_while", "wait_timeout", "wait_timeout_while"];

#[derive(Clone)]
struct Ctx {
    is_test: bool,
    hot: bool,
    clock_impl: bool,
    fn_name: Option<String>,
    odefunc_target: Option<String>,
    bit_test: bool,
    /// Enclosing impl's owner type, for the symbol table.
    owner: Option<String>,
    /// Index into `FileFacts::fns` of the enclosing function, if any.
    /// Closures and nested blocks inherit it, so their facts are
    /// attributed to the enclosing named function.
    fn_idx: Option<usize>,
}

/// How long a `.lock().unwrap()` guard lives, by the statement shape it
/// was created in. The model matches Rust temporary-lifetime rules:
/// a `let g = …;` binding lives to end of block (or `drop(g)`), a plain
/// `if`/`while` condition temporary dies at the body `{`, an
/// `if let`/`while let`/`for`/`match` scrutinee temporary lives through
/// the construct's body, and any other temporary dies at the `;`.
#[derive(Clone, Copy, PartialEq)]
enum GKind {
    Named,
    TempStmt,
    TempCond,
    TempConstruct,
}

struct Guard {
    /// Field/binding the mutex was reached through (`writer.lock()` →
    /// `writer`) — the identity used for held-set and order tracking.
    field: String,
    /// `let` binding name for `drop(binding)` detection (Named only).
    binding: Option<String>,
    /// Brace depth the guard's lifetime is anchored to.
    depth: i32,
    kind: GKind,
    /// TempConstruct: body `{` has been entered.
    entered: bool,
}

/// Statement shape of the header a lock acquisition appears in.
enum StmtShape {
    Let { binding: Option<String> },
    Cond,
    Construct,
    Plain,
}

fn stmt_shape(toks: &[Tok], header: &[usize]) -> StmtShape {
    let text = |k: usize| toks[header[k]].text.as_str();
    if header.is_empty() {
        return StmtShape::Plain;
    }
    match text(0) {
        "let" => {
            let mut k = 1;
            if header.len() > k && text(k) == "mut" {
                k += 1;
            }
            let mut binding = None;
            if header.len() > k + 1
                && toks[header[k]].kind == TokKind::Ident
                && matches!(text(k + 1), "=" | ":")
            {
                binding = Some(text(k).to_string());
            }
            StmtShape::Let { binding }
        }
        "if" | "while" => {
            if header.len() > 1 && text(1) == "let" {
                StmtShape::Construct
            } else {
                StmtShape::Cond
            }
        }
        "for" | "match" => StmtShape::Construct,
        _ => StmtShape::Plain,
    }
}

/// Deduplicated lock fields currently held, in acquisition order.
fn held_fields(guards: &[Guard]) -> Vec<String> {
    let mut h: Vec<String> = Vec::new();
    for g in guards {
        if !h.iter().any(|f| f == &g.field) {
            h.push(g.field.clone());
        }
    }
    h
}

/// Identifiers that look like calls but are control flow / binders.
const CALL_KEYWORDS: &[&str] = &[
    "if", "while", "for", "match", "return", "loop", "in", "as", "move", "ref", "break",
    "continue", "else", "unsafe", "where", "impl", "use", "pub", "let", "mut", "fn", "struct",
    "enum", "trait", "const", "static", "type", "mod", "crate", "super", "self", "Self", "dyn",
    "await", "true", "false",
];

/// Walk backward from the last token of an expression to the start of its
/// postfix chain (idents, field/method `.`s, `::` pairs, balanced
/// `(…)`/`[…]` groups). Used by the wire-determinism `.into()` check.
fn receiver_chain_start(toks: &[Tok], hi: usize) -> usize {
    let mut j = hi as isize;
    while j >= 0 {
        let t = &toks[j as usize];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, ")") | (TokKind::Punct, "]") => {
                let (open, close) = if t.text == ")" { ("(", ")") } else { ("[", "]") };
                let mut depth = 1i32;
                loop {
                    j -= 1;
                    if j < 0 {
                        return 0;
                    }
                    let u = &toks[j as usize];
                    if u.text == close {
                        depth += 1;
                    } else if u.text == open {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                }
                j -= 1;
            }
            (TokKind::Ident, _) | (TokKind::Num, _) | (TokKind::Str, _) => j -= 1,
            (TokKind::Punct, ".") => j -= 1,
            (TokKind::Punct, ":")
                if j >= 1 && toks[(j - 1) as usize].text == ":" =>
            {
                j -= 2;
            }
            _ => break,
        }
    }
    (j + 1) as usize
}

/// Does the token span contain a float value? (f32/f64-suffixed literal,
/// a `N.N` literal, or an `as f32`/`as f64` cast.)
fn span_has_float(toks: &[Tok], lo: usize, hi: usize) -> bool {
    let mut k = lo;
    while k <= hi && k < toks.len() {
        let t = &toks[k];
        if t.kind == TokKind::Num && (t.text.contains("f32") || t.text.contains("f64")) {
            return true;
        }
        if t.kind == TokKind::Num
            && k + 2 <= hi
            && toks[k + 1].text == "."
            && toks[k + 2].kind == TokKind::Num
        {
            return true;
        }
        if t.kind == TokKind::Ident
            && t.text == "as"
            && k + 1 <= hi
            && matches!(toks[k + 1].text.as_str(), "f32" | "f64")
        {
            return true;
        }
        k += 1;
    }
    false
}

/// Does a test-fn name advertise a bit-equality / parity check?
/// Underscore-split for the short markers so `orbit` does not match `bit`.
pub fn is_bit_marker(name: &str) -> bool {
    name.split('_').any(|p| matches!(p, "bit" | "bitwise" | "bitexact"))
        || name.contains("matches_scalar")
        || name.contains("parity")
        || name.contains("identical")
}

/// Extract every `NODAL_[A-Z0-9_]*` name from raw text. Used both on
/// string-literal contents and on the raw lib.rs source (the knob table
/// lives in doc comments, which never reach the token stream).
pub fn knob_names(s: &str) -> Vec<String> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut k = 0usize;
    while k + 6 <= b.len() {
        if &b[k..k + 6] == b"NODAL_" {
            let mut end = k;
            while end < b.len()
                && (b[end].is_ascii_uppercase() || b[end].is_ascii_digit() || b[end] == b'_')
            {
                end += 1;
            }
            out.push(String::from_utf8_lossy(&b[k..end]).into_owned());
            k = end;
        } else {
            k += 1;
        }
    }
    out
}

fn is_env_designated(path: &str, fn_name: Option<&str>) -> bool {
    let Some(f) = fn_name else { return false };
    ENV_HELPERS
        .iter()
        .any(|(suf, h)| f == *h && (path == *suf || path.ends_with(&format!("/{suf}"))))
}

fn diag(rule: &'static str, path: &str, line: u32, msg: String) -> Diagnostic {
    Diagnostic { rule, path: path.to_string(), line, msg }
}

/// Back-scan from an `unwrap`/`expect` ident (preceded by `.`) to the
/// method owning the receiver call: `x.lock().unwrap()` → `lock`.
fn is_poison_receiver(toks: &[Tok], i: usize) -> bool {
    if i < 3 || toks[i - 2].text != ")" {
        return false;
    }
    let mut depth = 1i32;
    let mut j = i - 2;
    while j > 0 {
        j -= 1;
        match toks[j].text.as_str() {
            ")" => depth += 1,
            "(" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
    }
    if depth != 0 || j == 0 {
        return false;
    }
    let m = &toks[j - 1];
    m.kind == TokKind::Ident && POISON_METHODS.contains(&m.text.as_str())
}

pub fn scan_file(path: &str, src: &str) -> FileFacts {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let comment_lines: BTreeSet<u32> = lexed.comments.iter().map(|c| c.line).collect();

    let file_is_test = path.contains("/tests/") || path.starts_with("tests/");
    let det_file_exempt = path.ends_with("bench.rs")
        || path.ends_with("util/timer.rs")
        || path.contains("/benches/")
        || path.starts_with("benches/");
    let in_serve = path.contains("src/serve/");
    let in_dist = path.contains("src/dist/");
    let in_det_mods =
        ["src/ode/", "src/grad/", "src/ckpt/"].iter().any(|m| path.contains(m));

    // ---- directives ----
    let mut hot_markers: Vec<u32> = Vec::new();
    let mut allows: Vec<AllowSpan> = Vec::new();
    // Directive diagnostics are never themselves suppressible.
    let mut diags: Vec<Diagnostic> = Vec::new();
    // Rule diagnostics, pre-suppression.
    let mut raw: Vec<Diagnostic> = Vec::new();

    for c in &lexed.comments {
        let Some(rest) = c.text.strip_prefix("nodal-lint:") else { continue };
        let rest = rest.trim();
        if rest == "hot" {
            hot_markers.push(c.line);
            continue;
        }
        if let Some(arg) = rest.strip_prefix("allow(") {
            match arg.split_once(')') {
                Some((rule, reason)) => {
                    let rule = rule.trim();
                    if !RULES.contains(&rule) {
                        diags.push(diag(
                            R_DIRECTIVE,
                            path,
                            c.line,
                            format!("allow names unknown rule `{rule}`"),
                        ));
                    } else if reason.trim().is_empty() {
                        diags.push(diag(
                            R_DIRECTIVE,
                            path,
                            c.line,
                            format!("allow({rule}) requires a reason after the closing paren"),
                        ));
                    } else {
                        allows.push(AllowSpan {
                            rule: rule.to_string(),
                            lo: c.line,
                            hi: c.line + 1,
                        });
                    }
                }
                None => diags.push(diag(
                    R_DIRECTIVE,
                    path,
                    c.line,
                    "malformed allow directive: missing `)`".to_string(),
                )),
            }
            continue;
        }
        diags.push(diag(
            R_DIRECTIVE,
            path,
            c.line,
            format!("unknown nodal-lint directive `{rest}`"),
        ));
    }
    hot_markers.sort_unstable();
    let mut hot_iter = hot_markers.into_iter().peekable();

    // ---- single-pass region walk + checks ----
    let root = Ctx {
        is_test: file_is_test,
        hot: false,
        clock_impl: false,
        fn_name: None,
        odefunc_target: None,
        bit_test: false,
        owner: None,
        fn_idx: None,
    };
    let mut stack: Vec<Ctx> = vec![root];
    let mut header: Vec<usize> = Vec::new();
    let mut attrs = String::new();
    let mut paren = 0i32;
    let mut brack = 0i32;

    let mut overriders: Vec<(String, u32)> = Vec::new();
    let mut bit_idents: BTreeSet<String> = BTreeSet::new();
    let mut knob_lits: Vec<(String, u32)> = Vec::new();
    let mut fns: Vec<FnFact> = Vec::new();
    // Live mutex guards (the lock-discipline lifetime model) and the
    // brace depth their lifetimes are anchored to.
    let mut guards: Vec<Guard> = Vec::new();
    let mut bdepth = 0i32;

    let ident_text = |ix: usize| -> Option<&str> {
        toks.get(ix).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
    };
    let punct_is = |ix: usize, s: &str| toks.get(ix).is_some_and(|t| t.text == s);

    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];

        // Consume attributes `#[...]` / `#![...]`; outer attrs are stashed
        // for the next region's classification, inner attrs discarded.
        if t.kind == TokKind::Punct && t.text == "#" {
            let (inner, lb) = if punct_is(i + 1, "[") {
                (false, i + 1)
            } else if punct_is(i + 1, "!") && punct_is(i + 2, "[") {
                (true, i + 2)
            } else {
                (false, usize::MAX)
            };
            if lb != usize::MAX {
                let mut depth = 0i32;
                let mut j = lb;
                let mut captured = String::new();
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    if toks[j].kind == TokKind::Ident {
                        captured.push_str(&toks[j].text);
                        captured.push(' ');
                    }
                    j += 1;
                }
                if !inner {
                    attrs.push_str(&captured);
                }
                i = j;
                continue;
            }
        }

        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "{") => {
                bdepth += 1;
                // A plain if/while condition temporary dies at the body
                // brace; a construct scrutinee temporary enters its body.
                guards.retain(|g| g.kind != GKind::TempCond);
                for g in guards.iter_mut() {
                    if g.kind == GKind::TempConstruct && !g.entered {
                        g.entered = true;
                        g.depth = bdepth;
                    }
                }
                let mut ctx = classify(
                    toks,
                    &header,
                    &attrs,
                    stack.last().expect("ctx stack never empty"),
                    t.line,
                    &mut overriders,
                    path,
                    &mut fns,
                );
                if let Some(&m) = hot_iter.peek() {
                    if m <= t.line {
                        hot_iter.next();
                        ctx.hot = true;
                    }
                }
                stack.push(ctx);
                header.clear();
                attrs.clear();
            }
            (TokKind::Punct, "}") => {
                bdepth = (bdepth - 1).max(0);
                let into_else = punct_is(i + 1, "else");
                guards.retain(|g| match g.kind {
                    GKind::Named | GKind::TempStmt => g.depth <= bdepth,
                    GKind::TempConstruct => {
                        !g.entered || g.depth <= bdepth || into_else
                    }
                    GKind::TempCond => false,
                });
                if stack.len() > 1 {
                    stack.pop();
                }
                header.clear();
            }
            (TokKind::Punct, ";") if paren == 0 && brack == 0 => {
                guards.retain(|g| !(g.kind == GKind::TempStmt && g.depth >= bdepth));
                header.clear();
                attrs.clear();
            }
            _ => {
                match t.text.as_str() {
                    "(" => paren += 1,
                    ")" => paren -= 1,
                    "[" => brack += 1,
                    "]" => brack -= 1,
                    _ => {}
                }
                let ctx = stack.last().expect("ctx stack never empty");

                if ctx.bit_test && t.kind == TokKind::Ident {
                    bit_idents.insert(t.text.clone());
                }

                // Rule 1a: env reads outside designated helpers.
                if t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "var" | "var_os" | "vars")
                    && punct_is(i.wrapping_sub(1), ":")
                    && punct_is(i.wrapping_sub(2), ":")
                    && ident_text(i.wrapping_sub(3)) == Some("env")
                    && !ctx.is_test
                    && !is_env_designated(path, ctx.fn_name.as_deref())
                {
                    raw.push(diag(
                        R_ENV,
                        path,
                        t.line,
                        format!(
                            "env::{} outside a designated parse-and-clamp helper",
                            t.text
                        ),
                    ));
                }

                // Rule 1b (cross-file half): collect NODAL_* string literals.
                if t.kind == TokKind::Str && t.text.contains("NODAL_") {
                    for name in knob_names(&t.text) {
                        knob_lits.push((name, t.line));
                    }
                }

                // Rule 2a: wall-clock reads.
                if t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "Instant" | "SystemTime")
                    && punct_is(i + 1, ":")
                    && punct_is(i + 2, ":")
                    && ident_text(i + 3) == Some("now")
                    && !det_file_exempt
                    && !ctx.clock_impl
                    && !ctx.is_test
                {
                    raw.push(diag(
                        R_DET,
                        path,
                        t.line,
                        format!(
                            "{}::now outside a Clock impl, bench.rs, or util/timer.rs",
                            t.text
                        ),
                    ));
                }

                // Rule 2b: hashed collections in result-affecting modules.
                if t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "HashMap" | "HashSet")
                    && in_det_mods
                    && !ctx.is_test
                {
                    raw.push(diag(
                        R_DET,
                        path,
                        t.line,
                        format!(
                            "{} in a result-affecting module: iteration order can \
                             change float accumulation; use BTreeMap/BTreeSet or Vec",
                            t.text
                        ),
                    ));
                }

                // Rule 3: allocations inside `// nodal-lint: hot` regions.
                // The family match also feeds the per-function alloc facts
                // that rule 8 (transitive hot-alloc) checks via the graph.
                if t.kind == TokKind::Ident {
                    let alloc: Option<String> = if t.text == "vec" && punct_is(i + 1, "!") {
                        Some("vec!".to_string())
                    } else if matches!(t.text.as_str(), "Vec" | "Box" | "String")
                        && punct_is(i + 1, ":")
                        && punct_is(i + 2, ":")
                    {
                        match (t.text.as_str(), ident_text(i + 3)) {
                            ("Vec", Some(m @ ("new" | "with_capacity" | "from")))
                            | ("Box", Some(m @ "new"))
                            | ("String", Some(m @ ("new" | "with_capacity" | "from"))) => {
                                Some(format!("{}::{m}", t.text))
                            }
                            _ => None,
                        }
                    } else if punct_is(i.wrapping_sub(1), ".")
                        && matches!(
                            t.text.as_str(),
                            "to_vec" | "collect" | "clone" | "to_owned" | "to_string"
                        )
                    {
                        Some(format!(".{}()", t.text))
                    } else {
                        None
                    };
                    if let Some(what) = alloc {
                        if ctx.hot {
                            raw.push(diag(
                                R_HOT,
                                path,
                                t.line,
                                format!(
                                    "{what} inside a hot region; hoist into reusable scratch"
                                ),
                            ));
                        }
                        if let Some(fi) = ctx.fn_idx {
                            if !ctx.is_test {
                                fns[fi].allocs.push(AllocFact {
                                    what,
                                    line: t.line,
                                    in_hot: ctx.hot,
                                });
                            }
                        }
                    }
                }

                // Guard lifetimes: `drop(binding)` releases a named guard.
                if t.kind == TokKind::Ident
                    && t.text == "drop"
                    && punct_is(i + 1, "(")
                    && punct_is(i + 3, ")")
                {
                    if let Some(b) = ident_text(i + 2) {
                        guards.retain(|g| g.binding.as_deref() != Some(b));
                    }
                }

                // Guard acquisition: `<field>.lock().unwrap()` (or
                // `.expect(…)`). The statement shape decides the lifetime.
                if t.kind == TokKind::Ident
                    && t.text == "lock"
                    && punct_is(i.wrapping_sub(1), ".")
                    && punct_is(i + 1, "(")
                    && punct_is(i + 2, ")")
                    && punct_is(i + 3, ".")
                    && toks.get(i + 4).is_some_and(|u| {
                        u.kind == TokKind::Ident
                            && matches!(u.text.as_str(), "unwrap" | "expect")
                    })
                    && punct_is(i + 5, "(")
                {
                    // End of the unwrap/expect call: balanced scan.
                    let mut depth = 0i32;
                    let mut j = i + 5;
                    let mut end = usize::MAX;
                    while j < toks.len() {
                        match toks[j].text.as_str() {
                            "(" => depth += 1,
                            ")" => {
                                depth -= 1;
                                if depth == 0 {
                                    end = j;
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    if end != usize::MAX {
                        let field = ident_text(i.wrapping_sub(2))
                            .unwrap_or("<expr>")
                            .to_string();
                        let held = held_fields(&guards);
                        if let Some(fi) = ctx.fn_idx {
                            fns[fi].acqs.push(AcqFact {
                                field: field.clone(),
                                line: t.line,
                                held: held.clone(),
                            });
                        }
                        let mut binding = None;
                        let kind = match stmt_shape(toks, &header) {
                            StmtShape::Let { binding: Some(b) }
                                if b != "_" && punct_is(end + 1, ";") =>
                            {
                                binding = Some(b);
                                GKind::Named
                            }
                            StmtShape::Cond => GKind::TempCond,
                            StmtShape::Construct => GKind::TempConstruct,
                            _ => GKind::TempStmt,
                        };
                        guards.push(Guard {
                            field,
                            binding,
                            depth: bdepth,
                            kind,
                            entered: false,
                        });
                    }
                }

                // Call sites, for the graph: `name(…)`, `a::b::name(…)`,
                // `recv.name(…)`. Closure bodies attribute to the
                // enclosing named function via the inherited fn_idx.
                if t.kind == TokKind::Ident
                    && punct_is(i + 1, "(")
                    && !CALL_KEYWORDS.contains(&t.text.as_str())
                    && !(i >= 1
                        && toks[i - 1].kind == TokKind::Ident
                        && toks[i - 1].text == "fn")
                {
                    if let Some(fi) = ctx.fn_idx {
                        let method = punct_is(i.wrapping_sub(1), ".");
                        let mut quals: Vec<String> = Vec::new();
                        if !method {
                            let mut j = i;
                            while j >= 3
                                && toks[j - 1].text == ":"
                                && toks[j - 2].text == ":"
                                && toks[j - 3].kind == TokKind::Ident
                            {
                                quals.push(toks[j - 3].text.clone());
                                j -= 3;
                            }
                            quals.reverse();
                        }
                        let recv_self = method
                            && i >= 2
                            && toks[i - 2].kind == TokKind::Ident
                            && toks[i - 2].text == "self";
                        fns[fi].calls.push(CallFact {
                            name: t.text.clone(),
                            quals,
                            method,
                            recv_self,
                            line: t.line,
                            held: held_fields(&guards),
                            in_hot: ctx.hot,
                        });
                    }
                }

                // Rule 7: wire determinism in dist/ — floats must reach
                // the transport as u32/u64 bit patterns, never as JSON
                // float numbers.
                if in_dist && !ctx.is_test && t.kind == TokKind::Ident {
                    if t.text == "Json"
                        && punct_is(i + 1, ":")
                        && punct_is(i + 2, ":")
                        && ident_text(i + 3) == Some("Num")
                    {
                        raw.push(diag(
                            R_WIRE,
                            path,
                            t.line,
                            "Json::Num in dist/ puts a float on the wire; use the \
                             u32/u64 bit-pattern helpers (util::json::f32_bits)"
                                .to_string(),
                        ));
                    }
                    if t.text == "as_f64"
                        && punct_is(i.wrapping_sub(1), ".")
                        && punct_is(i + 1, "(")
                    {
                        raw.push(diag(
                            R_WIRE,
                            path,
                            t.line,
                            ".as_f64() in dist/ reads a float JSON number off the \
                             wire; decode bit patterns instead"
                                .to_string(),
                        ));
                    }
                    if t.text == "into"
                        && punct_is(i.wrapping_sub(1), ".")
                        && punct_is(i + 1, "(")
                        && punct_is(i + 2, ")")
                        && i >= 2
                    {
                        let hi = i - 2;
                        let lo = receiver_chain_start(toks, hi);
                        if span_has_float(toks, lo, hi) {
                            raw.push(diag(
                                R_WIRE,
                                path,
                                t.line,
                                "float value reaches Json via .into() in dist/; route \
                                 through the bit-pattern helpers"
                                    .to_string(),
                            ));
                        }
                    }
                }

                // Rule 4: panic isolation in serve/ and non-test dist/.
                if (in_serve || in_dist) && !ctx.is_test {
                    let scope = if in_serve { "serve request-handling" } else { "dist" };
                    if t.kind == TokKind::Ident
                        && matches!(
                            t.text.as_str(),
                            "panic" | "unreachable" | "todo" | "unimplemented"
                        )
                        && punct_is(i + 1, "!")
                    {
                        raw.push(diag(
                            R_PANIC,
                            path,
                            t.line,
                            format!("{}! in {scope} code", t.text),
                        ));
                    }
                    if t.kind == TokKind::Ident
                        && matches!(t.text.as_str(), "unwrap" | "expect")
                        && punct_is(i.wrapping_sub(1), ".")
                        && !is_poison_receiver(toks, i)
                    {
                        raw.push(diag(
                            R_PANIC,
                            path,
                            t.line,
                            format!(
                                ".{}() in {scope} code; return an error or route to \
                                 the per-sample fallback",
                                t.text
                            ),
                        ));
                    }
                    // Constant index `x[0]` without a bound comment on this
                    // or the preceding line.
                    if t.kind == TokKind::Punct
                        && t.text == "["
                        && toks.get(i.wrapping_sub(1)).is_some_and(|p| {
                            p.kind == TokKind::Ident || p.text == "]" || p.text == ")"
                        })
                        && toks.get(i + 1).is_some_and(|n| n.kind == TokKind::Num)
                        && punct_is(i + 2, "]")
                        && !comment_lines.contains(&t.line)
                        && !(t.line > 1 && comment_lines.contains(&(t.line - 1)))
                    {
                        raw.push(diag(
                            R_PANIC,
                            path,
                            t.line,
                            format!(
                                "constant index in {} code without a bound comment \
                                 justifying non-emptiness",
                                if in_serve { "serve" } else { "dist" }
                            ),
                        ));
                    }
                }

                header.push(i);
            }
        }
        i += 1;
    }

    // ---- apply allows to local rule diagnostics ----
    let mut suppressed = 0usize;
    for d in raw {
        if allows.iter().any(|a| a.rule == d.rule && a.lo <= d.line && d.line <= a.hi) {
            suppressed += 1;
        } else {
            diags.push(d);
        }
    }

    FileFacts { diags, suppressed, allows, overriders, bit_idents, knob_lits, fns }
}

/// Classify the region a `{` opens, from the header tokens accumulated
/// since the last region boundary plus the pending outer attributes.
/// Function regions also register a `FnFact` in the symbol table.
#[allow(clippy::too_many_arguments)]
fn classify(
    toks: &[Tok],
    header: &[usize],
    attrs: &str,
    parent: &Ctx,
    line: u32,
    overriders: &mut Vec<(String, u32)>,
    path: &str,
    fns: &mut Vec<FnFact>,
) -> Ctx {
    let mut c = parent.clone();
    let kw = |k: &str| {
        header
            .iter()
            .position(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == k)
    };
    let next_ident_after = |p: usize| -> Option<String> {
        header[p + 1..]
            .iter()
            .find(|&&ix| toks[ix].kind == TokKind::Ident)
            .map(|&ix| toks[ix].text.clone())
    };
    let attr_test = attrs.split_whitespace().any(|w| w == "test");

    // `fn` before `impl`: an `impl Trait` in a signature must not turn a
    // function into an impl region.
    if let Some(p) = kw("fn") {
        let name = next_ident_after(p);
        c.fn_name = name.clone();
        if attr_test {
            c.is_test = true;
        }
        if let (Some(target), Some(n)) = (c.odefunc_target.as_ref(), name.as_deref()) {
            if !c.is_test && matches!(n, "eval_batch" | "vjp_batch") {
                overriders.push((target.clone(), line));
            }
        }
        if c.is_test && name.as_deref().is_some_and(is_bit_marker) {
            c.bit_test = true;
        }
        if let Some(n) = name {
            fns.push(FnFact {
                name: n,
                owner: c.owner.clone(),
                path: path.to_string(),
                line,
                is_test: c.is_test,
                calls: Vec::new(),
                acqs: Vec::new(),
                allocs: Vec::new(),
            });
            c.fn_idx = Some(fns.len() - 1);
        }
        return c;
    }
    if let Some(p) = kw("impl") {
        if header
            .iter()
            .any(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text.contains("Clock"))
        {
            c.clock_impl = true;
        }
        let has_odefunc = header
            .iter()
            .any(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == "OdeFunc");
        c.odefunc_target = None;
        if has_odefunc {
            // `impl<F: OdeFunc> OdeFunc for Wrapper<F>`: the target is the
            // first ident after the last `for` (skipping `&`, `mut`).
            if let Some(fp) = header
                .iter()
                .rposition(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == "for")
            {
                c.odefunc_target = next_ident_after(fp).filter(|t| t != "mut");
            }
        }
        // General impl owner, for the symbol table: the first ident after
        // the last `for` (trait impls), else the first ident after the
        // `impl` keyword's generic parameter list (inherent impls).
        let mut q = p + 1;
        if header.len() > q && toks[header[q]].text == "<" {
            let mut d = 0i32;
            while q < header.len() {
                match toks[header[q]].text.as_str() {
                    "<" => d += 1,
                    ">" => {
                        d -= 1;
                        if d == 0 {
                            q += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                q += 1;
            }
        }
        c.owner = if let Some(fp) = header
            .iter()
            .rposition(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == "for")
        {
            next_ident_after(fp).filter(|t| t != "mut")
        } else {
            header[q..]
                .iter()
                .find(|&&ix| toks[ix].kind == TokKind::Ident)
                .map(|&ix| toks[ix].text.clone())
        };
        return c;
    }
    if let Some(p) = kw("mod") {
        if attr_test || next_ident_after(p).as_deref() == Some("tests") {
            c.is_test = true;
        }
        return c;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_markers_split_on_underscores() {
        assert!(is_bit_marker("vjp_batch_bit_identical_to_scalar"));
        assert!(is_bit_marker("default_eval_batch_matches_scalar_and_counts"));
        assert!(is_bit_marker("thinned_parity_roundtrip"));
        assert!(!is_bit_marker("orbit_energy_drift"));
        assert!(!is_bit_marker("habit_tracker"));
    }

    #[test]
    fn knob_extraction() {
        let names = knob_names("set NODAL_WORKERS and NODAL_SERVE_MAX_BATCH=4");
        assert_eq!(names, vec!["NODAL_WORKERS", "NODAL_SERVE_MAX_BATCH"]);
    }

    #[test]
    fn env_read_flagged_outside_designated_helper() {
        let f = scan_file(
            "rust/src/ode/mod.rs",
            "fn sneak() -> usize { std::env::var(\"NODAL_WORKERS\").is_ok() as usize }",
        );
        assert_eq!(f.diags.len(), 1, "{:?}", f.diags);
        assert_eq!(f.diags[0].rule, R_ENV);
    }

    #[test]
    fn env_read_ok_in_designated_helper_and_tests() {
        let f = scan_file(
            "rust/src/pool.rs",
            "pub fn default_workers() -> usize { std::env::var(\"NODAL_WORKERS\").map_or(1, |_| 2) }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
        let f = scan_file(
            "rust/src/pool.rs",
            "#[cfg(test)] mod tests { #[test] fn t() { std::env::var(\"NODAL_WORKERS\").ok(); } }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn instant_now_flagged_except_clock_impl() {
        let f = scan_file(
            "rust/src/serve/batcher.rs",
            "fn t() -> Instant { std::time::Instant::now() }",
        );
        assert_eq!(f.diags.len(), 1);
        assert_eq!(f.diags[0].rule, R_DET);
        let f = scan_file(
            "rust/src/serve/mod.rs",
            "impl Clock for WallClock { fn now(&self) -> Instant { Instant::now() } }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
        // `impl Default for WallClock` is also a Clock-typed impl.
        let f = scan_file(
            "rust/src/serve/mod.rs",
            "impl Default for WallClock { fn default() -> Self { WallClock(Instant::now()) } }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn hashmap_flagged_only_in_det_modules() {
        let f = scan_file("rust/src/grad/adjoint.rs", "use std::collections::HashMap;");
        assert_eq!(f.diags.len(), 1);
        let f = scan_file("rust/src/serve/registry.rs", "use std::collections::HashMap;");
        assert!(f.diags.is_empty());
    }

    #[test]
    fn hot_region_catches_alloc_families() {
        let src = "// nodal-lint: hot\nfn step() {\n let a = vec![0.0];\n let b: Vec<f32> = Vec::new();\n let c = xs.to_vec();\n let d = xs.iter().collect();\n let e = xs.clone();\n let f = Box::new(1);\n let g = Vec::with_capacity(4);\n}\nfn cold() { let a = vec![1]; }";
        let f = scan_file("rust/src/ode/step.rs", src);
        let hot: Vec<_> = f.diags.iter().filter(|d| d.rule == R_HOT).collect();
        assert_eq!(hot.len(), 7, "{:?}", f.diags);
    }

    #[test]
    fn hot_marker_attaches_to_loop_braces_too() {
        let src = "fn run() {\n // nodal-lint: hot\n while go {\n buf.push(x.clone());\n }\n let post = y.clone();\n}";
        let f = scan_file("rust/src/grad/batch.rs", src);
        assert_eq!(f.diags.len(), 1, "{:?}", f.diags);
        assert_eq!(f.diags[0].line, 4);
    }

    #[test]
    fn serve_panics_flagged_poison_allowed() {
        let src = "fn go(&self) {\n let g = self.inner.lock().unwrap();\n let v = item.grad.as_ref().unwrap();\n let w = item.grad.as_ref().expect(\"grad\");\n panic!(\"boom\");\n}";
        let f = scan_file("rust/src/serve/worker.rs", src);
        let p: Vec<_> = f.diags.iter().filter(|d| d.rule == R_PANIC).collect();
        assert_eq!(p.len(), 3, "{:?}", f.diags);
        assert!(p.iter().all(|d| d.line != 2), "poison unwrap must pass");
    }

    #[test]
    fn serve_constant_index_needs_bound_comment() {
        let bad = "fn f() { let x = batch.items[0]; }";
        let f = scan_file("rust/src/serve/worker.rs", bad);
        assert_eq!(f.diags.len(), 1, "{:?}", f.diags);
        let good = "fn f() {\n // formed batches are non-empty by construction\n let x = batch.items[0];\n}";
        let f = scan_file("rust/src/serve/worker.rs", good);
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn allow_suppresses_with_reason_only() {
        let with_reason = "fn f() {\n // nodal-lint: allow(panic-isolation) checked above\n let v = g.unwrap();\n}";
        let f = scan_file("rust/src/serve/worker.rs", with_reason);
        assert!(f.diags.is_empty(), "{:?}", f.diags);
        assert_eq!(f.suppressed, 1);
        let without = "fn f() {\n // nodal-lint: allow(panic-isolation)\n let v = g.unwrap();\n}";
        let f = scan_file("rust/src/serve/worker.rs", without);
        // Malformed directive diag + the unsuppressed panic diag.
        assert_eq!(f.diags.len(), 2, "{:?}", f.diags);
    }

    #[test]
    fn overriders_and_bit_tests_collected() {
        let src = "impl OdeFunc for VanDerPol {\n fn eval(&self) {}\n fn eval_batch(&self) {}\n}\nimpl<F: OdeFunc + ?Sized> OdeFunc for &F {\n fn vjp_batch(&self) {}\n}\n#[cfg(test)] mod tests {\n #[test] fn vjp_batch_bit_identical_to_scalar() { let f = VanDerPol::new(1.0); }\n}";
        let f = scan_file("rust/src/ode/vdp.rs", src);
        let names: Vec<_> = f.overriders.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["VanDerPol", "F"]);
        assert!(f.bit_idents.contains("VanDerPol"));
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn test_impl_overrides_are_not_overriders() {
        let src = "#[cfg(test)]\nmod tests {\n struct M;\n impl OdeFunc for M {\n fn eval_batch(&self) {}\n }\n}";
        let f = scan_file("rust/src/ode/func.rs", src);
        assert!(f.overriders.is_empty(), "{:?}", f.overriders);
    }

    #[test]
    fn wire_rule_fires_only_in_dist() {
        let src = "fn send(x: f32) {\n let a = Json::Num(1.0);\n let b = v.as_f64();\n let c: Json = (x as f64).into();\n let d: Json = (\"ok\").into();\n}";
        let f = scan_file("rust/src/dist/transport.rs", src);
        let wire: Vec<_> = f.diags.iter().filter(|d| d.rule == R_WIRE).collect();
        assert_eq!(wire.len(), 3, "{:?}", f.diags);
        let f = scan_file("rust/src/serve/request.rs", src);
        assert!(f.diags.iter().all(|d| d.rule != R_WIRE), "{:?}", f.diags);
    }

    #[test]
    fn float_literal_into_is_flagged_in_dist() {
        let src = "fn send() { let a: Json = 1.5f32.into(); let b: Json = obj.id.into(); }";
        let f = scan_file("rust/src/dist/shard.rs", src);
        let wire: Vec<_> = f.diags.iter().filter(|d| d.rule == R_WIRE).collect();
        assert_eq!(wire.len(), 1, "{:?}", f.diags);
    }

    #[test]
    fn dist_panics_flagged_poison_allowed() {
        let src = "fn go(&self) {\n let g = self.inner.lock().unwrap();\n let v = frame.first().unwrap();\n}";
        let f = scan_file("rust/src/dist/dispatch.rs", src);
        let p: Vec<_> = f.diags.iter().filter(|d| d.rule == R_PANIC).collect();
        assert_eq!(p.len(), 1, "{:?}", f.diags);
        assert_eq!(p[0].line, 3);
        assert!(p[0].msg.contains("dist"), "{:?}", p);
    }

    #[test]
    fn fn_facts_record_owner_calls_and_guards() {
        let src = "impl Shard {\n fn respond(&self) {\n let mut w = self.writer.lock().unwrap();\n send_frame(&mut w, m);\n }\n}";
        let f = scan_file("rust/src/ode/x.rs", src);
        assert_eq!(f.fns.len(), 1);
        let fun = &f.fns[0];
        assert_eq!(fun.name, "respond");
        assert_eq!(fun.owner.as_deref(), Some("Shard"));
        assert_eq!(fun.acqs.len(), 1);
        assert_eq!(fun.acqs[0].field, "writer");
        let sf = fun.calls.iter().find(|c| c.name == "send_frame").expect("call recorded");
        assert_eq!(sf.held, vec!["writer".to_string()]);
        assert!(!sf.method);
    }
}
