//! Brace-aware region scanner and the per-file half of the rule engine.
//!
//! The scanner walks the token stream once, maintaining a stack of region
//! contexts (fn / impl / mod / block). A region inherits its parent's
//! context — test-ness, hot-ness, enclosing `OdeFunc` impl target — so a
//! check at any token only needs the top of the stack.
//!
//! Region classification reads the "header": the tokens accumulated since
//! the last `{`, `}`, or statement-level `;`. `fn` is checked before
//! `impl` so `impl Trait` in a signature does not misclassify a function
//! as an impl block.

use std::collections::BTreeSet;

use crate::lexer::{lex, Tok, TokKind};
use crate::{Diagnostic, RULES, R_DET, R_DIRECTIVE, R_ENV, R_HOT, R_PANIC};

/// A `// nodal-lint: allow(<rule>) <reason>` span. Covers the directive's
/// own line and the next one, so it works both trailing and stand-alone.
#[derive(Debug, Clone)]
pub struct AllowSpan {
    pub rule: String,
    pub lo: u32,
    pub hi: u32,
}

/// Everything the cross-file pass needs from one file.
#[derive(Debug, Default)]
pub struct FileFacts {
    /// Local diagnostics, already filtered through this file's allows.
    pub diags: Vec<Diagnostic>,
    /// Count of locally suppressed diagnostics.
    pub suppressed: usize,
    /// Allow spans, kept for cross-file rules (parity, knob table).
    pub allows: Vec<AllowSpan>,
    /// Non-test `OdeFunc` impls overriding `eval_batch`/`vjp_batch`:
    /// (target type name, line of the overriding fn).
    pub overriders: Vec<(String, u32)>,
    /// Identifiers appearing inside bit-equality test functions.
    pub bit_idents: BTreeSet<String>,
    /// `NODAL_*` names found in string literals: (name, line).
    pub knob_lits: Vec<(String, u32)>,
}

/// Designated parse-and-clamp helpers: the only non-test places allowed to
/// read the environment. Matched as (`/`-anchored path suffix, fn name).
const ENV_HELPERS: &[(&str, &str)] = &[
    ("pool.rs", "default_workers"),
    ("report.rs", "results_dir"),
    ("runtime/mod.rs", "artifact_root"),
    ("ckpt/mod.rs", "parse_budget_env"),
    ("ckpt/mod.rs", "env_budget_bytes"),
    ("serve/mod.rs", "env_clamped"),
    ("dist/env.rs", "from_env"),
    ("dist/env.rs", "env_usize"),
];

/// Methods whose `.unwrap()` propagates poison rather than encoding a
/// fallible assumption — the one panic idiom `serve/` is allowed.
const POISON_METHODS: &[&str] =
    &["lock", "read", "write", "wait", "wait_while", "wait_timeout", "wait_timeout_while"];

#[derive(Clone)]
struct Ctx {
    is_test: bool,
    hot: bool,
    clock_impl: bool,
    fn_name: Option<String>,
    odefunc_target: Option<String>,
    bit_test: bool,
}

/// Does a test-fn name advertise a bit-equality / parity check?
/// Underscore-split for the short markers so `orbit` does not match `bit`.
pub fn is_bit_marker(name: &str) -> bool {
    name.split('_').any(|p| matches!(p, "bit" | "bitwise" | "bitexact"))
        || name.contains("matches_scalar")
        || name.contains("parity")
        || name.contains("identical")
}

/// Extract every `NODAL_[A-Z0-9_]*` name from raw text. Used both on
/// string-literal contents and on the raw lib.rs source (the knob table
/// lives in doc comments, which never reach the token stream).
pub fn knob_names(s: &str) -> Vec<String> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    let mut k = 0usize;
    while k + 6 <= b.len() {
        if &b[k..k + 6] == b"NODAL_" {
            let mut end = k;
            while end < b.len()
                && (b[end].is_ascii_uppercase() || b[end].is_ascii_digit() || b[end] == b'_')
            {
                end += 1;
            }
            out.push(String::from_utf8_lossy(&b[k..end]).into_owned());
            k = end;
        } else {
            k += 1;
        }
    }
    out
}

fn is_env_designated(path: &str, fn_name: Option<&str>) -> bool {
    let Some(f) = fn_name else { return false };
    ENV_HELPERS
        .iter()
        .any(|(suf, h)| f == *h && (path == *suf || path.ends_with(&format!("/{suf}"))))
}

fn diag(rule: &'static str, path: &str, line: u32, msg: String) -> Diagnostic {
    Diagnostic { rule, path: path.to_string(), line, msg }
}

/// Back-scan from an `unwrap`/`expect` ident (preceded by `.`) to the
/// method owning the receiver call: `x.lock().unwrap()` → `lock`.
fn is_poison_receiver(toks: &[Tok], i: usize) -> bool {
    if i < 3 || toks[i - 2].text != ")" {
        return false;
    }
    let mut depth = 1i32;
    let mut j = i - 2;
    while j > 0 {
        j -= 1;
        match toks[j].text.as_str() {
            ")" => depth += 1,
            "(" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
    }
    if depth != 0 || j == 0 {
        return false;
    }
    let m = &toks[j - 1];
    m.kind == TokKind::Ident && POISON_METHODS.contains(&m.text.as_str())
}

pub fn scan_file(path: &str, src: &str) -> FileFacts {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let comment_lines: BTreeSet<u32> = lexed.comments.iter().map(|c| c.line).collect();

    let file_is_test = path.contains("/tests/") || path.starts_with("tests/");
    let det_file_exempt = path.ends_with("bench.rs")
        || path.ends_with("util/timer.rs")
        || path.contains("/benches/")
        || path.starts_with("benches/");
    let in_serve = path.contains("src/serve/");
    let in_det_mods =
        ["src/ode/", "src/grad/", "src/ckpt/"].iter().any(|m| path.contains(m));

    // ---- directives ----
    let mut hot_markers: Vec<u32> = Vec::new();
    let mut allows: Vec<AllowSpan> = Vec::new();
    // Directive diagnostics are never themselves suppressible.
    let mut diags: Vec<Diagnostic> = Vec::new();
    // Rule diagnostics, pre-suppression.
    let mut raw: Vec<Diagnostic> = Vec::new();

    for c in &lexed.comments {
        let Some(rest) = c.text.strip_prefix("nodal-lint:") else { continue };
        let rest = rest.trim();
        if rest == "hot" {
            hot_markers.push(c.line);
            continue;
        }
        if let Some(arg) = rest.strip_prefix("allow(") {
            match arg.split_once(')') {
                Some((rule, reason)) => {
                    let rule = rule.trim();
                    if !RULES.contains(&rule) {
                        diags.push(diag(
                            R_DIRECTIVE,
                            path,
                            c.line,
                            format!("allow names unknown rule `{rule}`"),
                        ));
                    } else if reason.trim().is_empty() {
                        diags.push(diag(
                            R_DIRECTIVE,
                            path,
                            c.line,
                            format!("allow({rule}) requires a reason after the closing paren"),
                        ));
                    } else {
                        allows.push(AllowSpan {
                            rule: rule.to_string(),
                            lo: c.line,
                            hi: c.line + 1,
                        });
                    }
                }
                None => diags.push(diag(
                    R_DIRECTIVE,
                    path,
                    c.line,
                    "malformed allow directive: missing `)`".to_string(),
                )),
            }
            continue;
        }
        diags.push(diag(
            R_DIRECTIVE,
            path,
            c.line,
            format!("unknown nodal-lint directive `{rest}`"),
        ));
    }
    hot_markers.sort_unstable();
    let mut hot_iter = hot_markers.into_iter().peekable();

    // ---- single-pass region walk + checks ----
    let root = Ctx {
        is_test: file_is_test,
        hot: false,
        clock_impl: false,
        fn_name: None,
        odefunc_target: None,
        bit_test: false,
    };
    let mut stack: Vec<Ctx> = vec![root];
    let mut header: Vec<usize> = Vec::new();
    let mut attrs = String::new();
    let mut paren = 0i32;
    let mut brack = 0i32;

    let mut overriders: Vec<(String, u32)> = Vec::new();
    let mut bit_idents: BTreeSet<String> = BTreeSet::new();
    let mut knob_lits: Vec<(String, u32)> = Vec::new();

    let ident_text = |ix: usize| -> Option<&str> {
        toks.get(ix).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
    };
    let punct_is = |ix: usize, s: &str| toks.get(ix).is_some_and(|t| t.text == s);

    let mut i = 0usize;
    while i < toks.len() {
        let t = &toks[i];

        // Consume attributes `#[...]` / `#![...]`; outer attrs are stashed
        // for the next region's classification, inner attrs discarded.
        if t.kind == TokKind::Punct && t.text == "#" {
            let (inner, lb) = if punct_is(i + 1, "[") {
                (false, i + 1)
            } else if punct_is(i + 1, "!") && punct_is(i + 2, "[") {
                (true, i + 2)
            } else {
                (false, usize::MAX)
            };
            if lb != usize::MAX {
                let mut depth = 0i32;
                let mut j = lb;
                let mut captured = String::new();
                while j < toks.len() {
                    match toks[j].text.as_str() {
                        "[" => depth += 1,
                        "]" => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    if toks[j].kind == TokKind::Ident {
                        captured.push_str(&toks[j].text);
                        captured.push(' ');
                    }
                    j += 1;
                }
                if !inner {
                    attrs.push_str(&captured);
                }
                i = j;
                continue;
            }
        }

        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "{") => {
                let mut ctx = classify(
                    toks,
                    &header,
                    &attrs,
                    stack.last().expect("ctx stack never empty"),
                    t.line,
                    &mut overriders,
                );
                if let Some(&m) = hot_iter.peek() {
                    if m <= t.line {
                        hot_iter.next();
                        ctx.hot = true;
                    }
                }
                stack.push(ctx);
                header.clear();
                attrs.clear();
            }
            (TokKind::Punct, "}") => {
                if stack.len() > 1 {
                    stack.pop();
                }
                header.clear();
            }
            (TokKind::Punct, ";") if paren == 0 && brack == 0 => {
                header.clear();
                attrs.clear();
            }
            _ => {
                match t.text.as_str() {
                    "(" => paren += 1,
                    ")" => paren -= 1,
                    "[" => brack += 1,
                    "]" => brack -= 1,
                    _ => {}
                }
                let ctx = stack.last().expect("ctx stack never empty");

                if ctx.bit_test && t.kind == TokKind::Ident {
                    bit_idents.insert(t.text.clone());
                }

                // Rule 1a: env reads outside designated helpers.
                if t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "var" | "var_os" | "vars")
                    && punct_is(i.wrapping_sub(1), ":")
                    && punct_is(i.wrapping_sub(2), ":")
                    && ident_text(i.wrapping_sub(3)) == Some("env")
                    && !ctx.is_test
                    && !is_env_designated(path, ctx.fn_name.as_deref())
                {
                    raw.push(diag(
                        R_ENV,
                        path,
                        t.line,
                        format!(
                            "env::{} outside a designated parse-and-clamp helper",
                            t.text
                        ),
                    ));
                }

                // Rule 1b (cross-file half): collect NODAL_* string literals.
                if t.kind == TokKind::Str && t.text.contains("NODAL_") {
                    for name in knob_names(&t.text) {
                        knob_lits.push((name, t.line));
                    }
                }

                // Rule 2a: wall-clock reads.
                if t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "Instant" | "SystemTime")
                    && punct_is(i + 1, ":")
                    && punct_is(i + 2, ":")
                    && ident_text(i + 3) == Some("now")
                    && !det_file_exempt
                    && !ctx.clock_impl
                    && !ctx.is_test
                {
                    raw.push(diag(
                        R_DET,
                        path,
                        t.line,
                        format!(
                            "{}::now outside a Clock impl, bench.rs, or util/timer.rs",
                            t.text
                        ),
                    ));
                }

                // Rule 2b: hashed collections in result-affecting modules.
                if t.kind == TokKind::Ident
                    && matches!(t.text.as_str(), "HashMap" | "HashSet")
                    && in_det_mods
                    && !ctx.is_test
                {
                    raw.push(diag(
                        R_DET,
                        path,
                        t.line,
                        format!(
                            "{} in a result-affecting module: iteration order can \
                             change float accumulation; use BTreeMap/BTreeSet or Vec",
                            t.text
                        ),
                    ));
                }

                // Rule 3: allocations inside `// nodal-lint: hot` regions.
                if ctx.hot && t.kind == TokKind::Ident {
                    let alloc: Option<String> = if t.text == "vec" && punct_is(i + 1, "!") {
                        Some("vec!".to_string())
                    } else if matches!(t.text.as_str(), "Vec" | "Box" | "String")
                        && punct_is(i + 1, ":")
                        && punct_is(i + 2, ":")
                    {
                        match (t.text.as_str(), ident_text(i + 3)) {
                            ("Vec", Some(m @ ("new" | "with_capacity" | "from")))
                            | ("Box", Some(m @ "new"))
                            | ("String", Some(m @ ("new" | "with_capacity" | "from"))) => {
                                Some(format!("{}::{m}", t.text))
                            }
                            _ => None,
                        }
                    } else if punct_is(i.wrapping_sub(1), ".")
                        && matches!(
                            t.text.as_str(),
                            "to_vec" | "collect" | "clone" | "to_owned" | "to_string"
                        )
                    {
                        Some(format!(".{}()", t.text))
                    } else {
                        None
                    };
                    if let Some(what) = alloc {
                        raw.push(diag(
                            R_HOT,
                            path,
                            t.line,
                            format!("{what} inside a hot region; hoist into reusable scratch"),
                        ));
                    }
                }

                // Rule 4: panic isolation in serve/.
                if in_serve && !ctx.is_test {
                    if t.kind == TokKind::Ident
                        && matches!(
                            t.text.as_str(),
                            "panic" | "unreachable" | "todo" | "unimplemented"
                        )
                        && punct_is(i + 1, "!")
                    {
                        raw.push(diag(
                            R_PANIC,
                            path,
                            t.line,
                            format!("{}! in serve request-handling code", t.text),
                        ));
                    }
                    if t.kind == TokKind::Ident
                        && matches!(t.text.as_str(), "unwrap" | "expect")
                        && punct_is(i.wrapping_sub(1), ".")
                        && !is_poison_receiver(toks, i)
                    {
                        raw.push(diag(
                            R_PANIC,
                            path,
                            t.line,
                            format!(
                                ".{}() in serve request-handling code; return an error \
                                 or route to the per-sample fallback",
                                t.text
                            ),
                        ));
                    }
                    // Constant index `x[0]` without a bound comment on this
                    // or the preceding line.
                    if t.kind == TokKind::Punct
                        && t.text == "["
                        && toks.get(i.wrapping_sub(1)).is_some_and(|p| {
                            p.kind == TokKind::Ident || p.text == "]" || p.text == ")"
                        })
                        && toks.get(i + 1).is_some_and(|n| n.kind == TokKind::Num)
                        && punct_is(i + 2, "]")
                        && !comment_lines.contains(&t.line)
                        && !(t.line > 1 && comment_lines.contains(&(t.line - 1)))
                    {
                        raw.push(diag(
                            R_PANIC,
                            path,
                            t.line,
                            "constant index in serve code without a bound comment \
                             justifying non-emptiness"
                                .to_string(),
                        ));
                    }
                }

                header.push(i);
            }
        }
        i += 1;
    }

    // ---- apply allows to local rule diagnostics ----
    let mut suppressed = 0usize;
    for d in raw {
        if allows.iter().any(|a| a.rule == d.rule && a.lo <= d.line && d.line <= a.hi) {
            suppressed += 1;
        } else {
            diags.push(d);
        }
    }

    FileFacts { diags, suppressed, allows, overriders, bit_idents, knob_lits }
}

/// Classify the region a `{` opens, from the header tokens accumulated
/// since the last region boundary plus the pending outer attributes.
fn classify(
    toks: &[Tok],
    header: &[usize],
    attrs: &str,
    parent: &Ctx,
    line: u32,
    overriders: &mut Vec<(String, u32)>,
) -> Ctx {
    let mut c = parent.clone();
    let kw = |k: &str| {
        header
            .iter()
            .position(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == k)
    };
    let next_ident_after = |p: usize| -> Option<String> {
        header[p + 1..]
            .iter()
            .find(|&&ix| toks[ix].kind == TokKind::Ident)
            .map(|&ix| toks[ix].text.clone())
    };
    let attr_test = attrs.split_whitespace().any(|w| w == "test");

    // `fn` before `impl`: an `impl Trait` in a signature must not turn a
    // function into an impl region.
    if let Some(p) = kw("fn") {
        let name = next_ident_after(p);
        c.fn_name = name.clone();
        if attr_test {
            c.is_test = true;
        }
        if let (Some(target), Some(n)) = (c.odefunc_target.as_ref(), name.as_deref()) {
            if !c.is_test && matches!(n, "eval_batch" | "vjp_batch") {
                overriders.push((target.clone(), line));
            }
        }
        if c.is_test && name.as_deref().is_some_and(is_bit_marker) {
            c.bit_test = true;
        }
        return c;
    }
    if let Some(_p) = kw("impl") {
        if header
            .iter()
            .any(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text.contains("Clock"))
        {
            c.clock_impl = true;
        }
        let has_odefunc = header
            .iter()
            .any(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == "OdeFunc");
        c.odefunc_target = None;
        if has_odefunc {
            // `impl<F: OdeFunc> OdeFunc for Wrapper<F>`: the target is the
            // first ident after the last `for` (skipping `&`, `mut`).
            if let Some(fp) = header
                .iter()
                .rposition(|&ix| toks[ix].kind == TokKind::Ident && toks[ix].text == "for")
            {
                c.odefunc_target = next_ident_after(fp).filter(|t| t != "mut");
            }
        }
        return c;
    }
    if let Some(p) = kw("mod") {
        if attr_test || next_ident_after(p).as_deref() == Some("tests") {
            c.is_test = true;
        }
        return c;
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_markers_split_on_underscores() {
        assert!(is_bit_marker("vjp_batch_bit_identical_to_scalar"));
        assert!(is_bit_marker("default_eval_batch_matches_scalar_and_counts"));
        assert!(is_bit_marker("thinned_parity_roundtrip"));
        assert!(!is_bit_marker("orbit_energy_drift"));
        assert!(!is_bit_marker("habit_tracker"));
    }

    #[test]
    fn knob_extraction() {
        let names = knob_names("set NODAL_WORKERS and NODAL_SERVE_MAX_BATCH=4");
        assert_eq!(names, vec!["NODAL_WORKERS", "NODAL_SERVE_MAX_BATCH"]);
    }

    #[test]
    fn env_read_flagged_outside_designated_helper() {
        let f = scan_file(
            "rust/src/ode/mod.rs",
            "fn sneak() -> usize { std::env::var(\"NODAL_WORKERS\").is_ok() as usize }",
        );
        assert_eq!(f.diags.len(), 1, "{:?}", f.diags);
        assert_eq!(f.diags[0].rule, R_ENV);
    }

    #[test]
    fn env_read_ok_in_designated_helper_and_tests() {
        let f = scan_file(
            "rust/src/pool.rs",
            "pub fn default_workers() -> usize { std::env::var(\"NODAL_WORKERS\").map_or(1, |_| 2) }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
        let f = scan_file(
            "rust/src/pool.rs",
            "#[cfg(test)] mod tests { #[test] fn t() { std::env::var(\"NODAL_WORKERS\").ok(); } }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn instant_now_flagged_except_clock_impl() {
        let f = scan_file(
            "rust/src/serve/batcher.rs",
            "fn t() -> Instant { std::time::Instant::now() }",
        );
        assert_eq!(f.diags.len(), 1);
        assert_eq!(f.diags[0].rule, R_DET);
        let f = scan_file(
            "rust/src/serve/mod.rs",
            "impl Clock for WallClock { fn now(&self) -> Instant { Instant::now() } }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
        // `impl Default for WallClock` is also a Clock-typed impl.
        let f = scan_file(
            "rust/src/serve/mod.rs",
            "impl Default for WallClock { fn default() -> Self { WallClock(Instant::now()) } }",
        );
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn hashmap_flagged_only_in_det_modules() {
        let f = scan_file("rust/src/grad/adjoint.rs", "use std::collections::HashMap;");
        assert_eq!(f.diags.len(), 1);
        let f = scan_file("rust/src/serve/registry.rs", "use std::collections::HashMap;");
        assert!(f.diags.is_empty());
    }

    #[test]
    fn hot_region_catches_alloc_families() {
        let src = "// nodal-lint: hot\nfn step() {\n let a = vec![0.0];\n let b: Vec<f32> = Vec::new();\n let c = xs.to_vec();\n let d = xs.iter().collect();\n let e = xs.clone();\n let f = Box::new(1);\n let g = Vec::with_capacity(4);\n}\nfn cold() { let a = vec![1]; }";
        let f = scan_file("rust/src/ode/step.rs", src);
        let hot: Vec<_> = f.diags.iter().filter(|d| d.rule == R_HOT).collect();
        assert_eq!(hot.len(), 7, "{:?}", f.diags);
    }

    #[test]
    fn hot_marker_attaches_to_loop_braces_too() {
        let src = "fn run() {\n // nodal-lint: hot\n while go {\n buf.push(x.clone());\n }\n let post = y.clone();\n}";
        let f = scan_file("rust/src/grad/batch.rs", src);
        assert_eq!(f.diags.len(), 1, "{:?}", f.diags);
        assert_eq!(f.diags[0].line, 4);
    }

    #[test]
    fn serve_panics_flagged_poison_allowed() {
        let src = "fn go(&self) {\n let g = self.inner.lock().unwrap();\n let v = item.grad.as_ref().unwrap();\n let w = item.grad.as_ref().expect(\"grad\");\n panic!(\"boom\");\n}";
        let f = scan_file("rust/src/serve/worker.rs", src);
        let p: Vec<_> = f.diags.iter().filter(|d| d.rule == R_PANIC).collect();
        assert_eq!(p.len(), 3, "{:?}", f.diags);
        assert!(p.iter().all(|d| d.line != 2), "poison unwrap must pass");
    }

    #[test]
    fn serve_constant_index_needs_bound_comment() {
        let bad = "fn f() { let x = batch.items[0]; }";
        let f = scan_file("rust/src/serve/worker.rs", bad);
        assert_eq!(f.diags.len(), 1, "{:?}", f.diags);
        let good = "fn f() {\n // formed batches are non-empty by construction\n let x = batch.items[0];\n}";
        let f = scan_file("rust/src/serve/worker.rs", good);
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn allow_suppresses_with_reason_only() {
        let with_reason = "fn f() {\n // nodal-lint: allow(panic-isolation) checked above\n let v = g.unwrap();\n}";
        let f = scan_file("rust/src/serve/worker.rs", with_reason);
        assert!(f.diags.is_empty(), "{:?}", f.diags);
        assert_eq!(f.suppressed, 1);
        let without = "fn f() {\n // nodal-lint: allow(panic-isolation)\n let v = g.unwrap();\n}";
        let f = scan_file("rust/src/serve/worker.rs", without);
        // Malformed directive diag + the unsuppressed panic diag.
        assert_eq!(f.diags.len(), 2, "{:?}", f.diags);
    }

    #[test]
    fn overriders_and_bit_tests_collected() {
        let src = "impl OdeFunc for VanDerPol {\n fn eval(&self) {}\n fn eval_batch(&self) {}\n}\nimpl<F: OdeFunc + ?Sized> OdeFunc for &F {\n fn vjp_batch(&self) {}\n}\n#[cfg(test)] mod tests {\n #[test] fn vjp_batch_bit_identical_to_scalar() { let f = VanDerPol::new(1.0); }\n}";
        let f = scan_file("rust/src/ode/vdp.rs", src);
        let names: Vec<_> = f.overriders.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["VanDerPol", "F"]);
        assert!(f.bit_idents.contains("VanDerPol"));
        assert!(f.diags.is_empty(), "{:?}", f.diags);
    }

    #[test]
    fn test_impl_overrides_are_not_overriders() {
        let src = "#[cfg(test)]\nmod tests {\n struct M;\n impl OdeFunc for M {\n fn eval_batch(&self) {}\n }\n}";
        let f = scan_file("rust/src/ode/func.rs", src);
        assert!(f.overriders.is_empty(), "{:?}", f.overriders);
    }
}
