//! `nodal-lint`: an offline static-analysis gate for the nodal codebase.
//!
//! The compiler cannot check the disciplines this reproduction depends on:
//! ACA's correctness claim is that the reverse trajectory is the *recorded*
//! forward trajectory, enforced as bit-equality between the scalar, batched,
//! and thinned paths. One stray `HashMap` iteration, wall-clock read, or
//! allocation in a hot loop silently erodes that. This crate turns the
//! tribal rules into machine-checked ones, with no dependencies (like the
//! vendored `anyhow`/`xla`) so it runs fully offline.
//!
//! Eight rules, each with file:line diagnostics:
//!
//! 1. **env-knob** — `std::env::var*` only inside the designated
//!    parse-and-clamp helpers; every `NODAL_*` literal must appear in the
//!    main crate's lib.rs knob table.
//! 2. **determinism** — `Instant::now`/`SystemTime::now` only in `Clock`
//!    impls, `bench.rs`, `util/timer.rs`, and benches; no `HashMap`/
//!    `HashSet` in `ode/`, `grad/`, `ckpt/`.
//! 3. **hot-alloc** — regions marked `// nodal-lint: hot` must not
//!    allocate (`vec!`, `Vec::new`/`with_capacity`/`from`, `to_vec`,
//!    `collect`, `clone`, `to_owned`, `to_string`, `Box::new`,
//!    `String::new`/`from`/`with_capacity`).
//! 4. **panic-isolation** — no `unwrap`/`expect`/`panic!`-family or
//!    uncommented constant indexing in `serve/` and `dist/` non-test
//!    code; the mutex `.lock().unwrap()` poison idiom is allowed.
//! 5. **parity-linkage** — every non-test `OdeFunc` impl overriding
//!    `eval_batch`/`vjp_batch` must be named by a bit-equality test.
//! 6. **lock-discipline** *(interprocedural)* — in `dist/` and `serve/`,
//!    no mutex guard may be live across a blocking call (frame I/O,
//!    connect/accept, channel recv, `join`, `sleep`), directly or
//!    transitively through the call graph; and two locks must be taken
//!    in one consistent order everywhere.
//! 7. **wire-determinism** — in `dist/`, floats reach the transport only
//!    as u32/u64 bit patterns: no `Json::Num` construction, `.as_f64()`
//!    decode, or float-valued `.into()` JSON conversion.
//! 8. **transitive hot-alloc** *(interprocedural)* — the rule-3
//!    allocation families are also diagnosed in every function reachable
//!    from a hot region through resolved call edges (reported under the
//!    `hot-alloc` rule, so one allow covers both halves).
//!
//! Rules 6 and 8 run on an intra-crate call graph; see `graph` for how
//! edges are resolved and the documented limits (no trait dispatch,
//! best-effort method calls — unresolved method edges are counted in the
//! report, never silently dropped).
//!
//! Escape hatch: `// nodal-lint: allow(<rule>) <reason>` on the offending
//! line or the line above. The reason is mandatory; a bare allow is itself
//! a diagnostic and suppresses nothing.

pub mod graph;
pub mod lexer;
pub mod scan;

use std::collections::BTreeSet;
use std::io::Write;
use std::path::{Path, PathBuf};

pub const R_ENV: &str = "env-knob";
pub const R_DET: &str = "determinism";
pub const R_HOT: &str = "hot-alloc";
pub const R_PANIC: &str = "panic-isolation";
pub const R_PARITY: &str = "parity-linkage";
pub const R_LOCK: &str = "lock-discipline";
pub const R_WIRE: &str = "wire-determinism";
/// Pseudo-rule for malformed `nodal-lint:` directives; not allowable.
pub const R_DIRECTIVE: &str = "directive";

pub const RULES: [&str; 7] = [R_ENV, R_DET, R_HOT, R_PANIC, R_PARITY, R_LOCK, R_WIRE];

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub msg: String,
}

#[derive(Debug, Default)]
pub struct Outcome {
    /// Sorted by (path, line, rule).
    pub diags: Vec<Diagnostic>,
    /// Diagnostics silenced by justified `allow` directives.
    pub suppressed: usize,
    /// Number of files scanned.
    pub files: usize,
    /// Method-call edges the graph could not resolve to a unique
    /// intra-crate function (see `graph` module docs).
    pub unresolved: usize,
}

impl Outcome {
    pub fn clean(&self) -> bool {
        self.diags.is_empty()
    }
}

/// Lint a set of (path, source) pairs. Paths drive the path-scoped rules
/// (`src/serve/`, `src/ode/`, test-ness, …), so fixture tests can exercise
/// any rule by choosing virtual paths.
///
/// Cross-file rules: the knob table is extracted from every input whose
/// path ends in `src/lib.rs` (skipped entirely when no such file is in the
/// set); parity linkage unions bit-test identifiers across all inputs.
pub fn lint_sources(files: &[(String, String)]) -> Outcome {
    let mut table: Option<BTreeSet<String>> = None;
    for (path, src) in files {
        if path.ends_with("src/lib.rs") {
            table.get_or_insert_with(BTreeSet::new).extend(scan::knob_names(src));
        }
    }

    let facts: Vec<scan::FileFacts> =
        files.iter().map(|(p, s)| scan::scan_file(p, s)).collect();

    let mut bit_idents: BTreeSet<String> = BTreeSet::new();
    for f in &facts {
        bit_idents.extend(f.bit_idents.iter().cloned());
    }

    let mut diags = Vec::new();
    let mut suppressed = 0usize;

    // Interprocedural pass: symbol table + call graph over every file's
    // function facts (rules 6 and 8). Its diagnostics are filtered through
    // the allows of the file each one lands in.
    let unresolved = {
        let all_fns: Vec<&graph::FnFact> =
            facts.iter().flat_map(|f| f.fns.iter()).collect();
        let g = graph::analyze(&all_fns);
        let allow_of: std::collections::BTreeMap<&str, &[scan::AllowSpan]> = files
            .iter()
            .zip(&facts)
            .map(|((p, _), f)| (p.as_str(), f.allows.as_slice()))
            .collect();
        for d in g.diags {
            let allowed = allow_of.get(d.path.as_str()).is_some_and(|al| {
                al.iter().any(|a| a.rule == d.rule && a.lo <= d.line && d.line <= a.hi)
            });
            if allowed {
                suppressed += 1;
            } else {
                diags.push(d);
            }
        }
        g.unresolved
    };

    for (f, (path, _)) in facts.into_iter().zip(files) {
        suppressed += f.suppressed;
        diags.extend(f.diags);

        let suppress = |rule: &str, line: u32| {
            f.allows.iter().any(|a| a.rule == rule && a.lo <= line && line <= a.hi)
        };

        if let Some(tab) = &table {
            for (name, line) in &f.knob_lits {
                if !tab.contains(name) {
                    if suppress(R_ENV, *line) {
                        suppressed += 1;
                    } else {
                        diags.push(Diagnostic {
                            rule: R_ENV,
                            path: path.clone(),
                            line: *line,
                            msg: format!(
                                "knob `{name}` is not documented in the lib.rs knob table"
                            ),
                        });
                    }
                }
            }
        }

        for (target, line) in &f.overriders {
            // Single-letter targets are generic parameters (`impl OdeFunc
            // for &F`): pure forwarding, not a parity surface of their own.
            if target.chars().count() <= 1 {
                continue;
            }
            if !bit_idents.contains(target) {
                if suppress(R_PARITY, *line) {
                    suppressed += 1;
                } else {
                    diags.push(Diagnostic {
                        rule: R_PARITY,
                        path: path.clone(),
                        line: *line,
                        msg: format!(
                            "`{target}` overrides eval_batch/vjp_batch but no \
                             bit-equality test names it"
                        ),
                    });
                }
            }
        }
    }

    diags.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    Outcome { diags, suppressed, files: files.len(), unresolved }
}

/// Walk `rust/src`, `rust/benches`, `rust/tests` under `root` and lint
/// every `.rs` file, with paths reported relative to `root`. Traversal is
/// sorted so diagnostics and the report are deterministic.
pub fn lint_tree(root: &Path) -> std::io::Result<Outcome> {
    let mut paths: Vec<PathBuf> = Vec::new();
    for sub in ["rust/src", "rust/benches", "rust/tests"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut paths)?;
        }
    }
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        files.push((rel, std::fs::read_to_string(p)?));
    }
    Ok(lint_sources(&files))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Per-rule diagnostic counts in a fixed order (declared rules, then the
/// directive pseudo-rule), so report summaries diff meaningfully.
pub fn rule_counts(out: &Outcome) -> Vec<(&'static str, usize)> {
    RULES
        .iter()
        .copied()
        .chain(std::iter::once(R_DIRECTIVE))
        .map(|r| (r, out.diags.iter().filter(|d| d.rule == r).count()))
        .collect()
}

/// Write the machine-readable report: a summary line (totals plus
/// per-rule counts and the unresolved-edge count, all in fixed key order
/// so artifact diffs between commits are meaningful) followed by one JSON
/// object per diagnostic, sorted by (file, line, rule). Hand-rolled
/// serialization — no serde.
pub fn write_report(path: &Path, out: &Outcome) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    let rules = rule_counts(out)
        .iter()
        .map(|(r, n)| format!("\"{r}\":{n}"))
        .collect::<Vec<_>>()
        .join(",");
    writeln!(
        w,
        "{{\"files\":{},\"diagnostics\":{},\"suppressed\":{},\
         \"unresolved_method_calls\":{},\"rules\":{{{rules}}}}}",
        out.files,
        out.diags.len(),
        out.suppressed,
        out.unresolved
    )?;
    for d in &out.diags {
        writeln!(
            w,
            "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"msg\":\"{}\"}}",
            json_escape(d.rule),
            json_escape(&d.path),
            d.line,
            json_escape(&d.msg)
        )?;
    }
    w.flush()
}

fn json_escape(s: &str) -> String {
    let mut o = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => o.push_str("\\\""),
            '\\' => o.push_str("\\\\"),
            '\n' => o.push_str("\\n"),
            '\r' => o.push_str("\\r"),
            '\t' => o.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                o.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => o.push(c),
        }
    }
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    fn f(path: &str, src: &str) -> (String, String) {
        (path.to_string(), src.to_string())
    }

    #[test]
    fn knob_table_checked_only_when_lib_present() {
        let user = f(
            "rust/src/serve/mod.rs",
            "#[cfg(test)] mod tests { #[test] fn t() { std::env::set_var(\"NODAL_ROGUE\", \"1\"); } }",
        );
        // Without a lib.rs in the set the table check is skipped.
        let out = lint_sources(&[user.clone()]);
        assert!(out.clean(), "{:?}", out.diags);
        // With a lib.rs lacking the knob it fires.
        let lib = f("rust/src/lib.rs", "//! Knobs: `NODAL_WORKERS`.\n");
        let out = lint_sources(&[lib, user]);
        assert_eq!(out.diags.len(), 1, "{:?}", out.diags);
        assert_eq!(out.diags[0].rule, R_ENV);
    }

    #[test]
    fn parity_links_across_files() {
        let imp = f(
            "rust/src/ode/linear.rs",
            "impl OdeFunc for Linear { fn vjp_batch(&self) {} }",
        );
        let out = lint_sources(&[imp.clone()]);
        assert_eq!(out.diags.len(), 1, "{:?}", out.diags);
        assert_eq!(out.diags[0].rule, R_PARITY);
        let test = f(
            "rust/tests/parity.rs",
            "#[test] fn linear_vjp_batch_bit_identical() { let f = Linear::new(-0.5, 2); }",
        );
        let out = lint_sources(&[imp, test]);
        assert!(out.clean(), "{:?}", out.diags);
    }

    #[test]
    fn report_is_valid_jsonl_shape() {
        let out = Outcome {
            diags: vec![Diagnostic {
                rule: R_HOT,
                path: "a\\b.rs".into(),
                line: 3,
                msg: "say \"no\"".into(),
            }],
            suppressed: 1,
            files: 2,
            unresolved: 4,
        };
        let dir = std::env::temp_dir().join("nodal-lint-test");
        let p = dir.join("report.jsonl");
        write_report(&p, &out).unwrap();
        let got = std::fs::read_to_string(&p).unwrap();
        let mut lines = got.lines();
        assert_eq!(
            lines.next().unwrap(),
            "{\"files\":2,\"diagnostics\":1,\"suppressed\":1,\
             \"unresolved_method_calls\":4,\"rules\":{\"env-knob\":0,\
             \"determinism\":0,\"hot-alloc\":1,\"panic-isolation\":0,\
             \"parity-linkage\":0,\"lock-discipline\":0,\
             \"wire-determinism\":0,\"directive\":0}}"
        );
        let d = lines.next().unwrap();
        assert!(d.contains("\\\\b.rs") && d.contains("say \\\"no\\\""), "{d}");
    }
}
