//! CLI entry point: `cargo run -p nodal-lint [ROOT] [--rule NAME]`.
//!
//! Lints `rust/src`, `rust/benches`, `rust/tests` under ROOT (default: the
//! repository root containing this crate), prints diagnostics and a
//! per-rule summary, writes `results/lint/report.jsonl` (honouring
//! `NODAL_RESULTS`), and exits non-zero when the tree is not clean — the
//! CI hard gate. `--rule NAME` restricts the printed diagnostics and the
//! exit status to one rule, for local iteration; the report always covers
//! the full tree.

use std::path::{Path, PathBuf};

fn main() {
    let mut root_arg: Option<PathBuf> = None;
    let mut rule_filter: Option<String> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--rule" {
            match args.next() {
                Some(r) => rule_filter = Some(r),
                None => {
                    eprintln!("nodal-lint: --rule requires a rule name");
                    std::process::exit(2);
                }
            }
        } else {
            root_arg = Some(PathBuf::from(a));
        }
    }
    if let Some(r) = &rule_filter {
        if !nodal_lint::RULES.contains(&r.as_str()) && r != nodal_lint::R_DIRECTIVE {
            eprintln!(
                "nodal-lint: unknown rule `{r}` (expected one of {}, {})",
                nodal_lint::RULES.join(", "),
                nodal_lint::R_DIRECTIVE
            );
            std::process::exit(2);
        }
    }

    let root: PathBuf = match root_arg {
        Some(p) => p,
        // crate dir = <root>/rust/tools/nodal-lint → third ancestor is <root>.
        None => Path::new(env!("CARGO_MANIFEST_DIR"))
            .ancestors()
            .nth(3)
            .expect("crate sits three levels below the repo root")
            .to_path_buf(),
    };

    let out = match nodal_lint::lint_tree(&root) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("nodal-lint: failed to read tree under {}: {e}", root.display());
            std::process::exit(2);
        }
    };

    let results = std::env::var("NODAL_RESULTS").unwrap_or_else(|_| "results".to_string());
    let report = root.join(results).join("lint").join("report.jsonl");
    if let Err(e) = nodal_lint::write_report(&report, &out) {
        eprintln!("nodal-lint: failed to write {}: {e}", report.display());
        std::process::exit(2);
    }

    let shown: Vec<&nodal_lint::Diagnostic> = out
        .diags
        .iter()
        .filter(|d| rule_filter.as_deref().is_none_or(|r| d.rule == r))
        .collect();
    for d in &shown {
        eprintln!("{}:{}: [{}] {}", d.path, d.line, d.rule, d.msg);
    }
    let per_rule = nodal_lint::rule_counts(&out)
        .iter()
        .map(|(r, n)| format!("{r}={n}"))
        .collect::<Vec<_>>()
        .join(" ");
    println!("nodal-lint: rules: {per_rule} unresolved_method_calls={}", out.unresolved);
    println!(
        "nodal-lint: {} file(s) scanned, {} diagnostic(s), {} suppressed by allow; report at {}",
        out.files,
        out.diags.len(),
        out.suppressed,
        report.display()
    );
    if let Some(r) = &rule_filter {
        println!("nodal-lint: --rule {r}: {} matching diagnostic(s)", shown.len());
    }
    if !shown.is_empty() {
        std::process::exit(1);
    }
}
