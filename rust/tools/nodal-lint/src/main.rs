//! CLI entry point: `cargo run -p nodal-lint [ROOT]`.
//!
//! Lints `rust/src`, `rust/benches`, `rust/tests` under ROOT (default: the
//! repository root containing this crate), prints diagnostics, writes
//! `results/lint/report.jsonl` (honouring `NODAL_RESULTS`), and exits
//! non-zero when the tree is not clean — the CI hard gate.

use std::path::{Path, PathBuf};

fn main() {
    let root: PathBuf = match std::env::args().nth(1) {
        Some(p) => PathBuf::from(p),
        // crate dir = <root>/rust/tools/nodal-lint → third ancestor is <root>.
        None => Path::new(env!("CARGO_MANIFEST_DIR"))
            .ancestors()
            .nth(3)
            .expect("crate sits three levels below the repo root")
            .to_path_buf(),
    };

    let out = match nodal_lint::lint_tree(&root) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("nodal-lint: failed to read tree under {}: {e}", root.display());
            std::process::exit(2);
        }
    };

    let results = std::env::var("NODAL_RESULTS").unwrap_or_else(|_| "results".to_string());
    let report = root.join(results).join("lint").join("report.jsonl");
    if let Err(e) = nodal_lint::write_report(&report, &out) {
        eprintln!("nodal-lint: failed to write {}: {e}", report.display());
        std::process::exit(2);
    }

    for d in &out.diags {
        eprintln!("{}:{}: [{}] {}", d.path, d.line, d.rule, d.msg);
    }
    println!(
        "nodal-lint: {} file(s) scanned, {} diagnostic(s), {} suppressed by allow; report at {}",
        out.files,
        out.diags.len(),
        out.suppressed,
        report.display()
    );
    if !out.clean() {
        std::process::exit(1);
    }
}
