//! A minimal hand-rolled Rust lexer — just enough fidelity to strip
//! comments, string/char literals and lifetimes so the scanner can trust
//! brace balance and identifier matches. No `syn`, no dependencies.
//!
//! What it gets right (because the rules depend on it):
//! * nested block comments;
//! * raw strings (`r"…"`, `r#"…"#`) and byte strings (`b"…"`, `br#"…"#`) —
//!   braces inside them must not disturb region tracking;
//! * `'a` lifetimes vs `'x'` / `'\n'` char literals;
//! * line comments are captured with their line number, so `// nodal-lint:`
//!   directives and bound comments can be located.

/// Token class. `text` is meaningful for `Ident`, `Num`, `Str` and `Punct`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Char,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A `//` comment (regular or doc), with the text after the slashes.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub comments: Vec<Comment>,
}

pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut out = Lexed::default();

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (incl. /// and //! doc comments).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            let text: String = b[start..j].iter().collect();
            out.comments.push(Comment { line, text: text.trim().to_string() });
            i = j;
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Identifier / keyword — possibly a raw/byte string prefix.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            let word: String = b[start..j].iter().collect();
            if matches!(word.as_str(), "r" | "b" | "br" | "rb") && j < n {
                let raw = word.contains('r');
                // `r"…"` / `b"…"` directly, or `r#…` only when the hashes
                // are followed by a quote (so raw identifiers like `r#type`
                // fall through as plain idents).
                let is_string = if b[j] == '"' {
                    true
                } else if raw && b[j] == '#' {
                    let mut k = j;
                    while k < n && b[k] == '#' {
                        k += 1;
                    }
                    k < n && b[k] == '"'
                } else {
                    false
                };
                if is_string {
                    let (tok, nj, nl) = lex_string(&b, j, line, raw);
                    out.toks.push(tok);
                    i = nj;
                    line = nl;
                    continue;
                }
            }
            out.toks.push(Tok { kind: TokKind::Ident, text: word, line });
            i = j;
            continue;
        }
        // Number literal (suffixes ride along; `1.5` lexes as Num '.' Num).
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            out.toks.push(Tok { kind: TokKind::Num, text: b[start..j].iter().collect(), line });
            i = j;
            continue;
        }
        if c == '"' {
            let (tok, nj, nl) = lex_string(&b, i, line, false);
            out.toks.push(tok);
            i = nj;
            line = nl;
            continue;
        }
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: scan to the closing quote.
                let mut j = i + 2;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i = (j + 1).min(n);
            } else if i + 2 < n && b[i + 2] == '\'' {
                // One-char literal: 'x', '-', ' ', '_', …
                out.toks.push(Tok { kind: TokKind::Char, text: String::new(), line });
                i += 3;
            } else {
                // Lifetime (or loop label): consume the identifier.
                let mut j = i + 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                out.toks.push(Tok {
                    kind: TokKind::Lifetime,
                    text: b[i + 1..j].iter().collect(),
                    line,
                });
                i = j;
            }
            continue;
        }
        out.toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    out
}

/// Lex a string literal starting at `i` (pointing at `"` or, for raw
/// strings, at the first `#`). Returns the token, the index just past the
/// literal, and the updated line counter.
fn lex_string(b: &[char], mut i: usize, mut line: u32, raw: bool) -> (Tok, usize, u32) {
    let start_line = line;
    let mut hashes = 0usize;
    if raw {
        while i < b.len() && b[i] == '#' {
            hashes += 1;
            i += 1;
        }
    }
    // b[i] is the opening quote.
    i += 1;
    let mut val = String::new();
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            line += 1;
            val.push(c);
            i += 1;
            continue;
        }
        if !raw && c == '\\' {
            // Skip the escape; the exact value is irrelevant to the rules.
            i = (i + 2).min(b.len());
            val.push('\u{FFFD}');
            continue;
        }
        if c == '"' {
            if !raw {
                i += 1;
                break;
            }
            let mut k = 0usize;
            while k < hashes && i + 1 + k < b.len() && b[i + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                i += 1 + hashes;
                break;
            }
        }
        val.push(c);
        i += 1;
    }
    (Tok { kind: TokKind::Str, text: val, line: start_line }, i, line)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = "let a = \"fn { } unwrap\"; // fn in comment\n/* fn */ call();";
        assert_eq!(idents(src), vec!["let", "a", "call"]);
    }

    #[test]
    fn raw_strings_hide_braces() {
        let src = "let j = r#\"{\"a\": [1, {\"b\": 2}]}\"#; done();";
        let l = lex(src);
        assert!(l.toks.iter().all(|t| t.text != "{"));
        assert_eq!(idents(src), vec!["let", "j", "done"]);
    }

    #[test]
    fn raw_identifier_is_not_a_string() {
        // `r#type` must lex as idents, not swallow the rest of the file.
        let src = "let r#type = 1; after();";
        assert!(idents(src).contains(&"after".to_string()));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { m('-'); m('\\n'); m('_'); }";
        let l = lex(src);
        let lifetimes: Vec<_> =
            l.toks.iter().filter(|t| t.kind == TokKind::Lifetime).collect();
        assert_eq!(lifetimes.len(), 2);
        let chars = l.toks.iter().filter(|t| t.kind == TokKind::Char).count();
        assert_eq!(chars, 3);
        // Brace balance must survive.
        let open = l.toks.iter().filter(|t| t.text == "{").count();
        let close = l.toks.iter().filter(|t| t.text == "}").count();
        assert_eq!(open, close);
    }

    #[test]
    fn line_numbers_and_directive_comments() {
        let src = "a();\n// nodal-lint: hot\nb();\n";
        let l = lex(src);
        assert_eq!(l.comments.len(), 1);
        assert_eq!(l.comments[0].line, 2);
        assert_eq!(l.comments[0].text, "nodal-lint: hot");
        let b_tok = l.toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b_tok.line, 3);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ real();";
        assert_eq!(idents(src), vec!["real"]);
    }
}
