//! Symbol table, intra-crate call graph, and the interprocedural rules
//! (6 lock-discipline, 7's cross-function half lives in `scan`, 8
//! transitive hot-alloc) built on the per-function facts `scan` collects.
//!
//! ## Call-graph construction and its documented limits
//!
//! Function definitions are keyed by (module path derived from the file
//! path, impl owner type, name). Edges are resolved best-effort:
//!
//! * **Direct calls** (`foo(…)`, `module::foo(…)`, `Type::foo(…)`,
//!   `Self::foo(…)`): the candidate set is every non-test `fn` with that
//!   name. A qualifier chain filters candidates by impl owner or module
//!   path suffix (`Self` maps to the caller's impl owner; `crate`/`super`
//!   accept any intra-crate candidate). Multiple survivors resolve to
//!   *all* of them (conservative over-approximation); zero candidates
//!   means the call targets std/vendored code and is external. Direct
//!   intra-crate calls therefore always resolve — they are never counted
//!   as unresolved.
//! * **Method calls** (`recv.foo(…)`): there is no type inference, so
//!   resolution is heuristic. Names on the ambient deny-list (`push`,
//!   `collect`, `lock`, the condvar `wait*` family, …) are assumed to be
//!   std and treated as external. A bare `self.foo(…)` resolves to the
//!   enclosing impl owner's `foo` when it exists. Otherwise a unique
//!   non-test candidate resolves; **multiple candidates are counted as
//!   unresolved edges** (reported, not silently dropped) — this is the
//!   "no trait dispatch" limit: `f.eval_batch(…)` through `&dyn OdeFunc`
//!   stays unresolved by design.
//!
//! Closures are attributed to their enclosing function; test functions
//! are excluded from the graph entirely (as callers and as candidates).

use std::collections::{BTreeMap, BTreeSet};

use crate::{Diagnostic, R_HOT, R_LOCK};

/// One function definition, with the per-body facts the rules consume.
#[derive(Debug, Clone, Default)]
pub struct FnFact {
    pub name: String,
    /// Impl owner type (`impl Foo { fn bar }` → `Foo`); `None` for free
    /// functions and trait default methods.
    pub owner: Option<String>,
    /// File path (as linted, `/`-separated).
    pub path: String,
    pub line: u32,
    pub is_test: bool,
    pub calls: Vec<CallFact>,
    /// Lock acquisitions (`.lock().unwrap()` / `.expect(…)`).
    pub acqs: Vec<AcqFact>,
    /// Allocation-family sites anywhere in the body (rule 8 checks these
    /// for functions reachable from hot regions).
    pub allocs: Vec<AllocFact>,
}

/// One call site inside a function body.
#[derive(Debug, Clone, Default)]
pub struct CallFact {
    pub name: String,
    /// `a::b::name(…)` qualifier chain (empty for plain/method calls).
    pub quals: Vec<String>,
    /// `recv.name(…)` — resolved heuristically (see module docs).
    pub method: bool,
    /// Method call whose receiver is a bare `self`.
    pub recv_self: bool,
    pub line: u32,
    /// Lock fields whose guards are live at this call site.
    pub held: Vec<String>,
    /// Call site lies inside a `// nodal-lint: hot` region.
    pub in_hot: bool,
}

/// One `.lock().unwrap()` acquisition site.
#[derive(Debug, Clone, Default)]
pub struct AcqFact {
    /// The field/binding the mutex was reached through (`writer.lock()`
    /// → `writer`).
    pub field: String,
    pub line: u32,
    /// Lock fields already held when this one is acquired (lock-order
    /// evidence).
    pub held: Vec<String>,
}

/// One allocation-family site.
#[derive(Debug, Clone, Default)]
pub struct AllocFact {
    pub what: String,
    pub line: u32,
    /// Inside a lexical hot region (already covered by rule 3; rule 8
    /// skips these to avoid double-reporting).
    pub in_hot: bool,
}

/// Functions that block the calling thread on I/O or another thread,
/// recognized *by name* at the call site (so `send_frame` through a
/// trait object still counts). The condvar `wait*` family is exempt by
/// design: waiting on a condvar with its own guard is the idiom.
const BLOCKING: &[&str] = &[
    "send_frame",
    "recv_frame",
    "write_frame_bytes",
    "connect",
    "connect_timeout",
    "connect_retry",
    "accept",
    "recv",
    "recv_one",
    "recv_all",
    "recv_timeout",
    "join",
    "sleep",
];

/// Method names assumed to be std/ambient (collections, iterators,
/// atomics, Option/Result, condvars). Method calls with these names are
/// never resolved intra-crate — the deny-list is what keeps
/// `queue.push(x)` from resolving to `BatchFormer::push`.
const AMBIENT: &[&str] = &[
    "push", "pop", "pop_front", "push_back", "insert", "remove", "get", "get_mut", "len",
    "is_empty", "is_some", "is_none", "is_ok", "is_err", "is_finite", "clear", "drain", "iter",
    "iter_mut", "into_iter", "next", "peek", "collect", "clone", "cloned", "copied", "to_vec",
    "to_string", "to_owned", "extend", "extend_from_slice", "truncate", "resize", "reserve",
    "take", "replace", "swap", "split_at", "split_at_mut", "copy_from_slice", "fill", "min",
    "max", "abs", "map", "map_or", "map_err", "and_then", "or_else", "ok_or", "ok_or_else",
    "unwrap_or", "unwrap_or_else", "unwrap_or_default", "filter", "filter_map", "flat_map",
    "zip", "enumerate", "rev", "sum", "fold", "all", "any", "position", "find", "count", "last",
    "first", "keys", "values", "sort", "sort_unstable", "sort_by", "sort_by_key", "chunks",
    "chunks_exact", "chunks_exact_mut", "windows", "lock", "unwrap", "expect",
    "get_or_insert_with", "contains", "contains_key", "starts_with", "ends_with", "trim",
    "split", "splitn", "split_once", "parse", "fetch_add", "fetch_sub", "store", "load",
    "compare_exchange", "saturating_add", "saturating_sub", "saturating_mul", "wrapping_sub",
    "checked_add", "checked_mul", "wait", "wait_timeout", "wait_while", "wait_timeout_while",
    "notify_all", "notify_one", "to_bits", "from_bits", "to_be_bytes", "from_be_bytes",
    "try_clone", "try_into", "try_from", "into", "from", "as_str", "as_ref", "as_mut",
    "as_bytes", "as_slice", "set", "flush", "write_all", "read_exact",
];

/// Result of the interprocedural pass over one source set.
#[derive(Debug, Default)]
pub struct GraphOutcome {
    /// Rule 6 / rule 8 diagnostics (pre-allow; the caller applies allows).
    pub diags: Vec<Diagnostic>,
    /// Method-call edges with multiple intra-crate candidates — the
    /// documented resolution limit, counted rather than silently dropped.
    pub unresolved: usize,
}

/// `"rust/src/dist/transport.rs"` → `["dist", "transport"]` (drops a
/// trailing `mod`/`lib` segment so `dist/mod.rs` is module `dist`).
fn module_segments(path: &str) -> Vec<&str> {
    let p = path.strip_suffix(".rs").unwrap_or(path);
    let p = match p.find("src/") {
        Some(k) => &p[k + 4..],
        None => p,
    };
    let mut segs: Vec<&str> = p.split('/').filter(|s| !s.is_empty()).collect();
    if matches!(segs.last(), Some(&"mod") | Some(&"lib")) {
        segs.pop();
    }
    segs
}

fn in_lock_scope(path: &str) -> bool {
    path.contains("src/dist/") || path.contains("src/serve/")
}

enum Res {
    Resolved(Vec<usize>),
    Unresolved,
    External,
}

struct Graph<'a> {
    fns: Vec<&'a FnFact>,
    by_name: BTreeMap<&'a str, Vec<usize>>,
    mods: Vec<Vec<&'a str>>,
}

impl<'a> Graph<'a> {
    fn build(all: &[&'a FnFact]) -> Graph<'a> {
        let fns: Vec<&FnFact> = all.iter().copied().filter(|f| !f.is_test).collect();
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            by_name.entry(f.name.as_str()).or_default().push(i);
        }
        let mods = fns.iter().map(|f| module_segments(&f.path)).collect();
        Graph { fns, by_name, mods }
    }

    fn resolve(&self, caller: usize, c: &CallFact) -> Res {
        let cands = match self.by_name.get(c.name.as_str()) {
            Some(v) => v.as_slice(),
            None => return Res::External,
        };
        if c.method {
            if AMBIENT.contains(&c.name.as_str()) {
                return Res::External;
            }
            if c.recv_self {
                if let Some(o) = &self.fns[caller].owner {
                    let own: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&k| self.fns[k].owner.as_deref() == Some(o.as_str()))
                        .collect();
                    if !own.is_empty() {
                        return Res::Resolved(own);
                    }
                }
            }
            let typed: Vec<usize> =
                cands.iter().copied().filter(|&k| self.fns[k].owner.is_some()).collect();
            match typed.len() {
                0 => Res::External,
                1 => Res::Resolved(typed),
                _ => Res::Unresolved,
            }
        } else {
            match c.quals.last().map(String::as_str) {
                None | Some("crate") | Some("super") => Res::Resolved(cands.to_vec()),
                Some(q) => {
                    let q = if q == "Self" {
                        match &self.fns[caller].owner {
                            Some(o) => o.as_str(),
                            None => return Res::External,
                        }
                    } else {
                        q
                    };
                    let filtered: Vec<usize> = cands
                        .iter()
                        .copied()
                        .filter(|&k| {
                            self.fns[k].owner.as_deref() == Some(q) || self.mods[k].contains(&q)
                        })
                        .collect();
                    if filtered.is_empty() {
                        Res::External
                    } else {
                        Res::Resolved(filtered)
                    }
                }
            }
        }
    }
}

/// Run the interprocedural rules over every collected function fact.
pub fn analyze(all: &[&FnFact]) -> GraphOutcome {
    let g = Graph::build(all);
    let n = g.fns.len();

    // Resolve every call once: per caller, (call index, targets).
    let mut edges: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); n];
    let mut unresolved = 0usize;
    for (i, f) in g.fns.iter().enumerate() {
        for (ci, c) in f.calls.iter().enumerate() {
            match g.resolve(i, c) {
                Res::Resolved(ts) => edges[i].push((ci, ts)),
                Res::Unresolved => unresolved += 1,
                Res::External => {}
            }
        }
    }

    // blocks*: the primitive blocking name a function reaches, if any.
    let mut blocks: Vec<Option<String>> = g
        .fns
        .iter()
        .map(|f| {
            f.calls
                .iter()
                .find(|c| BLOCKING.contains(&c.name.as_str()))
                .map(|c| c.name.clone())
        })
        .collect();
    loop {
        let mut changed = false;
        for i in 0..n {
            if blocks[i].is_some() {
                continue;
            }
            let hit = edges[i]
                .iter()
                .flat_map(|(_, ts)| ts.iter())
                .find_map(|&t| blocks[t].clone());
            if let Some(via) = hit {
                blocks[i] = Some(via);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    // acquires*: lock fields a function may take, directly or transitively.
    let mut acq: Vec<BTreeSet<String>> = g
        .fns
        .iter()
        .map(|f| f.acqs.iter().map(|a| a.field.clone()).collect())
        .collect();
    loop {
        let mut changed = false;
        for i in 0..n {
            let mut add: Vec<String> = Vec::new();
            for (_, ts) in &edges[i] {
                for &t in ts {
                    for fld in &acq[t] {
                        if !acq[i].contains(fld) {
                            add.push(fld.clone());
                        }
                    }
                }
            }
            for fld in add {
                acq[i].insert(fld);
                changed = true;
            }
        }
        if !changed {
            break;
        }
    }

    let mut diags: Vec<Diagnostic> = Vec::new();

    // ---- rule 6a: guard live across a blocking call (dist/ + serve/) ----
    for (i, f) in g.fns.iter().enumerate() {
        if !in_lock_scope(&f.path) {
            continue;
        }
        let targets = |ci: usize| {
            edges[i].iter().find(|(k, _)| *k == ci).map(|(_, ts)| ts.as_slice())
        };
        for (ci, c) in f.calls.iter().enumerate() {
            if c.held.is_empty() {
                continue;
            }
            let held = c.held.join("`, `");
            if BLOCKING.contains(&c.name.as_str()) {
                diags.push(Diagnostic {
                    rule: R_LOCK,
                    path: f.path.clone(),
                    line: c.line,
                    msg: format!(
                        "`{}` blocks while guard(s) `{held}` are held; \
                         serialize first and drop the guard before blocking",
                        c.name
                    ),
                });
            } else if let Some(ts) = targets(ci) {
                if let Some((t, via)) =
                    ts.iter().find_map(|&t| blocks[t].as_ref().map(|v| (t, v)))
                {
                    diags.push(Diagnostic {
                        rule: R_LOCK,
                        path: f.path.clone(),
                        line: c.line,
                        msg: format!(
                            "`{}` reaches blocking `{via}` while guard(s) `{held}` \
                             are held; drop the guard before calling it",
                            g.fns[t].name
                        ),
                    });
                }
            }
        }
    }

    // ---- rule 6b: inconsistent lock acquisition order ----
    // Evidence: (held, acquired) pairs from direct acquisitions and from
    // calls into functions that acquire transitively. An inversion is the
    // same unordered pair seen in both orders anywhere in dist/ + serve/.
    let mut pairs: BTreeMap<(String, String), Vec<(String, u32)>> = BTreeMap::new();
    for (i, f) in g.fns.iter().enumerate() {
        if !in_lock_scope(&f.path) {
            continue;
        }
        for a in &f.acqs {
            for h in &a.held {
                if h != &a.field {
                    pairs
                        .entry((h.clone(), a.field.clone()))
                        .or_default()
                        .push((f.path.clone(), a.line));
                }
            }
        }
        for (ci, ts) in &edges[i] {
            let c = &f.calls[*ci];
            if c.held.is_empty() {
                continue;
            }
            for &t in ts {
                for fld in &acq[t] {
                    for h in &c.held {
                        if h != fld {
                            pairs
                                .entry((h.clone(), fld.clone()))
                                .or_default()
                                .push((f.path.clone(), c.line));
                        }
                    }
                }
            }
        }
    }
    let mut order_sites: BTreeSet<(String, u32, String)> = BTreeSet::new();
    for ((a, b), sites) in &pairs {
        let Some(rev) = pairs.get(&(b.clone(), a.clone())) else { continue };
        let (opath, oline) = &rev[0];
        for (path, line) in sites {
            if order_sites.insert((path.clone(), *line, format!("{a}->{b}"))) {
                diags.push(Diagnostic {
                    rule: R_LOCK,
                    path: path.clone(),
                    line: *line,
                    msg: format!(
                        "lock `{b}` taken while `{a}` is held, but the opposite \
                         order appears at {opath}:{oline}; pick one order"
                    ),
                });
            }
        }
    }

    // ---- rule 8: transitive hot-alloc ----
    // Seeds: resolved callees of calls made inside hot regions. Walk the
    // resolved graph from them; any allocation-family site in a reached
    // body (outside that body's own lexical hot regions, which rule 3
    // already covers) is on a hot path.
    let mut chain: BTreeMap<usize, String> = BTreeMap::new();
    let mut queue: Vec<usize> = Vec::new();
    for (i, f) in g.fns.iter().enumerate() {
        for (ci, ts) in &edges[i] {
            if !f.calls[*ci].in_hot {
                continue;
            }
            for &t in ts {
                if t != i && !chain.contains_key(&t) {
                    chain.insert(t, format!("{} -> {}", f.name, g.fns[t].name));
                    queue.push(t);
                }
            }
        }
    }
    let mut seen_alloc: BTreeSet<(String, u32, String)> = BTreeSet::new();
    while let Some(t) = queue.pop() {
        let via = chain[&t].clone();
        for a in &g.fns[t].allocs {
            if a.in_hot {
                continue;
            }
            if seen_alloc.insert((g.fns[t].path.clone(), a.line, a.what.clone())) {
                diags.push(Diagnostic {
                    rule: R_HOT,
                    path: g.fns[t].path.clone(),
                    line: a.line,
                    msg: format!(
                        "{} in `{}` is on a hot path ({via}); hoist into \
                         caller-provided scratch",
                        a.what, g.fns[t].name
                    ),
                });
            }
        }
        for (_, ts) in &edges[t] {
            for &u in ts {
                if u != t && !chain.contains_key(&u) {
                    chain.insert(u, format!("{via} -> {}", g.fns[u].name));
                    queue.push(u);
                }
            }
        }
    }

    GraphOutcome { diags, unresolved }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn facts(path: &str, src: &str) -> Vec<FnFact> {
        crate::scan::scan_file(path, src).fns
    }

    fn run(sources: &[(&str, &str)]) -> GraphOutcome {
        let all: Vec<Vec<FnFact>> =
            sources.iter().map(|(p, s)| facts(p, s)).collect();
        let refs: Vec<&FnFact> = all.iter().flatten().collect();
        analyze(&refs)
    }

    #[test]
    fn module_segments_drop_mod_and_lib() {
        assert_eq!(module_segments("rust/src/dist/transport.rs"), vec!["dist", "transport"]);
        assert_eq!(module_segments("rust/src/dist/mod.rs"), vec!["dist"]);
        assert!(module_segments("rust/src/lib.rs").is_empty());
    }

    #[test]
    fn guard_across_blocking_call_direct_and_transitive() {
        let src = "fn helper(w: &mut T) { send_frame(w, m); }\n\
                   fn bad(x: &S) {\n let mut w = x.writer.lock().unwrap();\n helper(&mut w);\n}\n\
                   fn good(x: &S) {\n let b = encode(m);\n let mut w = x.writer.lock().unwrap();\n drop(w);\n helper_b();\n}";
        let out = run(&[("rust/src/dist/a.rs", src)]);
        assert_eq!(out.diags.len(), 1, "{:?}", out.diags);
        assert_eq!(out.diags[0].line, 4);
        assert!(out.diags[0].msg.contains("send_frame"), "{:?}", out.diags);
    }

    #[test]
    fn temp_guard_dies_at_statement_end() {
        let src = "fn ok(x: &S) {\n x.pending.lock().unwrap().insert(1, 2);\n send_frame(w, m);\n}";
        let out = run(&[("rust/src/dist/a.rs", src)]);
        assert!(out.diags.is_empty(), "{:?}", out.diags);
    }

    #[test]
    fn for_iterator_guard_lives_through_body() {
        let src = "fn bad(x: &S) {\n for h in x.readers.lock().unwrap().drain(..) {\n let _ = h.join();\n }\n}";
        let out = run(&[("rust/src/dist/a.rs", src)]);
        assert_eq!(out.diags.len(), 1, "{:?}", out.diags);
        assert_eq!(out.diags[0].line, 3);
    }

    #[test]
    fn plain_if_condition_guard_dies_at_brace() {
        let src = "fn ok(x: &S) {\n if x.pending.lock().unwrap().remove(&id).is_some() {\n send_frame(w, m);\n }\n}";
        let out = run(&[("rust/src/dist/a.rs", src)]);
        assert!(out.diags.is_empty(), "{:?}", out.diags);
    }

    #[test]
    fn lock_order_inversion_reported_both_sites() {
        let src = "fn a(x: &S) {\n let g = x.writer.lock().unwrap();\n let p = x.pending.lock().unwrap();\n}\n\
                   fn b(x: &S) {\n let p = x.pending.lock().unwrap();\n let g = x.writer.lock().unwrap();\n}";
        let out = run(&[("rust/src/dist/a.rs", src)]);
        assert_eq!(out.diags.len(), 2, "{:?}", out.diags);
        assert!(out.diags.iter().all(|d| d.msg.contains("opposite")), "{:?}", out.diags);
    }

    #[test]
    fn transitive_hot_alloc_reaches_two_hops() {
        let src = "fn leaf() -> Vec<f32> { xs.to_vec() }\n\
                   fn mid() { leaf(); }\n\
                   // nodal-lint: hot\n\
                   fn hot_loop() { mid(); }";
        let out = run(&[("rust/src/grad/a.rs", src)]);
        assert_eq!(out.diags.len(), 1, "{:?}", out.diags);
        assert_eq!(out.diags[0].rule, R_HOT);
        assert!(out.diags[0].msg.contains("hot_loop -> mid -> leaf"), "{:?}", out.diags);
    }

    #[test]
    fn ambiguous_method_call_is_counted_not_resolved() {
        let src = "impl A { fn go(&self) {} }\nimpl B { fn go(&self) {} }\n\
                   // nodal-lint: hot\n\
                   fn hot_loop(x: &X) { x.go(); }";
        let out = run(&[("rust/src/ode/a.rs", src)]);
        assert!(out.diags.is_empty(), "{:?}", out.diags);
        assert_eq!(out.unresolved, 1);
    }

    #[test]
    fn bare_self_method_resolves_to_owner() {
        let src = "impl A {\n fn kernel(&self) -> Vec<f32> { xs.to_vec() }\n}\n\
                   impl B {\n fn kernel(&self) {}\n}\n\
                   impl Tr for A {\n // nodal-lint: hot\n fn batch(&self) { self.kernel(); }\n}";
        let out = run(&[("rust/src/ode/a.rs", src)]);
        assert_eq!(out.diags.len(), 1, "{:?}", out.diags);
        assert!(out.diags[0].msg.contains("batch -> kernel"), "{:?}", out.diags);
        assert_eq!(out.unresolved, 0);
    }
}
