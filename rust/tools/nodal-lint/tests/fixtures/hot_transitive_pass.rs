// Fixture: the hot loop only reaches an allocation-free helper, so the
// transitive hot-alloc rule stays quiet. The `obj.step(y)` method call
// has two same-named candidates (`A::step`, `B::step`) and is counted as
// unresolved rather than guessed. Virtual path `rust/src/ode/batch.rs`.

fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

pub struct A;
pub struct B;

impl A {
    pub fn step(&self, y: &mut [f32]) {
        axpy(y, 2.0, y.to_vec().as_slice());
    }
}

impl B {
    pub fn step(&self, y: &mut [f32]) {
        axpy(y, 3.0, y.to_vec().as_slice());
    }
}

pub fn sweep(obj: &A, y: &mut [f32], x: &[f32], rounds: usize) {
    // nodal-lint: hot
    for _ in 0..rounds {
        axpy(y, 0.5, x);
        obj.step(y);
    }
}
