// Fixture: wall-clock reads are fine inside Clock impls (virtual path
// `rust/src/serve/mod.rs`), and HashMap is fine outside ode/grad/ckpt.

use std::collections::HashMap;
use std::time::Instant;

pub trait Clock {
    fn now(&self) -> Instant;
}

pub struct WallClock;

impl Clock for WallClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

impl Default for WallClock {
    fn default() -> Self {
        let _warm = Instant::now();
        WallClock
    }
}

pub fn registry() -> HashMap<String, usize> {
    HashMap::new()
}
