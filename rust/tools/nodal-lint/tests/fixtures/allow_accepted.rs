// Fixture: a justified allow (rule name + reason) suppresses the
// diagnostic on its own line and the next. Virtual path
// `rust/src/serve/worker.rs`.

pub fn drain(q: &Queue) -> Item {
    // nodal-lint: allow(panic-isolation) drain() is only called after poll() returned Ready
    q.pop().unwrap()
}
