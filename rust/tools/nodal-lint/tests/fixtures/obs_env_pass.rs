// Fixture: the tracing subsystem's parse-and-clamp helper (virtual path
// `rust/src/obs/mod.rs`) is a designated env reader — `NODAL_TRACE_*`
// knobs are parsed and clamped there and nowhere else.

pub fn trace_env() -> (u64, String) {
    let sample_n = match std::env::var("NODAL_TRACE_SAMPLE_N")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
    {
        Some(n) => n.clamp(0, 1_000_000),
        None => 0,
    };
    let dir = match std::env::var("NODAL_TRACE_DIR") {
        Ok(d) if !d.is_empty() => d,
        _ => String::from("results/trace"),
    };
    (sample_n, dir)
}
