// Fixture: env reads are fine inside the designated parse-and-clamp helper
// (linted under the virtual path `rust/src/pool.rs`) and inside tests.

pub fn default_workers() -> usize {
    std::env::var("NODAL_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
        .clamp(1, 64)
}

#[cfg(test)]
mod tests {
    #[test]
    fn reads_env_freely() {
        std::env::var("NODAL_WORKERS").ok();
    }
}
