// Fixture: the HTTP front door's parse-and-clamp helper (virtual path
// `rust/src/serve/http.rs`) is a designated env reader — `NODAL_HTTP_*`
// knobs are parsed and clamped there and nowhere else.

fn env_clamped(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(lo, hi),
        None => default,
    }
}

pub fn max_body_bytes() -> usize {
    env_clamped("NODAL_HTTP_MAX_BODY_BYTES", 1 << 20, 1024, 64 << 20)
}
