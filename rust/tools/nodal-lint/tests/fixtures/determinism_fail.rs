// Fixture: result-affecting module (virtual path `rust/src/ode/solver.rs`)
// reading the wall clock and iterating a HashMap. Four determinism
// diagnostics: Instant::now, SystemTime::now, and both HashMap mentions.

use std::collections::HashMap;

pub fn step(weights: &HashMap<usize, f64>) -> f64 {
    let _t0 = std::time::Instant::now();
    let _stamp = std::time::SystemTime::now();
    weights.values().sum()
}
