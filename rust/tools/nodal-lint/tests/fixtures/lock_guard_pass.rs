// Fixture: guards are scoped so every blocking call runs guard-free —
// serialize under the lock, send outside it. Virtual path
// `rust/src/dist/dispatch.rs`.

use std::sync::Mutex;

fn send_frame(link: &mut Vec<u8>, bytes: &[u8]) {
    link.extend_from_slice(bytes);
}

fn encode(n: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(n);
    out.resize(n, 0u8);
    out
}

pub fn dispatch(staged: &Mutex<Vec<u8>>, link: &mut Vec<u8>, n: usize) {
    let bytes = encode(n);
    {
        let mut s = staged.lock().unwrap();
        s.extend_from_slice(&bytes);
    }
    send_frame(link, &bytes);
}

pub fn flush_staged(staged: &Mutex<Vec<u8>>, link: &mut Vec<u8>) {
    // Temporary guard: dies at the end of this statement, before the send.
    let bytes = staged.lock().unwrap().clone();
    send_frame(link, &bytes);
}
