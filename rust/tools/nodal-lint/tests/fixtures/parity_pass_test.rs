// Fixture: the bit-equality test that links parity_pass_impl.rs. Virtual
// path `rust/tests/parity.rs`. The marker is the `bit` name segment.

#[test]
fn vdp_vjp_batch_bit_identical_to_scalar() {
    let f = VanDerPol { mu: 1.0 };
    let scalar = run_scalar(&f);
    let batched = run_batched(&f);
    assert_eq!(scalar.to_bits(), batched.to_bits());
}
