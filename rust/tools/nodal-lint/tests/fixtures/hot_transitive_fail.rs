// Fixture: allocations reached *transitively* from a hot region — one
// hop into `stage` (a `vec!`), two hops through `mid` into `leaf` (a
// `.collect()`). Neither allocation is lexically inside the marked
// region. Virtual path `rust/src/grad/batch.rs`.

fn leaf(n: usize) -> Vec<u32> {
    (0..n).collect()
}

fn mid(n: usize) -> Vec<u32> {
    leaf(n)
}

fn stage(buf: &mut Vec<f32>) {
    let extra = vec![0.0f32; 4];
    buf.extend_from_slice(&extra);
}

pub fn hot_loop(buf: &mut Vec<f32>, n: usize) {
    // nodal-lint: hot
    for _ in 0..n {
        stage(buf);
        let _ = mid(n);
    }
}
