// Fixture: an OdeFunc impl overriding eval_batch with no bit-equality
// test anywhere naming the type. Virtual path `rust/src/ode/rogue.rs`.

pub struct RogueFlow;

impl OdeFunc for RogueFlow {
    fn eval_batch(&self, _t: &[f64], z: &[f64], dz: &mut [f64]) {
        dz.copy_from_slice(z);
    }
}
