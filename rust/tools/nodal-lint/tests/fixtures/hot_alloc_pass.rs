// Fixture: a hot region that reuses pre-sized scratch — no allocation in
// the marked loop, so the hot-alloc rule stays quiet. The allocations all
// happen before the marker. Virtual path `rust/src/ode/batch.rs`.

pub fn advance(z: &mut [f64], k: &[f64], rounds: usize) {
    let mut active: Vec<usize> = (0..z.len()).collect();
    let mut next_active: Vec<usize> = Vec::with_capacity(active.len());
    let mut scratch = vec![0.0; z.len()];

    // nodal-lint: hot
    for _ in 0..rounds {
        next_active.clear();
        for &a in &active {
            scratch[a] = z[a] + k[a];
            if scratch[a] > 0.0 {
                next_active.push(a);
            }
        }
        z.copy_from_slice(&scratch);
        std::mem::swap(&mut active, &mut next_active);
    }
}
