// Fixture: six allocations inside a `hot` region, one per banned family.
// Virtual path `rust/src/grad/batch.rs`.

// nodal-lint: hot
pub fn reverse_sweep(lam: &[f64]) -> Vec<f64> {
    let a = vec![0.0; lam.len()];
    let mut b: Vec<f64> = Vec::new();
    let c = lam.to_vec();
    let d: Vec<f64> = lam.iter().copied().collect();
    let e = c.clone();
    let f = Box::new(e);
    b.extend_from_slice(&a);
    b.extend_from_slice(&d);
    b.extend_from_slice(&f);
    b
}
