// Fixture: the dist/ parse-and-clamp helpers (`from_env`, `env_usize`)
// are designated env readers when linted under the virtual path
// `rust/src/dist/env.rs`.

pub struct DistConfig {
    pub world_size: usize,
}

impl DistConfig {
    pub fn from_env() -> Self {
        Self { world_size: env_usize("NODAL_DIST_WORLD_SIZE", 1, 1, 256) }
    }
}

fn env_usize(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(default)
        .clamp(lo, hi)
}
