// Fixture: allows that must NOT suppress — one missing its mandatory
// reason, one naming an unknown rule. Both are directive diagnostics and
// the underlying panic-isolation diagnostics still fire. Virtual path
// `rust/src/serve/worker.rs`.

pub fn drain(q: &Queue) -> Item {
    // nodal-lint: allow(panic-isolation)
    q.pop().unwrap()
}

pub fn peek(q: &Queue) -> Item {
    // nodal-lint: allow(no-such-rule) because reasons
    q.front().unwrap()
}
