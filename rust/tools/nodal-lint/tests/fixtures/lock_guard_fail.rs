// Fixture: three guard-across-blocking shapes — a direct send under a
// named guard, a `for` whose iterator keeps the temporary guard alive
// through the body, and a transitive reach through a helper. Virtual
// path `rust/src/dist/dispatch.rs`.

use std::sync::Mutex;

fn send_frame(link: &mut Vec<u8>, bytes: &[u8]) {
    link.extend_from_slice(bytes);
}

fn flush_link(link: &mut Vec<u8>) {
    send_frame(link, &[0u8]);
}

pub fn direct(writer: &Mutex<Vec<u8>>) {
    let mut w = writer.lock().unwrap();
    send_frame(&mut w, &[1u8]);
}

pub fn for_temp(conns: &Mutex<Vec<Vec<u8>>>) {
    for c in conns.lock().unwrap().iter_mut() {
        send_frame(c, &[2u8]);
    }
}

pub fn transitive(writer: &Mutex<Vec<u8>>) {
    let mut w = writer.lock().unwrap();
    flush_link(&mut w);
}
