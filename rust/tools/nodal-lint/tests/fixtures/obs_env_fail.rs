// Fixture: the same helper name OUTSIDE the designated file (virtual path
// `rust/src/obs/export.rs`) must be flagged — the env-knob allowlist is
// (path suffix, fn name) pairs, never fn name alone.

pub fn trace_env() -> u64 {
    match std::env::var("NODAL_TRACE_SAMPLE_N").ok().and_then(|v| v.parse::<u64>().ok()) {
        Some(n) => n,
        None => 0,
    }
}
