// Fixture: serve code (virtual path `rust/src/serve/worker.rs`) that
// handles failure without panicking: poison-idiom unwrap on a mutex,
// a bound-commented constant index, and error returns elsewhere.

pub fn execute(core: &Core, batch: &FormedBatch) -> Result<(), ServeError> {
    let mut led = core.inflight.lock().unwrap();
    // Formed batches are non-empty by construction (batcher never drains
    // an empty bucket), so indexing the first item is safe.
    let first = &batch.items[0];
    let grad = match first.req.grad.as_ref() {
        Some(g) => g,
        None => return Err(ServeError::MissingGrad),
    };
    led.count += grad.len();
    Ok(())
}
