// Fixture: `emit` is defined on two different owners, so `t.emit()`
// cannot be attributed to either — the edge is counted as unresolved
// (and reported in the summary), never guessed. Virtual path
// `rust/src/ode/probe.rs`.

pub struct Tcp;
pub struct Udp;

impl Tcp {
    pub fn emit(&self) -> usize {
        1
    }
}

impl Udp {
    pub fn emit(&self) -> usize {
        2
    }
}

pub fn poke(t: &Tcp) -> usize {
    t.emit()
}
