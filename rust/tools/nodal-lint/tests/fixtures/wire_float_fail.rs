// Fixture: three ways a raw float reaches the JSON wire — constructing
// `Json::Num`, reading `.as_f64()` off the wire, and a float literal
// converted via `.into()`. Virtual path `rust/src/dist/reduce.rs`.

use crate::util::json::Json;

pub fn encode(loss: f64) -> Json {
    Json::Num(loss)
}

pub fn decode(v: &Json) -> f64 {
    v.as_f64().unwrap_or(0.0)
}

pub fn tag() -> Json {
    1.5f32.into()
}
