// Fixture: the same helper name OUTSIDE the designated file (virtual path
// `rust/src/serve/wire.rs`) must be flagged — the env-knob allowlist is
// (path suffix, fn name) pairs, never fn name alone.

fn env_clamped(name: &str, default: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n,
        None => default,
    }
}

pub fn sneak_port() -> usize {
    env_clamped("NODAL_HTTP_PORT", 7118)
}
