// Fixture: floats cross the wire as u32 bit patterns in both
// directions, so NaN / -0.0 / infinities survive bit-exactly and the
// wire-determinism rule stays quiet. Virtual path
// `rust/src/dist/reduce.rs`.

fn f32_bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn f32s_from_bits(bits: &[u32]) -> Vec<f32> {
    bits.iter().map(|b| f32::from_bits(*b)).collect()
}

pub fn encode(values: &[f32]) -> Vec<u32> {
    f32_bits(values)
}

pub fn decode(bits: &[u32]) -> Vec<f32> {
    f32s_from_bits(bits)
}
