// Fixture: an env read outside the designated dist/env.rs helpers —
// here inside the transport layer — must fire the env-knob rule.

pub fn io_timeout_ms() -> u64 {
    std::env::var("NODAL_DIST_PORT").map_or(30_000, |s| s.len() as u64)
}
