// Fixture: an env read smuggled into solver code (virtual path
// `rust/src/ode/solver.rs`) must be flagged by the env-knob rule.

pub fn step_budget() -> usize {
    std::env::var("NODAL_WORKERS").map_or(64, |s| s.len())
}
