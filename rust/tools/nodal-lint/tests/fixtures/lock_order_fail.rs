// Fixture: `enqueue` takes `pending` then `writer`; `flush` takes them
// in the opposite order — the classic ABBA deadlock shape. Both sites
// are reported, each pointing at the other. Virtual path
// `rust/src/dist/dispatch.rs`.

use std::sync::Mutex;

pub struct Link {
    pending: Mutex<Vec<u64>>,
    writer: Mutex<Vec<u8>>,
}

pub fn enqueue(link: &Link, id: u64) {
    let mut pending = link.pending.lock().unwrap();
    pending.push(id);
    let mut w = link.writer.lock().unwrap();
    w.push(id as u8);
}

pub fn flush(link: &Link) {
    let mut w = link.writer.lock().unwrap();
    let pending = link.pending.lock().unwrap();
    w.push(pending.len() as u8);
}
