// Fixture: four panic-isolation violations in serve code (virtual path
// `rust/src/serve/worker.rs`): .unwrap() on request data, .expect(),
// panic!, and an uncommented constant index.

pub fn execute(batch: &FormedBatch) -> f64 {
    let lam = batch.items[0].req.grad.as_ref().unwrap();
    let z = batch.traj.last().expect("non-empty trajectory");
    if lam.is_empty() {
        panic!("empty cotangent");
    }
    z + lam.len() as f64
}
