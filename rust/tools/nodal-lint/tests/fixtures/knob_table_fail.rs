// Fixture: references a knob that the lib.rs table does not document.
// Linted together with knob_table_lib.rs (as `rust/src/lib.rs`).

pub fn results_dir() -> String {
    std::env::var("NODAL_UNDOCUMENTED_KNOB").unwrap_or_else(|_| "results".to_string())
}
