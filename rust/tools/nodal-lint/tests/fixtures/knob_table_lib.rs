//! Fixture: a miniature main-crate lib.rs whose knob table documents only
//! `NODAL_WORKERS`. Linted under the virtual path `rust/src/lib.rs`.
//!
//! | knob            | meaning              |
//! |-----------------|----------------------|
//! | `NODAL_WORKERS` | worker thread count  |

pub mod pool;
