// Fixture: two functions take the same pair of locks, both in the same
// `pending` → `writer` order, so no inversion is possible. Virtual path
// `rust/src/dist/dispatch.rs`.

use std::sync::Mutex;

pub struct Link {
    pending: Mutex<Vec<u64>>,
    writer: Mutex<Vec<u8>>,
}

pub fn enqueue(link: &Link, id: u64) {
    let mut pending = link.pending.lock().unwrap();
    pending.push(id);
    let mut w = link.writer.lock().unwrap();
    w.push(id as u8);
}

pub fn retire(link: &Link, id: u64) {
    let mut pending = link.pending.lock().unwrap();
    pending.retain(|x| *x != id);
    let mut w = link.writer.lock().unwrap();
    w.clear();
}
