// Fixture: an OdeFunc impl overriding both batch methods (virtual path
// `rust/src/ode/vdp.rs`). Clean only when linted together with
// parity_pass_test.rs, whose bit-equality test names VanDerPol.

pub struct VanDerPol {
    mu: f64,
}

impl OdeFunc for VanDerPol {
    fn eval(&self, _t: f64, z: &[f64], dz: &mut [f64]) {
        dz[0] = z[1] * self.mu;
    }

    fn eval_batch(&self, _t: &[f64], z: &[f64], dz: &mut [f64]) {
        dz.copy_from_slice(z);
    }

    fn vjp_batch(&self, _t: &[f64], z: &[f64], lam: &mut [f64]) {
        lam.copy_from_slice(z);
    }
}

// The generic forwarding impl is exempt: a single-letter target is a
// generic parameter, not a parity surface of its own.
impl<F: OdeFunc + ?Sized> OdeFunc for &F {
    fn eval_batch(&self, t: &[f64], z: &[f64], dz: &mut [f64]) {
        (**self).eval_batch(t, z, dz)
    }
}
