//! Fixture-driven tests for each lint rule (one passing and one failing
//! snippet per rule, allow accepted/rejected), plus the meta-test that the
//! real tree is lint-clean.
//!
//! Fixtures live in `tests/fixtures/` and are linted under *virtual* paths
//! so the path-scoped rules (serve/, ode/, tests/) engage exactly as they
//! would in the real tree.

use std::path::Path;

use nodal_lint::{
    lint_sources, lint_tree, Outcome, R_DET, R_DIRECTIVE, R_ENV, R_HOT, R_LOCK, R_PANIC, R_PARITY,
    R_WIRE,
};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

fn lint_one(virtual_path: &str, name: &str) -> Outcome {
    lint_sources(&[(virtual_path.to_string(), fixture(name))])
}

fn rules_of(out: &Outcome) -> Vec<&'static str> {
    out.diags.iter().map(|d| d.rule).collect()
}

// ---- rule 1: env-knob ----

#[test]
fn env_knob_pass_fixture_is_clean() {
    let out = lint_one("rust/src/pool.rs", "env_knob_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn env_knob_fail_fixture_fires() {
    let out = lint_one("rust/src/ode/solver.rs", "env_knob_fail.rs");
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
}

#[test]
fn dist_env_pass_fixture_is_clean() {
    let out = lint_one("rust/src/dist/env.rs", "dist_env_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn dist_env_fail_fixture_fires() {
    let out = lint_one("rust/src/dist/transport.rs", "dist_env_fail.rs");
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
}

#[test]
fn http_env_pass_fixture_is_clean() {
    let out = lint_one("rust/src/serve/http.rs", "http_env_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn http_env_fail_fixture_fires_outside_the_designated_file() {
    // Same helper name, wrong file: the allowlist is (path, fn) pairs.
    let out = lint_one("rust/src/serve/wire.rs", "http_env_fail.rs");
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
}

#[test]
fn obs_env_pass_fixture_is_clean() {
    let out = lint_one("rust/src/obs/mod.rs", "obs_env_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn obs_env_fail_fixture_fires_outside_the_designated_file() {
    // Same helper name, wrong file: the allowlist is (path, fn) pairs.
    let out = lint_one("rust/src/obs/export.rs", "obs_env_fail.rs");
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
}

#[test]
fn knob_table_flags_undocumented_knob() {
    let lib = ("rust/src/lib.rs".to_string(), fixture("knob_table_lib.rs"));
    // A documented knob passes…
    let ok = ("rust/src/pool.rs".to_string(), fixture("env_knob_pass.rs"));
    let out = lint_sources(&[lib.clone(), ok]);
    assert!(out.clean(), "{:?}", out.diags);
    // …an undocumented one is flagged even inside a designated helper.
    let bad = ("rust/src/report.rs".to_string(), fixture("knob_table_fail.rs"));
    let out = lint_sources(&[lib, bad]);
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
    assert!(out.diags[0].msg.contains("NODAL_UNDOCUMENTED_KNOB"), "{:?}", out.diags);
}

// ---- rule 2: determinism ----

#[test]
fn determinism_pass_fixture_is_clean() {
    let out = lint_one("rust/src/serve/mod.rs", "determinism_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn determinism_fail_fixture_fires() {
    let out = lint_one("rust/src/ode/solver.rs", "determinism_fail.rs");
    assert_eq!(rules_of(&out), vec![R_DET; 4], "{:?}", out.diags);
}

// ---- rule 3: hot-alloc ----

#[test]
fn hot_alloc_pass_fixture_is_clean() {
    let out = lint_one("rust/src/ode/batch.rs", "hot_alloc_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn hot_alloc_fail_fixture_fires_per_family() {
    let out = lint_one("rust/src/grad/batch.rs", "hot_alloc_fail.rs");
    assert_eq!(rules_of(&out), vec![R_HOT; 6], "{:?}", out.diags);
    for family in ["vec!", "Vec::new", ".to_vec()", ".collect()", ".clone()", "Box::new"] {
        assert!(
            out.diags.iter().any(|d| d.msg.contains(family)),
            "missing {family}: {:?}",
            out.diags
        );
    }
}

// ---- rule 4: panic-isolation ----

#[test]
fn panic_pass_fixture_is_clean() {
    let out = lint_one("rust/src/serve/worker.rs", "panic_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn panic_fail_fixture_fires() {
    let out = lint_one("rust/src/serve/worker.rs", "panic_fail.rs");
    assert_eq!(rules_of(&out), vec![R_PANIC; 4], "{:?}", out.diags);
}

// ---- rule 5: parity-linkage ----

#[test]
fn parity_unlinked_impl_fires_per_override() {
    let out = lint_one("rust/src/ode/rogue.rs", "parity_fail.rs");
    assert_eq!(rules_of(&out), vec![R_PARITY], "{:?}", out.diags);
    // Both overrides of an unlinked impl are reported.
    let out = lint_one("rust/src/ode/vdp.rs", "parity_pass_impl.rs");
    assert_eq!(rules_of(&out), vec![R_PARITY; 2], "{:?}", out.diags);
}

#[test]
fn parity_linked_by_cross_file_bit_test_is_clean() {
    let out = lint_sources(&[
        ("rust/src/ode/vdp.rs".to_string(), fixture("parity_pass_impl.rs")),
        ("rust/tests/parity.rs".to_string(), fixture("parity_pass_test.rs")),
    ]);
    assert!(out.clean(), "{:?}", out.diags);
}

// ---- rule 6: lock-discipline ----

#[test]
fn lock_guard_pass_fixture_is_clean() {
    let out = lint_one("rust/src/dist/dispatch.rs", "lock_guard_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
    assert_eq!(out.unresolved, 0, "all calls in the fixture are direct");
}

#[test]
fn lock_guard_fail_fixture_fires_direct_for_temp_and_transitive() {
    let out = lint_one("rust/src/dist/dispatch.rs", "lock_guard_fail.rs");
    assert_eq!(rules_of(&out), vec![R_LOCK; 3], "{:?}", out.diags);
    // Direct and for-temp sites name the blocking call; the transitive
    // site names the helper that reaches it.
    assert_eq!(
        out.diags.iter().filter(|d| d.msg.contains("`send_frame` blocks")).count(),
        2,
        "{:?}",
        out.diags
    );
    assert!(
        out.diags.iter().any(|d| d.msg.contains("`flush_link` reaches blocking `send_frame`")),
        "{:?}",
        out.diags
    );
}

#[test]
fn lock_order_pass_fixture_is_clean() {
    let out = lint_one("rust/src/dist/dispatch.rs", "lock_order_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn lock_order_inversion_fires_at_both_sites() {
    let out = lint_one("rust/src/dist/dispatch.rs", "lock_order_fail.rs");
    assert_eq!(rules_of(&out), vec![R_LOCK; 2], "{:?}", out.diags);
    for d in &out.diags {
        assert!(d.msg.contains("opposite order"), "{:?}", out.diags);
    }
}

// ---- rule 7: wire-determinism ----

#[test]
fn wire_float_pass_fixture_is_clean() {
    let out = lint_one("rust/src/dist/reduce.rs", "wire_float_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn wire_float_fail_fixture_fires_per_shape() {
    let out = lint_one("rust/src/dist/reduce.rs", "wire_float_fail.rs");
    assert_eq!(rules_of(&out), vec![R_WIRE; 3], "{:?}", out.diags);
    // The same file outside dist/ is not wire-scoped.
    let out = lint_one("rust/src/serve/mod.rs", "wire_float_fail.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

// ---- rule 8: transitive hot-alloc ----

#[test]
fn hot_transitive_pass_fixture_is_clean_and_counts_ambiguity() {
    let out = lint_one("rust/src/ode/batch.rs", "hot_transitive_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
    // `obj.step(y)` has two same-named candidates: counted, not guessed —
    // so the `.to_vec()` inside the candidates is NOT flagged.
    assert!(out.unresolved >= 1, "ambiguous method call should be counted");
}

#[test]
fn hot_transitive_fail_fixture_fires_through_one_and_two_hops() {
    let out = lint_one("rust/src/grad/batch.rs", "hot_transitive_fail.rs");
    assert_eq!(rules_of(&out), vec![R_HOT; 2], "{:?}", out.diags);
    assert!(
        out.diags.iter().any(|d| d.msg.contains("(hot_loop -> stage)")),
        "{:?}",
        out.diags
    );
    assert!(
        out.diags.iter().any(|d| d.msg.contains("(hot_loop -> mid -> leaf)")),
        "{:?}",
        out.diags
    );
}

#[test]
fn unresolved_method_calls_are_counted() {
    let out = lint_one("rust/src/ode/probe.rs", "unresolved_calls.rs");
    assert!(out.clean(), "{:?}", out.diags);
    assert_eq!(out.unresolved, 1, "exactly one ambiguous `emit` edge");
}

// ---- escape hatch ----

#[test]
fn allow_with_reason_suppresses() {
    let out = lint_one("rust/src/serve/worker.rs", "allow_accepted.rs");
    assert!(out.clean(), "{:?}", out.diags);
    assert_eq!(out.suppressed, 1);
}

#[test]
fn allow_without_reason_or_with_unknown_rule_is_rejected() {
    let out = lint_one("rust/src/serve/worker.rs", "allow_rejected.rs");
    let directives = out.diags.iter().filter(|d| d.rule == R_DIRECTIVE).count();
    let panics = out.diags.iter().filter(|d| d.rule == R_PANIC).count();
    assert_eq!((directives, panics), (2, 2), "{:?}", out.diags);
    assert_eq!(out.suppressed, 0);
}

// ---- acceptance: every rule has a failing fixture, and the tree is clean ----

#[test]
fn every_rule_has_a_failing_fixture() {
    let cases = [
        (R_ENV, "rust/src/ode/solver.rs", "env_knob_fail.rs"),
        (R_ENV, "rust/src/serve/wire.rs", "http_env_fail.rs"),
        (R_ENV, "rust/src/obs/export.rs", "obs_env_fail.rs"),
        (R_DET, "rust/src/ode/solver.rs", "determinism_fail.rs"),
        (R_HOT, "rust/src/grad/batch.rs", "hot_alloc_fail.rs"),
        (R_PANIC, "rust/src/serve/worker.rs", "panic_fail.rs"),
        (R_PARITY, "rust/src/ode/rogue.rs", "parity_fail.rs"),
        (R_LOCK, "rust/src/dist/dispatch.rs", "lock_guard_fail.rs"),
        (R_WIRE, "rust/src/dist/reduce.rs", "wire_float_fail.rs"),
        (R_HOT, "rust/src/grad/batch.rs", "hot_transitive_fail.rs"),
    ];
    for (rule, vpath, name) in cases {
        let out = lint_one(vpath, name);
        assert!(
            out.diags.iter().any(|d| d.rule == rule),
            "fixture {name} did not trip {rule}: {:?}",
            out.diags
        );
    }
}

#[test]
fn real_tree_is_lint_clean() {
    // crate dir = <root>/rust/tools/nodal-lint → third ancestor is <root>.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).ancestors().nth(3).unwrap();
    let out = lint_tree(root).expect("lint_tree reads the repo");
    assert!(out.files > 10, "walked only {} files — wrong root?", out.files);
    let rendered: Vec<String> = out
        .diags
        .iter()
        .map(|d| format!("{}:{}: [{}] {}", d.path, d.line, d.rule, d.msg))
        .collect();
    assert!(out.clean(), "real tree is not lint-clean:\n{}", rendered.join("\n"));
}
