//! Fixture-driven tests for each lint rule (one passing and one failing
//! snippet per rule, allow accepted/rejected), plus the meta-test that the
//! real tree is lint-clean.
//!
//! Fixtures live in `tests/fixtures/` and are linted under *virtual* paths
//! so the path-scoped rules (serve/, ode/, tests/) engage exactly as they
//! would in the real tree.

use std::path::Path;

use nodal_lint::{lint_sources, lint_tree, Outcome, R_DET, R_DIRECTIVE, R_ENV, R_HOT, R_PANIC, R_PARITY};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

fn lint_one(virtual_path: &str, name: &str) -> Outcome {
    lint_sources(&[(virtual_path.to_string(), fixture(name))])
}

fn rules_of(out: &Outcome) -> Vec<&'static str> {
    out.diags.iter().map(|d| d.rule).collect()
}

// ---- rule 1: env-knob ----

#[test]
fn env_knob_pass_fixture_is_clean() {
    let out = lint_one("rust/src/pool.rs", "env_knob_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn env_knob_fail_fixture_fires() {
    let out = lint_one("rust/src/ode/solver.rs", "env_knob_fail.rs");
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
}

#[test]
fn dist_env_pass_fixture_is_clean() {
    let out = lint_one("rust/src/dist/env.rs", "dist_env_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn dist_env_fail_fixture_fires() {
    let out = lint_one("rust/src/dist/transport.rs", "dist_env_fail.rs");
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
}

#[test]
fn knob_table_flags_undocumented_knob() {
    let lib = ("rust/src/lib.rs".to_string(), fixture("knob_table_lib.rs"));
    // A documented knob passes…
    let ok = ("rust/src/pool.rs".to_string(), fixture("env_knob_pass.rs"));
    let out = lint_sources(&[lib.clone(), ok]);
    assert!(out.clean(), "{:?}", out.diags);
    // …an undocumented one is flagged even inside a designated helper.
    let bad = ("rust/src/report.rs".to_string(), fixture("knob_table_fail.rs"));
    let out = lint_sources(&[lib, bad]);
    assert_eq!(rules_of(&out), vec![R_ENV], "{:?}", out.diags);
    assert!(out.diags[0].msg.contains("NODAL_UNDOCUMENTED_KNOB"), "{:?}", out.diags);
}

// ---- rule 2: determinism ----

#[test]
fn determinism_pass_fixture_is_clean() {
    let out = lint_one("rust/src/serve/mod.rs", "determinism_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn determinism_fail_fixture_fires() {
    let out = lint_one("rust/src/ode/solver.rs", "determinism_fail.rs");
    assert_eq!(rules_of(&out), vec![R_DET; 4], "{:?}", out.diags);
}

// ---- rule 3: hot-alloc ----

#[test]
fn hot_alloc_pass_fixture_is_clean() {
    let out = lint_one("rust/src/ode/batch.rs", "hot_alloc_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn hot_alloc_fail_fixture_fires_per_family() {
    let out = lint_one("rust/src/grad/batch.rs", "hot_alloc_fail.rs");
    assert_eq!(rules_of(&out), vec![R_HOT; 6], "{:?}", out.diags);
    for family in ["vec!", "Vec::new", ".to_vec()", ".collect()", ".clone()", "Box::new"] {
        assert!(
            out.diags.iter().any(|d| d.msg.contains(family)),
            "missing {family}: {:?}",
            out.diags
        );
    }
}

// ---- rule 4: panic-isolation ----

#[test]
fn panic_pass_fixture_is_clean() {
    let out = lint_one("rust/src/serve/worker.rs", "panic_pass.rs");
    assert!(out.clean(), "{:?}", out.diags);
}

#[test]
fn panic_fail_fixture_fires() {
    let out = lint_one("rust/src/serve/worker.rs", "panic_fail.rs");
    assert_eq!(rules_of(&out), vec![R_PANIC; 4], "{:?}", out.diags);
}

// ---- rule 5: parity-linkage ----

#[test]
fn parity_unlinked_impl_fires_per_override() {
    let out = lint_one("rust/src/ode/rogue.rs", "parity_fail.rs");
    assert_eq!(rules_of(&out), vec![R_PARITY], "{:?}", out.diags);
    // Both overrides of an unlinked impl are reported.
    let out = lint_one("rust/src/ode/vdp.rs", "parity_pass_impl.rs");
    assert_eq!(rules_of(&out), vec![R_PARITY; 2], "{:?}", out.diags);
}

#[test]
fn parity_linked_by_cross_file_bit_test_is_clean() {
    let out = lint_sources(&[
        ("rust/src/ode/vdp.rs".to_string(), fixture("parity_pass_impl.rs")),
        ("rust/tests/parity.rs".to_string(), fixture("parity_pass_test.rs")),
    ]);
    assert!(out.clean(), "{:?}", out.diags);
}

// ---- escape hatch ----

#[test]
fn allow_with_reason_suppresses() {
    let out = lint_one("rust/src/serve/worker.rs", "allow_accepted.rs");
    assert!(out.clean(), "{:?}", out.diags);
    assert_eq!(out.suppressed, 1);
}

#[test]
fn allow_without_reason_or_with_unknown_rule_is_rejected() {
    let out = lint_one("rust/src/serve/worker.rs", "allow_rejected.rs");
    let directives = out.diags.iter().filter(|d| d.rule == R_DIRECTIVE).count();
    let panics = out.diags.iter().filter(|d| d.rule == R_PANIC).count();
    assert_eq!((directives, panics), (2, 2), "{:?}", out.diags);
    assert_eq!(out.suppressed, 0);
}

// ---- acceptance: every rule has a failing fixture, and the tree is clean ----

#[test]
fn every_rule_has_a_failing_fixture() {
    let cases = [
        (R_ENV, "rust/src/ode/solver.rs", "env_knob_fail.rs"),
        (R_DET, "rust/src/ode/solver.rs", "determinism_fail.rs"),
        (R_HOT, "rust/src/grad/batch.rs", "hot_alloc_fail.rs"),
        (R_PANIC, "rust/src/serve/worker.rs", "panic_fail.rs"),
        (R_PARITY, "rust/src/ode/rogue.rs", "parity_fail.rs"),
    ];
    for (rule, vpath, name) in cases {
        let out = lint_one(vpath, name);
        assert!(
            out.diags.iter().any(|d| d.rule == rule),
            "fixture {name} did not trip {rule}: {:?}",
            out.diags
        );
    }
}

#[test]
fn real_tree_is_lint_clean() {
    // crate dir = <root>/rust/tools/nodal-lint → third ancestor is <root>.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).ancestors().nth(3).unwrap();
    let out = lint_tree(root).expect("lint_tree reads the repo");
    assert!(out.files > 10, "walked only {} files — wrong root?", out.files);
    let rendered: Vec<String> = out
        .diags
        .iter()
        .map(|d| format!("{}:{}: [{}] {}", d.path, d.line, d.rule, d.msg))
        .collect();
    assert!(out.clean(), "real tree is not lint-clean:\n{}", rendered.join("\n"));
}
