//! Structured tracing: request-scoped spans from the HTTP socket down to
//! the stage sweeps, with zero dependencies and zero hot-path allocation.
//!
//! The paper's claim is a *cost* claim — ACA wins because NFE, checkpoint
//! memory and wall time are lower for the same gradient — so the serving
//! stack must be able to answer "where did *this* request's 40 ms go?":
//! queue wait, DRR deferral, forward rounds, per-stage sweeps, segment
//! replay, reverse rounds. This module provides the span vocabulary, the
//! per-thread recorder, the cross-thread trace store, and the JSONL/JSON
//! codecs; the serve/dist layers emit into it.
//!
//! ## Design: preallocated per-thread recorder
//!
//! Spans are recorded into a thread-local, fixed-capacity `Vec<SpanRec>`
//! ([`record`]). `SpanRec` is `Copy` with `&'static str` names and a
//! fixed-size attribute array, so recording is a bounds check plus a
//! memcpy — **no allocation once the buffer exists** (workers call
//! [`thread_init`] at startup; other threads fault the buffer in on their
//! first non-hot `record`). When the buffer is full, spans are dropped and
//! counted, never reallocated — this is what makes recorder calls legal
//! near `// nodal-lint: hot` regions. Inside the hot loops themselves only
//! [`hot_count`] is used: a thread-local integer add with no branch on
//! sampling state, cheap enough to run unconditionally.
//!
//! ## Why timestamps only come from [`Clock`](crate::serve::Clock)
//!
//! This module never reads a time source. Every `start`/`end` is a
//! [`Duration`] handed in by the caller, who got it from the injected
//! serve-layer clock. That is what makes traces *deterministic*: under
//! [`ManualClock`](crate::serve::ManualClock) a scripted test asserts the
//! exact span tree **and the exact durations**, and the determinism lint
//! rule (no raw `Instant::now` outside the clock) keeps it that way.
//!
//! ## Answer neutrality
//!
//! Tracing never touches the float path: span emission happens strictly
//! outside the solver loops, and the in-loop counters are integer adds.
//! Solves with tracing on and off are bit-identical (grids, finals,
//! gradients, meters) — property-tested in `tests/proptests.rs`.
//!
//! ## Knobs
//!
//! * `NODAL_TRACE_SAMPLE_N` — trace every Nth unsolicited HTTP request
//!   (0 = off; an `x-nodal-trace` header always traces). Parsed and
//!   clamped only by [`trace_env`], the designated env helper.
//! * `NODAL_TRACE_DIR` — JSONL export directory; defaults to
//!   `<results>/trace/` under `NODAL_RESULTS`.

use crate::util::json::{obj, Json};
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Identifiers and context

/// A 64-bit trace identifier; crosses the wire and HTTP headers as 16
/// lower-hex characters. Zero is reserved ("no trace").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// The canonical 16-char lower-hex form (`x-nodal-trace` header value,
    /// wire field, JSONL file stem).
    pub fn to_hex(self) -> String {
        format!("{:016x}", self.0)
    }

    /// Parse the canonical form; rejects anything but exactly 16 hex
    /// digits, and the reserved all-zero id.
    pub fn parse_hex(s: &str) -> Option<TraceId> {
        if s.len() != 16 || !s.bytes().all(|b| b.is_ascii_hexdigit()) {
            return None;
        }
        match u64::from_str_radix(s, 16) {
            Ok(0) | Err(_) => None,
            Ok(v) => Some(TraceId(v)),
        }
    }
}

/// Mint a fresh trace id from a process-wide sequence mixed with the
/// caller's clock reading (splitmix64 finalizer). No wall-clock or RNG is
/// consulted, so minting is deterministic under a `ManualClock`.
pub fn mint(now: Duration) -> TraceId {
    static TRACE_SEQ: AtomicU64 = AtomicU64::new(1);
    let seq = TRACE_SEQ.fetch_add(1, Ordering::Relaxed);
    let mut z = seq ^ (now.as_nanos() as u64);
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^= z >> 31;
    TraceId(if z == 0 { 1 } else { z })
}

/// Propagated trace context: rides inside a
/// [`SolveRequest`](crate::serve::SolveRequest) (never part of the batch
/// key) and inside dist wire frames.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceCtx {
    /// The trace every downstream span joins.
    pub trace: TraceId,
    /// Span id downstream spans parent to (0 = root).
    pub parent: u64,
    /// Shard index stamped on downstream spans (−1 = front door / local).
    pub shard: i64,
}

impl TraceCtx {
    /// A root context for `trace`: parent 0, front-door shard.
    pub fn root(trace: TraceId) -> TraceCtx {
        TraceCtx { trace, parent: 0, shard: -1 }
    }
}

// ---------------------------------------------------------------------------
// Span taxonomy (closed name/key vocabulary — this is also the interning
// table the wire decoder maps onto, so names stay `&'static str`).

/// Root span for one HTTP request (attr: `status`).
pub const HTTP_REQUEST: &str = "http_request";
/// Admission-control decision at submit time.
pub const ADMISSION: &str = "admission";
/// Submit → batch flush, per request (attrs: `lane`, `deferred`).
pub const QUEUE_WAIT: &str = "queue_wait";
/// Batch flush → worker dispatch (attrs: `reason`, `size`).
pub const BATCH_FORM: &str = "batch_form";
/// One request's solve inside a worker batch (attr: `batch_size`).
pub const SOLVE: &str = "solve";
/// Forward integration (attrs: `nfe`, `rounds`, `sweeps`).
pub const FORWARD: &str = "forward";
/// ACA reverse sweep (attrs: `nfe`, `rounds`, `sweeps`).
pub const REVERSE: &str = "reverse";
/// Segment-cache replay cost, child of `reverse` (attrs: `nfe`, `bytes`).
pub const REPLAY: &str = "replay";
/// Per-sample scalar fallback after a poisoned batch (attr: `nfe`).
pub const FALLBACK: &str = "fallback";
/// Dispatcher routing decision (attr: `shard`).
pub const DISPATCH: &str = "dispatch";
/// Work-stealing event: routed off the hash-primary shard.
pub const STEAL: &str = "steal";
/// Dead-shard re-dispatch event.
pub const FAILOVER: &str = "failover";

static SPAN_NAMES: [&str; 12] = [
    HTTP_REQUEST,
    ADMISSION,
    QUEUE_WAIT,
    BATCH_FORM,
    SOLVE,
    FORWARD,
    REVERSE,
    REPLAY,
    FALLBACK,
    DISPATCH,
    STEAL,
    FAILOVER,
];

static ATTR_KEYS: [&str; 10] = [
    "lane", "deferred", "reason", "size", "batch_size", "nfe", "rounds", "sweeps", "bytes",
    "status",
];

fn intern(table: &'static [&'static str], s: &str) -> &'static str {
    table.iter().find(|t| **t == s).copied().unwrap_or("unknown")
}

/// Attribute slots per span; extra attrs are silently dropped.
pub const MAX_ATTRS: usize = 6;

/// One recorded span. `Copy` with a fixed attribute array so the
/// per-thread recorder never allocates per span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRec {
    /// Owning trace (a raw [`TraceId`]).
    pub trace: u64,
    /// This span's id (process-unique; remapped dense on JSONL export).
    pub span: u64,
    /// Parent span id (0 = root).
    pub parent: u64,
    /// Taxonomy name (see the module constants).
    pub name: &'static str,
    pub start_ns: u64,
    pub end_ns: u64,
    /// Shard index (−1 = front door / local process).
    pub shard: i64,
    /// `("", 0)` marks an empty slot.
    pub attrs: [(&'static str, u64); MAX_ATTRS],
}

fn next_span_id() -> u64 {
    static SPAN_SEQ: AtomicU64 = AtomicU64::new(1);
    SPAN_SEQ.fetch_add(1, Ordering::Relaxed)
}

fn dur_ns(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

impl SpanRec {
    /// A span with a freshly minted id under `ctx.parent`.
    pub fn new(ctx: TraceCtx, name: &'static str, start: Duration, end: Duration) -> SpanRec {
        SpanRec {
            trace: ctx.trace.0,
            span: next_span_id(),
            parent: ctx.parent,
            name,
            start_ns: dur_ns(start),
            end_ns: dur_ns(end),
            shard: ctx.shard,
            attrs: [("", 0); MAX_ATTRS],
        }
    }

    /// A zero-duration event span (dispatch / steal / failover markers).
    pub fn event(ctx: TraceCtx, name: &'static str, at: Duration) -> SpanRec {
        SpanRec::new(ctx, name, at, at)
    }

    /// Attach an attribute (dropped silently when all slots are taken).
    pub fn attr(mut self, key: &'static str, val: u64) -> SpanRec {
        for slot in self.attrs.iter_mut() {
            if slot.0.is_empty() {
                *slot = (key, val);
                break;
            }
        }
        self
    }

    /// The context downstream spans use to parent to this span.
    pub fn ctx(&self) -> TraceCtx {
        TraceCtx { trace: TraceId(self.trace), parent: self.span, shard: self.shard }
    }

    /// Attribute lookup (first match).
    pub fn get_attr(&self, key: &str) -> Option<u64> {
        self.attrs.iter().find(|(k, _)| *k == key).map(|(_, v)| *v)
    }
}

// ---------------------------------------------------------------------------
// Per-thread recorder

/// Fixed recorder capacity per thread — spans past this are dropped (and
/// counted), never reallocated into.
const RECORDER_CAP: usize = 256;

/// Forward active-set rounds (one per `while !active.is_empty()` pass).
pub const CTR_FWD_ROUNDS: usize = 0;
/// Forward `eval_batch` stage sweeps.
pub const CTR_FWD_SWEEPS: usize = 1;
/// Reverse rounds (one shared-stage adjoint step over the live set).
pub const CTR_REV_ROUNDS: usize = 2;
/// Reverse `eval_batch`/`vjp_batch` stage sweeps.
pub const CTR_REV_SWEEPS: usize = 3;
const N_CTRS: usize = 4;

struct Recorder {
    spans: Vec<SpanRec>,
    dropped: u64,
    counters: [u64; N_CTRS],
}

thread_local! {
    static RECORDER: RefCell<Recorder> =
        const { RefCell::new(Recorder { spans: Vec::new(), dropped: 0, counters: [0; N_CTRS] }) };
}

/// Preallocate this thread's span buffer. Workers call this once at
/// startup so that no later `record` allocates; threads that skip it pay
/// one allocation on their first (non-hot) `record`.
pub fn thread_init() {
    RECORDER.with(|r| {
        let mut rec = r.borrow_mut();
        let len = rec.spans.len();
        rec.spans.reserve(RECORDER_CAP.saturating_sub(len));
    });
}

/// Record a span into this thread's buffer. Never called from hot regions
/// (only [`hot_count`] is); outside them the one-time buffer fault-in is
/// acceptable.
pub fn record(span: SpanRec) {
    RECORDER.with(|r| {
        let mut rec = r.borrow_mut();
        if rec.spans.capacity() == 0 {
            rec.spans.reserve(RECORDER_CAP);
        }
        if rec.spans.len() < rec.spans.capacity() {
            rec.spans.push(span);
        } else {
            rec.dropped += 1;
        }
    });
}

/// Bump a hot-loop counter: a thread-local integer add, the only obs call
/// legal *inside* `// nodal-lint: hot` regions (no allocation, no branch
/// on sampling state, no float contact).
#[inline]
pub fn hot_count(counter: usize, n: u64) {
    RECORDER.with(|r| {
        if let Some(slot) = r.borrow_mut().counters.get_mut(counter) {
            *slot += n;
        }
    });
}

/// Snapshot this thread's hot counters (monotonic; callers diff around a
/// region of interest).
pub fn counters() -> [u64; N_CTRS] {
    RECORDER.with(|r| r.borrow().counters)
}

/// Spans dropped on this thread because the recorder was full.
pub fn dropped() -> u64 {
    RECORDER.with(|r| r.borrow().dropped)
}

/// Move this thread's recorded spans into the global [`TraceStore`]
/// (keeping the preallocated buffer). Emitters publish *before* they
/// fulfill a response, so a trace is complete in the store by the time its
/// requester wakes.
pub fn publish() {
    RECORDER.with(|r| {
        let mut rec = r.borrow_mut();
        if !rec.spans.is_empty() {
            global().ingest(&rec.spans);
            rec.spans.clear();
        }
    });
}

// ---------------------------------------------------------------------------
// Global trace store

/// Traces retained in memory (oldest evicted first).
const MAX_TRACES: usize = 256;
/// Spans retained per trace (later spans dropped).
const MAX_SPANS_PER_TRACE: usize = 1024;

struct StoreInner {
    traces: BTreeMap<u64, Vec<SpanRec>>,
    order: VecDeque<u64>,
}

/// Process-wide span sink: threads [`publish`] into it, the HTTP layer and
/// the dist reply path read/stitch out of it. Bounded in both dimensions;
/// a trace's spans are kept in arrival order, which a happens-before
/// emission chain (submit → batch → worker → respond) makes deterministic.
pub struct TraceStore {
    inner: Mutex<StoreInner>,
}

/// The process-wide store.
pub fn global() -> &'static TraceStore {
    static GLOBAL: OnceLock<TraceStore> = OnceLock::new();
    GLOBAL.get_or_init(|| TraceStore {
        inner: Mutex::new(StoreInner { traces: BTreeMap::new(), order: VecDeque::new() }),
    })
}

impl TraceStore {
    /// Append spans to their traces (creating and, at capacity, evicting).
    pub fn ingest(&self, spans: &[SpanRec]) {
        let mut inner = self.inner.lock().unwrap();
        for s in spans {
            if s.trace == 0 {
                continue;
            }
            if !inner.traces.contains_key(&s.trace) {
                while inner.order.len() >= MAX_TRACES {
                    if let Some(old) = inner.order.pop_front() {
                        inner.traces.remove(&old);
                    }
                }
                inner.order.push_back(s.trace);
                inner.traces.insert(s.trace, Vec::new());
            }
            if let Some(list) = inner.traces.get_mut(&s.trace) {
                if list.len() < MAX_SPANS_PER_TRACE {
                    list.push(*s);
                }
            }
        }
    }

    /// Copy of a trace's spans, stably ordered by `start_ns` (arrival order
    /// breaks ties). Empty when unknown.
    pub fn get(&self, trace: TraceId) -> Vec<SpanRec> {
        let inner = self.inner.lock().unwrap();
        let mut spans = inner.traces.get(&trace.0).cloned().unwrap_or_default();
        drop(inner);
        spans.sort_by_key(|s| s.start_ns);
        spans
    }

    /// Remove and return a trace (same ordering as [`TraceStore::get`]).
    /// The dist shard uses this to hand a solve's spans back to the
    /// dispatcher exactly once.
    pub fn take(&self, trace: TraceId) -> Vec<SpanRec> {
        let mut inner = self.inner.lock().unwrap();
        let mut spans = inner.traces.remove(&trace.0).unwrap_or_default();
        inner.order.retain(|t| *t != trace.0);
        drop(inner);
        spans.sort_by_key(|s| s.start_ns);
        spans
    }

    /// Export one trace as deterministic JSONL: spans in [`TraceStore::get`]
    /// order, ids remapped dense (1..n) so the file does not depend on the
    /// process-global id sequence. Returns the written path
    /// (`<dir>/<hex>.jsonl`).
    pub fn flush_jsonl(&self, trace: TraceId, dir: &Path) -> std::io::Result<PathBuf> {
        let spans = remap_ids(self.get(trace));
        std::fs::create_dir_all(dir)?;
        let mut out = String::new();
        for s in &spans {
            out.push_str(&span_to_json(s).to_string());
            out.push('\n');
        }
        let path = dir.join(format!("{}.jsonl", trace.to_hex()));
        std::fs::write(&path, out)?;
        Ok(path)
    }
}

/// Remap span ids to dense 1..n in list order, rewriting parent edges
/// (parents outside the list become roots).
fn remap_ids(spans: Vec<SpanRec>) -> Vec<SpanRec> {
    let mut map: BTreeMap<u64, u64> = BTreeMap::new();
    for (i, s) in spans.iter().enumerate() {
        map.insert(s.span, (i + 1) as u64);
    }
    spans
        .into_iter()
        .map(|mut s| {
            s.parent = map.get(&s.parent).copied().unwrap_or(0);
            s.span = map.get(&s.span).copied().unwrap_or(0);
            s
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Env knobs

/// Parsed `NODAL_TRACE_*` configuration.
#[derive(Debug, Clone)]
pub struct TraceKnobs {
    /// Trace every Nth unsolicited request (0 = only header-solicited).
    pub sample_n: u64,
    /// JSONL export directory.
    pub dir: PathBuf,
}

impl Default for TraceKnobs {
    fn default() -> Self {
        TraceKnobs { sample_n: 0, dir: crate::coordinator::report::results_dir().join("trace") }
    }
}

/// Designated parse-and-clamp reader for the `NODAL_TRACE_*` knobs (the
/// only place they are read; allowlisted in nodal-lint). `sample_n` clamps
/// to `0..=10⁶`; an unset or empty `NODAL_TRACE_DIR` falls back to
/// `<results>/trace` under `NODAL_RESULTS`.
pub fn trace_env() -> TraceKnobs {
    let sample_n = match std::env::var("NODAL_TRACE_SAMPLE_N")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
    {
        Some(n) => n.clamp(0, 1_000_000),
        None => 0,
    };
    let dir = match std::env::var("NODAL_TRACE_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => crate::coordinator::report::results_dir().join("trace"),
    };
    TraceKnobs { sample_n, dir }
}

// ---------------------------------------------------------------------------
// JSON codecs (integers and hex strings only — no float fields, so these
// are safe for dist frames under the wire-determinism rule)

/// One span as a JSON object (trace as hex string; ids/times as exact
/// integers — span ids and process-relative nanos stay far below 2⁵³).
pub fn span_to_json(s: &SpanRec) -> Json {
    let mut attrs: Vec<(&str, Json)> = Vec::new();
    for (k, v) in s.attrs.iter() {
        if !k.is_empty() {
            attrs.push((*k, (*v as usize).into()));
        }
    }
    let mut pairs: Vec<(&str, Json)> = vec![
        ("trace", TraceId(s.trace).to_hex().into()),
        ("span", (s.span as usize).into()),
        ("parent", (s.parent as usize).into()),
        ("name", s.name.into()),
        ("start_ns", (s.start_ns as usize).into()),
        ("end_ns", (s.end_ns as usize).into()),
        ("attrs", obj(attrs)),
    ];
    if s.shard >= 0 {
        pairs.push(("shard", (s.shard as usize).into()));
    }
    obj(pairs)
}

/// Decode one span; `name` and attr keys are interned against the closed
/// taxonomy (unknown names decode as `"unknown"`, never as new strings).
pub fn span_from_json(v: &Json) -> anyhow::Result<SpanRec> {
    let trace = TraceId::parse_hex(v.get("trace")?.as_str()?)
        .ok_or_else(|| anyhow::anyhow!("bad trace id"))?;
    let mut attrs = [("", 0u64); MAX_ATTRS];
    if let Some(Json::Obj(m)) = v.opt("attrs") {
        for (slot, (k, val)) in attrs.iter_mut().zip(m.iter()) {
            *slot = (intern(&ATTR_KEYS, k), val.as_usize()? as u64);
        }
    }
    Ok(SpanRec {
        trace: trace.0,
        span: v.get("span")?.as_usize()? as u64,
        parent: v.get("parent")?.as_usize()? as u64,
        name: intern(&SPAN_NAMES, v.get("name")?.as_str()?),
        start_ns: v.get("start_ns")?.as_usize()? as u64,
        end_ns: v.get("end_ns")?.as_usize()? as u64,
        shard: match v.opt("shard") {
            Some(s) => s.as_usize()? as i64,
            None => -1,
        },
        attrs,
    })
}

/// A span list as a JSON array (piggybacked on dist `resp` frames).
pub fn spans_to_json(spans: &[SpanRec]) -> Json {
    Json::Arr(spans.iter().map(span_to_json).collect())
}

/// Decode a span list (tolerates an absent/non-array value as empty).
pub fn spans_from_json(v: &Json) -> Vec<SpanRec> {
    match v {
        Json::Arr(items) => items.iter().filter_map(|s| span_from_json(s).ok()).collect(),
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ns(n: u64) -> Duration {
        Duration::from_nanos(n)
    }

    #[test]
    fn trace_id_hex_round_trips_and_rejects_garbage() {
        let id = TraceId(0x0123_4567_89ab_cdef);
        assert_eq!(id.to_hex(), "0123456789abcdef");
        assert_eq!(TraceId::parse_hex(&id.to_hex()), Some(id));
        assert_eq!(TraceId::parse_hex("0123456789abcde"), None, "15 chars");
        assert_eq!(TraceId::parse_hex("0123456789abcdeg"), None, "non-hex");
        assert_eq!(TraceId::parse_hex("0000000000000000"), None, "reserved zero");
        assert_eq!(TraceId::parse_hex(""), None);
    }

    #[test]
    fn minting_is_deterministic_in_sequence_and_nonzero() {
        let a = mint(ns(5));
        let b = mint(ns(5));
        assert_ne!(a.0, 0);
        assert_ne!(a, b, "sequence makes same-instant mints distinct");
    }

    #[test]
    fn record_publish_take_round_trip() {
        let trace = mint(ns(1));
        let ctx = TraceCtx::root(trace);
        let root = SpanRec::new(ctx, SOLVE, ns(10), ns(50)).attr("batch_size", 3);
        record(root);
        record(SpanRec::new(root.ctx(), FORWARD, ns(10), ns(30)).attr("nfe", 120));
        publish();
        let spans = global().get(trace);
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].name, SOLVE);
        assert_eq!(spans[1].parent, spans[0].span, "child parents to the solve span");
        assert_eq!(spans[1].get_attr("nfe"), Some(120));
        let taken = global().take(trace);
        assert_eq!(taken, spans);
        assert!(global().get(trace).is_empty(), "take removes the trace");
    }

    #[test]
    fn recorder_drops_past_capacity_without_reallocating() {
        thread_init();
        let trace = mint(ns(2));
        let ctx = TraceCtx::root(trace);
        for _ in 0..600 {
            record(SpanRec::event(ctx, STEAL, ns(1)));
        }
        assert!(dropped() > 0, "over-capacity spans are counted, not grown into");
        publish();
        let spans = global().take(trace);
        assert!(spans.len() <= 256, "recorder capacity bounds one thread's burst");
    }

    #[test]
    fn span_json_round_trips_with_interned_names() {
        let trace = mint(ns(3));
        let mut ctx = TraceCtx::root(trace);
        ctx.shard = 1;
        let s = SpanRec::new(ctx, REPLAY, ns(7), ns(9)).attr("nfe", 40).attr("bytes", 1024);
        let j = Json::parse(&span_to_json(&s).to_string()).unwrap();
        let back = span_from_json(&j).unwrap();
        // Attrs travel as a key-sorted object, so compare semantically.
        assert_eq!(
            (back.trace, back.span, back.parent, back.name),
            (s.trace, s.span, s.parent, s.name)
        );
        assert_eq!((back.start_ns, back.end_ns, back.shard), (s.start_ns, s.end_ns, s.shard));
        assert_eq!(back.get_attr("nfe"), Some(40));
        assert_eq!(back.get_attr("bytes"), Some(1024));
        assert!(std::ptr::eq(back.name, REPLAY), "decoded name is the interned static");

        // Unknown names/keys intern to "unknown", never allocate new strings.
        let mut m = match span_to_json(&s) {
            Json::Obj(m) => m,
            _ => unreachable!(),
        };
        m.insert("name".into(), "mystery".into());
        let back = span_from_json(&Json::Obj(m)).unwrap();
        assert_eq!(back.name, "unknown");
    }

    #[test]
    fn flush_jsonl_remaps_ids_densely() {
        let trace = mint(ns(4));
        let ctx = TraceCtx::root(trace);
        let root = SpanRec::new(ctx, HTTP_REQUEST, ns(0), ns(100));
        let child = SpanRec::new(root.ctx(), SOLVE, ns(10), ns(90));
        global().ingest(&[root, child]);
        let dir = std::env::temp_dir()
            .join(format!("nodal-obs-test-{}-{}", std::process::id(), trace.to_hex()));
        let path = global().flush_jsonl(trace, &dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let first = span_from_json(&Json::parse(lines[0]).unwrap()).unwrap();
        let second = span_from_json(&Json::parse(lines[1]).unwrap()).unwrap();
        assert_eq!((first.span, first.parent), (1, 0), "dense ids from 1");
        assert_eq!((second.span, second.parent), (2, 1), "parent edge preserved");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn store_caps_spans_per_trace() {
        let trace = mint(ns(6));
        let ctx = TraceCtx::root(trace);
        let burst: Vec<SpanRec> =
            (0..1500).map(|_| SpanRec::event(ctx, FAILOVER, ns(1))).collect();
        global().ingest(&burst);
        assert_eq!(global().take(trace).len(), 1024, "per-trace span cap");
    }

    /// All `NODAL_TRACE_*` cases in ONE test: the process environment is
    /// shared across parallel test threads.
    #[test]
    fn trace_env_parse_and_clamp() {
        std::env::set_var("NODAL_TRACE_SAMPLE_N", "999999999");
        std::env::set_var("NODAL_TRACE_DIR", "/tmp/custom-trace");
        let k = trace_env();
        assert_eq!(k.sample_n, 1_000_000, "sample stride clamps");
        assert_eq!(k.dir, PathBuf::from("/tmp/custom-trace"));

        std::env::set_var("NODAL_TRACE_SAMPLE_N", "not-a-number");
        std::env::set_var("NODAL_TRACE_DIR", "");
        let k = trace_env();
        assert_eq!(k.sample_n, 0, "unparseable falls back to off");
        assert!(k.dir.ends_with("trace"), "empty dir falls back to <results>/trace");

        for v in ["NODAL_TRACE_SAMPLE_N", "NODAL_TRACE_DIR"] {
            std::env::remove_var(v);
        }
        let k = trace_env();
        assert_eq!(k.sample_n, 0);
        assert!(k.dir.ends_with("trace"));
    }

    #[test]
    fn hot_counters_accumulate_per_thread() {
        let before = counters();
        hot_count(CTR_FWD_ROUNDS, 3);
        hot_count(CTR_FWD_SWEEPS, 12);
        hot_count(99, 7); // out-of-range is ignored, never panics
        let after = counters();
        assert_eq!(after[CTR_FWD_ROUNDS] - before[CTR_FWD_ROUNDS], 3);
        assert_eq!(after[CTR_FWD_SWEEPS] - before[CTR_FWD_SWEEPS], 12);
    }
}
