//! High-level model API: a [`NodeSystem`] bundles an AOT-compiled NODE with
//! a solver, tolerances, and a gradient method — the object the examples
//! and the experiment coordinator train and evaluate.

use anyhow::Result;

use crate::grad::{self, CostMeter, Method};
use crate::ode::{integrate, IntegrateOpts, OdeFunc, Tableau, Trajectory};
use crate::runtime::hlo_model::{HloModel, Target};

/// A Neural-ODE "system": model + solver + gradient method.
pub struct NodeSystem {
    pub model: HloModel,
    pub tab: &'static Tableau,
    pub opts: IntegrateOpts,
    pub method: Method,
    /// Integration span of the ODE block (paper: [0, 1]).
    pub t1: f64,
}

impl NodeSystem {
    pub fn new(model: HloModel, tab: &'static Tableau, method: Method) -> Self {
        let opts = IntegrateOpts {
            rtol: 1e-2,
            atol: 1e-2,
            record_trials: method == Method::Naive,
            ..Default::default()
        };
        NodeSystem { model, tab, opts, method, t1: 1.0 }
    }

    /// Forward pass: encode + solve. Returns the trajectory (z0 implied by
    /// `traj.z(0)`).
    pub fn forward(&self, x: &[f32]) -> Result<Trajectory> {
        let z0 = self.model.encode(x)?;
        integrate(&self.model, 0.0, self.t1, &z0, self.tab, &self.opts)
    }

    /// Full training step gradient: returns (loss, dθ, cost meter).
    pub fn loss_grad(&self, x: &[f32], y: &Target) -> Result<(f64, Vec<f32>, CostMeter)> {
        let traj = self.forward(x)?;
        let mut dtheta = vec![0.0f32; self.model.n_params()];
        let (lam, loss) =
            self.model.decode_loss_vjp(traj.last().expect("non-empty trajectory"), y, &mut dtheta)?;
        let g = grad::backward(&self.model, self.tab, &traj, &lam, self.method, &self.opts)?;
        for (d, s) in dtheta.iter_mut().zip(&g.dl_dtheta) {
            *d += s;
        }
        self.model.encode_vjp_accum(x, &g.dl_dz0, &mut dtheta)?;
        let mut meter = g.meter;
        meter.nfe_forward = traj.nfe;
        Ok((loss, dtheta, meter))
    }

    /// Inference: predictions for a batch.
    pub fn predict(&self, x: &[f32], y: &Target) -> Result<(f64, Vec<f32>)> {
        let traj = self.forward(x)?;
        self.model.decode_loss(traj.last().expect("non-empty trajectory"), y)
    }
}
