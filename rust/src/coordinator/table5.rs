//! Table 5 + Fig 8 — the three-body problem: predict `[0, 2]` years of
//! trajectory from training data on `[0, 1]` year, with increasing physical
//! knowledge: LSTM (none) → LSTM-aug (pairwise geometry) → NODE over Aug
//! features (structure) → ODE with unknown masses (full law), the latter two
//! trained with adjoint / naive / ACA.

use anyhow::Result;

use super::report::{save_series, Table};
use crate::config::Config;
use crate::data::ThreeBodyDataset;
use crate::grad::{self, Method};
use crate::ode::analytic::ThreeBody;
use crate::ode::{integrate, tableau, IntegrateOpts, OdeFunc, Trajectory};
use crate::runtime::hlo_model::Target;
use crate::runtime::{Engine, HloModel, RecurrentBaseline};
use crate::train::segmented::{segmented_eval, segmented_loss_grad};
use crate::train::{Adam, Optimizer};

const N_PER_YEAR: usize = 100; // dt = 0.01 yr; LSTM rollout (200) covers 2 yr
const CHUNKS: usize = 4; // tb_node artifact batch
const TOL: f64 = 1e-5; // paper: rtol = atol = 1e-5

// ---------------------------------------------------------------------------
// LSTM baselines
// ---------------------------------------------------------------------------

fn train_lstm(
    cfg: &Config,
    name: &str,
    ds: &ThreeBodyDataset,
    seed: i32,
) -> Result<RecurrentBaseline> {
    let mut engine = Engine::cpu()?;
    let dir = crate::runtime::artifact_root().join(name);
    let mut m = RecurrentBaseline::load(&mut engine, &dir)?;
    m.init_params(seed)?;
    std::mem::forget(engine);
    let man = m.manifest.clone();
    let (xs, ys) = ds.lstm_windows(man.seq_len, 10);
    anyhow::ensure!(xs.len() >= man.batch, "not enough LSTM windows");
    let epochs = cfg.get_usize("lstm_epochs", 300);
    let mut opt = Adam::new(cfg.get_f64("lstm_lr", 0.01));
    for e in 0..epochs {
        // exponential decay (paper Eq. 83)
        opt.set_lr(cfg.get_f64("lstm_lr", 0.01) * 0.999f64.powi(e as i32));
        for chunk in xs.chunks(man.batch).zip(ys.chunks(man.batch)) {
            let (cx, cy) = chunk;
            if cx.len() < man.batch {
                continue;
            }
            let x: Vec<f32> = cx.concat();
            let y: Vec<f32> = cy.concat();
            let (_, grad) = m.loss_grad(&x, &y)?;
            opt.step(&mut m.params, &grad);
        }
    }
    Ok(m)
}

fn lstm_mse(m: &RecurrentBaseline, ds: &ThreeBodyDataset) -> Result<f64> {
    // Autoregressive rollout from the initial positions; compare the full
    // [0, 2] yr range (paper measures mean trajectory MSE over 2 years).
    let man = &m.manifest;
    let mut x0 = Vec::with_capacity(man.batch * 9);
    for _ in 0..man.batch {
        x0.extend_from_slice(ds.positions(0));
    }
    let traj = m.rollout(&x0)?;
    // row 0 of the batch
    let steps = man.rollout_steps;
    let preds: Vec<Vec<f32>> =
        (0..steps).map(|k| traj[k * 9..(k + 1) * 9].to_vec()).collect();
    Ok(ds.position_mse(&preds, 1))
}

// ---------------------------------------------------------------------------
// NODE over Aug features (tb_node artifacts, batch = CHUNKS)
// ---------------------------------------------------------------------------

fn train_node(
    cfg: &Config,
    ds: &ThreeBodyDataset,
    method: Method,
    seed: i32,
) -> Result<HloModel> {
    let mut engine = Engine::cpu()?;
    let dir = crate::runtime::artifact_root().join("tb_node");
    let mut model = HloModel::load(&mut engine, &dir)?;
    model.init_params(seed)?;
    std::mem::forget(engine);

    let tab = tableau::dopri5();
    let opts = IntegrateOpts {
        record_trials: method == Method::Naive,
        ..IntegrateOpts::with_tol(TOL, TOL)
    };
    // Split the training year into CHUNKS contiguous chunks sharing one
    // relative time grid; batch them.
    let steps_per_chunk = N_PER_YEAR / CHUNKS; // 25
    let dt = ds.t_train / N_PER_YEAR as f64;
    let times: Vec<f64> = (0..=steps_per_chunk).map(|k| k as f64 * dt).collect();
    let mut z0 = Vec::with_capacity(CHUNKS * 18);
    for c in 0..CHUNKS {
        z0.extend_from_slice(&ds.states[c * steps_per_chunk]);
    }
    let targets: Vec<Target> = (1..=steps_per_chunk)
        .map(|k| {
            let mut t = Vec::with_capacity(CHUNKS * 9);
            for c in 0..CHUNKS {
                t.extend_from_slice(ds.positions(c * steps_per_chunk + k));
            }
            Target::Values(t)
        })
        .collect();

    let epochs = cfg.get_usize("node_epochs", 60);
    let mut opt = Adam::new(cfg.get_f64("node_lr", 0.02));
    for e in 0..epochs {
        opt.set_lr(cfg.get_f64("node_lr", 0.02) * 0.99f64.powi(e as i32));
        let sg = segmented_loss_grad(&model, tab, &opts, method, &z0, &times, &targets)?;
        let mut dtheta = sg.dtheta.clone();
        crate::train::clip_grad_norm(&mut dtheta, 5.0);
        let mut params = OdeFunc::params(&model).to_vec();
        opt.step(&mut params, &dtheta);
        model.set_params(&params);
        if !sg.loss.is_finite() {
            anyhow::bail!("NODE-{} diverged at epoch {e}", method.name());
        }
    }
    Ok(model)
}

fn node_mse(model: &HloModel, ds: &ThreeBodyDataset) -> Result<(f64, Vec<Vec<f32>>)> {
    // Predict the whole [0, 2] yr from the true initial state (batch rows all
    // start identically; row 0 is read out).
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(TOL, TOL);
    let mut z0 = Vec::with_capacity(CHUNKS * 18);
    for _ in 0..CHUNKS {
        z0.extend_from_slice(&ds.states[0]);
    }
    let n = ds.times.len() - 1; // 200 segments
    let targets: Vec<Target> = (1..=n)
        .map(|k| {
            let mut t = Vec::with_capacity(CHUNKS * 9);
            for _ in 0..CHUNKS {
                t.extend_from_slice(ds.positions(k));
            }
            Target::Values(t)
        })
        .collect();
    let (_, preds_b) = segmented_eval(model, tab, &opts, &z0, &ds.times, &targets)?;
    let preds: Vec<Vec<f32>> = preds_b.iter().map(|p| p[..9].to_vec()).collect();
    Ok((ds.position_mse(&preds, 1), preds))
}

// ---------------------------------------------------------------------------
// ODE with unknown masses (analytic dynamics, Rust)
// ---------------------------------------------------------------------------

/// Segmented loss+grad for the analytic three-body ODE: loss = mean position
/// MSE at each training sample.
fn phys_loss_grad(
    f: &ThreeBody,
    ds: &ThreeBodyDataset,
    method: Method,
    opts: &IntegrateOpts,
) -> Result<(f64, Vec<f32>)> {
    let tab = tableau::dopri5();
    let end = ds.train_end();
    let mut z = ds.states[0].clone();
    let mut segs: Vec<Trajectory> = Vec::with_capacity(end);
    let mut jumps: Vec<Vec<f32>> = Vec::with_capacity(end);
    let mut loss = 0.0f64;
    for k in 1..=end {
        let traj = integrate(f, ds.times[k - 1], ds.times[k], &z, tab, opts)?;
        z = traj.last().expect("non-empty trajectory").to_vec();
        // L_k = mean_j (pos_j − target_j)²  over 9 position dims.
        let target = ds.positions(k);
        let mut lam = vec![0.0f32; 18];
        for j in 0..9 {
            let d = z[j] - target[j];
            loss += (d as f64).powi(2) / 9.0;
            lam[j] = 2.0 * d / 9.0;
        }
        segs.push(traj);
        jumps.push(lam);
    }
    let n_obs = end as f32;
    let mut lam = vec![0.0f32; 18];
    let mut dtheta = vec![0.0f32; 3];
    for k in (0..end).rev() {
        for (l, j) in lam.iter_mut().zip(&jumps[k]) {
            *l += j / n_obs;
        }
        let g = grad::backward(f, tab, &segs[k], &lam, method, opts)?;
        lam = g.dl_dz0;
        for (d, s) in dtheta.iter_mut().zip(&g.dl_dtheta) {
            *d += s;
        }
    }
    Ok((loss / end as f64, dtheta))
}

fn train_phys(cfg: &Config, ds: &ThreeBodyDataset, method: Method) -> Result<ThreeBody> {
    let mut f = ThreeBody::new([0.6, 0.6, 0.6]); // unknown masses, neutral init
    let opts = IntegrateOpts {
        record_trials: method == Method::Naive,
        ..IntegrateOpts::with_tol(TOL, TOL)
    };
    let epochs = cfg.get_usize("phys_epochs", 100);
    let mut opt = Adam::new(cfg.get_f64("phys_lr", 0.05));
    for e in 0..epochs {
        opt.set_lr(cfg.get_f64("phys_lr", 0.05) * 0.99f64.powi(e as i32));
        let (loss, mut grad) = phys_loss_grad(&f, ds, method, &opts)?;
        if !loss.is_finite() {
            anyhow::bail!("ODE-{} diverged at epoch {e}", method.name());
        }
        crate::train::clip_grad_norm(&mut grad, 10.0);
        let mut m = f.params().to_vec();
        opt.step(&mut m, &grad);
        for v in m.iter_mut() {
            *v = v.max(1e-3); // masses stay positive
        }
        f.set_params(&m);
    }
    Ok(f)
}

fn phys_mse(f: &ThreeBody, ds: &ThreeBodyDataset) -> Result<f64> {
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(TOL, TOL);
    let mut z = ds.states[0].clone();
    let mut preds = Vec::new();
    for k in 1..ds.times.len() {
        let traj = integrate(f, ds.times[k - 1], ds.times[k], &z, tab, &opts)?;
        z = traj.last().expect("non-empty trajectory").to_vec();
        preds.push(z[..9].to_vec());
    }
    Ok(ds.position_mse(&preds, 1))
}

// ---------------------------------------------------------------------------

pub fn run(cfg: &Config) -> Result<()> {
    let n_runs = cfg.get_usize("runs", 3);
    let mut table = Table::new(
        "table5",
        &format!("three-body [0,2]yr trajectory MSE over {n_runs} systems (mean ± std)"),
        &["model", "mean MSE", "std"],
    );

    let mut rows: Vec<(String, Vec<f64>)> = vec![
        ("LSTM".into(), vec![]),
        ("LSTM-aug-input".into(), vec![]),
        ("NODE-adjoint".into(), vec![]),
        ("NODE-naive".into(), vec![]),
        ("NODE-ACA".into(), vec![]),
        ("ODE-adjoint".into(), vec![]),
        ("ODE-naive".into(), vec![]),
        ("ODE-ACA".into(), vec![]),
    ];

    for run in 0..n_runs {
        let seed = 1 + run as u64;
        println!("== system {seed} ==");
        let ds = ThreeBodyDataset::generate(seed, N_PER_YEAR);
        println!("  true masses: {:?}", ds.masses);

        println!("  LSTM…");
        let m = train_lstm(cfg, "tb_lstm", &ds, seed as i32)?;
        rows[0].1.push(lstm_mse(&m, &ds)?);
        println!("  LSTM-aug…");
        let m = train_lstm(cfg, "tb_lstm_aug", &ds, seed as i32)?;
        rows[1].1.push(lstm_mse(&m, &ds)?);

        for (i, method) in [Method::Adjoint, Method::Naive, Method::Aca].iter().enumerate() {
            println!("  NODE-{}…", method.name());
            match train_node(cfg, &ds, *method, seed as i32) {
                Ok(m) => {
                    let (mse, preds) = node_mse(&m, &ds)?;
                    rows[2 + i].1.push(mse);
                    if *method == Method::Aca && run == 0 {
                        // Fig 8 data: predicted vs true trajectory of planet 1.
                        let cols = vec![
                            ds.times[1..].to_vec(),
                            preds.iter().map(|p| p[0] as f64).collect(),
                            preds.iter().map(|p| p[1] as f64).collect(),
                            preds.iter().map(|p| p[2] as f64).collect(),
                            (1..ds.times.len()).map(|k| ds.positions(k)[0] as f64).collect(),
                            (1..ds.times.len()).map(|k| ds.positions(k)[1] as f64).collect(),
                            (1..ds.times.len()).map(|k| ds.positions(k)[2] as f64).collect(),
                        ];
                        save_series(
                            "fig8_node_aca",
                            &["t", "px", "py", "pz", "tx", "ty", "tz"],
                            &cols,
                        )?;
                    }
                }
                Err(e) => println!("    diverged: {e}"),
            }
        }
        for (i, method) in [Method::Adjoint, Method::Naive, Method::Aca].iter().enumerate() {
            println!("  ODE-{} (3 masses)…", method.name());
            match train_phys(cfg, &ds, *method) {
                Ok(f) => {
                    println!("    learned masses: {:?}", f.masses());
                    rows[5 + i].1.push(phys_mse(&f, &ds)?);
                }
                Err(e) => println!("    diverged: {e}"),
            }
        }
    }

    for (name, vals) in rows {
        if vals.is_empty() {
            table.row(vec![name, "-".into(), "-".into()]);
            continue;
        }
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        let var = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / vals.len() as f64;
        table.row(vec![name, Table::fmt(mean), Table::fmt(var.sqrt())]);
    }
    table.emit()
}
