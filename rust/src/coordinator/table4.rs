//! Table 4 — irregularly-sampled time series: interpolation MSE vs the
//! fraction of training data, for RNN / GRU baselines and the latent NODE
//! trained with adjoint / naive / ACA.

use anyhow::Result;

use super::report::Table;
use crate::config::Config;
use crate::data::timeseries::{Group, TimeSeriesDataset};
use crate::grad::Method;
use crate::ode::{tableau, IntegrateOpts, OdeFunc};
use crate::runtime::hlo_model::Target;
use crate::runtime::{Engine, HloModel, RecurrentBaseline};
use crate::train::segmented::{segmented_eval, segmented_loss_grad};
use crate::train::{Adam, Optimizer};

fn node_mse(model: &HloModel, groups: &[&Group]) -> Result<f64> {
    let tab = tableau::dopri5();
    let opts = IntegrateOpts::with_tol(1e-3, 1e-4);
    let mut acc = 0.0;
    for g in groups {
        let z0 = model.encode(&g.encoder_input())?;
        let targets: Vec<Target> =
            (0..g.n_targets()).map(|k| Target::Values(g.target_at(k))).collect();
        let (mse, _) = segmented_eval(model, tab, &opts, &z0, g.target_times(), &targets)?;
        acc += mse;
    }
    Ok(acc / groups.len().max(1) as f64)
}

fn train_node(
    cfg: &Config,
    groups: &[&Group],
    method: Method,
    seed: i32,
) -> Result<HloModel> {
    let mut engine = Engine::cpu()?;
    let dir = crate::runtime::artifact_root().join("ts");
    let mut model = HloModel::load(&mut engine, &dir)?;
    model.init_params(seed)?;
    std::mem::forget(engine);

    let tab = tableau::dopri5();
    let opts = IntegrateOpts {
        record_trials: method == Method::Naive,
        ..IntegrateOpts::with_tol(1e-3, 1e-4)
    };
    let epochs = cfg.get_usize("epochs", 25);
    let mut opt = Adam::new(cfg.get_f64("lr", 0.01));
    for _epoch in 0..epochs {
        for g in groups {
            let z0 = model.encode(&g.encoder_input())?;
            let targets: Vec<Target> =
                (0..g.n_targets()).map(|k| Target::Values(g.target_at(k))).collect();
            let sg = segmented_loss_grad(
                &model,
                tab,
                &opts,
                method,
                &z0,
                g.target_times(),
                &targets,
            )?;
            let mut dtheta = sg.dtheta;
            model.encode_vjp_accum(&g.encoder_input(), &sg.dl_dz0, &mut dtheta)?;
            crate::train::clip_grad_norm(&mut dtheta, 5.0);
            let mut params = crate::ode::OdeFunc::params(&model).to_vec();
            opt.step(&mut params, &dtheta);
            model.set_params(&params);
        }
    }
    Ok(model)
}

fn train_rnn(cfg: &Config, name: &str, groups: &[&Group], seed: i32) -> Result<RecurrentBaseline> {
    let mut engine = Engine::cpu()?;
    let dir = crate::runtime::artifact_root().join(name);
    let mut m = RecurrentBaseline::load(&mut engine, &dir)?;
    m.init_params(seed)?;
    std::mem::forget(engine);
    let epochs = cfg.get_usize("rnn_epochs", 60);
    let mut opt = Adam::new(cfg.get_f64("rnn_lr", 0.01));
    for _ in 0..epochs {
        for g in groups {
            let (loss, grad) = m.loss_grad(&g.rnn_inputs(), &g.rnn_targets())?;
            debug_assert!(loss.is_finite());
            opt.step(&mut m.params, &grad);
        }
    }
    Ok(m)
}

fn rnn_mse(m: &RecurrentBaseline, groups: &[&Group]) -> Result<f64> {
    let mut acc = 0.0;
    for g in groups {
        let pred = m.predict(&g.rnn_inputs())?;
        acc += g.rnn_interp_mse(&pred);
    }
    Ok(acc / groups.len().max(1) as f64)
}

pub fn run(cfg: &Config) -> Result<()> {
    let group_size = 32; // must match the ts artifacts' batch
    let n_groups = cfg.get_usize("n_groups", 10);
    let n_test = cfg.get_usize("n_test_groups", 4);
    let data = TimeSeriesDataset::generate(n_groups, n_test, group_size, 5.0, 11);
    let test_groups: Vec<&Group> = data.test.iter().collect();

    let mut table = Table::new(
        "table4",
        "irregular time-series interpolation MSE (x 1e-2 to match paper units)",
        &["% train data", "RNN", "RNN-GRU", "NODE-adjoint", "NODE-naive", "NODE-ACA"],
    );

    for pct in [10usize, 20, 50] {
        let groups = data.subset(pct);
        println!("-- {pct}% of training data ({} groups) --", groups.len());
        let mut row = vec![format!("{pct}%")];

        for name in ["ts_rnn", "ts_gru"] {
            println!("  training {name}…");
            let m = train_rnn(cfg, name, &groups, 1)?;
            row.push(format!("{:.3}", 100.0 * rnn_mse(&m, &test_groups)?));
        }
        for method in [Method::Adjoint, Method::Naive, Method::Aca] {
            println!("  training NODE-{}…", method.name());
            let m = train_node(cfg, &groups, method, 1)?;
            row.push(format!("{:.3}", 100.0 * node_mse(&m, &test_groups)?));
        }
        table.row(row);
    }
    table.emit()
}
