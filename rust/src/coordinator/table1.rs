//! Table 1 — measured computation / memory / graph-depth costs of the three
//! gradient methods on one forward+backward pass of the image NODE.
//!
//! The paper states asymptotics; we report the instrumented counters from
//! [`crate::grad::CostMeter`] on identical workloads so the *ordering and
//! ratios* can be checked: ACA cheapest compute, adjoint smallest memory,
//! naive deepest graph.

use anyhow::Result;

use super::report::Table;
use crate::config::Config;
use crate::grad::{self, Method};
use crate::ode::{integrate, tableau, IntegrateOpts, OdeFunc};
use crate::runtime::{Engine, HloModel};
use crate::util::Timer;

pub fn run(cfg: &Config) -> Result<()> {
    let mut engine = Engine::cpu()?;
    let dir = crate::runtime::artifact_root().join(cfg.get_str("model", "img"));
    let mut model = HloModel::load(&mut engine, &dir)?;
    model.init_params(cfg.get_usize("seed", 0) as i32)?;
    // Freshly-initialized dynamics are nearly linear and trivially solvable;
    // scale the weights up to the magnitude of a *trained* NODE so the solver
    // works at a realistic N_t and step-size search depth m.
    {
        let boosted: Vec<f32> = OdeFunc::params(&model)
            .iter()
            .map(|p| p * cfg.get_f64("boost", 6.0) as f32)
            .collect();
        model.set_params(&boosted);
    }
    let tab = tableau::by_name(&cfg.get_str("solver", "dopri5")).unwrap();
    let rtol = cfg.get_f64("rtol", 1e-3);

    // One representative batch.
    let data = crate::data::ImageDataset::generate(model.manifest.batch, 0, 0.05, 3);
    let ids: Vec<usize> = (0..model.manifest.batch).collect();
    let (x, y) = data.gather(&ids);

    let mut table = Table::new(
        "table1",
        "measured cost per fwd+bwd pass (img NODE, Dopri5)",
        &[
            "method",
            "NFE fwd",
            "NFE bwd",
            "VJP calls",
            "graph depth",
            "memory (KiB)",
            "N_t",
            "rejected",
            "N_r",
            "wall (ms)",
        ],
    );

    for method in [Method::Naive, Method::Adjoint, Method::Aca] {
        let opts = IntegrateOpts {
            record_trials: method == Method::Naive,
            // Force a nontrivial step-size search.
            h0: Some(4.0),
            ..IntegrateOpts::with_tol(rtol, rtol * 1e-2)
        };
        let timer = Timer::new();
        let z0 = model.encode(&x)?;
        let traj = integrate(&model, 0.0, 1.0, &z0, tab, &opts)?;
        let mut dtheta = vec![0.0f32; crate::ode::OdeFunc::n_params(&model)];
        let (lam, _loss) =
            model.decode_loss_vjp(traj.last().expect("non-empty trajectory"), &y, &mut dtheta)?;
        let g = grad::backward(&model, tab, &traj, &lam, method, &opts)?;
        let wall = timer.elapsed_ms();
        let m = &g.meter;
        table.row(vec![
            method.name().to_string(),
            m.nfe_forward.to_string(),
            m.nfe_backward.to_string(),
            m.vjp_calls.to_string(),
            m.graph_depth.to_string(),
            format!("{}", m.checkpoint_bytes / 1024),
            m.n_steps.to_string(),
            m.n_rejected.to_string(),
            m.n_reverse_steps.to_string(),
            format!("{wall:.1}"),
        ]);
    }
    table.emit()?;
    println!(
        "paper Table 1 asymptotics — compute: naive O(Nf·Nt·m·2), adjoint O(Nf·(Nt+Nr)·m), \
         ACA O(Nf·Nt·(m+1)); memory: naive O(Nf·Nt·m), adjoint O(Nf), ACA O(Nf+Nt); \
         depth: naive O(Nf·Nt·m), adjoint O(Nf·Nr), ACA O(Nf·Nt)."
    );
    Ok(())
}
