//! Table/figure rendering: ASCII to stdout, CSV + JSON into `results/`.
//!
//! Every `nodal repro <id>` command emits its paper table/figure through
//! this module so EXPERIMENTS.md can reference stable file names.

use std::fmt::Write as _;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::json::{obj, Json};

/// Output directory for experiment results (override: `NODAL_RESULTS`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("NODAL_RESULTS")
        .map(Into::into)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// A rendered result table.
#[derive(Debug, Clone)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Table {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Format a float with sensible precision for result tables.
    pub fn fmt(v: f64) -> String {
        if v.is_nan() {
            "-".to_string()
        } else if v == 0.0 {
            "0".to_string()
        } else if v.abs() >= 1000.0 || v.abs() < 1e-3 {
            format!("{v:.3e}")
        } else {
            format!("{v:.4}")
        }
    }

    /// ASCII rendering.
    pub fn ascii(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} — {} ==", self.id, self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<w$} |", c, w = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// Print to stdout and persist CSV + JSON under `results/`.
    pub fn emit(&self) -> Result<()> {
        println!("{}", self.ascii());
        let dir = results_dir();
        std::fs::create_dir_all(&dir).context("creating results dir")?;
        // CSV
        let mut csv = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ =
            writeln!(csv, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(csv, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        std::fs::write(dir.join(format!("{}.csv", self.id)), csv)?;
        // JSON
        let j = obj(vec![
            ("id", self.id.as_str().into()),
            ("title", self.title.as_str().into()),
            (
                "headers",
                self.headers.iter().map(|h| Json::from(h.as_str())).collect::<Vec<_>>().into(),
            ),
            (
                "rows",
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::from(c.as_str())).collect()))
                    .collect::<Vec<Json>>()
                    .into(),
            ),
        ]);
        std::fs::write(dir.join(format!("{}.json", self.id)), j.to_string())?;
        Ok(())
    }
}

/// Persist an x/y-series CSV (figure data).
pub fn save_series(id: &str, headers: &[&str], cols: &[Vec<f64>]) -> Result<PathBuf> {
    assert_eq!(headers.len(), cols.len());
    let n = cols.first().map(|c| c.len()).unwrap_or(0);
    assert!(cols.iter().all(|c| c.len() == n), "ragged series");
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let mut csv = String::new();
    let _ = writeln!(csv, "{}", headers.join(","));
    for i in 0..n {
        let row: Vec<String> = cols.iter().map(|c| format!("{}", c[i])).collect();
        let _ = writeln!(csv, "{}", row.join(","));
    }
    let path = dir.join(format!("{id}.csv"));
    std::fs::write(&path, csv)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// `NODAL_RESULTS` is process-global and the test harness runs tests on
    /// parallel threads — every test that touches it must hold this lock or
    /// the tests race each other's set/remove.
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn ascii_rendering_aligned() {
        let mut t = Table::new("t", "demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["longer".into(), "2.5".into()]);
        let s = t.ascii();
        assert!(s.contains("| name   | value |"), "{s}");
        assert!(s.contains("| longer | 2.5   |"), "{s}");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("t", "demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(Table::fmt(f64::NAN), "-");
        assert_eq!(Table::fmt(0.5), "0.5000");
        assert_eq!(Table::fmt(1234.5), "1.234e3");
        assert_eq!(Table::fmt(1e-5), "1.000e-5");
    }

    #[test]
    fn emit_writes_files() {
        let _guard = ENV_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join(format!("nodal_res_{}", std::process::id()));
        std::env::set_var("NODAL_RESULTS", &dir);
        let mut t = Table::new("unit_test_table", "x", &["a,b", "c"]);
        t.row(vec!["v,1".into(), "2".into()]);
        t.emit().unwrap();
        let csv = std::fs::read_to_string(dir.join("unit_test_table.csv")).unwrap();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"v,1\",2"));
        let j = std::fs::read_to_string(dir.join("unit_test_table.json")).unwrap();
        assert!(j.contains("unit_test_table"));
        std::env::remove_var("NODAL_RESULTS");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn series_csv() {
        let _guard = ENV_LOCK.lock().unwrap();
        let dir = std::env::temp_dir().join(format!("nodal_res2_{}", std::process::id()));
        std::env::set_var("NODAL_RESULTS", &dir);
        let p = save_series("unit_series", &["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.0]]).unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(s, "x,y\n1,3\n2,4\n");
        std::env::remove_var("NODAL_RESULTS");
        std::fs::remove_dir_all(&dir).ok();
    }
}
