//! Multi-run worker pool for the repeated-training experiments
//! (Table 3's ten independently-initialized runs, seed sweeps).
//!
//! PJRT objects are not `Send`, so each job constructs its own
//! [`crate::runtime::Engine`] *inside* the worker thread; only the job
//! closure and its plain-data result cross threads.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `jobs` on up to `workers` OS threads; results return in job order.
///
/// Panics in jobs are contained per-thread: the affected slot carries the
/// panic message as `Err`.
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<Result<T, String>>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    let workers = workers.clamp(1, n.max(1));
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, Result<T, String>)>();

    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = queue.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            let Some((idx, f)) = job else { break };
            // NB: `&*e` — coercing `&Box<dyn Any>` itself to `&dyn Any`
            // would downcast the Box, not the payload.
            let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                .map_err(|e| panic_msg(&*e));
            if tx.send((idx, out)).is_err() {
                break;
            }
        }));
    }
    drop(tx);

    let mut results: Vec<Option<Result<T, String>>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        results[idx] = Some(r);
    }
    for h in handles {
        let _ = h.join();
    }
    results
        .into_iter()
        .map(|r| r.unwrap_or_else(|| Err("job vanished".to_string())))
        .collect()
}

/// Human-readable message from a `catch_unwind` payload (also reused by the
/// serve workers' panic containment).
pub(crate) fn panic_msg(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "panic".to_string()
    }
}

/// Hard cap on `NODAL_WORKERS` overrides (OS-thread pools stop helping far
/// below this; mostly a guard against fat-fingered values).
const MAX_WORKERS: usize = 256;

/// Number of worker threads to default to (respects `NODAL_WORKERS`).
///
/// The override is parsed **and clamped at the source**: `NODAL_WORKERS=0`
/// used to flow a zero-thread pool to every caller and only survived because
/// `run_parallel` re-clamped it — callers sizing their own pools from this
/// value would deadlock. Unparseable values fall back to the hardware count.
pub fn default_workers() -> usize {
    match std::env::var("NODAL_WORKERS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(1, MAX_WORKERS),
        None => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(8),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_job_order() {
        let jobs: Vec<_> = (0..16)
            .map(|i| {
                move || {
                    // stagger so completion order != submission order
                    std::thread::sleep(std::time::Duration::from_millis((16 - i) as u64));
                    i * 10
                }
            })
            .collect();
        let out = run_parallel(4, jobs);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(*r.as_ref().unwrap(), i * 10);
        }
    }

    #[test]
    fn panics_are_contained() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom")),
            Box::new(|| 3),
        ];
        let out = run_parallel(2, jobs);
        assert_eq!(*out[0].as_ref().unwrap(), 1);
        assert!(out[1].as_ref().unwrap_err().contains("boom"));
        assert_eq!(*out[2].as_ref().unwrap(), 3);
    }

    #[test]
    fn single_worker_serial() {
        let order = Arc::new(Mutex::new(Vec::new()));
        let jobs: Vec<_> = (0..5)
            .map(|i| {
                let order = order.clone();
                move || {
                    order.lock().unwrap().push(i);
                    i
                }
            })
            .collect();
        let out = run_parallel(1, jobs);
        assert_eq!(out.len(), 5);
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<Result<usize, String>> = run_parallel(4, Vec::<fn() -> usize>::new());
        assert!(out.is_empty());
    }

    /// All `NODAL_WORKERS` cases live in ONE test: the process environment is
    /// shared across the parallel test harness, so splitting these up would
    /// race on the variable.
    #[test]
    fn default_workers_env_parse_and_clamp() {
        std::env::set_var("NODAL_WORKERS", "0");
        assert_eq!(default_workers(), 1, "zero must clamp to one worker");
        std::env::set_var("NODAL_WORKERS", "3");
        assert_eq!(default_workers(), 3);
        std::env::set_var("NODAL_WORKERS", "1000000");
        assert_eq!(default_workers(), MAX_WORKERS);
        std::env::set_var("NODAL_WORKERS", "not-a-number");
        let d = default_workers();
        assert!((1..=8).contains(&d), "unparseable falls back to hardware: {d}");
        std::env::remove_var("NODAL_WORKERS");
        assert!(default_workers() >= 1);
    }
}
