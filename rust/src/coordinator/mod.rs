//! Experiment coordinator: every table and figure of the paper, as a
//! reproducible `nodal repro <id>` command (DESIGN.md §4).

pub mod fig7;
pub mod figs;
pub mod pool;
pub mod report;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;

pub use report::{results_dir, save_series, Table};

use anyhow::{bail, Result};

use crate::config::Config;

/// All experiment ids, in paper order.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("fig4", "van der Pol forward vs reverse trajectory (adjoint inaccuracy)"),
    ("fig5", "conv-flow image reverse reconstruction"),
    ("fig6", "toy-problem gradient error vs T for naive/adjoint/ACA"),
    ("fig7", "image classification: accuracy vs epoch and wall-clock per method"),
    ("table1", "measured computation/memory/depth costs per method"),
    ("table2", "error rates across training methods and test solvers"),
    ("table3", "ICC test-retest reliability over repeated runs"),
    ("table4", "irregular time-series MSE vs training-set fraction"),
    ("table5", "three-body problem: LSTM / NODE / ODE x gradient methods"),
    ("table6", "solver-robustness grid for the discrete baseline"),
    ("table7", "solver-robustness grid for NODE"),
];

/// Dispatch an experiment by id.
pub fn run(id: &str, cfg: &Config) -> Result<()> {
    match id {
        "fig4" => figs::fig4(cfg),
        "fig5" => figs::fig5(cfg),
        "fig6" => figs::fig6(cfg),
        "fig7" => fig7::run(cfg),
        "table1" => table1::run(cfg),
        "table2" => table2::run(cfg),
        "table3" => table3::run(cfg),
        "table4" => table4::run(cfg),
        "table5" => table5::run(cfg),
        "table6" => table2::table6(cfg),
        "table7" => table2::table7(cfg),
        "all" => {
            for (id, _) in EXPERIMENTS {
                println!("\n################ {id} ################");
                run(id, cfg)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' — see `nodal list`"),
    }
}
