//! Table 2 — test error rates: NODE trained with ACA (HeunEuler, tol 1e-2)
//! evaluated with every solver **without retraining**, vs the same NODE
//! trained with the adjoint and naive methods, vs the discrete baseline
//! (paper: ResNet ≡ NODE with one-step Euler, App. D).
//!
//! Tables 6/7 (appendix) are the full solver-robustness grids for the
//! discrete baseline and the NODE respectively.

use anyhow::Result;

use super::report::Table;
use crate::config::Config;
use crate::data::{Dataset, ImageDataset};
use crate::grad::Method;
use crate::ode::{tableau, IntegrateOpts, Tableau};
use crate::runtime::{Engine, HloModel};
use crate::train::trainer::evaluate;
use crate::train::{LrSchedule, TrainConfig, Trainer};

fn data(cfg: &Config) -> Dataset {
    ImageDataset::generate(
        cfg.get_usize("n_train", 960),
        cfg.get_usize("n_test", 320),
        0.05,
        cfg.get_usize("seed", 0) as u64,
    )
}

fn train_once(
    cfg: &Config,
    data: &Dataset,
    method: Method,
    tab: &'static Tableau,
    fixed_h: Option<f64>,
) -> Result<HloModel> {
    let mut engine = Engine::cpu()?;
    let dir = crate::runtime::artifact_root().join("img");
    let mut model = HloModel::load(&mut engine, &dir)?;
    let seed = cfg.get_usize("seed", 0) as u64;
    model.init_params(seed as i32)?;
    let epochs = cfg.get_usize("epochs", 10);
    let tcfg = TrainConfig {
        method,
        epochs,
        lr: LrSchedule::Step {
            initial: cfg.get_f64("lr", 0.05),
            factor: 0.1,
            milestones: vec![epochs * 2 / 3],
        },
        rtol: cfg.get_f64("rtol", 1e-2),
        atol: cfg.get_f64("atol", 1e-2),
        fixed_h,
        seed,
        verbose: cfg.get_bool("verbose", false),
        ..Default::default()
    };
    let mut trainer = Trainer::new(tcfg);
    trainer.fit(&mut model, tab, data)?;
    // Engine must stay alive while the model's executables are used; leak it
    // for the duration of the experiment (cheap: one client).
    std::mem::forget(engine);
    Ok(model)
}

/// Test error (%) of `model` under a given solver configuration.
fn test_err(
    model: &HloModel,
    data: &Dataset,
    tab: &Tableau,
    rtol: f64,
    fixed_h: Option<f64>,
) -> Result<f64> {
    let opts = IntegrateOpts { rtol, atol: rtol, fixed_h, ..Default::default() };
    let (_, acc) = evaluate(model, tab, &opts, 1.0, data, true)?;
    Ok(100.0 * (1.0 - acc))
}

pub fn run(cfg: &Config) -> Result<()> {
    let data = data(cfg);

    // NODE trained with ACA + HeunEuler tol 1e-2 (the paper's recipe).
    println!("training NODE-ACA (HeunEuler, tol 1e-2)…");
    let node_aca = train_once(cfg, &data, Method::Aca, tableau::heun_euler(), None)?;
    // Baselines trained and tested with their own method (Dopri5 for
    // adjoint/naive as in the paper; discrete = fixed-step Euler).
    println!("training NODE-adjoint (Dopri5)…");
    let node_adj = train_once(cfg, &data, Method::Adjoint, tableau::dopri5(), None)?;
    println!("training NODE-naive (Dopri5)…");
    let node_naive = train_once(cfg, &data, Method::Naive, tableau::dopri5(), None)?;
    println!("training discrete baseline (Euler, 1 step)…");
    let discrete = train_once(cfg, &data, Method::Aca, tableau::euler(), Some(1.0))?;

    let mut table = Table::new(
        "table2",
        "test error rate (%) — img dataset",
        &["model / test solver", "err %"],
    );
    // NODE-ACA tested across solvers without retraining.
    for (name, tab, rtol, fixed) in [
        ("NODE-ACA / HeunEuler 1e-2", tableau::heun_euler(), 1e-2, None),
        ("NODE-ACA / RK23 1e-2", tableau::rk23(), 1e-2, None),
        ("NODE-ACA / RK45 1e-2", tableau::dopri5(), 1e-2, None),
        ("NODE-ACA / Euler h=0.1", tableau::euler(), 1e-2, Some(0.1)),
        ("NODE-ACA / RK2 h=0.1", tableau::rk2(), 1e-2, Some(0.1)),
        ("NODE-ACA / RK4 h=0.1", tableau::rk4(), 1e-2, Some(0.1)),
    ] {
        table.row(vec![
            name.to_string(),
            format!("{:.2}", test_err(&node_aca, &data, tab, rtol, fixed)?),
        ]);
    }
    table.row(vec![
        "NODE-adjoint / Dopri5".into(),
        format!("{:.2}", test_err(&node_adj, &data, tableau::dopri5(), 1e-2, None)?),
    ]);
    table.row(vec![
        "NODE-naive / Dopri5".into(),
        format!("{:.2}", test_err(&node_naive, &data, tableau::dopri5(), 1e-2, None)?),
    ]);
    table.row(vec![
        "discrete (Euler 1-step)".into(),
        format!("{:.2}", test_err(&discrete, &data, tableau::euler(), 1e-2, Some(1.0))?),
    ]);
    table.emit()
}

/// Shared grid used by Tables 6 and 7: test a trained model across fixed
/// solvers × step sizes and adaptive solvers × tolerances; report the
/// *increase* in error rate vs the train-matched configuration.
fn robustness_grid(
    id: &str,
    title: &str,
    model: &HloModel,
    data: &Dataset,
    base_err: f64,
) -> Result<()> {
    let mut table = Table::new(
        id,
        title,
        &["solver", "h=1.0", "h=0.5", "h=0.2", "h=0.1", "tol 1e-1", "tol 1e-2", "tol 1e-3"],
    );
    for (name, tab) in [
        ("Euler", tableau::euler()),
        ("RK2", tableau::rk2()),
        ("RK4", tableau::rk4()),
    ] {
        let mut row = vec![name.to_string()];
        for h in [1.0, 0.5, 0.2, 0.1] {
            let e = test_err(model, data, tab, 1e-2, Some(h))?;
            row.push(format!("{:+.2}", e - base_err));
        }
        row.extend(["-".to_string(), "-".to_string(), "-".to_string()]);
        table.row(row);
    }
    for (name, tab) in [
        ("HeunEuler", tableau::heun_euler()),
        ("RK23", tableau::rk23()),
        ("RK45", tableau::dopri5()),
    ] {
        let mut row = vec![name.to_string(), "-".into(), "-".into(), "-".into(), "-".into()];
        for tol in [1e-1, 1e-2, 1e-3] {
            let e = test_err(model, data, tab, tol, None)?;
            row.push(format!("{:+.2}", e - base_err));
        }
        table.row(row);
    }
    println!("(entries are error-rate increases vs the train-matched config, {base_err:.2}%)");
    table.emit()
}

/// Table 6: the discrete baseline (1-step Euler training) across solvers.
pub fn table6(cfg: &Config) -> Result<()> {
    let data = data(cfg);
    println!("training discrete baseline (Euler, 1 step)…");
    let discrete = train_once(cfg, &data, Method::Aca, tableau::euler(), Some(1.0))?;
    let base = test_err(&discrete, &data, tableau::euler(), 1e-2, Some(1.0))?;
    robustness_grid(
        "table6",
        "discrete baseline: error-rate increase across test solvers",
        &discrete,
        &data,
        base,
    )
}

/// Table 7: NODE trained with HeunEuler tol 1e-2 across solvers.
pub fn table7(cfg: &Config) -> Result<()> {
    let data = data(cfg);
    println!("training NODE-ACA (HeunEuler, tol 1e-2)…");
    let node = train_once(cfg, &data, Method::Aca, tableau::heun_euler(), None)?;
    let base = test_err(&node, &data, tableau::heun_euler(), 1e-2, None)?;
    robustness_grid(
        "table7",
        "NODE (HeunEuler-trained): error-rate increase across test solvers",
        &node,
        &data,
        base,
    )
}
