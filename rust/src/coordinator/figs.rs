//! Figures 4, 5 and 6 — the paper's numerical-error studies. Pure-Rust
//! analytic dynamics; no artifacts required.

use anyhow::Result;

use super::report::{save_series, Table};
use crate::config::Config;
use crate::grad::{self, Method};
use crate::ode::analytic::{ConvFlow, Linear, VanDerPol};
use crate::ode::{integrate, tableau, IntegrateOpts};
use crate::tensor;

/// Fig 4: solve van der Pol forward over `[0, T]`, then solve backward from
/// `z(T)` — the adjoint method's reverse trajectory — and measure how far
/// `z̄(0)` lands from `z(0)`, per tolerance.
pub fn fig4(cfg: &Config) -> Result<()> {
    let t_end = cfg.get_f64("t_end", 25.0);
    let mu = cfg.get_f64("mu", 0.15) as f32;
    let z0 = [2.0f32, 0.0];
    let f = VanDerPol::new(mu);
    let tab = tableau::dopri5();

    let mut table = Table::new(
        "fig4",
        "van der Pol: reverse-trajectory reconstruction error (Dopri5)",
        &["rtol", "atol", "fwd steps", "rev steps", "|z̄(0) − z(0)|∞"],
    );
    for (rtol, atol) in [(1e-3, 1e-6), (1e-6, 1e-9), (1e-9, 1e-12)] {
        let opts = IntegrateOpts::with_tol(rtol, atol);
        let fwd = integrate(&f, 0.0, t_end, &z0, tab, &opts)?;
        let zt = fwd.last().expect("non-empty trajectory").to_vec();
        let rev = integrate(&f, t_end, 0.0, &zt, tab, &opts)?;
        let err = tensor::max_abs_diff(rev.last().unwrap(), &z0) as f64;
        table.row(vec![
            format!("{rtol:.0e}"),
            format!("{atol:.0e}"),
            fwd.len().to_string(),
            rev.len().to_string(),
            Table::fmt(err),
        ]);
        // Trajectory dump (the figure itself) for the loosest tolerance.
        if rtol == 1e-3 {
            let cols = vec![
                fwd.ts.clone(),
                fwd.states().map(|z| z[0] as f64).collect(),
                fwd.states().map(|z| z[1] as f64).collect(),
            ];
            save_series("fig4_forward", &["t", "y1", "y2"], &cols)?;
            let cols = vec![
                rev.ts.clone(),
                rev.states().map(|z| z[0] as f64).collect(),
                rev.states().map(|z| z[1] as f64).collect(),
            ];
            save_series("fig4_reverse", &["t", "y1", "y2"], &cols)?;
        }
    }
    table.emit()
}

/// Fig 5: evolve a 16×16 image under a random 3×3 conv flow, then reverse
/// from `z(T)`; report relative reconstruction error.
pub fn fig5(cfg: &Config) -> Result<()> {
    let t_end = cfg.get_f64("t_end", 5.0);
    let seed = cfg.get_usize("seed", 7) as u64;
    let f = ConvFlow::random(16, 16, seed, 0.4);
    let tab = tableau::dopri5();

    // Input image: the class-0 (circle) pattern from the image dataset.
    let data = crate::data::ImageDataset::generate(1, 0, 0.0, seed);
    let z0 = &data.train_x[..256];

    let mut table = Table::new(
        "fig5",
        "conv-flow: reverse reconstruction relative L2 error (Dopri5)",
        &["rtol", "‖z(T)‖₂/‖z0‖₂", "rel. reconstruction err"],
    );
    for rtol in [1e-3, 1e-6, 1e-9] {
        let opts = IntegrateOpts::with_tol(rtol, rtol * 1e-3);
        let fwd = integrate(&f, 0.0, t_end, z0, tab, &opts)?;
        let zt = fwd.last().expect("non-empty trajectory").to_vec();
        let rev = integrate(&f, t_end, 0.0, &zt, tab, &opts)?;
        let diff: Vec<f32> = rev.last().unwrap().iter().zip(z0).map(|(a, b)| a - b).collect();
        let rel = tensor::norm2(&diff) / tensor::norm2(z0);
        let growth = tensor::norm2(fwd.last().unwrap()) / tensor::norm2(z0);
        table.row(vec![format!("{rtol:.0e}"), Table::fmt(growth), Table::fmt(rel)]);
        if rtol == 1e-3 {
            save_series(
                "fig5_images",
                &["input", "evolved", "reconstructed"],
                &[
                    z0.iter().map(|&v| v as f64).collect(),
                    fwd.last().unwrap().iter().map(|&v| v as f64).collect(),
                    rev.last().unwrap().iter().map(|&v| v as f64).collect(),
                ],
            )?;
        }
    }
    table.emit()
}

/// Fig 6: |gradient error| vs end time T on the toy problem (Eq. 27–29) for
/// the three methods, Dopri5 at tol 1e-5.
pub fn fig6(cfg: &Config) -> Result<()> {
    let k = cfg.get_f64("k", -0.5) as f32;
    let z0 = 1.0f32;
    let tol = cfg.get_f64("tol", 1e-5);
    let tab = tableau::dopri5();
    let f = Linear::new(k, 1);

    // Two gradients are compared against their analytic forms:
    // dL/dz0 (Eq. 29) and the parameter gradient dL/dk. The latter is the
    // sensitive one: the adjoint method computes ∫ λᵀ ∂f/∂k dt along its
    // *reconstructed* reverse trajectory z̄ (Sec 3.2), so reverse-trajectory
    // drift corrupts it directly, while ACA evaluates on the checkpoints.
    let ts: Vec<f64> = (1..=10).map(|i| i as f64).collect();
    let mut cols: Vec<Vec<f64>> = vec![ts.clone(); 7];
    for c in cols.iter_mut().skip(1) {
        c.clear();
    }
    let mut table = Table::new(
        "fig6",
        "toy problem relative |grad error| vs T (Dopri5)",
        &[
            "T",
            "dz0 naive",
            "dz0 adjoint",
            "dz0 ACA",
            "dk naive",
            "dk adjoint",
            "dk ACA",
        ],
    );
    for &t_end in &ts {
        let exact_z = f.exact_dl_dz0(z0, t_end);
        let exact_k = f.exact_dl_dk(z0, t_end);
        let mut row = vec![format!("{t_end}")];
        let mut errs_z = Vec::new();
        let mut errs_k = Vec::new();
        for method in [Method::Naive, Method::Adjoint, Method::Aca] {
            let opts = IntegrateOpts {
                record_trials: true,
                ..IntegrateOpts::with_tol(tol, tol * 1e-3)
            };
            let traj = integrate(&f, 0.0, t_end, &[z0], tab, &opts)?;
            let zt = traj.last().unwrap()[0];
            let g = grad::backward(&f, tab, &traj, &[2.0 * zt], method, &opts)?;
            errs_z.push(((g.dl_dz0[0] as f64 - exact_z) / exact_z).abs());
            errs_k.push(((g.dl_dtheta[0] as f64 - exact_k) / exact_k).abs());
        }
        for e in errs_z.iter().chain(&errs_k) {
            row.push(Table::fmt(*e));
        }
        for (i, e) in errs_z.iter().chain(&errs_k).enumerate() {
            cols[i + 1].push(*e);
        }
        table.row(row);
    }
    save_series(
        "fig6_series",
        &["T", "dz0_naive", "dz0_adjoint", "dz0_aca", "dk_naive", "dk_adjoint", "dk_aca"],
        &cols,
    )?;
    table.emit()?;

    let mean = |c: &[f64]| c.iter().sum::<f64>() / c.len() as f64;
    println!(
        "mean rel |dz0 err|: naive {:.3e}  adjoint {:.3e}  ACA {:.3e}",
        mean(&cols[1]),
        mean(&cols[2]),
        mean(&cols[3])
    );
    println!(
        "mean rel |dk  err|: naive {:.3e}  adjoint {:.3e}  ACA {:.3e}",
        mean(&cols[4]),
        mean(&cols[5]),
        mean(&cols[6])
    );
    Ok(())
}
