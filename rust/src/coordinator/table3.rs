//! Table 3 — test-retest reliability: ICC(1) and ICC(1,k) of per-sample
//! correctness across independently-initialized training runs, on the whole
//! test set and on the misclassified subset, NODE vs the discrete baseline.
//!
//! Runs execute in parallel on the worker pool (one PJRT client per thread).

use anyhow::{anyhow, Result};

use super::pool::{default_workers, run_parallel};
use super::report::Table;
use crate::config::Config;
use crate::data::ImageDataset;
use crate::grad::Method;
use crate::metrics::{icc1, icc1k, IccInput};
use crate::ode::{tableau, IntegrateOpts};
use crate::runtime::{Engine, HloModel};
use crate::train::trainer::per_sample_correct;
use crate::train::{LrSchedule, TrainConfig, Trainer};

/// One training run: returns the per-test-sample correctness vector.
fn one_run(seed: u64, epochs: usize, discrete: bool, n_train: usize, n_test: usize) -> Vec<bool> {
    let data = ImageDataset::generate(n_train, n_test, 0.05, 0); // same data every run
    let mut engine = Engine::cpu().expect("engine");
    let dir = crate::runtime::artifact_root().join("img");
    let mut model = HloModel::load(&mut engine, &dir).expect("load img model");
    model.init_params(seed as i32).expect("init");
    let (tab, fixed_h) = if discrete {
        (tableau::euler(), Some(1.0))
    } else {
        (tableau::heun_euler(), None)
    };
    let tcfg = TrainConfig {
        method: Method::Aca,
        epochs,
        lr: LrSchedule::Step { initial: 0.05, factor: 0.1, milestones: vec![epochs * 2 / 3] },
        fixed_h,
        seed,
        ..Default::default()
    };
    let mut trainer = Trainer::new(tcfg);
    trainer.fit(&mut model, tab, &data).expect("fit");
    let opts = IntegrateOpts { rtol: 1e-2, atol: 1e-2, fixed_h, ..Default::default() };
    per_sample_correct(&model, tab, &opts, 1.0, &data).expect("eval")
}

pub fn run(cfg: &Config) -> Result<()> {
    let runs = cfg.get_usize("runs", 10);
    let epochs = cfg.get_usize("epochs", 8);
    let n_train = cfg.get_usize("n_train", 640);
    let n_test = cfg.get_usize("n_test", 320);
    let workers = cfg.get_usize("workers", default_workers());

    let mut table = Table::new(
        "table3",
        &format!("ICC over {runs} runs (img dataset)"),
        &["model", "subset", "ICC1", "ICC1k", "mean acc"],
    );

    for (label, discrete) in [("NODE18-ACA", false), ("discrete", true)] {
        println!("{label}: launching {runs} runs on {workers} workers…");
        let jobs: Vec<_> = (0..runs)
            .map(|r| {
                let seed = 100 + r as u64;
                move || one_run(seed, epochs, discrete, n_train, n_test)
            })
            .collect();
        let results = run_parallel(workers, jobs);
        let correctness: Vec<Vec<bool>> = results
            .into_iter()
            .collect::<Result<Vec<_>, String>>()
            .map_err(|e| anyhow!("run failed: {e}"))?;

        let mean_acc = correctness
            .iter()
            .map(|r| r.iter().filter(|&&b| b).count() as f64 / r.len() as f64)
            .sum::<f64>()
            / correctness.len() as f64;

        let input = IccInput::from_correctness(&correctness);
        table.row(vec![
            label.to_string(),
            "whole test set".into(),
            Table::fmt(icc1(&input)),
            Table::fmt(icc1k(&input)),
            format!("{mean_acc:.4}"),
        ]);
        let mis = input.misclassified_subset();
        table.row(vec![
            label.to_string(),
            "misclassified".into(),
            Table::fmt(icc1(&mis)),
            Table::fmt(icc1k(&mis)),
            "-".into(),
        ]);
    }
    table.emit()
}
