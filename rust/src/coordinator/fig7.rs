//! Fig 7 (a)/(b): test accuracy vs epoch and vs wall-clock for the image
//! NODE trained with naive / adjoint / ACA.
//!
//! The paper's claim under test: for the same model, ACA reaches roughly
//! half the error rate of the baselines at the same epoch count, in about
//! half (adjoint) to a third (naive) of the wall-clock time.
//!
//! The three method runs are independent, so they can be sharded across the
//! worker pool (one PJRT client per worker thread — PJRT objects are not
//! `Send`, so each job builds its own engine/model inside the worker).
//! Default is `--workers 1`: the per-method wall-clock comparison is the
//! experiment's headline metric, and concurrent runs contending for cores
//! would bias exactly those ratios. Pass `--workers 3` when only the
//! accuracy columns matter and throughput is the priority.

use anyhow::Result;

use super::pool::{default_workers, run_parallel};
use super::report::{save_series, Table};
use crate::config::Config;
use crate::data::ImageDataset;
use crate::grad::Method;
use crate::ode::tableau;
use crate::runtime::{Engine, HloModel};
use crate::train::{LrSchedule, TrainConfig, TrainRecord, Trainer};

pub fn run(cfg: &Config) -> Result<()> {
    let epochs = cfg.get_usize("epochs", 12);
    let n_train = cfg.get_usize("n_train", 960);
    let n_test = cfg.get_usize("n_test", 320);
    let seed = cfg.get_usize("seed", 0) as u64;
    let solver = cfg.get_str("solver", "heuneuler");
    let tab = tableau::by_name(&solver).expect("unknown solver");
    let lr = cfg.get_f64("lr", 0.05);
    let rtol = cfg.get_f64("rtol", 1e-2);
    let atol = cfg.get_f64("atol", 1e-2);
    let clip = cfg.get_f64("clip", 1.0);
    let verbose = cfg.get_bool("verbose", true);

    let methods = [Method::Aca, Method::Adjoint, Method::Naive];
    let jobs: Vec<_> = methods
        .iter()
        .map(|&method| {
            let dir = crate::runtime::artifact_root().join("img");
            move || -> Result<Vec<TrainRecord>> {
                // Dataset regenerated per worker (deterministic from the
                // seed) — plain data only crosses the thread boundary.
                let data = ImageDataset::generate(n_train, n_test, 0.05, seed);
                let mut engine = Engine::cpu()?;
                let mut model = HloModel::load(&mut engine, &dir)?;
                model.init_params(seed as i32)?;

                // Paper recipe scaled down: SGD momentum 0.9, step decay.
                let tcfg = TrainConfig {
                    method,
                    epochs,
                    lr: LrSchedule::Step {
                        initial: lr,
                        factor: 0.1,
                        milestones: vec![epochs * 2 / 3, epochs * 9 / 10],
                    },
                    rtol,
                    atol,
                    clip,
                    seed,
                    verbose,
                    ..Default::default()
                };
                let mut trainer = Trainer::new(tcfg);
                trainer.fit(&mut model, tab, &data)?;
                Ok(trainer.history)
            }
        })
        .collect();

    let workers =
        cfg.get_usize("workers", 1).min(default_workers()).min(methods.len());
    if workers > 1 {
        println!(
            "fig7: sharding {} training runs over {workers} workers — per-method wall-clock \
             columns are contended and not comparable across methods",
            methods.len()
        );
    }
    let results = run_parallel(workers, jobs);

    let mut table = Table::new(
        "fig7",
        "img-NODE: final accuracy + time per method",
        &["method", "final err %", "best err %", "total time (s)", "s/epoch", "nfe f/b per batch"],
    );
    let mut curves: Vec<Vec<f64>> = Vec::new();
    let mut curve_names: Vec<String> = Vec::new();

    for (method, res) in methods.iter().zip(results) {
        let hist = match res {
            Ok(Ok(h)) => h,
            Ok(Err(e)) => anyhow::bail!("fig7 {} run failed: {e}", method.name()),
            Err(p) => anyhow::bail!("fig7 {} run panicked: {p}", method.name()),
        };
        let final_acc = hist.last().map(|r| r.test_acc).unwrap_or(0.0);
        let final_err = 100.0 * (1.0 - final_acc);
        let best_err =
            100.0 * (1.0 - hist.iter().map(|r| r.test_acc).fold(0.0f64, f64::max));
        let total = hist.last().map(|r| r.wall_s).unwrap_or(0.0);
        let nfe = hist
            .last()
            .map(|r| format!("{:.0}/{:.0}", r.nfe_forward, r.nfe_backward))
            .unwrap_or_default();
        table.row(vec![
            method.name().to_string(),
            format!("{final_err:.2}"),
            format!("{best_err:.2}"),
            format!("{total:.1}"),
            format!("{:.2}", total / epochs.max(1) as f64),
            nfe,
        ]);

        // Figure series: epoch, wall_s, accuracy.
        curves.push(hist.iter().map(|r| r.epoch as f64).collect());
        curves.push(hist.iter().map(|r| r.wall_s).collect());
        curves.push(hist.iter().map(|r| r.test_acc).collect());
        for suffix in ["epoch", "wall_s", "acc"] {
            curve_names.push(format!("{}_{suffix}", method.name()));
        }
    }

    let name_refs: Vec<&str> = curve_names.iter().map(|s| s.as_str()).collect();
    save_series("fig7_curves", &name_refs, &curves)?;
    table.emit()
}
