//! One serve shard: a [`SolveServer`] behind a TCP endpoint.
//!
//! Frame protocol (one JSON object per frame, see `dist::transport`).
//! Request/response/error bodies are the **versioned wire schema** from
//! `serve::wire` — the same codecs the HTTP front door uses, carrying a
//! `"v"` field checked on decode ([`crate::serve::WIRE_VERSION`]) — so
//! shards and HTTP clients speak one schema:
//!
//! * `{"kind":"solve","id":N,"req":{…}}` → `{"kind":"resp","id":N,…}`
//!   with either `"ok":true,"resp":{…}` or `"ok":false,"err":{…}` —
//!   admission errors ([`ServeError::Overloaded`] included) travel on the
//!   same channel, so backpressure propagates end-to-end. A traced
//!   request's locally recorded spans ride back on the same frame as a
//!   `"spans"` array (taken from the shard's
//!   [`TraceStore`](crate::obs::TraceStore) exactly once), which is how
//!   the dispatcher stitches one cross-process trace.
//! * `{"kind":"metrics"}` → `{"kind":"metrics","snapshot":{…}}`.
//! * `{"kind":"shutdown"}` → `{"kind":"bye"}`, then the connection closes.
//!
//! Responses are written as each solve completes, so they interleave out
//! of request order; the `id` is the correlation tag. [`ShardServer`]
//! drops gracefully (stop intake, drain admitted work, then cut
//! connections); [`ShardServer::abort`] is the crash lever for tests —
//! it severs every socket without draining, exactly what a dying process
//! looks like from the dispatcher's side.

use super::transport::{encode_frame, recv_frame, write_frame_bytes};
use crate::obs::{self, SpanRec};
use crate::serve::request::{ServeError, SolveRequest, SolveResponse};
use crate::serve::SolveServer;
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A running shard endpoint.
pub struct ShardServer {
    addr: String,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<JoinHandle<()>>,
    server: Arc<SolveServer>,
}

impl ShardServer {
    /// Bind `bind` (use port 0 for an ephemeral test port) and serve
    /// `server` over it until shutdown.
    pub fn spawn(server: SolveServer, bind: &str) -> Result<ShardServer> {
        let server = Arc::new(server);
        let listener = TcpListener::bind(bind).with_context(|| format!("bind shard at {bind}"))?;
        let addr = listener.local_addr().context("shard local addr")?.to_string();
        listener.set_nonblocking(true).context("shard listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (server, stop, conns) = (server.clone(), stop.clone(), conns.clone());
            std::thread::spawn(move || accept_loop(&listener, &server, &stop, &conns))
        };
        Ok(ShardServer { addr, stop, conns, accept: Some(accept), server })
    }

    /// The bound address (`host:port`) clients dial.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The shard's underlying server (for registry/metrics access in
    /// tests and examples).
    pub fn server(&self) -> &Arc<SolveServer> {
        &self.server
    }

    /// Simulate a crash: sever every connection and stop accepting,
    /// WITHOUT draining. In-flight solves still complete inside the
    /// server, but their responses hit dead sockets — from a peer's view
    /// this process died mid-conversation.
    pub fn abort(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap().iter() {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }

    /// Graceful shutdown: stop accepting, answer everything already
    /// admitted (`SolveServer::drain`), then close the connections and
    /// join the service threads. Idempotent.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        self.server.drain();
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    server: &Arc<SolveServer>,
    stop: &AtomicBool,
    conns: &Mutex<Vec<TcpStream>>,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((s, _)) => {
                let _ = s.set_nodelay(true);
                if let Ok(c) = s.try_clone() {
                    conns.lock().unwrap().push(c);
                }
                let server = server.clone();
                handlers.push(std::thread::spawn(move || handle_conn(s, &server)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// Serialize `body` outside the writer lock, then write it under the lock.
/// Concurrent waiter threads answer on the same socket, so the guard must
/// span the socket write to keep each response frame atomic.
fn send_locked(writer: &Mutex<TcpStream>, body: &Json) {
    let Ok(bytes) = encode_frame(body) else { return };
    let mut w = writer.lock().unwrap();
    // nodal-lint: allow(lock-discipline) the writer mutex must span the socket write so response frames from concurrent waiters stay atomic
    let _ = write_frame_bytes(&mut *w, &bytes);
}

/// Write one correlated response frame (ok or error) to the shared writer,
/// piggybacking the solve's recorded spans when the request was traced
/// (span JSON carries only integers and hex strings, so the frame stays
/// wire-deterministic).
fn respond(
    writer: &Mutex<TcpStream>,
    id: usize,
    result: Result<SolveResponse, ServeError>,
    spans: &[SpanRec],
) {
    let mut pairs = match result {
        Ok(r) => vec![
            ("kind", Json::from("resp")),
            ("id", id.into()),
            ("ok", true.into()),
            ("resp", r.to_json()),
        ],
        Err(e) => vec![
            ("kind", Json::from("resp")),
            ("id", id.into()),
            ("ok", false.into()),
            ("err", e.to_json()),
        ],
    };
    if !spans.is_empty() {
        pairs.push(("spans", obs::spans_to_json(spans)));
    }
    send_locked(writer, &obj(pairs));
}

fn handle_conn(stream: TcpStream, server: &Arc<SolveServer>) {
    let mut reader = match stream.try_clone() {
        Ok(r) => r,
        Err(_) => return,
    };
    let writer = Arc::new(Mutex::new(stream));
    let mut waiters: Vec<JoinHandle<()>> = Vec::new();
    loop {
        let msg = match recv_frame(&mut reader) {
            Ok(m) => m,
            Err(_) => break, // peer hung up (or timed out): stop serving it
        };
        let kind = match msg.get("kind").and_then(Json::as_str) {
            Ok(k) => k.to_string(),
            Err(_) => break,
        };
        match kind.as_str() {
            "solve" => {
                let id = match msg.get("id").and_then(Json::as_usize) {
                    Ok(id) => id,
                    Err(_) => break, // uncorrelatable request: protocol error
                };
                let req = match msg.get("req").and_then(SolveRequest::from_json) {
                    Ok(r) => r,
                    Err(e) => {
                        respond(&writer, id, Err(ServeError::BadRequest(e.to_string())), &[]);
                        continue;
                    }
                };
                let trace = req.trace.map(|c| c.trace);
                match server.submit(req) {
                    Ok(handle) => {
                        // Answer out-of-band when the batch completes; the
                        // read loop keeps accepting pipelined requests.
                        let writer = writer.clone();
                        waiters.push(std::thread::spawn(move || {
                            // Emitters publish before fulfilling, so by the
                            // time wait() returns the solve's spans are in
                            // the local store; hand them back exactly once.
                            let result = handle.wait();
                            let spans =
                                trace.map(|t| obs::global().take(t)).unwrap_or_default();
                            respond(&writer, id, result, &spans);
                        }));
                    }
                    Err(e) => respond(&writer, id, Err(e), &[]),
                }
            }
            "metrics" => {
                let body = obj(vec![
                    ("kind", "metrics".into()),
                    ("snapshot", server.metrics().to_json()),
                ]);
                send_locked(&writer, &body);
            }
            "shutdown" => {
                send_locked(&writer, &obj(vec![("kind", "bye".into())]));
                break;
            }
            _ => break,
        }
    }
    for w in waiters {
        let _ = w.join();
    }
}
