//! Multi-process scale-out: deterministic data-parallel training and a
//! sharded solve service, two halves of one subsystem sharing a single
//! TCP transport.
//!
//! **Training** (`train`, `reduce`, `env`): rank 0 shards each
//! mini-batch deterministically across the world ([`shard_range`]),
//! every rank runs the same forward/backward locally, and the partial
//! gradients are combined by a fixed adjacent-pairwise tree
//! ([`tree_combine`]) whose association depends only on rank slots —
//! never on message arrival order — so a W-rank step is bit-identical
//! run to run and equal to [`grad_accum_reference`] computed in one
//! process. Worker death is survived by re-sharding over the remaining
//! members and bumping an attempt tag that quarantines stale partials.
//!
//! **Serving** (`shard`, `dispatch`): each shard is a `SolveServer`
//! behind a framed TCP endpoint; the [`Dispatcher`] routes requests by
//! batch-key hash (preserving coalescing), steals work past a load
//! margin, propagates `Overloaded` backpressure end-to-end, fails over
//! dead shards by re-dispatching their pending requests, and merges
//! per-shard metrics into one [`DistMetricsReport`].
//!
//! **Transport** (`transport`): length-prefixed JSON frames over
//! `std::net::TcpStream` with connect retry, bounded backoff, and I/O
//! timeouts. f32 payloads travel as bit patterns ([`crate::util::json`])
//! so NaN, -0.0 and infinities survive the wire bit-exactly.
//!
//! Everything is testable in-process: threads on loopback sockets stand
//! in for processes (`rust/tests/dist_integration.rs`), and CI runs a
//! real two-process smoke (`examples/dist_train.rs`).

pub mod dispatch;
pub mod env;
pub mod reduce;
pub mod shard;
pub mod train;
pub mod transport;

pub use dispatch::{key_hash, route, Dispatcher, DispatcherConfig, DistMetricsReport};
pub use env::DistConfig;
pub use reduce::{
    bucket_leaves, flat_combine, tree_combine, GradLeaf, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES,
};
pub use shard::ShardServer;
pub use train::{
    grad_accum_reference, local_partial, run_root, run_worker, shard_range, train_step, DistGrad,
    RootOpts, StepSpec,
};
pub use transport::{
    connect_retry, encode_frame, recv_frame, send_frame, write_frame_bytes, TransportOpts,
    MAX_FRAME_BYTES,
};
