//! Key-affine request dispatcher over a fleet of serve shards.
//!
//! The dispatcher owns one long-lived connection per [`ShardServer`]
//! (`dist::shard`) and routes every [`SolveRequest`] by its
//! [`BatchKey`] hash, so requests that would coalesce into one batch on
//! a single server still land on the same shard and keep coalescing.
//! Two departures from pure hashing:
//!
//! * **Work stealing** — when the hash-preferred shard is backed up by
//!   at least `steal_margin` more in-flight requests than the least
//!   loaded shard, the request goes to the latter instead. A margin of
//!   zero disables stealing. Stealing trades batch affinity for
//!   latency, which is why it only kicks in past a real imbalance.
//! * **Failover** — a shard whose socket dies is marked unhealthy; its
//!   pending requests are drained and re-dispatched to the survivors,
//!   and the hash ring contracts deterministically to the healthy set.
//!   Responses race benignly: [`ResponseSlot`] is first-write-wins, so
//!   a late answer from a shard declared dead is simply ignored.
//!
//! [`ServeError`]s decoded off the wire — [`ServeError::Overloaded`]
//! included — surface through [`ResponseHandle::wait`] exactly as they
//! do in-process, so backpressure crosses the process boundary intact.
//!
//! **Tracing.** A traced request's [`TraceCtx`] crosses the wire inside
//! the solve frame: the dispatcher records a `dispatch` event span tagged
//! with the chosen shard (plus `steal`/`failover` events when routing
//! departs from the hash), re-parents the context under that event, and
//! the shard's spans come back piggybacked on the `resp` frame — ingested
//! into the local [`TraceStore`](crate::obs::TraceStore) *before* the
//! waiter is fulfilled, so one request routed through the dispatcher
//! yields a single stitched cross-process trace.
//!
//! [`ShardServer`]: super::shard::ShardServer
//! [`BatchKey`]: crate::serve::request::BatchKey
//! [`TraceCtx`]: crate::obs::TraceCtx

use super::transport::{
    connect_retry, encode_frame, recv_frame, send_frame, write_frame_bytes, TransportOpts,
};
use crate::obs::{self, SpanRec};
use crate::serve::metrics::MetricsSnapshot;
use crate::serve::request::{
    BatchKey, ResponseHandle, ResponseSlot, ServeError, SolveRequest, SolveResponse,
};
use crate::serve::{Clock, SolveFrontend, Waiter, WallClock};
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Dispatcher tuning.
#[derive(Debug, Clone)]
pub struct DispatcherConfig {
    /// Steal when the hash-preferred shard has at least this many more
    /// in-flight requests than the least loaded one. Zero disables.
    pub steal_margin: usize,
    /// Connection and I/O behaviour for the shard links.
    pub transport: TransportOpts,
}

impl Default for DispatcherConfig {
    fn default() -> Self {
        DispatcherConfig { steal_margin: 8, transport: TransportOpts::default() }
    }
}

/// FNV-1a over every field of the batch key. Stable across runs and
/// platforms (no `RandomState`), so shard placement is reproducible —
/// tests can precompute which shard a key lands on.
pub fn key_hash(key: &BatchKey) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= u64::from(b);
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(key.dynamics.as_bytes());
    eat(&[0xff]); // separator: "ab"+"c" must not collide with "a"+"bc"
    eat(key.tab.as_bytes());
    eat(&[0xff]);
    eat(&[
        key.dir as u8,
        key.tol_kind,
        u8::from(key.wants_grad),
        u8::from(key.wants_obs),
        key.lane as u8,
    ]);
    eat(&key.tol_a.to_le_bytes());
    eat(&key.tol_b.to_le_bytes());
    h
}

/// Pick a shard for `hash` among `loads` (pairs of shard index and
/// in-flight count for every *healthy* shard, in fixed index order).
/// The hash-preferred entry wins unless stealing is enabled and it is
/// at least `steal_margin` busier than the least loaded entry.
///
/// Panics on an empty slate; callers check for survivors first.
pub fn route(hash: u64, loads: &[(usize, usize)], steal_margin: usize) -> usize {
    assert!(!loads.is_empty(), "route over zero shards");
    let primary = loads[(hash % loads.len() as u64) as usize];
    if steal_margin == 0 {
        return primary.0;
    }
    // min_by_key is stable: ties go to the lowest shard index.
    let least = loads.iter().copied().min_by_key(|&(_, l)| l).unwrap_or(primary);
    if primary.1 >= least.1 + steal_margin {
        least.0
    } else {
        primary.0
    }
}

struct PendingEntry {
    req: SolveRequest,
    slot: Arc<ResponseSlot>,
}

struct ShardConn {
    addr: String,
    writer: Mutex<TcpStream>,
    /// Requests sent but not yet answered, by correlation id. The map
    /// length doubles as the shard's load figure for routing.
    pending: Mutex<BTreeMap<u64, PendingEntry>>,
    healthy: AtomicBool,
}

struct Inner {
    shards: Vec<ShardConn>,
    next_id: AtomicUsize,
    steal_margin: usize,
    transport: TransportOpts,
    clock: Arc<dyn Clock>,
}

impl Inner {
    /// Route and send, registering the pending entry *before* the write
    /// so the response cannot race past an empty map. On a dead socket,
    /// mark the shard unhealthy and retry on the survivors — unless the
    /// reader thread's drain already adopted the entry, in which case
    /// the re-dispatch is its problem and ours is done.
    fn dispatch(&self, mut req: SolveRequest, slot: Arc<ResponseSlot>) -> Result<(), ServeError> {
        let hash = key_hash(&req.batch_key());
        loop {
            let loads: Vec<(usize, usize)> = self
                .shards
                .iter()
                .enumerate()
                .filter(|(_, s)| s.healthy.load(Ordering::SeqCst))
                .map(|(i, s)| (i, s.pending.lock().unwrap().len()))
                .collect();
            if loads.is_empty() {
                return Err(ServeError::ShuttingDown);
            }
            let chosen = route(hash, &loads, self.steal_margin);
            let primary = loads[(hash % loads.len() as u64) as usize].0;
            if let Some(ctx) = req.trace {
                // The routing decision becomes an event span tagged with
                // the chosen shard; downstream (shard-side) spans parent
                // to it, stitching the cross-process trace.
                let at = self.clock.now();
                let mut ev_ctx = ctx;
                ev_ctx.shard = chosen as i64;
                let ev = SpanRec::event(ev_ctx, obs::DISPATCH, at);
                obs::record(ev);
                if chosen != primary {
                    obs::record(SpanRec::event(ev.ctx(), obs::STEAL, at));
                }
                obs::publish();
                req.trace = Some(ev.ctx());
            }
            let shard = &self.shards[chosen];
            let id = self.next_id.fetch_add(1, Ordering::SeqCst) as u64;
            shard
                .pending
                .lock()
                .unwrap()
                .insert(id, PendingEntry { req: req.clone(), slot: slot.clone() });
            // Serialize outside the writer lock; hold it only for the
            // actual socket write so a slow shard cannot stall routing.
            let sent = match encode_frame(&solve_message(id, &req)) {
                Ok(bytes) => {
                    let mut w = shard.writer.lock().unwrap();
                    // nodal-lint: allow(lock-discipline) the writer mutex must span the socket write so concurrent dispatchers cannot interleave frame bytes
                    write_frame_bytes(&mut *w, &bytes)
                }
                Err(e) => Err(e),
            };
            if sent.is_ok() {
                // A write into a dying socket can still "succeed" (the OS
                // buffers it) after the reader saw EOF and ran its drain.
                // The reader marks unhealthy *before* draining, so if the
                // flag is still set here, our entry is either already
                // adopted by that drain or it is ours to retry — never
                // silently leaked.
                if shard.healthy.load(Ordering::SeqCst) {
                    return Ok(());
                }
                if shard.pending.lock().unwrap().remove(&id).is_some() {
                    continue; // the drain ran before our insert: retry
                }
                return Ok(()); // the drain adopted the entry
            }
            shard.healthy.store(false, Ordering::SeqCst);
            if shard.pending.lock().unwrap().remove(&id).is_some() {
                continue; // still ours: try the survivors
            }
            return Ok(()); // the reader's drain took it
        }
    }
}

fn solve_message(id: u64, req: &SolveRequest) -> Json {
    obj(vec![
        ("kind", "solve".into()),
        ("id", (id as usize).into()),
        ("req", req.to_json()),
    ])
}

/// Client-side front door for a shard fleet. See the module docs.
pub struct Dispatcher {
    inner: Arc<Inner>,
    readers: Mutex<Vec<JoinHandle<()>>>,
}

impl Dispatcher {
    /// Dial every shard (with `cfg.transport` retry/backoff) and start a
    /// reader thread per link. Fails if any shard is unreachable —
    /// starting degraded is a deployment error, unlike *becoming*
    /// degraded, which failover handles.
    pub fn connect(addrs: &[String], cfg: &DispatcherConfig) -> Result<Dispatcher> {
        Self::connect_with_clock(addrs, cfg, Arc::new(WallClock::default()))
    }

    /// [`Dispatcher::connect`] with an injected clock for the dispatch /
    /// steal / failover event timestamps (tests use a
    /// [`ManualClock`](crate::serve::ManualClock) for deterministic
    /// traces).
    pub fn connect_with_clock(
        addrs: &[String],
        cfg: &DispatcherConfig,
        clock: Arc<dyn Clock>,
    ) -> Result<Dispatcher> {
        let mut shards = Vec::with_capacity(addrs.len());
        let mut read_halves = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = connect_retry(addr, &cfg.transport)
                .with_context(|| format!("dial shard {addr}"))?;
            let read_half = stream.try_clone().context("clone shard stream")?;
            shards.push(ShardConn {
                addr: addr.clone(),
                writer: Mutex::new(stream),
                pending: Mutex::new(BTreeMap::new()),
                healthy: AtomicBool::new(true),
            });
            read_halves.push(read_half);
        }
        let inner = Arc::new(Inner {
            shards,
            next_id: AtomicUsize::new(0),
            steal_margin: cfg.steal_margin,
            transport: cfg.transport.clone(),
            clock,
        });
        let readers = read_halves
            .into_iter()
            .enumerate()
            .map(|(idx, stream)| {
                let inner = inner.clone();
                std::thread::spawn(move || reader_loop(&inner, idx, stream))
            })
            .collect();
        Ok(Dispatcher { inner, readers: Mutex::new(readers) })
    }

    /// Route `req` to a shard and return a handle, exactly like
    /// `SolveServer::submit` but across the wire. Admission errors from
    /// the shard (including `Overloaded`) come back through the handle;
    /// `Err` here means no healthy shard remains.
    pub fn submit(&self, req: SolveRequest) -> Result<ResponseHandle, ServeError> {
        let (handle, slot) = ResponseHandle::new();
        self.inner.dispatch(req, slot)?;
        Ok(handle)
    }

    /// Number of shards still considered healthy.
    pub fn healthy_shards(&self) -> usize {
        self.inner
            .shards
            .iter()
            .filter(|s| s.healthy.load(Ordering::SeqCst))
            .count()
    }

    /// Fetch a metrics snapshot from every healthy shard over fresh
    /// short-lived connections (the long-lived links stay dedicated to
    /// solve traffic).
    pub fn metrics(&self) -> Result<DistMetricsReport> {
        let mut shards = Vec::new();
        for s in &self.inner.shards {
            if !s.healthy.load(Ordering::SeqCst) {
                continue;
            }
            let mut c = connect_retry(&s.addr, &self.inner.transport)
                .with_context(|| format!("dial shard {} for metrics", s.addr))?;
            send_frame(&mut c, &obj(vec![("kind", "metrics".into())]))?;
            let m = recv_frame(&mut c)?;
            let snap = MetricsSnapshot::from_json(m.get("snapshot")?)
                .with_context(|| format!("metrics snapshot from {}", s.addr))?;
            shards.push((s.addr.clone(), snap));
        }
        Ok(DistMetricsReport { shards })
    }

    /// Close every shard link and join the reader threads. Requests
    /// still pending when the links drop are fulfilled with
    /// [`ServeError::ShuttingDown`] by the readers' drain path.
    pub fn shutdown(&self) {
        for s in &self.inner.shards {
            s.healthy.store(false, Ordering::SeqCst);
            let _ = s.writer.lock().unwrap().shutdown(std::net::Shutdown::Both);
        }
        // Move the handles out first: joining while holding the readers
        // lock would block any concurrent shutdown caller on the mutex for
        // the whole join.
        let handles: Vec<JoinHandle<()>> = self.readers.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Dispatcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The dispatcher can sit directly behind the HTTP front door: submit
/// routes across the fleet, metrics merge shard snapshots bucket-exactly,
/// and spans are stamped off the injected clock.
impl SolveFrontend for Dispatcher {
    fn submit_front(&self, req: SolveRequest) -> Result<Waiter, ServeError> {
        let handle = self.submit(req)?;
        Ok(Box::new(move || handle.wait()))
    }

    fn metrics_front(&self) -> MetricsSnapshot {
        self.metrics().map(|r| r.totals()).unwrap_or_default()
    }

    fn now(&self) -> Duration {
        self.inner.clock.now()
    }
}

/// Per-link reader: decode correlated responses and fulfil their slots.
/// On EOF (shard death or dispatcher shutdown) drain the link's pending
/// map and re-dispatch every orphan to the survivors; with none left,
/// fail the orphans with `ShuttingDown` so no waiter hangs.
fn reader_loop(inner: &Inner, idx: usize, mut stream: TcpStream) {
    let shard = &inner.shards[idx];
    loop {
        let msg = match recv_frame(&mut stream) {
            Ok(m) => m,
            Err(_) => break,
        };
        if !matches!(msg.opt("kind"), Some(Json::Str(k)) if k == "resp") {
            continue;
        }
        let Ok(id) = msg.get("id").and_then(Json::as_usize) else {
            continue;
        };
        let Some(entry) = shard.pending.lock().unwrap().remove(&(id as u64)) else {
            continue; // already failed over; late answer loses the race
        };
        // Piggybacked shard-side spans join the local store BEFORE the
        // waiter is fulfilled, so the stitched trace is complete by the
        // time the requester wakes. Spans the shard left untagged get
        // this link's shard index.
        if let Some(spans_json) = msg.opt("spans") {
            let mut spans = obs::spans_from_json(spans_json);
            for s in &mut spans {
                if s.shard < 0 {
                    s.shard = idx as i64;
                }
            }
            obs::global().ingest(&spans);
        }
        let ok = matches!(msg.opt("ok"), Some(Json::Bool(true)));
        let result = if ok {
            match msg.get("resp").and_then(SolveResponse::from_json) {
                Ok(r) => Ok(r),
                Err(e) => Err(ServeError::Solver(format!("undecodable response: {e}"))),
            }
        } else {
            match msg.get("err").and_then(ServeError::from_json) {
                Ok(e) => Err(e),
                Err(e) => Err(ServeError::Solver(format!("undecodable error frame: {e}"))),
            }
        };
        entry.slot.fulfill(result);
    }
    shard.healthy.store(false, Ordering::SeqCst);
    let orphans: Vec<PendingEntry> = {
        let mut pending = shard.pending.lock().unwrap();
        let ids: Vec<u64> = pending.keys().copied().collect();
        ids.into_iter().filter_map(|id| pending.remove(&id)).collect()
    };
    for e in orphans {
        if let Some(ctx) = e.req.trace {
            obs::record(SpanRec::event(ctx, obs::FAILOVER, inner.clock.now()));
            obs::publish();
        }
        if inner.dispatch(e.req, e.slot.clone()).is_err() {
            e.slot.fulfill(Err(ServeError::ShuttingDown));
        }
    }
}

/// Per-shard snapshots plus a fleet-wide aggregate.
pub struct DistMetricsReport {
    pub shards: Vec<(String, MetricsSnapshot)>,
}

impl DistMetricsReport {
    /// Merge the shard snapshots into one fleet view. Counters add;
    /// means are count-weighted; latency summaries carry their raw
    /// histogram bucket counts across the wire, so the merge is
    /// **bucket-wise exact** ([`LatencySummary::merge`]): a fleet p99 is
    /// bit-identical to the p99 of one histogram fed every shard's
    /// stream, not a lossy max-bound over pre-computed floats.
    ///
    /// [`LatencySummary::merge`]: crate::serve::metrics::LatencySummary::merge
    pub fn totals(&self) -> MetricsSnapshot {
        let mut t = MetricsSnapshot::default();
        let mut batch_weight = 0.0f64;
        for (_, m) in &self.shards {
            t.submitted += m.submitted;
            t.completed += m.completed;
            t.rejected += m.rejected;
            t.failed += m.failed;
            t.batches += m.batches;
            t.nfe_total += m.nfe_total;
            t.nfe_max = t.nfe_max.max(m.nfe_max);
            t.http_conns_accepted += m.http_conns_accepted;
            t.http_conns_active += m.http_conns_active;
            t.http_conns_reused += m.http_conns_reused;
            t.http_reqs_per_conn = t.http_reqs_per_conn.merge(&m.http_reqs_per_conn);
            batch_weight += m.mean_batch_size * m.batches as f64;
            if m.batch_sizes.len() > t.batch_sizes.len() {
                t.batch_sizes.resize(m.batch_sizes.len(), 0);
            }
            for (slot, c) in t.batch_sizes.iter_mut().zip(&m.batch_sizes) {
                *slot += c;
            }
            t.queue_wait = t.queue_wait.merge(&m.queue_wait);
            t.service = t.service.merge(&m.service);
            // Per-tenant fairness summaries merge key-wise with the same
            // bucket-exact kernel as the global summaries.
            for (k, l) in &m.per_key_queue_wait {
                match t.per_key_queue_wait.iter_mut().find(|(tk, _)| tk == k) {
                    Some((_, tl)) => *tl = tl.merge(l),
                    None => t.per_key_queue_wait.push((k.clone(), l.clone())),
                }
            }
        }
        t.per_key_queue_wait.sort_by(|a, b| a.0.cmp(&b.0));
        t.mean_batch_size = if t.batches > 0 { batch_weight / t.batches as f64 } else { 0.0 };
        t.nfe_mean = if t.completed > 0 { t.nfe_total as f64 / t.completed as f64 } else { 0.0 };
        t
    }
}

impl std::fmt::Display for DistMetricsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (addr, m) in &self.shards {
            writeln!(f, "-- shard {addr} --")?;
            write!(f, "{m}")?;
        }
        writeln!(f, "-- fleet ({} shards) --", self.shards.len())?;
        write!(f, "{}", self.totals())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::tableau;
    use crate::serve::metrics::{LatencySummary, LogHistogram};
    use crate::serve::request::{Lane, Tolerance};

    fn req(dynamics: &str, rtol: f64) -> SolveRequest {
        SolveRequest {
            dynamics: dynamics.to_string(),
            t0: 0.0,
            t1: 1.0,
            z0: vec![1.0, 0.0],
            tab: tableau::by_name("rk45").unwrap(),
            tol: Tolerance::Adaptive { rtol, atol: 1e-6 },
            grad: None,
            observe_at: Vec::new(),
            lane: Lane::Interactive,
            trace: None,
        }
    }

    #[test]
    fn key_hash_is_stable_and_field_sensitive() {
        let a = key_hash(&req("vdp", 1e-3).batch_key());
        assert_eq!(a, key_hash(&req("vdp", 1e-3).batch_key()), "same key, same hash");
        assert_ne!(a, key_hash(&req("linear", 1e-3).batch_key()), "dynamics");
        assert_ne!(a, key_hash(&req("vdp", 1e-4).batch_key()), "tolerance");
        let mut g = req("vdp", 1e-3);
        g.grad = Some(vec![1.0, 0.0]);
        assert_ne!(a, key_hash(&g.batch_key()), "grad flag");
        let mut o = req("vdp", 1e-3);
        o.observe_at = vec![0.5];
        assert_ne!(a, key_hash(&o.batch_key()), "dense-output flag");
        let mut b = req("vdp", 1e-3);
        b.lane = Lane::Batch;
        assert_ne!(a, key_hash(&b.batch_key()), "priority lane");
    }

    #[test]
    fn route_prefers_the_hash_shard_until_the_margin_trips() {
        let loads = vec![(0, 10), (1, 0), (2, 3)];
        // hash 3 % 3 == 0 -> shard 0, which is 10 ahead of shard 1.
        assert_eq!(route(3, &loads, 8), 1, "steals to the least loaded");
        assert_eq!(route(3, &loads, 11), 0, "margin not reached: stays");
        assert_eq!(route(3, &loads, 0), 0, "margin 0 disables stealing");
        // hash 4 % 3 == 1 -> already the least loaded shard.
        assert_eq!(route(4, &loads, 1), 1);
    }

    #[test]
    fn route_contracts_deterministically_when_shards_die() {
        // Healthy set {0,2}: position hash%2 indexes into the survivors.
        let survivors = vec![(0, 0), (2, 0)];
        assert_eq!(route(6, &survivors, 8), 0);
        assert_eq!(route(7, &survivors, 8), 2);
        // Load ties steal to the lowest index (stable min).
        let tied = vec![(0, 5), (1, 1), (2, 1)];
        assert_eq!(route(0, &tied, 4), 1);
    }

    /// A summary built the same way a live shard builds one: every value
    /// through a [`LogHistogram`], then `from_parts` over its raw state.
    fn lat(values_ns: &[u64]) -> LatencySummary {
        let h = LogHistogram::default();
        for &v in values_ns {
            h.record(v);
        }
        LatencySummary::from_parts(h.count(), h.sum(), h.max(), h.bucket_counts())
    }

    /// The satellite regression: merging two shards' summaries of
    /// disjoint streams is **bit-identical** to one histogram fed both
    /// streams — quantiles included, not a max-bound.
    #[test]
    fn two_shard_merge_equals_single_histogram_fed_both_streams() {
        let stream_a: Vec<u64> = (1..=40u64).map(|i| i * 130_000).collect();
        let stream_b: Vec<u64> = (1..=15u64).map(|i| i * i * 1_900_000).collect();
        let a = MetricsSnapshot { queue_wait: lat(&stream_a), ..MetricsSnapshot::default() };
        let b = MetricsSnapshot { queue_wait: lat(&stream_b), ..MetricsSnapshot::default() };
        let report = DistMetricsReport { shards: vec![("a".into(), a), ("b".into(), b)] };
        let merged = report.totals().queue_wait;

        let both: Vec<u64> = stream_a.iter().chain(&stream_b).copied().collect();
        assert_eq!(merged, lat(&both), "fleet summary == single-histogram summary");
        assert!(merged.p99_ms > 0.0, "non-degenerate quantiles");
    }

    #[test]
    fn totals_aggregate_across_shards() {
        let vdp_a = [2_000_000u64; 5];
        let vdp_b = [9_000_000u64];
        let a = MetricsSnapshot {
            submitted: 10,
            completed: 8,
            batches: 4,
            mean_batch_size: 2.0,
            batch_sizes: vec![0, 1, 3],
            nfe_total: 80,
            nfe_max: 20,
            http_conns_accepted: 3,
            http_conns_reused: 1,
            per_key_queue_wait: vec![
                ("linear".into(), lat(&[1_000_000; 3])),
                ("vdp".into(), lat(&vdp_a)),
            ],
            ..MetricsSnapshot::default()
        };
        let b = MetricsSnapshot {
            submitted: 6,
            completed: 4,
            batches: 2,
            mean_batch_size: 2.0,
            batch_sizes: vec![0, 0, 1, 1],
            nfe_total: 100,
            nfe_max: 50,
            http_conns_accepted: 2,
            per_key_queue_wait: vec![("vdp".into(), lat(&vdp_b))],
            ..MetricsSnapshot::default()
        };
        let report = DistMetricsReport { shards: vec![("a".into(), a), ("b".into(), b)] };
        let t = report.totals();
        assert_eq!(t.submitted, 16);
        assert_eq!(t.completed, 12);
        assert_eq!(t.batches, 6);
        assert!((t.mean_batch_size - 2.0).abs() < 1e-12);
        assert_eq!(t.batch_sizes, vec![0, 1, 4, 1]);
        assert_eq!(t.nfe_total, 180);
        assert_eq!(t.nfe_max, 50);
        assert!((t.nfe_mean - 15.0).abs() < 1e-12);
        assert_eq!(t.http_conns_accepted, 5, "door counters add across shards");
        assert_eq!(t.http_conns_reused, 1);
        assert_eq!(t.per_key_queue_wait.len(), 2, "per-tenant entries merge key-wise");
        assert_eq!(t.per_key_queue_wait[0].0, "linear");
        assert_eq!(t.per_key_queue_wait[0].1.count, 3);
        assert_eq!(t.per_key_queue_wait[1].0, "vdp");
        assert_eq!(t.per_key_queue_wait[1].1.count, 6, "vdp counts add across shards");
        let vdp_both: Vec<u64> = vdp_a.iter().chain(&vdp_b).copied().collect();
        assert_eq!(t.per_key_queue_wait[1].1, lat(&vdp_both), "per-tenant merge is exact");
    }
}
