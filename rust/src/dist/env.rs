//! `NODAL_DIST_*` knob parsing — the **single** parse-and-clamp source for
//! the distributed subsystem.
//!
//! The env-knob rule (lib.rs "Invariants", rule 1) requires every
//! environment read to happen in a designated helper next to its clamping
//! logic. For `dist/` those helpers are [`DistConfig::from_env`] and the
//! shared [`env_usize`] below; nothing else in the subsystem may touch the
//! environment, and `nodal-lint` enforces exactly that.

/// Hard cap on world size. Far above any realistic deployment of this
/// trainer; exists so a corrupt `NODAL_DIST_WORLD_SIZE` cannot make rank 0
/// wait on thousands of peers that will never call in.
pub const MAX_WORLD: usize = 256;

/// Default coordinator port when `NODAL_DIST_PORT` is unset.
pub const DEFAULT_PORT: u16 = 7117;

/// Identity of one process in a distributed run, parsed from the
/// `NODAL_DIST_{RANK,WORLD_SIZE,PORT,HOSTS}` knobs.
///
/// Rank 0 is always the coordinator: it binds the listener, owns the
/// reduction, and is the only rank whose death is fatal to the step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DistConfig {
    /// This process's rank in `0..world_size`.
    pub rank: usize,
    /// Number of cooperating processes; `1` means fully local (no sockets).
    pub world_size: usize,
    /// TCP port the rank-0 coordinator listens on.
    pub port: u16,
    /// Host list, index-aligned with ranks; empty means single-host
    /// loopback. Only `hosts[0]` (the coordinator address) is dialed today;
    /// the rest are recorded for a future hostfile launcher.
    pub hosts: Vec<String>,
}

impl Default for DistConfig {
    fn default() -> Self {
        DistConfig::local()
    }
}

impl DistConfig {
    /// The single-process default: a world of one, loopback, no sockets.
    pub fn local() -> Self {
        DistConfig { rank: 0, world_size: 1, port: DEFAULT_PORT, hosts: Vec::new() }
    }

    /// Read and clamp the `NODAL_DIST_*` knobs (see the lib.rs knob table).
    /// Unset or unparseable values fall back to the single-process
    /// defaults; `rank` is clamped into `0..world_size` so a stray rank can
    /// never address a slot outside the configured world.
    pub fn from_env() -> Self {
        let world_size = env_usize("NODAL_DIST_WORLD_SIZE", 1, 1, MAX_WORLD);
        let rank = env_usize("NODAL_DIST_RANK", 0, 0, world_size - 1);
        let port = env_usize("NODAL_DIST_PORT", DEFAULT_PORT as usize, 1, 65535) as u16;
        let hosts = match std::env::var("NODAL_DIST_HOSTS") {
            Ok(v) => v
                .split(',')
                .map(str::trim)
                .filter(|h| !h.is_empty())
                .map(String::from)
                .collect(),
            Err(_) => Vec::new(),
        };
        DistConfig { rank, world_size, port, hosts }
    }

    /// Address of the rank-0 coordinator: `hosts[0]` if a host list was
    /// given, loopback otherwise.
    pub fn root_addr(&self) -> String {
        let host = self.hosts.first().map_or("127.0.0.1", String::as_str);
        format!("{host}:{}", self.port)
    }
}

/// Parse-and-clamp one `usize` knob at the source (the same shape as
/// `serve::mod`'s `env_clamped`; duplicated rather than shared so each
/// subsystem's designated helper stays next to its own clamping policy).
fn env_usize(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(lo, hi),
        None => default,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One test mutates every `NODAL_DIST_*` var (tests within a binary may
    /// run concurrently, so the env mutations live in a single test).
    #[test]
    fn from_env_parses_and_clamps_every_knob() {
        let keys =
            ["NODAL_DIST_RANK", "NODAL_DIST_WORLD_SIZE", "NODAL_DIST_PORT", "NODAL_DIST_HOSTS"];
        for k in keys {
            std::env::remove_var(k);
        }
        let d = DistConfig::from_env();
        assert_eq!(d, DistConfig::local(), "unset env must yield the local default");

        std::env::set_var("NODAL_DIST_WORLD_SIZE", "4");
        std::env::set_var("NODAL_DIST_RANK", "2");
        std::env::set_var("NODAL_DIST_PORT", "9001");
        std::env::set_var("NODAL_DIST_HOSTS", " a.local , b.local,,c.local ");
        let d = DistConfig::from_env();
        assert_eq!(d.world_size, 4);
        assert_eq!(d.rank, 2);
        assert_eq!(d.port, 9001);
        assert_eq!(d.hosts, vec!["a.local", "b.local", "c.local"]);
        assert_eq!(d.root_addr(), "a.local:9001");

        // Out-of-range values clamp instead of erroring.
        std::env::set_var("NODAL_DIST_WORLD_SIZE", "100000");
        std::env::set_var("NODAL_DIST_RANK", "100000");
        std::env::set_var("NODAL_DIST_PORT", "0");
        let d = DistConfig::from_env();
        assert_eq!(d.world_size, MAX_WORLD);
        assert_eq!(d.rank, MAX_WORLD - 1, "rank clamps into the world");
        assert_eq!(d.port, 1);

        // Garbage falls back to defaults.
        std::env::set_var("NODAL_DIST_WORLD_SIZE", "not-a-number");
        std::env::set_var("NODAL_DIST_RANK", "-3");
        std::env::set_var("NODAL_DIST_PORT", "");
        std::env::set_var("NODAL_DIST_HOSTS", " , ,");
        let d = DistConfig::from_env();
        assert_eq!(d.world_size, 1);
        assert_eq!(d.rank, 0);
        assert_eq!(d.port, DEFAULT_PORT);
        assert!(d.hosts.is_empty(), "blank host entries are dropped");
        assert_eq!(d.root_addr(), format!("127.0.0.1:{DEFAULT_PORT}"));

        for k in keys {
            std::env::remove_var(k);
        }
    }
}
