//! Length-prefixed JSON framing over `TcpStream` — the one transport both
//! halves of `dist/` share.
//!
//! A frame is a 4-byte big-endian byte length followed by that many bytes
//! of compact JSON (`util::json`). Float payloads travel as `f32` bit
//! patterns (`util::json::f32_bits`): a `u32` is exact in a JSON number,
//! so states and gradients cross the wire bit-exactly — including NaN and
//! the infinities, which the plain number grammar cannot carry (the codec
//! writes non-finite numbers as `null` by policy).
//!
//! Determinism note: nothing here reads a wall clock. Deadlines are
//! expressed through socket timeouts (`set_read_timeout`) and bounded
//! retry loops with `thread::sleep` backoff, so the module stays clean
//! under the repo-wide `Instant::now` ban.
//!
//! Trace context rides *inside* the framed messages, not in the framing:
//! a solve frame's request body carries the optional `trace` /
//! `trace_parent` / `trace_shard` fields (see `serve::request`), and a
//! shard's `resp` frame may carry a `"spans"` array of integer/hex-only
//! span objects (see [`crate::obs`]) — both stay within the
//! wire-determinism rule because no float fields are involved.

use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Hard cap on one frame's byte length. A corrupt or hostile length prefix
/// must not trigger a multi-gigabyte allocation before the body arrives.
pub const MAX_FRAME_BYTES: usize = 64 * 1024 * 1024;

/// Connection and IO policy shared by the trainer and the dispatcher.
#[derive(Debug, Clone)]
pub struct TransportOpts {
    /// Connect attempts before giving up. Workers routinely start before
    /// the coordinator's listener is up, so the default is generous.
    pub connect_attempts: usize,
    /// Base delay between connect attempts; grows linearly with the
    /// attempt number, capped at 8× the base.
    pub backoff: Duration,
    /// Read/connect timeout applied to established connections — the
    /// peer-death backstop for a peer that stalls without closing its
    /// socket.
    pub io_timeout: Duration,
}

impl Default for TransportOpts {
    fn default() -> Self {
        TransportOpts {
            connect_attempts: 40,
            backoff: Duration::from_millis(25),
            io_timeout: Duration::from_secs(30),
        }
    }
}

/// Serialize one message to its framed byte form (length prefix + body).
/// Splitting encode from write lets callers do the serialization work
/// outside any lock and hold a writer guard only for the socket write.
pub fn encode_frame(msg: &Json) -> Result<Vec<u8>> {
    let body = msg.to_string();
    ensure!(body.len() <= MAX_FRAME_BYTES, "frame of {} bytes exceeds cap", body.len());
    let mut bytes = Vec::with_capacity(4 + body.len());
    bytes.extend_from_slice(&(body.len() as u32).to_be_bytes());
    bytes.extend_from_slice(body.as_bytes());
    Ok(bytes)
}

/// Write pre-encoded frame bytes and flush them. One `write_all` keeps the
/// frame a single atomic unit from the caller's perspective.
pub fn write_frame_bytes<W: Write>(w: &mut W, bytes: &[u8]) -> Result<()> {
    w.write_all(bytes).context("frame write")?;
    w.flush().context("frame flush")?;
    Ok(())
}

/// Write one framed message and flush it (encode + write in one step, for
/// callers with exclusive stream access).
pub fn send_frame<W: Write>(w: &mut W, msg: &Json) -> Result<()> {
    let bytes = encode_frame(msg)?;
    write_frame_bytes(w, &bytes)
}

/// Read one framed message, blocking up to the stream's read timeout.
pub fn recv_frame<R: Read>(r: &mut R) -> Result<Json> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len).context("frame header read")?;
    let n = u32::from_be_bytes(len) as usize;
    ensure!(n <= MAX_FRAME_BYTES, "frame of {n} bytes exceeds cap");
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("frame body read")?;
    let txt = std::str::from_utf8(&buf).context("frame is not UTF-8")?;
    Json::parse(txt)
}

/// Connect with bounded retry and linear backoff (workers racing the
/// coordinator's bind), then apply the IO timeouts to the stream.
pub fn connect_retry(addr: &str, opts: &TransportOpts) -> Result<TcpStream> {
    let attempts = opts.connect_attempts.max(1);
    let mut last: Option<std::io::Error> = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            std::thread::sleep(opts.backoff.saturating_mul(attempt.min(8) as u32));
        }
        let resolved: Vec<_> = match addr.to_socket_addrs() {
            Ok(it) => it.collect(),
            Err(e) => {
                last = Some(e);
                continue;
            }
        };
        for a in &resolved {
            match TcpStream::connect_timeout(a, opts.io_timeout) {
                Ok(s) => {
                    // Small framed messages: batching hurts latency more
                    // than it saves bytes. Timeout-set failures are not
                    // fatal; the read path degrades to blocking.
                    let _ = s.set_nodelay(true);
                    let _ = s.set_read_timeout(Some(opts.io_timeout));
                    let _ = s.set_write_timeout(Some(opts.io_timeout));
                    return Ok(s);
                }
                Err(e) => last = Some(e),
            }
        }
    }
    bail!("connect to {addr} failed after {attempts} attempts: {last:?}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{f32_bits, f32s_from_bits, obj};
    use std::io::Cursor;
    use std::net::TcpListener;

    #[test]
    fn frame_round_trips_in_memory() {
        let msg = obj(vec![
            ("kind", "step".into()),
            ("attempt", 3usize.into()),
            ("bits", f32_bits(&[1.5, -0.0, f32::NAN, f32::INFINITY, 1e-45])),
        ]);
        let mut buf = Vec::new();
        send_frame(&mut buf, &msg).unwrap();
        assert_eq!(&buf[..4], &(u32::try_from(buf.len() - 4).unwrap()).to_be_bytes());
        let back = recv_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(back.get("kind").unwrap().as_str().unwrap(), "step");
        let bits = f32s_from_bits(back.get("bits").unwrap()).unwrap();
        let want = [1.5f32, -0.0, f32::NAN, f32::INFINITY, 1e-45];
        let got: Vec<u32> = bits.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp, "f32 payloads must round-trip bit-exactly, NaN included");
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        buf.extend_from_slice(b"junk");
        let err = recv_frame(&mut Cursor::new(&buf)).unwrap_err();
        assert!(err.to_string().contains("exceeds cap"), "{err}");
    }

    #[test]
    fn truncated_body_is_an_error_not_a_hang() {
        let msg = Json::from("hello");
        let mut buf = Vec::new();
        send_frame(&mut buf, &msg).unwrap();
        buf.truncate(buf.len() - 2);
        assert!(recv_frame(&mut Cursor::new(&buf)).is_err());
    }

    #[test]
    fn frame_round_trips_over_loopback_tcp() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let echo = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let msg = recv_frame(&mut s).unwrap();
            send_frame(&mut s, &msg).unwrap();
        });
        let opts = TransportOpts { io_timeout: Duration::from_secs(5), ..Default::default() };
        let mut s = connect_retry(&addr, &opts).unwrap();
        let msg = obj(vec![("rank", 1usize.into()), ("bits", f32_bits(&[0.1, 0.2, 0.3]))]);
        send_frame(&mut s, &msg).unwrap();
        let back = recv_frame(&mut s).unwrap();
        assert_eq!(back, msg);
        echo.join().unwrap();
    }

    #[test]
    fn connect_retry_gives_up_with_bounded_attempts() {
        // Bind-then-drop: the port existed but nothing listens on it now.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let opts = TransportOpts {
            connect_attempts: 2,
            backoff: Duration::from_millis(1),
            io_timeout: Duration::from_millis(200),
        };
        let err = connect_retry(&addr, &opts).unwrap_err();
        assert!(err.to_string().contains("after 2 attempts"), "{err}");
    }
}
