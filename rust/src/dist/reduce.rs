//! Deterministic gradient reduction.
//!
//! Floating-point addition is not associative, so "sum the ranks'
//! gradients" is only reproducible if the association order is pinned.
//! This module fixes it structurally: partials are stored by **rank slot**
//! (never by arrival order) and combined by [`tree_combine`], an
//! adjacent-pairwise binary tree over those slots. The result is
//! bit-identical run to run, independent of message timing, and equal to
//! what a single process computes when it folds the same per-shard
//! partials through the same tree (`train::grad_accum_reference`).
//!
//! Small parameter leaves are bucketed into shared payloads below
//! [`DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES`] so a model with many tiny
//! tensors does not pay one frame per tensor.

use crate::util::json::{f32_bits, f32s_from_bits, obj, Json};
use anyhow::{ensure, Result};

/// Leaves smaller than this are packed together into one wire payload;
/// leaves at or above it travel alone.
pub const DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES: usize = 64 * 1024;

/// One named gradient tensor (flattened), the unit of reduction.
#[derive(Debug, Clone, PartialEq)]
pub struct GradLeaf {
    pub name: String,
    pub values: Vec<f32>,
}

impl GradLeaf {
    pub fn new(name: &str, values: Vec<f32>) -> Self {
        GradLeaf { name: name.to_string(), values }
    }

    /// Wire size of the values payload.
    pub fn bytes(&self) -> usize {
        self.values.len() * std::mem::size_of::<f32>()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![("name", self.name.as_str().into()), ("bits", f32_bits(&self.values))])
    }

    pub fn from_json(v: &Json) -> Result<GradLeaf> {
        Ok(GradLeaf {
            name: v.get("name")?.as_str()?.to_string(),
            values: f32s_from_bits(v.get("bits")?)?,
        })
    }
}

/// `acc[i] += rhs[i]` — the single elementwise combine both reduction
/// orders are built from.
pub fn add_into(acc: &mut [f32], rhs: &[f32]) {
    debug_assert_eq!(acc.len(), rhs.len());
    for (a, r) in acc.iter_mut().zip(rhs) {
        *a += *r;
    }
}

/// Combine rank partials with a **fixed adjacent-pairwise tree**: round 1
/// sums slots (0,1), (2,3), …; round 2 sums the survivors pairwise again;
/// an odd tail carries to the next round unchanged. The association
/// depends only on the number of slots, never on arrival timing.
///
/// All partials must share one length; panics on empty input (a reduction
/// over zero ranks is a caller bug, not a runtime condition).
pub fn tree_combine(partials: &[Vec<f32>]) -> Vec<f32> {
    assert!(!partials.is_empty(), "tree_combine over zero partials");
    let mut round: Vec<Vec<f32>> = partials.to_vec();
    while round.len() > 1 {
        let mut next = Vec::with_capacity(round.len().div_ceil(2));
        let mut it = round.into_iter();
        while let Some(mut left) = it.next() {
            if let Some(right) = it.next() {
                add_into(&mut left, &right);
            }
            next.push(left);
        }
        round = next;
    }
    round.remove(0)
}

/// Left-to-right sequential fold — the flat baseline [`tree_combine`] is
/// benchmarked and contrasted against. Same determinism (fixed order),
/// different association: for more than two slots the two generally
/// differ in the low bits, which is exactly why the association must be
/// part of the protocol.
pub fn flat_combine(partials: &[Vec<f32>]) -> Vec<f32> {
    assert!(!partials.is_empty(), "flat_combine over zero partials");
    // index 0 in bounds: non-emptiness asserted above
    let mut acc = partials[0].clone();
    for p in &partials[1..] {
        add_into(&mut acc, p);
    }
    acc
}

/// Greedily pack leaf indices into payload groups, preserving leaf order:
/// a leaf at or above `threshold_bytes` travels alone; consecutive small
/// leaves share a group until adding the next would cross the threshold.
pub fn bucket_leaves(leaves: &[GradLeaf], threshold_bytes: usize) -> Vec<Vec<usize>> {
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut cur_bytes = 0usize;
    for (i, leaf) in leaves.iter().enumerate() {
        let b = leaf.bytes();
        if b >= threshold_bytes {
            if !cur.is_empty() {
                groups.push(std::mem::take(&mut cur));
                cur_bytes = 0;
            }
            groups.push(vec![i]);
            continue;
        }
        if !cur.is_empty() && cur_bytes + b > threshold_bytes {
            groups.push(std::mem::take(&mut cur));
            cur_bytes = 0;
        }
        cur.push(i);
        cur_bytes += b;
    }
    if !cur.is_empty() {
        groups.push(cur);
    }
    groups
}

/// Serialize a group of leaves as one payload frame body.
pub fn leaves_to_json(leaves: &[GradLeaf]) -> Json {
    Json::Arr(leaves.iter().map(GradLeaf::to_json).collect())
}

/// Decode [`leaves_to_json`].
pub fn leaves_from_json(v: &Json) -> Result<Vec<GradLeaf>> {
    v.as_arr()?.iter().map(GradLeaf::from_json).collect()
}

/// Tree-combine per-rank leaf sets (each rank's leaves in identical
/// name order). Errors on shape mismatch between ranks.
pub fn tree_combine_leaves(per_rank: &[Vec<GradLeaf>]) -> Result<Vec<GradLeaf>> {
    ensure!(!per_rank.is_empty(), "reduction over zero ranks");
    // index 0 in bounds: non-emptiness ensured above
    let first = &per_rank[0];
    for (r, leaves) in per_rank.iter().enumerate() {
        ensure!(
            leaves.len() == first.len(),
            "rank slot {r} has {} leaves, slot 0 has {}",
            leaves.len(),
            first.len()
        );
    }
    let mut out = Vec::with_capacity(first.len());
    for (j, proto) in first.iter().enumerate() {
        let mut slots = Vec::with_capacity(per_rank.len());
        for (r, leaves) in per_rank.iter().enumerate() {
            let leaf = &leaves[j];
            ensure!(
                leaf.name == proto.name && leaf.values.len() == proto.values.len(),
                "rank slot {r} leaf {j} ({}, n={}) does not match slot 0 ({}, n={})",
                leaf.name,
                leaf.values.len(),
                proto.name,
                proto.values.len()
            );
            slots.push(leaf.values.clone());
        }
        out.push(GradLeaf { name: proto.name.clone(), values: tree_combine(&slots) });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(name: &str, n: usize, scale: f32) -> GradLeaf {
        GradLeaf::new(name, (0..n).map(|i| scale * (i as f32 + 1.0)).collect())
    }

    #[test]
    fn tree_matches_manual_association_for_four_slots() {
        let p: Vec<Vec<f32>> = vec![vec![0.1, 1.0], vec![0.2, 2.0], vec![0.3, 3.0], vec![0.4, 4.0]];
        let got = tree_combine(&p);
        // ((p0+p1)+(p2+p3)), elementwise, in f32.
        let mut want = Vec::new();
        for i in 0..2 {
            want.push((p[0][i] + p[1][i]) + (p[2][i] + p[3][i]));
        }
        assert_eq!(
            got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn tree_handles_odd_world_sizes() {
        // Slots (0,1),(2,3),(4) -> ((01),(23)),(4) -> (((01)(23)),4).
        let p: Vec<Vec<f32>> = (0..5).map(|r| vec![(r as f32) + 0.5]).collect();
        let got = tree_combine(&p)[0];
        let want = ((p[0][0] + p[1][0]) + (p[2][0] + p[3][0])) + p[4][0];
        assert_eq!(got.to_bits(), want.to_bits());
        // World of one and two degenerate to identity and a single add.
        assert_eq!(tree_combine(&p[..1]), p[0]);
        let two = tree_combine(&p[..2])[0];
        assert_eq!(two.to_bits(), (p[0][0] + p[1][0]).to_bits());
    }

    #[test]
    fn association_actually_matters_in_f32() {
        // 1e8 swallows a unit in f32, so the tree and the flat fold give
        // different bits — the reason the association is part of the
        // protocol, not an implementation detail.
        let p: Vec<Vec<f32>> = vec![vec![1e8], vec![1.0], vec![-1e8], vec![1.0]];
        let tree = tree_combine(&p)[0]; // (1e8+1) + (-1e8+1) = 0.0
        let flat = flat_combine(&p)[0]; // ((1e8+1)-1e8) + 1 = 1.0
        assert_eq!(tree, 0.0);
        assert_eq!(flat, 1.0);
        // And each is individually deterministic across repeats.
        assert_eq!(tree.to_bits(), tree_combine(&p)[0].to_bits());
        assert_eq!(flat.to_bits(), flat_combine(&p)[0].to_bits());
    }

    #[test]
    fn bucketing_packs_small_leaves_and_isolates_large_ones() {
        let thr = DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES;
        let small = thr / 4 / 4; // floats per quarter-threshold leaf
        let leaves = vec![
            leaf("w1", small, 1.0),
            leaf("b1", small, 1.0),
            leaf("big", thr / 4 + 1, 1.0), // >= threshold bytes: alone
            leaf("w2", small, 1.0),
            leaf("b2", small, 1.0),
            leaf("w3", small, 1.0),
            leaf("b3", small, 1.0),
            leaf("b4", small, 1.0), // fifth quarter spills a new group
        ];
        let groups = bucket_leaves(&leaves, thr);
        assert_eq!(groups, vec![vec![0, 1], vec![2], vec![3, 4, 5, 6], vec![7]]);
        // Order is preserved across the flattened groups.
        let flat: Vec<usize> = groups.concat();
        assert_eq!(flat, (0..leaves.len()).collect::<Vec<_>>());
        // Degenerate threshold: everything travels alone.
        assert_eq!(bucket_leaves(&leaves, 0).len(), leaves.len());
        assert!(bucket_leaves(&[], thr).is_empty());
    }

    #[test]
    fn leaf_groups_round_trip_bit_exactly() {
        let mut a = leaf("dl_dtheta", 7, 0.3);
        a.values[2] = f32::NAN;
        a.values[5] = -0.0;
        let b = leaf("aux", 3, -2.0);
        let j = leaves_to_json(&[a.clone(), b.clone()]);
        let back = leaves_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].name, "dl_dtheta");
        let got: Vec<u32> = back[0].values.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = a.values.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp, "NaN and -0.0 must survive the wire");
        assert_eq!(back[1], b);
    }

    #[test]
    fn leaf_reduction_validates_shapes() {
        let ok = tree_combine_leaves(&[
            vec![leaf("a", 2, 1.0), leaf("b", 3, 1.0)],
            vec![leaf("a", 2, 2.0), leaf("b", 3, 2.0)],
        ])
        .unwrap();
        assert_eq!(ok[0].values, vec![3.0, 6.0]);
        assert_eq!(ok[1].name, "b");
        let bad = tree_combine_leaves(&[
            vec![leaf("a", 2, 1.0)],
            vec![leaf("a", 3, 1.0)], // wrong length
        ]);
        assert!(bad.is_err());
        let bad = tree_combine_leaves(&[vec![leaf("a", 2, 1.0)], vec![]]);
        assert!(bad.is_err());
    }
}
