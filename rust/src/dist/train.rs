//! Deterministic data-parallel training over TCP.
//!
//! SPMD layout: every rank holds the same [`StepSpec`] (dynamics, solver,
//! full mini-batch) and computes the gradient of its own contiguous shard
//! locally with `integrate_batch_tspans` + shared-stage `backward_batch`.
//! Rank 0 is the coordinator: it collects the per-rank partials **by rank
//! slot** and combines them with [`super::reduce::tree_combine_leaves`],
//! so the association order is a function of the membership alone — never
//! of message arrival — and the reduced gradient is bit-identical run to
//! run and bit-identical to [`grad_accum_reference`] computed in a single
//! process (the engine's batch-composition invariance makes per-sample
//! gradients independent of how the batch is sharded).
//!
//! Failure model: worker death (EOF, timeout, send failure) is detected by
//! rank 0, which evicts the peer, re-broadcasts the step with a bumped
//! `attempt` tag, and re-partitions the batch deterministically over the
//! survivors. Stale partials are discarded by their attempt tag. Rank 0's
//! own death fails the step — there is deliberately no election.

use super::env::DistConfig;
use super::reduce::{
    bucket_leaves, leaves_from_json, leaves_to_json, tree_combine_leaves, GradLeaf,
    DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES,
};
use super::transport::{connect_retry, recv_frame, send_frame, TransportOpts};
use crate::grad::{backward_batch, Method};
use crate::ode::batch::integrate_batch_tspans;
use crate::ode::{IntegrateOpts, OdeFunc, Tableau};
use crate::util::json::{obj, Json};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// One distributed training step's workload, identical on every rank.
pub struct StepSpec<'a> {
    pub f: &'a (dyn OdeFunc + Sync),
    pub tab: &'static Tableau,
    pub opts: IntegrateOpts,
    /// Per-sample integration spans (`B` entries each).
    pub t0s: Vec<f64>,
    pub t1s: Vec<f64>,
    /// Flattened initial states, `B × dim`.
    pub z0: Vec<f32>,
    /// Flattened loss seeds `∂L/∂z(t1)`, `B × dim`.
    pub lam: Vec<f32>,
}

impl StepSpec<'_> {
    pub fn n_samples(&self) -> usize {
        self.t0s.len()
    }

    pub fn validate(&self) -> Result<()> {
        let (b, d) = (self.n_samples(), self.f.dim());
        ensure!(b > 0, "empty batch");
        ensure!(self.t1s.len() == b, "t1s: {} spans for {b} samples", self.t1s.len());
        ensure!(self.z0.len() == b * d, "z0: {} values for {b}x{d}", self.z0.len());
        ensure!(self.lam.len() == b * d, "lam: {} values for {b}x{d}", self.lam.len());
        Ok(())
    }
}

/// One rank's contribution to the step.
#[derive(Debug, Clone, PartialEq)]
pub struct Partial {
    pub leaves: Vec<GradLeaf>,
    /// Total `f` evaluations spent (forward + backward + replay).
    pub nfe: usize,
    pub n_samples: usize,
}

/// The reduced result every surviving rank returns with.
#[derive(Debug, Clone)]
pub struct DistGrad {
    pub leaves: Vec<GradLeaf>,
    /// The membership (sorted ranks) that produced the result.
    pub members: Vec<usize>,
    /// Attempts the step took (1 = no failures).
    pub attempts: usize,
    /// Total `f` evaluations across all members.
    pub nfe: usize,
}

impl DistGrad {
    /// The reduced parameter gradient (empty if the model has no params).
    pub fn dl_dtheta(&self) -> &[f32] {
        self.leaves.iter().find(|l| l.name == "dl_dtheta").map_or(&[], |l| &l.values)
    }
}

/// Policy knobs for the rank-0 coordinator.
#[derive(Debug, Clone)]
pub struct RootOpts {
    pub transport: TransportOpts,
    /// How long rank 0 waits for the expected peers to call in; whoever
    /// misses the window is treated as dead-on-arrival.
    pub register_timeout: Duration,
    /// Membership-shrink retries before the step is declared failed.
    pub max_attempts: usize,
}

impl Default for RootOpts {
    fn default() -> Self {
        RootOpts {
            transport: TransportOpts::default(),
            register_timeout: Duration::from_secs(10),
            max_attempts: 8,
        }
    }
}

/// The contiguous sample range owned by membership position `pos` in a
/// world of `world` ranks: balanced partition, remainder spread over the
/// leading positions. Purely arithmetic, so every rank derives the same
/// partition from the membership without further communication.
pub fn shard_range(n: usize, world: usize, pos: usize) -> std::ops::Range<usize> {
    debug_assert!(pos < world);
    let base = n / world;
    let extra = n % world;
    let start = pos * base + pos.min(extra);
    let len = base + usize::from(pos < extra);
    start..start + len
}

/// Compute one shard's gradient locally: batched forward over the shard's
/// samples, shared-stage ACA backward, then a sequential in-order fold of
/// the per-sample `dl_dtheta` contributions (the same accumulation order
/// as `train::Trainer::loss_grad_accum`).
pub fn local_partial(spec: &StepSpec, range: std::ops::Range<usize>) -> Result<Partial> {
    let d = spec.f.dim();
    let n_params = spec.f.n_params();
    if range.is_empty() {
        // More ranks than samples: this shard holds nothing and its
        // partial is the additive identity.
        let leaves = vec![GradLeaf::new("dl_dtheta", vec![0.0; n_params])];
        return Ok(Partial { leaves, nfe: 0, n_samples: 0 });
    }
    let t0s = &spec.t0s[range.clone()];
    let t1s = &spec.t1s[range.clone()];
    let z0 = &spec.z0[range.start * d..range.end * d];
    let lam = &spec.lam[range.start * d..range.end * d];
    let traj = integrate_batch_tspans(spec.f, t0s, t1s, z0, spec.tab, &spec.opts)?;
    let grads = backward_batch(spec.f, spec.tab, &traj, lam, Method::Aca, &spec.opts)?;
    let mut dtheta = vec![0.0f32; n_params];
    let mut nfe = 0usize;
    for g in &grads {
        for (a, r) in dtheta.iter_mut().zip(&g.dl_dtheta) {
            *a += *r;
        }
        nfe += g.meter.nfe_forward + g.meter.nfe_backward + g.meter.nfe_replay;
    }
    let leaves = vec![GradLeaf::new("dl_dtheta", dtheta)];
    Ok(Partial { leaves, nfe, n_samples: range.len() })
}

/// The single-process baseline the distributed path must match bit for
/// bit: shard the batch exactly as a `world`-rank run would, fold each
/// shard sequentially, combine the shards through the same fixed tree.
/// `world = 1` degenerates to the plain sequential `grad_accum` sum.
pub fn grad_accum_reference(spec: &StepSpec, world: usize) -> Result<Vec<f32>> {
    spec.validate()?;
    let w = world.max(1);
    let n = spec.n_samples();
    let mut slots = Vec::with_capacity(w);
    for pos in 0..w {
        slots.push(local_partial(spec, shard_range(n, w, pos))?.leaves);
    }
    let reduced = tree_combine_leaves(&slots)?;
    Ok(reduced.into_iter().find(|l| l.name == "dl_dtheta").map(|l| l.values).unwrap_or_default())
}

// ---------------------------------------------------------------------------
// Wire messages. Public where scripted peers (tests, examples) need to
// speak the protocol directly.

/// A worker's registration frame.
pub fn hello_message(rank: usize) -> Json {
    obj(vec![("kind", "hello".into()), ("rank", rank.into())])
}

/// A rank's partial, split into grouped payload frames: small leaves share
/// a frame below `threshold_bytes`, large leaves travel alone (see
/// [`bucket_leaves`]). Always at least one frame, so the header fields
/// (`nfe`, `n_samples`, `parts`) ride on part 0.
pub fn partial_messages(
    rank: usize,
    attempt: usize,
    partial: &Partial,
    threshold_bytes: usize,
) -> Vec<Json> {
    let mut groups = bucket_leaves(&partial.leaves, threshold_bytes);
    if groups.is_empty() {
        groups.push(Vec::new());
    }
    let parts = groups.len();
    groups
        .iter()
        .enumerate()
        .map(|(part, idxs)| {
            let leaves: Vec<GradLeaf> = idxs.iter().map(|&i| partial.leaves[i].clone()).collect();
            obj(vec![
                ("kind", "partial".into()),
                ("rank", rank.into()),
                ("attempt", attempt.into()),
                ("part", part.into()),
                ("parts", parts.into()),
                ("nfe", partial.nfe.into()),
                ("n_samples", partial.n_samples.into()),
                ("leaves", leaves_to_json(&leaves)),
            ])
        })
        .collect()
}

fn step_message(attempt: usize, members: &[usize]) -> Json {
    obj(vec![
        ("kind", "step".into()),
        ("attempt", attempt.into()),
        ("members", members.to_vec().into()),
    ])
}

fn reduced_message(attempt: usize, members: &[usize], nfe: usize, leaves: &[GradLeaf]) -> Json {
    obj(vec![
        ("kind", "reduced".into()),
        ("attempt", attempt.into()),
        ("members", members.to_vec().into()),
        ("nfe", nfe.into()),
        ("leaves", leaves_to_json(leaves)),
    ])
}

fn members_from_json(v: &Json) -> Result<Vec<usize>> {
    v.as_arr()?.iter().map(Json::as_usize).collect()
}

/// Reassemble one rank's (possibly multi-part) partial, discarding stale
/// frames from earlier attempts.
fn recv_partial(s: &mut TcpStream, want_rank: usize, want_attempt: usize) -> Result<Partial> {
    let mut leaves: Vec<GradLeaf> = Vec::new();
    let mut nfe = 0usize;
    let mut n_samples = 0usize;
    let mut next_part = 0usize;
    let mut parts = 1usize;
    loop {
        let m = recv_frame(s)?;
        ensure!(m.get("kind")?.as_str()? == "partial", "expected a partial frame");
        ensure!(m.get("rank")?.as_usize()? == want_rank, "partial from the wrong rank");
        let attempt = m.get("attempt")?.as_usize()?;
        if attempt < want_attempt {
            continue; // stale: sent against a membership that no longer exists
        }
        ensure!(attempt == want_attempt, "partial from future attempt {attempt}");
        let part = m.get("part")?.as_usize()?;
        if part == 0 {
            leaves.clear();
            nfe = m.get("nfe")?.as_usize()?;
            n_samples = m.get("n_samples")?.as_usize()?;
            parts = m.get("parts")?.as_usize()?.max(1);
            next_part = 0;
        }
        ensure!(part == next_part, "partial part {part} out of order (expected {next_part})");
        leaves.extend(leaves_from_json(m.get("leaves")?)?);
        next_part += 1;
        if next_part == parts {
            return Ok(Partial { leaves, nfe, n_samples });
        }
    }
}

/// Collect `hello`s until the expected peers registered or the window
/// closes (sleep-counting loop: no wall-clock reads on this path).
fn register_peers(
    listener: &TcpListener,
    expected_world: usize,
    opts: &RootOpts,
) -> Result<BTreeMap<usize, TcpStream>> {
    let mut peers: BTreeMap<usize, TcpStream> = BTreeMap::new();
    listener.set_nonblocking(true).context("listener nonblocking")?;
    let poll = Duration::from_millis(5);
    let mut waited = Duration::ZERO;
    while peers.len() + 1 < expected_world && waited < opts.register_timeout {
        match listener.accept() {
            Ok((mut s, _)) => {
                s.set_nonblocking(false).context("peer blocking mode")?;
                let _ = s.set_nodelay(true);
                let _ = s.set_read_timeout(Some(opts.transport.io_timeout));
                let _ = s.set_write_timeout(Some(opts.transport.io_timeout));
                match recv_frame(&mut s) {
                    Ok(m) if matches!(m.opt("kind"), Some(Json::Str(k)) if k == "hello") => {
                        let rank = m.get("rank")?.as_usize()?;
                        ensure!(rank != 0, "a peer claimed rank 0");
                        // Latest registration for a rank wins (a restarted
                        // worker replaces its dead predecessor).
                        peers.insert(rank, s);
                    }
                    _ => {} // not a hello; drop the connection
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(poll);
                waited += poll;
            }
            Err(e) => return Err(e).context("accept"),
        }
    }
    listener.set_nonblocking(false).context("listener blocking mode")?;
    Ok(peers)
}

/// Run the rank-0 coordinator for one step: broadcast the membership,
/// compute slot 0's shard locally, collect the peers' partials by rank
/// slot, tree-combine, and broadcast the reduced gradient. Evicts dead
/// peers and retries with the survivors.
pub fn run_root(
    listener: &TcpListener,
    expected_world: usize,
    spec: &StepSpec,
    opts: &RootOpts,
) -> Result<DistGrad> {
    spec.validate()?;
    let mut peers = register_peers(listener, expected_world, opts)?;
    let n = spec.n_samples();
    let mut attempt = 1usize;
    loop {
        ensure!(
            attempt <= opts.max_attempts,
            "distributed step failed after {} attempts",
            attempt - 1
        );
        let members: Vec<usize> = std::iter::once(0).chain(peers.keys().copied()).collect();
        let w = members.len();
        let step = step_message(attempt, &members);
        let mut dead: Vec<usize> = Vec::new();
        for (r, s) in peers.iter_mut() {
            if send_frame(s, &step).is_err() {
                dead.push(*r);
            }
        }
        if !dead.is_empty() {
            for r in &dead {
                peers.remove(r);
            }
            attempt += 1;
            continue;
        }
        let own = local_partial(spec, shard_range(n, w, 0))?;
        let mut nfe = own.nfe;
        let mut slots: Vec<Vec<GradLeaf>> = vec![own.leaves];
        for (pos, r) in members.iter().enumerate().skip(1) {
            let s = peers.get_mut(r).ok_or_else(|| anyhow!("rank {r} vanished"))?;
            match recv_partial(s, *r, attempt) {
                Ok(p) => {
                    ensure!(
                        p.n_samples == shard_range(n, w, pos).len(),
                        "rank {r} computed {} samples for a {}-sample shard",
                        p.n_samples,
                        shard_range(n, w, pos).len()
                    );
                    nfe += p.nfe;
                    slots.push(p.leaves);
                }
                Err(_) => dead.push(*r),
            }
            if !dead.is_empty() {
                break; // membership changed; re-partition and retry
            }
        }
        if !dead.is_empty() {
            for r in &dead {
                peers.remove(r);
            }
            attempt += 1;
            continue;
        }
        let leaves = tree_combine_leaves(&slots)?;
        let done = reduced_message(attempt, &members, nfe, &leaves);
        for s in peers.values_mut() {
            // The reduction is already final; a peer that dies here simply
            // misses the result.
            let _ = send_frame(s, &done);
        }
        return Ok(DistGrad { leaves, members, attempts: attempt, nfe });
    }
}

/// Run a worker rank: register, then serve `step` broadcasts (recompute
/// the local shard for whatever membership the coordinator announces)
/// until the reduced gradient arrives.
pub fn run_worker(
    root_addr: &str,
    rank: usize,
    spec: &StepSpec,
    topts: &TransportOpts,
) -> Result<DistGrad> {
    spec.validate()?;
    ensure!(rank != 0, "rank 0 is the coordinator; call run_root");
    let mut s = connect_retry(root_addr, topts)?;
    send_frame(&mut s, &hello_message(rank))?;
    loop {
        let m = recv_frame(&mut s).context("lost the coordinator")?;
        match m.get("kind")?.as_str()? {
            "step" => {
                let attempt = m.get("attempt")?.as_usize()?;
                let members = members_from_json(m.get("members")?)?;
                let pos = members
                    .iter()
                    .position(|&r| r == rank)
                    .ok_or_else(|| anyhow!("rank {rank} evicted from the membership"))?;
                let range = shard_range(spec.n_samples(), members.len(), pos);
                let p = local_partial(spec, range)?;
                let msgs =
                    partial_messages(rank, attempt, &p, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES);
                for msg in &msgs {
                    send_frame(&mut s, msg)?;
                }
            }
            "reduced" => {
                return Ok(DistGrad {
                    leaves: leaves_from_json(m.get("leaves")?)?,
                    members: members_from_json(m.get("members")?)?,
                    attempts: m.get("attempt")?.as_usize()?,
                    nfe: m.get("nfe")?.as_usize()?,
                });
            }
            k => bail!("unexpected message kind {k:?}"),
        }
    }
}

/// One distributed training step, dispatched by [`DistConfig`]: a world of
/// one runs fully local (no sockets); rank 0 binds the coordinator
/// listener; everyone else runs a worker against `root_addr`.
pub fn train_step(cfg: &DistConfig, spec: &StepSpec, opts: &RootOpts) -> Result<DistGrad> {
    spec.validate()?;
    if cfg.world_size <= 1 {
        let p = local_partial(spec, 0..spec.n_samples())?;
        return Ok(DistGrad { leaves: p.leaves, members: vec![0], attempts: 1, nfe: p.nfe });
    }
    if cfg.rank == 0 {
        let listener = TcpListener::bind(("0.0.0.0", cfg.port))
            .with_context(|| format!("bind coordinator port {}", cfg.port))?;
        run_root(&listener, cfg.world_size, spec, opts)
    } else {
        run_worker(&cfg.root_addr(), cfg.rank, spec, &opts.transport)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_partition_the_batch() {
        for (n, w) in [(10, 3), (7, 7), (5, 8), (64, 4), (1, 1), (9, 2)] {
            let mut covered = 0usize;
            let mut next = 0usize;
            for pos in 0..w {
                let r = shard_range(n, w, pos);
                assert_eq!(r.start, next, "shards must be contiguous in order");
                next = r.end;
                covered += r.len();
                // Balanced: no shard is more than one sample bigger.
                assert!(r.len() <= n / w + 1);
            }
            assert_eq!(covered, n, "n={n} w={w}");
            assert_eq!(next, n);
        }
    }

    #[test]
    fn partial_messages_reassemble() {
        let big = 32 * 1024; // floats -> 128 KiB, travels alone
        let partial = Partial {
            leaves: vec![
                GradLeaf::new("w", (0..big).map(|i| i as f32).collect()),
                GradLeaf::new("b1", vec![1.0, 2.0]),
                GradLeaf::new("b2", vec![3.0]),
            ],
            nfe: 42,
            n_samples: 5,
        };
        let msgs = partial_messages(3, 2, &partial, DEFAULT_GROUPED_REDUCE_THRESHOLD_BYTES);
        assert_eq!(msgs.len(), 2, "one lone large leaf + one grouped payload");
        for (i, m) in msgs.iter().enumerate() {
            assert_eq!(m.get("part").unwrap().as_usize().unwrap(), i);
            assert_eq!(m.get("parts").unwrap().as_usize().unwrap(), msgs.len());
            assert_eq!(m.get("rank").unwrap().as_usize().unwrap(), 3);
            assert_eq!(m.get("attempt").unwrap().as_usize().unwrap(), 2);
        }
        // Concatenating the parts in order reproduces the leaf sequence.
        let mut names = Vec::new();
        for m in &msgs {
            for l in leaves_from_json(m.get("leaves").unwrap()).unwrap() {
                names.push(l.name);
            }
        }
        assert_eq!(names, vec!["w", "b1", "b2"]);
    }

    #[test]
    fn empty_shard_is_the_additive_identity() {
        use crate::ode::analytic::Linear;
        use crate::ode::tableau;
        let f = Linear::new(-0.5, 2);
        let spec = StepSpec {
            f: &f,
            tab: tableau::rk4(),
            opts: IntegrateOpts { fixed_h: Some(0.1), ..Default::default() },
            t0s: vec![0.0; 2],
            t1s: vec![1.0; 2],
            z0: vec![1.0; 4],
            lam: vec![1.0; 4],
        };
        // 3 ranks, 2 samples: position 2 owns nothing.
        let p = local_partial(&spec, shard_range(2, 3, 2)).unwrap();
        assert_eq!(p.n_samples, 0);
        assert_eq!(p.nfe, 0);
        assert_eq!(p.leaves, vec![GradLeaf::new("dl_dtheta", vec![0.0])]);
        // And the world-3 reference still matches a world-2 partition of
        // the same two samples plus the identity slot folded by the tree.
        let g3 = grad_accum_reference(&spec, 3).unwrap();
        assert_eq!(g3.len(), 1);
        assert!(g3[0].is_finite());
    }
}
