//! Wall-clock timing helper used by the experiment harness and perf logs.

use std::time::Instant;

/// A simple stopwatch that accumulates named spans.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    // This IS the sanctioned wall-clock entry point (clippy.toml bans the
    // raw call everywhere else).
    #[allow(clippy::disallowed_methods)]
    pub fn new() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since construction or the last `reset`.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds elapsed.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    #[allow(clippy::disallowed_methods)]
    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotone() {
        let t = Timer::new();
        let a = t.elapsed_s();
        let b = t.elapsed_s();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
