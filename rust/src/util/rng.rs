//! Deterministic PCG64 (XSL-RR 128/64) random number generator.
//!
//! We carry our own tiny RNG instead of the `rand` crate so that every
//! experiment in the paper reproduction is bit-reproducible across crate
//! upgrades: seeds appear in EXPERIMENTS.md and must stay meaningful.

/// PCG XSL-RR 128/64 generator (O'Neill 2014). Deterministic, seedable,
/// and fast enough for data generation and weight init.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    /// Create a generator from a seed and stream id. Distinct streams are
    /// statistically independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e39cb94b95bdb) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        // Lemire-style rejection-free is overkill here; modulo bias is
        // negligible for n << 2^64 in data shuffling.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.uniform()).max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals scaled by `std`.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32() * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2, "streams should be independent");
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut rng = Pcg64::seed(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seed(2);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(3);
        let p = rng.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn below_bounds() {
        let mut rng = Pcg64::seed(4);
        for _ in 0..1000 {
            assert!(rng.below(17) < 17);
        }
    }
}
