//! Minimal JSON reader/writer (the build environment vendors no serde).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifests written by `python/compile/aot.py` and for experiment
//! result files. Not a general-purpose library — inputs are trusted build
//! products.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // Policy: the JSON grammar has no NaN/Infinity form, so
                    // non-finite numbers serialize as `null` (writing `NaN`
                    // would produce unparseable output). Transports that
                    // need non-finite fidelity must use the bit-pattern
                    // encoding ([`f32_bits`]).
                    out.push_str("null");
                } else if *n == 0.0 && n.is_sign_negative() {
                    // The integer fast path below would print `0` and drop
                    // the sign bit.
                    out.push_str("-0");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object from pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Encode an `f32` slice as an array of bit patterns. A `u32` is exact in
/// a JSON number (f64 holds every integer up to 2^53), so this is the
/// bit-exact wire form for states and gradients — including NaN/Inf/-0.0,
/// which the plain number grammar cannot carry (see the non-finite `null`
/// policy in [`Json::to_string`]'s number writer).
pub fn f32_bits(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|x| Json::Num(f64::from(x.to_bits()))).collect())
}

/// Decode [`f32_bits`]; rejects anything that is not an exact `u32`.
pub fn f32s_from_bits(v: &Json) -> Result<Vec<f32>> {
    v.as_arr()?
        .iter()
        .map(|b| {
            let n = b.as_f64()?;
            if !(0.0..=f64::from(u32::MAX)).contains(&n) || n.fract() != 0.0 {
                bail!("not an f32 bit pattern: {n}");
            }
            Ok(f32::from_bits(n as u32))
        })
        .collect()
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected '{}' at offset {}", c as char, self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let len = if c >= 0xf0 {
                        4
                    } else if c >= 0xe0 {
                        3
                    } else {
                        2
                    };
                    let start = self.i - 1;
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(txt.parse()?))
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' got '{}' at {}", c as char, self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_bool().unwrap(), false);
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"s"],"flag":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "name": "spiral", "kind": "node", "batch": 64, "n_params": 1154,
          "artifacts": {"f_eval": {"file": "f_eval.hlo.txt",
            "inputs": [{"shape": [1154], "dtype": "f32"}],
            "outputs": [{"shape": [64, 16], "dtype": "f32"}]}}
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("batch").unwrap().as_usize().unwrap(), 64);
        let art = v.get("artifacts").unwrap().get("f_eval").unwrap();
        assert_eq!(
            art.get("outputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\n".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\n""#);
    }

    #[test]
    fn unicode_pass_through() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn builder_helpers() {
        let v = obj(vec![("x", 1.5.into()), ("name", "m".into()), ("ns", vec![1usize, 2].into())]);
        assert_eq!(v.get("x").unwrap().as_f64().unwrap(), 1.5);
        assert_eq!(v.get("ns").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap().as_f64().unwrap();
        assert!(back == 0.0 && back.is_sign_negative(), "parse must keep the sign bit");
        assert_eq!(Json::Num(0.0).to_string(), "0", "positive zero stays the short form");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // Policy: JSON has no NaN/Inf form — they degrade to null rather
        // than producing unparseable output like "NaN".
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Num(v).to_string();
            assert_eq!(s, "null", "{v} must serialize as null");
            assert_eq!(Json::parse(&s).unwrap(), Json::Null);
        }
    }

    #[test]
    fn finite_f64_round_trips_bit_exactly() {
        let mut rng = crate::util::Pcg64::seed(0x1157);
        let mut checked = 0;
        while checked < 500 {
            let x = f64::from_bits(rng.next_u64());
            if !x.is_finite() {
                continue;
            }
            let s = Json::Num(x).to_string();
            let y = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(y.to_bits(), x.to_bits(), "{x:?} -> {s} -> {y:?}");
            checked += 1;
        }
    }

    #[test]
    fn integer_boundaries_round_trip() {
        let edges = [
            0.0,
            1.0,
            -1.0,
            2f64.powi(53),
            2f64.powi(53) - 1.0,
            -(2f64.powi(53)),
            1e15,
            1e15 - 1.0,
            -1e15,
            f64::MAX,
            f64::MIN_POSITIVE,
            5e-324, // smallest subnormal
        ];
        for x in edges {
            let s = Json::Num(x).to_string();
            let y = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(y.to_bits(), x.to_bits(), "{x:?} -> {s} -> {y:?}");
        }
    }

    /// Random JSON value over every shape: scalars, strings with escapes
    /// and unicode, arrays, and objects, bounded in depth.
    fn rand_value(rng: &mut crate::util::Pcg64, depth: usize) -> Json {
        let top = if depth == 0 { 4 } else { 6 };
        match rng.below(top) {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => {
                // Mix exact integers with arbitrary finite doubles.
                if rng.below(2) == 0 {
                    Json::Num(rng.below(1 << 20) as f64 - 524_288.0)
                } else {
                    loop {
                        let x = f64::from_bits(rng.next_u64());
                        if x.is_finite() {
                            break Json::Num(x);
                        }
                    }
                }
            }
            3 => {
                let alphabet = ['a', '"', '\\', '\n', '\t', '\u{1}', 'é', '世', '🦀', ' '];
                let n = rng.below(12);
                Json::Str((0..n).map(|_| alphabet[rng.below(alphabet.len())]).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| rand_value(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{}{i}", rng.below(100)), rand_value(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    #[test]
    fn property_random_values_round_trip() {
        let mut rng = crate::util::Pcg64::seed(0x00de);
        for case in 0..300 {
            let v = rand_value(&mut rng, 4);
            let s = v.to_string();
            let back = Json::parse(&s).unwrap_or_else(|e| panic!("case {case}: {e}\n{s}"));
            assert_eq!(back, v, "case {case}: {s}");
            // Second trip: serialization of the parsed value is stable.
            assert_eq!(back.to_string(), s, "case {case}");
        }
    }

    #[test]
    fn deeply_nested_round_trips() {
        let mut v = Json::Num(1.0);
        for _ in 0..200 {
            v = Json::Arr(vec![v]);
        }
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
        let mut o = Json::Bool(true);
        for i in 0..200 {
            o = obj(vec![(&format!("k{i}"), o)]);
        }
        let s = o.to_string();
        assert_eq!(Json::parse(&s).unwrap(), o);
    }

    #[test]
    fn large_payloads_round_trip() {
        // The transport frames gradients of this shape; make sure nothing
        // degrades past 64 KiB of serialized text.
        let mut rng = crate::util::Pcg64::seed(9);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.normal_f32()).collect();
        let v = obj(vec![("name", "dl_dtheta".into()), ("bits", f32_bits(&xs))]);
        let s = v.to_string();
        assert!(s.len() > 64 * 1024, "payload too small to exercise the path: {}", s.len());
        let back = Json::parse(&s).unwrap();
        let ys = f32s_from_bits(back.get("bits").unwrap()).unwrap();
        let got: Vec<u32> = ys.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp);
    }

    #[test]
    fn f32_bits_carries_every_value_class() {
        let weird = [
            0.0f32,
            -0.0,
            1.0,
            f32::NAN,
            f32::from_bits(0x7fc0_0001), // NaN with a payload
            f32::from_bits(0xff80_0001), // negative signaling-ish NaN
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            1e-45, // smallest subnormal
            f32::MAX,
        ];
        let s = f32_bits(&weird).to_string();
        let back = f32s_from_bits(&Json::parse(&s).unwrap()).unwrap();
        let got: Vec<u32> = back.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = weird.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp, "bit-pattern encoding must be lossless for every class");
    }

    #[test]
    fn f32s_from_bits_rejects_non_patterns() {
        assert!(f32s_from_bits(&Json::parse("[0.5]").unwrap()).is_err());
        assert!(f32s_from_bits(&Json::parse("[-1]").unwrap()).is_err());
        assert!(f32s_from_bits(&Json::parse("[4294967296]").unwrap()).is_err());
        assert!(f32s_from_bits(&Json::parse("[true]").unwrap()).is_err());
        assert!(f32s_from_bits(&Json::parse("{}").unwrap()).is_err());
        assert!(f32s_from_bits(&Json::parse("[0,4294967295]").unwrap()).is_ok());
    }
}
