//! Small shared utilities: deterministic RNG, timing, logging helpers.

pub mod json;
pub mod rng;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Timer;
