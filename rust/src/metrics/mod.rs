//! Evaluation metrics: accuracy, MSE, and the intraclass correlation
//! coefficients ICC(1) / ICC(1,k) used for the paper's test-retest
//! reliability analysis (Table 3).

pub mod icc;

pub use icc::{icc1, icc1k, IccInput};

/// Classification accuracy from predicted and true labels.
pub fn accuracy(pred: &[usize], truth: &[i32]) -> f64 {
    if pred.is_empty() {
        return f64::NAN;
    }
    let correct = pred.iter().zip(truth).filter(|(p, t)| **p == **t as usize).count();
    correct as f64 / pred.len() as f64
}

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    crate::tensor::mse(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[0, 1, 1], &[0, 1, 0]), 2.0 / 3.0);
        assert!(accuracy(&[], &[]).is_nan());
    }
}
