//! Intraclass correlation coefficients (Weir 2005) — the paper's
//! test-retest reliability measure (Table 3).
//!
//! One-way random-effects model: `n` subjects (test samples) rated by `k`
//! raters (independently-initialized training runs). Ratings here are the
//! per-sample correctness indicators (1 = classified correctly).
//!
//! ```text
//! ICC(1)   = (MSB − MSW) / (MSB + (k−1)·MSW)      single-rater reliability
//! ICC(1,k) = (MSB − MSW) / MSB                     mean-of-k reliability
//! ```

/// Ratings matrix: `runs[r][s]` = rating of subject `s` by rater `r`.
pub struct IccInput {
    pub runs: Vec<Vec<f64>>,
}

impl IccInput {
    /// Build from per-run boolean correctness vectors.
    pub fn from_correctness(runs: &[Vec<bool>]) -> Self {
        IccInput {
            runs: runs
                .iter()
                .map(|r| r.iter().map(|&b| if b { 1.0 } else { 0.0 }).collect())
                .collect(),
        }
    }

    /// Restrict to the subjects where at least one rater erred — the paper's
    /// "misclassified test data" rows of Table 3.
    pub fn misclassified_subset(&self) -> IccInput {
        let n = self.runs[0].len();
        let keep: Vec<usize> = (0..n)
            .filter(|&s| self.runs.iter().any(|r| r[s] < 0.5))
            .collect();
        IccInput {
            runs: self
                .runs
                .iter()
                .map(|r| keep.iter().map(|&s| r[s]).collect())
                .collect(),
        }
    }

    fn n_subjects(&self) -> usize {
        self.runs.first().map(|r| r.len()).unwrap_or(0)
    }
}

/// One-way ANOVA mean squares (MSB between subjects, MSW within subjects).
fn anova(input: &IccInput) -> Option<(f64, f64, usize)> {
    let k = input.runs.len();
    let n = input.n_subjects();
    if k < 2 || n < 2 {
        return None;
    }
    debug_assert!(input.runs.iter().all(|r| r.len() == n));
    let grand: f64 = input.runs.iter().flat_map(|r| r.iter()).sum::<f64>() / (n * k) as f64;
    // Subject means.
    let mut ssb = 0.0;
    let mut ssw = 0.0;
    for s in 0..n {
        let mean_s: f64 = input.runs.iter().map(|r| r[s]).sum::<f64>() / k as f64;
        ssb += (mean_s - grand).powi(2);
        for r in 0..k {
            ssw += (input.runs[r][s] - mean_s).powi(2);
        }
    }
    let msb = k as f64 * ssb / (n - 1) as f64;
    let msw = ssw / (n * (k - 1)) as f64;
    Some((msb, msw, k))
}

/// ICC(1): single-rater reliability. Returns NaN for degenerate inputs.
pub fn icc1(input: &IccInput) -> f64 {
    match anova(input) {
        Some((msb, msw, k)) => {
            let denom = msb + (k as f64 - 1.0) * msw;
            if denom == 0.0 {
                f64::NAN
            } else {
                (msb - msw) / denom
            }
        }
        None => f64::NAN,
    }
}

/// ICC(1,k): reliability of the mean of k raters.
pub fn icc1k(input: &IccInput) -> f64 {
    match anova(input) {
        Some((msb, msw, _)) => {
            if msb == 0.0 {
                f64::NAN
            } else {
                (msb - msw) / msb
            }
        }
        None => f64::NAN,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_agreement_gives_one() {
        // All raters agree exactly, subjects differ.
        let runs = vec![vec![1.0, 0.0, 1.0, 0.0]; 5];
        let input = IccInput { runs };
        assert!((icc1(&input) - 1.0).abs() < 1e-12);
        assert!((icc1k(&input) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pure_noise_gives_near_zero() {
        // Ratings independent of subject: expected ICC ~ 0.
        let mut rng = crate::util::Pcg64::seed(9);
        let runs: Vec<Vec<f64>> = (0..20)
            .map(|_| (0..200).map(|_| if rng.uniform() < 0.5 { 1.0 } else { 0.0 }).collect())
            .collect();
        let v = icc1(&IccInput { runs });
        assert!(v.abs() < 0.05, "noise ICC should be ~0, got {v}");
    }

    #[test]
    fn icc1k_geq_icc1() {
        // Averaging raters can only help.
        let runs = vec![
            vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0],
            vec![1.0, 0.0, 0.0, 1.0, 0.0, 1.0],
            vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0],
        ];
        let input = IccInput { runs };
        let a = icc1(&input);
        let b = icc1k(&input);
        assert!(b >= a, "ICC1k {b} < ICC1 {a}");
    }

    #[test]
    fn hand_computed_example() {
        // 2 raters, 3 subjects; ratings chosen for a tractable ANOVA.
        // subjects means: 1.0, 0.5, 0.0 ; grand = 0.5
        let runs = vec![vec![1.0, 1.0, 0.0], vec![1.0, 0.0, 0.0]];
        let input = IccInput { runs };
        // ssb = (0.5^2 + 0 + 0.5^2) = 0.5 ; msb = 2*0.5/2 = 0.5
        // ssw = 0 + 0.5 + 0 = 0.5 ; msw = 0.5/3
        let msb = 0.5;
        let msw = 0.5 / 3.0;
        let want1 = (msb - msw) / (msb + msw);
        let want1k = (msb - msw) / msb;
        assert!((icc1(&input) - want1).abs() < 1e-12);
        assert!((icc1k(&input) - want1k).abs() < 1e-12);
    }

    #[test]
    fn misclassified_subset_filters() {
        let runs = vec![vec![true, true, false, true], vec![true, false, false, true]];
        let input = IccInput::from_correctness(&runs);
        let sub = input.misclassified_subset();
        // subjects 1 and 2 had at least one error
        assert_eq!(sub.runs[0].len(), 2);
        assert_eq!(sub.runs[0], vec![1.0, 0.0]);
        assert_eq!(sub.runs[1], vec![0.0, 0.0]);
    }

    #[test]
    fn degenerate_inputs_nan() {
        assert!(icc1(&IccInput { runs: vec![] }).is_nan());
        assert!(icc1(&IccInput { runs: vec![vec![1.0, 0.0]] }).is_nan());
        // All identical ratings everywhere: 0/0.
        let runs = vec![vec![1.0, 1.0], vec![1.0, 1.0]];
        assert!(icc1(&IccInput { runs }).is_nan());
    }

    #[test]
    fn more_consistent_runs_higher_icc() {
        let mut rng = crate::util::Pcg64::seed(4);
        let base: Vec<f64> =
            (0..300).map(|_| if rng.uniform() < 0.7 { 1.0 } else { 0.0 }).collect();
        let noisy = |p: f64, rng: &mut crate::util::Pcg64| -> Vec<Vec<f64>> {
            (0..8)
                .map(|_| {
                    base.iter()
                        .map(|&v| if rng.uniform() < p { 1.0 - v } else { v })
                        .collect()
                })
                .collect()
        };
        let hi = icc1(&IccInput { runs: noisy(0.05, &mut rng) });
        let lo = icc1(&IccInput { runs: noisy(0.4, &mut rng) });
        assert!(hi > lo, "consistent {hi} should beat noisy {lo}");
    }
}
