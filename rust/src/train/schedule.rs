//! Learning-rate schedules from the paper's experimental setups:
//! step decay (×0.1 at epochs 30/60 — Fig 7; 150/250 — Table 2) and
//! exponential decay (`lr · d^epoch` — the three-body recipe, paper Eq. 83).

/// Learning-rate schedule (epoch-indexed).
#[derive(Debug, Clone)]
pub enum LrSchedule {
    /// Constant `lr`.
    Constant(f64),
    /// `initial × factor^(number of milestones passed)`.
    Step { initial: f64, factor: f64, milestones: Vec<usize> },
    /// `initial × decay^epoch` (paper Eq. 83).
    Exp { initial: f64, decay: f64 },
}

impl LrSchedule {
    /// Paper Fig 7 recipe: 0.01, ×0.1 at epochs 30 and 60.
    pub fn paper_fig7() -> Self {
        LrSchedule::Step { initial: 0.01, factor: 0.1, milestones: vec![30, 60] }
    }

    /// Paper three-body recipe for NODE: 0.1 × 0.99^epoch.
    pub fn paper_threebody() -> Self {
        LrSchedule::Exp { initial: 0.1, decay: 0.99 }
    }

    pub fn at(&self, epoch: usize) -> f64 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::Step { initial, factor, milestones } => {
                let k = milestones.iter().filter(|&&m| epoch >= m).count();
                initial * factor.powi(k as i32)
            }
            LrSchedule::Exp { initial, decay } => initial * decay.powi(epoch as i32),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_decay_milestones() {
        let s = LrSchedule::Step { initial: 0.1, factor: 0.1, milestones: vec![30, 60] };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(29), 0.1);
        assert!((s.at(30) - 0.01).abs() < 1e-12);
        assert!((s.at(59) - 0.01).abs() < 1e-12);
        assert!((s.at(60) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn exp_decay() {
        let s = LrSchedule::Exp { initial: 0.1, decay: 0.99 };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(100) - 0.1 * 0.99f64.powi(100)).abs() < 1e-12);
    }

    #[test]
    fn constant() {
        assert_eq!(LrSchedule::Constant(0.5).at(1000), 0.5);
    }

    #[test]
    fn monotone_nonincreasing() {
        for s in [LrSchedule::paper_fig7(), LrSchedule::paper_threebody()] {
            let mut prev = f64::INFINITY;
            for e in 0..100 {
                let lr = s.at(e);
                assert!(lr <= prev + 1e-15);
                prev = lr;
            }
        }
    }
}
