//! Training-facing surface of the distributed subsystem.
//!
//! The mechanics live in [`crate::dist::train`]; this module re-exports
//! them under `train::` so a training loop swaps `loss_grad_accum` for
//! [`distributed_step`] without importing `dist` paths, and holds the
//! end-to-end parity tests tying the two halves together: a W-rank step
//! over real sockets must be bit-identical to the single-process
//! [`grad_accum_reference`] fold.

pub use crate::dist::env::DistConfig;
pub use crate::dist::train::{
    grad_accum_reference, local_partial, run_root, run_worker, shard_range, DistGrad, RootOpts,
    StepSpec,
};

/// One data-parallel training step, dispatched by `cfg` (see
/// [`crate::dist::train::train_step`]): world 1 is fully local, rank 0
/// coordinates, other ranks work. The returned gradient is bit-identical
/// on every surviving rank and to [`grad_accum_reference`] for the same
/// membership size.
pub fn distributed_step(
    cfg: &DistConfig,
    spec: &StepSpec,
    opts: &RootOpts,
) -> anyhow::Result<DistGrad> {
    crate::dist::train::train_step(cfg, spec, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::transport::TransportOpts;
    use crate::ode::analytic::Linear;
    use crate::ode::{tableau, IntegrateOpts};
    use crate::util::rng::Pcg64;

    fn spec(f: &Linear, b: usize) -> StepSpec<'_> {
        let d = 3;
        let mut rng = Pcg64::seed(0x21);
        StepSpec {
            f,
            tab: tableau::by_name("rk45").unwrap(),
            opts: IntegrateOpts::with_tol(1e-5, 1e-7),
            t0s: vec![0.0; b],
            t1s: (0..b).map(|_| rng.range(0.6, 1.4)).collect(),
            z0: (0..b * d).map(|_| rng.uniform_f32() - 0.5).collect(),
            lam: vec![1.0; b * d],
        }
    }

    /// World 1 takes the no-socket path and still equals the reference.
    #[test]
    fn single_rank_step_is_the_local_fold() {
        let f = Linear::new(-0.6, 3);
        let s = spec(&f, 5);
        let got = distributed_step(&DistConfig::default(), &s, &RootOpts::default()).unwrap();
        assert_eq!(got.members, vec![0]);
        assert_eq!(got.attempts, 1);
        let want = grad_accum_reference(&s, 1).unwrap();
        let got_bits: Vec<u32> = got.dl_dtheta().iter().map(|x| x.to_bits()).collect();
        let want_bits: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got_bits, want_bits);
    }

    /// Two real ranks over loopback TCP: the reduced gradient on both
    /// ranks is bit-identical to the single-process reference.
    #[test]
    fn two_rank_step_matches_the_reference_bit_for_bit() {
        let f = Linear::new(-0.6, 3);
        let s = spec(&f, 7);
        let want: Vec<u32> =
            grad_accum_reference(&s, 2).unwrap().iter().map(|x| x.to_bits()).collect();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let (root, worker) = std::thread::scope(|sc| {
            let w = sc.spawn(|| run_worker(&addr, 1, &s, &TransportOpts::default()));
            let root = run_root(&listener, 2, &s, &RootOpts::default()).unwrap();
            (root, w.join().unwrap().unwrap())
        });
        assert_eq!(root.members, vec![0, 1]);
        assert_eq!(root.attempts, 1);
        let root_bits: Vec<u32> = root.dl_dtheta().iter().map(|x| x.to_bits()).collect();
        let worker_bits: Vec<u32> = worker.dl_dtheta().iter().map(|x| x.to_bits()).collect();
        assert_eq!(root_bits, want, "root must match the single-process fold");
        assert_eq!(worker_bits, want, "the broadcast result must be the same bits");
        assert_eq!(root.nfe, worker.nfe);
        assert!(root.nfe > 0);
    }
}
