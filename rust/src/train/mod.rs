//! Training infrastructure: optimizers, LR schedules, the classification
//! trainer (Fig 7 / Tables 2–3) and segmented integration for losses at
//! multiple observation times (Tables 4–5).

pub mod distributed;
pub mod optim;
pub mod schedule;
pub mod segmented;
pub mod trainer;

pub use distributed::distributed_step;
pub use optim::{clip_grad_norm, Adam, Optimizer, Sgd};
pub use schedule::LrSchedule;
pub use segmented::segmented_loss_grad;
pub use trainer::{TrainConfig, TrainRecord, Trainer};
