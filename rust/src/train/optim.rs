//! First-order optimizers matching the paper's training recipes
//! (SGD+momentum for classification, Adam for the three-body problem).

/// Clip a gradient to a maximum L2 norm (in place); returns the pre-clip
/// norm. Standard stabilizer for NODE training: a bad step can push the
/// dynamics into a stiff region where NFE explodes (see EXPERIMENTS.md).
pub fn clip_grad_norm(grad: &mut [f32], max_norm: f64) -> f64 {
    let n = crate::tensor::norm2(grad);
    if n > max_norm && n > 0.0 {
        let s = (max_norm / n) as f32;
        for g in grad.iter_mut() {
            *g *= s;
        }
    }
    n
}

/// A stateful first-order optimizer over a flat parameter vector.
pub trait Optimizer {
    /// In-place parameter update from gradients.
    fn step(&mut self, params: &mut [f32], grads: &[f32]);
    /// Current learning rate.
    fn lr(&self) -> f64;
    /// Override the learning rate (driven by an [`super::LrSchedule`]).
    fn set_lr(&mut self, lr: f64);
}

/// SGD with classical momentum and decoupled weight decay.
#[derive(Debug, Clone)]
pub struct Sgd {
    lr: f64,
    momentum: f64,
    weight_decay: f64,
    buf: Vec<f32>,
}

impl Sgd {
    pub fn new(lr: f64, momentum: f64, weight_decay: f64) -> Self {
        Sgd { lr, momentum, weight_decay, buf: Vec::new() }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        if self.buf.len() != params.len() {
            self.buf = vec![0.0; params.len()];
        }
        let (lr, mu, wd) = (self.lr as f32, self.momentum as f32, self.weight_decay as f32);
        for i in 0..params.len() {
            let g = grads[i] + wd * params[i];
            self.buf[i] = mu * self.buf[i] + g;
            params[i] -= lr * self.buf[i];
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

/// Adam (Kingma & Ba) with bias correction.
#[derive(Debug, Clone)]
pub struct Adam {
    lr: f64,
    beta1: f64,
    beta2: f64,
    eps: f64,
    t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(lr: f64) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: Vec::new(), v: Vec::new() }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), grads.len());
        if self.m.len() != params.len() {
            self.m = vec![0.0; params.len()];
            self.v = vec![0.0; params.len()];
        }
        self.t += 1;
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let alpha = self.lr * bc2.sqrt() / bc1;
        for i in 0..params.len() {
            let g = grads[i] as f64;
            self.m[i] = (b1 * self.m[i] as f64 + (1.0 - b1) * g) as f32;
            self.v[i] = (b2 * self.v[i] as f64 + (1.0 - b2) * g * g) as f32;
            params[i] -= (alpha * self.m[i] as f64 / ((self.v[i] as f64).sqrt() + self.eps)) as f32;
        }
    }

    fn lr(&self) -> f64 {
        self.lr
    }

    fn set_lr(&mut self, lr: f64) {
        self.lr = lr;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Both optimizers minimize the quadratic f(x) = Σ (x_i − c_i)².
    fn run<O: Optimizer>(mut opt: O, iters: usize) -> f32 {
        let c = [1.0f32, -2.0, 0.5, 3.0];
        let mut x = [0.0f32; 4];
        for _ in 0..iters {
            let g: Vec<f32> = x.iter().zip(&c).map(|(xi, ci)| 2.0 * (xi - ci)).collect();
            opt.step(&mut x, &g);
        }
        x.iter().zip(&c).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(run(Sgd::new(0.1, 0.0, 0.0), 200) < 1e-4);
    }

    #[test]
    fn sgd_momentum_converges() {
        assert!(run(Sgd::new(0.05, 0.9, 0.0), 300) < 1e-3);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(run(Adam::new(0.1), 500) < 1e-2);
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut opt = Sgd::new(0.1, 0.0, 0.5);
        let mut x = [2.0f32];
        for _ in 0..50 {
            opt.step(&mut x, &[0.0]);
        }
        assert!(x[0] < 0.2, "decay should shrink: {}", x[0]);
    }

    #[test]
    fn clip_grad() {
        let mut g = vec![3.0f32, 4.0];
        let n = clip_grad_norm(&mut g, 1.0);
        assert!((n - 5.0).abs() < 1e-6);
        assert!((crate::tensor::norm2(&g) - 1.0).abs() < 1e-6);
        let mut g2 = vec![0.3f32, 0.4];
        clip_grad_norm(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4], "below-threshold gradients untouched");
    }

    #[test]
    fn lr_setter() {
        let mut opt = Adam::new(0.1);
        opt.set_lr(0.01);
        assert_eq!(opt.lr(), 0.01);
    }

    #[test]
    fn momentum_accelerates_along_consistent_gradient() {
        let mut plain = Sgd::new(0.01, 0.0, 0.0);
        let mut mom = Sgd::new(0.01, 0.9, 0.0);
        let mut xp = [0.0f32];
        let mut xm = [0.0f32];
        for _ in 0..20 {
            plain.step(&mut xp, &[-1.0]);
            mom.step(&mut xm, &[-1.0]);
        }
        assert!(xm[0] > xp[0] * 2.0, "momentum should move farther: {} vs {}", xm[0], xp[0]);
    }
}
