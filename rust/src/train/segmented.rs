//! Segmented integration for losses at multiple observation times —
//! the Latent-ODE (Table 4) and three-body (Table 5) training path.
//!
//! The trajectory is solved segment-by-segment between consecutive
//! observation times (so gradients at the observation points are *exact* —
//! no dense-output interpolation on the training path). The backward sweep
//! runs reverse over segments with adjoint jumps `λ ← λ + dL_k/dz(t_k)` at
//! each observation, exactly as Latent-ODE training does through
//! torchdiffeq.

use anyhow::{ensure, Result};

use crate::grad::{self, CostMeter, Method};
use crate::ode::{integrate, IntegrateOpts, OdeFunc, Tableau, Trajectory};
use crate::runtime::hlo_model::{HloModel, Target};

/// Result of a segmented forward+backward pass.
pub struct SegmentedGrad {
    /// Mean loss over observations.
    pub loss: f64,
    /// `dL/dθ` (dynamics + head parameters combined — flat θ).
    pub dtheta: Vec<f32>,
    /// `dL/dz(t_0)` for the encoder.
    pub dl_dz0: Vec<f32>,
    /// Aggregate cost across segments.
    pub meter: CostMeter,
}

/// Forward + backward through a trajectory observed at `times[1..]`
/// (`times[0]` is the initial time of `z0`; a target may also be supplied
/// for it via `targets[0]` = target at `times[1]`, i.e. `targets[k]`
/// corresponds to `times[k+1]`).
///
/// Loss = mean over observations of the model head loss.
pub fn segmented_loss_grad(
    model: &HloModel,
    tab: &Tableau,
    opts: &IntegrateOpts,
    method: Method,
    z0: &[f32],
    times: &[f64],
    targets: &[Target],
) -> Result<SegmentedGrad> {
    ensure!(times.len() >= 2, "need at least one observation after t0");
    ensure!(
        targets.len() == times.len() - 1,
        "targets ({}) must match observation times ({})",
        targets.len(),
        times.len() - 1
    );
    let n_obs = targets.len();
    let p = model.n_params();

    // ---- forward: one trajectory per segment ----
    let mut segs: Vec<Trajectory> = Vec::with_capacity(n_obs);
    let mut z = z0.to_vec();
    let mut loss_sum = 0.0f64;
    let mut dtheta = vec![0.0f32; p];
    let mut lam_jumps: Vec<Vec<f32>> = Vec::with_capacity(n_obs);
    let mut meter = CostMeter::default();

    for k in 0..n_obs {
        let traj = integrate(model, times[k], times[k + 1], &z, tab, opts)?;
        z = traj.last().expect("non-empty trajectory").to_vec();
        meter.nfe_forward += traj.nfe;
        meter.n_steps += traj.len();
        meter.n_rejected += traj.n_rejected;
        meter.checkpoint_bytes += traj.checkpoint_bytes();

        // Loss + dL/dz at this observation; head-θ gradient accumulates.
        let (lam_k, loss_k) = model.decode_loss_vjp(&z, &targets[k], &mut dtheta)?;
        loss_sum += loss_k;
        lam_jumps.push(lam_k);
        segs.push(traj);
    }

    // Normalize: total loss = (1/n_obs) Σ loss_k. decode_loss_vjp already
    // used per-call means, so scale everything by 1/n_obs.
    let scale = 1.0 / n_obs as f32;
    for d in dtheta.iter_mut() {
        *d *= scale;
    }

    // ---- backward: reverse over segments with λ jumps ----
    let dim = model.dim();
    let mut lam = vec![0.0f32; dim];
    for k in (0..n_obs).rev() {
        // Jump at t_{k+1}.
        for (l, j) in lam.iter_mut().zip(&lam_jumps[k]) {
            *l += j * scale;
        }
        let g = grad::backward(model, tab, &segs[k], &lam, method, opts)?;
        lam = g.dl_dz0;
        for (d, s) in dtheta.iter_mut().zip(&g.dl_dtheta) {
            *d += s;
        }
        meter.nfe_backward += g.meter.nfe_backward;
        meter.nfe_replay += g.meter.nfe_replay;
        meter.vjp_calls += g.meter.vjp_calls;
        meter.graph_depth += g.meter.graph_depth;
        meter.n_reverse_steps += g.meter.n_reverse_steps;
    }

    Ok(SegmentedGrad { loss: loss_sum / n_obs as f64, dtheta, dl_dz0: lam, meter })
}

/// Forward-only evaluation: predictions and mean loss at observation times.
pub fn segmented_eval(
    model: &HloModel,
    tab: &Tableau,
    opts: &IntegrateOpts,
    z0: &[f32],
    times: &[f64],
    targets: &[Target],
) -> Result<(f64, Vec<Vec<f32>>)> {
    let mut z = z0.to_vec();
    let mut loss_sum = 0.0;
    let mut preds = Vec::new();
    for k in 0..targets.len() {
        let traj = integrate(model, times[k], times[k + 1], &z, tab, opts)?;
        z = traj.last().expect("non-empty trajectory").to_vec();
        let (l, pred) = model.decode_loss(&z, &targets[k])?;
        loss_sum += l;
        preds.push(pred);
    }
    Ok((loss_sum / targets.len().max(1) as f64, preds))
}

// Integration-level tests (require artifacts) live in
// rust/tests/training_integration.rs.
