//! Classification trainer — the Fig 7 / Table 2 / Table 3 training loop.
//!
//! Drives a [`HloModel`] through encode → adaptive ODE solve → loss head,
//! with the gradient method under study (ACA / naive / adjoint), SGD with
//! momentum + step-decay LR (the paper's recipe), per-epoch evaluation, and
//! a full cost/time record per epoch.

use anyhow::Result;

use super::optim::{Optimizer, Sgd};
use super::schedule::LrSchedule;
use crate::data::Dataset;
use crate::grad::{self, Method};
use crate::ode::{integrate, integrate_batch, IntegrateOpts, OdeFunc, Tableau};
use crate::runtime::hlo_model::{HloModel, Target};
use crate::util::{Pcg64, Timer};

/// Trainer configuration (defaults follow the paper's Appendix D recipe,
/// scaled to the substitute workload).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    pub epochs: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub weight_decay: f64,
    /// Integration span of the ODE block (paper: [0, 1]).
    pub t1: f64,
    pub rtol: f64,
    pub atol: f64,
    /// Fixed step (discrete baseline / fixed-solver columns of Table 2).
    pub fixed_h: Option<f64>,
    pub seed: u64,
    /// Limit batches per epoch (0 = all) — keeps CPU experiments tractable.
    pub max_batches: usize,
    /// Max gradient L2 norm (0 disables clipping).
    pub clip: f64,
    /// Mini-batches accumulated per optimizer step (≥1, clamped). Groups of
    /// `grad_accum` batches solve through the batched engine — one
    /// [`integrate_batch`] + shared-stage [`grad::backward_batch`] pair over
    /// the group's flattened states — and their summed gradient drives a
    /// single update (standard gradient accumulation; scale `lr`
    /// accordingly). `1` keeps the scalar per-batch path bit-for-bit.
    pub grad_accum: usize,
    /// Per-sample checkpoint budget in bytes (0 = dense storage, today's
    /// behavior). Nonzero runs every solve under
    /// [`crate::ckpt::CkptPolicy::Budgeted`]: gradients stay bit-identical
    /// (segment replay), but a long-horizon solve can no longer grow its
    /// checkpoint memory without bound. Default comes from
    /// `NODAL_CKPT_BUDGET_BYTES` ([`crate::ckpt::env_budget_bytes`]).
    pub ckpt_budget_bytes: usize,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::Aca,
            epochs: 10,
            lr: LrSchedule::Step { initial: 0.05, factor: 0.1, milestones: vec![6, 9] },
            momentum: 0.9,
            weight_decay: 1e-4,
            t1: 1.0,
            rtol: 1e-2,
            atol: 1e-2,
            fixed_h: None,
            seed: 0,
            max_batches: 0,
            clip: 5.0,
            grad_accum: 1,
            ckpt_budget_bytes: crate::ckpt::env_budget_bytes(),
            verbose: false,
        }
    }
}

/// Per-epoch record of a training run.
#[derive(Debug, Clone)]
pub struct TrainRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub test_acc: f64,
    pub test_loss: f64,
    /// Cumulative wall-clock seconds since training started (Fig 7b x-axis).
    pub wall_s: f64,
    /// Mean forward NFE per batch this epoch.
    pub nfe_forward: f64,
    /// Mean backward NFE (+VJPs) per batch this epoch.
    pub nfe_backward: f64,
}

/// The training driver.
pub struct Trainer {
    pub cfg: TrainConfig,
    pub history: Vec<TrainRecord>,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Self {
        Trainer { cfg, history: Vec::new() }
    }

    fn opts(&self) -> IntegrateOpts {
        IntegrateOpts {
            rtol: self.cfg.rtol,
            atol: self.cfg.atol,
            fixed_h: self.cfg.fixed_h,
            record_trials: self.cfg.method == Method::Naive,
            // Hand-set budgets go through the same clamp as env/serve ones.
            ckpt: crate::ckpt::CkptPolicy::from_budget(crate::ckpt::clamp_budget(
                self.cfg.ckpt_budget_bytes,
            )),
            ..Default::default()
        }
    }

    /// One full forward+backward step on a batch; returns (loss, dθ, meters).
    pub fn loss_grad(
        &self,
        model: &HloModel,
        tab: &Tableau,
        x: &[f32],
        y: &Target,
    ) -> Result<(f64, Vec<f32>, grad::CostMeter)> {
        let opts = self.opts();
        let z0 = model.encode(x)?;
        let traj = integrate(model, 0.0, self.cfg.t1, &z0, tab, &opts)?;
        let mut dtheta = vec![0.0f32; model.n_params()];
        let (lam, loss) =
            model.decode_loss_vjp(traj.last().expect("non-empty trajectory"), y, &mut dtheta)?;
        let g = grad::backward(model, tab, &traj, &lam, self.cfg.method, &opts)?;
        for (d, s) in dtheta.iter_mut().zip(&g.dl_dtheta) {
            *d += s;
        }
        model.encode_vjp_accum(x, &g.dl_dz0, &mut dtheta)?;
        let mut meter = g.meter;
        meter.nfe_forward = traj.nfe;
        Ok((loss, dtheta, meter))
    }

    /// Forward + backward over a *group* of mini-batches through the batched
    /// engine: the group's encoded states solve in one [`integrate_batch`]
    /// call (each HLO-batch is one batch-engine "sample" with its own
    /// adaptive step control) and differentiate in one shared-stage
    /// [`grad::backward_batch`] call, instead of one scalar solve + reverse
    /// sweep per batch. Training always integrates every group member over
    /// the same `[0, cfg.t1]`, so it stays on the shared-span wrapper; the
    /// per-sample-span entry point
    /// ([`crate::ode::integrate_batch_spans`]) exists for callers whose
    /// samples genuinely end at different times (the serve worker's
    /// mixed-span batches, time-series with ragged horizons).
    ///
    /// Returns (mean loss over the group, **summed** dθ, summed meters) —
    /// gradient-accumulation semantics: per-batch results are bit-identical
    /// to [`Self::loss_grad`] by the engine's equivalence guarantees; only
    /// the final summation order differs.
    pub fn loss_grad_accum(
        &self,
        model: &HloModel,
        tab: &Tableau,
        group: &[(Vec<f32>, Target)],
    ) -> Result<(f64, Vec<f32>, grad::CostMeter)> {
        assert!(!group.is_empty(), "empty accumulation group");
        let opts = self.opts();
        let d = model.dim();
        let mut z0s = Vec::with_capacity(group.len() * d);
        for (x, _) in group {
            z0s.extend_from_slice(&model.encode(x)?);
        }
        let bt = integrate_batch(model, 0.0, self.cfg.t1, &z0s, tab, &opts)?;
        let mut dtheta = vec![0.0f32; model.n_params()];
        let mut lams = Vec::with_capacity(group.len() * d);
        let mut loss_sum = 0.0;
        for (i, (_, y)) in group.iter().enumerate() {
            let (lam, loss) = model.decode_loss_vjp(bt.last(i), y, &mut dtheta)?;
            lams.extend_from_slice(&lam);
            loss_sum += loss;
        }
        let gs = grad::backward_batch(model, tab, &bt, &lams, self.cfg.method, &opts)?;
        let mut meter = grad::CostMeter::default();
        for ((x, _), g) in group.iter().zip(&gs) {
            for (dst, s) in dtheta.iter_mut().zip(&g.dl_dtheta) {
                *dst += *s;
            }
            model.encode_vjp_accum(x, &g.dl_dz0, &mut dtheta)?;
            meter.nfe_forward += g.meter.nfe_forward;
            meter.nfe_backward += g.meter.nfe_backward;
            meter.nfe_replay += g.meter.nfe_replay;
            meter.vjp_calls += g.meter.vjp_calls;
            meter.checkpoint_bytes += g.meter.checkpoint_bytes;
            meter.graph_depth = meter.graph_depth.max(g.meter.graph_depth);
            meter.n_steps += g.meter.n_steps;
            meter.n_rejected += g.meter.n_rejected;
        }
        Ok((loss_sum / group.len() as f64, dtheta, meter))
    }

    /// Train `model` on `data`, filling `self.history`.
    pub fn fit(&mut self, model: &mut HloModel, tab: &Tableau, data: &Dataset) -> Result<()> {
        let b = model.manifest.batch;
        let mut opt = Sgd::new(self.cfg.lr.at(0), self.cfg.momentum, self.cfg.weight_decay);
        let mut rng = Pcg64::new(self.cfg.seed, 77);
        let timer = Timer::new();

        for epoch in 0..self.cfg.epochs {
            opt.set_lr(self.cfg.lr.at(epoch));
            let mut order = rng.permutation(data.len());
            if self.cfg.max_batches > 0 {
                order.truncate(self.cfg.max_batches * b);
            }
            let mut loss_sum = 0.0;
            let mut n_mb = 0usize; // full mini-batches consumed (NFE/loss denominator)
            let mut nfe_f = 0usize;
            let mut nfe_b = 0usize;
            let accum = self.cfg.grad_accum.max(1);
            // Full mini-batches only (the ragged sub-batch tail is dropped,
            // paper drops the last batch too), grouped `accum` at a time; a
            // ragged trailing *group* still trains — otherwise an epoch with
            // fewer than `accum` batches would silently take zero steps.
            let full_chunks: Vec<&[usize]> =
                order.chunks(b).filter(|c| c.len() == b).collect();
            for gchunk in full_chunks.chunks(accum) {
                let group: Vec<(Vec<f32>, Target)> =
                    gchunk.iter().map(|c| data.gather(c)).collect();
                let (loss, mut dtheta, meter) = if group.len() == 1 {
                    let (x, y) = &group[0];
                    self.loss_grad(model, tab, x, y)?
                } else {
                    // Accumulation groups run through the batched engine:
                    // one integrate_batch + shared-stage backward_batch.
                    self.loss_grad_accum(model, tab, &group)?
                };
                if self.cfg.clip > 0.0 {
                    super::optim::clip_grad_norm(&mut dtheta, self.cfg.clip);
                }
                let mut params = model.params().to_vec();
                opt.step(&mut params, &dtheta);
                model.set_params(&params);
                // Per-mini-batch accounting: `loss` is the group mean and the
                // meters sum over the group, so weight by group size — the
                // recorded per-batch NFE/loss stay comparable across
                // grad_accum settings.
                loss_sum += loss * group.len() as f64;
                n_mb += group.len();
                nfe_f += meter.nfe_forward;
                nfe_b += meter.nfe_backward + meter.vjp_calls;
            }

            let (test_loss, test_acc) =
                evaluate(model, tab, &self.opts(), self.cfg.t1, data, true)?;
            let rec = TrainRecord {
                epoch,
                train_loss: loss_sum / n_mb.max(1) as f64,
                test_acc,
                test_loss,
                wall_s: timer.elapsed_s(),
                nfe_forward: nfe_f as f64 / n_mb.max(1) as f64,
                nfe_backward: nfe_b as f64 / n_mb.max(1) as f64,
            };
            if self.cfg.verbose {
                println!(
                    "  [{}] epoch {:>3}  train_loss {:.4}  test_acc {:.4}  ({:.1}s, nfe {:.0}/{:.0})",
                    self.cfg.method.name(),
                    epoch,
                    rec.train_loss,
                    rec.test_acc,
                    rec.wall_s,
                    rec.nfe_forward,
                    rec.nfe_backward,
                );
            }
            self.history.push(rec);
        }
        Ok(())
    }

    /// Final test accuracy (last epoch's evaluation).
    pub fn final_acc(&self) -> f64 {
        self.history.last().map(|r| r.test_acc).unwrap_or(0.0)
    }
}

/// Evaluation solves at most this many HLO-batches per `integrate_batch`
/// call: the batch engine keeps every live sample's checkpoints until the
/// call returns, and evaluation only consumes the final states — chunking
/// bounds the transient checkpoint memory at `CHUNK × (per-batch arena)`
/// instead of growing linearly with the split size.
const EVAL_CHUNK_BATCHES: usize = 16;

/// Encode the full mini-batches of a split and solve them through
/// [`integrate_batch`] — each HLO-batch state is one "sample" of the batch
/// engine, so every batch keeps its own adaptive step control exactly as
/// the old one-`integrate`-per-batch loop did, while the solver advances
/// a chunk of them together (shared checkpoint arena, one stage sweep per
/// round). Returns the final states `z(T)` alongside the gathered targets.
fn solve_split_batched(
    model: &HloModel,
    tab: &Tableau,
    opts: &IntegrateOpts,
    t1: f64,
    data: &Dataset,
    test_split: bool,
) -> Result<(Vec<Vec<f32>>, Vec<Target>)> {
    let b = model.manifest.batch;
    let n = if test_split { data.test_len() } else { data.len() };
    let n_batches = n / b;
    let mut finals: Vec<Vec<f32>> = Vec::with_capacity(n_batches);
    let mut ys = Vec::with_capacity(n_batches);
    let mut start = 0;
    while start < n_batches {
        let end = (start + EVAL_CHUNK_BATCHES).min(n_batches);
        let mut z0s = Vec::with_capacity((end - start) * model.dim());
        for k in start..end {
            let ids: Vec<usize> = (k * b..(k + 1) * b).collect();
            let (x, y) = if test_split { data.gather_test(&ids) } else { data.gather(&ids) };
            z0s.extend_from_slice(&model.encode(&x)?);
            ys.push(y);
        }
        let btraj = integrate_batch(model, 0.0, t1, &z0s, tab, opts)?;
        finals.extend((0..end - start).map(|k| btraj.last(k).to_vec()));
        start = end;
    }
    Ok((finals, ys))
}

/// Evaluate accuracy/loss on the dataset's test split (or train split).
pub fn evaluate(
    model: &HloModel,
    tab: &Tableau,
    opts: &IntegrateOpts,
    t1: f64,
    data: &Dataset,
    test_split: bool,
) -> Result<(f64, f64)> {
    let b = model.manifest.batch;
    let n = if test_split { data.test_len() } else { data.len() };
    let classes = model.manifest.dim_out;
    let (finals, ys) = solve_split_batched(model, tab, opts, t1, data, test_split)?;
    let mut loss_sum = 0.0;
    let mut correct = 0usize;
    let mut total = 0usize;
    for (zt, y) in finals.iter().zip(&ys) {
        let (loss, pred) = model.decode_loss(zt, y)?;
        loss_sum += loss;
        if let Target::Classes(truth) = y {
            let hats = HloModel::argmax_classes(&pred, classes);
            for (h, t) in hats.iter().zip(truth) {
                if *h == *t as usize {
                    correct += 1;
                }
            }
            total += truth.len();
        }
    }
    let batches = (n / b).max(1) as f64;
    let acc = if total > 0 { correct as f64 / total as f64 } else { f64::NAN };
    Ok((loss_sum / batches, acc))
}

/// Per-sample correctness vector on the test split — the input to the
/// ICC test-retest analysis (Table 3).
pub fn per_sample_correct(
    model: &HloModel,
    tab: &Tableau,
    opts: &IntegrateOpts,
    t1: f64,
    data: &Dataset,
) -> Result<Vec<bool>> {
    let classes = model.manifest.dim_out;
    let (finals, ys) = solve_split_batched(model, tab, opts, t1, data, true)?;
    let mut out = Vec::with_capacity(data.test_len());
    for (zt, y) in finals.iter().zip(&ys) {
        let (_, pred) = model.decode_loss(zt, y)?;
        if let Target::Classes(truth) = y {
            let hats = HloModel::argmax_classes(&pred, classes);
            for (h, t) in hats.iter().zip(truth) {
                out.push(*h == *t as usize);
            }
        }
    }
    Ok(out)
}
