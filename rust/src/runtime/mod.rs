//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! L3 hot loop. Python never runs here — `make artifacts` produced the
//! `artifacts/<model>/*.hlo.txt` files once at build time.
//!
//! * [`engine`] — client + executable cache + literal marshalling;
//! * [`manifest`] — typed view of `manifest.json`;
//! * [`hlo_model`] — an [`crate::ode::OdeFunc`] (plus encoder / loss head)
//!   backed by compiled executables.

pub mod engine;
pub mod hlo_model;
pub mod manifest;

pub use engine::{Engine, Executable};
pub use hlo_model::{HloModel, RecurrentBaseline};
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifact root, overridable with `NODAL_ARTIFACTS`.
pub fn artifact_root() -> std::path::PathBuf {
    std::env::var_os("NODAL_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
