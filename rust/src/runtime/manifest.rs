//! Typed view of the `manifest.json` emitted per model by
//! `python/compile/aot.py` (DESIGN.md §5).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<Self> {
        Ok(TensorSpec {
            shape: j
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Parsed model manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    /// "node" or "recurrent".
    pub kind: String,
    pub batch: usize,
    pub n_params: usize,
    // NODE fields:
    pub dim_in: usize,
    pub dim_state: usize,
    pub dim_out: usize,
    pub loss: String,
    pub has_encoder: bool,
    // Recurrent fields:
    pub seq_len: usize,
    pub hidden: usize,
    pub rollout_steps: usize,
    pub cell: String,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path:?}"))?;

        let kind = j.get("kind")?.as_str()?.to_string();
        let mut artifacts = BTreeMap::new();
        for (name, art) in j.get("artifacts")?.as_obj()? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file: dir.join(art.get("file")?.as_str()?),
                    inputs: art
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: art
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }

        let get_usize = |k: &str| -> usize {
            j.opt(k).and_then(|v| v.as_usize().ok()).unwrap_or(0)
        };
        let m = Manifest {
            name: j.get("name")?.as_str()?.to_string(),
            kind: kind.clone(),
            batch: j.get("batch")?.as_usize()?,
            n_params: j.get("n_params")?.as_usize()?,
            dim_in: get_usize("dim_in"),
            dim_state: get_usize("dim_state"),
            dim_out: get_usize("dim_out"),
            loss: j.opt("loss").and_then(|v| v.as_str().ok()).unwrap_or("mse").to_string(),
            has_encoder: j.opt("has_encoder").and_then(|v| v.as_bool().ok()).unwrap_or(false),
            seq_len: get_usize("seq_len"),
            hidden: get_usize("hidden"),
            rollout_steps: get_usize("rollout_steps"),
            cell: j.opt("cell").and_then(|v| v.as_str().ok()).unwrap_or("").to_string(),
            artifacts,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        let required: &[&str] = match self.kind.as_str() {
            "node" => &["init_params", "f_eval", "f_vjp", "decode_loss", "decode_loss_vjp"],
            "recurrent" => &["init_params", "loss_grad", "predict"],
            k => bail!("unknown manifest kind '{k}'"),
        };
        for r in required {
            if !self.artifacts.contains_key(*r) {
                bail!("manifest '{}' missing required artifact '{r}'", self.name);
            }
        }
        if self.kind == "node" {
            let f = &self.artifacts["f_eval"];
            if f.inputs[0].shape != [self.n_params] {
                bail!("f_eval theta shape mismatch: {:?}", f.inputs[0].shape);
            }
            if f.inputs[2].shape != [self.batch, self.dim_state] {
                bail!("f_eval z shape mismatch: {:?}", f.inputs[2].shape);
            }
        }
        Ok(())
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("model '{}' has no artifact '{name}'", self.name))
    }

    /// Flattened ODE state size (batch × dim_state).
    pub fn state_size(&self) -> usize {
        self.batch * self.dim_state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    fn minimal_node_manifest() -> String {
        let art = |ins: &str, outs: &str| {
            format!(r#"{{"file": "x.hlo.txt", "inputs": [{ins}], "outputs": [{outs}]}}"#)
        };
        let theta = r#"{"shape": [10], "dtype": "f32"}"#;
        let t = r#"{"shape": [1], "dtype": "f32"}"#;
        let z = r#"{"shape": [4, 3], "dtype": "f32"}"#;
        format!(
            r#"{{"name": "m", "kind": "node", "batch": 4, "n_params": 10,
                "dim_in": 3, "dim_state": 3, "dim_out": 2, "loss": "mse",
                "has_encoder": false,
                "artifacts": {{
                  "init_params": {},
                  "f_eval": {},
                  "f_vjp": {},
                  "decode_loss": {},
                  "decode_loss_vjp": {}
                }}}}"#,
            art(r#"{"shape": [1], "dtype": "i32"}"#, theta),
            art(&format!("{theta}, {t}, {z}"), z),
            art(&format!("{theta}, {t}, {z}, {z}"), &format!("{z}, {theta}")),
            art(&format!("{theta}, {z}, {z}"), z),
            art(&format!("{theta}, {z}, {z}"), z),
        )
    }

    #[test]
    fn loads_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("nodal_man_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(&dir, &minimal_node_manifest());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.name, "m");
        assert_eq!(m.batch, 4);
        assert_eq!(m.state_size(), 12);
        assert!(m.artifact("f_eval").is_ok());
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_missing_artifact() {
        let dir = std::env::temp_dir().join(format!("nodal_man2_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            r#"{"name": "m", "kind": "node", "batch": 4, "n_params": 10,
               "dim_state": 3, "artifacts": {}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_unknown_kind() {
        let dir = std::env::temp_dir().join(format!("nodal_man3_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        write_manifest(
            &dir,
            r#"{"name": "m", "kind": "tree", "batch": 1, "n_params": 1, "artifacts": {}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors_helpfully() {
        let dir = std::env::temp_dir().join("definitely_missing_nodal_dir");
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
