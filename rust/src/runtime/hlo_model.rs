//! AOT-compiled models as Rust objects.
//!
//! [`HloModel`] implements [`OdeFunc`] over the `f_eval` / `f_vjp` / `f_jvp`
//! executables, so every solver and every gradient method in [`crate::grad`]
//! runs the neural dynamics without touching Python. The encoder and loss
//! head round out the full forward/backward training step.

use std::cell::{Cell, RefCell};
use std::path::Path;
use std::rc::Rc;

use anyhow::{ensure, Context, Result};

use super::engine::{lit_f32_1d, lit_f32_2d, lit_f32_3d, lit_i32_1d, lit_time, Engine, Executable};
use super::manifest::Manifest;
use crate::ode::OdeFunc;

/// Supervision target for the loss head.
#[derive(Debug, Clone)]
pub enum Target {
    /// Class indices (xent loss), length `batch`.
    Classes(Vec<i32>),
    /// Regression targets (mse loss), length `batch × dim_out`.
    Values(Vec<f32>),
}

/// A Neural-ODE model backed by PJRT executables.
pub struct HloModel {
    pub manifest: Manifest,
    params: Vec<f32>,
    f_eval: Rc<Executable>,
    f_vjp: Rc<Executable>,
    f_jvp: Option<Rc<Executable>>,
    encode: Option<Rc<Executable>>,
    encode_vjp: Option<Rc<Executable>>,
    decode_loss: Rc<Executable>,
    decode_loss_vjp: Rc<Executable>,
    init: Rc<Executable>,
    /// PJRT dispatch counter (runtime_dispatch bench / Table 1 accounting).
    dispatches: Cell<usize>,
    /// Cached θ literal — parameters change once per optimizer step but are
    /// marshalled on *every* dispatch otherwise (§Perf iteration 2).
    theta_lit: RefCell<Option<xla::Literal>>,
}

impl HloModel {
    /// Load and compile all artifacts of `dir` (e.g. `artifacts/spiral`).
    pub fn load(engine: &mut Engine, dir: &Path) -> Result<HloModel> {
        let manifest = Manifest::load(dir)?;
        ensure!(
            manifest.kind == "node",
            "'{}' is a {} model, not a NODE model",
            manifest.name,
            manifest.kind
        );
        let mut get = |name: &str| -> Result<Rc<Executable>> {
            engine.load(&manifest.artifact(name)?.file)
        };
        let f_eval = get("f_eval")?;
        let f_vjp = get("f_vjp")?;
        let f_jvp = get("f_jvp").ok();
        let decode_loss = get("decode_loss")?;
        let decode_loss_vjp = get("decode_loss_vjp")?;
        let init = get("init_params")?;
        let (encode, encode_vjp) = if manifest.has_encoder {
            (Some(get("encode")?), Some(get("encode_vjp")?))
        } else {
            (None, None)
        };
        let params = vec![0.0f32; manifest.n_params];
        Ok(HloModel {
            manifest,
            params,
            f_eval,
            f_vjp,
            f_jvp,
            encode,
            encode_vjp,
            decode_loss,
            decode_loss_vjp,
            init,
            dispatches: Cell::new(0),
            theta_lit: RefCell::new(None),
        })
    }

    fn bump(&self) {
        self.dispatches.set(self.dispatches.get() + 1);
    }

    /// θ as a literal, rebuilt only after a parameter update.
    fn theta(&self) -> std::cell::Ref<'_, xla::Literal> {
        {
            let mut slot = self.theta_lit.borrow_mut();
            if slot.is_none() {
                *slot = Some(lit_f32_1d(&self.params));
            }
        }
        std::cell::Ref::map(self.theta_lit.borrow(), |o| o.as_ref().unwrap())
    }

    /// Number of PJRT executions since load (or the last reset).
    pub fn dispatches(&self) -> usize {
        self.dispatches.get()
    }

    pub fn reset_dispatches(&self) {
        self.dispatches.set(0);
    }

    /// (Re)initialize parameters from a seed, via the AOT `init_params`
    /// artifact (jax threefry — identical across Rust/Python).
    pub fn init_params(&mut self, seed: i32) -> Result<()> {
        self.bump();
        let outs = self.init.run_f32(&[&lit_i32_1d(&[seed])])?;
        ensure!(outs[0].len() == self.manifest.n_params);
        self.params = outs[0].clone();
        *self.theta_lit.borrow_mut() = None;
        Ok(())
    }

    fn lit_z(&self, z: &[f32]) -> Result<xla::Literal> {
        lit_f32_2d(z, self.manifest.batch, self.manifest.dim_state)
    }

    fn lit_y(&self, y: &Target) -> Result<xla::Literal> {
        match y {
            Target::Classes(c) => {
                ensure!(c.len() == self.manifest.batch, "class target length");
                ensure!(self.manifest.loss == "xent", "model expects {} loss", self.manifest.loss);
                Ok(lit_i32_1d(c))
            }
            Target::Values(v) => {
                ensure!(self.manifest.loss == "mse", "model expects {} loss", self.manifest.loss);
                lit_f32_2d(v, self.manifest.batch, self.manifest.dim_out)
            }
        }
    }

    /// Encoder: `x[B×Din] -> z0[B×D]`. Identity for encoder-less models.
    pub fn encode(&self, x: &[f32]) -> Result<Vec<f32>> {
        match &self.encode {
            None => Ok(x.to_vec()),
            Some(exe) => {
                self.bump();
                let lit =
                    lit_f32_2d(x, self.manifest.batch, self.manifest.dim_in)?;
                let theta = self.theta();
                Ok(exe.run_f32(&[&*theta, &lit])?.remove(0))
            }
        }
    }

    /// Accumulate `wᵀ ∂encode/∂θ` into `dtheta`.
    pub fn encode_vjp_accum(&self, x: &[f32], w: &[f32], dtheta: &mut [f32]) -> Result<()> {
        let Some(exe) = &self.encode_vjp else { return Ok(()) };
        self.bump();
        let theta = self.theta();
        let xl = lit_f32_2d(x, self.manifest.batch, self.manifest.dim_in)?;
        let wl = self.lit_z(w)?;
        let outs = exe.run_f32(&[&*theta, &xl, &wl])?;
        for (d, g) in dtheta.iter_mut().zip(&outs[0]) {
            *d += g;
        }
        Ok(())
    }

    /// Loss head: `(loss, pred[B×Dout])`.
    pub fn decode_loss(&self, z: &[f32], y: &Target) -> Result<(f64, Vec<f32>)> {
        self.bump();
        let theta = self.theta();
        let (zl, yl) = (self.lit_z(z)?, self.lit_y(y)?);
        let outs = self.decode_loss.run_f32(&[&*theta, &zl, &yl])?;
        Ok((outs[0][0] as f64, outs[1].clone()))
    }

    /// Loss head VJP: `(dL/dzT[B×D], loss)`, accumulating `dL/dθ_head` into
    /// `dtheta`.
    pub fn decode_loss_vjp(
        &self,
        z: &[f32],
        y: &Target,
        dtheta: &mut [f32],
    ) -> Result<(Vec<f32>, f64)> {
        self.bump();
        let theta = self.theta();
        let (zl, yl) = (self.lit_z(z)?, self.lit_y(y)?);
        let outs = self.decode_loss_vjp.run_f32(&[&*theta, &zl, &yl])?;
        let dz = outs[0].clone();
        for (d, g) in dtheta.iter_mut().zip(&outs[1]) {
            *d += g;
        }
        Ok((dz, outs[2][0] as f64))
    }

    /// Class predictions from logits/preds.
    pub fn argmax_classes(pred: &[f32], classes: usize) -> Vec<usize> {
        pred.chunks(classes)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

impl OdeFunc for HloModel {
    fn dim(&self) -> usize {
        self.manifest.state_size()
    }

    fn n_params(&self) -> usize {
        self.manifest.n_params
    }

    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        self.bump();
        let theta = self.theta();
        let (tl, zl) = (lit_time(t), self.lit_z(z).unwrap());
        let outs = self
            .f_eval
            .run_f32(&[&*theta, &tl, &zl])
            .expect("f_eval failed");
        dz.copy_from_slice(&outs[0]);
    }

    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.bump();
        let theta = self.theta();
        let (tl, zl, wl) = (lit_time(t), self.lit_z(z).unwrap(), self.lit_z(w).unwrap());
        let outs = self
            .f_vjp
            .run_f32(&[&*theta, &tl, &zl, &wl])
            .expect("f_vjp failed");
        wjz.copy_from_slice(&outs[0]);
        for (d, g) in wjp.iter_mut().zip(&outs[1]) {
            *d += g;
        }
    }

    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        let Some(exe) = &self.f_jvp else {
            // fall back to finite differences from the trait default
            return crate::ode::func::OdeFunc::jvp(&DefaultJvp(self), t, z, v, out);
        };
        self.bump();
        let theta = self.theta();
        let (tl, zl, vl) = (lit_time(t), self.lit_z(z).unwrap(), self.lit_z(v).unwrap());
        let outs = exe
            .run_f32(&[&*theta, &tl, &zl, &vl])
            .expect("f_jvp failed");
        out.copy_from_slice(&outs[0]);
    }

    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        assert_eq!(p.len(), self.manifest.n_params);
        self.params.copy_from_slice(p);
        *self.theta_lit.borrow_mut() = None;
    }
}

/// Shim to reach the trait-default finite-difference jvp without recursion.
struct DefaultJvp<'a>(&'a HloModel);
impl OdeFunc for DefaultJvp<'_> {
    fn dim(&self) -> usize {
        self.0.dim()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        self.0.eval(t, z, dz)
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], a: &mut [f32], b: &mut [f32]) {
        self.0.vjp(t, z, w, a, b)
    }
}

// ---------------------------------------------------------------------------
// Recurrent baselines (LSTM / GRU / RNN)
// ---------------------------------------------------------------------------

/// A sequence baseline trained by whole-graph AOT autodiff (paper Tables 4/5).
pub struct RecurrentBaseline {
    pub manifest: Manifest,
    pub params: Vec<f32>,
    loss_grad: Rc<Executable>,
    predict: Rc<Executable>,
    rollout: Option<Rc<Executable>>,
    init: Rc<Executable>,
}

impl RecurrentBaseline {
    pub fn load(engine: &mut Engine, dir: &Path) -> Result<RecurrentBaseline> {
        let manifest = Manifest::load(dir)?;
        ensure!(
            manifest.kind == "recurrent",
            "'{}' is not a recurrent model",
            manifest.name
        );
        let loss_grad = engine.load(&manifest.artifact("loss_grad")?.file)?;
        let predict = engine.load(&manifest.artifact("predict")?.file)?;
        let rollout = manifest
            .artifacts
            .get("rollout")
            .map(|a| engine.load(&a.file))
            .transpose()?;
        let init = engine.load(&manifest.artifact("init_params")?.file)?;
        let params = vec![0.0f32; manifest.n_params];
        Ok(RecurrentBaseline { manifest, params, loss_grad, predict, rollout, init })
    }

    pub fn init_params(&mut self, seed: i32) -> Result<()> {
        let outs = self.init.run_f32(&[&lit_i32_1d(&[seed])])?;
        self.params = outs[0].clone();
        Ok(())
    }

    /// `(loss, dθ)` for one batch `x[B,T,Din]`, `y[B,T,Dout]`.
    pub fn loss_grad(&self, x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
        let m = &self.manifest;
        let (tl, xl, yl) = (
            lit_f32_1d(&self.params),
            lit_f32_3d(x, m.batch, m.seq_len, m.dim_in)?,
            lit_f32_3d(y, m.batch, m.seq_len, m.dim_out)?,
        );
        let outs = self.loss_grad.run_f32(&[&tl, &xl, &yl])?;
        Ok((outs[0][0] as f64, outs[1].clone()))
    }

    /// One-step-ahead predictions `[B,T,Dout]`.
    pub fn predict(&self, x: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let (tl, xl) = (lit_f32_1d(&self.params), lit_f32_3d(x, m.batch, m.seq_len, m.dim_in)?);
        let outs = self.predict.run_f32(&[&tl, &xl])?;
        Ok(outs[0].clone())
    }

    /// Autoregressive rollout `[B, rollout_steps, Dout]` from `x0[B,Din]`.
    pub fn rollout(&self, x0: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let exe = self
            .rollout
            .as_ref()
            .with_context(|| format!("model '{}' has no rollout artifact", m.name))?;
        let (tl, xl) = (lit_f32_1d(&self.params), lit_f32_2d(x0, m.batch, m.dim_in)?);
        let outs = exe.run_f32(&[&tl, &xl])?;
        Ok(outs[0].clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_classes_rows() {
        let pred = [0.1f32, 0.9, 0.3, 0.2, 0.1, 0.05];
        assert_eq!(HloModel::argmax_classes(&pred, 3), vec![1, 0]);
    }

    #[test]
    fn target_variants() {
        let t = Target::Classes(vec![1, 0]);
        match t {
            Target::Classes(c) => assert_eq!(c.len(), 2),
            _ => unreachable!(),
        }
    }
    // Full load/execute tests need artifacts: rust/tests/runtime_round_trip.rs.
}
