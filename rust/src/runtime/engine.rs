//! PJRT CPU client + compiled-executable cache + literal marshalling.
//!
//! HLO **text** is the interchange format (see python/compile/aot.py): the
//! text parser reassigns instruction ids, avoiding the 64-bit-id proto
//! incompatibility between jax ≥ 0.5 and xla_extension 0.5.1.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// A compiled artifact ready to run.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name, for error messages.
    pub name: String,
}

impl Executable {
    /// Execute with the given inputs; returns the flattened tuple outputs.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// result literal is always a tuple (possibly of one element). Accepts
    /// owned or borrowed literals so callers can mix cached inputs (the θ
    /// literal) with per-call ones.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let res = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let lit = res[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of '{}'", self.name))?;
        Ok(lit.to_tuple()?)
    }

    /// Run and decode every output as an f32 vector.
    pub fn run_f32<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?
            .into_iter()
            .map(|l| {
                l.to_vec::<f32>()
                    .with_context(|| format!("decoding f32 output of '{}'", self.name))
            })
            .collect()
    }
}

/// Owns the PJRT client and a cache of compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, std::rc::Rc<Executable>>,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: HashMap::new() })
    }

    /// Platform string (e.g. "cpu") — useful for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file (cached by path).
    pub fn load(&mut self, path: &Path) -> Result<std::rc::Rc<Executable>> {
        if let Some(e) = self.cache.get(path) {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        let name = path.file_stem().unwrap_or_default().to_string_lossy().into_owned();
        let rc = std::rc::Rc::new(Executable { exe, name });
        self.cache.insert(path.to_path_buf(), rc.clone());
        Ok(rc)
    }
}

// ---------------------------------------------------------------------------
// Literal marshalling helpers
// ---------------------------------------------------------------------------

/// f32 slice -> rank-1 literal.
pub fn lit_f32_1d(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 slice -> rank-2 literal `[rows, cols]`.
pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    assert_eq!(v.len(), rows * cols);
    Ok(xla::Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// f32 slice -> rank-3 literal.
pub fn lit_f32_3d(v: &[f32], a: usize, b: usize, c: usize) -> Result<xla::Literal> {
    assert_eq!(v.len(), a * b * c);
    Ok(xla::Literal::vec1(v).reshape(&[a as i64, b as i64, c as i64])?)
}

/// i32 slice -> rank-1 literal.
pub fn lit_i32_1d(v: &[i32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Scalar time value as the `t[1]` artifact input.
pub fn lit_time(t: f64) -> xla::Literal {
    xla::Literal::vec1(&[t as f32])
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-level round-trip tests live in rust/tests/runtime_round_trip.rs
    // (they need artifacts). Here: marshalling only.

    #[test]
    fn literal_shapes() {
        let l = lit_f32_2d(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(l.element_count(), 6);
        let back = l.to_vec::<f32>().unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    #[should_panic]
    fn literal_shape_mismatch_panics() {
        let _ = lit_f32_2d(&[1.0; 5], 2, 3);
    }

    #[test]
    fn time_literal() {
        let l = lit_time(0.25);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![0.25]);
    }
}
