//! Bounded multi-producer channel used for both the submission queue
//! (request intake → batch former) and the work queue (formed batches →
//! worker shard).
//!
//! Unlike `std::sync::mpsc`, pushes on a full channel fail immediately —
//! that is the server's backpressure primitive: admission control turns a
//! full submission queue into [`super::ServeError::Overloaded`] instead of
//! letting the queue grow without bound. Closing the channel wakes all
//! waiters; receivers drain whatever is left before observing the close, so
//! shutdown never drops accepted work.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
    /// Set by [`Channel::kick`]; makes the next `recv_all` return even with
    /// nothing to deliver, so the receiver re-checks its out-of-band state
    /// (the batcher's drain flag).
    kicked: bool,
}

/// A bounded MPMC queue with blocking receives and a non-blocking,
/// fail-on-full send.
pub struct Channel<T> {
    inner: Mutex<Inner<T>>,
    ready: Condvar,
    capacity: usize,
}

/// Outcome of a receive: whether the channel can still produce more items.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelState {
    /// More items may arrive.
    Open,
    /// Closed and fully drained — no item will ever arrive again.
    Closed,
}

impl<T> Channel<T> {
    /// A channel that holds at most `capacity` items (clamped to ≥ 1).
    pub fn bounded(capacity: usize) -> Self {
        Channel {
            inner: Mutex::new(Inner { items: VecDeque::new(), closed: false, kicked: false }),
            ready: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// An effectively unbounded channel (used for the internal work queue,
    /// whose depth is already bounded by submission admission control).
    pub fn unbounded() -> Self {
        Self::bounded(usize::MAX)
    }

    /// Push one item. Fails with `Err(item)` when the channel is full or
    /// closed (the item is handed back so the caller can report it).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut g = self.inner.lock().unwrap();
        if g.closed || g.items.len() >= self.capacity {
            return Err(item);
        }
        g.items.push_back(item);
        self.ready.notify_one();
        Ok(())
    }

    /// Move every queued item into `buf`, blocking until at least one item
    /// is available, the channel is closed and empty, or `timeout` expires.
    /// Returns [`ChannelState::Closed`] only once the channel is closed
    /// *and* drained.
    pub fn recv_all(&self, timeout: Option<Duration>, buf: &mut Vec<T>) -> ChannelState {
        let mut g = self.inner.lock().unwrap();
        if g.items.is_empty() && !g.closed && !g.kicked {
            let pending = |s: &mut Inner<T>| s.items.is_empty() && !s.closed && !s.kicked;
            match timeout {
                Some(d) => {
                    let (guard, _) = self.ready.wait_timeout_while(g, d, pending).unwrap();
                    g = guard;
                }
                None => {
                    g = self.ready.wait_while(g, pending).unwrap();
                }
            }
        }
        g.kicked = false;
        buf.extend(g.items.drain(..));
        if g.closed && buf.is_empty() {
            ChannelState::Closed
        } else {
            ChannelState::Open
        }
    }

    /// Receive one item, blocking indefinitely; `None` once the channel is
    /// closed and drained.
    pub fn recv_one(&self) -> Option<T> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if let Some(item) = g.items.pop_front() {
                return Some(item);
            }
            if g.closed {
                return None;
            }
            g = self.ready.wait(g).unwrap();
        }
    }

    /// Close the channel: future pushes fail, waiters wake, queued items
    /// remain receivable.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.ready.notify_all();
    }

    /// Wake a blocked [`Channel::recv_all`] without delivering anything
    /// (used by `drain()` to get the batch former's attention). The wake-up
    /// is latched, so a kick that lands just before the receiver starts
    /// waiting is not lost.
    pub fn kick(&self) {
        self.inner.lock().unwrap().kicked = true;
        self.ready.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_fails_when_full() {
        let ch = Channel::bounded(2);
        assert!(ch.push(1).is_ok());
        assert!(ch.push(2).is_ok());
        assert_eq!(ch.push(3), Err(3), "third push must bounce");
        let mut buf = Vec::new();
        assert_eq!(ch.recv_all(Some(Duration::ZERO), &mut buf), ChannelState::Open);
        assert_eq!(buf, vec![1, 2]);
        assert!(ch.push(3).is_ok(), "space freed after receive");
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let ch = Channel::bounded(8);
        ch.push(1).unwrap();
        ch.push(2).unwrap();
        ch.close();
        assert_eq!(ch.push(3), Err(3), "push after close fails");
        assert_eq!(ch.recv_one(), Some(1), "queued items survive close");
        assert_eq!(ch.recv_one(), Some(2));
        assert_eq!(ch.recv_one(), None);
        let mut buf = Vec::new();
        assert_eq!(ch.recv_all(None, &mut buf), ChannelState::Closed);
        assert!(buf.is_empty());
    }

    #[test]
    fn recv_all_wakes_on_push() {
        let ch = std::sync::Arc::new(Channel::bounded(4));
        let c2 = ch.clone();
        let t = std::thread::spawn(move || {
            let mut buf = Vec::new();
            let state = c2.recv_all(None, &mut buf);
            (state, buf)
        });
        ch.push(42).unwrap();
        let (state, buf) = t.join().unwrap();
        assert_eq!(state, ChannelState::Open);
        assert_eq!(buf, vec![42]);
    }

    #[test]
    fn recv_all_timeout_returns_open_and_empty() {
        let ch: Channel<u32> = Channel::bounded(4);
        let mut buf = Vec::new();
        let state = ch.recv_all(Some(Duration::from_millis(1)), &mut buf);
        assert_eq!(state, ChannelState::Open);
        assert!(buf.is_empty());
    }

    #[test]
    fn kick_is_latched_and_consumed() {
        let ch: Channel<u32> = Channel::bounded(4);
        ch.kick(); // lands before the receiver waits — must not be lost
        let mut buf = Vec::new();
        let state = ch.recv_all(None, &mut buf);
        assert_eq!(state, ChannelState::Open);
        assert!(buf.is_empty(), "kick delivers nothing");
        // Consumed: the next receive with a timeout waits it out normally.
        let state = ch.recv_all(Some(Duration::from_millis(1)), &mut buf);
        assert_eq!(state, ChannelState::Open);
        assert!(buf.is_empty());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let ch = Channel::bounded(0);
        assert!(ch.push(7).is_ok());
        assert_eq!(ch.push(8), Err(8));
    }
}
