//! The batch former: coalesces pending requests into batches under a
//! `max_batch_size` / `max_queue_delay` policy — a batch flushes on
//! whichever trips first.
//!
//! [`BatchFormer`] is a **pure state machine**: it never reads a clock, never
//! sleeps, and never spawns a thread. Every method takes the current time as
//! an argument, so the flush policies are unit-testable with a
//! [`super::ManualClock`]-driven virtual timeline and no timing assertions.
//! The server's batcher thread drives the same code with wall time.
//!
//! Grouping: requests coalesce by [`BatchKey`] (same dynamics, solver,
//! direction, tolerance, gradient/observation flags, QoS lane); the initial
//! state *and the whole span `[t0, t1]`* may differ inside a batch —
//! exactly the axes `integrate_batch_tspans` vectorizes over without
//! changing any per-sample result. Under mixed-span traffic this is the
//! occupancy lever: requests that previously split into one group per start
//! time or endpoint now fill one batch.
//!
//! ## QoS: lanes and per-tenant quotas
//!
//! Emission (the order flushed batches leave the former, and hence the
//! order workers pick them up) is **ordering-only QoS** — a ready batch is
//! never withheld, so no policy here can deadlock or starve traffic
//! outright. Two levers:
//!
//! 1. **Priority lanes**: every ready [`Lane::Interactive`] batch is
//!    emitted before any [`Lane::Batch`] one.
//! 2. **Per-tenant deficit round-robin** within a lane: tenants (one per
//!    dynamics id) take turns; each visit grants `quantum` credits (capped
//!    at `max_deficit`), and a tenant emits its oldest ready batches while
//!    its deficit covers their sample counts. One hot dynamics with a deep
//!    backlog therefore *interleaves* with light tenants instead of
//!    emitting its whole backlog first — a flooded key's batches and a
//!    victim key's singleton alternate at roughly `quantum` samples per
//!    turn.
//!
//! Each round visits tenants ordered by their queue-head trigger time, so
//! when every tenant is under its quantum the emission degenerates to pure
//! trigger order — light traffic sees no reordering at all.

use super::request::{BatchKey, Lane, ResponseSlot, SolveRequest};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// A request waiting to be batched, with its completion slot, submit time
/// (in the server clock's timeline), and the projected checkpoint bytes
/// charged against the admission memory budget (released on completion).
pub struct Pending {
    pub req: SolveRequest,
    pub slot: Arc<ResponseSlot>,
    pub submitted: Duration,
    pub cost: usize,
}

/// Why a batch left the former.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The group reached `max_batch_size`.
    Size,
    /// The group's oldest request aged past `max_queue_delay`.
    Deadline,
    /// An explicit drain/shutdown flushed it regardless of policy.
    Drain,
}

/// A group of co-batchable requests ready to execute.
pub struct FormedBatch {
    pub key: BatchKey,
    pub items: Vec<Pending>,
    pub reason: FlushReason,
    /// When the flush condition tripped (virtual/server time).
    pub triggered_at: Duration,
    /// How many DRR rounds this batch sat at its tenant's queue head
    /// unaffordable (cost above the deficit) before emission. Zero on light
    /// traffic; surfaces in the `batch_form` trace span as the QoS-induced
    /// share of the batch's wait.
    pub deferred: u64,
}

struct Group {
    key: BatchKey,
    items: Vec<Pending>,
    /// Submit time of the group's oldest member — the deadline anchor.
    oldest: Duration,
}

/// Coalesces [`Pending`] requests into [`FormedBatch`]es.
pub struct BatchFormer {
    max_batch: usize,
    max_delay: Duration,
    /// DRR credits granted per tenant visit (samples).
    quantum: usize,
    /// Cap on accumulated credits (≥ `max_batch`, so a full batch always
    /// eventually fits — a smaller cap could starve a tenant forever).
    max_deficit: usize,
    groups: Vec<Group>,
    ready: VecDeque<FormedBatch>,
}

impl BatchFormer {
    /// Default QoS quotas: `quantum` 32 samples per tenant visit, deficit
    /// capped at 128 (see [`BatchFormer::with_quota`]).
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        Self::with_quota(max_batch, max_delay, 32, 128)
    }

    /// Full constructor with explicit per-tenant DRR quotas. `quantum` is
    /// clamped to ≥ 1 and `max_deficit` to ≥ `max(max_batch, quantum)` —
    /// below `max_batch` a full batch could never afford emission.
    pub fn with_quota(
        max_batch: usize,
        max_delay: Duration,
        quantum: usize,
        max_deficit: usize,
    ) -> Self {
        let max_batch = max_batch.max(1);
        let quantum = quantum.max(1);
        BatchFormer {
            max_batch,
            max_delay,
            quantum,
            max_deficit: max_deficit.max(max_batch).max(quantum),
            groups: Vec::new(),
            ready: VecDeque::new(),
        }
    }

    /// Add a request at time `now`. If its group reaches `max_batch_size`
    /// the group is moved to the ready queue immediately (size flush).
    ///
    /// The group's flush deadline anchors to the oldest member's **submit**
    /// time, not its push time: a request that sat in the submission queue
    /// (e.g. while the batcher slept toward another group's deadline) has
    /// already spent part of its `max_queue_delay` budget.
    pub fn push(&mut self, pending: Pending, now: Duration) {
        let key = pending.req.batch_key();
        let submitted = pending.submitted;
        let idx = match self.groups.iter().position(|g| g.key == key) {
            Some(i) => {
                let g = &mut self.groups[i];
                g.items.push(pending);
                g.oldest = g.oldest.min(submitted);
                i
            }
            None => {
                self.groups.push(Group { key, items: vec![pending], oldest: submitted });
                self.groups.len() - 1
            }
        };
        if self.groups[idx].items.len() >= self.max_batch {
            let g = self.groups.remove(idx);
            self.ready.push_back(FormedBatch {
                key: g.key,
                items: g.items,
                reason: FlushReason::Size,
                triggered_at: now,
                deferred: 0,
            });
        }
    }

    /// Collect every batch whose flush condition has tripped by `now`
    /// (size-flushed batches and groups whose oldest member has waited at
    /// least `max_queue_delay`), in QoS emission order: interactive lane
    /// first, deficit round-robin across tenants within a lane, trigger
    /// order within a tenant (see the module docs).
    pub fn poll(&mut self, now: Duration) -> Vec<FormedBatch> {
        let due = self.collect_due(now);
        self.schedule(due)
    }

    /// Size/deadline-tripped batches in raw trigger order (pre-QoS).
    fn collect_due(&mut self, now: Duration) -> Vec<FormedBatch> {
        let mut out: Vec<FormedBatch> = self.ready.drain(..).collect();
        let mut i = 0;
        while i < self.groups.len() {
            let deadline = self.groups[i].oldest + self.max_delay;
            if deadline <= now {
                let g = self.groups.remove(i);
                out.push(FormedBatch {
                    key: g.key,
                    items: g.items,
                    reason: FlushReason::Deadline,
                    triggered_at: deadline,
                    deferred: 0,
                });
            } else {
                i += 1;
            }
        }
        out
    }

    /// Flush everything regardless of policy (explicit `drain()`/shutdown).
    /// The flushed batches leave in the same QoS emission order as
    /// [`BatchFormer::poll`].
    pub fn drain(&mut self, now: Duration) -> Vec<FormedBatch> {
        let mut out = self.collect_due(now);
        for g in self.groups.drain(..) {
            out.push(FormedBatch {
                key: g.key,
                items: g.items,
                reason: FlushReason::Drain,
                triggered_at: now,
                deferred: 0,
            });
        }
        self.schedule(out)
    }

    /// QoS emission ordering over one flush set: stable-sort by trigger
    /// time, split by lane (interactive first), then deficit round-robin
    /// across tenants within each lane. Ordering-only: every input batch is
    /// emitted, exactly once.
    fn schedule(&self, mut batches: Vec<FormedBatch>) -> Vec<FormedBatch> {
        batches.sort_by_key(|b| b.triggered_at);
        if batches.len() <= 1 {
            return batches;
        }
        let mut interactive = Vec::new();
        let mut bulk = Vec::new();
        for b in batches {
            match b.key.lane {
                Lane::Interactive => interactive.push(b),
                Lane::Batch => bulk.push(b),
            }
        }
        let mut out = Vec::with_capacity(interactive.len() + bulk.len());
        self.drr_emit(interactive, &mut out);
        self.drr_emit(bulk, &mut out);
        out
    }

    /// Deficit round-robin over one lane's batches. Tenants are keyed by
    /// dynamics id; each round visits tenants in queue-head trigger order
    /// and grants `quantum` credits per visit, a batch costing its sample
    /// count. The deficit cap (`max_deficit ≥ max_batch`) guarantees every
    /// head batch becomes affordable within finitely many rounds, so this
    /// always terminates having emitted everything.
    fn drr_emit(&self, batches: Vec<FormedBatch>, out: &mut Vec<FormedBatch>) {
        // Per-tenant FIFO queues in first-appearance (trigger) order.
        let mut queues: Vec<(String, VecDeque<FormedBatch>, usize)> = Vec::new();
        for b in batches {
            match queues.iter_mut().find(|(t, _, _)| *t == b.key.dynamics) {
                Some((_, q, _)) => q.push_back(b),
                None => {
                    let tenant = b.key.dynamics.clone();
                    queues.push((tenant, VecDeque::from([b]), 0));
                }
            }
        }
        while queues.iter().any(|(_, q, _)| !q.is_empty()) {
            // Stable sort: ties in trigger time keep first-appearance order.
            let mut order: Vec<usize> =
                (0..queues.len()).filter(|&i| !queues[i].1.is_empty()).collect();
            order.sort_by_key(|&i| queues[i].1.front().map(|b| b.triggered_at));
            for i in order {
                let (_, q, deficit) = &mut queues[i];
                *deficit = deficit.saturating_add(self.quantum).min(self.max_deficit);
                loop {
                    let cost = match q.front() {
                        Some(head) => head.items.len(),
                        None => break,
                    };
                    if cost > *deficit {
                        // The head couldn't afford this round; remember the
                        // QoS-induced wait for the batch_form trace span.
                        if let Some(head) = q.front_mut() {
                            head.deferred += 1;
                        }
                        break;
                    }
                    if let Some(b) = q.pop_front() {
                        *deficit -= cost;
                        out.push(b);
                    }
                }
                // An emptied tenant keeps no credit: deficits measure
                // *backlogged* entitlement, not a savings account.
                if q.is_empty() {
                    *deficit = 0;
                }
            }
        }
    }

    /// Earliest instant at which [`BatchFormer::poll`] would flush something
    /// new; `None` when no partial group is pending.
    pub fn next_deadline(&self) -> Option<Duration> {
        if !self.ready.is_empty() {
            return Some(Duration::ZERO); // already flushable
        }
        self.groups.iter().map(|g| g.oldest + self.max_delay).min()
    }

    /// Requests currently held (partial groups + ready batches).
    pub fn pending(&self) -> usize {
        self.groups.iter().map(|g| g.items.len()).sum::<usize>()
            + self.ready.iter().map(|b| b.items.len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty() && self.ready.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::ResponseHandle;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn pending(dynamics: &str, t1: f64, submitted: Duration) -> Pending {
        let (_, slot) = ResponseHandle::new();
        Pending {
            req: SolveRequest::adaptive(dynamics, 0.0, t1, vec![1.0, 0.0], 1e-6, 1e-8).unwrap(),
            slot,
            submitted,
            cost: 0,
        }
    }

    fn pending_lane(dynamics: &str, lane: Lane, submitted: Duration) -> Pending {
        let (_, slot) = ResponseHandle::new();
        Pending {
            req: SolveRequest::builder(dynamics)
                .span(0.0, 5.0)
                .state(vec![1.0, 0.0])
                .adaptive(1e-6, 1e-8)
                .priority(lane)
                .build()
                .unwrap(),
            slot,
            submitted,
            cost: 0,
        }
    }

    #[test]
    fn size_flush_trips_before_deadline() {
        let mut f = BatchFormer::new(3, ms(100));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 5.0, ms(1)), ms(1));
        assert!(f.poll(ms(1)).is_empty(), "under size and under deadline");
        f.push(pending("vdp", 5.0, ms(2)), ms(2));
        let out = f.poll(ms(2));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Size);
        assert_eq!(out[0].items.len(), 3);
        assert!(f.is_empty());
    }

    #[test]
    fn deadline_flush_fires_when_oldest_ages_out() {
        let mut f = BatchFormer::new(16, ms(10));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 5.0, ms(4)), ms(4));
        assert!(f.poll(ms(9)).is_empty(), "deadline anchored to the OLDEST member");
        let out = f.poll(ms(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Deadline);
        assert_eq!(out[0].items.len(), 2, "the young member rides along");
        assert_eq!(out[0].triggered_at, ms(10));
    }

    #[test]
    fn flush_order_is_trigger_order() {
        // Group A (vdp) deadline-expires at t=10; group B (other dynamics)
        // size-flushes at t=5. Poll at t=12 must yield B before A — with
        // every tenant under its DRR quantum the QoS ordering degenerates
        // to pure trigger order.
        let mut f = BatchFormer::new(2, ms(10));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("linear", 7.0, ms(4)), ms(4));
        f.push(pending("linear", 7.0, ms(5)), ms(5)); // B size-flushes here
        let out = f.poll(ms(12));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].reason, FlushReason::Size);
        assert_eq!(out[0].triggered_at, ms(5));
        assert_eq!(out[1].reason, FlushReason::Deadline);
        assert_eq!(out[1].triggered_at, ms(10));
    }

    /// Requests that differ only in `t1` are one group now: the former must
    /// size-flush them together instead of keeping one group per span.
    #[test]
    fn mixed_spans_coalesce_into_one_group() {
        let mut f = BatchFormer::new(3, ms(100));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 7.0, ms(1)), ms(1));
        assert!(f.poll(ms(1)).is_empty(), "one group of two, under size");
        f.push(pending("vdp", 3.0, ms(2)), ms(2));
        let out = f.poll(ms(2));
        assert_eq!(out.len(), 1, "three spans, one batch");
        assert_eq!(out[0].reason, FlushReason::Size);
        assert_eq!(out[0].items.len(), 3);
        let t1s: Vec<f64> = out[0].items.iter().map(|p| p.req.t1).collect();
        assert_eq!(t1s, vec![5.0, 7.0, 3.0], "per-request endpoints preserved in order");
    }

    #[test]
    fn deadline_anchored_to_submit_time_not_push_time() {
        let mut f = BatchFormer::new(8, ms(10));
        // Submitted at t=0, but only pushed into the former at t=6 (it sat
        // in the submission queue): the deadline is still submit + delay.
        f.push(pending("vdp", 5.0, ms(0)), ms(6));
        assert_eq!(f.next_deadline(), Some(ms(10)));
        assert!(f.poll(ms(9)).is_empty());
        let out = f.poll(ms(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Deadline);
        assert_eq!(out[0].triggered_at, ms(10));
    }

    #[test]
    fn incompatible_requests_never_share_a_batch() {
        let mut f = BatchFormer::new(2, ms(100));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("linear", 5.0, ms(0)), ms(0));
        assert!(f.poll(ms(0)).is_empty(), "two singleton groups, neither full");
        let out = f.drain(ms(1));
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|b| b.items.len() == 1));
        assert!(out.iter().all(|b| b.reason == FlushReason::Drain));
    }

    #[test]
    fn drain_flushes_partial_groups() {
        let mut f = BatchFormer::new(8, ms(1000));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 5.0, ms(1)), ms(1));
        assert_eq!(f.pending(), 2);
        let out = f.drain(ms(2));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items.len(), 2);
        assert_eq!(out[0].reason, FlushReason::Drain);
        assert!(f.is_empty());
        assert_eq!(f.pending(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest_group() {
        let mut f = BatchFormer::new(8, ms(10));
        assert_eq!(f.next_deadline(), None);
        f.push(pending("vdp", 5.0, ms(3)), ms(3));
        f.push(pending("linear", 5.0, ms(1)), ms(1));
        assert_eq!(f.next_deadline(), Some(ms(11)), "min over groups");
        let flushed = f.poll(ms(11));
        assert_eq!(flushed.len(), 1);
        assert_eq!(f.next_deadline(), Some(ms(13)), "remaining group");
    }

    #[test]
    fn zero_delay_flushes_on_first_poll() {
        let mut f = BatchFormer::new(64, Duration::ZERO);
        f.push(pending("vdp", 5.0, ms(7)), ms(7));
        let out = f.poll(ms(7));
        assert_eq!(out.len(), 1, "max_queue_delay = 0 degenerates to flush-per-poll");
        assert_eq!(out[0].reason, FlushReason::Deadline);
    }

    #[test]
    fn size_one_flushes_immediately_on_push() {
        let mut f = BatchFormer::new(1, ms(1000));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        let out = f.poll(ms(0));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Size);
    }

    /// Lane priority: every ready interactive batch is emitted before any
    /// batch-lane one, even when the batch-lane batch triggered earlier.
    #[test]
    fn interactive_lane_emits_before_batch_lane() {
        let mut f = BatchFormer::new(8, ms(1000));
        f.push(pending_lane("vdp", Lane::Batch, ms(0)), ms(0));
        f.push(pending_lane("vdp", Lane::Interactive, ms(5)), ms(5));
        let out = f.drain(ms(10));
        assert_eq!(out.len(), 2, "lanes never share a batch");
        assert_eq!(out[0].key.lane, Lane::Interactive);
        assert_eq!(out[1].key.lane, Lane::Batch);
    }

    /// Per-tenant DRR: a hot tenant with a deep ready backlog interleaves
    /// with a light tenant instead of emitting its whole backlog first —
    /// the victim's singleton comes out after at most ~quantum samples of
    /// hot traffic, not after all of it.
    #[test]
    fn drr_interleaves_hot_tenant_with_victim() {
        // quantum 2 = one hot batch per visit; deficit cap 4.
        let mut f = BatchFormer::with_quota(2, ms(1000), 2, 4);
        for i in 0..6 {
            f.push(pending("vdp", 5.0, ms(i)), ms(i)); // 3 size-flushed batches
        }
        f.push(pending("linear", 5.0, ms(6)), ms(6)); // the victim singleton
        let out = f.drain(ms(7));
        assert_eq!(out.len(), 4);
        let tenants: Vec<&str> = out.iter().map(|b| b.key.dynamics.as_str()).collect();
        assert_eq!(
            tenants,
            vec!["vdp", "linear", "vdp", "vdp"],
            "round 1 grants the hot tenant one batch (quantum 2), then the victim"
        );
        // Within the hot tenant, its own batches stay in trigger order.
        let hot: Vec<Duration> = out
            .iter()
            .filter(|b| b.key.dynamics == "vdp")
            .map(|b| b.triggered_at)
            .collect();
        assert!(hot.windows(2).all(|w| w[0] <= w[1]));
        // QoS-induced waits are attributed: the hot tenant's second and
        // third batches each sat out one round unaffordable; everything
        // emitted on its first eligible round reports zero.
        let deferred: Vec<u64> = out.iter().map(|b| b.deferred).collect();
        assert_eq!(deferred, vec![0, 0, 1, 1]);
    }

    /// The deficit cap floors at `max_batch`: even with an absurdly small
    /// configured cap, a full batch eventually affords emission (otherwise
    /// its tenant would starve forever on its own backlog).
    #[test]
    fn deficit_cap_never_starves_a_full_batch() {
        let mut f = BatchFormer::with_quota(8, ms(1000), 1, 1); // cap clamps to 8
        for i in 0..8 {
            f.push(pending("vdp", 5.0, ms(i)), ms(i)); // one size-flushed batch of 8
        }
        f.push(pending("linear", 5.0, ms(8)), ms(8));
        let out = f.drain(ms(9));
        assert_eq!(out.len(), 2);
        assert_eq!(
            out.iter().filter(|b| b.key.dynamics == "vdp").count(),
            1,
            "the full batch must be emitted"
        );
    }
}
