//! The batch former: coalesces pending requests into batches under a
//! `max_batch_size` / `max_queue_delay` policy — a batch flushes on
//! whichever trips first.
//!
//! [`BatchFormer`] is a **pure state machine**: it never reads a clock, never
//! sleeps, and never spawns a thread. Every method takes the current time as
//! an argument, so the flush policies are unit-testable with a
//! [`super::ManualClock`]-driven virtual timeline and no timing assertions.
//! The server's batcher thread drives the same code with wall time.
//!
//! Grouping: requests coalesce by [`BatchKey`] (same dynamics, solver,
//! direction, tolerance, gradient flag); the initial state *and the whole
//! span `[t0, t1]`* may differ inside a batch — exactly the axes
//! `integrate_batch_tspans` vectorizes over without changing any
//! per-sample result. Under mixed-span traffic this is the occupancy
//! lever: requests that previously split into one group per start time or
//! endpoint now fill one batch.

use super::request::{BatchKey, ResponseSlot, SolveRequest};
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Duration;

/// A request waiting to be batched, with its completion slot, submit time
/// (in the server clock's timeline), and the projected checkpoint bytes
/// charged against the admission memory budget (released on completion).
pub struct Pending {
    pub req: SolveRequest,
    pub slot: Arc<ResponseSlot>,
    pub submitted: Duration,
    pub cost: usize,
}

/// Why a batch left the former.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FlushReason {
    /// The group reached `max_batch_size`.
    Size,
    /// The group's oldest request aged past `max_queue_delay`.
    Deadline,
    /// An explicit drain/shutdown flushed it regardless of policy.
    Drain,
}

/// A group of co-batchable requests ready to execute.
pub struct FormedBatch {
    pub key: BatchKey,
    pub items: Vec<Pending>,
    pub reason: FlushReason,
    /// When the flush condition tripped (virtual/server time).
    pub triggered_at: Duration,
}

struct Group {
    key: BatchKey,
    items: Vec<Pending>,
    /// Submit time of the group's oldest member — the deadline anchor.
    oldest: Duration,
}

/// Coalesces [`Pending`] requests into [`FormedBatch`]es.
pub struct BatchFormer {
    max_batch: usize,
    max_delay: Duration,
    groups: Vec<Group>,
    ready: VecDeque<FormedBatch>,
}

impl BatchFormer {
    pub fn new(max_batch: usize, max_delay: Duration) -> Self {
        BatchFormer {
            max_batch: max_batch.max(1),
            max_delay,
            groups: Vec::new(),
            ready: VecDeque::new(),
        }
    }

    /// Add a request at time `now`. If its group reaches `max_batch_size`
    /// the group is moved to the ready queue immediately (size flush).
    ///
    /// The group's flush deadline anchors to the oldest member's **submit**
    /// time, not its push time: a request that sat in the submission queue
    /// (e.g. while the batcher slept toward another group's deadline) has
    /// already spent part of its `max_queue_delay` budget.
    pub fn push(&mut self, pending: Pending, now: Duration) {
        let key = pending.req.batch_key();
        let submitted = pending.submitted;
        let idx = match self.groups.iter().position(|g| g.key == key) {
            Some(i) => {
                let g = &mut self.groups[i];
                g.items.push(pending);
                g.oldest = g.oldest.min(submitted);
                i
            }
            None => {
                self.groups.push(Group { key, items: vec![pending], oldest: submitted });
                self.groups.len() - 1
            }
        };
        if self.groups[idx].items.len() >= self.max_batch {
            let g = self.groups.remove(idx);
            self.ready.push_back(FormedBatch {
                key: g.key,
                items: g.items,
                reason: FlushReason::Size,
                triggered_at: now,
            });
        }
    }

    /// Collect every batch whose flush condition has tripped by `now`:
    /// size-flushed batches (in the order they filled) and groups whose
    /// oldest member has waited at least `max_queue_delay`. Batches are
    /// returned in trigger order — a size flush that fired before another
    /// group's deadline comes out first.
    pub fn poll(&mut self, now: Duration) -> Vec<FormedBatch> {
        let mut out: Vec<FormedBatch> = self.ready.drain(..).collect();
        let mut i = 0;
        while i < self.groups.len() {
            let deadline = self.groups[i].oldest + self.max_delay;
            if deadline <= now {
                let g = self.groups.remove(i);
                out.push(FormedBatch {
                    key: g.key,
                    items: g.items,
                    reason: FlushReason::Deadline,
                    triggered_at: deadline,
                });
            } else {
                i += 1;
            }
        }
        out.sort_by_key(|b| b.triggered_at);
        out
    }

    /// Flush everything regardless of policy (explicit `drain()`/shutdown).
    pub fn drain(&mut self, now: Duration) -> Vec<FormedBatch> {
        let mut out = self.poll(now);
        for g in self.groups.drain(..) {
            out.push(FormedBatch {
                key: g.key,
                items: g.items,
                reason: FlushReason::Drain,
                triggered_at: now,
            });
        }
        out
    }

    /// Earliest instant at which [`BatchFormer::poll`] would flush something
    /// new; `None` when no partial group is pending.
    pub fn next_deadline(&self) -> Option<Duration> {
        if !self.ready.is_empty() {
            return Some(Duration::ZERO); // already flushable
        }
        self.groups.iter().map(|g| g.oldest + self.max_delay).min()
    }

    /// Requests currently held (partial groups + ready batches).
    pub fn pending(&self) -> usize {
        self.groups.iter().map(|g| g.items.len()).sum::<usize>()
            + self.ready.iter().map(|b| b.items.len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.groups.is_empty() && self.ready.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::ResponseHandle;

    fn ms(v: u64) -> Duration {
        Duration::from_millis(v)
    }

    fn pending(dynamics: &str, t1: f64, submitted: Duration) -> Pending {
        let (_, slot) = ResponseHandle::new();
        Pending {
            req: SolveRequest::adaptive(dynamics, 0.0, t1, vec![1.0, 0.0], 1e-6, 1e-8),
            slot,
            submitted,
            cost: 0,
        }
    }

    #[test]
    fn size_flush_trips_before_deadline() {
        let mut f = BatchFormer::new(3, ms(100));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 5.0, ms(1)), ms(1));
        assert!(f.poll(ms(1)).is_empty(), "under size and under deadline");
        f.push(pending("vdp", 5.0, ms(2)), ms(2));
        let out = f.poll(ms(2));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Size);
        assert_eq!(out[0].items.len(), 3);
        assert!(f.is_empty());
    }

    #[test]
    fn deadline_flush_fires_when_oldest_ages_out() {
        let mut f = BatchFormer::new(16, ms(10));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 5.0, ms(4)), ms(4));
        assert!(f.poll(ms(9)).is_empty(), "deadline anchored to the OLDEST member");
        let out = f.poll(ms(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Deadline);
        assert_eq!(out[0].items.len(), 2, "the young member rides along");
        assert_eq!(out[0].triggered_at, ms(10));
    }

    #[test]
    fn flush_order_is_trigger_order() {
        // Group A (vdp) deadline-expires at t=10; group B (other dynamics)
        // size-flushes at t=5. Poll at t=12 must yield B before A.
        let mut f = BatchFormer::new(2, ms(10));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("linear", 7.0, ms(4)), ms(4));
        f.push(pending("linear", 7.0, ms(5)), ms(5)); // B size-flushes here
        let out = f.poll(ms(12));
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].reason, FlushReason::Size);
        assert_eq!(out[0].triggered_at, ms(5));
        assert_eq!(out[1].reason, FlushReason::Deadline);
        assert_eq!(out[1].triggered_at, ms(10));
    }

    /// Requests that differ only in `t1` are one group now: the former must
    /// size-flush them together instead of keeping one group per span.
    #[test]
    fn mixed_spans_coalesce_into_one_group() {
        let mut f = BatchFormer::new(3, ms(100));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 7.0, ms(1)), ms(1));
        assert!(f.poll(ms(1)).is_empty(), "one group of two, under size");
        f.push(pending("vdp", 3.0, ms(2)), ms(2));
        let out = f.poll(ms(2));
        assert_eq!(out.len(), 1, "three spans, one batch");
        assert_eq!(out[0].reason, FlushReason::Size);
        assert_eq!(out[0].items.len(), 3);
        let t1s: Vec<f64> = out[0].items.iter().map(|p| p.req.t1).collect();
        assert_eq!(t1s, vec![5.0, 7.0, 3.0], "per-request endpoints preserved in order");
    }

    #[test]
    fn deadline_anchored_to_submit_time_not_push_time() {
        let mut f = BatchFormer::new(8, ms(10));
        // Submitted at t=0, but only pushed into the former at t=6 (it sat
        // in the submission queue): the deadline is still submit + delay.
        f.push(pending("vdp", 5.0, ms(0)), ms(6));
        assert_eq!(f.next_deadline(), Some(ms(10)));
        assert!(f.poll(ms(9)).is_empty());
        let out = f.poll(ms(10));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Deadline);
        assert_eq!(out[0].triggered_at, ms(10));
    }

    #[test]
    fn incompatible_requests_never_share_a_batch() {
        let mut f = BatchFormer::new(2, ms(100));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("linear", 5.0, ms(0)), ms(0));
        assert!(f.poll(ms(0)).is_empty(), "two singleton groups, neither full");
        let out = f.drain(ms(1));
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|b| b.items.len() == 1));
        assert!(out.iter().all(|b| b.reason == FlushReason::Drain));
    }

    #[test]
    fn drain_flushes_partial_groups() {
        let mut f = BatchFormer::new(8, ms(1000));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        f.push(pending("vdp", 5.0, ms(1)), ms(1));
        assert_eq!(f.pending(), 2);
        let out = f.drain(ms(2));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items.len(), 2);
        assert_eq!(out[0].reason, FlushReason::Drain);
        assert!(f.is_empty());
        assert_eq!(f.pending(), 0);
    }

    #[test]
    fn next_deadline_tracks_oldest_group() {
        let mut f = BatchFormer::new(8, ms(10));
        assert_eq!(f.next_deadline(), None);
        f.push(pending("vdp", 5.0, ms(3)), ms(3));
        f.push(pending("linear", 5.0, ms(1)), ms(1));
        assert_eq!(f.next_deadline(), Some(ms(11)), "min over groups");
        let flushed = f.poll(ms(11));
        assert_eq!(flushed.len(), 1);
        assert_eq!(f.next_deadline(), Some(ms(13)), "remaining group");
    }

    #[test]
    fn zero_delay_flushes_on_first_poll() {
        let mut f = BatchFormer::new(64, Duration::ZERO);
        f.push(pending("vdp", 5.0, ms(7)), ms(7));
        let out = f.poll(ms(7));
        assert_eq!(out.len(), 1, "max_queue_delay = 0 degenerates to flush-per-poll");
        assert_eq!(out[0].reason, FlushReason::Deadline);
    }

    #[test]
    fn size_one_flushes_immediately_on_push() {
        let mut f = BatchFormer::new(1, ms(1000));
        f.push(pending("vdp", 5.0, ms(0)), ms(0));
        let out = f.poll(ms(0));
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].reason, FlushReason::Size);
    }
}
