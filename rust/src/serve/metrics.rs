//! Server instrumentation: request counters, queue-wait and service latency
//! quantiles, batch-size histogram, and per-request NFE aggregates.
//!
//! Latencies go into fixed log₂-bucketed histograms (64 buckets over
//! nanoseconds — sub-µs to ~584 years), so recording is O(1), lock-free
//! reads are unnecessary, and quantiles are bucket-resolution estimates
//! (within a factor of 2), which is what a serving dashboard needs; exact
//! per-request numbers ride on every [`super::SolveResponse`].

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

const BUCKETS: usize = 64;

/// Log₂-bucketed histogram over `u64` magnitudes (latency nanoseconds,
/// NFE counts). Bucket `i` holds values `v` with `floor(log2(v)) == i`
/// (bucket 0 also holds 0).
#[derive(Debug)]
pub struct LogHistogram {
    counts: [AtomicU64; BUCKETS],
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

// std ships `Default` for arrays only up to length 32; build the 64 buckets
// explicitly.
impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl LogHistogram {
    fn bucket(v: u64) -> usize {
        (63 - (v | 1).leading_zeros()) as usize
    }

    pub fn record(&self, v: u64) {
        self.counts[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Exact sum of every recorded value.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile estimate: the upper edge of the first bucket whose
    /// cumulative count reaches `q` of the total (0 when empty). Accurate to
    /// bucket resolution (a factor of 2).
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        let mut last_nonempty = 0usize;
        for (i, c) in self.counts.iter().enumerate() {
            let c = c.load(Ordering::Relaxed);
            if c > 0 {
                last_nonempty = i;
            }
            cum += c;
            if cum >= target {
                return upper_edge(i);
            }
        }
        // Racing concurrent records can make `total` momentarily exceed the
        // bucket sum (both are Relaxed); bound the answer by the largest
        // recorded bucket instead of falling through to u64::MAX.
        upper_edge(last_nonempty)
    }
}

fn upper_edge(bucket: usize) -> u64 {
    if bucket >= 63 {
        u64::MAX
    } else {
        (2u64 << bucket) - 1
    }
}

/// Quantile summary of one latency histogram, in milliseconds.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    fn from_hist(h: &LogHistogram) -> Self {
        let ns_to_ms = 1e-6;
        LatencySummary {
            count: h.count(),
            mean_ms: h.mean() * ns_to_ms,
            p50_ms: h.quantile(0.50) as f64 * ns_to_ms,
            p95_ms: h.quantile(0.95) as f64 * ns_to_ms,
            p99_ms: h.quantile(0.99) as f64 * ns_to_ms,
            max_ms: h.max() as f64 * ns_to_ms,
        }
    }
}

/// Live metrics shared by the server, its workers, and callers.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Requests accepted by admission control.
    pub submitted: AtomicU64,
    /// Requests answered successfully.
    pub completed: AtomicU64,
    /// Requests bounced with `Overloaded`.
    pub rejected: AtomicU64,
    /// Requests answered with a solver error.
    pub failed: AtomicU64,
    /// Forward `f` evaluations served (per-request exact, summed).
    pub nfe: LogHistogram,
    /// Time between submit and batch execution start.
    pub queue_wait: LogHistogram,
    /// Time between batch execution start and response delivery.
    pub service: LogHistogram,
    /// `batch_sizes[s]` counts executed batches of size `s` (index 0 unused).
    batch_sizes: Mutex<Vec<u64>>,
    /// Per-tenant (per-dynamics-key) queue-wait histograms — the fairness
    /// signal for the QoS scheduler: under a single-tenant flood, the other
    /// tenants' p99 here must stay bounded.
    per_key_queue_wait: Mutex<BTreeMap<String, LogHistogram>>,
}

impl ServeMetrics {
    pub fn record_batch(&self, size: usize) {
        let mut sizes = self.batch_sizes.lock().unwrap();
        if sizes.len() <= size {
            sizes.resize(size + 1, 0);
        }
        sizes[size] += 1;
    }

    pub fn record_request(
        &self,
        tenant: &str,
        queue_wait: Duration,
        service: Duration,
        nfe: usize,
    ) {
        let qw_ns = queue_wait.as_nanos().min(u64::MAX as u128) as u64;
        self.queue_wait.record(qw_ns);
        self.service.record(service.as_nanos().min(u64::MAX as u128) as u64);
        self.nfe.record(nfe as u64);
        self.per_key_queue_wait
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_default()
            .record(qw_ns);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of every aggregate.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let per_key_queue_wait: Vec<(String, LatencySummary)> = self
            .per_key_queue_wait
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), LatencySummary::from_hist(h)))
            .collect();
        let sizes = self.batch_sizes.lock().unwrap().clone();
        // The size histogram is the single source of truth for batch counts.
        let batches: u64 = sizes.iter().sum();
        let weighted: u64 = sizes.iter().enumerate().map(|(s, c)| s as u64 * c).sum();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 { 0.0 } else { weighted as f64 / batches as f64 },
            batch_sizes: sizes,
            queue_wait: LatencySummary::from_hist(&self.queue_wait),
            service: LatencySummary::from_hist(&self.service),
            per_key_queue_wait,
            nfe_total: self.nfe.sum(),
            nfe_mean: self.nfe.mean(),
            nfe_max: self.nfe.max(),
        }
    }
}

/// Frozen view of [`ServeMetrics`] for reporting.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// `batch_sizes[s]` = executed batches of size `s`.
    pub batch_sizes: Vec<u64>,
    pub queue_wait: LatencySummary,
    pub service: LatencySummary,
    /// Per-tenant queue-wait summaries, sorted by tenant key.
    pub per_key_queue_wait: Vec<(String, LatencySummary)>,
    pub nfe_total: u64,
    pub nfe_mean: f64,
    pub nfe_max: u64,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: {} submitted, {} completed, {} rejected, {} failed",
            self.submitted, self.completed, self.rejected, self.failed
        )?;
        writeln!(
            f,
            "batches:  {} executed, mean size {:.2}, sizes {:?}",
            self.batches, self.mean_batch_size, self.batch_sizes
        )?;
        let q = &self.queue_wait;
        writeln!(
            f,
            "queue-wait ms: mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}",
            q.mean_ms, q.p50_ms, q.p95_ms, q.p99_ms, q.max_ms
        )?;
        for (k, q) in &self.per_key_queue_wait {
            writeln!(
                f,
                "  [{k}] queue-wait ms: p50 {:.3}  p99 {:.3}  max {:.3}  (n={})",
                q.p50_ms, q.p99_ms, q.max_ms, q.count
            )?;
        }
        let s = &self.service;
        writeln!(
            f,
            "service ms:    mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}",
            s.mean_ms, s.p50_ms, s.p95_ms, s.p99_ms, s.max_ms
        )?;
        write!(
            f,
            "nfe: total {}, mean {:.1}/request, max {}",
            self.nfe_total, self.nfe_mean, self.nfe_max
        )
    }
}

// ---------------------------------------------------------------------------
// Wire codecs: `dist::dispatch` pulls each shard's snapshot over TCP and
// aggregates them into one report. Counts are exact in a JSON number (they
// would have to exceed 2^53 events to lose precision); the quantile fields
// are already lossy summaries, so plain numbers are the honest encoding.

fn u64_field(v: &crate::util::json::Json, key: &str) -> anyhow::Result<u64> {
    Ok(v.get(key)?.as_usize()? as u64)
}

fn latency_to_json(l: &LatencySummary) -> crate::util::json::Json {
    crate::util::json::obj(vec![
        ("count", (l.count as usize).into()),
        ("mean_ms", l.mean_ms.into()),
        ("p50_ms", l.p50_ms.into()),
        ("p95_ms", l.p95_ms.into()),
        ("p99_ms", l.p99_ms.into()),
        ("max_ms", l.max_ms.into()),
    ])
}

fn latency_from_json(v: &crate::util::json::Json) -> anyhow::Result<LatencySummary> {
    Ok(LatencySummary {
        count: u64_field(v, "count")?,
        mean_ms: v.get("mean_ms")?.as_f64()?,
        p50_ms: v.get("p50_ms")?.as_f64()?,
        p95_ms: v.get("p95_ms")?.as_f64()?,
        p99_ms: v.get("p99_ms")?.as_f64()?,
        max_ms: v.get("max_ms")?.as_f64()?,
    })
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        let sizes: Vec<usize> = self.batch_sizes.iter().map(|&s| s as usize).collect();
        crate::util::json::obj(vec![
            ("submitted", (self.submitted as usize).into()),
            ("completed", (self.completed as usize).into()),
            ("rejected", (self.rejected as usize).into()),
            ("failed", (self.failed as usize).into()),
            ("batches", (self.batches as usize).into()),
            ("mean_batch_size", self.mean_batch_size.into()),
            ("batch_sizes", sizes.into()),
            ("queue_wait", latency_to_json(&self.queue_wait)),
            ("service", latency_to_json(&self.service)),
            (
                "per_key_queue_wait",
                crate::util::json::Json::Obj(
                    self.per_key_queue_wait
                        .iter()
                        .map(|(k, l)| (k.clone(), latency_to_json(l)))
                        .collect(),
                ),
            ),
            ("nfe_total", (self.nfe_total as usize).into()),
            ("nfe_mean", self.nfe_mean.into()),
            ("nfe_max", (self.nfe_max as usize).into()),
        ])
    }

    pub fn from_json(v: &crate::util::json::Json) -> anyhow::Result<MetricsSnapshot> {
        let mut batch_sizes = Vec::new();
        for s in v.get("batch_sizes")?.as_arr()? {
            batch_sizes.push(s.as_usize()? as u64);
        }
        Ok(MetricsSnapshot {
            submitted: u64_field(v, "submitted")?,
            completed: u64_field(v, "completed")?,
            rejected: u64_field(v, "rejected")?,
            failed: u64_field(v, "failed")?,
            batches: u64_field(v, "batches")?,
            mean_batch_size: v.get("mean_batch_size")?.as_f64()?,
            batch_sizes,
            queue_wait: latency_from_json(v.get("queue_wait")?)?,
            service: latency_from_json(v.get("service")?)?,
            per_key_queue_wait: {
                let mut per_key = Vec::new();
                for (k, l) in v.get("per_key_queue_wait")?.as_obj()? {
                    per_key.push((k.clone(), latency_from_json(l)?));
                }
                per_key
            },
            nfe_total: u64_field(v, "nfe_total")?,
            nfe_mean: v.get("nfe_mean")?.as_f64()?,
            nfe_max: u64_field(v, "nfe_max")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(LogHistogram::bucket(0), 0);
        assert_eq!(LogHistogram::bucket(1), 0);
        assert_eq!(LogHistogram::bucket(2), 1);
        assert_eq!(LogHistogram::bucket(3), 1);
        assert_eq!(LogHistogram::bucket(4), 2);
        assert_eq!(LogHistogram::bucket(u64::MAX), 63);
    }

    #[test]
    fn quantiles_are_bucket_accurate() {
        let h = LogHistogram::default();
        for _ in 0..99 {
            h.record(1_000_000); // 1 ms
        }
        h.record(100_000_000); // one 100 ms outlier
        let p50 = h.quantile(0.50);
        assert!((1_000_000..=2_097_152).contains(&p50), "p50 within 1ms bucket: {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 <= 2_097_152, "p99 still in the 1ms bucket: {p99}");
        let p999 = h.quantile(0.9999);
        assert!(p999 >= 67_108_864, "tail quantile sees the outlier: {p999}");
        assert_eq!(h.max(), 100_000_000);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LogHistogram::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn batch_size_histogram_and_mean() {
        let m = ServeMetrics::default();
        m.record_batch(4);
        m.record_batch(4);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.batches, 3);
        assert_eq!(s.batch_sizes[4], 2);
        assert_eq!(s.batch_sizes[2], 1);
        assert!((s.mean_batch_size - 10.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn request_recording_rolls_up() {
        let m = ServeMetrics::default();
        m.record_request("vdp", Duration::from_micros(10), Duration::from_millis(2), 120);
        m.record_request("vdp", Duration::from_micros(30), Duration::from_millis(4), 80);
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.queue_wait.count, 2);
        assert_eq!(s.nfe_total, 200, "exact sum, not mean*count round-trip");
        assert!((s.nfe_mean - 100.0).abs() < 1e-9);
        assert_eq!(s.nfe_max, 120);
        assert!(s.service.p50_ms > 0.0);
        let _ = format!("{s}"); // Display must not panic
    }

    /// Per-tenant queue waits are split by key and sorted: one slow tenant's
    /// latency shows up under its key only, not smeared over the others.
    #[test]
    fn per_key_queue_wait_splits_tenants() {
        let m = ServeMetrics::default();
        for _ in 0..4 {
            m.record_request("hot", Duration::from_millis(50), Duration::from_millis(1), 10);
        }
        m.record_request("calm", Duration::from_micros(20), Duration::from_millis(1), 10);
        let s = m.snapshot();
        assert_eq!(s.per_key_queue_wait.len(), 2);
        assert_eq!(s.per_key_queue_wait[0].0, "calm", "sorted by key");
        assert_eq!(s.per_key_queue_wait[1].0, "hot");
        let (calm, hot) = (s.per_key_queue_wait[0].1, s.per_key_queue_wait[1].1);
        assert_eq!(calm.count, 1);
        assert_eq!(hot.count, 4);
        assert!(calm.p99_ms < 1.0, "calm tenant keeps its own p99: {}", calm.p99_ms);
        assert!(hot.p99_ms >= 50.0, "hot tenant owns its latency: {}", hot.p99_ms);
        // The global histogram still aggregates everything.
        assert_eq!(s.queue_wait.count, 5);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let m = ServeMetrics::default();
        m.record_request("vdp", Duration::from_micros(10), Duration::from_millis(2), 120);
        m.record_request("linear", Duration::from_micros(30), Duration::from_millis(4), 80);
        m.record_batch(2);
        let s = m.snapshot();
        let j = crate::util::json::Json::parse(&s.to_json().to_string()).unwrap();
        let back = MetricsSnapshot::from_json(&j).unwrap();
        assert_eq!(back.completed, s.completed);
        assert_eq!(back.batches, s.batches);
        assert_eq!(back.batch_sizes, s.batch_sizes);
        assert_eq!(back.nfe_total, s.nfe_total);
        assert_eq!(back.nfe_max, s.nfe_max);
        assert_eq!(back.queue_wait.count, s.queue_wait.count);
        assert_eq!(back.service.p99_ms.to_bits(), s.service.p99_ms.to_bits());
        assert_eq!(back.mean_batch_size.to_bits(), s.mean_batch_size.to_bits());
        assert_eq!(back.per_key_queue_wait.len(), 2);
        for ((bk, bl), (sk, sl)) in back.per_key_queue_wait.iter().zip(&s.per_key_queue_wait) {
            assert_eq!(bk, sk);
            assert_eq!(bl.count, sl.count);
            assert_eq!(bl.p99_ms.to_bits(), sl.p99_ms.to_bits());
        }
        assert!(MetricsSnapshot::from_json(&crate::util::json::Json::Null).is_err());
    }
}
