//! Server instrumentation: request counters, queue-wait and service latency
//! quantiles, batch-size histogram, and per-request NFE aggregates.
//!
//! Latencies go into fixed log₂-bucketed histograms (64 buckets over
//! nanoseconds — sub-µs to ~584 years), so recording is O(1), lock-free
//! reads are unnecessary, and quantiles are bucket-resolution estimates
//! (within a factor of 2), which is what a serving dashboard needs; exact
//! per-request numbers ride on every [`super::SolveResponse`].

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

const BUCKETS: usize = 64;

/// Log₂-bucketed histogram over `u64` magnitudes (latency nanoseconds,
/// NFE counts). Bucket `i` holds values `v` with `floor(log2(v)) == i`
/// (bucket 0 also holds 0).
#[derive(Debug)]
pub struct LogHistogram {
    counts: [AtomicU64; BUCKETS],
    total: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

// std ships `Default` for arrays only up to length 32; build the 64 buckets
// explicitly.
impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            total: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl LogHistogram {
    fn bucket(v: u64) -> usize {
        (63 - (v | 1).leading_zeros()) as usize
    }

    pub fn record(&self, v: u64) {
        self.counts[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Exact sum of every recorded value.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Quantile estimate: the upper edge of the first bucket whose
    /// cumulative count reaches `q` of the total (0 when empty). Accurate to
    /// bucket resolution (a factor of 2).
    pub fn quantile(&self, q: f64) -> u64 {
        quantile_from_buckets(&self.bucket_counts(), self.count(), q)
    }

    /// Point-in-time copy of the raw per-bucket counts — the lossless form
    /// that crosses the dist wire so merged fleet quantiles are exactly as
    /// accurate as single-shard ones.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

/// Shared quantile kernel over a bucket-count vector: the upper edge of
/// the first bucket whose cumulative count reaches `q` of `n`. Racing
/// concurrent records can make `n` momentarily exceed the bucket sum (all
/// loads are Relaxed); the answer is then bounded by the largest recorded
/// bucket instead of falling through to `u64::MAX`.
fn quantile_from_buckets(buckets: &[u64], n: u64, q: f64) -> u64 {
    if n == 0 {
        return 0;
    }
    let target = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).max(1);
    let mut cum = 0u64;
    let mut last_nonempty = 0usize;
    for (i, &c) in buckets.iter().enumerate() {
        if c > 0 {
            last_nonempty = i;
        }
        cum += c;
        if cum >= target {
            return upper_edge(i);
        }
    }
    upper_edge(last_nonempty)
}

fn upper_edge(bucket: usize) -> u64 {
    if bucket >= 63 {
        u64::MAX
    } else {
        (2u64 << bucket) - 1
    }
}

/// Quantile summary of one latency histogram, in milliseconds, carrying
/// the **raw parts** (count, exact sum, max, per-bucket counts) it was
/// derived from. The parts are what cross the dist wire: two summaries
/// merge bucket-wise ([`LatencySummary::merge`]) and re-derive their
/// quantiles, so a fleet-merged p99 is exactly as accurate as a
/// single-shard one — not a lossy max-bound over pre-computed floats.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    /// Exact sum of recorded nanoseconds.
    pub sum_ns: u64,
    /// Largest recorded value in nanoseconds.
    pub max_ns: u64,
    /// Raw log₂ bucket counts (empty encodes as all-zero).
    pub buckets: Vec<u64>,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// The single constructor every path funnels through (live snapshot,
    /// wire decode, cross-shard merge): derived fields are a pure function
    /// of the parts, so equal parts give bit-equal summaries.
    pub fn from_parts(count: u64, sum_ns: u64, max_ns: u64, buckets: Vec<u64>) -> Self {
        let ns_to_ms = 1e-6;
        let mean_ns = if count == 0 { 0.0 } else { sum_ns as f64 / count as f64 };
        LatencySummary {
            mean_ms: mean_ns * ns_to_ms,
            p50_ms: quantile_from_buckets(&buckets, count, 0.50) as f64 * ns_to_ms,
            p95_ms: quantile_from_buckets(&buckets, count, 0.95) as f64 * ns_to_ms,
            p99_ms: quantile_from_buckets(&buckets, count, 0.99) as f64 * ns_to_ms,
            max_ms: max_ns as f64 * ns_to_ms,
            count,
            sum_ns,
            max_ns,
            buckets,
        }
    }

    fn from_hist(h: &LogHistogram) -> Self {
        Self::from_parts(h.count(), h.sum(), h.max(), h.bucket_counts())
    }

    /// Bucket-wise exact merge: counts and sums add, maxima take the max,
    /// buckets add slot-wise; quantiles are re-derived from the merged
    /// buckets. Merging the per-shard summaries of two disjoint streams
    /// yields bit-exactly the summary of one histogram fed both streams.
    pub fn merge(&self, other: &LatencySummary) -> LatencySummary {
        let n = self.buckets.len().max(other.buckets.len());
        let mut buckets = vec![0u64; n];
        for (i, slot) in buckets.iter_mut().enumerate() {
            *slot = self.buckets.get(i).copied().unwrap_or(0)
                + other.buckets.get(i).copied().unwrap_or(0);
        }
        LatencySummary::from_parts(
            self.count + other.count,
            self.sum_ns.saturating_add(other.sum_ns),
            self.max_ns.max(other.max_ns),
            buckets,
        )
    }
}

/// Raw-unit summary of a count histogram (requests per connection):
/// the same bucket-exact parts as [`LatencySummary`], without the
/// nanosecond→ms interpretation.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CountSummary {
    pub count: u64,
    /// Exact sum of recorded values.
    pub sum: u64,
    pub max: u64,
    /// Raw log₂ bucket counts.
    pub buckets: Vec<u64>,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
}

impl CountSummary {
    /// Derived fields are a pure function of the parts (see
    /// [`LatencySummary::from_parts`]).
    pub fn from_parts(count: u64, sum: u64, max: u64, buckets: Vec<u64>) -> Self {
        CountSummary {
            mean: if count == 0 { 0.0 } else { sum as f64 / count as f64 },
            p50: quantile_from_buckets(&buckets, count, 0.50),
            p99: quantile_from_buckets(&buckets, count, 0.99),
            count,
            sum,
            max,
            buckets,
        }
    }

    fn from_hist(h: &LogHistogram) -> Self {
        Self::from_parts(h.count(), h.sum(), h.max(), h.bucket_counts())
    }

    /// Bucket-wise exact merge (see [`LatencySummary::merge`]).
    pub fn merge(&self, other: &CountSummary) -> CountSummary {
        let n = self.buckets.len().max(other.buckets.len());
        let mut buckets = vec![0u64; n];
        for (i, slot) in buckets.iter_mut().enumerate() {
            *slot = self.buckets.get(i).copied().unwrap_or(0)
                + other.buckets.get(i).copied().unwrap_or(0);
        }
        CountSummary::from_parts(
            self.count + other.count,
            self.sum.saturating_add(other.sum),
            self.max.max(other.max),
            buckets,
        )
    }
}

/// Keep-alive connection accounting for the HTTP front door: owned by the
/// [`HttpServer`](super::HttpServer) (not the `SolveServer` — several
/// front ends can share one solver), overlaid onto the snapshot at render
/// time.
#[derive(Debug, Default)]
pub struct ConnMetrics {
    /// Connections accepted since startup.
    pub accepted: AtomicU64,
    /// Connections currently open.
    pub active: AtomicU64,
    /// Keep-alive reuses: requests served on an already-used connection.
    pub reused: AtomicU64,
    /// Requests served per connection, recorded at connection close.
    pub reqs_per_conn: LogHistogram,
}

impl ConnMetrics {
    /// Record one request served on a connection that has already served
    /// `served_before` requests.
    pub fn record_request(&self, served_before: u64) {
        if served_before > 0 {
            self.reused.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Connection opened.
    pub fn opened(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
        self.active.fetch_add(1, Ordering::Relaxed);
    }

    /// Connection closed after serving `served` requests.
    pub fn closed(&self, served: u64) {
        self.active.fetch_sub(1, Ordering::Relaxed);
        self.reqs_per_conn.record(served);
    }

    /// Overlay these counters onto a solver snapshot.
    pub fn annotate(&self, snap: &mut MetricsSnapshot) {
        snap.http_conns_accepted = self.accepted.load(Ordering::Relaxed);
        snap.http_conns_active = self.active.load(Ordering::Relaxed);
        snap.http_conns_reused = self.reused.load(Ordering::Relaxed);
        snap.http_reqs_per_conn = CountSummary::from_hist(&self.reqs_per_conn);
    }
}

/// Live metrics shared by the server, its workers, and callers.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    /// Requests accepted by admission control.
    pub submitted: AtomicU64,
    /// Requests answered successfully.
    pub completed: AtomicU64,
    /// Requests bounced with `Overloaded`.
    pub rejected: AtomicU64,
    /// Requests answered with a solver error.
    pub failed: AtomicU64,
    /// Forward `f` evaluations served (per-request exact, summed).
    pub nfe: LogHistogram,
    /// Time between submit and batch execution start.
    pub queue_wait: LogHistogram,
    /// Time between batch execution start and response delivery.
    pub service: LogHistogram,
    /// `batch_sizes[s]` counts executed batches of size `s` (index 0 unused).
    batch_sizes: Mutex<Vec<u64>>,
    /// Per-tenant (per-dynamics-key) queue-wait histograms — the fairness
    /// signal for the QoS scheduler: under a single-tenant flood, the other
    /// tenants' p99 here must stay bounded.
    per_key_queue_wait: Mutex<BTreeMap<String, LogHistogram>>,
}

impl ServeMetrics {
    pub fn record_batch(&self, size: usize) {
        let mut sizes = self.batch_sizes.lock().unwrap();
        if sizes.len() <= size {
            sizes.resize(size + 1, 0);
        }
        sizes[size] += 1;
    }

    pub fn record_request(
        &self,
        tenant: &str,
        queue_wait: Duration,
        service: Duration,
        nfe: usize,
    ) {
        let qw_ns = queue_wait.as_nanos().min(u64::MAX as u128) as u64;
        self.queue_wait.record(qw_ns);
        self.service.record(service.as_nanos().min(u64::MAX as u128) as u64);
        self.nfe.record(nfe as u64);
        self.per_key_queue_wait
            .lock()
            .unwrap()
            .entry(tenant.to_string())
            .or_default()
            .record(qw_ns);
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time copy of every aggregate.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let per_key_queue_wait: Vec<(String, LatencySummary)> = self
            .per_key_queue_wait
            .lock()
            .unwrap()
            .iter()
            .map(|(k, h)| (k.clone(), LatencySummary::from_hist(h)))
            .collect();
        let sizes = self.batch_sizes.lock().unwrap().clone();
        // The size histogram is the single source of truth for batch counts.
        let batches: u64 = sizes.iter().sum();
        let weighted: u64 = sizes.iter().enumerate().map(|(s, c)| s as u64 * c).sum();
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            batches,
            mean_batch_size: if batches == 0 { 0.0 } else { weighted as f64 / batches as f64 },
            batch_sizes: sizes,
            queue_wait: LatencySummary::from_hist(&self.queue_wait),
            service: LatencySummary::from_hist(&self.service),
            per_key_queue_wait,
            nfe_total: self.nfe.sum(),
            nfe_mean: self.nfe.mean(),
            nfe_max: self.nfe.max(),
            http_conns_accepted: 0,
            http_conns_active: 0,
            http_conns_reused: 0,
            http_reqs_per_conn: CountSummary::default(),
        }
    }
}

/// Frozen view of [`ServeMetrics`] for reporting.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub failed: u64,
    pub batches: u64,
    pub mean_batch_size: f64,
    /// `batch_sizes[s]` = executed batches of size `s`.
    pub batch_sizes: Vec<u64>,
    pub queue_wait: LatencySummary,
    pub service: LatencySummary,
    /// Per-tenant queue-wait summaries, sorted by tenant key.
    pub per_key_queue_wait: Vec<(String, LatencySummary)>,
    pub nfe_total: u64,
    pub nfe_mean: f64,
    pub nfe_max: u64,
    /// HTTP front-door connection counters. Zero unless a front door is
    /// attached and overlays them via [`ConnMetrics::annotate`].
    pub http_conns_accepted: u64,
    pub http_conns_active: u64,
    pub http_conns_reused: u64,
    /// Requests served per keep-alive connection (recorded at close).
    pub http_reqs_per_conn: CountSummary,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests: {} submitted, {} completed, {} rejected, {} failed",
            self.submitted, self.completed, self.rejected, self.failed
        )?;
        writeln!(
            f,
            "batches:  {} executed, mean size {:.2}, sizes {:?}",
            self.batches, self.mean_batch_size, self.batch_sizes
        )?;
        let q = &self.queue_wait;
        writeln!(
            f,
            "queue-wait ms: mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}",
            q.mean_ms, q.p50_ms, q.p95_ms, q.p99_ms, q.max_ms
        )?;
        for (k, q) in &self.per_key_queue_wait {
            writeln!(
                f,
                "  [{k}] queue-wait ms: p50 {:.3}  p99 {:.3}  max {:.3}  (n={})",
                q.p50_ms, q.p99_ms, q.max_ms, q.count
            )?;
        }
        let s = &self.service;
        writeln!(
            f,
            "service ms:    mean {:.3}  p50 {:.3}  p95 {:.3}  p99 {:.3}  max {:.3}",
            s.mean_ms, s.p50_ms, s.p95_ms, s.p99_ms, s.max_ms
        )?;
        write!(
            f,
            "nfe: total {}, mean {:.1}/request, max {}",
            self.nfe_total, self.nfe_mean, self.nfe_max
        )
    }
}

// ---------------------------------------------------------------------------
// Wire codecs: `dist::dispatch` pulls each shard's snapshot over TCP and
// aggregates them into one report. Counts are exact in a JSON number (they
// would have to exceed 2^53 events to lose precision); the quantile fields
// are already lossy summaries, so plain numbers are the honest encoding.

fn u64_field(v: &crate::util::json::Json, key: &str) -> anyhow::Result<u64> {
    Ok(v.get(key)?.as_usize()? as u64)
}

fn latency_to_json(l: &LatencySummary) -> crate::util::json::Json {
    // Only the raw parts cross the wire — exact u64s. The ms quantiles are
    // re-derived on decode through the same `from_parts`, so the decoded
    // summary is bit-identical AND two decoded summaries can merge without
    // quantile loss.
    let buckets: Vec<usize> = l.buckets.iter().map(|&b| b as usize).collect();
    crate::util::json::obj(vec![
        ("count", (l.count as usize).into()),
        ("sum_ns", (l.sum_ns as usize).into()),
        ("max_ns", (l.max_ns as usize).into()),
        ("buckets", buckets.into()),
    ])
}

fn latency_from_json(v: &crate::util::json::Json) -> anyhow::Result<LatencySummary> {
    let mut buckets = Vec::new();
    for b in v.get("buckets")?.as_arr()? {
        buckets.push(b.as_usize()? as u64);
    }
    Ok(LatencySummary::from_parts(
        u64_field(v, "count")?,
        u64_field(v, "sum_ns")?,
        u64_field(v, "max_ns")?,
        buckets,
    ))
}

fn count_to_json(c: &CountSummary) -> crate::util::json::Json {
    let buckets: Vec<usize> = c.buckets.iter().map(|&b| b as usize).collect();
    crate::util::json::obj(vec![
        ("count", (c.count as usize).into()),
        ("sum", (c.sum as usize).into()),
        ("max", (c.max as usize).into()),
        ("buckets", buckets.into()),
    ])
}

fn count_from_json(v: &crate::util::json::Json) -> anyhow::Result<CountSummary> {
    let mut buckets = Vec::new();
    for b in v.get("buckets")?.as_arr()? {
        buckets.push(b.as_usize()? as u64);
    }
    Ok(CountSummary::from_parts(
        u64_field(v, "count")?,
        u64_field(v, "sum")?,
        u64_field(v, "max")?,
        buckets,
    ))
}

/// Tolerant u64: missing key decodes as 0 so snapshots from peers predating
/// a field still parse (the additive-fields evolution rule, as in
/// [`super::wire`]).
fn u64_opt(v: &crate::util::json::Json, key: &str) -> anyhow::Result<u64> {
    match v.opt(key) {
        Some(x) => Ok(x.as_usize()? as u64),
        None => Ok(0),
    }
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        let sizes: Vec<usize> = self.batch_sizes.iter().map(|&s| s as usize).collect();
        crate::util::json::obj(vec![
            ("submitted", (self.submitted as usize).into()),
            ("completed", (self.completed as usize).into()),
            ("rejected", (self.rejected as usize).into()),
            ("failed", (self.failed as usize).into()),
            ("batches", (self.batches as usize).into()),
            ("mean_batch_size", self.mean_batch_size.into()),
            ("batch_sizes", sizes.into()),
            ("queue_wait", latency_to_json(&self.queue_wait)),
            ("service", latency_to_json(&self.service)),
            (
                "per_key_queue_wait",
                crate::util::json::Json::Obj(
                    self.per_key_queue_wait
                        .iter()
                        .map(|(k, l)| (k.clone(), latency_to_json(l)))
                        .collect(),
                ),
            ),
            ("nfe_total", (self.nfe_total as usize).into()),
            ("nfe_mean", self.nfe_mean.into()),
            ("nfe_max", (self.nfe_max as usize).into()),
            ("http_conns_accepted", (self.http_conns_accepted as usize).into()),
            ("http_conns_active", (self.http_conns_active as usize).into()),
            ("http_conns_reused", (self.http_conns_reused as usize).into()),
            ("http_reqs_per_conn", count_to_json(&self.http_reqs_per_conn)),
        ])
    }

    pub fn from_json(v: &crate::util::json::Json) -> anyhow::Result<MetricsSnapshot> {
        let mut batch_sizes = Vec::new();
        for s in v.get("batch_sizes")?.as_arr()? {
            batch_sizes.push(s.as_usize()? as u64);
        }
        Ok(MetricsSnapshot {
            submitted: u64_field(v, "submitted")?,
            completed: u64_field(v, "completed")?,
            rejected: u64_field(v, "rejected")?,
            failed: u64_field(v, "failed")?,
            batches: u64_field(v, "batches")?,
            mean_batch_size: v.get("mean_batch_size")?.as_f64()?,
            batch_sizes,
            queue_wait: latency_from_json(v.get("queue_wait")?)?,
            service: latency_from_json(v.get("service")?)?,
            per_key_queue_wait: {
                let mut per_key = Vec::new();
                for (k, l) in v.get("per_key_queue_wait")?.as_obj()? {
                    per_key.push((k.clone(), latency_from_json(l)?));
                }
                per_key
            },
            nfe_total: u64_field(v, "nfe_total")?,
            nfe_mean: v.get("nfe_mean")?.as_f64()?,
            nfe_max: u64_field(v, "nfe_max")?,
            http_conns_accepted: u64_opt(v, "http_conns_accepted")?,
            http_conns_active: u64_opt(v, "http_conns_active")?,
            http_conns_reused: u64_opt(v, "http_conns_reused")?,
            http_reqs_per_conn: match v.opt("http_reqs_per_conn") {
                Some(c) => count_from_json(c)?,
                None => CountSummary::default(),
            },
        })
    }

    /// Prometheus text exposition (version 0.0.4) of this snapshot, served
    /// by the front door at `GET /metrics` alongside the JSON form at
    /// `GET /v1/metrics`. Deterministic: a given snapshot always renders to
    /// the same bytes (maps are sorted, floats use Rust's shortest
    /// round-trip `Display`).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let counters = [
            ("nodal_requests_submitted_total", "requests admitted", self.submitted),
            ("nodal_requests_completed_total", "requests answered", self.completed),
            ("nodal_requests_rejected_total", "requests shed by admission", self.rejected),
            ("nodal_requests_failed_total", "requests failed in the solver", self.failed),
            ("nodal_batches_total", "batches executed", self.batches),
            ("nodal_nfe_total", "forward f evaluations served", self.nfe_total),
            (
                "nodal_http_connections_accepted_total",
                "connections accepted",
                self.http_conns_accepted,
            ),
            (
                "nodal_http_keepalive_reuses_total",
                "requests on an already-used connection",
                self.http_conns_reused,
            ),
        ];
        for (name, help, v) in counters {
            prom_counter(&mut out, name, help, v);
        }
        let gauges = [
            ("nodal_nfe_max", "largest per-request NFE", self.nfe_max),
            ("nodal_http_connections_active", "connections open now", self.http_conns_active),
        ];
        for (name, help, v) in gauges {
            prom_gauge(&mut out, name, help, v);
        }
        out.push_str("# TYPE nodal_batch_size_count gauge\n");
        for (size, &c) in self.batch_sizes.iter().enumerate() {
            if c > 0 {
                let _ = writeln!(out, "nodal_batch_size_count{{size=\"{size}\"}} {c}");
            }
        }
        let latencies = [
            ("nodal_queue_wait_seconds", "submit to batch start", &self.queue_wait),
            ("nodal_service_seconds", "batch start to response", &self.service),
        ];
        for (name, help, l) in latencies {
            prom_latency(&mut out, name, help, "", l);
        }
        if !self.per_key_queue_wait.is_empty() {
            let name = "nodal_tenant_queue_wait_seconds";
            let _ = writeln!(out, "# HELP {name} per-tenant submit to batch start");
            let _ = writeln!(out, "# TYPE {name} histogram");
            for (tenant, l) in &self.per_key_queue_wait {
                let labels = format!("tenant=\"{}\",", prom_escape(tenant));
                let sum_s = l.sum_ns as f64 * 1e-9;
                prom_hist_series(&mut out, name, &labels, l.count, sum_s, &l.buckets, 1e-9);
            }
        }
        let rc = &self.http_reqs_per_conn;
        let name = "nodal_http_requests_per_connection";
        let _ = writeln!(out, "# HELP {name} requests served per keep-alive connection");
        let _ = writeln!(out, "# TYPE {name} histogram");
        prom_hist_series(&mut out, name, "", rc.count, rc.sum as f64, &rc.buckets, 1.0);
        out
    }
}

fn prom_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn prom_counter(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}");
}

fn prom_gauge(out: &mut String, name: &str, help: &str, v: u64) {
    let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}");
}

/// One `<name>_bucket{le=...}` series (cumulative, Prometheus convention)
/// plus `_sum`/`_count`, from raw log₂ bucket counts. `scale` converts a
/// bucket's upper edge into the exposition unit (1e-9 for ns→s histograms,
/// 1.0 for plain counts). Empty buckets are elided; bucket 63's edge is
/// `u64::MAX`, which the trailing `+Inf` series already covers.
fn prom_hist_series(
    out: &mut String,
    name: &str,
    labels: &str,
    count: u64,
    sum: f64,
    buckets: &[u64],
    scale: f64,
) {
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        cum += c;
        if c == 0 || i >= 63 {
            continue;
        }
        let le = upper_edge(i) as f64 * scale;
        let _ = writeln!(out, "{name}_bucket{{{labels}le=\"{le}\"}} {cum}");
    }
    let _ = writeln!(out, "{name}_bucket{{{labels}le=\"+Inf\"}} {count}");
    let base = labels.trim_end_matches(',');
    if base.is_empty() {
        let _ = writeln!(out, "{name}_sum {sum}");
        let _ = writeln!(out, "{name}_count {count}");
    } else {
        let _ = writeln!(out, "{name}_sum{{{base}}} {sum}");
        let _ = writeln!(out, "{name}_count{{{base}}} {count}");
    }
}

/// Histogram exposition of a [`LatencySummary`] in seconds, with its own
/// HELP/TYPE header (single-series metrics).
fn prom_latency(out: &mut String, name: &str, help: &str, labels: &str, l: &LatencySummary) {
    let _ = writeln!(out, "# HELP {name} {help}\n# TYPE {name} histogram");
    prom_hist_series(out, name, labels, l.count, l.sum_ns as f64 * 1e-9, &l.buckets, 1e-9);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(LogHistogram::bucket(0), 0);
        assert_eq!(LogHistogram::bucket(1), 0);
        assert_eq!(LogHistogram::bucket(2), 1);
        assert_eq!(LogHistogram::bucket(3), 1);
        assert_eq!(LogHistogram::bucket(4), 2);
        assert_eq!(LogHistogram::bucket(u64::MAX), 63);
    }

    #[test]
    fn quantiles_are_bucket_accurate() {
        let h = LogHistogram::default();
        for _ in 0..99 {
            h.record(1_000_000); // 1 ms
        }
        h.record(100_000_000); // one 100 ms outlier
        let p50 = h.quantile(0.50);
        assert!((1_000_000..=2_097_152).contains(&p50), "p50 within 1ms bucket: {p50}");
        let p99 = h.quantile(0.99);
        assert!(p99 <= 2_097_152, "p99 still in the 1ms bucket: {p99}");
        let p999 = h.quantile(0.9999);
        assert!(p999 >= 67_108_864, "tail quantile sees the outlier: {p999}");
        assert_eq!(h.max(), 100_000_000);
        assert_eq!(h.count(), 100);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LogHistogram::default();
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn batch_size_histogram_and_mean() {
        let m = ServeMetrics::default();
        m.record_batch(4);
        m.record_batch(4);
        m.record_batch(2);
        let s = m.snapshot();
        assert_eq!(s.batches, 3);
        assert_eq!(s.batch_sizes[4], 2);
        assert_eq!(s.batch_sizes[2], 1);
        assert!((s.mean_batch_size - 10.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn request_recording_rolls_up() {
        let m = ServeMetrics::default();
        m.record_request("vdp", Duration::from_micros(10), Duration::from_millis(2), 120);
        m.record_request("vdp", Duration::from_micros(30), Duration::from_millis(4), 80);
        let s = m.snapshot();
        assert_eq!(s.completed, 2);
        assert_eq!(s.queue_wait.count, 2);
        assert_eq!(s.nfe_total, 200, "exact sum, not mean*count round-trip");
        assert!((s.nfe_mean - 100.0).abs() < 1e-9);
        assert_eq!(s.nfe_max, 120);
        assert!(s.service.p50_ms > 0.0);
        let _ = format!("{s}"); // Display must not panic
    }

    /// Per-tenant queue waits are split by key and sorted: one slow tenant's
    /// latency shows up under its key only, not smeared over the others.
    #[test]
    fn per_key_queue_wait_splits_tenants() {
        let m = ServeMetrics::default();
        for _ in 0..4 {
            m.record_request("hot", Duration::from_millis(50), Duration::from_millis(1), 10);
        }
        m.record_request("calm", Duration::from_micros(20), Duration::from_millis(1), 10);
        let s = m.snapshot();
        assert_eq!(s.per_key_queue_wait.len(), 2);
        assert_eq!(s.per_key_queue_wait[0].0, "calm", "sorted by key");
        assert_eq!(s.per_key_queue_wait[1].0, "hot");
        let (calm, hot) = (&s.per_key_queue_wait[0].1, &s.per_key_queue_wait[1].1);
        assert_eq!(calm.count, 1);
        assert_eq!(hot.count, 4);
        assert!(calm.p99_ms < 1.0, "calm tenant keeps its own p99: {}", calm.p99_ms);
        assert!(hot.p99_ms >= 50.0, "hot tenant owns its latency: {}", hot.p99_ms);
        // The global histogram still aggregates everything.
        assert_eq!(s.queue_wait.count, 5);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let m = ServeMetrics::default();
        m.record_request("vdp", Duration::from_micros(10), Duration::from_millis(2), 120);
        m.record_request("linear", Duration::from_micros(30), Duration::from_millis(4), 80);
        m.record_batch(2);
        let s = m.snapshot();
        let j = crate::util::json::Json::parse(&s.to_json().to_string()).unwrap();
        let back = MetricsSnapshot::from_json(&j).unwrap();
        assert_eq!(back.completed, s.completed);
        assert_eq!(back.batches, s.batches);
        assert_eq!(back.batch_sizes, s.batch_sizes);
        assert_eq!(back.nfe_total, s.nfe_total);
        assert_eq!(back.nfe_max, s.nfe_max);
        assert_eq!(back.queue_wait.count, s.queue_wait.count);
        assert_eq!(back.service.p99_ms.to_bits(), s.service.p99_ms.to_bits());
        assert_eq!(back.mean_batch_size.to_bits(), s.mean_batch_size.to_bits());
        assert_eq!(back.per_key_queue_wait.len(), 2);
        for ((bk, bl), (sk, sl)) in back.per_key_queue_wait.iter().zip(&s.per_key_queue_wait) {
            assert_eq!(bk, sk);
            assert_eq!(bl.count, sl.count);
            assert_eq!(bl.p99_ms.to_bits(), sl.p99_ms.to_bits());
        }
        assert!(MetricsSnapshot::from_json(&crate::util::json::Json::Null).is_err());
    }

    /// The lossless-merge contract behind cross-shard aggregation: merging
    /// the summaries of two disjoint streams is bit-identical to summarizing
    /// one histogram fed both streams. (The dist-level regression lives in
    /// `dist::dispatch`; this is the kernel.)
    #[test]
    fn merged_summaries_equal_single_histogram() {
        let a = LogHistogram::default();
        let b = LogHistogram::default();
        let both = LogHistogram::default();
        for v in [800u64, 1_200, 950_000, 2_000_000] {
            a.record(v);
            both.record(v);
        }
        for v in [65u64, 70, 500_000_000] {
            b.record(v);
            both.record(v);
        }
        let merged = LatencySummary::from_hist(&a).merge(&LatencySummary::from_hist(&b));
        assert_eq!(merged, LatencySummary::from_hist(&both));
        // Sanity: the merged p99 sees b's outlier even though a never did.
        assert!(merged.p99_ms >= 500.0, "merged p99 covers the outlier: {}", merged.p99_ms);
        // Merging with an empty (all-default) summary is the identity.
        assert_eq!(merged.merge(&LatencySummary::default()), merged);
    }

    #[test]
    fn conn_metrics_overlay_and_reuse_counting() {
        let c = ConnMetrics::default();
        c.opened();
        c.opened();
        c.record_request(0); // first request on conn 1: not a reuse
        c.record_request(1);
        c.record_request(2);
        c.record_request(0); // first request on conn 2
        c.closed(3);
        let mut s = MetricsSnapshot::default();
        c.annotate(&mut s);
        assert_eq!(s.http_conns_accepted, 2);
        assert_eq!(s.http_conns_active, 1);
        assert_eq!(s.http_conns_reused, 2);
        assert_eq!(s.http_reqs_per_conn.count, 1);
        assert_eq!(s.http_reqs_per_conn.sum, 3);
        assert_eq!(s.http_reqs_per_conn.max, 3);
        // And the overlay survives the wire codec exactly.
        let j = crate::util::json::Json::parse(&s.to_json().to_string()).unwrap();
        let back = MetricsSnapshot::from_json(&j).unwrap();
        assert_eq!(back.http_reqs_per_conn, s.http_reqs_per_conn);
        assert_eq!(back.http_conns_reused, 2);
    }

    /// Snapshots from peers that predate the connection fields still parse
    /// (additive evolution, mirroring the wire's tolerant-optional rule).
    #[test]
    fn from_json_tolerates_missing_conn_fields() {
        let m = ServeMetrics::default();
        m.record_request("vdp", Duration::from_micros(10), Duration::from_millis(2), 7);
        let mut j = match crate::util::json::Json::parse(&m.snapshot().to_json().to_string()) {
            Ok(crate::util::json::Json::Obj(map)) => map,
            other => panic!("snapshot must encode as an object: {other:?}"),
        };
        let added =
            ["http_conns_accepted", "http_conns_active", "http_conns_reused", "http_reqs_per_conn"];
        for k in added {
            j.remove(k);
        }
        let back = MetricsSnapshot::from_json(&crate::util::json::Json::Obj(j)).unwrap();
        assert_eq!(back.http_conns_accepted, 0);
        assert_eq!(back.http_reqs_per_conn, CountSummary::default());
        assert_eq!(back.completed, 1);
    }

    #[test]
    fn prometheus_exposition_is_deterministic_and_complete() {
        let m = ServeMetrics::default();
        m.record_request("vdp", Duration::from_micros(10), Duration::from_millis(2), 120);
        m.record_request("li\"near", Duration::from_micros(30), Duration::from_millis(4), 80);
        m.record_batch(2);
        let s = m.snapshot();
        let text = s.to_prometheus();
        assert_eq!(text, s.to_prometheus(), "same snapshot, same bytes");
        for needle in [
            "# TYPE nodal_requests_completed_total counter",
            "nodal_requests_completed_total 2",
            "nodal_batch_size_count{size=\"2\"} 1",
            "# TYPE nodal_queue_wait_seconds histogram",
            "nodal_queue_wait_seconds_bucket{le=\"+Inf\"} 2",
            "nodal_queue_wait_seconds_count 2",
            "nodal_tenant_queue_wait_seconds_bucket{tenant=\"vdp\",le=\"+Inf\"} 1",
            "nodal_tenant_queue_wait_seconds_count{tenant=\"li\\\"near\"} 1",
            "nodal_nfe_total 200",
            "# TYPE nodal_http_requests_per_connection histogram",
            "nodal_http_requests_per_connection_count 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Cumulative le-buckets are non-decreasing within each series.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("nodal_service_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "cumulative buckets must not decrease: {line}");
            last = v;
        }
        assert_eq!(last, 2);
    }
}
