//! Minimal vendored HTTP/1.1 front door over [`SolveServer`].
//!
//! Offline-friendly by construction: plain `std::net::TcpListener`, no TLS,
//! no external dependencies — JSON bodies use `util/json` and the
//! **versioned wire schema** from [`super::wire`] (the same codecs the
//! `dist` shards speak), so an HTTP client and a shard client exchange
//! byte-compatible payloads. f32 payloads keep the u32-bit-pattern
//! convention end-to-end.
//!
//! Routes:
//!
//! * `POST /v1/solve` — body is [`SolveRequest::to_json`] (forward,
//!   gradient via `lam`, or dense-output via `observe_at`); the response is
//!   [`SolveResponse::to_json`] on 200, or [`ServeError::to_json`] with the
//!   mapped status otherwise.
//! * `GET /v1/metrics` — the server's
//!   [`MetricsSnapshot`](super::metrics::MetricsSnapshot) as JSON,
//!   per-tenant queue-wait summaries included.
//! * `GET /healthz` — liveness probe, `{"ok":true}`.
//!
//! Error mapping (admission backpressure reaches clients end-to-end):
//!
//! | [`ServeError`]    | status | extra                |
//! |-------------------|--------|----------------------|
//! | `Overloaded`      | 429    | `Retry-After: 1`     |
//! | `BadRequest`      | 400    |                      |
//! | `UnknownDynamics` | 404    |                      |
//! | `Solver`          | 500    |                      |
//! | `ShuttingDown`    | 503    |                      |
//!
//! Malformed request lines, unparseable JSON, wrong wire versions, and
//! bodies above [`HttpConfig::max_body_bytes`] are all rejected with `400`
//! **before** any submit — a garbage request never reaches a worker.
//! Connections are keep-alive by default (`Connection: close` honored);
//! each connection runs one request at a time on its own thread, which is
//! the right shape for a loopback research server (the batcher, not the
//! socket count, is the concurrency lever).

use super::request::{ServeError, SolveRequest};
use super::SolveServer;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Longest accepted request/header line; longer lines poison the
/// connection (closed after a 400) since the framing can't be trusted.
const MAX_LINE_BYTES: usize = 8 * 1024;
/// Header-count cap per request.
const MAX_HEADERS: usize = 64;

/// `NODAL_HTTP_*` env knob with parse-and-clamp semantics (same contract
/// as the other `env_clamped` helpers; allowlisted in nodal-lint).
fn env_clamped(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(lo, hi),
        None => default,
    }
}

/// HTTP front-door tuning.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// TCP port bound on 127.0.0.1 by [`HttpServer::spawn`]
    /// (`NODAL_HTTP_PORT`).
    pub port: u16,
    /// Largest accepted request body in bytes (`NODAL_HTTP_MAX_BODY_BYTES`).
    /// Oversized bodies bounce with `400` before they are read.
    pub max_body_bytes: usize,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig { port: 7118, max_body_bytes: 1 << 20 }
    }
}

impl HttpConfig {
    /// Defaults with `NODAL_HTTP_*` overrides (see the lib.rs knob table).
    pub fn from_env() -> Self {
        HttpConfig {
            port: env_clamped("NODAL_HTTP_PORT", 7118, 1, 65535) as u16,
            max_body_bytes: env_clamped("NODAL_HTTP_MAX_BODY_BYTES", 1 << 20, 1024, 64 << 20),
        }
    }
}

/// A running HTTP endpoint over a shared [`SolveServer`].
///
/// Dropping (or [`HttpServer::shutdown`]) stops the listener and joins the
/// connection threads. The underlying `SolveServer` is **not** drained —
/// it is shared state the front door borrows, and other front ends (e.g. a
/// `dist` shard) may still be serving it.
pub struct HttpServer {
    addr: String,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<JoinHandle<()>>,
    server: Arc<SolveServer>,
}

impl HttpServer {
    /// Bind `127.0.0.1:{cfg.port}` and serve until shutdown.
    pub fn spawn(server: Arc<SolveServer>, cfg: HttpConfig) -> Result<HttpServer> {
        let bind = format!("127.0.0.1:{}", cfg.port);
        Self::spawn_at(server, &bind, cfg)
    }

    /// Bind an explicit address (use port 0 for an ephemeral test port).
    pub fn spawn_at(server: Arc<SolveServer>, bind: &str, cfg: HttpConfig) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind http front door at {bind}"))?;
        let addr = listener.local_addr().context("http local addr")?.to_string();
        listener.set_nonblocking(true).context("http listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let (server, stop, conns) = (server.clone(), stop.clone(), conns.clone());
            let max_body = cfg.max_body_bytes;
            std::thread::spawn(move || accept_loop(&listener, &server, &stop, &conns, max_body))
        };
        Ok(HttpServer { addr, stop, conns, accept: Some(accept), server })
    }

    /// The bound address (`host:port`) clients dial.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The front door's underlying server (registry/metrics access in
    /// tests and examples).
    pub fn server(&self) -> &Arc<SolveServer> {
        &self.server
    }

    /// Stop accepting, sever open connections, and join the service
    /// threads. Idempotent. Does not drain the shared `SolveServer`.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    server: &Arc<SolveServer>,
    stop: &AtomicBool,
    conns: &Mutex<Vec<TcpStream>>,
    max_body: usize,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((s, _)) => {
                let _ = s.set_nodelay(true);
                if let Ok(c) = s.try_clone() {
                    conns.lock().unwrap().push(c);
                }
                let server = server.clone();
                handlers.push(std::thread::spawn(move || handle_conn(s, &server, max_body)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// What to do with the connection after answering one request.
enum ConnState {
    KeepAlive,
    Close,
}

fn handle_conn(stream: TcpStream, server: &Arc<SolveServer>, max_body: usize) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    while let ConnState::KeepAlive = serve_one(&mut reader, &mut writer, server, max_body) {}
}

/// Read one CRLF-terminated line without ever buffering more than `cap`
/// bytes. `None` means the connection is unusable (EOF mid-line, I/O
/// error, over-long line, or non-UTF-8) — callers close it.
fn read_line_capped<R: BufRead>(r: &mut R, cap: usize) -> Option<String> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(_) => return None,
        };
        if chunk.is_empty() {
            return None; // EOF before the line terminator
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if buf.len() + i > cap {
                    return None;
                }
                buf.extend_from_slice(&chunk[..i]);
                r.consume(i + 1);
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                return String::from_utf8(buf).ok();
            }
            None => {
                let n = chunk.len();
                if buf.len() + n > cap {
                    return None;
                }
                buf.extend_from_slice(chunk);
                r.consume(n);
            }
        }
    }
}

/// Status line + reason for a [`ServeError`] (see the module-level table).
fn status_for(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::Overloaded => (429, "Too Many Requests"),
        ServeError::BadRequest(_) => (400, "Bad Request"),
        ServeError::UnknownDynamics(_) => (404, "Not Found"),
        ServeError::Solver(_) => (500, "Internal Server Error"),
        ServeError::ShuttingDown => (503, "Service Unavailable"),
    }
}

fn write_response(
    writer: &mut TcpStream,
    status: u16,
    reason: &str,
    retry_after: Option<u64>,
    keep_alive: bool,
    body: &str,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: application/json\r\ncontent-length: {}\r\n",
        body.len()
    );
    if let Some(secs) = retry_after {
        head.push_str(&format!("retry-after: {secs}\r\n"));
    }
    head.push_str(if keep_alive {
        "connection: keep-alive\r\n\r\n"
    } else {
        "connection: close\r\n\r\n"
    });
    writer.write_all(head.as_bytes())?;
    writer.write_all(body.as_bytes())?;
    writer.flush()
}

/// Answer a protocol-level defect with `400` and a `ServeError::BadRequest`
/// JSON body; the caller decides whether the connection survives.
fn reject(writer: &mut TcpStream, msg: &str, keep_alive: bool) -> ConnState {
    let body = ServeError::BadRequest(msg.to_string()).to_json().to_string();
    let _ = write_response(writer, 400, "Bad Request", None, keep_alive, &body);
    if keep_alive {
        ConnState::KeepAlive
    } else {
        ConnState::Close
    }
}

/// Serve exactly one HTTP request off the connection.
fn serve_one(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    server: &Arc<SolveServer>,
    max_body: usize,
) -> ConnState {
    let Some(request_line) = read_line_capped(reader, MAX_LINE_BYTES) else {
        return ConnState::Close;
    };
    let mut parts = request_line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return reject(writer, "malformed request line", false),
    };

    let mut content_length = 0usize;
    let mut keep_alive = true;
    let mut oversized = false;
    let mut terminated = false;
    for _ in 0..MAX_HEADERS {
        let Some(h) = read_line_capped(reader, MAX_LINE_BYTES) else {
            return ConnState::Close;
        };
        if h.is_empty() {
            terminated = true;
            break;
        }
        let Some((name, value)) = h.split_once(':') else {
            // A header without a colon is a framing error.
            return reject(writer, "malformed header", false);
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse::<usize>() {
                Ok(n) if n <= max_body => content_length = n,
                Ok(_) => oversized = true,
                Err(_) => return reject(writer, "unparseable content-length", false),
            }
        } else if name.eq_ignore_ascii_case("connection") {
            keep_alive = !value.eq_ignore_ascii_case("close");
        }
    }
    if !terminated {
        return reject(writer, "too many headers", false);
    }
    if oversized {
        // Refuse before reading a byte of the body; the unread bytes make
        // the connection unframeable, so it closes.
        return reject(writer, "request body exceeds max_body_bytes", false);
    }
    let mut body = vec![0u8; content_length];
    if reader.read_exact(&mut body).is_err() {
        return ConnState::Close;
    }

    match (method.as_str(), path.as_str()) {
        ("POST", "/v1/solve") => {
            // Decode fully — JSON syntax, wire version, schema — before any
            // submit, so garbage never reaches admission or a worker.
            let decoded = std::str::from_utf8(&body)
                .map_err(anyhow::Error::from)
                .and_then(Json::parse)
                .and_then(|j| SolveRequest::from_json(&j));
            let req = match decoded {
                Ok(r) => r,
                Err(e) => {
                    let msg = format!("undecodable solve request: {e}");
                    return reject(writer, &msg, keep_alive);
                }
            };
            let result = match server.submit(req) {
                Ok(handle) => handle.wait(),
                Err(e) => Err(e),
            };
            match result {
                Ok(resp) => {
                    let body = resp.to_json().to_string();
                    let _ = write_response(writer, 200, "OK", None, keep_alive, &body);
                }
                Err(e) => {
                    let (status, reason) = status_for(&e);
                    let retry = matches!(e, ServeError::Overloaded).then_some(1);
                    let body = e.to_json().to_string();
                    let _ = write_response(writer, status, reason, retry, keep_alive, &body);
                }
            }
        }
        ("GET", "/v1/metrics") => {
            let body = server.metrics().to_json().to_string();
            let _ = write_response(writer, 200, "OK", None, keep_alive, &body);
        }
        ("GET", "/healthz") => {
            let _ = write_response(writer, 200, "OK", None, keep_alive, "{\"ok\":true}");
        }
        ("GET", _) | ("POST", _) => {
            let _ = write_response(writer, 404, "Not Found", None, keep_alive, "{}");
        }
        _ => {
            let _ = write_response(writer, 405, "Method Not Allowed", None, keep_alive, "{}");
        }
    }
    if keep_alive {
        ConnState::KeepAlive
    } else {
        ConnState::Close
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// All `NODAL_HTTP_*` cases in ONE test: the process environment is
    /// shared across parallel test threads.
    #[test]
    fn config_env_parse_and_clamp() {
        std::env::set_var("NODAL_HTTP_PORT", "99999");
        std::env::set_var("NODAL_HTTP_MAX_BODY_BYTES", "1");
        let cfg = HttpConfig::from_env();
        assert_eq!(cfg.port, 65535, "port clamps to the u16 range");
        assert_eq!(cfg.max_body_bytes, 1024, "body cap clamps up to the floor");

        std::env::set_var("NODAL_HTTP_PORT", "not-a-number");
        let cfg = HttpConfig::from_env();
        assert_eq!(cfg.port, 7118, "unparseable falls back to default");

        for k in ["NODAL_HTTP_PORT", "NODAL_HTTP_MAX_BODY_BYTES"] {
            std::env::remove_var(k);
        }
        let cfg = HttpConfig::from_env();
        assert_eq!(cfg.port, 7118);
        assert_eq!(cfg.max_body_bytes, 1 << 20);
    }

    #[test]
    fn read_line_capped_handles_crlf_eof_and_caps() {
        let mut r = Cursor::new(b"GET / HTTP/1.1\r\nplain-lf\nrest".to_vec());
        assert_eq!(read_line_capped(&mut r, 64).as_deref(), Some("GET / HTTP/1.1"));
        assert_eq!(read_line_capped(&mut r, 64).as_deref(), Some("plain-lf"));
        assert_eq!(read_line_capped(&mut r, 64), None, "EOF mid-line is unusable");

        let long = vec![b'a'; 100];
        let mut r = Cursor::new([&long[..], b"\r\n"].concat());
        assert_eq!(read_line_capped(&mut r, 10), None, "over-cap line refused");
        let mut r = Cursor::new([&long[..], b"\r\n"].concat());
        assert!(read_line_capped(&mut r, 200).is_some(), "under-cap line accepted");

        let mut r = Cursor::new(vec![0xff, 0xfe, b'\n']);
        assert_eq!(read_line_capped(&mut r, 64), None, "non-UTF-8 refused");
    }

    #[test]
    fn status_mapping_matches_the_table() {
        assert_eq!(status_for(&ServeError::Overloaded).0, 429);
        assert_eq!(status_for(&ServeError::BadRequest(String::new())).0, 400);
        assert_eq!(status_for(&ServeError::UnknownDynamics(String::new())).0, 404);
        assert_eq!(status_for(&ServeError::Solver(String::new())).0, 500);
        assert_eq!(status_for(&ServeError::ShuttingDown).0, 503);
    }
}
