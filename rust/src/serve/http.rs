//! Minimal vendored HTTP/1.1 front door over a [`SolveFrontend`].
//!
//! Offline-friendly by construction: plain `std::net::TcpListener`, no TLS,
//! no external dependencies — JSON bodies use `util/json` and the
//! **versioned wire schema** from [`super::wire`] (the same codecs the
//! `dist` shards speak), so an HTTP client and a shard client exchange
//! byte-compatible payloads. f32 payloads keep the u32-bit-pattern
//! convention end-to-end. The door serves anything implementing
//! [`SolveFrontend`] — a local [`SolveServer`] or a multi-shard
//! `dist::Dispatcher` — through the same socket loop.
//!
//! Routes:
//!
//! * `POST /v1/solve` — body is [`SolveRequest::to_json`] (forward,
//!   gradient via `lam`, or dense-output via `observe_at`); the response is
//!   [`SolveResponse::to_json`] on 200, or [`ServeError::to_json`] with the
//!   mapped status otherwise.
//! * `GET /v1/metrics` — the frontend's
//!   [`MetricsSnapshot`](super::metrics::MetricsSnapshot) as JSON with the
//!   door's keep-alive connection counters overlaid;
//!   `?format=prometheus` renders the same snapshot as Prometheus text
//!   exposition instead.
//! * `GET /v1/trace/<id>` — a stored trace's spans as JSON (404 when the
//!   id is unknown or malformed).
//! * `GET /healthz` — liveness probe, `{"ok":true}`.
//!
//! ## Tracing
//!
//! A solve request carrying an `x-nodal-trace` header (16 lower-hex chars)
//! is always traced under that id — an unparseable value still traces,
//! under a freshly minted id. Without the header, every
//! [`TraceKnobs::sample_n`]-th request is traced (0 disables sampling).
//! Traced requests get a root `http_request` span and an `admission` span;
//! downstream spans (queue wait, batch formation, solve phases) join via
//! the context propagated inside the [`SolveRequest`]. Spans are published
//! and the JSONL export written **before** the response bytes go out, so a
//! client that got the echoed `x-nodal-trace` header back can immediately
//! `GET /v1/trace/<id>` and see the complete tree.
//!
//! Error mapping (admission backpressure reaches clients end-to-end):
//!
//! | [`ServeError`]    | status | extra                |
//! |-------------------|--------|----------------------|
//! | `Overloaded`      | 429    | `Retry-After: 1`     |
//! | `BadRequest`      | 400    |                      |
//! | `UnknownDynamics` | 404    |                      |
//! | `Solver`          | 500    |                      |
//! | `ShuttingDown`    | 503    |                      |
//!
//! Malformed request lines, unparseable JSON, wrong wire versions, and
//! bodies above [`HttpConfig::max_body_bytes`] are all rejected with `400`
//! **before** any submit — a garbage request never reaches a worker.
//! Connections are keep-alive by default (`Connection: close` honored);
//! each connection runs one request at a time on its own thread, which is
//! the right shape for a loopback research server (the batcher, not the
//! socket count, is the concurrency lever). Per-connection accounting
//! (accepted/active/reused, requests per connection) lives in
//! [`ConnMetrics`] owned by the door, not the solver.

use super::metrics::{ConnMetrics, MetricsSnapshot};
use super::request::{ServeError, SolveRequest, SolveResponse};
use super::SolveServer;
use crate::obs::{self, SpanRec, TraceCtx, TraceId, TraceKnobs};
use crate::util::json::{obj, Json};
use anyhow::{Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Longest accepted request/header line; longer lines poison the
/// connection (closed after a 400) since the framing can't be trusted.
const MAX_LINE_BYTES: usize = 8 * 1024;
/// Header-count cap per request.
const MAX_HEADERS: usize = 64;

const JSON_TYPE: &str = "application/json";
/// Prometheus text exposition format version scrapers expect.
const PROM_TYPE: &str = "text/plain; version=0.0.4";

/// `NODAL_HTTP_*` env knob with parse-and-clamp semantics (same contract
/// as the other `env_clamped` helpers; allowlisted in nodal-lint).
fn env_clamped(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(lo, hi),
        None => default,
    }
}

/// HTTP front-door tuning.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// TCP port bound on 127.0.0.1 by [`HttpServer::spawn`]
    /// (`NODAL_HTTP_PORT`).
    pub port: u16,
    /// Largest accepted request body in bytes (`NODAL_HTTP_MAX_BODY_BYTES`).
    /// Oversized bodies bounce with `400` before they are read.
    pub max_body_bytes: usize,
    /// Tracing knobs: sampling stride for unsolicited requests
    /// (`NODAL_TRACE_SAMPLE_N`) and the JSONL export directory
    /// (`NODAL_TRACE_DIR`).
    pub trace: TraceKnobs,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig { port: 7118, max_body_bytes: 1 << 20, trace: TraceKnobs::default() }
    }
}

impl HttpConfig {
    /// Defaults with `NODAL_HTTP_*` / `NODAL_TRACE_*` overrides (see the
    /// lib.rs knob table).
    pub fn from_env() -> Self {
        HttpConfig {
            port: env_clamped("NODAL_HTTP_PORT", 7118, 1, 65535) as u16,
            max_body_bytes: env_clamped("NODAL_HTTP_MAX_BODY_BYTES", 1 << 20, 1024, 64 << 20),
            trace: obs::trace_env(),
        }
    }
}

/// Blocking response waiter returned by [`SolveFrontend::submit_front`].
pub type Waiter = Box<dyn FnOnce() -> Result<SolveResponse, ServeError> + Send>;

/// What the HTTP door needs from whatever sits behind it — a local
/// [`SolveServer`] or a multi-shard `dist::Dispatcher`. Submission is
/// split from waiting so the `admission` span measures the admission
/// decision, not the solve.
pub trait SolveFrontend: Send + Sync {
    /// Admission decision: `Ok` hands back a blocking waiter for the
    /// response, `Err` is the mapped rejection.
    fn submit_front(&self, req: SolveRequest) -> Result<Waiter, ServeError>;
    /// Metrics snapshot (merged across shards behind a dispatcher).
    fn metrics_front(&self) -> MetricsSnapshot;
    /// A reading of the frontend's injected clock — the only time source
    /// the door stamps spans with, keeping traces deterministic under
    /// [`ManualClock`](super::ManualClock).
    fn now(&self) -> Duration;
}

impl SolveFrontend for SolveServer {
    fn submit_front(&self, req: SolveRequest) -> Result<Waiter, ServeError> {
        let handle = self.submit(req)?;
        Ok(Box::new(move || handle.wait()))
    }

    fn metrics_front(&self) -> MetricsSnapshot {
        self.metrics()
    }

    fn now(&self) -> Duration {
        self.core.clock.now()
    }
}

/// State every connection thread shares: the frontend, the door's
/// connection metrics, and the tracing configuration.
struct FrontShared {
    front: Arc<dyn SolveFrontend>,
    conn: ConnMetrics,
    trace: TraceKnobs,
    /// Unsolicited-solve counter driving `sample_n` selection.
    sample_seq: AtomicU64,
    max_body: usize,
}

/// A running HTTP endpoint over a shared [`SolveFrontend`].
///
/// Dropping (or [`HttpServer::shutdown`]) stops the listener and joins the
/// connection threads. The underlying frontend is **not** drained — it is
/// shared state the front door borrows, and other front ends (e.g. a
/// `dist` shard) may still be serving it.
pub struct HttpServer {
    addr: String,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Option<JoinHandle<()>>,
    shared: Arc<FrontShared>,
}

impl HttpServer {
    /// Bind `127.0.0.1:{cfg.port}` and serve until shutdown.
    pub fn spawn(server: Arc<SolveServer>, cfg: HttpConfig) -> Result<HttpServer> {
        let bind = format!("127.0.0.1:{}", cfg.port);
        Self::spawn_at(server, &bind, cfg)
    }

    /// Bind an explicit address (use port 0 for an ephemeral test port).
    pub fn spawn_at(server: Arc<SolveServer>, bind: &str, cfg: HttpConfig) -> Result<HttpServer> {
        Self::spawn_front_at(server, bind, cfg)
    }

    /// Bind an explicit address over any [`SolveFrontend`] (the `dist`
    /// dispatcher enters here).
    pub fn spawn_front_at(
        front: Arc<dyn SolveFrontend>,
        bind: &str,
        cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(bind).with_context(|| format!("bind http front door at {bind}"))?;
        let addr = listener.local_addr().context("http local addr")?.to_string();
        listener.set_nonblocking(true).context("http listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let shared = Arc::new(FrontShared {
            front,
            conn: ConnMetrics::default(),
            trace: cfg.trace.clone(),
            sample_seq: AtomicU64::new(0),
            max_body: cfg.max_body_bytes,
        });
        let accept = {
            let (shared, stop, conns) = (shared.clone(), stop.clone(), conns.clone());
            std::thread::spawn(move || accept_loop(&listener, &shared, &stop, &conns))
        };
        Ok(HttpServer { addr, stop, conns, accept: Some(accept), shared })
    }

    /// The bound address (`host:port`) clients dial.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// The door's keep-alive connection counters (overlaid onto
    /// `/v1/metrics` snapshots).
    pub fn conn_metrics(&self) -> &ConnMetrics {
        &self.shared.conn
    }

    /// Stop accepting, sever open connections, and join the service
    /// threads. Idempotent. Does not drain the shared frontend.
    pub fn shutdown(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &Arc<FrontShared>,
    stop: &AtomicBool,
    conns: &Mutex<Vec<TcpStream>>,
) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((s, _)) => {
                let _ = s.set_nodelay(true);
                if let Ok(c) = s.try_clone() {
                    conns.lock().unwrap().push(c);
                }
                let shared = shared.clone();
                handlers.push(std::thread::spawn(move || handle_conn(s, &shared)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => break,
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// What to do with the connection after answering one request.
enum ConnState {
    KeepAlive,
    Close,
}

fn handle_conn(stream: TcpStream, shared: &FrontShared) {
    let Ok(read_half) = stream.try_clone() else { return };
    shared.conn.opened();
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut served = 0u64;
    while let ConnState::KeepAlive = serve_one(&mut reader, &mut writer, shared, &mut served) {}
    shared.conn.closed(served);
}

/// Read one CRLF-terminated line without ever buffering more than `cap`
/// bytes. `None` means the connection is unusable (EOF mid-line, I/O
/// error, over-long line, or non-UTF-8) — callers close it.
fn read_line_capped<R: BufRead>(r: &mut R, cap: usize) -> Option<String> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            Err(_) => return None,
        };
        if chunk.is_empty() {
            return None; // EOF before the line terminator
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                if buf.len() + i > cap {
                    return None;
                }
                buf.extend_from_slice(&chunk[..i]);
                r.consume(i + 1);
                if buf.last() == Some(&b'\r') {
                    buf.pop();
                }
                return String::from_utf8(buf).ok();
            }
            None => {
                let n = chunk.len();
                if buf.len() + n > cap {
                    return None;
                }
                buf.extend_from_slice(chunk);
                r.consume(n);
            }
        }
    }
}

/// Status line + reason for a [`ServeError`] (see the module-level table).
fn status_for(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::Overloaded => (429, "Too Many Requests"),
        ServeError::BadRequest(_) => (400, "Bad Request"),
        ServeError::UnknownDynamics(_) => (404, "Not Found"),
        ServeError::Solver(_) => (500, "Internal Server Error"),
        ServeError::ShuttingDown => (503, "Service Unavailable"),
    }
}

fn write_response_full(
    writer: &mut TcpStream,
    status: u16,
    reason: &str,
    retry_after: Option<u64>,
    keep_alive: bool,
    content_type: &str,
    trace: Option<&str>,
    body: &str,
) -> std::io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\n",
        body.len()
    );
    if let Some(secs) = retry_after {
        head.push_str(&format!("retry-after: {secs}\r\n"));
    }
    if let Some(id) = trace {
        head.push_str(&format!("x-nodal-trace: {id}\r\n"));
    }
    head.push_str(if keep_alive {
        "connection: keep-alive\r\n\r\n"
    } else {
        "connection: close\r\n\r\n"
    });
    writer.write_all(head.as_bytes())?;
    writer.write_all(body.as_bytes())?;
    writer.flush()
}

fn write_response(
    writer: &mut TcpStream,
    status: u16,
    reason: &str,
    retry_after: Option<u64>,
    keep_alive: bool,
    body: &str,
) -> std::io::Result<()> {
    write_response_full(writer, status, reason, retry_after, keep_alive, JSON_TYPE, None, body)
}

/// Answer a protocol-level defect with `400` and a `ServeError::BadRequest`
/// JSON body; the caller decides whether the connection survives.
fn reject(writer: &mut TcpStream, msg: &str, keep_alive: bool) -> ConnState {
    let body = ServeError::BadRequest(msg.to_string()).to_json().to_string();
    let _ = write_response(writer, 400, "Bad Request", None, keep_alive, &body);
    if keep_alive {
        ConnState::KeepAlive
    } else {
        ConnState::Close
    }
}

/// Span timestamps are u64 nanos off the frontend's injected clock.
fn ns_of(d: Duration) -> u64 {
    d.as_nanos().min(u64::MAX as u128) as u64
}

/// Trace-or-not decision for one solve request: an `x-nodal-trace` header
/// always traces (a parseable id is adopted, anything else gets a freshly
/// minted one); without the header every `sample_n`-th request is traced
/// (0 = never).
fn resolve_trace(shared: &FrontShared, header: Option<&str>, now: Duration) -> Option<TraceId> {
    if let Some(h) = header {
        return Some(TraceId::parse_hex(h.trim()).unwrap_or_else(|| obs::mint(now)));
    }
    let n = shared.trace.sample_n;
    if n == 0 {
        return None;
    }
    let seq = shared.sample_seq.fetch_add(1, Ordering::Relaxed);
    (seq % n == 0).then(|| obs::mint(now))
}

/// Handle a decoded `POST /v1/solve`: resolve tracing, submit through the
/// frontend, emit the `http_request` + `admission` spans, publish and
/// export the JSONL **before** the response bytes go out (the trace is
/// queryable the instant the client wakes), then answer with the trace id
/// echoed in `x-nodal-trace`.
fn solve_route(
    writer: &mut TcpStream,
    shared: &FrontShared,
    mut req: SolveRequest,
    trace_header: Option<&str>,
    keep_alive: bool,
) {
    let front = &*shared.front;
    let t0 = front.now();
    let traced = resolve_trace(shared, trace_header, t0);
    let mut root = traced.map(|t| SpanRec::new(TraceCtx::root(t), obs::HTTP_REQUEST, t0, t0));
    let mut adm = root.as_ref().map(|r| SpanRec::new(r.ctx(), obs::ADMISSION, t0, t0));
    if let Some(a) = &adm {
        req.trace = Some(a.ctx());
    }
    let submitted = front.submit_front(req);
    if let Some(a) = adm.as_mut() {
        a.end_ns = ns_of(front.now());
    }
    let result = match submitted {
        Ok(wait) => wait(),
        Err(e) => Err(e),
    };
    let (status, reason, retry) = match &result {
        Ok(_) => (200, "OK", None),
        Err(e) => {
            let (s, r) = status_for(e);
            (s, r, matches!(e, ServeError::Overloaded).then_some(1))
        }
    };
    if let (Some(r), Some(a)) = (root.as_mut(), adm) {
        r.end_ns = ns_of(front.now());
        *r = r.attr("status", status as u64);
        obs::record(*r);
        obs::record(a);
        obs::publish();
        let _ = obs::global().flush_jsonl(TraceId(r.trace), &shared.trace.dir);
    }
    let body = match &result {
        Ok(resp) => resp.to_json().to_string(),
        Err(e) => e.to_json().to_string(),
    };
    let hex = traced.map(|t| t.to_hex());
    let _ = write_response_full(
        writer,
        status,
        reason,
        retry,
        keep_alive,
        JSON_TYPE,
        hex.as_deref(),
        &body,
    );
}

/// Serve exactly one HTTP request off the connection.
fn serve_one(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    shared: &FrontShared,
    served: &mut u64,
) -> ConnState {
    let Some(request_line) = read_line_capped(reader, MAX_LINE_BYTES) else {
        return ConnState::Close;
    };
    shared.conn.record_request(*served);
    *served += 1;
    let mut parts = request_line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m.to_string(), p.to_string()),
        _ => return reject(writer, "malformed request line", false),
    };

    let mut content_length = 0usize;
    let mut keep_alive = true;
    let mut oversized = false;
    let mut terminated = false;
    let mut trace_header: Option<String> = None;
    for _ in 0..MAX_HEADERS {
        let Some(h) = read_line_capped(reader, MAX_LINE_BYTES) else {
            return ConnState::Close;
        };
        if h.is_empty() {
            terminated = true;
            break;
        }
        let Some((name, value)) = h.split_once(':') else {
            // A header without a colon is a framing error.
            return reject(writer, "malformed header", false);
        };
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            match value.parse::<usize>() {
                Ok(n) if n <= shared.max_body => content_length = n,
                Ok(_) => oversized = true,
                Err(_) => return reject(writer, "unparseable content-length", false),
            }
        } else if name.eq_ignore_ascii_case("connection") {
            keep_alive = !value.eq_ignore_ascii_case("close");
        } else if name.eq_ignore_ascii_case("x-nodal-trace") {
            trace_header = Some(value.to_string());
        }
    }
    if !terminated {
        return reject(writer, "too many headers", false);
    }
    if oversized {
        // Refuse before reading a byte of the body; the unread bytes make
        // the connection unframeable, so it closes.
        return reject(writer, "request body exceeds max_body_bytes", false);
    }
    let mut body = vec![0u8; content_length];
    if reader.read_exact(&mut body).is_err() {
        return ConnState::Close;
    }

    let (path_base, query) = match path.split_once('?') {
        Some((p, q)) => (p, Some(q)),
        None => (path.as_str(), None),
    };
    match (method.as_str(), path_base) {
        ("POST", "/v1/solve") => {
            // Decode fully — JSON syntax, wire version, schema — before any
            // submit, so garbage never reaches admission or a worker.
            let decoded = std::str::from_utf8(&body)
                .map_err(anyhow::Error::from)
                .and_then(Json::parse)
                .and_then(|j| SolveRequest::from_json(&j));
            let req = match decoded {
                Ok(r) => r,
                Err(e) => {
                    let msg = format!("undecodable solve request: {e}");
                    return reject(writer, &msg, keep_alive);
                }
            };
            solve_route(writer, shared, req, trace_header.as_deref(), keep_alive);
        }
        ("GET", "/v1/metrics") => {
            let mut snap = shared.front.metrics_front();
            shared.conn.annotate(&mut snap);
            if query == Some("format=prometheus") {
                let text = snap.to_prometheus();
                let _ = write_response_full(
                    writer, 200, "OK", None, keep_alive, PROM_TYPE, None, &text,
                );
            } else {
                let body = snap.to_json().to_string();
                let _ = write_response(writer, 200, "OK", None, keep_alive, &body);
            }
        }
        ("GET", p) if p.starts_with("/v1/trace/") => {
            let spans = TraceId::parse_hex(&p["/v1/trace/".len()..])
                .map(|t| obs::global().get(t))
                .filter(|s| !s.is_empty());
            match spans {
                Some(spans) => {
                    let body = obj(vec![("spans", obs::spans_to_json(&spans))]).to_string();
                    let _ = write_response(writer, 200, "OK", None, keep_alive, &body);
                }
                None => {
                    let _ = write_response(writer, 404, "Not Found", None, keep_alive, "{}");
                }
            }
        }
        ("GET", "/healthz") => {
            let _ = write_response(writer, 200, "OK", None, keep_alive, "{\"ok\":true}");
        }
        ("GET", _) | ("POST", _) => {
            let _ = write_response(writer, 404, "Not Found", None, keep_alive, "{}");
        }
        _ => {
            let _ = write_response(writer, 405, "Method Not Allowed", None, keep_alive, "{}");
        }
    }
    if keep_alive {
        ConnState::KeepAlive
    } else {
        ConnState::Close
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    /// All `NODAL_HTTP_*` cases in ONE test: the process environment is
    /// shared across parallel test threads.
    #[test]
    fn config_env_parse_and_clamp() {
        std::env::set_var("NODAL_HTTP_PORT", "99999");
        std::env::set_var("NODAL_HTTP_MAX_BODY_BYTES", "1");
        let cfg = HttpConfig::from_env();
        assert_eq!(cfg.port, 65535, "port clamps to the u16 range");
        assert_eq!(cfg.max_body_bytes, 1024, "body cap clamps up to the floor");

        std::env::set_var("NODAL_HTTP_PORT", "not-a-number");
        let cfg = HttpConfig::from_env();
        assert_eq!(cfg.port, 7118, "unparseable falls back to default");

        for k in ["NODAL_HTTP_PORT", "NODAL_HTTP_MAX_BODY_BYTES"] {
            std::env::remove_var(k);
        }
        let cfg = HttpConfig::from_env();
        assert_eq!(cfg.port, 7118);
        assert_eq!(cfg.max_body_bytes, 1 << 20);
    }

    #[test]
    fn read_line_capped_handles_crlf_eof_and_caps() {
        let mut r = Cursor::new(b"GET / HTTP/1.1\r\nplain-lf\nrest".to_vec());
        assert_eq!(read_line_capped(&mut r, 64).as_deref(), Some("GET / HTTP/1.1"));
        assert_eq!(read_line_capped(&mut r, 64).as_deref(), Some("plain-lf"));
        assert_eq!(read_line_capped(&mut r, 64), None, "EOF mid-line is unusable");

        let long = vec![b'a'; 100];
        let mut r = Cursor::new([&long[..], b"\r\n"].concat());
        assert_eq!(read_line_capped(&mut r, 10), None, "over-cap line refused");
        let mut r = Cursor::new([&long[..], b"\r\n"].concat());
        assert!(read_line_capped(&mut r, 200).is_some(), "under-cap line accepted");

        let mut r = Cursor::new(vec![0xff, 0xfe, b'\n']);
        assert_eq!(read_line_capped(&mut r, 64), None, "non-UTF-8 refused");
    }

    #[test]
    fn status_mapping_matches_the_table() {
        assert_eq!(status_for(&ServeError::Overloaded).0, 429);
        assert_eq!(status_for(&ServeError::BadRequest(String::new())).0, 400);
        assert_eq!(status_for(&ServeError::UnknownDynamics(String::new())).0, 404);
        assert_eq!(status_for(&ServeError::Solver(String::new())).0, 500);
        assert_eq!(status_for(&ServeError::ShuttingDown).0, 503);
    }

    /// The sampling decision is pure arithmetic over the shared counter:
    /// a header always wins, `sample_n = 0` never samples, and stride N
    /// picks every Nth unsolicited request.
    #[test]
    fn resolve_trace_header_and_sampling_rules() {
        struct NullFront;
        impl SolveFrontend for NullFront {
            fn submit_front(&self, _req: SolveRequest) -> Result<Waiter, ServeError> {
                Err(ServeError::ShuttingDown)
            }
            fn metrics_front(&self) -> MetricsSnapshot {
                MetricsSnapshot::default()
            }
            fn now(&self) -> Duration {
                Duration::ZERO
            }
        }
        let mk = |n: u64| FrontShared {
            front: Arc::new(NullFront),
            conn: ConnMetrics::default(),
            trace: TraceKnobs { sample_n: n, dir: std::env::temp_dir() },
            sample_seq: AtomicU64::new(0),
            max_body: 1024,
        };
        let t = Duration::from_nanos(42);

        let off = mk(0);
        assert_eq!(resolve_trace(&off, None, t), None, "sampling off, no header");
        let id = resolve_trace(&off, Some("00000000000000ab"), t);
        assert_eq!(id, Some(TraceId(0xab)), "valid header id is adopted");
        let minted = resolve_trace(&off, Some("not-a-trace-id"), t);
        assert!(minted.is_some(), "bad header still traces under a minted id");
        assert_ne!(minted, Some(TraceId(0)), "minted ids are nonzero");

        let every2 = mk(2);
        let picks: Vec<bool> =
            (0..4).map(|_| resolve_trace(&every2, None, t).is_some()).collect();
        assert_eq!(picks, vec![true, false, true, false], "stride-2 sampling");
    }
}
