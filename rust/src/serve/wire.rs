//! Versioned JSON wire schema for [`SolveRequest`] / [`SolveResponse`] /
//! [`ServeError`] — spoken **verbatim** by both transports: the HTTP front
//! door ([`super::http`]) and the `dist::transport` frames between the
//! dispatcher and TCP shards. One schema, two carriers.
//!
//! Every wire object carries a `"v"` field ([`WIRE_VERSION`]); decoding an
//! object with a different version fails with the typed
//! [`WireVersionError`] (downcastable through `anyhow`), so a schema bump
//! is a clean protocol error instead of a shape-dependent parse failure.
//!
//! Float *state* payloads (`z0`, `lam`, `z_t1`, gradients, observed
//! states) travel as f32 bit patterns ([`f32_bits`]) so answers cross the
//! wire bit-exactly; f64 *scalars* (spans, tolerances, observation times)
//! ride as plain JSON numbers — the writer emits the shortest
//! round-tripping form, which is bit-exact for every finite value, and
//! non-finite values are rejected by request validation anyway.

use crate::grad::GradResult;
use crate::util::json::{f32_bits, f32s_from_bits, obj, Json};
use std::time::Duration;

use super::request::{
    Lane, Payload, RequestStats, ServeError, SolveRequest, SolveResponse, Tolerance,
};

/// Current wire schema version. Bump on any incompatible change to the
/// request/response/error JSON shapes below.
pub const WIRE_VERSION: u64 = 1;

/// Typed decode failure: the peer speaks a different wire schema version.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireVersionError {
    /// The version the peer sent.
    pub got: u64,
}

impl std::fmt::Display for WireVersionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "unsupported wire version {} (this side speaks {WIRE_VERSION})", self.got)
    }
}

impl std::error::Error for WireVersionError {}

/// Check the `"v"` field of a wire object: missing → malformed; present
/// but different → [`WireVersionError`].
fn expect_version(v: &Json) -> anyhow::Result<()> {
    let got = v
        .get("v")
        .map_err(|_| anyhow::anyhow!("missing wire version field 'v'"))?
        .as_usize()? as u64;
    if got != WIRE_VERSION {
        return Err(WireVersionError { got }.into());
    }
    Ok(())
}

impl SolveRequest {
    pub fn to_json(&self) -> Json {
        let (kind, a, b) = match self.tol {
            Tolerance::Adaptive { rtol, atol } => ("adaptive", rtol, atol),
            Tolerance::Fixed { h } => ("fixed", h, 0.0),
        };
        let mut pairs = vec![
            ("v", (WIRE_VERSION as usize).into()),
            ("dynamics", self.dynamics.as_str().into()),
            ("t0", self.t0.into()),
            ("t1", self.t1.into()),
            ("z0", f32_bits(&self.z0)),
            ("tab", self.tab.name.into()),
            ("tol_kind", kind.into()),
            ("tol_a", a.into()),
            ("tol_b", b.into()),
            ("lane", self.lane.as_str().into()),
        ];
        if let Some(lam) = &self.grad {
            pairs.push(("lam", f32_bits(lam)));
        }
        if !self.observe_at.is_empty() {
            pairs.push(("observe_at", self.observe_at.clone().into()));
        }
        // Trace context rides as optional fields (same tolerance pattern as
        // `lane`): hex trace id, parent span id, and — only when routed by
        // a dispatcher — the target shard index. No version bump needed;
        // old peers ignore the extra fields, absent fields decode as None.
        if let Some(ctx) = self.trace {
            pairs.push(("trace", ctx.trace.to_hex().into()));
            pairs.push(("trace_parent", (ctx.parent as usize).into()));
            if ctx.shard >= 0 {
                pairs.push(("trace_shard", (ctx.shard as usize).into()));
            }
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<SolveRequest> {
        expect_version(v)?;
        let tab_name = v.get("tab")?.as_str()?;
        let tab = crate::ode::tableau::by_name(tab_name)
            .ok_or_else(|| anyhow::anyhow!("unknown tableau '{tab_name}'"))?;
        let tol = match v.get("tol_kind")?.as_str()? {
            "adaptive" => Tolerance::Adaptive {
                rtol: v.get("tol_a")?.as_f64()?,
                atol: v.get("tol_b")?.as_f64()?,
            },
            "fixed" => Tolerance::Fixed { h: v.get("tol_a")?.as_f64()? },
            k => anyhow::bail!("unknown tolerance kind '{k}'"),
        };
        let grad = match v.opt("lam") {
            Some(l) => Some(f32s_from_bits(l)?),
            None => None,
        };
        // Missing lane decodes as Interactive: hand-written HTTP requests
        // should not have to know about QoS to get served.
        let lane = match v.opt("lane") {
            Some(l) => {
                let name = l.as_str()?;
                Lane::from_name(name).ok_or_else(|| anyhow::anyhow!("unknown lane '{name}'"))?
            }
            None => Lane::Interactive,
        };
        let observe_at = match v.opt("observe_at") {
            Some(ts) => {
                ts.as_arr()?.iter().map(Json::as_f64).collect::<anyhow::Result<Vec<f64>>>()?
            }
            None => Vec::new(),
        };
        let trace = match v.opt("trace") {
            Some(t) => {
                let hex = t.as_str()?;
                let id = crate::obs::TraceId::parse_hex(hex)
                    .ok_or_else(|| anyhow::anyhow!("bad trace id '{hex}'"))?;
                Some(crate::obs::TraceCtx {
                    trace: id,
                    parent: match v.opt("trace_parent") {
                        Some(p) => p.as_usize()? as u64,
                        None => 0,
                    },
                    shard: match v.opt("trace_shard") {
                        Some(s) => s.as_usize()? as i64,
                        None => -1,
                    },
                })
            }
            None => None,
        };
        Ok(SolveRequest {
            dynamics: v.get("dynamics")?.as_str()?.to_string(),
            t0: v.get("t0")?.as_f64()?,
            t1: v.get("t1")?.as_f64()?,
            z0: f32s_from_bits(v.get("z0")?)?,
            tab,
            tol,
            grad,
            observe_at,
            lane,
            trace,
        })
    }
}

fn duration_from_ns(v: &Json) -> anyhow::Result<Duration> {
    let n = v.as_f64()?;
    anyhow::ensure!(n.is_finite() && n >= 0.0, "bad duration: {n}");
    Ok(Duration::from_nanos(n as u64))
}

fn stats_to_json(s: &RequestStats) -> Json {
    obj(vec![
        ("steps", s.steps.into()),
        ("nfe", s.nfe.into()),
        ("n_rejected", s.n_rejected.into()),
        ("avg_m", s.avg_m.into()),
        ("checkpoint_bytes", s.checkpoint_bytes.into()),
        ("batch_size", s.batch_size.into()),
        ("queue_wait_ns", (s.queue_wait.as_nanos() as f64).into()),
        ("service_ns", (s.service.as_nanos() as f64).into()),
    ])
}

fn stats_from_json(v: &Json) -> anyhow::Result<RequestStats> {
    Ok(RequestStats {
        steps: v.get("steps")?.as_usize()?,
        nfe: v.get("nfe")?.as_usize()?,
        n_rejected: v.get("n_rejected")?.as_usize()?,
        avg_m: v.get("avg_m")?.as_f64()?,
        checkpoint_bytes: v.get("checkpoint_bytes")?.as_usize()?,
        batch_size: v.get("batch_size")?.as_usize()?,
        queue_wait: duration_from_ns(v.get("queue_wait_ns")?)?,
        service: duration_from_ns(v.get("service_ns")?)?,
    })
}

fn meter_to_json(m: &crate::grad::CostMeter) -> Json {
    obj(vec![
        ("nfe_forward", m.nfe_forward.into()),
        ("nfe_backward", m.nfe_backward.into()),
        ("nfe_replay", m.nfe_replay.into()),
        ("replay_peak_bytes", m.replay_peak_bytes.into()),
        ("vjp_calls", m.vjp_calls.into()),
        ("checkpoint_bytes", m.checkpoint_bytes.into()),
        ("graph_depth", m.graph_depth.into()),
        ("n_steps", m.n_steps.into()),
        ("n_rejected", m.n_rejected.into()),
        ("n_reverse_steps", m.n_reverse_steps.into()),
    ])
}

fn meter_from_json(v: &Json) -> anyhow::Result<crate::grad::CostMeter> {
    Ok(crate::grad::CostMeter {
        nfe_forward: v.get("nfe_forward")?.as_usize()?,
        nfe_backward: v.get("nfe_backward")?.as_usize()?,
        nfe_replay: v.get("nfe_replay")?.as_usize()?,
        replay_peak_bytes: v.get("replay_peak_bytes")?.as_usize()?,
        vjp_calls: v.get("vjp_calls")?.as_usize()?,
        checkpoint_bytes: v.get("checkpoint_bytes")?.as_usize()?,
        graph_depth: v.get("graph_depth")?.as_usize()?,
        n_steps: v.get("n_steps")?.as_usize()?,
        n_rejected: v.get("n_rejected")?.as_usize()?,
        n_reverse_steps: v.get("n_reverse_steps")?.as_usize()?,
    })
}

impl SolveResponse {
    pub fn to_json(&self) -> Json {
        let payload = match &self.payload {
            Payload::Forward { z_t1 } => {
                obj(vec![("kind", "forward".into()), ("z_t1", f32_bits(z_t1))])
            }
            Payload::Gradient { z_t1, grad } => obj(vec![
                ("kind", "gradient".into()),
                ("z_t1", f32_bits(z_t1)),
                ("dl_dz0", f32_bits(&grad.dl_dz0)),
                ("dl_dtheta", f32_bits(&grad.dl_dtheta)),
                ("meter", meter_to_json(&grad.meter)),
            ]),
            Payload::Observed { z_t1, zs } => obj(vec![
                ("kind", "observed".into()),
                ("z_t1", f32_bits(z_t1)),
                ("zs", Json::Arr(zs.iter().map(|z| f32_bits(z)).collect())),
            ]),
        };
        obj(vec![
            ("v", (WIRE_VERSION as usize).into()),
            ("payload", payload),
            ("stats", stats_to_json(&self.stats)),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<SolveResponse> {
        expect_version(v)?;
        let p = v.get("payload")?;
        let z_t1 = f32s_from_bits(p.get("z_t1")?)?;
        let payload = match p.get("kind")?.as_str()? {
            "forward" => Payload::Forward { z_t1 },
            "gradient" => Payload::Gradient {
                z_t1,
                grad: GradResult {
                    dl_dz0: f32s_from_bits(p.get("dl_dz0")?)?,
                    dl_dtheta: f32s_from_bits(p.get("dl_dtheta")?)?,
                    meter: meter_from_json(p.get("meter")?)?,
                },
            },
            "observed" => Payload::Observed {
                z_t1,
                zs: p
                    .get("zs")?
                    .as_arr()?
                    .iter()
                    .map(f32s_from_bits)
                    .collect::<anyhow::Result<Vec<Vec<f32>>>>()?,
            },
            k => anyhow::bail!("unknown payload kind '{k}'"),
        };
        Ok(SolveResponse { payload, stats: stats_from_json(v.get("stats")?)? })
    }
}

impl ServeError {
    pub fn to_json(&self) -> Json {
        let (kind, msg) = match self {
            ServeError::Overloaded => ("overloaded", ""),
            ServeError::ShuttingDown => ("shutting_down", ""),
            ServeError::UnknownDynamics(id) => ("unknown_dynamics", id.as_str()),
            ServeError::BadRequest(m) => ("bad_request", m.as_str()),
            ServeError::Solver(m) => ("solver", m.as_str()),
        };
        obj(vec![
            ("v", (WIRE_VERSION as usize).into()),
            ("kind", kind.into()),
            ("msg", msg.into()),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ServeError> {
        expect_version(v)?;
        let msg = v.get("msg")?.as_str()?.to_string();
        Ok(match v.get("kind")?.as_str()? {
            "overloaded" => ServeError::Overloaded,
            "shutting_down" => ServeError::ShuttingDown,
            "unknown_dynamics" => ServeError::UnknownDynamics(msg),
            "bad_request" => ServeError::BadRequest(msg),
            "solver" => ServeError::Solver(msg),
            k => anyhow::bail!("unknown error kind '{k}'"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_round_trips_bit_exactly() {
        let mut r = SolveRequest::adaptive("vdp", 0.25, 5.5, vec![2.0, -0.0], 1e-6, 1e-8).unwrap();
        r.z0[1] = f32::from_bits(0x0000_0001); // smallest subnormal
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let back = SolveRequest::from_json(&j).unwrap();
        assert_eq!(back.dynamics, "vdp");
        assert_eq!(back.t0.to_bits(), r.t0.to_bits());
        assert_eq!(back.t1.to_bits(), r.t1.to_bits());
        assert_eq!(back.tab.name, r.tab.name);
        assert_eq!(back.tol, r.tol);
        assert!(back.grad.is_none());
        assert!(back.observe_at.is_empty());
        assert_eq!(back.lane, Lane::Interactive);
        let got: Vec<u32> = back.z0.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = r.z0.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp);
        assert_eq!(back.batch_key(), r.batch_key(), "the key must survive the wire");

        let g = SolveRequest::fixed("linear", 1.0, -2.0, vec![0.5; 3], 0.125)
            .unwrap()
            .with_grad(vec![1.0, 0.0, -1.0]);
        let j = Json::parse(&g.to_json().to_string()).unwrap();
        let back = SolveRequest::from_json(&j).unwrap();
        assert_eq!(back.tol, Tolerance::Fixed { h: 0.125 });
        assert_eq!(back.grad, Some(vec![1.0, 0.0, -1.0]));
        assert_eq!(back.batch_key(), g.batch_key());

        // Dense-output grid and lane survive the wire; the grid rides as
        // plain f64 numbers, whose shortest form round-trips bit-exactly.
        let o = SolveRequest::builder("vdp")
            .span(0.0, 5.0)
            .state(vec![2.0, 0.0])
            .adaptive(1e-6, 1e-8)
            .observe_at(vec![0.1, 2.5, 4.999999999999999])
            .priority(Lane::Batch)
            .build()
            .unwrap();
        let j = Json::parse(&o.to_json().to_string()).unwrap();
        let back = SolveRequest::from_json(&j).unwrap();
        let got: Vec<u64> = back.observe_at.iter().map(|t| t.to_bits()).collect();
        let exp: Vec<u64> = o.observe_at.iter().map(|t| t.to_bits()).collect();
        assert_eq!(got, exp, "grid must round-trip bit-exactly");
        assert_eq!(back.lane, Lane::Batch);
        assert_eq!(back.batch_key(), o.batch_key());

        assert!(SolveRequest::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut bad = r.to_json();
        if let Json::Obj(m) = &mut bad {
            m.insert("tab".into(), "nope".into());
        }
        assert!(SolveRequest::from_json(&bad).is_err(), "unknown tableau must not decode");
    }

    #[test]
    fn trace_context_rides_optionally_and_round_trips() {
        use crate::obs::{TraceCtx, TraceId};
        // Untraced requests put no trace fields on the wire and decode
        // back as untraced (the pre-trace schema, bit for bit).
        let plain = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        let j = plain.to_json();
        assert!(j.opt("trace").is_none(), "no trace fields for untraced requests");
        assert!(SolveRequest::from_json(&j).unwrap().trace.is_none());

        // A full context — including a dispatcher-stamped shard — survives.
        let ctx = TraceCtx { trace: TraceId(0xdead_beef_0000_0001), parent: 42, shard: 1 };
        let mut traced = plain.clone();
        traced.trace = Some(ctx);
        let j = Json::parse(&traced.to_json().to_string()).unwrap();
        let back = SolveRequest::from_json(&j).unwrap();
        assert_eq!(back.trace, Some(ctx));
        assert_eq!(back.batch_key(), plain.batch_key(), "trace never joins the key");

        // Front-door contexts (shard −1) omit the shard field and decode
        // back to −1; a malformed trace id is an error, not a default.
        let mut front = plain.clone();
        front.trace = Some(TraceCtx { trace: TraceId(7), parent: 0, shard: -1 });
        let j = front.to_json();
        assert!(j.opt("trace_shard").is_none());
        assert_eq!(SolveRequest::from_json(&j).unwrap().trace.unwrap().shard, -1);
        let mut bad = front.to_json();
        if let Json::Obj(m) = &mut bad {
            m.insert("trace".into(), "xyz".into());
        }
        assert!(SolveRequest::from_json(&bad).is_err());
    }

    #[test]
    fn missing_lane_decodes_as_interactive() {
        let r = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("lane");
        }
        let back = SolveRequest::from_json(&j).unwrap();
        assert_eq!(back.lane, Lane::Interactive);
        // …but a present-and-bogus lane is an error, not a default.
        if let Json::Obj(m) = &mut j {
            m.insert("lane".into(), "express".into());
        }
        assert!(SolveRequest::from_json(&j).is_err());
    }

    #[test]
    fn unknown_wire_version_is_a_typed_error() {
        let r = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        let mut j = r.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), 2.0.into());
        }
        let err = SolveRequest::from_json(&j).unwrap_err();
        let ver = err.downcast_ref::<WireVersionError>().expect("typed version error");
        assert_eq!(ver.got, 2);
        assert!(ver.to_string().contains("unsupported wire version 2"), "{ver}");
        // A missing version field is malformed (not a version mismatch).
        if let Json::Obj(m) = &mut j {
            m.remove("v");
        }
        let err = SolveRequest::from_json(&j).unwrap_err();
        assert!(err.downcast_ref::<WireVersionError>().is_none());
        assert!(err.to_string().contains("missing wire version"), "{err}");

        // The same gate guards responses and errors.
        let resp = SolveResponse {
            payload: Payload::Forward { z_t1: vec![1.0] },
            stats: RequestStats::default(),
        };
        let mut j = resp.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), 7.0.into());
        }
        let err = SolveResponse::from_json(&j).unwrap_err();
        assert_eq!(err.downcast_ref::<WireVersionError>(), Some(&WireVersionError { got: 7 }));
        let mut j = ServeError::Overloaded.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("v".into(), 0.0.into());
        }
        let err = ServeError::from_json(&j).unwrap_err();
        assert_eq!(err.downcast_ref::<WireVersionError>(), Some(&WireVersionError { got: 0 }));
    }

    #[test]
    fn response_and_error_json_round_trip() {
        let resp = SolveResponse {
            payload: Payload::Gradient {
                z_t1: vec![1.5, f32::NAN, -0.0],
                grad: GradResult {
                    dl_dz0: vec![0.25, -0.5, 1e-45],
                    dl_dtheta: vec![3.5],
                    meter: crate::grad::CostMeter {
                        nfe_forward: 10,
                        nfe_backward: 20,
                        nfe_replay: 3,
                        replay_peak_bytes: 128,
                        vjp_calls: 5,
                        checkpoint_bytes: 256,
                        graph_depth: 7,
                        n_steps: 11,
                        n_rejected: 2,
                        n_reverse_steps: 0,
                    },
                },
            },
            stats: RequestStats {
                steps: 11,
                nfe: 44,
                n_rejected: 2,
                avg_m: 1.25,
                checkpoint_bytes: 256,
                batch_size: 4,
                queue_wait: Duration::from_micros(250),
                service: Duration::from_millis(3),
            },
        };
        let j = Json::parse(&resp.to_json().to_string()).unwrap();
        let back = SolveResponse::from_json(&j).unwrap();
        let got: Vec<u32> = back.z_t1().iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = resp.z_t1().iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp, "NaN and -0.0 states must survive the wire");
        let bg = back.grad().expect("gradient payload");
        assert_eq!(bg.dl_dtheta, vec![3.5]);
        assert_eq!(bg.dl_dz0[2].to_bits(), 1e-45f32.to_bits());
        assert_eq!(bg.meter.nfe_backward, 20);
        assert_eq!(bg.meter.n_reverse_steps, 0);
        assert_eq!(back.stats.batch_size, 4);
        assert_eq!(back.stats.queue_wait, Duration::from_micros(250));
        assert_eq!(back.stats.service, Duration::from_millis(3));

        // Forward and observed payloads keep their class across the wire.
        let fwd = SolveResponse {
            payload: Payload::Forward { z_t1: vec![2.0] },
            stats: RequestStats::default(),
        };
        let back = SolveResponse::from_json(&Json::parse(&fwd.to_json().to_string()).unwrap())
            .unwrap();
        assert!(back.grad().is_none());
        assert!(back.observations().is_none());

        let obs = SolveResponse {
            payload: Payload::Observed {
                z_t1: vec![1.0, 2.0],
                zs: vec![vec![0.5, -0.0], vec![f32::NAN, 1e-45]],
            },
            stats: RequestStats::default(),
        };
        let back = SolveResponse::from_json(&Json::parse(&obs.to_json().to_string()).unwrap())
            .unwrap();
        let zs = back.observations().expect("observed payload");
        assert_eq!(zs.len(), 2);
        assert_eq!(zs[1][0].to_bits(), f32::NAN.to_bits(), "observed states keep their bits");
        assert_eq!(zs[1][1].to_bits(), 1e-45f32.to_bits());
        assert_eq!(zs[0][1].to_bits(), (-0.0f32).to_bits());

        for e in [
            ServeError::Overloaded,
            ServeError::ShuttingDown,
            ServeError::UnknownDynamics("ghost".into()),
            ServeError::BadRequest("z0 length".into()),
            ServeError::Solver("step underflow".into()),
        ] {
            let back = ServeError::from_json(&Json::parse(&e.to_json().to_string()).unwrap());
            assert_eq!(back.unwrap(), e, "error variants must survive the wire");
        }
        assert!(ServeError::from_json(
            &Json::parse(r#"{"v":1,"kind":"??","msg":""}"#).unwrap()
        )
        .is_err());
    }
}
