//! `serve` — a dynamic micro-batching solve server over the batched engine.
//!
//! The ROADMAP's north star is serving heavy solve traffic; this subsystem
//! is the serving layer over [`crate::ode::integrate_batch_spans`] /
//! [`crate::grad::aca_backward_batch`]. Adaptive solvers make per-request
//! cost variable (NFE differs per initial condition), which is exactly the
//! workload where **dynamic batching** beats both one-request-at-a-time
//! dispatch and fixed-size batching: the engine's per-sample step control
//! and per-sample spans mean heterogeneous requests — different initial
//! states *and different endpoints `t1`* — share a batch *without changing
//! any per-sample result* (the ACA equivalence guarantee), so the batch
//! former is free to coalesce whatever compatible traffic is pending.
//!
//! ## Architecture
//!
//! ```text
//! submit() ── admission ──▶ submission queue (bounded; full ⇒ Overloaded)
//!                               │ batcher thread
//!                               ▼
//!                         BatchFormer  — groups by BatchKey (dynamics,
//!                               │        solver, direction, tolerance,
//!                               │        grad/observe flags, QoS lane —
//!                               │        z0, t0 AND t1 free per request),
//!                               │        flushes on max_batch_size OR
//!                               │        max_queue_delay, whichever trips
//!                               │        first; emits interactive lane
//!                               ▼        first, DRR across tenants
//!                          work queue ──▶ worker shard (N threads)
//!                                            │  integrate_batch_tspans
//!                                            │  (one (t0, t1) per sample;
//!                                            │  + aca_backward_batch
//!                                            │  + DenseOutput observation)
//!                                            ▼
//!                               per-request ResponseHandle + metrics
//! ```
//!
//! External clients reach `submit` through two wire carriers speaking the
//! same versioned JSON schema ([`wire`]): the HTTP front door ([`http`])
//! and the sharded TCP protocol (`crate::dist`). QoS — priority lanes and
//! per-tenant (per-dynamics) deficit-round-robin quotas — lives in the
//! [`batcher::BatchFormer`]'s emission ordering; see its module docs.
//!
//! * [`SolveServer::submit`] returns a [`ResponseHandle`] immediately, or
//!   [`ServeError::Overloaded`] when `queue_capacity` requests are already
//!   in flight (admission control — the queue never grows unboundedly) —
//!   **or** when admitting the request would push the *projected checkpoint
//!   bytes* of all in-flight requests past `mem_budget_bytes`. The
//!   projection upper-bounds what a solve can pin: the state part
//!   (`dim × (max_steps + 1) × 4`, capped by the per-sample checkpoint
//!   budget when one is set) plus the never-thinned trajectory spine. The
//!   budget gates *concurrency*: an idle server always admits one request
//!   (minimum progress — worker memory is then bounded by that request)
//!   rather than bricking under a budget below the smallest charge. A
//!   worker can no longer be OOM'd by traffic that admission control
//!   happily counted: memory is admitted, not just request count.
//! * [`SolveServer::drain`] flushes partial batches and blocks until every
//!   admitted request is answered; [`SolveServer::shutdown`] additionally
//!   stops the threads (in-flight work is still drained, never dropped).
//! * Determinism: the flush policy lives in the pure
//!   [`batcher::BatchFormer`] state machine and all timing flows through an
//!   injected [`Clock`], so policies are unit-testable with a
//!   [`ManualClock`] and explicit `drain()` — no sleeps anywhere in the
//!   tests.
//!
//! ## Tuning knobs (`NODAL_SERVE_*`)
//!
//! [`ServeConfig::from_env`] reads, parses **and clamps at the source**
//! (mirroring [`crate::coordinator::pool::default_workers`]):
//!
//! | env var                    | meaning                     | default, clamp |
//! |----------------------------|-----------------------------|----------------|
//! | `NODAL_SERVE_MAX_BATCH`    | max samples per batch       | 16, 1..=1024   |
//! | `NODAL_SERVE_MAX_DELAY_US` | max queue delay (µs)        | 500, 0..=10⁶   |
//! | `NODAL_SERVE_QUEUE_CAP`    | admitted-unanswered cap     | 1024, 1..=10⁶  |
//! | `NODAL_SERVE_WORKERS`      | worker threads              | [`crate::coordinator::pool::default_workers`], 1..=256 |
//! | `NODAL_CKPT_BUDGET_BYTES`  | per-sample checkpoint budget (0 = dense) | [`crate::ckpt::env_budget_bytes`], 0 or 64..=2⁴⁰ |
//! | `NODAL_SERVE_MEM_BUDGET_BYTES` | projected-checkpoint admission budget (0 = unlimited) | 0, 0 or 64..=2⁴⁰ |
//! | `NODAL_SERVE_QUOTA_QUANTUM` | DRR samples per tenant visit | 32, 1..=1024 |
//! | `NODAL_SERVE_QUOTA_MAX_DEFICIT` | DRR deficit cap (samples)  | 128, 1..=10⁶  |
//!
//! The HTTP front door's own knobs (`NODAL_HTTP_*`) are documented in
//! [`http`].

pub mod batcher;
pub mod http;
pub mod metrics;
pub mod queue;
pub mod request;
pub mod wire;
mod worker;

pub use batcher::{BatchFormer, FlushReason, FormedBatch, Pending};
pub use http::{HttpConfig, HttpServer, SolveFrontend, Waiter};
pub use metrics::{ConnMetrics, CountSummary, LatencySummary, MetricsSnapshot, ServeMetrics};
pub use request::{
    BatchKey, Lane, Payload, RequestStats, ResponseHandle, ServeError, SolveRequest,
    SolveRequestBuilder, SolveResponse, Tolerance,
};
pub use wire::{WireVersionError, WIRE_VERSION};

use crate::coordinator::pool::default_workers;
use crate::ode::OdeFunc;
use queue::{Channel, ChannelState};
use request::ResponseSlot;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Time source injected into the server. Returns a monotone `Duration`
/// since the clock's own epoch; all queue-delay arithmetic happens on that
/// timeline, so tests can substitute a [`ManualClock`].
pub trait Clock: Send + Sync {
    fn now(&self) -> Duration;
}

/// Production clock: monotonic wall time since construction.
#[derive(Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl Default for WallClock {
    // The production `Clock` is the sanctioned wall-clock reader for the
    // serve layer (clippy.toml bans the raw call elsewhere); everything
    // downstream sees only the injected trait.
    #[allow(clippy::disallowed_methods)]
    fn default() -> Self {
        WallClock { epoch: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Test clock: time advances only when the test says so.
#[derive(Debug, Default)]
pub struct ManualClock {
    nanos: std::sync::atomic::AtomicU64,
}

impl ManualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(ManualClock::default())
    }

    pub fn advance(&self, by: Duration) {
        self.nanos.fetch_add(by.as_nanos() as u64, Ordering::SeqCst);
    }

    pub fn set(&self, to: Duration) {
        self.nanos.store(to.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::SeqCst))
    }
}

/// Batching/backpressure policy of one server.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Flush a group as soon as it holds this many requests.
    pub max_batch_size: usize,
    /// Flush a group once its oldest request has waited this long.
    pub max_queue_delay: Duration,
    /// Admission cap: maximum admitted-but-unanswered requests; beyond it
    /// `submit` returns [`ServeError::Overloaded`].
    pub queue_capacity: usize,
    /// Worker threads executing batches.
    pub workers: usize,
    /// Per-sample checkpoint budget for worker solves (0 = dense storage).
    /// Nonzero values run every solve under
    /// [`CkptPolicy::Budgeted`](crate::ckpt::CkptPolicy) — answers are
    /// bit-identical (segment replay), only the memory a solve can pin
    /// changes.
    pub ckpt_budget_bytes: usize,
    /// Worker memory budget for admission (0 = unlimited): the sum of
    /// projected checkpoint bytes
    /// ([`SolveRequest::projected_ckpt_bytes`]) over
    /// admitted-but-unanswered requests may not exceed this; beyond it
    /// `submit` sheds load with [`ServeError::Overloaded`].
    pub mem_budget_bytes: usize,
    /// QoS: deficit-round-robin credits (samples) granted per tenant per
    /// emission visit (see [`batcher::BatchFormer::with_quota`]).
    pub quota_quantum: usize,
    /// QoS: cap on a tenant's accumulated DRR credits; floored at
    /// `max(max_batch_size, quota_quantum)` by the former so a full batch
    /// always eventually affords emission.
    pub quota_max_deficit: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig::from_env()
    }
}

/// Parse-and-clamp an env override at the source (the `default_workers`
/// convention): unset or unparseable falls back to `default`.
fn env_clamped(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(lo, hi),
        None => default,
    }
}

impl ServeConfig {
    /// Defaults with `NODAL_SERVE_*` / `NODAL_CKPT_*` overrides (see module
    /// docs).
    pub fn from_env() -> Self {
        ServeConfig {
            max_batch_size: env_clamped("NODAL_SERVE_MAX_BATCH", 16, 1, 1024),
            max_queue_delay: Duration::from_micros(env_clamped(
                "NODAL_SERVE_MAX_DELAY_US",
                500,
                0,
                1_000_000,
            ) as u64),
            queue_capacity: env_clamped("NODAL_SERVE_QUEUE_CAP", 1024, 1, 1_000_000),
            // Same hard cap as the coordinator pool's NODAL_WORKERS clamp.
            workers: env_clamped("NODAL_SERVE_WORKERS", default_workers(), 1, 256),
            ckpt_budget_bytes: crate::ckpt::env_budget_bytes(),
            // 0 = unlimited; nonzero parsed-and-clamped like the ckpt budget.
            mem_budget_bytes: crate::ckpt::parse_budget_env("NODAL_SERVE_MEM_BUDGET_BYTES"),
            quota_quantum: env_clamped("NODAL_SERVE_QUOTA_QUANTUM", 32, 1, 1024),
            quota_max_deficit: env_clamped("NODAL_SERVE_QUOTA_MAX_DEFICIT", 128, 1, 1_000_000),
        }
    }
}

/// The admission ledger: how many requests are admitted-but-unanswered and
/// how many projected checkpoint bytes they can pin in workers.
#[derive(Default)]
struct Inflight {
    count: usize,
    bytes: usize,
}

/// Shared server state (registry, queues, clock, metrics, lifecycle flags).
pub(crate) struct Core {
    pub(crate) cfg: ServeConfig,
    pub(crate) clock: Arc<dyn Clock>,
    pub(crate) registry: HashMap<String, Arc<dyn OdeFunc + Send + Sync>>,
    pub(crate) metrics: ServeMetrics,
    pub(crate) submit_q: Channel<Pending>,
    pub(crate) work_q: Channel<FormedBatch>,
    /// Admitted-but-unanswered requests + their projected checkpoint bytes;
    /// the admission-control meters.
    inflight: Mutex<Inflight>,
    idle: Condvar,
    /// `drain()` callers currently waiting — the batcher flushes partial
    /// groups whenever this is non-zero.
    drain_waiters: AtomicUsize,
    closed: AtomicBool,
}

impl Core {
    /// Deliver a result and release the request's admission slot (count and
    /// projected bytes — `cost` must be the value charged at admission).
    pub(crate) fn complete(
        &self,
        slot: &ResponseSlot,
        cost: usize,
        result: Result<SolveResponse, ServeError>,
    ) {
        slot.fulfill(result);
        let mut led = self.inflight.lock().unwrap();
        led.count -= 1;
        led.bytes = led.bytes.saturating_sub(cost);
        if led.count == 0 {
            self.idle.notify_all();
        }
    }
}

/// The dynamic micro-batching solve server. Construct via
/// [`SolveServer::builder`]; see the module docs for the architecture.
pub struct SolveServer {
    core: Arc<Core>,
    batcher: Mutex<Option<JoinHandle<()>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

/// Builder: register dynamics, then [`SolveServerBuilder::start`].
pub struct SolveServerBuilder {
    cfg: ServeConfig,
    clock: Option<Arc<dyn Clock>>,
    registry: HashMap<String, Arc<dyn OdeFunc + Send + Sync>>,
}

impl SolveServerBuilder {
    /// Register a dynamics under `id`; requests name it by this id.
    pub fn register<F>(mut self, id: &str, f: F) -> Self
    where
        F: OdeFunc + Send + Sync + 'static,
    {
        self.registry.insert(id.to_string(), Arc::new(f));
        self
    }

    pub fn config(mut self, cfg: ServeConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Inject a time source (tests pass a [`ManualClock`]).
    pub fn clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = Some(clock);
        self
    }

    /// Spawn the batcher thread and the worker shard and start serving.
    ///
    /// Hand-built configs are clamped the way [`ServeConfig::from_env`]
    /// clamps env overrides: `workers: 0` would deadlock every request (no
    /// one executes batches) and `queue_capacity: 0` would bounce every
    /// submission — the exact zero-pool footgun `default_workers` guards
    /// against.
    pub fn start(self) -> SolveServer {
        let cfg = ServeConfig {
            max_batch_size: self.cfg.max_batch_size.max(1),
            max_queue_delay: self.cfg.max_queue_delay,
            queue_capacity: self.cfg.queue_capacity.max(1),
            workers: self.cfg.workers.clamp(1, 256),
            ckpt_budget_bytes: crate::ckpt::clamp_budget(self.cfg.ckpt_budget_bytes),
            mem_budget_bytes: crate::ckpt::clamp_budget(self.cfg.mem_budget_bytes),
            quota_quantum: self.cfg.quota_quantum.clamp(1, 1024),
            quota_max_deficit: self.cfg.quota_max_deficit.clamp(1, 1_000_000),
        };
        let clock = self.clock.unwrap_or_else(|| Arc::new(WallClock::default()));
        let core = Arc::new(Core {
            submit_q: Channel::bounded(cfg.queue_capacity),
            work_q: Channel::unbounded(),
            cfg,
            clock,
            registry: self.registry,
            metrics: ServeMetrics::default(),
            inflight: Mutex::new(Inflight::default()),
            idle: Condvar::new(),
            drain_waiters: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        });
        let batcher = {
            let core = core.clone();
            std::thread::spawn(move || batcher_loop(&core))
        };
        let workers = (0..core.cfg.workers)
            .map(|_| {
                let core = core.clone();
                std::thread::spawn(move || worker::worker_loop(&core))
            })
            .collect();
        SolveServer { core, batcher: Mutex::new(Some(batcher)), workers: Mutex::new(workers) }
    }
}

impl SolveServer {
    pub fn builder() -> SolveServerBuilder {
        SolveServerBuilder {
            cfg: ServeConfig::default(),
            clock: None,
            registry: HashMap::new(),
        }
    }

    /// Submit one request. Returns immediately with a handle, or with
    /// [`ServeError::Overloaded`] / [`ServeError::ShuttingDown`] /
    /// a validation error — admission happens before any queuing.
    ///
    /// Admission is two-dimensional: request *count* (`queue_capacity`) and
    /// projected checkpoint *bytes* (`mem_budget_bytes`, when nonzero). The
    /// byte charge is [`SolveRequest::projected_ckpt_bytes`]'s upper bound
    /// (budget-capped states + the never-thinned spine), released when the
    /// request is answered — so a burst of long-horizon solves sheds load
    /// instead of OOM-ing a worker that a pure count bound would have
    /// admitted.
    pub fn submit(&self, req: SolveRequest) -> Result<ResponseHandle, ServeError> {
        if self.core.closed.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        let dim = self.validate(&req)?;
        let cost = req.projected_ckpt_bytes(dim, self.core.cfg.ckpt_budget_bytes);
        {
            let mut led = self.core.inflight.lock().unwrap();
            let over_count = led.count >= self.core.cfg.queue_capacity;
            // Minimum-progress rule: the byte budget gates *concurrency* —
            // with nothing in flight a request is admitted even when its
            // projection alone exceeds the budget (worker memory is then
            // bounded by that one request), instead of silently bricking
            // the server under a budget below the smallest possible charge.
            let budget = self.core.cfg.mem_budget_bytes;
            let over_bytes =
                budget > 0 && led.count > 0 && led.bytes.saturating_add(cost) > budget;
            if over_count || over_bytes {
                self.core.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::Overloaded);
            }
            led.count += 1;
            led.bytes = led.bytes.saturating_add(cost);
        }
        let (handle, slot) = ResponseHandle::new();
        let pending = Pending { req, slot, submitted: self.core.clock.now(), cost };
        match self.core.submit_q.push(pending) {
            // Count as submitted only once actually queued, so the
            // submitted == completed + failed + rejected ledger balances
            // even when a push loses the race against close().
            Ok(()) => {
                self.core.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(handle)
            }
            // Closed between the flag check and the push: release the
            // admission slot and report the shutdown.
            Err(p) => {
                self.core.complete(&p.slot, p.cost, Err(ServeError::ShuttingDown));
                Err(ServeError::ShuttingDown)
            }
        }
    }

    /// Validate a request against the registry; returns the dynamics' state
    /// dimension (the admission byte-charge needs it).
    ///
    /// Shape validation (span, tolerances, finiteness, grad/observe
    /// exclusivity) already ran in [`SolveRequestBuilder::build`], but
    /// requests are plain-old-data — a hand-rolled struct literal bypasses
    /// the builder — so admission re-runs
    /// [`SolveRequest::validate_shape`] and adds the registry-dependent
    /// checks (dynamics existence, state dimension).
    fn validate(&self, req: &SolveRequest) -> Result<usize, ServeError> {
        let f = self
            .core
            .registry
            .get(&req.dynamics)
            .ok_or_else(|| ServeError::UnknownDynamics(req.dynamics.clone()))?;
        let dim = f.dim();
        if req.z0.len() != dim {
            return Err(ServeError::BadRequest(format!(
                "z0 length {} != dynamics dim {dim}",
                req.z0.len()
            )));
        }
        req.validate_shape()?;
        Ok(dim)
    }

    /// Flush all partial batches and block until every admitted request has
    /// been answered. Concurrent submitters can extend the wait.
    pub fn drain(&self) {
        self.core.drain_waiters.fetch_add(1, Ordering::SeqCst);
        self.core.submit_q.kick();
        let led = self.core.inflight.lock().unwrap();
        let _led = self.core.idle.wait_while(led, |led| led.count > 0).unwrap();
        self.core.drain_waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Stop accepting work, drain everything in flight, and join all server
    /// threads. Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        self.core.closed.store(true, Ordering::SeqCst);
        self.core.submit_q.close();
        // Move the handles out of their mutexes before joining: holding
        // either lock across a join would block a concurrent shutdown (or
        // drop) for the whole thread lifetime.
        let batcher = self.batcher.lock().unwrap().take();
        if let Some(h) = batcher {
            let _ = h.join();
        }
        // The batcher has dispatched everything it will ever dispatch;
        // closing the work queue lets workers drain the remainder and exit.
        self.core.work_q.close();
        let workers: Vec<JoinHandle<()>> = self.workers.lock().unwrap().drain(..).collect();
        for h in workers {
            let _ = h.join();
        }
    }

    /// Point-in-time aggregate metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }

    /// Admitted-but-unanswered requests right now.
    pub fn inflight(&self) -> usize {
        self.core.inflight.lock().unwrap().count
    }

    /// Projected checkpoint bytes currently charged against the admission
    /// memory budget ([`SolveRequest::projected_ckpt_bytes`] summed over
    /// admitted-unanswered requests).
    pub fn inflight_bytes(&self) -> usize {
        self.core.inflight.lock().unwrap().bytes
    }

    /// The server's configuration (after env clamping).
    pub fn config(&self) -> &ServeConfig {
        &self.core.cfg
    }
}

impl Drop for SolveServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The batch-former thread: pull submissions, coalesce, dispatch.
fn batcher_loop(core: &Core) {
    let mut former = BatchFormer::with_quota(
        core.cfg.max_batch_size,
        core.cfg.max_queue_delay,
        core.cfg.quota_quantum,
        core.cfg.quota_max_deficit,
    );
    let mut pulled: Vec<Pending> = Vec::new();
    loop {
        // Receive before flushing. While a drain() is waiting the receive is
        // non-blocking, so every request already in the submission queue
        // reaches the former before the drain flush — a drain that follows a
        // burst of submits coalesces the full burst instead of whatever
        // subset happened to be pulled already. Otherwise sleep until new
        // work arrives, a drain() kicks us, or the earliest group deadline
        // passes (with a ManualClock that wall wait is just an upper bound —
        // drain()'s kick is what actually wakes us; tests never sleep it
        // out).
        let draining = core.drain_waiters.load(Ordering::SeqCst) > 0;
        let timeout = if draining && !former.is_empty() {
            Some(Duration::ZERO)
        } else if draining {
            None // everything flushed; block until new work or shutdown
        } else {
            former
                .next_deadline()
                .map(|d| d.saturating_sub(core.clock.now()).max(Duration::from_micros(50)))
        };
        let state = core.submit_q.recv_all(timeout, &mut pulled);
        let now = core.clock.now();
        for p in pulled.drain(..) {
            former.push(p, now);
        }
        // Re-check the drain flag after the receive, and if it is set scoop
        // the queue once more without blocking: every submit that
        // happened-before the drain() call is already in the queue by the
        // time the flag reads true, so the drain flush below sees the whole
        // pre-drain burst — never a subset.
        let draining = draining || core.drain_waiters.load(Ordering::SeqCst) > 0;
        if draining {
            core.submit_q.recv_all(Some(Duration::ZERO), &mut pulled);
            for p in pulled.drain(..) {
                former.push(p, now);
            }
        }
        let flushed = if draining { former.drain(now) } else { former.poll(now) };
        for b in flushed {
            dispatch(core, b);
        }
        if state == ChannelState::Closed {
            for b in former.drain(core.clock.now()) {
                dispatch(core, b);
            }
            return;
        }
    }
}

fn dispatch(core: &Core, batch: FormedBatch) {
    record_batch_spans(core, &batch);
    if let Err(b) = core.work_q.push(batch) {
        // Unreachable in normal operation (the work queue is unbounded and
        // closes only after this thread exits); fail the batch cleanly
        // rather than dropping its requests.
        for item in &b.items {
            core.complete(&item.slot, item.cost, Err(ServeError::ShuttingDown));
        }
    }
}

/// Trace hook on the batcher thread: one `queue_wait` + one `batch_form`
/// span per traced item, published to the global store *before* the batch
/// reaches the work queue — so by the time a worker fulfills the response
/// the spans are already stitchable. Untraced traffic skips everything.
fn record_batch_spans(core: &Core, batch: &FormedBatch) {
    use crate::obs::{self, SpanRec};
    let mut any = false;
    let now = core.clock.now();
    for item in &batch.items {
        let Some(ctx) = item.req.trace else { continue };
        any = true;
        obs::record(
            SpanRec::new(ctx, obs::QUEUE_WAIT, item.submitted, batch.triggered_at)
                .attr("lane", batch.key.lane as u64)
                .attr("deferred", batch.deferred),
        );
        obs::record(
            SpanRec::new(ctx, obs::BATCH_FORM, batch.triggered_at, now)
                .attr("reason", batch.reason as u64)
                .attr("size", batch.items.len() as u64),
        );
    }
    if any {
        obs::publish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::VanDerPol;

    /// All `NODAL_SERVE_*` cases in ONE test: the process environment is
    /// shared across parallel test threads (same pattern as the pool's
    /// `NODAL_WORKERS` test).
    #[test]
    fn config_env_parse_and_clamp() {
        std::env::set_var("NODAL_SERVE_MAX_BATCH", "0");
        std::env::set_var("NODAL_SERVE_MAX_DELAY_US", "250");
        std::env::set_var("NODAL_SERVE_QUEUE_CAP", "9999999");
        std::env::set_var("NODAL_SERVE_WORKERS", "3");
        std::env::set_var("NODAL_SERVE_MEM_BUDGET_BYTES", "12");
        std::env::set_var("NODAL_SERVE_QUOTA_QUANTUM", "0");
        std::env::set_var("NODAL_SERVE_QUOTA_MAX_DEFICIT", "99999999");
        let cfg = ServeConfig::from_env();
        assert_eq!(cfg.max_batch_size, 1, "zero clamps to one");
        assert_eq!(cfg.max_queue_delay, Duration::from_micros(250));
        assert_eq!(cfg.queue_capacity, 1_000_000, "cap clamps high");
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.mem_budget_bytes, 64, "nonzero budget clamps up");
        assert_eq!(cfg.quota_quantum, 1, "zero quantum clamps to one");
        assert_eq!(cfg.quota_max_deficit, 1_000_000, "deficit cap clamps high");

        std::env::set_var("NODAL_SERVE_MAX_BATCH", "not-a-number");
        std::env::set_var("NODAL_SERVE_MEM_BUDGET_BYTES", "0");
        let cfg = ServeConfig::from_env();
        assert_eq!(cfg.max_batch_size, 16, "unparseable falls back to default");
        assert_eq!(cfg.mem_budget_bytes, 0, "0 means unlimited");

        for k in [
            "NODAL_SERVE_MAX_BATCH",
            "NODAL_SERVE_MAX_DELAY_US",
            "NODAL_SERVE_QUEUE_CAP",
            "NODAL_SERVE_WORKERS",
            "NODAL_SERVE_MEM_BUDGET_BYTES",
            "NODAL_SERVE_QUOTA_QUANTUM",
            "NODAL_SERVE_QUOTA_MAX_DEFICIT",
        ] {
            std::env::remove_var(k);
        }
        let cfg = ServeConfig::from_env();
        assert_eq!(cfg.max_batch_size, 16);
        assert_eq!(cfg.max_queue_delay, Duration::from_micros(500));
        assert_eq!(cfg.queue_capacity, 1024);
        assert!(cfg.workers >= 1);
        assert_eq!(cfg.mem_budget_bytes, 0);
        assert_eq!(cfg.quota_quantum, 32);
        assert_eq!(cfg.quota_max_deficit, 128);
    }

    #[test]
    fn manual_clock_advances_only_on_demand() {
        let c = ManualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(5));
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(10));
        c.set(Duration::from_secs(1));
        assert_eq!(c.now(), Duration::from_secs(1));
    }

    #[test]
    fn submit_validation_errors() {
        let server = SolveServer::builder().register("vdp", VanDerPol::new(0.5)).start();
        let err = server
            .submit(SolveRequest::adaptive("nope", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap())
            .unwrap_err();
        assert!(matches!(err, ServeError::UnknownDynamics(_)), "{err}");

        let err = server
            .submit(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0], 1e-6, 1e-8).unwrap())
            .unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "dim mismatch: {err}");

        // Shape errors that the builder already rejects must ALSO bounce at
        // submit when the request is hand-mutated past the builder (the
        // fields are pub; admission re-validates).
        let mut bad_h = SolveRequest::fixed("vdp", 0.0, 1.0, vec![1.0, 0.0], 0.1).unwrap();
        bad_h.tol = Tolerance::Fixed { h: -0.1 };
        let err = server.submit(bad_h).unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "negative h: {err}");

        let mut bad_tab =
            SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        bad_tab.tab = crate::ode::tableau::rk4();
        let err = server.submit(bad_tab).unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "fixed tab + tol: {err}");

        let err = server
            .submit(
                SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8)
                    .unwrap()
                    .with_grad(vec![1.0]),
            )
            .unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "lam mismatch: {err}");

        let mut combo =
            SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        combo.grad = Some(vec![1.0, 0.0]);
        combo.observe_at = vec![0.5];
        let err = server.submit(combo).unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "grad+observe: {err}");

        server.shutdown();
        let err = server
            .submit(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap())
            .unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }

    /// Admission bugfix: a zero-length span used to sail through validation
    /// (t0/t1 are finite) and reach the solver. It now bounces at `build()`
    /// — and a hand-rolled struct literal that skips the builder still
    /// bounces at submit.
    #[test]
    fn zero_span_rejected_at_admission() {
        for t in [0.0, 2.5, -1.0] {
            let err =
                SolveRequest::adaptive("vdp", t, t, vec![1.0, 0.0], 1e-6, 1e-8).unwrap_err();
            match err {
                ServeError::BadRequest(msg) => {
                    assert!(msg.contains("zero-length span"), "{msg}")
                }
                other => panic!("zero span must be BadRequest, got {other:?}"),
            }
        }
        let server = SolveServer::builder().register("vdp", VanDerPol::new(0.5)).start();
        let literal = SolveRequest {
            dynamics: "vdp".into(),
            t0: 2.5,
            t1: 2.5,
            z0: vec![1.0, 0.0],
            tab: crate::ode::tableau::dopri5(),
            tol: Tolerance::Adaptive { rtol: 1e-6, atol: 1e-8 },
            grad: None,
            observe_at: Vec::new(),
            lane: Lane::Interactive,
            trace: None,
        };
        match server.submit(literal).unwrap_err() {
            ServeError::BadRequest(msg) => assert!(msg.contains("zero-length span"), "{msg}"),
            other => panic!("zero span must be BadRequest, got {other:?}"),
        }
        // Nothing was admitted: the ledger is untouched and a real request
        // still goes through.
        assert_eq!(server.inflight(), 0);
        assert_eq!(server.metrics().submitted, 0);
        let h = server
            .submit(SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1).unwrap())
            .unwrap();
        assert!(h.wait().is_ok());
    }

    #[test]
    fn start_clamps_degenerate_configs() {
        // workers: 0 would leave dispatched batches unexecuted forever and
        // queue_capacity: 0 would bounce every submission.
        let server = SolveServer::builder()
            .register("vdp", VanDerPol::new(0.5))
            .config(ServeConfig {
                max_batch_size: 0,
                max_queue_delay: Duration::ZERO,
                queue_capacity: 0,
                workers: 0,
                ckpt_budget_bytes: 0,
                mem_budget_bytes: 0,
                quota_quantum: 0,
                quota_max_deficit: 0,
            })
            .start();
        assert_eq!(server.config().workers, 1);
        assert_eq!(server.config().queue_capacity, 1);
        assert_eq!(server.config().max_batch_size, 1);
        let h = server
            .submit(SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1).unwrap())
            .unwrap();
        assert!(h.wait().is_ok(), "clamped server must still serve");
    }

    /// Admission accounts projected checkpoint *bytes*, not just request
    /// count: a budget sized for exactly one in-flight request sheds the
    /// second with `Overloaded`, and admits again once the first completes.
    #[test]
    fn mem_budget_sheds_load_by_projected_bytes() {
        let req = || SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1).unwrap();
        // Fixed-step projection for dim 2: exact ⌈0.5/0.1⌉+1 = 6 steps of
        // states + spine (a few hundred bytes), not the adaptive
        // max_steps bound.
        let one = req().projected_ckpt_bytes(2, 0);
        let server = SolveServer::builder()
            .register("vdp", VanDerPol::new(0.5))
            .config(ServeConfig {
                max_batch_size: 4,
                // Far-future deadline: requests sit in the former until the
                // budget test submits both, so the charge overlap is
                // deterministic.
                max_queue_delay: Duration::from_secs(3600),
                queue_capacity: 64,
                workers: 1,
                ckpt_budget_bytes: 0,
                mem_budget_bytes: one, // exactly one request's projection
                quota_quantum: 32,
                quota_max_deficit: 128,
            })
            .start();
        let h1 = server.submit(req()).unwrap();
        assert_eq!(server.inflight_bytes(), one, "first request charged its projection");
        let err = server.submit(req()).unwrap_err();
        assert_eq!(err, ServeError::Overloaded, "budget must shed the second request");
        assert_eq!(server.metrics().rejected, 1);
        server.drain();
        assert!(h1.wait().is_ok());
        assert_eq!(server.inflight_bytes(), 0, "completion releases the byte charge");
        let h3 = server.submit(req()).unwrap();
        server.drain();
        assert!(h3.wait().is_ok(), "admission must recover after the charge releases");
    }

    /// With a per-sample checkpoint budget configured, the admission charge
    /// of a forward-only adaptive request caps its state part: a memory
    /// budget sized for three capped charges admits exactly three
    /// concurrent requests and sheds the fourth.
    #[test]
    fn ckpt_budget_caps_admission_charge() {
        let req =
            || SolveRequest::adaptive("vdp", 0.0, 0.5, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        let capped = req().projected_ckpt_bytes(2, 4096);
        let uncapped = req().projected_ckpt_bytes(2, 0);
        assert!(capped < uncapped, "the ckpt budget must shrink the admission charge");
        let server = SolveServer::builder()
            .register("vdp", VanDerPol::new(0.5))
            .config(ServeConfig {
                max_batch_size: 8,
                // Far-future deadline: admitted requests stay in flight
                // until drain, so the charge overlap is deterministic.
                max_queue_delay: Duration::from_secs(3600),
                queue_capacity: 64,
                workers: 1,
                ckpt_budget_bytes: 4096,
                mem_budget_bytes: 3 * capped,
                quota_quantum: 32,
                quota_max_deficit: 128,
            })
            .start();
        let hs: Vec<_> = (0..3).map(|_| server.submit(req()).unwrap()).collect();
        assert_eq!(server.inflight_bytes(), 3 * capped);
        assert_eq!(
            server.submit(req()).unwrap_err(),
            ServeError::Overloaded,
            "budget sized for three capped charges must shed the fourth"
        );
        server.drain();
        for h in hs {
            assert!(h.wait().is_ok(), "budget-capped requests must be admitted and served");
        }
    }

    /// Minimum-progress rule: a memory budget below even one request's
    /// projection must not brick an idle server — the first request admits
    /// (bounding worker memory to itself); the second sheds.
    #[test]
    fn mem_budget_below_floor_still_admits_when_idle() {
        let server = SolveServer::builder()
            .register("vdp", VanDerPol::new(0.5))
            .config(ServeConfig {
                max_batch_size: 8,
                max_queue_delay: Duration::from_secs(3600),
                queue_capacity: 64,
                workers: 1,
                ckpt_budget_bytes: 0,
                mem_budget_bytes: 64, // below any request's charge
                quota_quantum: 32,
                quota_max_deficit: 128,
            })
            .start();
        let req = || SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1).unwrap();
        let h1 = server.submit(req()).expect("idle server must admit one request");
        assert_eq!(server.submit(req()).unwrap_err(), ServeError::Overloaded);
        server.drain();
        assert!(h1.wait().is_ok());
        assert!(server.submit(req()).is_ok(), "admission recovers once idle again");
    }

    #[test]
    fn smoke_submit_and_wait() {
        let server = SolveServer::builder().register("vdp", VanDerPol::new(0.5)).start();
        let h = server
            .submit(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![2.0, 0.0], 1e-6, 1e-8).unwrap())
            .unwrap();
        let resp = h.wait().unwrap();
        assert_eq!(resp.z_t1().len(), 2);
        assert!(resp.stats.nfe > 0);
        assert!(resp.stats.batch_size >= 1);
        // `wait` can return between the slot fulfillment and the admission
        // release; drain() waits for the release before we assert on it.
        server.drain();
        let m = server.metrics();
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(server.inflight(), 0);
    }

    /// Graceful-drain contract: `shutdown()` answers every admitted
    /// request before returning — none dropped, none failed. The manual
    /// clock never advances and the batch cap is never reached, so all
    /// ten requests are still sitting in the former when shutdown lands;
    /// only the drain path can answer them.
    #[test]
    fn shutdown_answers_every_admitted_request() {
        let clock = ManualClock::new();
        let server = SolveServer::builder()
            .register("vdp", VanDerPol::new(0.5))
            .clock(clock)
            .config(ServeConfig {
                max_batch_size: 64, // never reached: no size-triggered flush
                max_queue_delay: Duration::from_secs(3600), // never due
                queue_capacity: 64,
                workers: 2,
                ckpt_budget_bytes: 0,
                mem_budget_bytes: 0,
                quota_quantum: 32,
                quota_max_deficit: 128,
            })
            .start();
        // Three distinct batch keys, interleaved, so the drain has to
        // flush multiple partial batches.
        let mut handles = Vec::new();
        for i in 0..10 {
            let req = match i % 3 {
                0 => SolveRequest::adaptive("vdp", 0.0, 0.5, vec![1.0, 0.0], 1e-6, 1e-8),
                1 => SolveRequest::adaptive("vdp", 0.0, 0.5, vec![0.5, 0.1], 1e-5, 1e-8),
                _ => SolveRequest::fixed("vdp", 0.0, 0.5, vec![2.0, 0.0], 0.1),
            };
            handles.push(server.submit(req.unwrap()).unwrap());
        }
        assert_eq!(server.inflight(), 10, "all ten admitted, none answered yet");
        server.shutdown();
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.wait().unwrap_or_else(|e| panic!("request {i} dropped: {e}"));
            assert_eq!(resp.z_t1().len(), 2);
        }
        assert_eq!(server.inflight(), 0);
        let m = server.metrics();
        assert_eq!(m.submitted, 10);
        assert_eq!(m.completed, 10, "shutdown must answer, not drop");
        assert_eq!(m.failed, 0);
        assert_eq!(m.rejected, 0);
        // Post-shutdown submissions bounce cleanly.
        let err = server
            .submit(SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1).unwrap())
            .unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }
}
