//! `serve` — a dynamic micro-batching solve server over the batched engine.
//!
//! The ROADMAP's north star is serving heavy solve traffic; this subsystem
//! is the serving layer over [`crate::ode::integrate_batch_spans`] /
//! [`crate::grad::aca_backward_batch`]. Adaptive solvers make per-request
//! cost variable (NFE differs per initial condition), which is exactly the
//! workload where **dynamic batching** beats both one-request-at-a-time
//! dispatch and fixed-size batching: the engine's per-sample step control
//! and per-sample spans mean heterogeneous requests — different initial
//! states *and different endpoints `t1`* — share a batch *without changing
//! any per-sample result* (the ACA equivalence guarantee), so the batch
//! former is free to coalesce whatever compatible traffic is pending.
//!
//! ## Architecture
//!
//! ```text
//! submit() ── admission ──▶ submission queue (bounded; full ⇒ Overloaded)
//!                               │ batcher thread
//!                               ▼
//!                         BatchFormer  — groups by BatchKey (dynamics,
//!                               │        solver, t0, direction, tolerance,
//!                               │        grad flag — z0 AND t1 free per
//!                               │        request), flushes on
//!                               │        max_batch_size OR max_queue_delay,
//!                               ▼        whichever trips first
//!                          work queue ──▶ worker shard (N threads)
//!                                            │  integrate_batch_spans
//!                                            │  (one t1 per sample;
//!                                            │  + aca_backward_batch)
//!                                            ▼
//!                               per-request ResponseHandle + metrics
//! ```
//!
//! * [`SolveServer::submit`] returns a [`ResponseHandle`] immediately, or
//!   [`ServeError::Overloaded`] when `queue_capacity` requests are already
//!   in flight (admission control — the queue never grows unboundedly).
//! * [`SolveServer::drain`] flushes partial batches and blocks until every
//!   admitted request is answered; [`SolveServer::shutdown`] additionally
//!   stops the threads (in-flight work is still drained, never dropped).
//! * Determinism: the flush policy lives in the pure
//!   [`batcher::BatchFormer`] state machine and all timing flows through an
//!   injected [`Clock`], so policies are unit-testable with a
//!   [`ManualClock`] and explicit `drain()` — no sleeps anywhere in the
//!   tests.
//!
//! ## Tuning knobs (`NODAL_SERVE_*`)
//!
//! [`ServeConfig::from_env`] reads, parses **and clamps at the source**
//! (mirroring [`crate::coordinator::pool::default_workers`]):
//!
//! | env var                    | meaning                     | default, clamp |
//! |----------------------------|-----------------------------|----------------|
//! | `NODAL_SERVE_MAX_BATCH`    | max samples per batch       | 16, 1..=1024   |
//! | `NODAL_SERVE_MAX_DELAY_US` | max queue delay (µs)        | 500, 0..=10⁶   |
//! | `NODAL_SERVE_QUEUE_CAP`    | admitted-unanswered cap     | 1024, 1..=10⁶  |
//! | `NODAL_SERVE_WORKERS`      | worker threads              | [`crate::coordinator::pool::default_workers`], 1..=256 |

pub mod batcher;
pub mod metrics;
pub mod queue;
pub mod request;
mod worker;

pub use batcher::{BatchFormer, FlushReason, FormedBatch, Pending};
pub use metrics::{LatencySummary, MetricsSnapshot, ServeMetrics};
pub use request::{
    BatchKey, RequestStats, ResponseHandle, ServeError, SolveRequest, SolveResponse, Tolerance,
};

use crate::coordinator::pool::default_workers;
use crate::ode::OdeFunc;
use queue::{Channel, ChannelState};
use request::ResponseSlot;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Time source injected into the server. Returns a monotone `Duration`
/// since the clock's own epoch; all queue-delay arithmetic happens on that
/// timeline, so tests can substitute a [`ManualClock`].
pub trait Clock: Send + Sync {
    fn now(&self) -> Duration;
}

/// Production clock: monotonic wall time since construction.
#[derive(Debug)]
pub struct WallClock {
    epoch: Instant,
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock { epoch: Instant::now() }
    }
}

impl Clock for WallClock {
    fn now(&self) -> Duration {
        self.epoch.elapsed()
    }
}

/// Test clock: time advances only when the test says so.
#[derive(Debug, Default)]
pub struct ManualClock {
    nanos: std::sync::atomic::AtomicU64,
}

impl ManualClock {
    pub fn new() -> Arc<Self> {
        Arc::new(ManualClock::default())
    }

    pub fn advance(&self, by: Duration) {
        self.nanos.fetch_add(by.as_nanos() as u64, Ordering::SeqCst);
    }

    pub fn set(&self, to: Duration) {
        self.nanos.store(to.as_nanos() as u64, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now(&self) -> Duration {
        Duration::from_nanos(self.nanos.load(Ordering::SeqCst))
    }
}

/// Batching/backpressure policy of one server.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Flush a group as soon as it holds this many requests.
    pub max_batch_size: usize,
    /// Flush a group once its oldest request has waited this long.
    pub max_queue_delay: Duration,
    /// Admission cap: maximum admitted-but-unanswered requests; beyond it
    /// `submit` returns [`ServeError::Overloaded`].
    pub queue_capacity: usize,
    /// Worker threads executing batches.
    pub workers: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig::from_env()
    }
}

/// Parse-and-clamp an env override at the source (the `default_workers`
/// convention): unset or unparseable falls back to `default`.
fn env_clamped(name: &str, default: usize, lo: usize, hi: usize) -> usize {
    match std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) => n.clamp(lo, hi),
        None => default,
    }
}

impl ServeConfig {
    /// Defaults with `NODAL_SERVE_*` overrides (see module docs).
    pub fn from_env() -> Self {
        ServeConfig {
            max_batch_size: env_clamped("NODAL_SERVE_MAX_BATCH", 16, 1, 1024),
            max_queue_delay: Duration::from_micros(env_clamped(
                "NODAL_SERVE_MAX_DELAY_US",
                500,
                0,
                1_000_000,
            ) as u64),
            queue_capacity: env_clamped("NODAL_SERVE_QUEUE_CAP", 1024, 1, 1_000_000),
            // Same hard cap as the coordinator pool's NODAL_WORKERS clamp.
            workers: env_clamped("NODAL_SERVE_WORKERS", default_workers(), 1, 256),
        }
    }
}

/// Shared server state (registry, queues, clock, metrics, lifecycle flags).
pub(crate) struct Core {
    pub(crate) cfg: ServeConfig,
    pub(crate) clock: Arc<dyn Clock>,
    pub(crate) registry: HashMap<String, Arc<dyn OdeFunc + Send + Sync>>,
    pub(crate) metrics: ServeMetrics,
    pub(crate) submit_q: Channel<Pending>,
    pub(crate) work_q: Channel<FormedBatch>,
    /// Admitted-but-unanswered requests; the admission-control meter.
    inflight: Mutex<usize>,
    idle: Condvar,
    /// `drain()` callers currently waiting — the batcher flushes partial
    /// groups whenever this is non-zero.
    drain_waiters: AtomicUsize,
    closed: AtomicBool,
}

impl Core {
    /// Deliver a result and release the request's admission slot.
    pub(crate) fn complete(
        &self,
        slot: &ResponseSlot,
        result: Result<SolveResponse, ServeError>,
    ) {
        slot.fulfill(result);
        let mut n = self.inflight.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.idle.notify_all();
        }
    }
}

/// The dynamic micro-batching solve server. Construct via
/// [`SolveServer::builder`]; see the module docs for the architecture.
pub struct SolveServer {
    core: Arc<Core>,
    batcher: Mutex<Option<JoinHandle<()>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

/// Builder: register dynamics, then [`SolveServerBuilder::start`].
pub struct SolveServerBuilder {
    cfg: ServeConfig,
    clock: Option<Arc<dyn Clock>>,
    registry: HashMap<String, Arc<dyn OdeFunc + Send + Sync>>,
}

impl SolveServerBuilder {
    /// Register a dynamics under `id`; requests name it by this id.
    pub fn register<F>(mut self, id: &str, f: F) -> Self
    where
        F: OdeFunc + Send + Sync + 'static,
    {
        self.registry.insert(id.to_string(), Arc::new(f));
        self
    }

    pub fn config(mut self, cfg: ServeConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Inject a time source (tests pass a [`ManualClock`]).
    pub fn clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = Some(clock);
        self
    }

    /// Spawn the batcher thread and the worker shard and start serving.
    ///
    /// Hand-built configs are clamped the way [`ServeConfig::from_env`]
    /// clamps env overrides: `workers: 0` would deadlock every request (no
    /// one executes batches) and `queue_capacity: 0` would bounce every
    /// submission — the exact zero-pool footgun `default_workers` guards
    /// against.
    pub fn start(self) -> SolveServer {
        let cfg = ServeConfig {
            max_batch_size: self.cfg.max_batch_size.max(1),
            max_queue_delay: self.cfg.max_queue_delay,
            queue_capacity: self.cfg.queue_capacity.max(1),
            workers: self.cfg.workers.clamp(1, 256),
        };
        let clock = self.clock.unwrap_or_else(|| Arc::new(WallClock::default()));
        let core = Arc::new(Core {
            submit_q: Channel::bounded(cfg.queue_capacity),
            work_q: Channel::unbounded(),
            cfg,
            clock,
            registry: self.registry,
            metrics: ServeMetrics::default(),
            inflight: Mutex::new(0),
            idle: Condvar::new(),
            drain_waiters: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        });
        let batcher = {
            let core = core.clone();
            std::thread::spawn(move || batcher_loop(&core))
        };
        let workers = (0..core.cfg.workers)
            .map(|_| {
                let core = core.clone();
                std::thread::spawn(move || worker::worker_loop(&core))
            })
            .collect();
        SolveServer { core, batcher: Mutex::new(Some(batcher)), workers: Mutex::new(workers) }
    }
}

impl SolveServer {
    pub fn builder() -> SolveServerBuilder {
        SolveServerBuilder {
            cfg: ServeConfig::default(),
            clock: None,
            registry: HashMap::new(),
        }
    }

    /// Submit one request. Returns immediately with a handle, or with
    /// [`ServeError::Overloaded`] / [`ServeError::ShuttingDown`] /
    /// a validation error — admission happens before any queuing.
    pub fn submit(&self, req: SolveRequest) -> Result<ResponseHandle, ServeError> {
        if self.core.closed.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        self.validate(&req)?;
        {
            let mut n = self.core.inflight.lock().unwrap();
            if *n >= self.core.cfg.queue_capacity {
                self.core.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err(ServeError::Overloaded);
            }
            *n += 1;
        }
        let (handle, slot) = ResponseHandle::new();
        let pending = Pending { req, slot, submitted: self.core.clock.now() };
        match self.core.submit_q.push(pending) {
            // Count as submitted only once actually queued, so the
            // submitted == completed + failed + rejected ledger balances
            // even when a push loses the race against close().
            Ok(()) => {
                self.core.metrics.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(handle)
            }
            // Closed between the flag check and the push: release the
            // admission slot and report the shutdown.
            Err(p) => {
                self.core.complete(&p.slot, Err(ServeError::ShuttingDown));
                Err(ServeError::ShuttingDown)
            }
        }
    }

    fn validate(&self, req: &SolveRequest) -> Result<(), ServeError> {
        let f = self
            .core
            .registry
            .get(&req.dynamics)
            .ok_or_else(|| ServeError::UnknownDynamics(req.dynamics.clone()))?;
        let dim = f.dim();
        if req.z0.len() != dim {
            return Err(ServeError::BadRequest(format!(
                "z0 length {} != dynamics dim {dim}",
                req.z0.len()
            )));
        }
        if !req.z0.iter().all(|v| v.is_finite()) {
            return Err(ServeError::BadRequest("non-finite initial state".into()));
        }
        if let Some(lam) = &req.grad {
            if lam.len() != dim {
                return Err(ServeError::BadRequest(format!(
                    "grad cotangent length {} != dynamics dim {dim}",
                    lam.len()
                )));
            }
            if !lam.iter().all(|v| v.is_finite()) {
                return Err(ServeError::BadRequest("non-finite cotangent".into()));
            }
        }
        if !req.t0.is_finite() || !req.t1.is_finite() {
            return Err(ServeError::BadRequest("non-finite time span".into()));
        }
        // A zero-length span is an identity solve; letting it reach the
        // solver wastes a batch slot and (before per-span batching) used to
        // depend on engine edge-case behavior. Reject it at admission so the
        // caller hears about the no-op immediately.
        if req.t0 == req.t1 {
            return Err(ServeError::BadRequest(format!(
                "zero-length span: t0 == t1 == {}",
                req.t0
            )));
        }
        match req.tol {
            Tolerance::Adaptive { rtol, atol } => {
                if !req.tab.adaptive() {
                    return Err(ServeError::BadRequest(format!(
                        "tableau {} has no embedded error estimate; use Tolerance::Fixed",
                        req.tab.name
                    )));
                }
                if !(rtol > 0.0) || !(atol >= 0.0) {
                    return Err(ServeError::BadRequest(format!(
                        "bad tolerances rtol={rtol} atol={atol}"
                    )));
                }
            }
            Tolerance::Fixed { h } => {
                if !(h > 0.0) || !h.is_finite() {
                    return Err(ServeError::BadRequest(format!("bad fixed step h={h}")));
                }
            }
        }
        Ok(())
    }

    /// Flush all partial batches and block until every admitted request has
    /// been answered. Concurrent submitters can extend the wait.
    pub fn drain(&self) {
        self.core.drain_waiters.fetch_add(1, Ordering::SeqCst);
        self.core.submit_q.kick();
        let n = self.core.inflight.lock().unwrap();
        let _n = self.core.idle.wait_while(n, |n| *n > 0).unwrap();
        self.core.drain_waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Stop accepting work, drain everything in flight, and join all server
    /// threads. Idempotent; also invoked on drop.
    pub fn shutdown(&self) {
        self.core.closed.store(true, Ordering::SeqCst);
        self.core.submit_q.close();
        if let Some(h) = self.batcher.lock().unwrap().take() {
            let _ = h.join();
        }
        // The batcher has dispatched everything it will ever dispatch;
        // closing the work queue lets workers drain the remainder and exit.
        self.core.work_q.close();
        for h in self.workers.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }

    /// Point-in-time aggregate metrics.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.core.metrics.snapshot()
    }

    /// Admitted-but-unanswered requests right now.
    pub fn inflight(&self) -> usize {
        *self.core.inflight.lock().unwrap()
    }

    /// The server's configuration (after env clamping).
    pub fn config(&self) -> &ServeConfig {
        &self.core.cfg
    }
}

impl Drop for SolveServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The batch-former thread: pull submissions, coalesce, dispatch.
fn batcher_loop(core: &Core) {
    let mut former = BatchFormer::new(core.cfg.max_batch_size, core.cfg.max_queue_delay);
    let mut pulled: Vec<Pending> = Vec::new();
    loop {
        // Receive before flushing. While a drain() is waiting the receive is
        // non-blocking, so every request already in the submission queue
        // reaches the former before the drain flush — a drain that follows a
        // burst of submits coalesces the full burst instead of whatever
        // subset happened to be pulled already. Otherwise sleep until new
        // work arrives, a drain() kicks us, or the earliest group deadline
        // passes (with a ManualClock that wall wait is just an upper bound —
        // drain()'s kick is what actually wakes us; tests never sleep it
        // out).
        let draining = core.drain_waiters.load(Ordering::SeqCst) > 0;
        let timeout = if draining && !former.is_empty() {
            Some(Duration::ZERO)
        } else if draining {
            None // everything flushed; block until new work or shutdown
        } else {
            former
                .next_deadline()
                .map(|d| d.saturating_sub(core.clock.now()).max(Duration::from_micros(50)))
        };
        let state = core.submit_q.recv_all(timeout, &mut pulled);
        let now = core.clock.now();
        for p in pulled.drain(..) {
            former.push(p, now);
        }
        // Re-check the drain flag after the receive, and if it is set scoop
        // the queue once more without blocking: every submit that
        // happened-before the drain() call is already in the queue by the
        // time the flag reads true, so the drain flush below sees the whole
        // pre-drain burst — never a subset.
        let draining = draining || core.drain_waiters.load(Ordering::SeqCst) > 0;
        if draining {
            core.submit_q.recv_all(Some(Duration::ZERO), &mut pulled);
            for p in pulled.drain(..) {
                former.push(p, now);
            }
        }
        let flushed = if draining { former.drain(now) } else { former.poll(now) };
        for b in flushed {
            dispatch(core, b);
        }
        if state == ChannelState::Closed {
            for b in former.drain(core.clock.now()) {
                dispatch(core, b);
            }
            return;
        }
    }
}

fn dispatch(core: &Core, batch: FormedBatch) {
    if let Err(b) = core.work_q.push(batch) {
        // Unreachable in normal operation (the work queue is unbounded and
        // closes only after this thread exits); fail the batch cleanly
        // rather than dropping its requests.
        for item in &b.items {
            core.complete(&item.slot, Err(ServeError::ShuttingDown));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::VanDerPol;

    /// All `NODAL_SERVE_*` cases in ONE test: the process environment is
    /// shared across parallel test threads (same pattern as the pool's
    /// `NODAL_WORKERS` test).
    #[test]
    fn config_env_parse_and_clamp() {
        std::env::set_var("NODAL_SERVE_MAX_BATCH", "0");
        std::env::set_var("NODAL_SERVE_MAX_DELAY_US", "250");
        std::env::set_var("NODAL_SERVE_QUEUE_CAP", "9999999");
        std::env::set_var("NODAL_SERVE_WORKERS", "3");
        let cfg = ServeConfig::from_env();
        assert_eq!(cfg.max_batch_size, 1, "zero clamps to one");
        assert_eq!(cfg.max_queue_delay, Duration::from_micros(250));
        assert_eq!(cfg.queue_capacity, 1_000_000, "cap clamps high");
        assert_eq!(cfg.workers, 3);

        std::env::set_var("NODAL_SERVE_MAX_BATCH", "not-a-number");
        let cfg = ServeConfig::from_env();
        assert_eq!(cfg.max_batch_size, 16, "unparseable falls back to default");

        for k in [
            "NODAL_SERVE_MAX_BATCH",
            "NODAL_SERVE_MAX_DELAY_US",
            "NODAL_SERVE_QUEUE_CAP",
            "NODAL_SERVE_WORKERS",
        ] {
            std::env::remove_var(k);
        }
        let cfg = ServeConfig::from_env();
        assert_eq!(cfg.max_batch_size, 16);
        assert_eq!(cfg.max_queue_delay, Duration::from_micros(500));
        assert_eq!(cfg.queue_capacity, 1024);
        assert!(cfg.workers >= 1);
    }

    #[test]
    fn manual_clock_advances_only_on_demand() {
        let c = ManualClock::new();
        assert_eq!(c.now(), Duration::ZERO);
        c.advance(Duration::from_millis(5));
        c.advance(Duration::from_millis(5));
        assert_eq!(c.now(), Duration::from_millis(10));
        c.set(Duration::from_secs(1));
        assert_eq!(c.now(), Duration::from_secs(1));
    }

    #[test]
    fn submit_validation_errors() {
        let server = SolveServer::builder().register("vdp", VanDerPol::new(0.5)).start();
        let err = server
            .submit(SolveRequest::adaptive("nope", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8))
            .unwrap_err();
        assert!(matches!(err, ServeError::UnknownDynamics(_)), "{err}");

        let err = server
            .submit(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0], 1e-6, 1e-8))
            .unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "dim mismatch: {err}");

        let err = server
            .submit(SolveRequest::fixed("vdp", 0.0, 1.0, vec![1.0, 0.0], -0.1))
            .unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "negative h: {err}");

        let mut bad_tab = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8);
        bad_tab.tab = crate::ode::tableau::rk4();
        let err = server.submit(bad_tab).unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "fixed tab + tol: {err}");

        let err = server
            .submit(
                SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8)
                    .with_grad(vec![1.0]),
            )
            .unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "lam mismatch: {err}");

        server.shutdown();
        let err = server
            .submit(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8))
            .unwrap_err();
        assert_eq!(err, ServeError::ShuttingDown);
    }

    /// Admission bugfix: a zero-length span used to sail through validation
    /// (t0/t1 are finite) and reach the solver. It must bounce at submit.
    #[test]
    fn zero_span_rejected_at_admission() {
        let server = SolveServer::builder().register("vdp", VanDerPol::new(0.5)).start();
        for t in [0.0, 2.5, -1.0] {
            let err = server
                .submit(SolveRequest::adaptive("vdp", t, t, vec![1.0, 0.0], 1e-6, 1e-8))
                .unwrap_err();
            match err {
                ServeError::BadRequest(msg) => {
                    assert!(msg.contains("zero-length span"), "{msg}")
                }
                other => panic!("zero span must be BadRequest, got {other:?}"),
            }
        }
        // Nothing was admitted: the ledger is untouched and a real request
        // still goes through.
        assert_eq!(server.inflight(), 0);
        assert_eq!(server.metrics().submitted, 0);
        let h = server
            .submit(SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1))
            .unwrap();
        assert!(h.wait().is_ok());
    }

    #[test]
    fn start_clamps_degenerate_configs() {
        // workers: 0 would leave dispatched batches unexecuted forever and
        // queue_capacity: 0 would bounce every submission.
        let server = SolveServer::builder()
            .register("vdp", VanDerPol::new(0.5))
            .config(ServeConfig {
                max_batch_size: 0,
                max_queue_delay: Duration::ZERO,
                queue_capacity: 0,
                workers: 0,
            })
            .start();
        assert_eq!(server.config().workers, 1);
        assert_eq!(server.config().queue_capacity, 1);
        assert_eq!(server.config().max_batch_size, 1);
        let h = server
            .submit(SolveRequest::fixed("vdp", 0.0, 0.5, vec![1.0, 0.0], 0.1))
            .unwrap();
        assert!(h.wait().is_ok(), "clamped server must still serve");
    }

    #[test]
    fn smoke_submit_and_wait() {
        let server = SolveServer::builder().register("vdp", VanDerPol::new(0.5)).start();
        let h = server
            .submit(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![2.0, 0.0], 1e-6, 1e-8))
            .unwrap();
        let resp = h.wait().unwrap();
        assert_eq!(resp.z_t1.len(), 2);
        assert!(resp.stats.nfe > 0);
        assert!(resp.stats.batch_size >= 1);
        // `wait` can return between the slot fulfillment and the admission
        // release; drain() waits for the release before we assert on it.
        server.drain();
        let m = server.metrics();
        assert_eq!(m.submitted, 1);
        assert_eq!(m.completed, 1);
        assert_eq!(server.inflight(), 0);
    }
}
