//! Request/response vocabulary of the solve server.
//!
//! A [`SolveRequest`] names a registered dynamics, one initial state, a
//! t-span, a solver tableau, and a tolerance; optionally it carries a
//! terminal cotangent `dL/dz(T)` to request the batched ACA backward pass,
//! **or** a dense-output observation grid `observe_at` to request the
//! interpolated trajectory at client-chosen times. Requests that agree on
//! everything except the initial state **and the span `[t0, t1]`** (same
//! [`BatchKey`]) can share one [`crate::ode::integrate_batch_tspans`] call —
//! the engine's per-sample adaptive step control and fully per-sample spans
//! guarantee the co-batched results are the ones each request would have
//! gotten alone. The key pins only the integration direction (same-sign
//! spans, a scheduling-locality choice); where each sample *starts* and
//! *stops* is free per request.
//!
//! Construction goes through the typed builder ([`SolveRequest::builder`]):
//! validation — span, tolerances, state finiteness, grid finiteness — runs
//! in [`SolveRequestBuilder::build`], so a malformed request fails at
//! construction instead of deep inside a worker. The [`SolveRequest::adaptive`]
//! / [`SolveRequest::fixed`] constructors are thin wrappers over the builder.
//!
//! Wire codecs for these types live in [`super::wire`].

use crate::grad::GradResult;
use crate::obs::TraceCtx;
use crate::ode::integrate::IntegrateOpts;
use crate::ode::tableau::Tableau;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Step-size policy of one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tolerance {
    /// Adaptive stepping at `(rtol, atol)` (requires an adaptive tableau).
    Adaptive { rtol: f64, atol: f64 },
    /// Fixed step size `h > 0`.
    Fixed { h: f64 },
}

/// QoS priority lane of one request. Lanes are part of the [`BatchKey`]
/// (batches never mix lanes) and the batch former always emits every ready
/// interactive batch before any batch-lane one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Lane {
    /// Latency-sensitive traffic: emitted first.
    #[default]
    Interactive,
    /// Throughput traffic: emitted after the interactive lane.
    Batch,
}

impl Lane {
    /// Wire name of the lane (see [`super::wire`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            Lane::Interactive => "interactive",
            Lane::Batch => "batch",
        }
    }

    /// Inverse of [`Lane::as_str`].
    pub fn from_name(s: &str) -> Option<Lane> {
        match s {
            "interactive" => Some(Lane::Interactive),
            "batch" => Some(Lane::Batch),
            _ => None,
        }
    }
}

/// One solve submitted to the server: a single sample (`z0.len() == dim`).
#[derive(Debug, Clone)]
pub struct SolveRequest {
    /// Registry id of the dynamics to solve.
    pub dynamics: String,
    /// Integration span `[t0, t1]`.
    pub t0: f64,
    pub t1: f64,
    /// Initial state; length must equal the dynamics' `dim()`.
    pub z0: Vec<f32>,
    /// Solver tableau.
    pub tab: &'static Tableau,
    /// Step-size policy.
    pub tol: Tolerance,
    /// `Some(dL/dz(T))` requests the batched ACA backward pass; length must
    /// equal `dim()`.
    pub grad: Option<Vec<f32>>,
    /// Non-empty requests dense output: the worker evaluates the stored
    /// interpolant ([`crate::ode::DenseOutput`]) at each grid point,
    /// bit-equal to a direct solve. Points outside the span clamp to the
    /// nearest endpoint (the interpolant's own clamping rule). Mutually
    /// exclusive with `grad`.
    pub observe_at: Vec<f64>,
    /// QoS priority lane (see [`Lane`]).
    pub lane: Lane,
    /// Observability context ([`crate::obs`]): when set, every layer the
    /// request crosses emits spans into this trace. **Never** part of the
    /// [`BatchKey`] — traced and untraced requests coalesce freely, which
    /// is what keeps tracing answer-neutral.
    pub trace: Option<TraceCtx>,
}

/// Typed builder for [`SolveRequest`]; all validation happens in
/// [`SolveRequestBuilder::build`].
///
/// ```
/// use nodal::serve::{Lane, SolveRequest};
/// let req = SolveRequest::builder("vdp")
///     .span(0.0, 5.0)
///     .state(vec![2.0, 0.0])
///     .adaptive(1e-6, 1e-8)
///     .observe_at(vec![1.0, 2.5, 4.0])
///     .priority(Lane::Interactive)
///     .build()
///     .unwrap();
/// assert_eq!(req.observe_at.len(), 3);
/// ```
#[derive(Debug, Clone)]
pub struct SolveRequestBuilder {
    dynamics: String,
    t0: f64,
    t1: f64,
    z0: Vec<f32>,
    tab: Option<&'static Tableau>,
    tol: Option<Tolerance>,
    grad: Option<Vec<f32>>,
    observe_at: Vec<f64>,
    lane: Lane,
    trace: Option<TraceCtx>,
}

impl SolveRequestBuilder {
    /// Integration span `[t0, t1]` (backward spans `t1 < t0` are legal).
    pub fn span(mut self, t0: f64, t1: f64) -> Self {
        self.t0 = t0;
        self.t1 = t1;
        self
    }

    /// Initial state `z(t0)`.
    pub fn state(mut self, z0: Vec<f32>) -> Self {
        self.z0 = z0;
        self
    }

    /// Adaptive stepping at `(rtol, atol)`; the tableau defaults to dopri5
    /// unless [`SolveRequestBuilder::tableau`] overrides it.
    pub fn adaptive(mut self, rtol: f64, atol: f64) -> Self {
        self.tol = Some(Tolerance::Adaptive { rtol, atol });
        self
    }

    /// Fixed stepping at `h`; the tableau defaults to rk4 unless
    /// [`SolveRequestBuilder::tableau`] overrides it.
    pub fn fixed(mut self, h: f64) -> Self {
        self.tol = Some(Tolerance::Fixed { h });
        self
    }

    /// Override the solver tableau (adaptive tolerances require a tableau
    /// with an embedded error estimate — checked in `build`).
    pub fn tableau(mut self, tab: &'static Tableau) -> Self {
        self.tab = Some(tab);
        self
    }

    /// Attach a terminal cotangent `dL/dz(T)`, requesting the batched ACA
    /// backward pass. Mutually exclusive with `observe_at`.
    pub fn grad(mut self, lam_t1: Vec<f32>) -> Self {
        self.grad = Some(lam_t1);
        self
    }

    /// Request dense output at these times (see
    /// [`SolveRequest::observe_at`]).
    pub fn observe_at(mut self, ts: Vec<f64>) -> Self {
        self.observe_at = ts;
        self
    }

    /// QoS priority lane (defaults to [`Lane::Interactive`]).
    pub fn priority(mut self, lane: Lane) -> Self {
        self.lane = lane;
        self
    }

    /// Attach an observability trace context (see [`crate::obs`]): spans
    /// for this request's queue wait, batch formation, and solve phases
    /// join `ctx.trace`, parented under `ctx.parent`.
    pub fn trace(mut self, ctx: TraceCtx) -> Self {
        self.trace = Some(ctx);
        self
    }

    /// Validate and construct the request. Every shape error — missing or
    /// non-positive step policy, non-finite or zero-length span, non-finite
    /// state / cotangent / grid, adaptive tolerances on a fixed-step-only
    /// tableau, grad+observe combination — is rejected **here**, not at
    /// admission and not deep inside a worker.
    pub fn build(self) -> Result<SolveRequest, ServeError> {
        let tol = self.tol.ok_or_else(|| {
            ServeError::BadRequest(
                "no step-size policy: call .adaptive(rtol, atol) or .fixed(h)".into(),
            )
        })?;
        let tab = self.tab.unwrap_or_else(|| match tol {
            Tolerance::Adaptive { .. } => crate::ode::tableau::dopri5(),
            Tolerance::Fixed { .. } => crate::ode::tableau::rk4(),
        });
        let req = SolveRequest {
            dynamics: self.dynamics,
            t0: self.t0,
            t1: self.t1,
            z0: self.z0,
            tab,
            tol,
            grad: self.grad,
            observe_at: self.observe_at,
            lane: self.lane,
            trace: self.trace,
        };
        req.validate_shape()?;
        Ok(req)
    }
}

impl SolveRequest {
    /// Start building a request for the dynamics registered under
    /// `dynamics` (see [`SolveRequestBuilder`]).
    pub fn builder(dynamics: &str) -> SolveRequestBuilder {
        SolveRequestBuilder {
            dynamics: dynamics.to_string(),
            t0: 0.0,
            t1: 0.0,
            z0: Vec::new(),
            tab: None,
            tol: None,
            grad: None,
            observe_at: Vec::new(),
            lane: Lane::Interactive,
            trace: None,
        }
    }

    /// Forward-only request with adaptive tolerances and dopri5 — a thin
    /// wrapper over [`SolveRequest::builder`]; fails like
    /// [`SolveRequestBuilder::build`] does (bad tolerances, bad span, …).
    pub fn adaptive(
        dynamics: &str,
        t0: f64,
        t1: f64,
        z0: Vec<f32>,
        rtol: f64,
        atol: f64,
    ) -> Result<SolveRequest, ServeError> {
        SolveRequest::builder(dynamics).span(t0, t1).state(z0).adaptive(rtol, atol).build()
    }

    /// Forward-only fixed-step request — a thin wrapper over
    /// [`SolveRequest::builder`]; fails like [`SolveRequestBuilder::build`]
    /// does (non-finite or non-positive `h`, bad span, …).
    pub fn fixed(
        dynamics: &str,
        t0: f64,
        t1: f64,
        z0: Vec<f32>,
        h: f64,
    ) -> Result<SolveRequest, ServeError> {
        SolveRequest::builder(dynamics).span(t0, t1).state(z0).fixed(h).build()
    }

    /// Attach a terminal cotangent, turning this into a gradient request.
    /// (Post-build mutation: the server re-validates shape at admission, so
    /// a mismatched cotangent still bounces before any queuing.)
    pub fn with_grad(mut self, lam_t1: Vec<f32>) -> Self {
        self.grad = Some(lam_t1);
        self
    }

    /// Shape validation shared by [`SolveRequestBuilder::build`] and the
    /// server's admission check (requests are plain-old-data, so admission
    /// re-validates against hand-rolled struct literals). Everything here is
    /// registry-independent; the server additionally checks the dynamics
    /// exists and `z0.len() == dim()`.
    pub(crate) fn validate_shape(&self) -> Result<(), ServeError> {
        if !self.t0.is_finite() || !self.t1.is_finite() {
            return Err(ServeError::BadRequest("non-finite time span".into()));
        }
        // A zero-length span is an identity solve; letting it reach the
        // solver wastes a batch slot and (before per-span batching) used to
        // depend on engine edge-case behavior. Reject it at construction so
        // the caller hears about the no-op immediately.
        if self.t0 == self.t1 {
            return Err(ServeError::BadRequest(format!(
                "zero-length span: t0 == t1 == {}",
                self.t0
            )));
        }
        if self.z0.is_empty() {
            return Err(ServeError::BadRequest("empty initial state".into()));
        }
        if !self.z0.iter().all(|v| v.is_finite()) {
            return Err(ServeError::BadRequest("non-finite initial state".into()));
        }
        match self.tol {
            Tolerance::Adaptive { rtol, atol } => {
                if !self.tab.adaptive() {
                    return Err(ServeError::BadRequest(format!(
                        "tableau {} has no embedded error estimate; use Tolerance::Fixed",
                        self.tab.name
                    )));
                }
                // `!(x > 0.0)` is NaN-safe: NaN fails every comparison.
                if !(rtol > 0.0) || !rtol.is_finite() || !(atol >= 0.0) || !atol.is_finite() {
                    return Err(ServeError::BadRequest(format!(
                        "bad tolerances rtol={rtol} atol={atol}"
                    )));
                }
            }
            Tolerance::Fixed { h } => {
                if !(h > 0.0) || !h.is_finite() {
                    return Err(ServeError::BadRequest(format!("bad fixed step h={h}")));
                }
            }
        }
        if let Some(lam) = &self.grad {
            if lam.len() != self.z0.len() {
                return Err(ServeError::BadRequest(format!(
                    "grad cotangent length {} != state length {}",
                    lam.len(),
                    self.z0.len()
                )));
            }
            if !lam.iter().all(|v| v.is_finite()) {
                return Err(ServeError::BadRequest("non-finite cotangent".into()));
            }
        }
        if !self.observe_at.iter().all(|t| t.is_finite()) {
            return Err(ServeError::BadRequest("non-finite observation time".into()));
        }
        if self.grad.is_some() && !self.observe_at.is_empty() {
            return Err(ServeError::BadRequest(
                "gradient and dense-output observation are mutually exclusive".into(),
            ));
        }
        Ok(())
    }

    /// The solver options this request maps to.
    pub fn opts(&self) -> IntegrateOpts {
        match self.tol {
            Tolerance::Adaptive { rtol, atol } => IntegrateOpts::with_tol(rtol, atol),
            Tolerance::Fixed { h } => IntegrateOpts::fixed(h),
        }
    }

    /// Coalescing key: requests with equal keys run in one batched solve.
    /// Neither `t0` nor `t1` is part of the key — the batched engine
    /// integrates each sample over its own `[t0, t1]`
    /// ([`crate::ode::integrate_batch_tspans`]), so mixed-span requests
    /// coalesce freely (the direction still is: a forward and a backward
    /// solve never share a batch).
    pub fn batch_key(&self) -> BatchKey {
        let (tol_kind, tol_a, tol_b) = match self.tol {
            Tolerance::Adaptive { rtol, atol } => (0u8, rtol.to_bits(), atol.to_bits()),
            Tolerance::Fixed { h } => (1u8, h.to_bits(), 0),
        };
        BatchKey {
            dynamics: self.dynamics.clone(),
            tab: self.tab.name,
            dir: if self.t1 >= self.t0 { 1 } else { -1 },
            tol_kind,
            tol_a,
            tol_b,
            wants_grad: self.grad.is_some(),
            wants_obs: !self.observe_at.is_empty(),
            lane: self.lane,
        }
    }

    /// Upper bound on the checkpoint bytes this request can pin in a
    /// worker, matching [`Trajectory::checkpoint_bytes`]'s accounting: the
    /// state part (one f32 state per accepted step, capped by the
    /// per-sample checkpoint budget when one is configured) **plus** the
    /// trajectory spine (`ts`/`hs`/`errs` f64s — kept dense under every
    /// policy, so it is never capped), **plus** the observation buffer for
    /// dense-output requests (one f32 state and one f64 time per grid
    /// point). The admission controller sums this over admitted-unanswered
    /// requests.
    ///
    /// The step bound is exact for fixed-step requests (`⌈span/h⌉`, plus
    /// one for the clamped final step) and `max_steps` for adaptive ones.
    /// Gradient requests are **not** budget-capped: their backward pass
    /// additionally buffers one replay segment (up to the thinned-away
    /// states of a segment), so the dense bound is the honest charge.
    /// Dense-output requests are not capped either: interpolation needs
    /// every knot, so the worker runs them under dense storage regardless
    /// of the per-sample budget.
    ///
    /// [`Trajectory::checkpoint_bytes`]: crate::ode::Trajectory::checkpoint_bytes
    pub fn projected_ckpt_bytes(&self, dim: usize, ckpt_budget_bytes: usize) -> usize {
        let max_steps = self.opts().max_steps;
        let steps = match self.tol {
            // Float→int casts saturate, so a degenerate span/h stays sane.
            Tolerance::Fixed { h } => (((self.t1 - self.t0).abs() / h).ceil() as usize)
                .saturating_add(1)
                .min(max_steps),
            Tolerance::Adaptive { .. } => max_steps,
        };
        let states = steps
            .saturating_add(1)
            .saturating_mul(dim)
            .saturating_mul(std::mem::size_of::<f32>());
        let states =
            if ckpt_budget_bytes > 0 && self.grad.is_none() && self.observe_at.is_empty() {
                // A Budgeted store never holds fewer than 2 anchors (the
                // initial state and the tail), so the effective cap has that
                // floor — charging below it would under-count what the worker
                // actually pins.
                states.min(ckpt_budget_bytes.max(2 * dim * std::mem::size_of::<f32>()))
            } else {
                states
            };
        // Spine: (steps + 1) ts + steps hs + steps errs, all f64 (serve
        // requests never record trials).
        let spine =
            steps.saturating_mul(3).saturating_add(1).saturating_mul(std::mem::size_of::<f64>());
        // Observation buffer: one interpolated f32 state plus the f64 grid
        // point per observation time.
        let obs = self.observe_at.len().saturating_mul(
            dim.saturating_mul(std::mem::size_of::<f32>())
                .saturating_add(std::mem::size_of::<f64>()),
        );
        states.saturating_add(spine).saturating_add(obs)
    }
}

/// What makes two requests co-batchable: same dynamics, solver, integration
/// direction and tolerance bits, the same gradient flag (a batch either
/// runs the backward pass for all its samples or for none), the same
/// dense-output flag (observation batches run under dense checkpoint
/// storage), and the same QoS lane. The span is free per request: the
/// engine integrates each co-batched sample over its own `[t0, t1]`
/// ([`crate::ode::integrate_batch_tspans`]), entering the shared stage
/// sweeps at its own start and retiring at its own endpoint.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub dynamics: String,
    pub tab: &'static str,
    /// Sign of `t1 - t0`: kept in the key so forward and backward solves
    /// group separately even though the span itself is not keyed.
    pub dir: i8,
    pub tol_kind: u8,
    pub tol_a: u64,
    pub tol_b: u64,
    pub wants_grad: bool,
    /// True for dense-output batches — they force dense checkpoint storage,
    /// so they never mix with budget-thinned forward traffic.
    pub wants_obs: bool,
    /// QoS lane; batches never mix lanes.
    pub lane: Lane,
}

/// Per-request timing and solver-cost report.
#[derive(Debug, Clone, Default)]
pub struct RequestStats {
    /// Accepted steps `N_t`.
    pub steps: usize,
    /// `f` evaluations spent on this sample's forward pass.
    pub nfe: usize,
    /// Rejected step attempts.
    pub n_rejected: usize,
    /// Average inner iterations `m` per accepted step.
    pub avg_m: f64,
    /// Bytes the sample's checkpoints held during service.
    pub checkpoint_bytes: usize,
    /// Number of co-batched samples this request was served with.
    pub batch_size: usize,
    /// Time spent queued before its batch started executing.
    pub queue_wait: Duration,
    /// Time from batch start to response (shared by the whole batch).
    pub service: Duration,
}

/// What one answered request carries: exactly one of the three request
/// classes, with no `Option` stacking — a forward solve is not "a gradient
/// response with `None` gradients".
#[derive(Debug, Clone)]
pub enum Payload {
    /// Forward-only solve: the final state.
    Forward { z_t1: Vec<f32> },
    /// Gradient solve: the final state plus the ACA backward result.
    Gradient { z_t1: Vec<f32>, grad: GradResult },
    /// Dense-output solve: the final state plus the interpolant evaluated
    /// at each requested `observe_at` point, in request order.
    Observed { z_t1: Vec<f32>, zs: Vec<Vec<f32>> },
}

/// The server's answer to one [`SolveRequest`].
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// The class-specific payload (see [`Payload`]).
    pub payload: Payload,
    /// Timing and solver-cost bookkeeping.
    pub stats: RequestStats,
}

impl SolveResponse {
    /// Final state `z(t1)` — present in every payload class.
    pub fn z_t1(&self) -> &[f32] {
        match &self.payload {
            Payload::Forward { z_t1 }
            | Payload::Gradient { z_t1, .. }
            | Payload::Observed { z_t1, .. } => z_t1,
        }
    }

    /// The ACA backward result, iff this answered a gradient request.
    pub fn grad(&self) -> Option<&GradResult> {
        match &self.payload {
            Payload::Gradient { grad, .. } => Some(grad),
            _ => None,
        }
    }

    /// The interpolated states (one per `observe_at` point, in request
    /// order), iff this answered a dense-output request.
    pub fn observations(&self) -> Option<&[Vec<f32>]> {
        match &self.payload {
            Payload::Observed { zs, .. } => Some(zs),
            _ => None,
        }
    }
}

/// Why the server refused or failed a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control: the submission queue is at capacity. Retry later.
    Overloaded,
    /// The server is shutting down and no longer accepts work.
    ShuttingDown,
    /// The request names a dynamics id that was never registered.
    UnknownDynamics(String),
    /// The request is malformed (wrong state length, bad span, bad step…).
    BadRequest(String),
    /// The solver failed (stiffness blow-up, step underflow, …).
    Solver(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "server overloaded: submission queue full"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::UnknownDynamics(id) => write!(f, "unknown dynamics id '{id}'"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::Solver(msg) => write!(f, "solver error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One-shot completion slot shared between a request's handle and the worker
/// that eventually serves it.
#[derive(Debug, Default)]
pub struct ResponseSlot {
    value: Mutex<Option<Result<SolveResponse, ServeError>>>,
    ready: Condvar,
    /// Sticky: set on first delivery and never cleared, even after the
    /// caller takes the value — lets panic cleanup tell "never delivered"
    /// apart from "delivered and already consumed".
    fulfilled: std::sync::atomic::AtomicBool,
}

impl ResponseSlot {
    /// Deliver the result; wakes any waiter. Later calls are ignored (the
    /// first delivery wins, including when the caller already consumed it).
    pub fn fulfill(&self, result: Result<SolveResponse, ServeError>) {
        let mut v = self.value.lock().unwrap();
        if !self.fulfilled.swap(true, std::sync::atomic::Ordering::SeqCst) {
            *v = Some(result);
            self.ready.notify_all();
        }
    }

    /// True once a result has ever been delivered.
    pub fn is_fulfilled(&self) -> bool {
        self.fulfilled.load(std::sync::atomic::Ordering::SeqCst)
    }

    fn wait_take(&self) -> Result<SolveResponse, ServeError> {
        let mut v = self.value.lock().unwrap();
        loop {
            if let Some(r) = v.take() {
                return r;
            }
            v = self.ready.wait(v).unwrap();
        }
    }

    fn try_take(&self) -> Option<Result<SolveResponse, ServeError>> {
        self.value.lock().unwrap().take()
    }
}

/// The caller's side of a submitted request (one-shot: `wait` consumes it).
#[derive(Debug)]
pub struct ResponseHandle {
    pub(crate) slot: Arc<ResponseSlot>,
}

impl ResponseHandle {
    pub(crate) fn new() -> (Self, Arc<ResponseSlot>) {
        let slot = Arc::new(ResponseSlot::default());
        (ResponseHandle { slot: slot.clone() }, slot)
    }

    /// Block until the response is delivered and take it.
    pub fn wait(self) -> Result<SolveResponse, ServeError> {
        self.slot.wait_take()
    }

    /// Take the response if it has already been delivered.
    pub fn try_take(&self) -> Option<Result<SolveResponse, ServeError>> {
        self.slot.try_take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> SolveRequest {
        SolveRequest::adaptive("vdp", 0.0, 5.0, vec![2.0, 0.0], 1e-6, 1e-8).unwrap()
    }

    #[test]
    fn same_parameters_same_key() {
        let a = req();
        let mut b = req();
        b.z0 = vec![-1.0, 0.5]; // the state may differ inside a batch
        assert_eq!(a.batch_key(), b.batch_key());
    }

    /// The span is the other free axis: requests that differ only in `t0`
    /// and/or `t1` (same direction) coalesce — the engine integrates each
    /// sample over its own span.
    #[test]
    fn mixed_spans_share_a_key() {
        let a = req();
        let mut b = req();
        b.t1 = 6.0;
        b.z0 = vec![-1.0, 0.5];
        assert_eq!(a.batch_key(), b.batch_key(), "t1 must not split batches");
        let mut c = req();
        c.t0 = 1.0;
        c.t1 = 3.5;
        assert_eq!(a.batch_key(), c.batch_key(), "t0 must not split batches");
    }

    #[test]
    fn key_separates_incompatible_requests() {
        let base = req();
        let mut other = req();
        other.t1 = -5.0; // backward span from the same t0
        assert_ne!(base.batch_key(), other.batch_key(), "direction");
        let mut other = req();
        other.tol = Tolerance::Adaptive { rtol: 1e-5, atol: 1e-8 };
        assert_ne!(base.batch_key(), other.batch_key(), "tolerance");
        let mut other = req();
        other.tab = crate::ode::tableau::rk23();
        assert_ne!(base.batch_key(), other.batch_key(), "tableau");
        let other = req().with_grad(vec![1.0, 0.0]);
        assert_ne!(base.batch_key(), other.batch_key(), "grad flag");
        let mut other = req();
        other.dynamics = "linear".into();
        assert_ne!(base.batch_key(), other.batch_key(), "dynamics");
        let mut other = req();
        other.observe_at = vec![1.0, 2.0];
        assert_ne!(base.batch_key(), other.batch_key(), "dense-output flag");
        let mut other = req();
        other.lane = Lane::Batch;
        assert_ne!(base.batch_key(), other.batch_key(), "lane");
    }

    /// Projected checkpoint footprint: per-step state bytes (capped by the
    /// per-sample checkpoint budget for forward-only requests) plus the
    /// dense spine — the spine is never thinned, so the cap must not erase
    /// it; fixed-step requests project their exact step count instead of
    /// the `max_steps` upper bound; gradient and dense-output requests stay
    /// uncapped (the replay cache / interpolant needs the dense footprint).
    #[test]
    fn projected_bytes_upper_bound_and_budget_cap() {
        let r = req(); // adaptive → default max_steps = 100_000 bound
        let spine = (3 * 100_000 + 1) * 8;
        assert_eq!(r.projected_ckpt_bytes(2, 0), 100_001 * 2 * 4 + spine);
        assert_eq!(
            r.projected_ckpt_bytes(2, 4096),
            4096 + spine,
            "budget caps the state part only — the spine stays dense"
        );
        assert_eq!(r.projected_ckpt_bytes(2, usize::MAX), 100_001 * 2 * 4 + spine);

        // Gradient request: the cap does not apply.
        let g = req().with_grad(vec![1.0, 0.0]);
        assert_eq!(g.projected_ckpt_bytes(2, 4096), 100_001 * 2 * 4 + spine);

        // Fixed step over [0, 5] with h = 0.5: exactly 10 steps (+1 for the
        // final-step clamp margin) instead of the max_steps bound.
        let f = SolveRequest::fixed("vdp", 0.0, 5.0, vec![2.0, 0.0], 0.5).unwrap();
        assert_eq!(f.projected_ckpt_bytes(2, 0), 12 * 2 * 4 + (3 * 11 + 1) * 8);

        // Dense-output request: the observation buffer is charged on top
        // (one f32 state + one f64 time per grid point), and the per-sample
        // budget no longer caps the state part — interpolation pins every
        // knot.
        let mut o = req();
        o.observe_at = vec![1.0, 2.0, 3.0];
        let obs = 3 * (2 * 4 + 8);
        assert_eq!(o.projected_ckpt_bytes(2, 0), 100_001 * 2 * 4 + spine + obs);
        assert_eq!(
            o.projected_ckpt_bytes(2, 4096),
            100_001 * 2 * 4 + spine + obs,
            "dense-output requests run dense: the ckpt budget must not cap the charge"
        );
    }

    #[test]
    fn fixed_vs_adaptive_keys_differ() {
        let a = SolveRequest::fixed("vdp", 0.0, 5.0, vec![2.0, 0.0], 0.01).unwrap();
        let mut b = req();
        b.tab = a.tab;
        assert_ne!(a.batch_key(), b.batch_key());
    }

    #[test]
    fn builder_matches_thin_wrappers() {
        let a = SolveRequest::builder("vdp")
            .span(0.0, 5.0)
            .state(vec![2.0, 0.0])
            .adaptive(1e-6, 1e-8)
            .build()
            .unwrap();
        let b = req();
        assert_eq!(a.batch_key(), b.batch_key());
        assert_eq!(a.z0, b.z0);
        assert_eq!(a.lane, Lane::Interactive, "default lane is interactive");
        assert!(a.observe_at.is_empty());

        let f = SolveRequest::builder("vdp")
            .span(0.0, 5.0)
            .state(vec![2.0, 0.0])
            .fixed(0.5)
            .build()
            .unwrap();
        assert_eq!(f.tab.name, "rk4", "fixed defaults to rk4");
        assert_eq!(f.tol, Tolerance::Fixed { h: 0.5 });

        let o = SolveRequest::builder("vdp")
            .span(0.0, 5.0)
            .state(vec![2.0, 0.0])
            .adaptive(1e-6, 1e-8)
            .observe_at(vec![1.0, 2.5])
            .priority(Lane::Batch)
            .build()
            .unwrap();
        assert_eq!(o.lane, Lane::Batch);
        assert!(o.batch_key().wants_obs);
    }

    /// Satellite bugfix: the old ctors silently accepted non-finite / zero
    /// `h` / `rtol` / `atol` and deferred the failure deep into the worker.
    /// One case per bad-input class, all rejected at `build()`.
    #[test]
    fn build_rejects_bad_step_policy() {
        let base = || SolveRequest::builder("vdp").span(0.0, 1.0).state(vec![1.0, 0.0]);
        for h in [0.0, -0.1, f64::NAN, f64::INFINITY] {
            let err = base().fixed(h).build().unwrap_err();
            assert!(matches!(err, ServeError::BadRequest(_)), "h={h}: {err}");
        }
        for (rtol, atol) in [
            (0.0, 1e-8),
            (-1e-6, 1e-8),
            (f64::NAN, 1e-8),
            (f64::INFINITY, 1e-8),
            (1e-6, -1e-8),
            (1e-6, f64::NAN),
            (1e-6, f64::INFINITY),
        ] {
            let err = base().adaptive(rtol, atol).build().unwrap_err();
            assert!(
                matches!(err, ServeError::BadRequest(_)),
                "rtol={rtol} atol={atol}: {err}"
            );
        }
        // The thin wrappers reject the same inputs (they delegate to build).
        assert!(SolveRequest::fixed("vdp", 0.0, 1.0, vec![1.0, 0.0], f64::NAN).is_err());
        assert!(SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 0.0, 1e-8).is_err());
        // No step policy at all.
        let err = base().build().unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "{err}");
        // Adaptive tolerances on a fixed-step-only tableau.
        let err = base()
            .adaptive(1e-6, 1e-8)
            .tableau(crate::ode::tableau::rk4())
            .build()
            .unwrap_err();
        assert!(matches!(err, ServeError::BadRequest(_)), "{err}");
    }

    #[test]
    fn build_rejects_bad_span_and_state() {
        let mk = |t0: f64, t1: f64, z0: Vec<f32>| {
            SolveRequest::builder("vdp").span(t0, t1).state(z0).adaptive(1e-6, 1e-8).build()
        };
        let err = mk(2.5, 2.5, vec![1.0, 0.0]).unwrap_err();
        match err {
            ServeError::BadRequest(msg) => assert!(msg.contains("zero-length span"), "{msg}"),
            other => panic!("zero span must be BadRequest, got {other:?}"),
        }
        assert!(mk(f64::NAN, 1.0, vec![1.0, 0.0]).is_err(), "NaN t0");
        assert!(mk(0.0, f64::INFINITY, vec![1.0, 0.0]).is_err(), "infinite t1");
        assert!(mk(0.0, 1.0, vec![]).is_err(), "empty state");
        assert!(mk(0.0, 1.0, vec![1.0, f32::NAN]).is_err(), "non-finite state");
    }

    #[test]
    fn build_rejects_bad_grad_and_grid() {
        let base = || {
            SolveRequest::builder("vdp").span(0.0, 1.0).state(vec![1.0, 0.0]).adaptive(1e-6, 1e-8)
        };
        assert!(base().grad(vec![1.0]).build().is_err(), "cotangent length mismatch");
        assert!(base().grad(vec![1.0, f32::NAN]).build().is_err(), "non-finite cotangent");
        assert!(base().observe_at(vec![0.5, f64::NAN]).build().is_err(), "non-finite grid");
        assert!(
            base().grad(vec![1.0, 0.0]).observe_at(vec![0.5]).build().is_err(),
            "grad + observe are mutually exclusive"
        );
        assert!(base().observe_at(vec![0.25, 0.75]).build().is_ok());
    }

    #[test]
    fn response_accessors_match_payload_class() {
        let fwd = SolveResponse {
            payload: Payload::Forward { z_t1: vec![1.0, 2.0] },
            stats: RequestStats::default(),
        };
        assert_eq!(fwd.z_t1(), &[1.0, 2.0]);
        assert!(fwd.grad().is_none());
        assert!(fwd.observations().is_none());

        let obs = SolveResponse {
            payload: Payload::Observed { z_t1: vec![3.0], zs: vec![vec![1.0], vec![2.0]] },
            stats: RequestStats::default(),
        };
        assert_eq!(obs.z_t1(), &[3.0]);
        assert_eq!(obs.observations().map(<[Vec<f32>]>::len), Some(2));

        let grad = SolveResponse {
            payload: Payload::Gradient {
                z_t1: vec![4.0],
                grad: GradResult {
                    dl_dz0: vec![0.5],
                    dl_dtheta: vec![],
                    meter: Default::default(),
                },
            },
            stats: RequestStats::default(),
        };
        assert_eq!(grad.z_t1(), &[4.0]);
        assert_eq!(grad.grad().map(|g| g.dl_dz0.clone()), Some(vec![0.5]));
    }

    #[test]
    fn lane_names_round_trip() {
        for lane in [Lane::Interactive, Lane::Batch] {
            assert_eq!(Lane::from_name(lane.as_str()), Some(lane));
        }
        assert_eq!(Lane::from_name("express"), None);
    }

    #[test]
    fn response_slot_one_shot() {
        let (handle, slot) = ResponseHandle::new();
        assert!(handle.try_take().is_none());
        assert!(!slot.is_fulfilled());
        slot.fulfill(Err(ServeError::Overloaded));
        slot.fulfill(Err(ServeError::ShuttingDown)); // ignored: first wins
        assert!(slot.is_fulfilled());
        assert_eq!(handle.try_take().unwrap().unwrap_err(), ServeError::Overloaded);
        // A late delivery after the caller consumed the value must not
        // resurrect the slot (fulfilled is sticky).
        slot.fulfill(Err(ServeError::ShuttingDown));
        assert!(handle.try_take().is_none());
        assert!(slot.is_fulfilled());
    }

    #[test]
    fn response_slot_wakes_waiter() {
        let (handle, slot) = ResponseHandle::new();
        let t = std::thread::spawn(move || handle.wait());
        slot.fulfill(Err(ServeError::Overloaded));
        assert_eq!(t.join().unwrap().unwrap_err(), ServeError::Overloaded);
    }

    #[test]
    fn opts_round_trip() {
        let o = req().opts();
        assert_eq!(o.rtol, 1e-6);
        assert_eq!(o.atol, 1e-8);
        assert!(o.fixed_h.is_none());
        let o = SolveRequest::fixed("vdp", 0.0, 1.0, vec![0.0, 0.0], 0.05).unwrap().opts();
        assert_eq!(o.fixed_h, Some(0.05));
    }
}
