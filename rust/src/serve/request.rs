//! Request/response vocabulary of the solve server.
//!
//! A [`SolveRequest`] names a registered dynamics, one initial state, a
//! t-span, a solver tableau, and a tolerance; optionally it carries a
//! terminal cotangent `dL/dz(T)` to request the batched ACA backward pass.
//! Requests that agree on everything except the initial state **and the
//! span `[t0, t1]`** (same [`BatchKey`]) can share one
//! [`crate::ode::integrate_batch_tspans`] call — the engine's per-sample
//! adaptive step control and fully per-sample spans guarantee the
//! co-batched results are the ones each request would have gotten alone.
//! The key pins only the integration direction (same-sign spans, a
//! scheduling-locality choice); where each sample *starts* and *stops* is
//! free per request.

use crate::grad::GradResult;
use crate::ode::integrate::IntegrateOpts;
use crate::ode::tableau::Tableau;
use crate::util::json::{f32_bits, f32s_from_bits, obj, Json};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Step-size policy of one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tolerance {
    /// Adaptive stepping at `(rtol, atol)` (requires an adaptive tableau).
    Adaptive { rtol: f64, atol: f64 },
    /// Fixed step size `h > 0`.
    Fixed { h: f64 },
}

/// One solve submitted to the server: a single sample (`z0.len() == dim`).
#[derive(Debug, Clone)]
pub struct SolveRequest {
    /// Registry id of the dynamics to solve.
    pub dynamics: String,
    /// Integration span `[t0, t1]`.
    pub t0: f64,
    pub t1: f64,
    /// Initial state; length must equal the dynamics' `dim()`.
    pub z0: Vec<f32>,
    /// Solver tableau.
    pub tab: &'static Tableau,
    /// Step-size policy.
    pub tol: Tolerance,
    /// `Some(dL/dz(T))` requests the batched ACA backward pass; length must
    /// equal `dim()`.
    pub grad: Option<Vec<f32>>,
}

impl SolveRequest {
    /// Forward-only request with adaptive tolerances and dopri5.
    pub fn adaptive(dynamics: &str, t0: f64, t1: f64, z0: Vec<f32>, rtol: f64, atol: f64) -> Self {
        SolveRequest {
            dynamics: dynamics.to_string(),
            t0,
            t1,
            z0,
            tab: crate::ode::tableau::dopri5(),
            tol: Tolerance::Adaptive { rtol, atol },
            grad: None,
        }
    }

    /// Forward-only fixed-step request.
    pub fn fixed(dynamics: &str, t0: f64, t1: f64, z0: Vec<f32>, h: f64) -> Self {
        SolveRequest {
            dynamics: dynamics.to_string(),
            t0,
            t1,
            z0,
            tab: crate::ode::tableau::rk4(),
            tol: Tolerance::Fixed { h },
            grad: None,
        }
    }

    /// Attach a terminal cotangent, turning this into a gradient request.
    pub fn with_grad(mut self, lam_t1: Vec<f32>) -> Self {
        self.grad = Some(lam_t1);
        self
    }

    /// The solver options this request maps to.
    pub fn opts(&self) -> IntegrateOpts {
        match self.tol {
            Tolerance::Adaptive { rtol, atol } => IntegrateOpts::with_tol(rtol, atol),
            Tolerance::Fixed { h } => IntegrateOpts::fixed(h),
        }
    }

    /// Coalescing key: requests with equal keys run in one batched solve.
    /// Neither `t0` nor `t1` is part of the key — the batched engine
    /// integrates each sample over its own `[t0, t1]`
    /// ([`crate::ode::integrate_batch_tspans`]), so mixed-span requests
    /// coalesce freely (the direction still is: a forward and a backward
    /// solve never share a batch).
    pub fn batch_key(&self) -> BatchKey {
        let (tol_kind, tol_a, tol_b) = match self.tol {
            Tolerance::Adaptive { rtol, atol } => (0u8, rtol.to_bits(), atol.to_bits()),
            Tolerance::Fixed { h } => (1u8, h.to_bits(), 0),
        };
        BatchKey {
            dynamics: self.dynamics.clone(),
            tab: self.tab.name,
            dir: if self.t1 >= self.t0 { 1 } else { -1 },
            tol_kind,
            tol_a,
            tol_b,
            wants_grad: self.grad.is_some(),
        }
    }

    /// Upper bound on the checkpoint bytes this request can pin in a
    /// worker, matching [`Trajectory::checkpoint_bytes`]'s accounting: the
    /// state part (one f32 state per accepted step, capped by the
    /// per-sample checkpoint budget when one is configured) **plus** the
    /// trajectory spine (`ts`/`hs`/`errs` f64s — kept dense under every
    /// policy, so it is never capped). The admission controller sums this
    /// over admitted-unanswered requests.
    ///
    /// The step bound is exact for fixed-step requests (`⌈span/h⌉`, plus
    /// one for the clamped final step) and `max_steps` for adaptive ones.
    /// Gradient requests are **not** budget-capped: their backward pass
    /// additionally buffers one replay segment (up to the thinned-away
    /// states of a segment), so the dense bound is the honest charge.
    ///
    /// [`Trajectory::checkpoint_bytes`]: crate::ode::Trajectory::checkpoint_bytes
    pub fn projected_ckpt_bytes(&self, dim: usize, ckpt_budget_bytes: usize) -> usize {
        let max_steps = self.opts().max_steps;
        let steps = match self.tol {
            // Float→int casts saturate, so a degenerate span/h stays sane.
            Tolerance::Fixed { h } => (((self.t1 - self.t0).abs() / h).ceil() as usize)
                .saturating_add(1)
                .min(max_steps),
            Tolerance::Adaptive { .. } => max_steps,
        };
        let states = steps
            .saturating_add(1)
            .saturating_mul(dim)
            .saturating_mul(std::mem::size_of::<f32>());
        let states = if ckpt_budget_bytes > 0 && self.grad.is_none() {
            // A Budgeted store never holds fewer than 2 anchors (the
            // initial state and the tail), so the effective cap has that
            // floor — charging below it would under-count what the worker
            // actually pins.
            states.min(ckpt_budget_bytes.max(2 * dim * std::mem::size_of::<f32>()))
        } else {
            states
        };
        // Spine: (steps + 1) ts + steps hs + steps errs, all f64 (serve
        // requests never record trials).
        let spine =
            steps.saturating_mul(3).saturating_add(1).saturating_mul(std::mem::size_of::<f64>());
        states.saturating_add(spine)
    }
}

/// What makes two requests co-batchable: same dynamics, solver, integration
/// direction and tolerance bits, and the same gradient flag (a batch either
/// runs the backward pass for all its samples or for none). The span is
/// free per request: the engine integrates each co-batched sample over its
/// own `[t0, t1]` ([`crate::ode::integrate_batch_tspans`]), entering the
/// shared stage sweeps at its own start and retiring at its own endpoint.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub dynamics: String,
    pub tab: &'static str,
    /// Sign of `t1 - t0`: kept in the key so forward and backward solves
    /// group separately even though the span itself is not keyed.
    pub dir: i8,
    pub tol_kind: u8,
    pub tol_a: u64,
    pub tol_b: u64,
    pub wants_grad: bool,
}

/// Per-request timing and solver-cost report.
#[derive(Debug, Clone, Default)]
pub struct RequestStats {
    /// Accepted steps `N_t`.
    pub steps: usize,
    /// `f` evaluations spent on this sample's forward pass.
    pub nfe: usize,
    /// Rejected step attempts.
    pub n_rejected: usize,
    /// Average inner iterations `m` per accepted step.
    pub avg_m: f64,
    /// Bytes the sample's checkpoints held during service.
    pub checkpoint_bytes: usize,
    /// Number of co-batched samples this request was served with.
    pub batch_size: usize,
    /// Time spent queued before its batch started executing.
    pub queue_wait: Duration,
    /// Time from batch start to response (shared by the whole batch).
    pub service: Duration,
}

/// The server's answer to one [`SolveRequest`].
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Final state `z(t1)`.
    pub z_t1: Vec<f32>,
    /// `Some` iff the request asked for gradients.
    pub grad: Option<GradResult>,
    /// Timing and solver-cost bookkeeping.
    pub stats: RequestStats,
}

/// Why the server refused or failed a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Admission control: the submission queue is at capacity. Retry later.
    Overloaded,
    /// The server is shutting down and no longer accepts work.
    ShuttingDown,
    /// The request names a dynamics id that was never registered.
    UnknownDynamics(String),
    /// The request is malformed (wrong state length, bad span, bad step…).
    BadRequest(String),
    /// The solver failed (stiffness blow-up, step underflow, …).
    Solver(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded => write!(f, "server overloaded: submission queue full"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::UnknownDynamics(id) => write!(f, "unknown dynamics id '{id}'"),
            ServeError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            ServeError::Solver(msg) => write!(f, "solver error: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

// ---------------------------------------------------------------------------
// Wire codecs (used by `dist::shard` / `dist::dispatch` to ship requests and
// responses between processes). Float *state* payloads (`z0`, `lam`,
// `z_t1`, gradients) travel as f32 bit patterns so answers cross the wire
// bit-exactly; f64 *scalars* (spans, tolerances) ride as plain JSON numbers
// — the writer emits the shortest round-tripping form, which is bit-exact
// for every finite value, and non-finite spans/tolerances are rejected by
// request validation anyway.

impl SolveRequest {
    pub fn to_json(&self) -> Json {
        let (kind, a, b) = match self.tol {
            Tolerance::Adaptive { rtol, atol } => ("adaptive", rtol, atol),
            Tolerance::Fixed { h } => ("fixed", h, 0.0),
        };
        let mut pairs = vec![
            ("dynamics", self.dynamics.as_str().into()),
            ("t0", self.t0.into()),
            ("t1", self.t1.into()),
            ("z0", f32_bits(&self.z0)),
            ("tab", self.tab.name.into()),
            ("tol_kind", kind.into()),
            ("tol_a", a.into()),
            ("tol_b", b.into()),
        ];
        if let Some(lam) = &self.grad {
            pairs.push(("lam", f32_bits(lam)));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<SolveRequest> {
        let tab_name = v.get("tab")?.as_str()?;
        let tab = crate::ode::tableau::by_name(tab_name)
            .ok_or_else(|| anyhow::anyhow!("unknown tableau '{tab_name}'"))?;
        let tol = match v.get("tol_kind")?.as_str()? {
            "adaptive" => Tolerance::Adaptive {
                rtol: v.get("tol_a")?.as_f64()?,
                atol: v.get("tol_b")?.as_f64()?,
            },
            "fixed" => Tolerance::Fixed { h: v.get("tol_a")?.as_f64()? },
            k => anyhow::bail!("unknown tolerance kind '{k}'"),
        };
        let grad = match v.opt("lam") {
            Some(l) => Some(f32s_from_bits(l)?),
            None => None,
        };
        Ok(SolveRequest {
            dynamics: v.get("dynamics")?.as_str()?.to_string(),
            t0: v.get("t0")?.as_f64()?,
            t1: v.get("t1")?.as_f64()?,
            z0: f32s_from_bits(v.get("z0")?)?,
            tab,
            tol,
            grad,
        })
    }
}

fn duration_from_ns(v: &Json) -> anyhow::Result<Duration> {
    let n = v.as_f64()?;
    anyhow::ensure!(n.is_finite() && n >= 0.0, "bad duration: {n}");
    Ok(Duration::from_nanos(n as u64))
}

fn stats_to_json(s: &RequestStats) -> Json {
    obj(vec![
        ("steps", s.steps.into()),
        ("nfe", s.nfe.into()),
        ("n_rejected", s.n_rejected.into()),
        ("avg_m", s.avg_m.into()),
        ("checkpoint_bytes", s.checkpoint_bytes.into()),
        ("batch_size", s.batch_size.into()),
        ("queue_wait_ns", (s.queue_wait.as_nanos() as f64).into()),
        ("service_ns", (s.service.as_nanos() as f64).into()),
    ])
}

fn stats_from_json(v: &Json) -> anyhow::Result<RequestStats> {
    Ok(RequestStats {
        steps: v.get("steps")?.as_usize()?,
        nfe: v.get("nfe")?.as_usize()?,
        n_rejected: v.get("n_rejected")?.as_usize()?,
        avg_m: v.get("avg_m")?.as_f64()?,
        checkpoint_bytes: v.get("checkpoint_bytes")?.as_usize()?,
        batch_size: v.get("batch_size")?.as_usize()?,
        queue_wait: duration_from_ns(v.get("queue_wait_ns")?)?,
        service: duration_from_ns(v.get("service_ns")?)?,
    })
}

fn meter_to_json(m: &crate::grad::CostMeter) -> Json {
    obj(vec![
        ("nfe_forward", m.nfe_forward.into()),
        ("nfe_backward", m.nfe_backward.into()),
        ("nfe_replay", m.nfe_replay.into()),
        ("replay_peak_bytes", m.replay_peak_bytes.into()),
        ("vjp_calls", m.vjp_calls.into()),
        ("checkpoint_bytes", m.checkpoint_bytes.into()),
        ("graph_depth", m.graph_depth.into()),
        ("n_steps", m.n_steps.into()),
        ("n_rejected", m.n_rejected.into()),
        ("n_reverse_steps", m.n_reverse_steps.into()),
    ])
}

fn meter_from_json(v: &Json) -> anyhow::Result<crate::grad::CostMeter> {
    Ok(crate::grad::CostMeter {
        nfe_forward: v.get("nfe_forward")?.as_usize()?,
        nfe_backward: v.get("nfe_backward")?.as_usize()?,
        nfe_replay: v.get("nfe_replay")?.as_usize()?,
        replay_peak_bytes: v.get("replay_peak_bytes")?.as_usize()?,
        vjp_calls: v.get("vjp_calls")?.as_usize()?,
        checkpoint_bytes: v.get("checkpoint_bytes")?.as_usize()?,
        graph_depth: v.get("graph_depth")?.as_usize()?,
        n_steps: v.get("n_steps")?.as_usize()?,
        n_rejected: v.get("n_rejected")?.as_usize()?,
        n_reverse_steps: v.get("n_reverse_steps")?.as_usize()?,
    })
}

impl SolveResponse {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("z_t1", f32_bits(&self.z_t1)), ("stats", stats_to_json(&self.stats))];
        if let Some(g) = &self.grad {
            pairs.push(("dl_dz0", f32_bits(&g.dl_dz0)));
            pairs.push(("dl_dtheta", f32_bits(&g.dl_dtheta)));
            pairs.push(("meter", meter_to_json(&g.meter)));
        }
        obj(pairs)
    }

    pub fn from_json(v: &Json) -> anyhow::Result<SolveResponse> {
        let grad = match v.opt("dl_dz0") {
            Some(z) => Some(GradResult {
                dl_dz0: f32s_from_bits(z)?,
                dl_dtheta: f32s_from_bits(v.get("dl_dtheta")?)?,
                meter: meter_from_json(v.get("meter")?)?,
            }),
            None => None,
        };
        Ok(SolveResponse {
            z_t1: f32s_from_bits(v.get("z_t1")?)?,
            grad,
            stats: stats_from_json(v.get("stats")?)?,
        })
    }
}

impl ServeError {
    pub fn to_json(&self) -> Json {
        let (kind, msg) = match self {
            ServeError::Overloaded => ("overloaded", ""),
            ServeError::ShuttingDown => ("shutting_down", ""),
            ServeError::UnknownDynamics(id) => ("unknown_dynamics", id.as_str()),
            ServeError::BadRequest(m) => ("bad_request", m.as_str()),
            ServeError::Solver(m) => ("solver", m.as_str()),
        };
        obj(vec![("kind", kind.into()), ("msg", msg.into())])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<ServeError> {
        let msg = v.get("msg")?.as_str()?.to_string();
        Ok(match v.get("kind")?.as_str()? {
            "overloaded" => ServeError::Overloaded,
            "shutting_down" => ServeError::ShuttingDown,
            "unknown_dynamics" => ServeError::UnknownDynamics(msg),
            "bad_request" => ServeError::BadRequest(msg),
            "solver" => ServeError::Solver(msg),
            k => anyhow::bail!("unknown error kind '{k}'"),
        })
    }
}

/// One-shot completion slot shared between a request's handle and the worker
/// that eventually serves it.
#[derive(Debug, Default)]
pub struct ResponseSlot {
    value: Mutex<Option<Result<SolveResponse, ServeError>>>,
    ready: Condvar,
    /// Sticky: set on first delivery and never cleared, even after the
    /// caller takes the value — lets panic cleanup tell "never delivered"
    /// apart from "delivered and already consumed".
    fulfilled: std::sync::atomic::AtomicBool,
}

impl ResponseSlot {
    /// Deliver the result; wakes any waiter. Later calls are ignored (the
    /// first delivery wins, including when the caller already consumed it).
    pub fn fulfill(&self, result: Result<SolveResponse, ServeError>) {
        let mut v = self.value.lock().unwrap();
        if !self.fulfilled.swap(true, std::sync::atomic::Ordering::SeqCst) {
            *v = Some(result);
            self.ready.notify_all();
        }
    }

    /// True once a result has ever been delivered.
    pub fn is_fulfilled(&self) -> bool {
        self.fulfilled.load(std::sync::atomic::Ordering::SeqCst)
    }

    fn wait_take(&self) -> Result<SolveResponse, ServeError> {
        let mut v = self.value.lock().unwrap();
        loop {
            if let Some(r) = v.take() {
                return r;
            }
            v = self.ready.wait(v).unwrap();
        }
    }

    fn try_take(&self) -> Option<Result<SolveResponse, ServeError>> {
        self.value.lock().unwrap().take()
    }
}

/// The caller's side of a submitted request (one-shot: `wait` consumes it).
#[derive(Debug)]
pub struct ResponseHandle {
    pub(crate) slot: Arc<ResponseSlot>,
}

impl ResponseHandle {
    pub(crate) fn new() -> (Self, Arc<ResponseSlot>) {
        let slot = Arc::new(ResponseSlot::default());
        (ResponseHandle { slot: slot.clone() }, slot)
    }

    /// Block until the response is delivered and take it.
    pub fn wait(self) -> Result<SolveResponse, ServeError> {
        self.slot.wait_take()
    }

    /// Take the response if it has already been delivered.
    pub fn try_take(&self) -> Option<Result<SolveResponse, ServeError>> {
        self.slot.try_take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> SolveRequest {
        SolveRequest::adaptive("vdp", 0.0, 5.0, vec![2.0, 0.0], 1e-6, 1e-8)
    }

    #[test]
    fn same_parameters_same_key() {
        let a = req();
        let mut b = req();
        b.z0 = vec![-1.0, 0.5]; // the state may differ inside a batch
        assert_eq!(a.batch_key(), b.batch_key());
    }

    /// The span is the other free axis: requests that differ only in `t0`
    /// and/or `t1` (same direction) coalesce — the engine integrates each
    /// sample over its own span.
    #[test]
    fn mixed_spans_share_a_key() {
        let a = req();
        let mut b = req();
        b.t1 = 6.0;
        b.z0 = vec![-1.0, 0.5];
        assert_eq!(a.batch_key(), b.batch_key(), "t1 must not split batches");
        let mut c = req();
        c.t0 = 1.0;
        c.t1 = 3.5;
        assert_eq!(a.batch_key(), c.batch_key(), "t0 must not split batches");
    }

    #[test]
    fn key_separates_incompatible_requests() {
        let base = req();
        let mut other = req();
        other.t1 = -5.0; // backward span from the same t0
        assert_ne!(base.batch_key(), other.batch_key(), "direction");
        let mut other = req();
        other.tol = Tolerance::Adaptive { rtol: 1e-5, atol: 1e-8 };
        assert_ne!(base.batch_key(), other.batch_key(), "tolerance");
        let mut other = req();
        other.tab = crate::ode::tableau::rk23();
        assert_ne!(base.batch_key(), other.batch_key(), "tableau");
        let other = req().with_grad(vec![1.0, 0.0]);
        assert_ne!(base.batch_key(), other.batch_key(), "grad flag");
        let mut other = req();
        other.dynamics = "linear".into();
        assert_ne!(base.batch_key(), other.batch_key(), "dynamics");
    }

    /// Projected checkpoint footprint: per-step state bytes (capped by the
    /// per-sample checkpoint budget for forward-only requests) plus the
    /// dense spine — the spine is never thinned, so the cap must not erase
    /// it; fixed-step requests project their exact step count instead of
    /// the `max_steps` upper bound; gradient requests stay uncapped (their
    /// replay cache can transiently reach the dense footprint).
    #[test]
    fn projected_bytes_upper_bound_and_budget_cap() {
        let r = req(); // adaptive → default max_steps = 100_000 bound
        let spine = (3 * 100_000 + 1) * 8;
        assert_eq!(r.projected_ckpt_bytes(2, 0), 100_001 * 2 * 4 + spine);
        assert_eq!(
            r.projected_ckpt_bytes(2, 4096),
            4096 + spine,
            "budget caps the state part only — the spine stays dense"
        );
        assert_eq!(r.projected_ckpt_bytes(2, usize::MAX), 100_001 * 2 * 4 + spine);

        // Gradient request: the cap does not apply.
        let g = req().with_grad(vec![1.0, 0.0]);
        assert_eq!(g.projected_ckpt_bytes(2, 4096), 100_001 * 2 * 4 + spine);

        // Fixed step over [0, 5] with h = 0.5: exactly 10 steps (+1 for the
        // final-step clamp margin) instead of the max_steps bound.
        let f = SolveRequest::fixed("vdp", 0.0, 5.0, vec![2.0, 0.0], 0.5);
        assert_eq!(f.projected_ckpt_bytes(2, 0), 12 * 2 * 4 + (3 * 11 + 1) * 8);
    }

    #[test]
    fn fixed_vs_adaptive_keys_differ() {
        let a = SolveRequest::fixed("vdp", 0.0, 5.0, vec![2.0, 0.0], 0.01);
        let mut b = req();
        b.tab = a.tab;
        assert_ne!(a.batch_key(), b.batch_key());
    }

    #[test]
    fn response_slot_one_shot() {
        let (handle, slot) = ResponseHandle::new();
        assert!(handle.try_take().is_none());
        assert!(!slot.is_fulfilled());
        slot.fulfill(Err(ServeError::Overloaded));
        slot.fulfill(Err(ServeError::ShuttingDown)); // ignored: first wins
        assert!(slot.is_fulfilled());
        assert_eq!(handle.try_take().unwrap().unwrap_err(), ServeError::Overloaded);
        // A late delivery after the caller consumed the value must not
        // resurrect the slot (fulfilled is sticky).
        slot.fulfill(Err(ServeError::ShuttingDown));
        assert!(handle.try_take().is_none());
        assert!(slot.is_fulfilled());
    }

    #[test]
    fn response_slot_wakes_waiter() {
        let (handle, slot) = ResponseHandle::new();
        let t = std::thread::spawn(move || handle.wait());
        slot.fulfill(Err(ServeError::Overloaded));
        assert_eq!(t.join().unwrap().unwrap_err(), ServeError::Overloaded);
    }

    #[test]
    fn request_json_round_trips_bit_exactly() {
        let mut r = SolveRequest::adaptive("vdp", 0.25, 5.5, vec![2.0, -0.0], 1e-6, 1e-8);
        r.z0[1] = f32::from_bits(0x0000_0001); // smallest subnormal
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let back = SolveRequest::from_json(&j).unwrap();
        assert_eq!(back.dynamics, "vdp");
        assert_eq!(back.t0.to_bits(), r.t0.to_bits());
        assert_eq!(back.t1.to_bits(), r.t1.to_bits());
        assert_eq!(back.tab.name, r.tab.name);
        assert_eq!(back.tol, r.tol);
        assert!(back.grad.is_none());
        let got: Vec<u32> = back.z0.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = r.z0.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp);
        assert_eq!(back.batch_key(), r.batch_key(), "the key must survive the wire");

        let g = SolveRequest::fixed("linear", 1.0, -2.0, vec![0.5; 3], 0.125)
            .with_grad(vec![1.0, 0.0, -1.0]);
        let j = Json::parse(&g.to_json().to_string()).unwrap();
        let back = SolveRequest::from_json(&j).unwrap();
        assert_eq!(back.tol, Tolerance::Fixed { h: 0.125 });
        assert_eq!(back.grad, Some(vec![1.0, 0.0, -1.0]));
        assert_eq!(back.batch_key(), g.batch_key());

        assert!(SolveRequest::from_json(&Json::parse("{}").unwrap()).is_err());
        let mut bad = r.to_json();
        if let Json::Obj(m) = &mut bad {
            m.insert("tab".into(), "nope".into());
        }
        assert!(SolveRequest::from_json(&bad).is_err(), "unknown tableau must not decode");
    }

    #[test]
    fn response_and_error_json_round_trip() {
        let resp = SolveResponse {
            z_t1: vec![1.5, f32::NAN, -0.0],
            grad: Some(GradResult {
                dl_dz0: vec![0.25, -0.5, 1e-45],
                dl_dtheta: vec![3.5],
                meter: crate::grad::CostMeter {
                    nfe_forward: 10,
                    nfe_backward: 20,
                    nfe_replay: 3,
                    replay_peak_bytes: 128,
                    vjp_calls: 5,
                    checkpoint_bytes: 256,
                    graph_depth: 7,
                    n_steps: 11,
                    n_rejected: 2,
                    n_reverse_steps: 0,
                },
            }),
            stats: RequestStats {
                steps: 11,
                nfe: 44,
                n_rejected: 2,
                avg_m: 1.25,
                checkpoint_bytes: 256,
                batch_size: 4,
                queue_wait: Duration::from_micros(250),
                service: Duration::from_millis(3),
            },
        };
        let j = Json::parse(&resp.to_json().to_string()).unwrap();
        let back = SolveResponse::from_json(&j).unwrap();
        let got: Vec<u32> = back.z_t1.iter().map(|x| x.to_bits()).collect();
        let exp: Vec<u32> = resp.z_t1.iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, exp, "NaN and -0.0 states must survive the wire");
        let bg = back.grad.unwrap();
        assert_eq!(bg.dl_dtheta, vec![3.5]);
        assert_eq!(bg.dl_dz0[2].to_bits(), 1e-45f32.to_bits());
        assert_eq!(bg.meter.nfe_backward, 20);
        assert_eq!(bg.meter.n_reverse_steps, 0);
        assert_eq!(back.stats.batch_size, 4);
        assert_eq!(back.stats.queue_wait, Duration::from_micros(250));
        assert_eq!(back.stats.service, Duration::from_millis(3));

        for e in [
            ServeError::Overloaded,
            ServeError::ShuttingDown,
            ServeError::UnknownDynamics("ghost".into()),
            ServeError::BadRequest("z0 length".into()),
            ServeError::Solver("step underflow".into()),
        ] {
            let back = ServeError::from_json(&Json::parse(&e.to_json().to_string()).unwrap());
            assert_eq!(back.unwrap(), e, "error variants must survive the wire");
        }
        assert!(ServeError::from_json(&Json::parse(r#"{"kind":"??","msg":""}"#).unwrap()).is_err());
    }

    #[test]
    fn opts_round_trip() {
        let o = req().opts();
        assert_eq!(o.rtol, 1e-6);
        assert_eq!(o.atol, 1e-8);
        assert!(o.fixed_h.is_none());
        let o = SolveRequest::fixed("vdp", 0.0, 1.0, vec![0.0, 0.0], 0.05).opts();
        assert_eq!(o.fixed_h, Some(0.05));
    }
}
