//! Batch execution: a shard of worker threads pulls [`FormedBatch`]es off
//! the work queue, runs them through the batched engine
//! ([`crate::ode::integrate_batch_tspans`] +
//! [`crate::grad::aca_backward_batch`]), and scatters per-sample results
//! back to each request's response slot. Co-batched requests share solver
//! and tolerance (the [`super::request::BatchKey`]) but each keeps its
//! **own span**: the worker hands the engine one `(t0, t1)` per sample and
//! every sample enters/retires from the shared stage sweeps at its own
//! endpoints. Gradient batches share stage sweeps in **both** directions:
//! the forward solve amortizes `eval_batch` across co-batched requests and
//! the backward pass runs the shared-stage reverse sweep (`step_vjp_batch`
//! — one `eval_batch`/`vjp_batch` dispatch per stage per reverse round), so
//! co-batching gradient traffic costs per-stage dispatch, not per-request.
//!
//! Dense-output batches (`BatchKey::wants_obs`) additionally build a
//! [`DenseOutput`] interpolant per sample and evaluate it at the request's
//! `observe_at` grid. Such batches run under the **dense** checkpoint
//! policy regardless of the server budget — the interpolant needs every
//! knot, and the admission charge already billed the full store
//! (`projected_ckpt_bytes` never caps an observing request). Because the
//! batch engine's per-sample trajectories are bit-identical to scalar
//! solves, each served observation is bit-equal to `DenseOutput::eval` on a
//! direct solve.
//!
//! Memory: solves run under the server's per-sample checkpoint budget
//! (`ServeConfig::ckpt_budget_bytes` → [`crate::ckpt::CkptPolicy`]) — a
//! thinned store changes nothing about any answer (bit-exact segment
//! replay), only how many bytes a long solve can pin.
//!
//! Poison isolation: `integrate_batch_tspans` fails the whole batch when any
//! one sample blows up (stiffness, step underflow). A serving layer must not let
//! one bad request fail its co-batched neighbors, so on batch failure the
//! worker falls back to per-sample scalar solves — bit-identical to the
//! batched path by the engine's equivalence guarantee — and only the
//! offending samples report [`ServeError::Solver`].

use super::batcher::FormedBatch;
use super::request::{Payload, RequestStats, ServeError, SolveResponse};
use super::Core;
use crate::ckpt::CkptPolicy;
use crate::coordinator::pool::panic_msg;
use crate::grad::{aca_backward, aca_backward_batch};
use crate::obs::{self, SpanRec};
use crate::ode::dense::DenseOutput;
use crate::ode::{integrate, integrate_batch_tspans};
use std::time::Duration;

/// Worker thread body: serve batches until the work queue closes and drains.
///
/// Panic containment (same discipline as `coordinator::pool::run_parallel`):
/// a panicking dynamics `eval`/`vjp` — arbitrary user trait impls — must not
/// kill the worker thread. An uncontained panic would leave every
/// co-batched `ResponseHandle::wait` blocked forever, leak their admission
/// slots until `submit` returns `Overloaded` for all traffic, and deadlock
/// `drain`/`shutdown`. Instead the panicking batch's undelivered requests
/// are failed with [`ServeError::Solver`] and the worker keeps serving.
pub(crate) fn worker_loop(core: &Core) {
    // Preallocate this thread's span recorder up front: no later record()
    // call on this thread allocates, traced batch or not.
    obs::thread_init();
    while let Some(batch) = core.work_q.recv_one() {
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| execute_batch(core, &batch)));
        if let Err(payload) = outcome {
            let err =
                ServeError::Solver(format!("panic in batch execution: {}", panic_msg(&*payload)));
            for item in &batch.items {
                // complete() releases the admission slot exactly once; skip
                // requests the panicking pass already delivered.
                if !item.slot.is_fulfilled() {
                    core.metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    core.complete(&item.slot, item.cost, Err(err.clone()));
                }
            }
        }
    }
}

type SampleOutcome = Result<(Payload, RequestStats), ServeError>;

/// Clock readings and hot-counter snapshots bracketing the batched
/// attempt's phases — what turns one executed batch into per-item
/// `forward`/`reverse` spans with exact (ManualClock-deterministic)
/// durations and round/sweep counts. Captured unconditionally (three clock
/// reads and three thread-local copies per *batch*, nowhere near the hot
/// loops); only read when the batch carries traced items.
#[derive(Clone, Copy, Default)]
struct PhaseMarks {
    fwd_start: Duration,
    fwd_end: Duration,
    bwd_end: Duration,
    ctr_before: [u64; 4],
    ctr_mid: [u64; 4],
    ctr_after: [u64; 4],
}

fn ctr_delta(before: &[u64; 4], after: &[u64; 4], i: usize) -> u64 {
    after[i].saturating_sub(before[i])
}

/// Run one formed batch and deliver every member's response.
pub(crate) fn execute_batch(core: &Core, batch: &FormedBatch) {
    let started = core.clock.now();
    let n = batch.items.len();
    core.metrics.record_batch(n);

    let Some(f) = core.registry.get(&batch.key.dynamics).cloned() else {
        // submit() validates ids, so this only guards registry mutation bugs.
        let err = ServeError::UnknownDynamics(batch.key.dynamics.clone());
        for item in &batch.items {
            core.complete(&item.slot, item.cost, Err(err.clone()));
        }
        return;
    };
    let dim = f.dim();
    // Formed batches are never empty (the batcher only flushes non-empty
    // buckets), so the key-equal fields can be read off the first item.
    let first = &batch.items[0].req;
    // tab/opts are key-equal across the batch; the span is per-request. The
    // worker's solves run under the server's checkpoint budget — except
    // dense-output batches, which need every knot stored (see module docs).
    let tab = first.tab;
    let mut opts = first.opts();
    opts.ckpt = CkptPolicy::from_budget(core.cfg.ckpt_budget_bytes);
    let wants_grad = batch.key.wants_grad;
    let wants_obs = batch.key.wants_obs;
    if wants_obs {
        opts.ckpt = CkptPolicy::from_budget(0);
    }

    let mut z0 = Vec::with_capacity(n * dim);
    let mut t0s = Vec::with_capacity(n);
    let mut t1s = Vec::with_capacity(n);
    for item in &batch.items {
        z0.extend_from_slice(&item.req.z0);
        t0s.push(item.req.t0);
        t1s.push(item.req.t1);
    }

    // The whole batched attempt — forward AND backward — is panic-contained
    // like it is error-contained: a dynamics whose `eval` or `vjp` panics on
    // one sample's state sends the batch down the same per-sample fallback
    // an integration error does.
    let mut marks = PhaseMarks::default();
    let batched = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<Vec<SampleOutcome>> {
            // A gradient batch must carry a cotangent on every member (the
            // batch key pins `wants_grad`); a grad-less straggler is a
            // batcher bug. Catch it *before* the solve and route the batch
            // down the per-sample fallback instead of panicking.
            let lam = if wants_grad {
                let mut lam = Vec::with_capacity(n * dim);
                for item in &batch.items {
                    match item.req.grad.as_ref() {
                        Some(g) => lam.extend_from_slice(g),
                        None => anyhow::bail!(
                            "request without a cotangent in a wants_grad batch; \
                             taking the per-sample fallback"
                        ),
                    }
                }
                Some(lam)
            } else {
                None
            };
            marks.ctr_before = obs::counters();
            marks.fwd_start = core.clock.now();
            let bt = integrate_batch_tspans(&*f, &t0s, &t1s, &z0, tab, &opts)?;
            marks.fwd_end = core.clock.now();
            marks.ctr_mid = obs::counters();
            let grads = lam.map(|lam| aca_backward_batch(&*f, tab, &bt, &lam));
            marks.bwd_end = core.clock.now();
            marks.ctr_after = obs::counters();
            Ok((0..n)
                .map(|i| {
                    let tr = &bt.tracks[i];
                    let z_t1 = bt.last(i).to_vec();
                    let payload = if wants_obs {
                        // Per-sample interpolant over the (dense) per-sample
                        // trajectory — identical knots to a direct solve, so
                        // identical observations.
                        let traj = bt.to_trajectory(i);
                        let dense = DenseOutput::new(&*f, &traj);
                        let zs = dense.eval_grid(&batch.items[i].req.observe_at);
                        Payload::Observed { z_t1, zs }
                    } else if let Some(g) = grads.as_ref() {
                        Payload::Gradient { z_t1, grad: g[i].clone() }
                    } else {
                        Payload::Forward { z_t1 }
                    };
                    Ok((
                        payload,
                        RequestStats {
                            steps: tr.steps(),
                            nfe: tr.nfe,
                            n_rejected: tr.n_rejected,
                            avg_m: tr.avg_m(),
                            checkpoint_bytes: bt.checkpoint_bytes(i),
                            ..Default::default()
                        },
                    ))
                })
                .collect())
        },
    ));
    let fell_back = !matches!(batched, Ok(Ok(_)));
    let outcomes: Vec<SampleOutcome> = match batched {
        Ok(Ok(v)) => v,
        // Per-sample fallback: isolate the poison sample(s) — error or
        // panic — while the healthy ones still get their (bit-identical)
        // scalar results.
        Ok(Err(_)) | Err(_) => batch
            .items
            .iter()
            .map(|item| {
                let one = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> SampleOutcome {
                        match integrate(&*f, item.req.t0, item.req.t1, &item.req.z0, tab, &opts) {
                            Ok(traj) => {
                                let Some(z_t1) = traj.last() else {
                                    return Err(ServeError::Solver(
                                        "integration returned an empty trajectory".to_string(),
                                    ));
                                };
                                let z_t1 = z_t1.to_vec();
                                let payload = if wants_obs {
                                    // `opts.ckpt` is dense for observing
                                    // batches, so every knot is stored.
                                    let dense = DenseOutput::new(&*f, &traj);
                                    let zs = dense.eval_grid(&item.req.observe_at);
                                    Payload::Observed { z_t1, zs }
                                } else {
                                    // A grad-less request in a gradient
                                    // batch degrades to a forward-only
                                    // answer here — its healthy neighbors
                                    // keep their grads.
                                    match item.req.grad.as_ref() {
                                        Some(lam) if wants_grad => Payload::Gradient {
                                            z_t1,
                                            grad: aca_backward(&*f, tab, &traj, lam),
                                        },
                                        _ => Payload::Forward { z_t1 },
                                    }
                                };
                                Ok((
                                    payload,
                                    RequestStats {
                                        steps: traj.len(),
                                        nfe: traj.nfe,
                                        n_rejected: traj.n_rejected,
                                        avg_m: traj.avg_m(),
                                        checkpoint_bytes: traj.checkpoint_bytes(),
                                        ..Default::default()
                                    },
                                ))
                            }
                            Err(e) => Err(ServeError::Solver(e.to_string())),
                        }
                    },
                ));
                one.unwrap_or_else(|p| {
                    Err(ServeError::Solver(format!("panic in solve: {}", panic_msg(&*p))))
                })
            })
            .collect(),
    };

    let done = core.clock.now();
    let service = done.saturating_sub(started);
    // Spans go to the global store *before* any response is fulfilled, so
    // a trace is complete by the time its requester wakes.
    record_solve_spans(batch, &outcomes, &marks, started, done, fell_back);
    for (item, outcome) in batch.items.iter().zip(outcomes) {
        let queue_wait = started.saturating_sub(item.submitted);
        match outcome {
            Ok((payload, mut stats)) => {
                stats.batch_size = n;
                stats.queue_wait = queue_wait;
                stats.service = service;
                core.metrics.record_request(&batch.key.dynamics, queue_wait, service, stats.nfe);
                core.complete(&item.slot, item.cost, Ok(SolveResponse { payload, stats }));
            }
            Err(e) => {
                core.metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                core.complete(&item.slot, item.cost, Err(e));
            }
        }
    }
}

/// Per-item span trees for one executed batch. Batched path:
/// `solve → forward [→ reverse [→ replay]]`, with NFE attribution drawn
/// from the same [`crate::grad::CostMeter`] the response carries (so
/// forward + reverse + replay NFE sums to the meter totals by
/// construction) and round/sweep counts from the hot-counter deltas around
/// each phase. Fallback path: `solve → fallback`. Untraced items emit
/// nothing.
fn record_solve_spans(
    batch: &FormedBatch,
    outcomes: &[SampleOutcome],
    marks: &PhaseMarks,
    started: Duration,
    done: Duration,
    fell_back: bool,
) {
    let n = batch.items.len() as u64;
    let mut any = false;
    for (item, outcome) in batch.items.iter().zip(outcomes) {
        let Some(ctx) = item.req.trace else { continue };
        any = true;
        let solve = SpanRec::new(ctx, obs::SOLVE, started, done).attr("batch_size", n);
        obs::record(solve);
        let inner = solve.ctx();
        if fell_back {
            let span = SpanRec::new(inner, obs::FALLBACK, started, done);
            obs::record(match outcome {
                Ok((_, stats)) => span.attr("nfe", stats.nfe as u64),
                Err(_) => span.attr("status", 1),
            });
            continue;
        }
        let meter = match outcome {
            Ok((Payload::Gradient { grad, .. }, _)) => Some(&grad.meter),
            _ => None,
        };
        let fwd_nfe = match outcome {
            Ok((_, stats)) => stats.nfe as u64,
            Err(_) => 0,
        };
        obs::record(
            SpanRec::new(inner, obs::FORWARD, marks.fwd_start, marks.fwd_end)
                .attr("nfe", fwd_nfe)
                .attr("rounds", ctr_delta(&marks.ctr_before, &marks.ctr_mid, obs::CTR_FWD_ROUNDS))
                .attr("sweeps", ctr_delta(&marks.ctr_before, &marks.ctr_mid, obs::CTR_FWD_SWEEPS)),
        );
        if let Some(m) = meter {
            let rev = SpanRec::new(inner, obs::REVERSE, marks.fwd_end, marks.bwd_end)
                .attr("nfe", m.nfe_backward as u64)
                .attr("rounds", ctr_delta(&marks.ctr_mid, &marks.ctr_after, obs::CTR_REV_ROUNDS))
                .attr("sweeps", ctr_delta(&marks.ctr_mid, &marks.ctr_after, obs::CTR_REV_SWEEPS));
            obs::record(rev);
            if m.nfe_replay > 0 {
                obs::record(
                    SpanRec::event(rev.ctx(), obs::REPLAY, marks.bwd_end)
                        .attr("nfe", m.nfe_replay as u64)
                        .attr("bytes", m.replay_peak_bytes as u64),
                );
            }
        }
    }
    if any {
        obs::publish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::VanDerPol;
    use crate::ode::IntegrateOpts;
    use crate::serve::batcher::{FlushReason, Pending};
    use crate::serve::queue::Channel;
    use crate::serve::request::{ResponseHandle, ResponseSlot, SolveRequest};
    use crate::serve::{Inflight, ManualClock, ServeConfig, ServeMetrics};
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicBool, AtomicUsize};
    use std::sync::{Arc, Condvar, Mutex};
    use std::time::Duration;

    /// A `Core` wired for direct `execute_batch` calls: no threads, manual
    /// clock, `inflight` pre-charged for the requests the test will deliver
    /// (each `complete` releases one admission slot).
    fn test_core(inflight: usize) -> Core {
        let mut registry: HashMap<String, Arc<dyn crate::ode::OdeFunc + Send + Sync>> =
            HashMap::new();
        registry.insert("vdp".to_string(), Arc::new(VanDerPol::new(0.5)));
        Core {
            cfg: ServeConfig {
                max_batch_size: 8,
                max_queue_delay: Duration::ZERO,
                queue_capacity: 64,
                workers: 1,
                ckpt_budget_bytes: 0,
                mem_budget_bytes: 0,
                quota_quantum: 32,
                quota_max_deficit: 128,
            },
            clock: ManualClock::new(),
            registry,
            metrics: ServeMetrics::default(),
            submit_q: Channel::bounded(64),
            work_q: Channel::unbounded(),
            inflight: Mutex::new(Inflight { count: inflight, bytes: 0 }),
            idle: Condvar::new(),
            drain_waiters: AtomicUsize::new(0),
            closed: AtomicBool::new(false),
        }
    }

    fn pend(req: SolveRequest, slot: Arc<ResponseSlot>) -> Pending {
        Pending { req, slot, submitted: Duration::ZERO, cost: 0 }
    }

    /// Regression: a grad-less request sharing a `wants_grad` batch (a
    /// batcher bug — the key pins the grad flag) used to hit
    /// `req.grad.unwrap()` and panic, failing its healthy co-batched
    /// neighbor. Now the batch routes down the per-sample fallback: the
    /// gradient request keeps its (bit-identical) gradient, the straggler
    /// degrades to a forward-only answer, and nothing reports an error.
    #[test]
    fn grad_less_item_in_grad_batch_degrades_instead_of_panicking() {
        let core = test_core(2);
        let with_grad = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![2.0, 0.0], 1e-6, 1e-8)
            .unwrap()
            .with_grad(vec![1.0, 0.0]);
        let without_grad =
            SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.5, -0.5], 1e-6, 1e-8).unwrap();
        let key = with_grad.batch_key();
        assert!(key.wants_grad);

        let (h1, slot1) = ResponseHandle::new();
        let (h2, slot2) = ResponseHandle::new();
        let batch = FormedBatch {
            key,
            items: vec![pend(with_grad.clone(), slot1), pend(without_grad.clone(), slot2)],
            reason: FlushReason::Drain,
            triggered_at: Duration::ZERO,
            deferred: 0,
        };
        execute_batch(&core, &batch);

        let r1 = h1.try_take().expect("grad request answered").expect("grad request succeeds");
        let r2 = h2.try_take().expect("straggler answered").expect("straggler succeeds");

        // Fallback answers are the scalar engine's answers, bit-for-bit.
        let mut opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        opts.ckpt = CkptPolicy::from_budget(0);
        let t1 = integrate(&*core.registry["vdp"], 0.0, 1.0, &with_grad.z0, with_grad.tab, &opts)
            .unwrap();
        assert_eq!(r1.z_t1(), t1.last().unwrap());
        let g = aca_backward(&*core.registry["vdp"], with_grad.tab, &t1, &[1.0, 0.0]);
        assert_eq!(r1.grad().expect("gradient kept").dl_dz0, g.dl_dz0);

        let t2 =
            integrate(&*core.registry["vdp"], 0.0, 1.0, &without_grad.z0, without_grad.tab, &opts)
                .unwrap();
        assert_eq!(r2.z_t1(), t2.last().unwrap());
        assert!(r2.grad().is_none(), "the straggler degrades to forward-only");

        assert_eq!(
            core.metrics.failed.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "a batcher bug must not surface as request failures"
        );
        assert_eq!(core.inflight.lock().unwrap().count, 0, "both admission slots released");
    }

    /// The healthy path is unaffected: a well-formed gradient batch runs the
    /// batched forward + backward and answers every member with a gradient.
    #[test]
    fn well_formed_grad_batch_serves_all_members() {
        let core = test_core(2);
        let reqs: Vec<SolveRequest> = [vec![2.0, 0.0], vec![1.0, 0.5]]
            .into_iter()
            .map(|z0| {
                SolveRequest::adaptive("vdp", 0.0, 1.0, z0, 1e-6, 1e-8)
                    .unwrap()
                    .with_grad(vec![1.0, 0.0])
            })
            .collect();
        let key = reqs[0].batch_key();
        let (handles, items): (Vec<_>, Vec<_>) = reqs
            .into_iter()
            .map(|req| {
                let (h, slot) = ResponseHandle::new();
                (h, pend(req, slot))
            })
            .unzip();
        let batch = FormedBatch {
            key,
            items,
            reason: FlushReason::Size,
            triggered_at: Duration::ZERO,
            deferred: 0,
        };
        execute_batch(&core, &batch);
        for h in handles {
            let resp = h.try_take().expect("answered").expect("succeeds");
            assert_eq!(resp.z_t1().len(), 2);
            assert!(resp.grad().is_some(), "every member of a grad batch gets its gradient");
            assert_eq!(resp.stats.batch_size, 2);
        }
        assert_eq!(core.inflight.lock().unwrap().count, 0);
    }

    /// Dense-output serving contract: a co-batched observation request's
    /// grid values are bit-identical to building a `DenseOutput` over a
    /// direct scalar solve and calling `eval` — even when the server runs a
    /// thinning checkpoint budget (observing batches force the dense
    /// policy).
    #[test]
    fn observed_batch_is_bit_equal_to_direct_dense_eval() {
        let mut core = test_core(2);
        core.cfg.ckpt_budget_bytes = 4096; // thinning budget; obs must override
        let grid = vec![0.0, 0.25, 0.9, 1.0];
        let reqs: Vec<SolveRequest> = [vec![2.0, 0.0], vec![1.0, 0.5]]
            .into_iter()
            .map(|z0| {
                SolveRequest::builder("vdp")
                    .span(0.0, 1.0)
                    .state(z0)
                    .adaptive(1e-6, 1e-8)
                    .observe_at(grid.clone())
                    .build()
                    .unwrap()
            })
            .collect();
        let key = reqs[0].batch_key();
        assert!(key.wants_obs);
        let (handles, items): (Vec<_>, Vec<_>) = reqs
            .iter()
            .map(|req| {
                let (h, slot) = ResponseHandle::new();
                (h, pend(req.clone(), slot))
            })
            .unzip();
        let batch = FormedBatch {
            key,
            items,
            reason: FlushReason::Size,
            triggered_at: Duration::ZERO,
            deferred: 0,
        };
        execute_batch(&core, &batch);
        for (h, req) in handles.into_iter().zip(&reqs) {
            let resp = h.try_take().expect("answered").expect("succeeds");
            let mut opts = IntegrateOpts::with_tol(1e-6, 1e-8);
            opts.ckpt = CkptPolicy::from_budget(0);
            let traj =
                integrate(&*core.registry["vdp"], 0.0, 1.0, &req.z0, req.tab, &opts).unwrap();
            assert_eq!(resp.z_t1(), traj.last().unwrap());
            let dense = DenseOutput::new(&*core.registry["vdp"], &traj);
            let zs = resp.observations().expect("observation payload");
            assert_eq!(zs.len(), grid.len());
            for (&t, z) in grid.iter().zip(zs) {
                let direct = dense.eval(t);
                let got: Vec<u32> = z.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = direct.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "t={t}");
            }
        }
        assert_eq!(core.inflight.lock().unwrap().count, 0);
    }

    /// Traced gradient batch under a thinning checkpoint budget: the span
    /// tree is `solve → forward, reverse → replay`, and the per-span NFE
    /// attribution sums exactly to the response's `CostMeter` totals.
    #[test]
    fn traced_grad_batch_emits_attributed_span_tree() {
        let mut core = test_core(1);
        core.cfg.ckpt_budget_bytes = 64; // tiny budget → thinning → replay
        let trace = crate::obs::mint(Duration::from_nanos(77));
        let ctx = crate::obs::TraceCtx::root(trace);
        let mut req = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![2.0, 0.0], 1e-6, 1e-8)
            .unwrap()
            .with_grad(vec![1.0, 0.0]);
        req.trace = Some(ctx);
        let key = req.batch_key();
        let (h, slot) = ResponseHandle::new();
        let batch = FormedBatch {
            key,
            items: vec![pend(req, slot)],
            reason: FlushReason::Drain,
            triggered_at: Duration::ZERO,
            deferred: 0,
        };
        execute_batch(&core, &batch);
        let resp = h.try_take().expect("answered").expect("succeeds");
        let meter = resp.grad().expect("gradient").meter.clone();
        assert!(meter.nfe_replay > 0, "the tiny budget must force replay");

        let spans = crate::obs::global().take(trace);
        let names: Vec<&str> = spans.iter().map(|s| s.name).collect();
        assert_eq!(names, vec![obs::SOLVE, obs::FORWARD, obs::REVERSE, obs::REPLAY]);
        let (solve, fwd, rev, replay) = (&spans[0], &spans[1], &spans[2], &spans[3]);
        assert_eq!(solve.parent, 0, "root context");
        assert_eq!(solve.get_attr("batch_size"), Some(1));
        assert_eq!(fwd.parent, solve.span);
        assert_eq!(rev.parent, solve.span);
        assert_eq!(replay.parent, rev.span, "replay is attributed under reverse");
        assert!(fwd.get_attr("rounds").unwrap() > 0, "forward active-set rounds counted");
        assert!(fwd.get_attr("sweeps").unwrap() > 0, "forward stage sweeps counted");
        assert!(rev.get_attr("rounds").unwrap() > 0, "reverse rounds counted");
        assert!(rev.get_attr("sweeps").unwrap() > 0, "reverse stage sweeps counted");
        assert!(replay.get_attr("bytes").unwrap() > 0, "replay buffer bytes attributed");
        let span_nfe = fwd.get_attr("nfe").unwrap()
            + rev.get_attr("nfe").unwrap()
            + replay.get_attr("nfe").unwrap();
        let meter_nfe = (meter.nfe_forward + meter.nfe_backward + meter.nfe_replay) as u64;
        assert_eq!(span_nfe, meter_nfe, "span NFE attribution sums to the CostMeter");
    }

    /// An untraced batch leaves no footprint in the trace store and a
    /// traced batch's spans never leak into another trace.
    #[test]
    fn untraced_batch_records_nothing() {
        let core = test_core(1);
        let probe = crate::obs::mint(Duration::from_nanos(78));
        let req = SolveRequest::adaptive("vdp", 0.0, 1.0, vec![1.0, 0.0], 1e-6, 1e-8).unwrap();
        let key = req.batch_key();
        let (h, slot) = ResponseHandle::new();
        let batch = FormedBatch {
            key,
            items: vec![pend(req, slot)],
            reason: FlushReason::Drain,
            triggered_at: Duration::ZERO,
            deferred: 0,
        };
        execute_batch(&core, &batch);
        assert!(h.try_take().expect("answered").is_ok());
        assert!(crate::obs::global().get(probe).is_empty());
    }
}
