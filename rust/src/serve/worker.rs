//! Batch execution: a shard of worker threads pulls [`FormedBatch`]es off
//! the work queue, runs them through the batched engine
//! ([`crate::ode::integrate_batch_tspans`] +
//! [`crate::grad::aca_backward_batch`]), and scatters per-sample results
//! back to each request's response slot. Co-batched requests share solver
//! and tolerance (the [`super::request::BatchKey`]) but each keeps its
//! **own span**: the worker hands the engine one `(t0, t1)` per sample and
//! every sample enters/retires from the shared stage sweeps at its own
//! endpoints. Gradient batches share stage sweeps in **both** directions:
//! the forward solve amortizes `eval_batch` across co-batched requests and
//! the backward pass runs the shared-stage reverse sweep (`step_vjp_batch`
//! — one `eval_batch`/`vjp_batch` dispatch per stage per reverse round), so
//! co-batching gradient traffic costs per-stage dispatch, not per-request.
//!
//! Memory: solves run under the server's per-sample checkpoint budget
//! (`ServeConfig::ckpt_budget_bytes` → [`crate::ckpt::CkptPolicy`]) — a
//! thinned store changes nothing about any answer (bit-exact segment
//! replay), only how many bytes a long solve can pin.
//!
//! Poison isolation: `integrate_batch_tspans` fails the whole batch when any
//! one sample blows up (stiffness, step underflow). A serving layer must not let
//! one bad request fail its co-batched neighbors, so on batch failure the
//! worker falls back to per-sample scalar solves — bit-identical to the
//! batched path by the engine's equivalence guarantee — and only the
//! offending samples report [`ServeError::Solver`].

use super::batcher::FormedBatch;
use super::request::{RequestStats, ServeError, SolveResponse};
use super::Core;
use crate::ckpt::CkptPolicy;
use crate::coordinator::pool::panic_msg;
use crate::grad::{aca_backward, aca_backward_batch, GradResult};
use crate::ode::{integrate, integrate_batch_tspans};

/// Worker thread body: serve batches until the work queue closes and drains.
///
/// Panic containment (same discipline as `coordinator::pool::run_parallel`):
/// a panicking dynamics `eval`/`vjp` — arbitrary user trait impls — must not
/// kill the worker thread. An uncontained panic would leave every
/// co-batched `ResponseHandle::wait` blocked forever, leak their admission
/// slots until `submit` returns `Overloaded` for all traffic, and deadlock
/// `drain`/`shutdown`. Instead the panicking batch's undelivered requests
/// are failed with [`ServeError::Solver`] and the worker keeps serving.
pub(crate) fn worker_loop(core: &Core) {
    while let Some(batch) = core.work_q.recv_one() {
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| execute_batch(core, &batch)));
        if let Err(payload) = outcome {
            let err =
                ServeError::Solver(format!("panic in batch execution: {}", panic_msg(&*payload)));
            for item in &batch.items {
                // complete() releases the admission slot exactly once; skip
                // requests the panicking pass already delivered.
                if !item.slot.is_fulfilled() {
                    core.metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    core.complete(&item.slot, item.cost, Err(err.clone()));
                }
            }
        }
    }
}

type SampleOutcome = Result<(Vec<f32>, Option<GradResult>, RequestStats), ServeError>;

/// Run one formed batch and deliver every member's response.
pub(crate) fn execute_batch(core: &Core, batch: &FormedBatch) {
    let started = core.clock.now();
    let n = batch.items.len();
    core.metrics.record_batch(n);

    let Some(f) = core.registry.get(&batch.key.dynamics).cloned() else {
        // submit() validates ids, so this only guards registry mutation bugs.
        let err = ServeError::UnknownDynamics(batch.key.dynamics.clone());
        for item in &batch.items {
            core.complete(&item.slot, item.cost, Err(err.clone()));
        }
        return;
    };
    let dim = f.dim();
    let first = &batch.items[0].req;
    // tab/opts are key-equal across the batch; the span is per-request. The
    // worker's solves run under the server's checkpoint budget.
    let tab = first.tab;
    let mut opts = first.opts();
    opts.ckpt = CkptPolicy::from_budget(core.cfg.ckpt_budget_bytes);
    let wants_grad = batch.key.wants_grad;

    let mut z0 = Vec::with_capacity(n * dim);
    let mut t0s = Vec::with_capacity(n);
    let mut t1s = Vec::with_capacity(n);
    for item in &batch.items {
        z0.extend_from_slice(&item.req.z0);
        t0s.push(item.req.t0);
        t1s.push(item.req.t1);
    }

    // The whole batched attempt — forward AND backward — is panic-contained
    // like it is error-contained: a dynamics whose `eval` or `vjp` panics on
    // one sample's state sends the batch down the same per-sample fallback
    // an integration error does.
    let batched = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
        || -> anyhow::Result<Vec<SampleOutcome>> {
            let bt = integrate_batch_tspans(&*f, &t0s, &t1s, &z0, tab, &opts)?;
            let grads = wants_grad.then(|| {
                let mut lam = Vec::with_capacity(n * dim);
                for item in &batch.items {
                    lam.extend_from_slice(item.req.grad.as_ref().expect("keyed wants_grad"));
                }
                aca_backward_batch(&*f, tab, &bt, &lam)
            });
            Ok((0..n)
                .map(|i| {
                    let tr = &bt.tracks[i];
                    Ok((
                        bt.last(i).to_vec(),
                        grads.as_ref().map(|g| g[i].clone()),
                        RequestStats {
                            steps: tr.steps(),
                            nfe: tr.nfe,
                            n_rejected: tr.n_rejected,
                            avg_m: tr.avg_m(),
                            checkpoint_bytes: bt.checkpoint_bytes(i),
                            ..Default::default()
                        },
                    ))
                })
                .collect())
        },
    ));
    let outcomes: Vec<SampleOutcome> = match batched {
        Ok(Ok(v)) => v,
        // Per-sample fallback: isolate the poison sample(s) — error or
        // panic — while the healthy ones still get their (bit-identical)
        // scalar results.
        Ok(Err(_)) | Err(_) => batch
            .items
            .iter()
            .map(|item| {
                let one = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || -> SampleOutcome {
                        match integrate(&*f, item.req.t0, item.req.t1, &item.req.z0, tab, &opts) {
                            Ok(traj) => {
                                let grad = wants_grad.then(|| {
                                    aca_backward(&*f, tab, &traj, item.req.grad.as_ref().unwrap())
                                });
                                Ok((
                                    traj.last().expect("non-empty trajectory").to_vec(),
                                    grad,
                                    RequestStats {
                                        steps: traj.len(),
                                        nfe: traj.nfe,
                                        n_rejected: traj.n_rejected,
                                        avg_m: traj.avg_m(),
                                        checkpoint_bytes: traj.checkpoint_bytes(),
                                        ..Default::default()
                                    },
                                ))
                            }
                            Err(e) => Err(ServeError::Solver(e.to_string())),
                        }
                    },
                ));
                one.unwrap_or_else(|p| {
                    Err(ServeError::Solver(format!("panic in solve: {}", panic_msg(&*p))))
                })
            })
            .collect(),
    };

    let service = core.clock.now().saturating_sub(started);
    for (item, outcome) in batch.items.iter().zip(outcomes) {
        let queue_wait = started.saturating_sub(item.submitted);
        match outcome {
            Ok((z_t1, grad, mut stats)) => {
                stats.batch_size = n;
                stats.queue_wait = queue_wait;
                stats.service = service;
                core.metrics.record_request(queue_wait, service, stats.nfe);
                core.complete(&item.slot, item.cost, Ok(SolveResponse { z_t1, grad, stats }));
            }
            Err(e) => {
                core.metrics.failed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                core.complete(&item.slot, item.cost, Err(e));
            }
        }
    }
}
