//! Explicit Runge–Kutta ODE solving substrate (paper Sec 2.3, Algo 1).
//!
//! The solver is generic over [`func::OdeFunc`]: analytic dynamics (van der
//! Pol, three-body, …) for the paper's numerical-error studies, or
//! AOT-compiled neural dynamics executed through PJRT for the learning
//! experiments. Stage arithmetic, the embedded error estimate, and the
//! adaptive step-size controller all live here in Rust — one artifact set per
//! model serves every solver in the paper's Table 2.

pub mod analytic;
pub mod batch;
pub mod controller;
pub mod dense;
pub mod func;
pub mod integrate;
pub mod step;
pub mod tableau;

pub use batch::{
    integrate_batch, integrate_batch_spans, integrate_batch_tspans, BatchTrajectory, SampleStore,
    SampleTrack,
};
pub use controller::{Controller, StepDecision};
pub use func::OdeFunc;
pub use integrate::{integrate, IntegrateOpts, Trajectory, TrialRecord};
pub use step::{rk_step, StepOut, StepScratch};
pub use tableau::Tableau;
