//! Batched adaptive integration: advance `B` independent solves of the same
//! dynamics in lock-step rounds, with **per-sample** step-size control and
//! **per-sample integration spans** (starts *and* endpoints).
//!
//! Layout: current states, stage derivatives and stage inputs live in flat
//! row-major `[B × D]` buffers; accepted checkpoints land in one shared
//! arena ([`BatchTrajectory`]-internal) instead of one `Vec` allocation per
//! accepted step per sample. Each sample keeps its own
//! `(ts, hs, errs, trials)` track plus exact `nfe` / `n_rejected`
//! bookkeeping, so the per-sample cost meters of paper Table 1 are identical
//! to what `B` separate [`integrate`](crate::ode::integrate) calls report.
//!
//! State storage follows the [`CkptPolicy`] of the solve (see
//! [`crate::ckpt`]): each track records sparse anchors into the shared
//! arena, thinning **live** under a byte budget; thinned slots return to a
//! free-list and are recycled, so the arena's physical growth is bounded by
//! the per-sample budgets, not by `N_t`. Dropped states are regenerated
//! bit-exactly by segment replay ([`crate::ckpt::SegmentCache`]); `Dense`
//! (the default) keeps every state, bit-for-bit the previous behavior.
//!
//! Equivalence guarantee: every per-sample arithmetic operation (stage
//! combination, embedded error norm, controller decision, FSAL/stage-0
//! reuse) mirrors the scalar loop exactly, and the default
//! [`OdeFunc::eval_batch`] evaluates samples one by one — so per-sample
//! results are **bit-identical** to the scalar path on both the fixed-step
//! and the adaptive path (asserted by `rust/tests/proptests.rs`). What the
//! batch engine buys today is amortized allocation and a single stage sweep
//! over all live samples; what it enables next is an `eval_batch` override
//! that dispatches one batched HLO call instead of `B` host round trips.
//!
//! Spans are fully per-sample: [`integrate_batch_tspans`] takes
//! `t0s: &[f64]` and `t1s: &[f64]` and integrates sample `i` over
//! `[t0s[i], t1s[i]]` — each sample derives its own direction, endpoint
//! epsilon and final-step clamp from its own span (exactly what the scalar
//! loop derives from *its* span, so bit-equality holds span by span) and
//! retires through the active set at its own `t1`. Nothing in the
//! checkpoint math couples co-batched samples, so mixed starts, mixed
//! endpoints — and even mixed directions — share stage sweeps for the
//! rounds they are jointly live. [`integrate_batch_spans`] (shared start)
//! and [`integrate_batch`] (shared span) are convenience wrappers.

use super::controller::Controller;
use super::func::OdeFunc;
use super::integrate::{IntegrateOpts, Trajectory, TrialRecord};
use super::tableau::Tableau;
use crate::ckpt::{AnchorSource, CheckpointStore, CkptPolicy, Thinner};
use crate::tensor;
use anyhow::{bail, ensure, Result};

/// Per-sample record of one batched integration: the accepted
/// discretization points (`ts`), the step sizes exactly as stepped (`hs`),
/// per-step error norms, optional rejected trials, and cost bookkeeping.
/// Checkpoint states live in the shared arena of the owning
/// [`BatchTrajectory`]; the track holds the sparse anchor bookkeeping
/// (`anchor_idx[p]`'s state sits in arena slot `anchor_slot[p]`).
#[derive(Debug, Clone, Default)]
pub struct SampleTrack {
    /// Accepted times `t_0 .. t_{N_t}` (monotone, endpoints exact).
    pub ts: Vec<f64>,
    /// Accepted step sizes, exactly as used by the stepper.
    pub hs: Vec<f64>,
    /// Error norm of each accepted step.
    pub errs: Vec<f64>,
    /// Rejected trials per accepted step (when recorded).
    pub trials: Vec<Vec<TrialRecord>>,
    /// Stored anchor state-indices, ascending (always contains 0 and the
    /// most recent state).
    pub anchor_idx: Vec<usize>,
    /// Arena slot of each anchor (parallel to `anchor_idx`).
    pub anchor_slot: Vec<usize>,
    /// Thinning state machine for this track's policy.
    thin: Thinner,
    /// Policy the track was recorded under.
    policy: CkptPolicy,
    /// High-water mark of stored state bytes (the budget must bound this).
    peak_state_bytes: usize,
    /// `f` evaluations spent on this sample.
    pub nfe: usize,
    /// Rejected step attempts for this sample.
    pub n_rejected: usize,
}

impl SampleTrack {
    /// Number of accepted steps `N_t`.
    pub fn steps(&self) -> usize {
        self.ts.len().saturating_sub(1)
    }

    /// Average inner iterations `m` (trials per accepted step, counting the
    /// accepted attempt) — per-sample exact.
    pub fn avg_m(&self) -> f64 {
        if self.steps() == 0 {
            return 0.0;
        }
        (self.steps() + self.n_rejected) as f64 / self.steps() as f64
    }
}

/// Record of one batched forward integration over `B` samples.
#[derive(Debug, Clone, Default)]
pub struct BatchTrajectory {
    /// Number of samples `B`.
    pub batch: usize,
    /// Per-sample state dimension `D`.
    pub dim: usize,
    /// Shared checkpoint arena: slot `s` is `zbuf[s*dim .. (s+1)*dim]`.
    zbuf: Vec<f32>,
    /// Recycled arena slots of thinned anchors — physical arena growth is
    /// bounded by the live anchor counts, not by total accepted steps.
    free: Vec<usize>,
    drop_scratch: Vec<usize>,
    /// Per-sample checkpoint tracks.
    pub tracks: Vec<SampleTrack>,
}

/// [`AnchorSource`] view of one sample's anchors inside the shared arena —
/// what a [`crate::ckpt::SegmentCache`] replays from.
#[derive(Clone, Copy)]
pub struct SampleStore<'a> {
    bt: &'a BatchTrajectory,
    i: usize,
}

impl<'a> AnchorSource<'a> for SampleStore<'a> {
    fn dim(self) -> usize {
        self.bt.dim
    }

    fn stored(self, k: usize) -> Option<&'a [f32]> {
        let tr = &self.bt.tracks[self.i];
        let p = crate::ckpt::anchor_pos(tr.policy, &tr.anchor_idx, k)?;
        let s = tr.anchor_slot[p];
        Some(&self.bt.zbuf[s * self.bt.dim..(s + 1) * self.bt.dim])
    }

    fn anchor_at_or_before(self, k: usize) -> usize {
        crate::ckpt::anchor_floor(&self.bt.tracks[self.i].anchor_idx, k)
    }
}

impl BatchTrajectory {
    /// Checkpoint `k` of sample `i` if it is currently stored (`None` means
    /// the policy thinned it — replay it through a
    /// [`crate::ckpt::SegmentCache`] over [`Self::sample_store`]).
    pub fn stored(&self, i: usize, k: usize) -> Option<&[f32]> {
        SampleStore { bt: self, i }.stored(k)
    }

    /// Checkpoint `k` of sample `i`. Panics if the state was thinned;
    /// dense-store callers (benches, tests) keep the direct path.
    pub fn z(&self, i: usize, k: usize) -> &[f32] {
        self.stored(i, k).expect("checkpoint thinned; replay via SegmentCache/sample_store")
    }

    /// Anchor view of sample `i` for segment replay.
    pub fn sample_store(&self, i: usize) -> SampleStore<'_> {
        SampleStore { bt: self, i }
    }

    /// Final state `z(T)` of sample `i` — the tail anchor, stored under
    /// every policy (every track holds at least its initial state).
    pub fn last(&self, i: usize) -> &[f32] {
        let tr = &self.tracks[i];
        let s = *tr.anchor_slot.last().expect("track has no states");
        &self.zbuf[s * self.dim..(s + 1) * self.dim]
    }

    /// Accepted steps `N_t` of sample `i`.
    pub fn steps(&self, i: usize) -> usize {
        self.tracks[i].steps()
    }

    /// Bytes held by sample `i`'s checkpoint store — full accounting
    /// (*stored* state anchors, times, step sizes, error norms, and recorded
    /// trials), matching [`Trajectory::checkpoint_bytes`].
    pub fn checkpoint_bytes(&self, i: usize) -> usize {
        use std::mem::size_of;
        let tr = &self.tracks[i];
        tr.anchor_idx.len() * self.dim * size_of::<f32>()
            + tr.ts.len() * size_of::<f64>()
            + tr.hs.len() * size_of::<f64>()
            + tr.errs.len() * size_of::<f64>()
            + tr.trials.iter().map(|t| t.len() * size_of::<TrialRecord>()).sum::<usize>()
    }

    /// Total checkpoint bytes across the batch.
    pub fn checkpoint_bytes_total(&self) -> usize {
        (0..self.batch).map(|i| self.checkpoint_bytes(i)).sum()
    }

    /// Bytes currently held by sample `i`'s *stored states* (the quantity a
    /// checkpoint budget bounds; excludes the tiny spine).
    pub fn state_bytes(&self, i: usize) -> usize {
        self.tracks[i].anchor_idx.len() * self.dim * std::mem::size_of::<f32>()
    }

    /// High-water mark of [`Self::state_bytes`] over the solve — a budget
    /// must bound this *mid-flight*, not just at the end.
    pub fn peak_state_bytes(&self, i: usize) -> usize {
        self.tracks[i].peak_state_bytes
    }

    /// Total `f` evaluations across the batch.
    pub fn nfe_total(&self) -> usize {
        self.tracks.iter().map(|t| t.nfe).sum()
    }

    /// Record state `idx` of sample `i`: thin per the track's policy, then
    /// store into a recycled (or fresh) arena slot. The budget invariant
    /// holds before and after every call.
    fn record_state(&mut self, i: usize, idx: usize, z: &[f32]) {
        let dim = self.dim;
        {
            let tr = &mut self.tracks[i];
            tr.thin.plan_push(&tr.anchor_idx, &mut self.drop_scratch);
        }
        if !self.drop_scratch.is_empty() {
            // One shared compaction sweep: shift the surviving anchors left
            // and return dropped slots to the free-list.
            let tr = &mut self.tracks[i];
            let (idx, slots, free) = (&mut tr.anchor_idx, &mut tr.anchor_slot, &mut self.free);
            let w = crate::ckpt::compact_drops(idx.len(), &self.drop_scratch, |r, dst| match dst {
                None => free.push(slots[r]),
                Some(w) => {
                    idx[w] = idx[r];
                    slots[w] = slots[r];
                }
            });
            idx.truncate(w);
            slots.truncate(w);
            self.drop_scratch.clear();
        }
        let slot = match self.free.pop() {
            Some(s) => {
                self.zbuf[s * dim..(s + 1) * dim].copy_from_slice(z);
                s
            }
            None => {
                let s = self.zbuf.len() / dim;
                self.zbuf.extend_from_slice(z);
                s
            }
        };
        let tr = &mut self.tracks[i];
        tr.anchor_idx.push(idx);
        tr.anchor_slot.push(slot);
        let bytes = tr.anchor_idx.len() * dim * std::mem::size_of::<f32>();
        tr.peak_state_bytes = tr.peak_state_bytes.max(bytes);
    }

    /// Materialize sample `i` as a standalone [`Trajectory`] (copies the
    /// stored anchors out of the arena, preserving the thinning state) —
    /// the interop path for per-sample consumers such as the naive /
    /// continuous-adjoint backward passes.
    pub fn to_trajectory(&self, i: usize) -> Trajectory {
        let tr = &self.tracks[i];
        let mut buf = Vec::with_capacity(tr.anchor_slot.len() * self.dim);
        for &s in &tr.anchor_slot {
            buf.extend_from_slice(&self.zbuf[s * self.dim..(s + 1) * self.dim]);
        }
        Trajectory {
            ts: tr.ts.clone(),
            store: CheckpointStore::from_parts(
                self.dim,
                tr.policy,
                tr.thin.clone(),
                tr.ts.len(),
                tr.anchor_idx.clone(),
                buf,
                tr.peak_state_bytes,
            ),
            hs: tr.hs.clone(),
            errs: tr.errs.clone(),
            trials: tr.trials.clone(),
            nfe: tr.nfe,
            n_rejected: tr.n_rejected,
        }
    }
}

/// Integrate `B` independent copies of `dz/dt = f(t, z)` from `(t0, z0_i)`
/// to a shared `t1` (paper Algo 1, vectorized over samples) — the
/// shared-span convenience wrapper over [`integrate_batch_tspans`].
///
/// `z0` is row-major `[B × D]` with `D = f.dim()`; `B` is inferred. Each
/// sample runs the exact scalar control flow (per-sample `h`, retries,
/// FSAL/stage-0 reuse, trial recording); stage derivatives for all samples
/// still in flight are evaluated with one [`OdeFunc::eval_batch`] call per
/// stage per round.
pub fn integrate_batch<F: OdeFunc + ?Sized>(
    f: &F,
    t0: f64,
    t1: f64,
    z0: &[f32],
    tab: &Tableau,
    opts: &IntegrateOpts,
) -> Result<BatchTrajectory> {
    let dim = f.dim();
    ensure!(dim > 0, "dynamics must have a positive dimension");
    let b = z0.len() / dim.max(1);
    integrate_batch_tspans(f, &vec![t0; b], &vec![t1; b], z0, tab, opts)
}

/// Integrate `B` independent copies of `dz/dt = f(t, z)`, sample `i` over
/// `[t0, t1s[i]]` — the shared-start wrapper over
/// [`integrate_batch_tspans`].
pub fn integrate_batch_spans<F: OdeFunc + ?Sized>(
    f: &F,
    t0: f64,
    t1s: &[f64],
    z0: &[f32],
    tab: &Tableau,
    opts: &IntegrateOpts,
) -> Result<BatchTrajectory> {
    integrate_batch_tspans(f, &vec![t0; t1s.len()], t1s, z0, tab, opts)
}

/// Integrate `B` independent copies of `dz/dt = f(t, z)`, sample `i` over
/// its **own** span `[t0s[i], t1s[i]]`.
///
/// Per-sample span geometry: direction, endpoint epsilon, final-step clamp
/// and the initial-step bound all derive from `(t0s[i], t1s[i])` exactly
/// the way the scalar [`integrate`](super::integrate) derives them from its
/// span, so every sample's grid, checkpoints and meters are bit-identical
/// to a scalar solve over the same span. A sample whose span is zero
/// (`t1s[i] == t0s[i]`) never enters the round loop and costs zero
/// evaluations — its track is just the initial checkpoint, matching the
/// scalar zero-span early return. Samples retire from the shared stage
/// sweeps as they land on their own `t1`, via the same active-set
/// machinery that already retires fast samples under a shared span. No new
/// engine machinery is needed for per-sample starts: the `t0` that was a
/// scalar is simply per-sample bookkeeping (which is what lets the serve
/// layer drop `t0` from its batch key).
pub fn integrate_batch_tspans<F: OdeFunc + ?Sized>(
    f: &F,
    t0s: &[f64],
    t1s: &[f64],
    z0: &[f32],
    tab: &Tableau,
    opts: &IntegrateOpts,
) -> Result<BatchTrajectory> {
    let dim = f.dim();
    ensure!(dim > 0, "dynamics must have a positive dimension");
    ensure!(
        !z0.is_empty() && z0.len() % dim == 0,
        "batch state length {} is not a positive multiple of dim {}",
        z0.len(),
        dim
    );
    let b = z0.len() / dim;
    ensure!(
        t1s.len() == b,
        "t1s length {} != batch size {b} (z0 holds {b} samples of dim {dim})",
        t1s.len()
    );
    ensure!(t0s.len() == b, "t0s length {} != batch size {b}", t0s.len());
    let s = tab.stages;

    let mut out = BatchTrajectory {
        batch: b,
        dim,
        zbuf: Vec::with_capacity(b * dim),
        free: Vec::new(),
        drop_scratch: Vec::new(),
        tracks: (0..b)
            .map(|i| SampleTrack {
                ts: vec![t0s[i]],
                thin: Thinner::new(opts.ckpt, dim),
                policy: opts.ckpt,
                ..Default::default()
            })
            .collect(),
    };
    for i in 0..b {
        out.record_state(i, 0, &z0[i * dim..(i + 1) * dim]);
    }

    // Per-sample span geometry — exactly what the scalar loop computes from
    // its single span, evaluated per sample.
    let dir: Vec<f64> = t1s.iter().zip(t0s).map(|(t1, t0)| (t1 - t0).signum()).collect();
    let span: Vec<f64> = t1s.iter().zip(t0s).map(|(t1, t0)| (t1 - t0).abs()).collect();
    let eps_t: Vec<f64> = span.iter().map(|sp| 1e-12 * sp.max(1.0)).collect();
    let fixed = opts.fixed_h.is_some() || !tab.adaptive();
    let ctrl = opts.controller.unwrap_or_else(|| Controller::for_tableau(tab));

    // Per-sample mutable state (indexed by sample id).
    let mut t = t0s.to_vec();
    let mut z = z0.to_vec();
    let mut z_next = vec![0.0f32; b * dim];
    let mut k0 = vec![0.0f32; b * dim];
    let mut k0_valid = vec![false; b];
    let mut h = vec![0.0f64; b];
    let mut attempts = vec![0usize; b];
    let mut trial_buf: Vec<Vec<TrialRecord>> = vec![Vec::new(); b];

    for i in 0..b {
        if t1s[i] == t0s[i] {
            continue; // zero-span: scalar early return — no h init, no nfe
        }
        h[i] = if fixed {
            opts.fixed_h.map(|h| h.abs()).unwrap_or(span[i] / 100.0) * dir[i]
        } else {
            match opts.h0 {
                Some(h0) => h0.abs().min(span[i]) * dir[i],
                None => {
                    let zi = &z[i * dim..(i + 1) * dim];
                    let hi = ctrl.initial_step(f, t0s[i], zi, dir[i], opts.atol, opts.rtol);
                    out.tracks[i].nfe += 1;
                    hi.abs().min(span[i]) * dir[i]
                }
            }
        };
        assert!(h[i].abs() > 0.0, "initial step size must be nonzero");
    }

    // Round scratch, packed in active order (slot `a` of a round buffer is
    // the `a`-th live sample). No allocation inside the loop. A span below
    // its eps_t never enters the loop — same as the scalar path.
    let mut active: Vec<usize> = (0..b).filter(|&i| span[i] > eps_t[i]).collect();
    let mut h_try = vec![0.0f64; b];
    let mut ks: Vec<Vec<f32>> = (0..s).map(|_| vec![0.0f32; b * dim]).collect();
    let mut us = vec![0.0f32; b * dim];
    let mut dz_scratch = vec![0.0f32; b * dim];
    let mut ts_stage = vec![0.0f64; b];
    let mut ev = vec![0.0f32; dim];
    let mut need_k0: Vec<usize> = Vec::with_capacity(b);
    let mut next_active: Vec<usize> = Vec::with_capacity(b);

    // nodal-lint: hot
    while !active.is_empty() {
        let na = active.len();
        crate::obs::hot_count(crate::obs::CTR_FWD_ROUNDS, 1);

        // ---- step setup: per-sample trial size, clamped onto its own t1 ----
        for (a, &i) in active.iter().enumerate() {
            attempts[i] += 1;
            if attempts[i] > opts.max_steps {
                bail!(
                    "sample {i}: max_steps ({}) exceeded at t={} (h={}); solver may be stiff \
                     at these tolerances",
                    opts.max_steps,
                    t[i],
                    h[i]
                );
            }
            let ht = if (t[i] + h[i] - t1s[i]) * dir[i] > 0.0 { t1s[i] - t[i] } else { h[i] };
            if ht.abs() < 1e-14 * span[i].max(1.0) {
                bail!("sample {i}: step size underflow at t={} (h={ht})", t[i]);
            }
            h_try[a] = ht;
        }

        // ---- stage 0: k_0 = f(t, z); reused across retries and via FSAL ----
        need_k0.clear();
        for (a, &i) in active.iter().enumerate() {
            if k0_valid[i] {
                ks[0][a * dim..(a + 1) * dim].copy_from_slice(&k0[i * dim..(i + 1) * dim]);
            } else {
                need_k0.push(a);
            }
        }
        if !need_k0.is_empty() {
            for (p, &a) in need_k0.iter().enumerate() {
                let i = active[a];
                us[p * dim..(p + 1) * dim].copy_from_slice(&z[i * dim..(i + 1) * dim]);
                ts_stage[p] = t[i];
            }
            let np = need_k0.len();
            crate::obs::hot_count(crate::obs::CTR_FWD_SWEEPS, 1);
            f.eval_batch(&ts_stage[..np], &us[..np * dim], &mut dz_scratch[..np * dim]);
            for (p, &a) in need_k0.iter().enumerate() {
                ks[0][a * dim..(a + 1) * dim]
                    .copy_from_slice(&dz_scratch[p * dim..(p + 1) * dim]);
                out.tracks[active[a]].nfe += 1;
            }
        }

        // ---- stages 1..s: one batched eval per stage over live samples ----
        for j in 1..s {
            for (a, &i) in active.iter().enumerate() {
                let u = &mut us[a * dim..(a + 1) * dim];
                u.copy_from_slice(&z[i * dim..(i + 1) * dim]);
                for (l, aa) in tab.a[j].iter().enumerate() {
                    if *aa != 0.0 {
                        tensor::axpy((h_try[a] * *aa) as f32, &ks[l][a * dim..(a + 1) * dim], u);
                    }
                }
                ts_stage[a] = t[i] + tab.c[j] * h_try[a];
            }
            crate::obs::hot_count(crate::obs::CTR_FWD_SWEEPS, 1);
            f.eval_batch(&ts_stage[..na], &us[..na * dim], &mut ks[j][..na * dim]);
            for &i in &active {
                out.tracks[i].nfe += 1;
            }
        }

        // ---- per-sample solution, error estimate, accept/reject ----
        next_active.clear();
        for (a, &i) in active.iter().enumerate() {
            let (a0, a1, hta) = (a * dim, (a + 1) * dim, h_try[a]);
            // Propagating solution: z_next = z + h Σ b_j k_j (same axpy
            // sequence as `tensor::combine` / `rk_step`).
            {
                let zn = &mut z_next[i * dim..(i + 1) * dim];
                zn.copy_from_slice(&z[i * dim..(i + 1) * dim]);
                for (c, ksj) in tab.b.iter().zip(&ks) {
                    if *c != 0.0 {
                        tensor::axpy((hta * *c) as f32, &ksj[a0..a1], zn);
                    }
                }
            }
            // Embedded error estimate (scale from the step's start state,
            // matching `rk_step`).
            let en = if let Some(e) = tab.b_err {
                ev.fill(0.0);
                for (c, ksj) in e.iter().zip(&ks) {
                    if *c != 0.0 {
                        tensor::axpy((hta * *c) as f32, &ksj[a0..a1], &mut ev);
                    }
                }
                let zi = &z[i * dim..(i + 1) * dim];
                tensor::wrms_norm(&ev, zi, zi, opts.atol, opts.rtol)
            } else {
                0.0
            };

            if !tensor::all_finite(&z_next[i * dim..(i + 1) * dim]) {
                if fixed {
                    bail!("sample {i}: non-finite state in fixed-step integration at t={}", t[i]);
                }
                out.tracks[i].n_rejected += 1;
                if opts.record_trials {
                    trial_buf[i].push(TrialRecord { h: hta, err: f64::INFINITY });
                }
                h[i] = hta * 0.5;
                k0[i * dim..(i + 1) * dim].copy_from_slice(&ks[0][a0..a1]);
                k0_valid[i] = true;
                next_active.push(i);
                continue;
            }

            let accepted = fixed || en <= 1.0;
            if !accepted {
                let dec = ctrl.decide(hta, en, 0.0);
                out.tracks[i].n_rejected += 1;
                if opts.record_trials {
                    trial_buf[i].push(TrialRecord { h: hta, err: en });
                }
                h[i] = dec.h_next;
                k0[i * dim..(i + 1) * dim].copy_from_slice(&ks[0][a0..a1]);
                k0_valid[i] = true;
                next_active.push(i);
                continue;
            }

            // Accept: advance state, record the checkpoint into the arena
            // (thinning live per the track's policy).
            let t_new = if hta == t1s[i] - t[i] { t1s[i] } else { t[i] + hta };
            z[i * dim..(i + 1) * dim].copy_from_slice(&z_next[i * dim..(i + 1) * dim]);
            t[i] = t_new;
            let idx = out.tracks[i].ts.len();
            out.record_state(i, idx, &z[i * dim..(i + 1) * dim]);
            let track = &mut out.tracks[i];
            track.ts.push(t_new);
            track.hs.push(hta);
            track.errs.push(en);
            if opts.record_trials {
                track.trials.push(std::mem::take(&mut trial_buf[i]));
            }
            if !fixed {
                h[i] = ctrl.decide(hta, en, 0.0).h_next;
            }
            if tab.fsal {
                k0[i * dim..(i + 1) * dim].copy_from_slice(&ks[s - 1][a0..a1]);
                k0_valid[i] = true;
            } else {
                k0_valid[i] = false;
            }
            if (t1s[i] - t[i]) * dir[i] > eps_t[i] {
                next_active.push(i);
            }
        }
        std::mem::swap(&mut active, &mut next_active);
    }

    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::func::CountingFunc;
    use crate::ode::{integrate, tableau};

    fn scalar_ref(
        f: &impl OdeFunc,
        t1: f64,
        z0: &[f32],
        dim: usize,
        tab: &Tableau,
        opts: &IntegrateOpts,
    ) -> Vec<Trajectory> {
        (0..z0.len() / dim)
            .map(|i| integrate(f, 0.0, t1, &z0[i * dim..(i + 1) * dim], tab, opts).unwrap())
            .collect()
    }

    #[test]
    fn b1_fixed_step_bit_exact() {
        let f = Linear::new(-1.0, 4);
        let z0 = [1.0f32, 2.0, -1.0, 0.5];
        let opts = IntegrateOpts::fixed(0.1);
        let tab = tableau::rk4();
        let bt = integrate_batch(&f, 0.0, 1.0, &z0, tab, &opts).unwrap();
        let traj = integrate(&f, 0.0, 1.0, &z0, tab, &opts).unwrap();
        assert_eq!(bt.batch, 1);
        assert_eq!(bt.steps(0), traj.len());
        assert_eq!(bt.tracks[0].ts, traj.ts);
        assert_eq!(bt.tracks[0].hs, traj.hs);
        for k in 0..=traj.len() {
            assert_eq!(bt.z(0, k), traj.z(k).unwrap(), "checkpoint {k}");
        }
        assert_eq!(bt.tracks[0].nfe, traj.nfe);
        assert_eq!(bt.checkpoint_bytes(0), traj.checkpoint_bytes());
    }

    #[test]
    fn adaptive_batch_matches_scalar_bitwise() {
        let f = VanDerPol::new(0.6);
        let z0 = [2.0f32, 0.0, -1.0, 0.5, 0.3, -0.8];
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let tab = tableau::dopri5();
        let bt = integrate_batch(&f, 0.0, 3.0, &z0, tab, &opts).unwrap();
        let refs = scalar_ref(&f, 3.0, &z0, 2, tab, &opts);
        for (i, traj) in refs.iter().enumerate() {
            assert_eq!(bt.tracks[i].ts, traj.ts, "sample {i} grid");
            assert_eq!(bt.tracks[i].hs, traj.hs, "sample {i} steps");
            assert_eq!(bt.last(i), traj.last().unwrap(), "sample {i} endpoint");
            assert_eq!(bt.tracks[i].nfe, traj.nfe, "sample {i} nfe");
            assert_eq!(bt.tracks[i].n_rejected, traj.n_rejected);
        }
    }

    #[test]
    fn trial_recording_per_sample() {
        let f = VanDerPol::new(5.0);
        let z0 = [2.0f32, 0.0, 1.0, -1.0];
        let mut opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        opts.record_trials = true;
        opts.h0 = Some(1.0);
        let bt = integrate_batch(&f, 0.0, 2.0, &z0, tableau::dopri5(), &opts).unwrap();
        for i in 0..2 {
            let tr = &bt.tracks[i];
            assert_eq!(tr.trials.len(), tr.steps());
            let total: usize = tr.trials.iter().map(|t| t.len()).sum();
            assert_eq!(total, tr.n_rejected, "sample {i}");
            assert!(tr.n_rejected > 0, "h0=1 must reject at least once");
        }
    }

    #[test]
    fn zero_span_returns_initial_states() {
        let f = Linear::new(1.0, 2);
        let z0 = [3.0f32, 4.0, -1.0, 2.0];
        let bt =
            integrate_batch(&f, 1.0, 1.0, &z0, tableau::dopri5(), &IntegrateOpts::default())
                .unwrap();
        assert_eq!(bt.steps(0), 0);
        assert_eq!(bt.last(0), &[3.0, 4.0]);
        assert_eq!(bt.last(1), &[-1.0, 2.0]);
    }

    #[test]
    fn samples_can_finish_at_different_rounds() {
        // Different initial conditions => different step counts; the batch
        // must keep advancing the slower samples after the fast ones finish.
        let f = VanDerPol::new(1.0);
        let z0 = [0.01f32, 0.0, 2.0, 2.0];
        let opts = IntegrateOpts::with_tol(1e-7, 1e-9);
        let bt = integrate_batch(&f, 0.0, 5.0, &z0, tableau::rk23(), &opts).unwrap();
        assert_ne!(bt.steps(0), bt.steps(1), "workloads should differ");
        for i in 0..2 {
            assert_eq!(*bt.tracks[i].ts.last().unwrap(), 5.0, "sample {i} endpoint exact");
        }
    }

    #[test]
    fn nfe_matches_scalar_accounting() {
        let f = CountingFunc::new(Linear::new(-1.0, 1));
        let z0 = [1.0f32, 2.0, 3.0];
        let traj =
            integrate_batch(&f, 0.0, 1.0, &z0, tableau::rk4(), &IntegrateOpts::fixed(0.1))
                .unwrap();
        // RK4 = 4 evals × 10 steps × 3 samples.
        assert_eq!(f.evals(), 120);
        assert_eq!(traj.nfe_total(), f.evals());
        for i in 0..3 {
            assert_eq!(traj.tracks[i].nfe, 40);
        }
    }

    #[test]
    fn mixed_spans_match_scalar_bitwise() {
        // Each sample integrates to its own t1; grids, checkpoints and
        // meters must be bit-identical to scalar solves over those spans —
        // on both the adaptive and the fixed-step path.
        let f = VanDerPol::new(0.6);
        let z0 = [2.0f32, 0.0, -1.0, 0.5, 0.3, -0.8];
        let t1s = [1.0f64, 2.5, 0.4];
        for opts in [IntegrateOpts::with_tol(1e-6, 1e-8), IntegrateOpts::fixed(0.05)] {
            let tab = if opts.fixed_h.is_some() { tableau::rk4() } else { tableau::dopri5() };
            let bt = integrate_batch_spans(&f, 0.0, &t1s, &z0, tab, &opts).unwrap();
            for (i, &t1) in t1s.iter().enumerate() {
                let traj = integrate(&f, 0.0, t1, &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
                assert_eq!(bt.tracks[i].ts, traj.ts, "sample {i} grid");
                assert_eq!(bt.tracks[i].hs, traj.hs, "sample {i} steps");
                assert_eq!(bt.last(i), traj.last().unwrap(), "sample {i} endpoint");
                assert_eq!(*bt.tracks[i].ts.last().unwrap(), t1, "sample {i} lands on its t1");
                assert_eq!(bt.tracks[i].nfe, traj.nfe, "sample {i} nfe");
                assert_eq!(bt.tracks[i].n_rejected, traj.n_rejected, "sample {i} rejected");
                assert_eq!(bt.checkpoint_bytes(i), traj.checkpoint_bytes(), "sample {i} bytes");
            }
        }
    }

    #[test]
    fn mixed_starts_match_scalar_bitwise() {
        // Fully per-sample spans: each sample has its own `t0` AND `t1`.
        // Grids, checkpoints and meters must be bit-identical to scalar
        // solves over the same `[t0s[i], t1s[i]]` — the bookkeeping that
        // lets serve drop `t0` from its batch key.
        let f = VanDerPol::new(0.6);
        let z0 = [2.0f32, 0.0, -1.0, 0.5, 0.3, -0.8];
        let t0s = [0.0f64, 0.5, -1.0];
        let t1s = [1.0f64, 2.5, 0.4];
        for opts in [IntegrateOpts::with_tol(1e-6, 1e-8), IntegrateOpts::fixed(0.05)] {
            let tab = if opts.fixed_h.is_some() { tableau::rk4() } else { tableau::dopri5() };
            let bt = integrate_batch_tspans(&f, &t0s, &t1s, &z0, tab, &opts).unwrap();
            for i in 0..3 {
                let traj =
                    integrate(&f, t0s[i], t1s[i], &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
                assert_eq!(bt.tracks[i].ts, traj.ts, "sample {i} grid");
                assert_eq!(bt.tracks[i].hs, traj.hs, "sample {i} steps");
                assert_eq!(bt.last(i), traj.last().unwrap(), "sample {i} endpoint");
                assert_eq!(bt.tracks[i].ts[0], t0s[i], "sample {i} starts at its t0");
                assert_eq!(bt.tracks[i].nfe, traj.nfe, "sample {i} nfe");
                assert_eq!(bt.tracks[i].n_rejected, traj.n_rejected, "sample {i} rejected");
            }
        }
    }

    #[test]
    fn mixed_directions_match_scalar_bitwise() {
        // Per-sample spans make direction per-sample too: a forward and a
        // backward solve can share a batch (serve keys still separate them,
        // but the engine itself must not care).
        let f = Linear::new(-0.4, 2);
        let z0 = [1.0f32, -0.5, 0.8, 0.2];
        let t1s = [1.5f64, -1.0];
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let tab = tableau::dopri5();
        let bt = integrate_batch_spans(&f, 0.0, &t1s, &z0, tab, &opts).unwrap();
        for (i, &t1) in t1s.iter().enumerate() {
            let traj = integrate(&f, 0.0, t1, &z0[i * 2..(i + 1) * 2], tab, &opts).unwrap();
            assert_eq!(bt.tracks[i].ts, traj.ts, "sample {i} grid");
            assert_eq!(bt.last(i), traj.last().unwrap(), "sample {i} endpoint");
            assert_eq!(bt.tracks[i].nfe, traj.nfe, "sample {i} nfe");
        }
    }

    #[test]
    fn zero_span_sample_rides_along_for_free() {
        // One sample with t1 == t0 co-batched with live ones: it must report
        // its initial state, zero steps and zero nfe (the scalar zero-span
        // early return), without perturbing its neighbors.
        let f = CountingFunc::new(VanDerPol::new(0.5));
        let z0 = [2.0f32, 0.0, -1.0, 0.5];
        let t1s = [0.0f64, 2.0];
        let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        let bt = integrate_batch_spans(&f, 0.0, &t1s, &z0, tableau::dopri5(), &opts).unwrap();
        assert_eq!(bt.steps(0), 0);
        assert_eq!(bt.last(0), &[2.0, 0.0]);
        assert_eq!(bt.tracks[0].nfe, 0, "zero-span sample must cost nothing");
        let traj = integrate(&f.inner, 0.0, 2.0, &z0[2..4], tableau::dopri5(), &opts).unwrap();
        assert_eq!(bt.last(1), traj.last().unwrap(), "live neighbor unperturbed");
        assert_eq!(bt.tracks[1].nfe, traj.nfe);
        assert_eq!(f.evals(), traj.nfe, "batch spent exactly the live sample's evals");
    }

    #[test]
    fn t1s_length_mismatch_errors() {
        let f = Linear::new(-1.0, 2);
        let err = integrate_batch_spans(
            &f,
            0.0,
            &[1.0],
            &[1.0, 2.0, 3.0, 4.0],
            tableau::rk4(),
            &IntegrateOpts::fixed(0.1),
        )
        .unwrap_err();
        assert!(err.to_string().contains("t1s length"), "{err}");
    }

    #[test]
    fn max_steps_names_the_offending_sample() {
        let f = Linear::new(1.0, 1);
        let mut opts = IntegrateOpts::with_tol(1e-12, 1e-14);
        opts.max_steps = 3;
        let err = integrate_batch(&f, 0.0, 100.0, &[1.0, 1.0], tableau::heun_euler(), &opts)
            .unwrap_err();
        assert!(err.to_string().contains("max_steps"), "{err}");
    }

    #[test]
    fn to_trajectory_round_trips() {
        let f = VanDerPol::new(0.3);
        let z0 = [1.5f32, -0.5, 0.5, 1.0];
        let opts = IntegrateOpts::with_tol(1e-5, 1e-7);
        let bt = integrate_batch(&f, 0.0, 2.0, &z0, tableau::dopri5(), &opts).unwrap();
        for i in 0..2 {
            let tr = bt.to_trajectory(i);
            let direct = integrate(&f, 0.0, 2.0, &z0[i * 2..(i + 1) * 2], tableau::dopri5(), &opts)
                .unwrap();
            assert_eq!(tr.ts, direct.ts);
            for k in 0..tr.store.len() {
                assert_eq!(tr.z(k).unwrap(), direct.z(k).unwrap(), "sample {i} state {k}");
            }
            assert_eq!(tr.hs, direct.hs);
            assert_eq!(tr.checkpoint_bytes(), direct.checkpoint_bytes());
        }
    }

    #[test]
    fn budgeted_batch_thins_live_and_recycles_slots() {
        // A budgeted batched solve must (a) hold each sample's budget at
        // every accepted step, (b) keep grids and finals bit-identical to
        // the dense solve, and (c) keep the shared arena's physical size
        // bounded by the budgets (free-list recycling) instead of N_t.
        let f = VanDerPol::new(0.6);
        let z0 = [2.0f32, 0.0, -1.0, 0.5];
        let opts_dense = IntegrateOpts::fixed(0.01);
        let tab = tableau::rk4();
        let dense = integrate_batch(&f, 0.0, 2.0, &z0, tab, &opts_dense).unwrap();
        let budget = dense.state_bytes(0) / 8;
        let opts_thin =
            IntegrateOpts { ckpt: CkptPolicy::Budgeted(budget), ..IntegrateOpts::fixed(0.01) };
        let thin = integrate_batch(&f, 0.0, 2.0, &z0, tab, &opts_thin).unwrap();
        for i in 0..2 {
            assert_eq!(thin.tracks[i].ts, dense.tracks[i].ts, "sample {i} grid");
            assert_eq!(thin.last(i), dense.last(i), "sample {i} final");
            assert_eq!(thin.tracks[i].nfe, dense.tracks[i].nfe, "sample {i} nfe");
            assert!(
                thin.peak_state_bytes(i) <= budget,
                "sample {i}: peak {} bytes over budget {budget}",
                thin.peak_state_bytes(i)
            );
            assert!(thin.state_bytes(i) * 4 <= dense.state_bytes(i), "sample {i} thinned ≥4×");
        }
        // Physical arena: dense holds every state; thinned must be far
        // smaller (anchors + recycled slack), proving slots are reused.
        assert!(
            thin.zbuf.len() * 4 <= dense.zbuf.len(),
            "arena {} floats vs dense {} — free-list not recycling",
            thin.zbuf.len(),
            dense.zbuf.len()
        );
    }
}
