//! One explicit RK step `ψ_h(t, z)` with embedded error estimate
//! (the inner body of the paper's Algo 1).
//!
//! The scratch arena ([`StepScratch`]) is reused across step attempts so the
//! hot loop performs no allocation after warm-up (see EXPERIMENTS.md §Perf).

use super::func::OdeFunc;
use super::tableau::Tableau;
use crate::tensor;

/// Reusable buffers for step evaluation. One arena per integration; sized on
/// first use for the tableau with the most stages seen.
#[derive(Default, Debug)]
pub struct StepScratch {
    /// Stage derivatives `k_j`, each of length `dim`.
    pub ks: Vec<Vec<f32>>,
    /// Stage state `u_j = z + h Σ a_jl k_l`.
    pub u: Vec<f32>,
    /// Error-vector buffer (reused across step attempts; §Perf iteration 1 —
    /// the per-attempt `vec![]` allocation showed up on the adaptive loop).
    pub ev: Vec<f32>,
}

impl StepScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, stages: usize, dim: usize) {
        while self.ks.len() < stages {
            self.ks.push(vec![0.0; dim]);
        }
        for k in self.ks.iter_mut() {
            if k.len() != dim {
                k.resize(dim, 0.0);
            }
        }
        if self.u.len() != dim {
            self.u.resize(dim, 0.0);
        }
        if self.ev.len() != dim {
            self.ev.resize(dim, 0.0);
        }
    }
}

/// Result of a single step attempt.
#[derive(Debug, Clone, Copy)]
pub struct StepOut {
    /// Weighted-RMS error norm of the embedded estimate; `<= 1` means
    /// acceptable at the given tolerances. `0` for fixed-step tableaus.
    pub err_norm: f64,
    /// Number of `f` evaluations spent (stage count minus FSAL reuse).
    pub nfe: usize,
}

/// Advance one step: `z_next = z + h Σ b_j k_j`, error `= h Σ e_j k_j`.
///
/// * `k0`: optionally the precomputed `f(t, z)` (FSAL reuse from the previous
///   accepted step, or shared across retries of the same step — stage 0 does
///   not depend on `h`).
/// * On return `scratch.ks[..stages]` holds the stage derivatives (consumed by
///   [`crate::grad::step_vjp`] and by FSAL propagation).
pub fn rk_step<F: OdeFunc + ?Sized>(
    f: &F,
    tab: &Tableau,
    t: f64,
    h: f64,
    z: &[f32],
    k0: Option<&[f32]>,
    atol: f64,
    rtol: f64,
    z_next: &mut [f32],
    err_vec: Option<&mut Vec<f32>>,
    scratch: &mut StepScratch,
) -> StepOut {
    let dim = z.len();
    let s = tab.stages;
    scratch.ensure(s, dim);
    let mut nfe = 0;

    // Stage 0.
    if let Some(k0) = k0 {
        scratch.ks[0].copy_from_slice(k0);
    } else {
        f.eval(t, z, &mut scratch.ks[0]);
        nfe += 1;
    }

    // Stages 1..s. Split borrows: compute u from ks[..j], write ks[j].
    for j in 1..s {
        let (done, rest) = scratch.ks.split_at_mut(j);
        let u = &mut scratch.u;
        u.copy_from_slice(z);
        for (l, a) in tab.a[j].iter().enumerate() {
            if *a != 0.0 {
                tensor::axpy((h * *a) as f32, &done[l], u);
            }
        }
        f.eval(t + tab.c[j] * h, u, &mut rest[0]);
        nfe += 1;
    }

    // Propagating solution.
    tensor::combine(z, h, tab.b, &scratch.ks[..s], z_next);

    // Embedded error estimate.
    let err_norm = if let Some(e) = tab.b_err {
        let ev = &mut scratch.ev;
        ev.fill(0.0);
        // err = h Σ e_j k_j  (note: combine adds z, so subtract-free variant)
        for (c, k) in e.iter().zip(&scratch.ks[..s]) {
            if *c != 0.0 {
                tensor::axpy((h * *c) as f32, k, ev);
            }
        }
        // Scale uses the step's *start* state only (scipy's `y0` convention).
        // This makes the error norm independent of `z_next`, so the naive
        // method's backprop through the error estimate (grad::err_norm_vjp)
        // is exact in `h`.
        let n = tensor::wrms_norm(ev, z, z, atol, rtol);
        if let Some(out) = err_vec {
            out.clear();
            out.extend_from_slice(ev);
        }
        n
    } else {
        if let Some(out) = err_vec {
            out.clear();
        }
        0.0
    };

    StepOut { err_norm, nfe }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::Linear;
    use crate::ode::tableau;

    /// One step of each method on dz/dt = z from z=1 must match the Taylor
    /// polynomial of exp(h) to the method's order.
    #[test]
    fn step_matches_taylor_order() {
        let f = Linear::new(1.0, 1);
        let h = 0.1f64;
        let exact = h.exp();
        // Tolerances bounded below by f32 state precision (~1e-7 relative).
        let cases: Vec<(&Tableau, f64)> = vec![
            (tableau::euler(), 1e-2),
            (tableau::rk2(), 1e-3),
            (tableau::heun_euler(), 1e-3),
            (tableau::rk23(), 1e-5),
            (tableau::rk4(), 5e-7),
            (tableau::dopri5(), 5e-7),
        ];
        for (tab, tol) in cases {
            let mut z_next = [0.0f32];
            let mut scratch = StepScratch::new();
            rk_step(&f, tab, 0.0, h, &[1.0], None, 1e-9, 1e-9, &mut z_next, None, &mut scratch);
            let err = (z_next[0] as f64 - exact).abs();
            assert!(err < tol, "{}: |{} - {}| = {} >= {}", tab.name, z_next[0], exact, err, tol);
        }
    }

    /// Error estimate of an adaptive pair scales like h^order.
    #[test]
    fn error_estimate_scaling() {
        let f = Linear::new(1.0, 1);
        for tab in [tableau::heun_euler(), tableau::rk23(), tableau::dopri5()] {
            let mut scratch = StepScratch::new();
            let mut z = [0.0f32];
            let norms: Vec<f64> = [0.2, 0.1]
                .iter()
                .map(|&h| {
                    rk_step(&f, tab, 0.0, h, &[1.0], None, 1.0, 0.0, &mut z, None, &mut scratch)
                        .err_norm
                })
                .collect();
            let rate = (norms[0] / norms[1]).log2();
            // err ~ h^(q+1) where q = order - 1 (embedded), so rate ~= order.
            let expect = tab.order as f64;
            assert!(
                (rate - expect).abs() < 0.7,
                "{}: observed rate {} expected ~{}",
                tab.name,
                rate,
                expect
            );
        }
    }

    /// FSAL: last stage of an accepted step equals f at (t+h, z_next).
    #[test]
    fn fsal_last_stage() {
        let f = Linear::new(-0.5, 2);
        for tab in [tableau::rk23(), tableau::dopri5()] {
            let mut z_next = [0.0f32; 2];
            let mut scratch = StepScratch::new();
            rk_step(
                &f,
                tab,
                0.0,
                0.3,
                &[1.0, 2.0],
                None,
                1e-6,
                1e-6,
                &mut z_next,
                None,
                &mut scratch,
            );
            let mut expect = [0.0f32; 2];
            f.eval(0.3, &z_next, &mut expect);
            for i in 0..2 {
                assert!(
                    (scratch.ks[tab.stages - 1][i] - expect[i]).abs() < 1e-6,
                    "{}: ks[-1]={:?} expect={:?}",
                    tab.name,
                    scratch.ks[tab.stages - 1],
                    expect
                );
            }
        }
    }

    /// Passing k0 must reproduce the same step with one fewer evaluation.
    #[test]
    fn k0_reuse_identical() {
        let f = crate::ode::func::CountingFunc::new(Linear::new(0.8, 3));
        let z = [1.0f32, -1.0, 0.5];
        let tab = tableau::dopri5();
        let mut scratch = StepScratch::new();
        let mut z1 = [0.0f32; 3];
        let o1 = rk_step(&f, tab, 0.0, 0.05, &z, None, 1e-6, 1e-6, &mut z1, None, &mut scratch);
        assert_eq!(o1.nfe, 7);
        let k0 = scratch.ks[0].clone();
        let mut z2 = [0.0f32; 3];
        let o2 =
            rk_step(&f, tab, 0.0, 0.05, &z, Some(&k0), 1e-6, 1e-6, &mut z2, None, &mut scratch);
        assert_eq!(o2.nfe, 6);
        assert_eq!(z1, z2);
    }

    /// Fixed-step tableaus report zero error.
    #[test]
    fn fixed_step_zero_error() {
        let f = Linear::new(1.0, 1);
        let mut z = [0.0f32];
        let mut scratch = StepScratch::new();
        let out = rk_step(
            &f,
            tableau::rk4(),
            0.0,
            0.5,
            &[1.0],
            None,
            1e-9,
            1e-9,
            &mut z,
            None,
            &mut scratch,
        );
        assert_eq!(out.err_norm, 0.0);
    }

    /// Negative step sizes integrate backward (needed by the adjoint method).
    #[test]
    fn negative_step() {
        let f = Linear::new(1.0, 1);
        let mut z = [0.0f32];
        let mut scratch = StepScratch::new();
        rk_step(
            &f,
            tableau::dopri5(),
            1.0,
            -0.1,
            &[1.0],
            None,
            1e-9,
            1e-9,
            &mut z,
            None,
            &mut scratch,
        );
        let exact = (-0.1f64).exp();
        assert!((z[0] as f64 - exact).abs() < 5e-7, "{} vs {}", z[0], exact);
    }
}
