//! `dz/dt = k z` — the paper's Fig 6 toy problem (Eq. 27–29).
//!
//! With `L(z(T)) = z(T)^2` the exact parameter-free input gradient is
//! `dL/dz0 = 2 z0 exp(2kT)`, giving a closed-form target against which the
//! three gradient-estimation methods are compared. `k` is exposed as a
//! single trainable parameter so parameter-gradient paths are exercised too:
//! `dL/dk = 2 T z0² exp(2kT)`.

use crate::ode::func::OdeFunc;

/// Scalar-field linear dynamics `f(z) = k z` applied element-wise.
#[derive(Debug, Clone)]
pub struct Linear {
    k: [f32; 1],
    dim: usize,
}

impl Linear {
    pub fn new(k: f32, dim: usize) -> Self {
        Linear { k: [k], dim }
    }

    pub fn k(&self) -> f32 {
        self.k[0]
    }

    /// Exact flow: `z(t) = z0 · exp(k t)`.
    pub fn exact(&self, z0: f32, t: f64) -> f64 {
        z0 as f64 * (self.k[0] as f64 * t).exp()
    }

    /// Exact `dL/dz0` for `L = z(T)^2` (paper Eq. 29).
    pub fn exact_dl_dz0(&self, z0: f32, t_end: f64) -> f64 {
        2.0 * z0 as f64 * (2.0 * self.k[0] as f64 * t_end).exp()
    }

    /// Exact `dL/dk` for `L = z(T)^2`.
    pub fn exact_dl_dk(&self, z0: f32, t_end: f64) -> f64 {
        2.0 * t_end * (z0 as f64).powi(2) * (2.0 * self.k[0] as f64 * t_end).exp()
    }
}

impl OdeFunc for Linear {
    fn dim(&self) -> usize {
        self.dim
    }

    fn n_params(&self) -> usize {
        1
    }

    fn eval(&self, _t: f64, z: &[f32], dz: &mut [f32]) {
        for (d, &zi) in dz.iter_mut().zip(z) {
            *d = self.k[0] * zi;
        }
    }

    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        // Time-invariant and element-wise: the whole batch is one flat axpy
        // (bit-identical to the per-sample path — same op per element).
        debug_assert_eq!(zs.len(), ts.len() * self.dim);
        for (d, &zi) in dzs.iter_mut().zip(zs) {
            *d = self.k[0] * zi;
        }
    }

    fn vjp(&self, _t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        // ∂f/∂z = k I ; ∂f/∂k = z.
        for (o, &wi) in wjz.iter_mut().zip(w) {
            *o = self.k[0] * wi;
        }
        wjp[0] += crate::tensor::dot(w, z) as f32;
    }

    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], wjps: &mut [f32]) {
        // Time-invariant and element-wise: the state pullback is one flat
        // sweep over the whole batch; the parameter pullback is one dot per
        // sample row — the same ops per sample as `vjp`, so results stay
        // bit-identical to the scalar path.
        debug_assert_eq!(zs.len(), ts.len() * self.dim);
        debug_assert_eq!(wjps.len(), ts.len());
        for (o, &wi) in wjzs.iter_mut().zip(ws) {
            *o = self.k[0] * wi;
        }
        for (i, p) in wjps.iter_mut().enumerate() {
            *p += crate::tensor::dot(
                &ws[i * self.dim..(i + 1) * self.dim],
                &zs[i * self.dim..(i + 1) * self.dim],
            ) as f32;
        }
    }

    fn jvp(&self, _t: f64, _z: &[f32], v: &[f32], out: &mut [f32]) {
        for (o, &vi) in out.iter_mut().zip(v) {
            *o = self.k[0] * vi;
        }
    }

    fn params(&self) -> &[f32] {
        &self.k
    }

    fn set_params(&mut self, p: &[f32]) {
        assert_eq!(p.len(), 1);
        self.k[0] = p[0];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_and_exact() {
        let f = Linear::new(-0.5, 2);
        let mut dz = [0.0f32; 2];
        f.eval(0.0, &[2.0, -4.0], &mut dz);
        assert_eq!(dz, [-1.0, 2.0]);
        assert!((f.exact(1.0, 2.0) - (-1.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn vjp_matches_finite_difference() {
        let f = Linear::new(0.7, 3);
        let z = [1.0f32, -2.0, 0.5];
        let w = [0.2f32, 1.0, -0.3];
        let mut wjz = [0.0f32; 3];
        let mut wjp = [0.0f32; 1];
        f.vjp(0.0, &z, &w, &mut wjz, &mut wjp);
        // wjz = k w.
        for i in 0..3 {
            assert!((wjz[i] - 0.7 * w[i]).abs() < 1e-6);
        }
        // wjp = w.z
        let expect: f32 = z.iter().zip(&w).map(|(a, b)| a * b).sum();
        assert!((wjp[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn vjp_accumulates_into_wjp() {
        let f = Linear::new(1.0, 1);
        let mut wjz = [0.0f32];
        let mut wjp = [5.0f32];
        f.vjp(0.0, &[2.0], &[3.0], &mut wjz, &mut wjp);
        assert_eq!(wjp[0], 5.0 + 6.0);
    }

    #[test]
    fn analytic_gradients_consistency() {
        // dL/dk via finite difference on exact flow.
        let z0 = 1.3f32;
        let t = 2.0;
        let f = Linear::new(-0.8, 1);
        let eps = 1e-6;
        let lp = (z0 as f64 * ((-0.8f64 + eps) * t).exp()).powi(2);
        let lm = (z0 as f64 * ((-0.8f64 - eps) * t).exp()).powi(2);
        let fd = (lp - lm) / (2.0 * eps);
        assert!((f.exact_dl_dk(z0, t) - fd).abs() < 1e-5 * fd.abs().max(1.0));
    }

    #[test]
    fn vjp_batch_bit_identical_to_scalar() {
        let f = Linear::new(0.7, 3);
        let ts = [0.0f64, 1.0, -0.5];
        let zs: Vec<f32> = (0..9).map(|i| (i as f32 * 0.43).sin() * 2.0).collect();
        let ws: Vec<f32> = (0..9).map(|i| (i as f32 * 0.29).cos()).collect();
        let mut wjzs = vec![0.0f32; 9];
        let mut wjps = vec![0.5f32; 3]; // nonzero: the override must accumulate
        f.vjp_batch(&ts, &zs, &ws, &mut wjzs, &mut wjps);
        for i in 0..3 {
            let mut wjz = [0.0f32; 3];
            let mut wjp = [0.5f32; 1];
            f.vjp(ts[i], &zs[i * 3..(i + 1) * 3], &ws[i * 3..(i + 1) * 3], &mut wjz, &mut wjp);
            assert_eq!(&wjzs[i * 3..(i + 1) * 3], &wjz, "sample {i}");
            assert_eq!(wjps[i], wjp[0], "sample {i}");
        }
    }

    #[test]
    fn set_params() {
        let mut f = Linear::new(1.0, 1);
        f.set_params(&[-2.0]);
        assert_eq!(f.k(), -2.0);
        assert_eq!(f.params(), &[-2.0]);
    }
}
