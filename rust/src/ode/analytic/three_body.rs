//! Newtonian three-body dynamics (paper Sec 4.4, Eq. 32):
//!
//! ```text
//! r̈_i = − Σ_{j≠i} G m_j (r_i − r_j) / |r_i − r_j|³
//! ```
//!
//! State layout (dim 18): `[r_1(3), r_2(3), r_3(3), v_1(3), v_2(3), v_3(3)]`.
//! The three masses are the trainable parameters — the paper's "ODE" model
//! where only `m_i` are unknown. Also used (with fixed masses) as the
//! ground-truth simulator for the Table 5 dataset.
//!
//! Units: G = 4π² (AU, years, solar masses) so `t ∈ [0,1]` is one year as in
//! the paper.

use crate::ode::func::OdeFunc;

/// Gravitational constant in AU³ yr⁻² M☉⁻¹.
pub const G: f32 = 4.0 * std::f32::consts::PI * std::f32::consts::PI;

/// Softening length to keep close encounters integrable (standard N-body
/// practice; the paper's simulated systems avoid collisions but gradient
/// trials may not).
pub const SOFTENING: f32 = 1e-3;

/// Three-body dynamics with learnable masses.
#[derive(Debug, Clone)]
pub struct ThreeBody {
    masses: [f32; 3],
}

impl ThreeBody {
    pub fn new(masses: [f32; 3]) -> Self {
        ThreeBody { masses }
    }

    pub fn masses(&self) -> [f32; 3] {
        self.masses
    }

    #[inline]
    fn pos(z: &[f32], i: usize) -> [f32; 3] {
        [z[3 * i], z[3 * i + 1], z[3 * i + 2]]
    }

    /// Pairwise inverse-cube kernel `(r_i − r_j)/|r_i − r_j|³` with softening.
    #[inline]
    fn inv_cube(di: [f32; 3]) -> ([f32; 3], f32) {
        let r2 = di[0] * di[0] + di[1] * di[1] + di[2] * di[2] + SOFTENING * SOFTENING;
        let r = r2.sqrt();
        let ic = 1.0 / (r2 * r);
        ([di[0] * ic, di[1] * ic, di[2] * ic], ic)
    }

    /// One sample's derivative — shared by `eval` and the batched sweep.
    #[inline]
    fn eval_one(&self, z: &[f32], dz: &mut [f32]) {
        // ṙ = v
        dz[..9].copy_from_slice(&z[9..18]);
        // v̇_i = −G Σ_{j≠i} m_j (r_i − r_j)/|r_i − r_j|³
        for i in 0..3 {
            let ri = Self::pos(z, i);
            let mut acc = [0.0f32; 3];
            for j in 0..3 {
                if i == j {
                    continue;
                }
                let rj = Self::pos(z, j);
                let d = [ri[0] - rj[0], ri[1] - rj[1], ri[2] - rj[2]];
                let (k, _) = Self::inv_cube(d);
                for a in 0..3 {
                    acc[a] -= G * self.masses[j] * k[a];
                }
            }
            for a in 0..3 {
                dz[9 + 3 * i + a] = acc[a];
            }
        }
    }

    /// One sample's pullback — shared by `vjp` and the batched sweep.
    ///
    /// Position block of J is dense & nonlinear; the mass gradient is
    /// analytic and cheap. Positions/velocities: finite differences over
    /// eval (18-dim — 36 evals; negligible next to neural-f costs, and
    /// this path is exercised only by the small Table 5 experiments).
    fn vjp_one(&self, _t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        // wᵀ∂f/∂m_j: v̇_i depends on m_j (j≠i) linearly:
        //   ∂v̇_i/∂m_j = −G (r_i − r_j)/|·|³
        for j in 0..3 {
            let rj = Self::pos(z, j);
            let mut g = 0.0f32;
            for i in 0..3 {
                if i == j {
                    continue;
                }
                let ri = Self::pos(z, i);
                let d = [ri[0] - rj[0], ri[1] - rj[1], ri[2] - rj[2]];
                let (k, _) = Self::inv_cube(d);
                for a in 0..3 {
                    g += w[9 + 3 * i + a] * (-G * k[a]);
                }
            }
            wjp[j] += g;
        }
        // wᵀ∂f/∂z by finite differences (central). Stack buffers: this
        // runs once per reverse stage inside the hot batched sweep
        // (vjp_batch → vjp_one), so it must not allocate.
        let n = 18;
        let eps = 1e-4f32;
        let mut zp = [0.0f32; 18];
        zp.copy_from_slice(z);
        let mut fp = [0.0f32; 18];
        let mut fm = [0.0f32; 18];
        for c in 0..n {
            let orig = zp[c];
            zp[c] = orig + eps;
            self.eval_one(&zp, &mut fp);
            zp[c] = orig - eps;
            self.eval_one(&zp, &mut fm);
            zp[c] = orig;
            let mut acc = 0.0f32;
            for r in 0..n {
                acc += w[r] * (fp[r] - fm[r]) / (2.0 * eps);
            }
            wjz[c] = acc;
        }
    }
}

impl OdeFunc for ThreeBody {
    fn dim(&self) -> usize {
        18
    }

    fn n_params(&self) -> usize {
        3
    }

    fn eval(&self, _t: f64, z: &[f32], dz: &mut [f32]) {
        self.eval_one(z, dz);
    }

    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        // Time-invariant: sweep the flat [n × 18] buffer with the inlined
        // per-sample kernel (no per-sample dynamic dispatch); arithmetic is
        // identical to `eval`, so results are bit-identical per sample.
        debug_assert_eq!(zs.len(), ts.len() * 18);
        for (z, dz) in zs.chunks_exact(18).zip(dzs.chunks_exact_mut(18)) {
            self.eval_one(z, dz);
        }
    }

    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.vjp_one(t, z, w, wjz, wjp);
    }

    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], wjps: &mut [f32]) {
        // Sweep the flat [n × 18] buffers with the inlined per-sample kernel
        // (no per-sample dynamic dispatch); each sample's mass pullback
        // accumulates into its own [3] row. Arithmetic is identical to
        // `vjp`, so results are bit-identical per sample.
        debug_assert_eq!(zs.len(), ts.len() * 18);
        debug_assert_eq!(wjps.len(), ts.len() * 3);
        for (i, &t) in ts.iter().enumerate() {
            self.vjp_one(
                t,
                &zs[i * 18..(i + 1) * 18],
                &ws[i * 18..(i + 1) * 18],
                &mut wjzs[i * 18..(i + 1) * 18],
                &mut wjps[i * 3..(i + 1) * 3],
            );
        }
    }

    fn params(&self) -> &[f32] {
        &self.masses
    }

    fn set_params(&mut self, p: &[f32]) {
        assert_eq!(p.len(), 3);
        self.masses.copy_from_slice(p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{integrate, tableau, IntegrateOpts};

    fn sun_earth_like() -> (ThreeBody, Vec<f32>) {
        // Central mass 1 M☉, two light bodies on circular-ish orbits.
        let f = ThreeBody::new([1.0, 1e-5, 1e-5]);
        let mut z = vec![0.0f32; 18];
        // body 1 at origin; body 2 at 1 AU with circular speed 2π AU/yr.
        z[3] = 1.0;
        z[9 + 3 + 1] = std::f32::consts::TAU;
        // body 3 at 1.5 AU.
        z[6] = 1.5;
        z[9 + 6 + 1] = (G / 1.5).sqrt();
        (f, z)
    }

    #[test]
    fn velocities_copied() {
        let (f, mut z) = sun_earth_like();
        z[9] = 0.123;
        let mut dz = vec![0.0f32; 18];
        f.eval(0.0, &z, &mut dz);
        assert_eq!(&dz[..9], &z[9..18]);
    }

    #[test]
    fn newton_third_law_momentum_conserved() {
        // Σ m_i v̇_i ≈ 0 (equal & opposite forces).
        let f = ThreeBody::new([1.0, 2.0, 0.5]);
        let z: Vec<f32> = (0..18).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut dz = vec![0.0f32; 18];
        f.eval(0.0, &z, &mut dz);
        for a in 0..3 {
            let total: f32 = (0..3).map(|i| f.masses[i] * dz[9 + 3 * i + a]).sum();
            assert!(total.abs() < 1e-3, "axis {a}: net force {total}");
        }
    }

    #[test]
    fn circular_orbit_period() {
        // Earth-like body must return near its start after 1 year.
        let (f, z0) = sun_earth_like();
        let traj = integrate(
            &f,
            0.0,
            1.0,
            &z0,
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-9, 1e-9),
        )
        .unwrap();
        let zf = traj.last().unwrap();
        let d = ((zf[3] - z0[3]).powi(2) + (zf[4] - z0[4]).powi(2)).sqrt();
        assert!(d < 0.05, "earth drifted {d} AU after one period");
    }

    #[test]
    fn energy_conservation() {
        let (f, z0) = sun_earth_like();
        let energy = |z: &[f32]| -> f64 {
            let m = f.masses();
            let mut e = 0.0f64;
            for i in 0..3 {
                let v2: f32 = (0..3).map(|a| z[9 + 3 * i + a].powi(2)).sum();
                e += 0.5 * m[i] as f64 * v2 as f64;
            }
            for i in 0..3 {
                for j in (i + 1)..3 {
                    let d2: f32 = (0..3).map(|a| (z[3 * i + a] - z[3 * j + a]).powi(2)).sum();
                    e -= (G * m[i] * m[j]) as f64 / (d2.sqrt() as f64).max(1e-9);
                }
            }
            e
        };
        let e0 = energy(&z0);
        let traj = integrate(
            &f,
            0.0,
            2.0,
            &z0,
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-9, 1e-9),
        )
        .unwrap();
        let e1 = energy(traj.last().unwrap());
        assert!(
            ((e1 - e0) / e0.abs()).abs() < 1e-3,
            "energy drift: {e0} -> {e1}"
        );
    }

    #[test]
    fn mass_vjp_matches_finite_difference() {
        let z: Vec<f32> = (0..18).map(|i| 0.5 + (i as f32 * 0.61).cos()).collect();
        let w: Vec<f32> = (0..18).map(|i| (i as f32 * 0.17).sin()).collect();
        let mut wjz = vec![0.0f32; 18];
        let mut wjp = vec![0.0f32; 3];
        let f = ThreeBody::new([1.0, 0.8, 1.2]);
        f.vjp(0.0, &z, &w, &mut wjz, &mut wjp);
        let eps = 1e-3f32;
        for j in 0..3 {
            let mut mp = f.masses();
            let mut mm = f.masses();
            mp[j] += eps;
            mm[j] -= eps;
            let mut fp = vec![0.0f32; 18];
            let mut fm = vec![0.0f32; 18];
            ThreeBody::new(mp).eval(0.0, &z, &mut fp);
            ThreeBody::new(mm).eval(0.0, &z, &mut fm);
            let fd: f32 = (0..18).map(|r| w[r] * (fp[r] - fm[r]) / (2.0 * eps)).sum();
            assert!(
                (wjp[j] - fd).abs() < 1e-2 * fd.abs().max(1.0),
                "mass {j}: analytic {} vs fd {}",
                wjp[j],
                fd
            );
        }
    }

    #[test]
    fn vjp_batch_bit_identical_to_scalar() {
        let f = ThreeBody::new([1.0, 0.8, 1.2]);
        let n = 3;
        let ts = [0.0f64, 0.5, 1.0];
        let zs: Vec<f32> = (0..n * 18).map(|i| 0.6 + (i as f32 * 0.23).cos()).collect();
        let ws: Vec<f32> = (0..n * 18).map(|i| (i as f32 * 0.41).sin()).collect();
        let mut wjzs = vec![0.0f32; n * 18];
        let mut wjps = vec![0.1f32; n * 3]; // nonzero: the override must accumulate
        f.vjp_batch(&ts, &zs, &ws, &mut wjzs, &mut wjps);
        for i in 0..n {
            let mut wjz = vec![0.0f32; 18];
            let mut wjp = vec![0.1f32; 3];
            f.vjp(ts[i], &zs[i * 18..(i + 1) * 18], &ws[i * 18..(i + 1) * 18], &mut wjz, &mut wjp);
            assert_eq!(&wjzs[i * 18..(i + 1) * 18], &wjz[..], "sample {i} state pullback");
            assert_eq!(&wjps[i * 3..(i + 1) * 3], &wjp[..], "sample {i} mass pullback");
        }
    }

    #[test]
    fn state_vjp_adjoint_identity() {
        // <w, J v> == <w^T J, v> with J from finite differences both ways.
        let f = ThreeBody::new([1.0, 0.5, 0.7]);
        // Well-separated bodies: finite-difference Jacobians are accurate in
        // f32 only away from close encounters (1/r³ curvature).
        let mut z: Vec<f32> = vec![
            0.0, 0.0, 0.0, // r1
            1.2, 0.3, -0.2, // r2
            -0.8, 1.0, 0.5, // r3
            0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
        ];
        for (i, v) in z.iter_mut().enumerate().skip(9) {
            *v = (i as f32 * 0.4).sin();
        }
        let v: Vec<f32> = (0..18).map(|i| (i as f32 * 0.71).cos()).collect();
        let w: Vec<f32> = (0..18).map(|i| (i as f32 * 0.31).sin()).collect();
        let mut jv = vec![0.0f32; 18];
        f.jvp(0.0, &z, &v, &mut jv);
        let mut wj = vec![0.0f32; 18];
        f.vjp(0.0, &z, &w, &mut wj, &mut vec![0.0; 3]);
        let lhs = crate::tensor::dot(&w, &jv);
        let rhs = crate::tensor::dot(&wj, &v);
        assert!((lhs - rhs).abs() < 2e-2 * lhs.abs().max(1.0), "{lhs} vs {rhs}");
    }
}
