//! Closed-form dynamics used by the paper's numerical studies:
//!
//! * [`Linear`] — `dz/dt = k z`, the Fig 6 toy problem with analytic gradient;
//! * [`VanDerPol`] — the Fig 4 reverse-trajectory study;
//! * [`ConvFlow`] — image evolving under a random 3×3 convolution (Fig 5);
//! * [`ThreeBody`] — Newtonian gravity with learnable masses (Table 5, also
//!   the ground-truth simulator for the three-body dataset).

mod conv_flow;
mod linear;
pub mod three_body;
mod vdp;

pub use conv_flow::ConvFlow;
pub use linear::Linear;
pub use three_body::ThreeBody;
pub use vdp::VanDerPol;
