//! Image evolving under a random 3×3 convolution — the paper's Fig 5 study:
//! `dz/dt = conv3x3(z, K)` over a `H×W` single-channel image. Forward-solve
//! the flow, then reverse-solve from `z(T)` with the adjoint method's
//! forgotten trajectory and observe the reconstruction error.

use crate::ode::func::OdeFunc;
use crate::util::Pcg64;

/// Linear convolution flow `f(z) = K * z` (zero padding, 3×3 kernel).
#[derive(Debug, Clone)]
pub struct ConvFlow {
    h: usize,
    w: usize,
    kernel: [f32; 9],
}

impl ConvFlow {
    pub fn new(h: usize, w: usize, kernel: [f32; 9]) -> Self {
        ConvFlow { h, w, kernel }
    }

    /// Random kernel drawn N(0, scale²) — the paper's "random 3×3 kernel".
    /// The kernel is mean-subtracted so the flow is neither uniformly
    /// exploding nor uniformly decaying over the Fig 5 time span.
    pub fn random(h: usize, w: usize, seed: u64, scale: f32) -> Self {
        let mut rng = Pcg64::new(seed, 50);
        let mut kernel = [0.0f32; 9];
        for k in kernel.iter_mut() {
            *k = rng.normal_f32() * scale;
        }
        let mean: f32 = kernel.iter().sum::<f32>() / 9.0;
        for k in kernel.iter_mut() {
            *k -= mean;
        }
        ConvFlow { h, w, kernel }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.h, self.w)
    }

    #[inline]
    fn at(&self, z: &[f32], r: isize, c: isize) -> f32 {
        if r < 0 || c < 0 || r >= self.h as isize || c >= self.w as isize {
            0.0
        } else {
            z[r as usize * self.w + c as usize]
        }
    }

    /// Forward correlation with the kernel.
    fn conv(&self, z: &[f32], out: &mut [f32], transpose: bool) {
        for r in 0..self.h as isize {
            for c in 0..self.w as isize {
                let mut acc = 0.0f32;
                for dr in -1..=1isize {
                    for dc in -1..=1isize {
                        let kidx = ((dr + 1) * 3 + (dc + 1)) as usize;
                        let k = if transpose {
                            // adjoint of correlation = correlation with the
                            // flipped kernel
                            self.kernel[8 - kidx]
                        } else {
                            self.kernel[kidx]
                        };
                        acc += k * self.at(z, r + dr, c + dc);
                    }
                }
                out[(r as usize) * self.w + c as usize] = acc;
            }
        }
    }
}

impl OdeFunc for ConvFlow {
    fn dim(&self) -> usize {
        self.h * self.w
    }

    fn eval(&self, _t: f64, z: &[f32], dz: &mut [f32]) {
        self.conv(z, dz, false);
    }

    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        // Time-invariant linear map: convolve each image in the flat
        // [n × H·W] buffer without per-sample dynamic dispatch. Same kernel
        // sweep as `eval`, so results are bit-identical per sample.
        let d = self.h * self.w;
        debug_assert_eq!(zs.len(), ts.len() * d);
        for (z, dz) in zs.chunks_exact(d).zip(dzs.chunks_exact_mut(d)) {
            self.conv(z, dz, false);
        }
    }

    fn vjp(&self, _t: f64, _z: &[f32], w: &[f32], wjz: &mut [f32], _wjp: &mut [f32]) {
        // Linear map: wᵀ ∂f/∂z = Kᵀ w.
        self.conv(w, wjz, true);
    }

    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], _zs: &[f32], ws: &[f32], wjzs: &mut [f32], _wjps: &mut [f32]) {
        // Time-invariant linear map: pull each cotangent image back through
        // the flipped kernel without per-sample dynamic dispatch. Same
        // kernel sweep as `vjp`, so results are bit-identical per sample.
        let d = self.h * self.w;
        debug_assert_eq!(ws.len(), ts.len() * d);
        for (w, wjz) in ws.chunks_exact(d).zip(wjzs.chunks_exact_mut(d)) {
            self.conv(w, wjz, true);
        }
    }

    fn jvp(&self, _t: f64, _z: &[f32], v: &[f32], out: &mut [f32]) {
        self.conv(v, out, false);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_is_identity_map() {
        let mut k = [0.0f32; 9];
        k[4] = 1.0;
        let f = ConvFlow::new(4, 4, k);
        let z: Vec<f32> = (0..16).map(|i| i as f32).collect();
        let mut dz = vec![0.0f32; 16];
        f.eval(0.0, &z, &mut dz);
        assert_eq!(dz, z);
    }

    #[test]
    fn shift_kernel_shifts() {
        // Kernel with a 1 at position (0,1)-offset (dr=-1, dc=0): output(r,c) = z(r-1,c).
        let mut k = [0.0f32; 9];
        k[1] = 1.0; // dr = -1, dc = 0
        let f = ConvFlow::new(3, 3, k);
        let z = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0f32];
        let mut dz = [0.0f32; 9];
        f.eval(0.0, &z, &mut dz);
        // row 0 reads out of bounds (0), rows 1,2 read rows 0,1.
        assert_eq!(&dz[0..3], &[0.0, 0.0, 0.0]);
        assert_eq!(&dz[3..6], &[1.0, 2.0, 3.0]);
        assert_eq!(&dz[6..9], &[4.0, 5.0, 6.0]);
    }

    #[test]
    fn vjp_is_adjoint_of_jvp() {
        // <w, Kv> == <K^T w, v>
        let f = ConvFlow::random(5, 5, 3, 0.4);
        let mut rng = Pcg64::seed(11);
        let v: Vec<f32> = (0..25).map(|_| rng.normal_f32()).collect();
        let w: Vec<f32> = (0..25).map(|_| rng.normal_f32()).collect();
        let mut kv = vec![0.0f32; 25];
        f.jvp(0.0, &v, &v, &mut kv);
        let mut ktw = vec![0.0f32; 25];
        f.vjp(0.0, &v, &w, &mut ktw, &mut []);
        let lhs = crate::tensor::dot(&w, &kv);
        let rhs = crate::tensor::dot(&ktw, &v);
        assert!((lhs - rhs).abs() < 1e-4, "{lhs} vs {rhs}");
    }

    #[test]
    fn vjp_batch_bit_identical_to_scalar() {
        let f = ConvFlow::random(5, 5, 7, 0.4);
        let n = 3;
        let ts = [0.0f64, 1.0, 2.0];
        let mut rng = Pcg64::seed(23);
        let zs: Vec<f32> = (0..n * 25).map(|_| rng.normal_f32()).collect();
        let ws: Vec<f32> = (0..n * 25).map(|_| rng.normal_f32()).collect();
        let mut wjzs = vec![0.0f32; n * 25];
        f.vjp_batch(&ts, &zs, &ws, &mut wjzs, &mut []);
        for i in 0..n {
            let mut wjz = vec![0.0f32; 25];
            f.vjp(ts[i], &zs[i * 25..(i + 1) * 25], &ws[i * 25..(i + 1) * 25], &mut wjz, &mut []);
            assert_eq!(&wjzs[i * 25..(i + 1) * 25], &wjz[..], "sample {i}");
        }
    }

    #[test]
    fn random_kernel_mean_zero() {
        let f = ConvFlow::random(8, 8, 42, 0.5);
        let s: f32 = f.kernel.iter().sum();
        assert!(s.abs() < 1e-5);
    }
}
