//! Van der Pol oscillator — the paper's Fig 4 / Appendix D.1 study of
//! forward-vs-reverse trajectory mismatch (paper Eq. 81–82):
//!
//! ```text
//! dy1/dt = y2
//! dy2/dt = (mu − y1²) · y2 − y1
//! ```
//!
//! with the paper's `mu = 0.15`, `y(0) = (2, 0)`.

use crate::ode::func::OdeFunc;

/// Van der Pol dynamics with damping parameter `mu` (fixed, not trained).
#[derive(Debug, Clone)]
pub struct VanDerPol {
    mu: f32,
}

impl VanDerPol {
    pub fn new(mu: f32) -> Self {
        VanDerPol { mu }
    }

    /// The paper's configuration (Appendix D.1).
    pub fn paper() -> Self {
        VanDerPol::new(0.15)
    }

    /// One sample's derivative — shared by `eval` and the batched sweep.
    #[inline]
    fn eval_one(&self, z: &[f32], dz: &mut [f32]) {
        let (y1, y2) = (z[0], z[1]);
        dz[0] = y2;
        dz[1] = (self.mu - y1 * y1) * y2 - y1;
    }

    /// One sample's state pullback — shared by `vjp` and the batched sweep.
    #[inline]
    fn vjp_one(&self, z: &[f32], w: &[f32], wjz: &mut [f32]) {
        // J = [[0, 1], [−2 y1 y2 − 1, mu − y1²]];  wjz = wᵀ J.
        let (y1, y2) = (z[0], z[1]);
        wjz[0] = w[1] * (-2.0 * y1 * y2 - 1.0);
        wjz[1] = w[0] + w[1] * (self.mu - y1 * y1);
    }
}

impl OdeFunc for VanDerPol {
    fn dim(&self) -> usize {
        2
    }

    fn eval(&self, _t: f64, z: &[f32], dz: &mut [f32]) {
        self.eval_one(z, dz);
    }

    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        // Time-invariant: one monomorphized pass over the flat [n × 2]
        // buffer, no per-sample dynamic dispatch. Same arithmetic per sample
        // as `eval`, so results stay bit-identical to the scalar path.
        debug_assert_eq!(zs.len(), ts.len() * 2);
        for (z, dz) in zs.chunks_exact(2).zip(dzs.chunks_exact_mut(2)) {
            self.eval_one(z, dz);
        }
    }

    fn vjp(&self, _t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], _wjp: &mut [f32]) {
        self.vjp_one(z, w, wjz);
    }

    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], _wjps: &mut [f32]) {
        // Time-invariant, parameter-free: one monomorphized pass over the
        // flat [n × 2] buffers, no per-sample dynamic dispatch. Same
        // arithmetic per sample as `vjp`, so results stay bit-identical.
        debug_assert_eq!(zs.len(), ts.len() * 2);
        debug_assert_eq!(ws.len(), ts.len() * 2);
        for ((z, w), wjz) in
            zs.chunks_exact(2).zip(ws.chunks_exact(2)).zip(wjzs.chunks_exact_mut(2))
        {
            self.vjp_one(z, w, wjz);
        }
    }

    fn jvp(&self, _t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        let (y1, y2) = (z[0], z[1]);
        out[0] = v[1];
        out[1] = (-2.0 * y1 * y2 - 1.0) * v[0] + (self.mu - y1 * y1) * v[1];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{integrate, tableau, IntegrateOpts};

    #[test]
    fn eval_matches_equations() {
        let f = VanDerPol::new(0.15);
        let mut dz = [0.0f32; 2];
        f.eval(0.0, &[2.0, 0.5], &mut dz);
        assert_eq!(dz[0], 0.5);
        assert!((dz[1] - ((0.15 - 4.0) * 0.5 - 2.0)).abs() < 1e-6);
    }

    #[test]
    fn vjp_vs_jvp_adjoint_identity() {
        // w.(J v) == (w^T J).v for random-ish vectors.
        let f = VanDerPol::new(0.15);
        let z = [1.5f32, -0.7];
        let w = [0.3f32, 0.9];
        let v = [-1.1f32, 0.4];
        let mut jv = [0.0f32; 2];
        f.jvp(0.0, &z, &v, &mut jv);
        let mut wj = [0.0f32; 2];
        f.vjp(0.0, &z, &w, &mut wj, &mut []);
        let lhs: f32 = w.iter().zip(&jv).map(|(a, b)| a * b).sum();
        let rhs: f32 = wj.iter().zip(&v).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-5, "{lhs} vs {rhs}");
    }

    #[test]
    fn jvp_matches_finite_difference() {
        let f = VanDerPol::new(0.15);
        let z = [2.0f32, 0.0];
        let v = [0.5f32, -1.0];
        let mut analytic = [0.0f32; 2];
        f.jvp(0.0, &z, &v, &mut analytic);
        let eps = 1e-3f32;
        let zp = [z[0] + eps * v[0], z[1] + eps * v[1]];
        let zm = [z[0] - eps * v[0], z[1] - eps * v[1]];
        let mut fp = [0.0f32; 2];
        let mut fm = [0.0f32; 2];
        f.eval(0.0, &zp, &mut fp);
        f.eval(0.0, &zm, &mut fm);
        for i in 0..2 {
            let fd = (fp[i] - fm[i]) / (2.0 * eps);
            assert!((analytic[i] - fd).abs() < 1e-2, "{analytic:?} vs fd {fd}");
        }
    }

    #[test]
    fn vjp_batch_bit_identical_to_scalar() {
        let f = VanDerPol::new(0.4);
        let ts = [0.0f64, 1.0, 2.0, -1.0];
        let zs: Vec<f32> = (0..8).map(|i| (i as f32 * 0.37).sin() * 1.5).collect();
        let ws: Vec<f32> = (0..8).map(|i| (i as f32 * 0.53).cos()).collect();
        let mut wjzs = vec![0.0f32; 8];
        f.vjp_batch(&ts, &zs, &ws, &mut wjzs, &mut []);
        for i in 0..4 {
            let mut wjz = [0.0f32; 2];
            f.vjp(ts[i], &zs[i * 2..(i + 1) * 2], &ws[i * 2..(i + 1) * 2], &mut wjz, &mut []);
            assert_eq!(&wjzs[i * 2..(i + 1) * 2], &wjz, "sample {i}");
        }
    }

    /// Low-mu van der Pol is a slightly-damped oscillator; energy should not
    /// explode over one period.
    #[test]
    fn trajectory_bounded() {
        let f = VanDerPol::paper();
        let traj = integrate(
            &f,
            0.0,
            25.0,
            &[2.0, 0.0],
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-6, 1e-8),
        )
        .unwrap();
        for z in traj.states() {
            assert!(z[0].abs() < 5.0 && z[1].abs() < 5.0, "unbounded: {z:?}");
        }
    }
}
