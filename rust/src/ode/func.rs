//! The dynamics interface `f(z, t, θ)` (paper Eq. 1).
//!
//! Implementations are either **analytic** (closed-form Rust, see
//! [`super::analytic`]) or **AOT-compiled neural dynamics** executed through
//! PJRT ([`crate::runtime::hlo_func::HloOdeFunc`]). The gradient methods in
//! [`crate::grad`] only speak this trait, so every method runs unchanged on
//! both kinds of dynamics.

/// Continuous dynamics with parameters, evaluated by the solver hot loop.
///
/// The state is a flat `[f32]` buffer of length [`OdeFunc::dim`] (batch
/// dimensions flattened). Times are `f64` to keep the step-size arithmetic
/// exact; states are `f32` matching the XLA artifacts.
pub trait OdeFunc {
    /// Flat state dimension.
    fn dim(&self) -> usize;

    /// Number of trainable parameters (0 for fixed analytic dynamics).
    fn n_params(&self) -> usize {
        0
    }

    /// `dz = f(t, z)`.
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]);

    /// Evaluate the dynamics for `ts.len()` independent samples packed
    /// row-major in `zs` (`n × dim`), each at its own time `ts[i]`, writing
    /// the derivatives into `dzs` with the same layout.
    ///
    /// Default: one `eval` per sample, bit-identical to the scalar path —
    /// which is what [`crate::ode::integrate_batch`]'s equivalence guarantee
    /// relies on. Backends that can amortize dispatch overhead (a single
    /// batched HLO call through the PJRT engine, SIMD over the batch axis)
    /// override this.
    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        let d = self.dim();
        debug_assert_eq!(zs.len(), ts.len() * d);
        debug_assert_eq!(dzs.len(), ts.len() * d);
        for (i, &t) in ts.iter().enumerate() {
            self.eval(t, &zs[i * d..(i + 1) * d], &mut dzs[i * d..(i + 1) * d]);
        }
    }

    /// Vector-Jacobian product: given `w`, compute
    /// `wjz = wᵀ ∂f/∂z` and accumulate `wᵀ ∂f/∂θ` into `wjp` (`+=`).
    ///
    /// `wjp` has length [`OdeFunc::n_params`] and is *accumulated into* so a
    /// backward sweep can sum contributions without temporaries.
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]);

    /// Vector-Jacobian products for `ts.len()` independent samples packed
    /// row-major: states `zs` and cotangents `ws` are `[n × dim]`, the state
    /// pullbacks land in `wjzs` (same layout, overwritten), and each sample's
    /// parameter pullback is *accumulated* into its own `[n_params]` row of
    /// `wjps` (`[n × n_params]`) — mirroring the scalar [`OdeFunc::vjp`]
    /// contract per sample.
    ///
    /// Default: one `vjp` per sample, bit-identical to the scalar path —
    /// the contract the batched backward pass
    /// ([`crate::grad::step_vjp_batch`]) relies on for its per-sample
    /// equivalence guarantee. Backends that can amortize dispatch overhead
    /// (a batched HLO pullback, a flat monomorphized sweep) override this.
    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], wjps: &mut [f32]) {
        let d = self.dim();
        let p = self.n_params();
        debug_assert_eq!(zs.len(), ts.len() * d);
        debug_assert_eq!(ws.len(), ts.len() * d);
        debug_assert_eq!(wjzs.len(), ts.len() * d);
        debug_assert_eq!(wjps.len(), ts.len() * p);
        for (i, &t) in ts.iter().enumerate() {
            self.vjp(
                t,
                &zs[i * d..(i + 1) * d],
                &ws[i * d..(i + 1) * d],
                &mut wjzs[i * d..(i + 1) * d],
                &mut wjps[i * p..(i + 1) * p],
            );
        }
    }

    /// Jacobian-vector product `∂f/∂z · v`. Default: central finite
    /// difference via two `eval` calls — adequate for the naive method's
    /// step-size-chain terms; override for exactness.
    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        let n = self.dim();
        let vnorm = crate::tensor::norm2(v);
        if vnorm == 0.0 {
            out.fill(0.0);
            return;
        }
        // Perturbation ‖eps·v‖ ≈ 1e-4 · max(1, ‖z‖): relative to the state
        // magnitude so large states don't cancel catastrophically (an
        // absolute 1e-4 nudge on ‖z‖ ~ 1e5 is below one f32 ulp and the
        // difference quotient collapses to 0/eps), with the max(1, ·) floor
        // keeping tiny states at a sane absolute perturbation.
        let znorm = crate::tensor::norm2(z);
        let eps = (1e-4 * znorm.max(1.0) / vnorm).max(1e-7) as f32;
        let mut zp = z.to_vec();
        let mut zm = z.to_vec();
        for i in 0..n {
            zp[i] += eps * v[i];
            zm[i] -= eps * v[i];
        }
        let mut fp = vec![0.0f32; n];
        self.eval(t, &zp, &mut fp);
        self.eval(t, &zm, out);
        for i in 0..n {
            out[i] = (fp[i] - out[i]) / (2.0 * eps);
        }
    }

    /// Current parameter vector (empty for parameterless dynamics).
    fn params(&self) -> &[f32] {
        &[]
    }

    /// Replace the parameter vector. Panics if `p.len() != n_params()`.
    fn set_params(&mut self, p: &[f32]) {
        assert_eq!(p.len(), 0, "dynamics has no parameters");
    }
}

/// Blanket impl so `&F` works wherever `impl OdeFunc` is expected.
impl<F: OdeFunc + ?Sized> OdeFunc for &F {
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn n_params(&self) -> usize {
        (**self).n_params()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        (**self).eval(t, z, dz)
    }
    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        (**self).eval_batch(ts, zs, dzs)
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        (**self).vjp(t, z, w, wjz, wjp)
    }
    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], wjps: &mut [f32]) {
        (**self).vjp_batch(ts, zs, ws, wjzs, wjps)
    }
    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        (**self).jvp(t, z, v, out)
    }
    fn params(&self) -> &[f32] {
        (**self).params()
    }
}

/// Wraps any `OdeFunc` and counts evaluations — the paper's NFE metric
/// (`N_f × N_t × m` accounting of Table 1).
pub struct CountingFunc<F> {
    pub inner: F,
    evals: std::cell::Cell<usize>,
    vjps: std::cell::Cell<usize>,
    jvps: std::cell::Cell<usize>,
}

impl<F: OdeFunc> CountingFunc<F> {
    pub fn new(inner: F) -> Self {
        CountingFunc {
            inner,
            evals: std::cell::Cell::new(0),
            vjps: std::cell::Cell::new(0),
            jvps: std::cell::Cell::new(0),
        }
    }

    pub fn evals(&self) -> usize {
        self.evals.get()
    }
    pub fn vjps(&self) -> usize {
        self.vjps.get()
    }
    pub fn jvps(&self) -> usize {
        self.jvps.get()
    }
    pub fn reset(&self) {
        self.evals.set(0);
        self.vjps.set(0);
        self.jvps.set(0);
    }
}

impl<F: OdeFunc> OdeFunc for CountingFunc<F> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn n_params(&self) -> usize {
        self.inner.n_params()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        self.evals.set(self.evals.get() + 1);
        self.inner.eval(t, z, dz)
    }
    // nodal-lint: hot
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        // Forward to the inner dynamics so wrapping never disables its fast
        // path (the trait default would silently loop `eval` instead); the
        // NFE meter still counts per sample, identical to the scalar path.
        self.evals.set(self.evals.get() + ts.len());
        self.inner.eval_batch(ts, zs, dzs)
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.vjps.set(self.vjps.get() + 1);
        self.inner.vjp(t, z, w, wjz, wjp)
    }
    // nodal-lint: hot
    fn vjp_batch(&self, ts: &[f64], zs: &[f32], ws: &[f32], wjzs: &mut [f32], wjps: &mut [f32]) {
        self.vjps.set(self.vjps.get() + ts.len());
        self.inner.vjp_batch(ts, zs, ws, wjzs, wjps)
    }
    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        self.jvps.set(self.jvps.get() + 1);
        self.inner.jvp(t, z, v, out)
    }
    fn params(&self) -> &[f32] {
        self.inner.params()
    }
    fn set_params(&mut self, p: &[f32]) {
        self.inner.set_params(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::Linear;

    #[test]
    fn counting_wrapper_counts() {
        let f = CountingFunc::new(Linear::new(-1.0, 1));
        let mut dz = [0.0f32];
        f.eval(0.0, &[1.0], &mut dz);
        f.eval(0.0, &[1.0], &mut dz);
        let mut wjp = [0.0f32];
        let mut wjz = [0.0f32];
        f.vjp(0.0, &[1.0], &[1.0], &mut wjz, &mut wjp);
        assert_eq!(f.evals(), 2);
        assert_eq!(f.vjps(), 1);
        f.reset();
        assert_eq!(f.evals(), 0);
    }

    #[test]
    fn default_jvp_matches_analytic_for_linear() {
        // f = kz  =>  J v = k v.
        let f = Linear::new(-0.7, 3);
        let z = [1.0f32, -2.0, 0.5];
        let v = [0.3f32, 1.0, -1.0];
        let mut out = [0.0f32; 3];
        // Force the default finite-difference path.
        struct NoJvp(Linear);
        impl OdeFunc for NoJvp {
            fn dim(&self) -> usize {
                self.0.dim()
            }
            fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
                self.0.eval(t, z, dz)
            }
            fn vjp(&self, t: f64, z: &[f32], w: &[f32], a: &mut [f32], b: &mut [f32]) {
                self.0.vjp(t, z, w, a, b)
            }
        }
        NoJvp(f).jvp(0.0, &z, &v, &mut out);
        for i in 0..3 {
            assert!((out[i] - (-0.7 * v[i])).abs() < 1e-3, "{:?}", out);
        }
    }

    #[test]
    fn default_eval_batch_matches_scalar_and_counts() {
        let f = CountingFunc::new(Linear::new(-0.5, 2));
        let ts = [0.0f64, 1.0, 2.0];
        let zs = [1.0f32, 2.0, -1.0, 0.5, 4.0, -4.0];
        let mut dzs = [0.0f32; 6];
        f.eval_batch(&ts, &zs, &mut dzs);
        // Forwarded to the inner batch sweep, counted per sample — the same
        // accounting the scalar loop produced.
        assert_eq!(f.evals(), 3);
        let mut expect = [0.0f32; 2];
        for i in 0..3 {
            f.inner.eval(ts[i], &zs[i * 2..(i + 1) * 2], &mut expect);
            assert_eq!(&dzs[i * 2..(i + 1) * 2], &expect, "sample {i}");
        }
    }

    #[test]
    fn default_jvp_zero_vector() {
        let f = Linear::new(2.0, 2);
        let mut out = [9.0f32; 2];
        f.jvp(0.0, &[1.0, 1.0], &[0.0, 0.0], &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }

    /// Strips every override so the trait defaults are what run.
    struct DefaultsOnly<F>(F);
    impl<F: OdeFunc> OdeFunc for DefaultsOnly<F> {
        fn dim(&self) -> usize {
            self.0.dim()
        }
        fn n_params(&self) -> usize {
            self.0.n_params()
        }
        fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
            self.0.eval(t, z, dz)
        }
        fn vjp(&self, t: f64, z: &[f32], w: &[f32], a: &mut [f32], b: &mut [f32]) {
            self.0.vjp(t, z, w, a, b)
        }
        fn params(&self) -> &[f32] {
            self.0.params()
        }
    }

    /// The default finite-difference `jvp` must stay accurate when the state
    /// is many orders of magnitude larger or smaller than O(1): the
    /// perturbation scales with max(1, ‖z‖), so a huge state no longer
    /// swallows an absolute 1e-4 nudge below its f32 ulp (which used to
    /// collapse the difference quotient to 0) and a tiny state is not
    /// over-perturbed relative to its own magnitude.
    #[test]
    fn default_jvp_accurate_at_extreme_state_scales() {
        for scale in [1e-6f32, 1e-3, 1.0, 1e3, 1e5] {
            // Linear: J v = k v exactly, at any state scale.
            let f = DefaultsOnly(Linear::new(-0.7, 3));
            let z = [scale, -2.0 * scale, 0.5 * scale];
            let v = [0.3f32, 1.0, -1.0];
            let mut out = [0.0f32; 3];
            f.jvp(0.0, &z, &v, &mut out);
            for i in 0..3 {
                // Pre-fix failure mode was a ~100% error (FD collapsed to 0
                // at large ‖z‖), so a 2% band is ample to pin the fix while
                // leaving room for f32 rounding in the difference quotient.
                let exact = -0.7 * v[i];
                assert!(
                    (out[i] - exact).abs() < 2e-2 * exact.abs().max(1e-3),
                    "linear scale {scale}: jvp[{i}] {} vs {exact}",
                    out[i]
                );
            }
            // Van der Pol: nonlinear, analytic J available as reference.
            let f = DefaultsOnly(crate::ode::analytic::VanDerPol::new(0.15));
            let z = [1.7 * scale, -0.4 * scale];
            let v = [0.5f32, -1.0];
            let mut fd = [0.0f32; 2];
            f.jvp(0.0, &z, &v, &mut fd);
            let mut exact = [0.0f32; 2];
            f.0.jvp(0.0, &z, &v, &mut exact);
            // Row 1 mixes O(scale²) Jacobian entries with O(1) ones; compare
            // against the row magnitude, not element-wise.
            let mag = exact.iter().fold(0.0f32, |m, &e| m.max(e.abs())).max(1e-3);
            for i in 0..2 {
                assert!(
                    (fd[i] - exact[i]).abs() < 2e-2 * mag,
                    "vdp scale {scale}: jvp[{i}] {} vs {} (mag {mag})",
                    fd[i],
                    exact[i]
                );
            }
        }
    }

    /// Default `vjp_batch` loops `vjp` bit-identically per sample.
    #[test]
    fn default_vjp_batch_matches_scalar() {
        let f = Linear::new(-0.5, 2);
        let ts = [0.0f64, 1.0, 2.0];
        let zs = [1.0f32, 2.0, -1.0, 0.5, 4.0, -4.0];
        let ws = [0.3f32, -0.7, 1.0, 0.2, -0.1, 0.8];
        let mut wjzs = [0.0f32; 6];
        let mut wjps = [0.0f32; 3];
        f.vjp_batch(&ts, &zs, &ws, &mut wjzs, &mut wjps);
        for i in 0..3 {
            let mut wjz = [0.0f32; 2];
            let mut wjp = [0.0f32; 1];
            f.vjp(ts[i], &zs[i * 2..(i + 1) * 2], &ws[i * 2..(i + 1) * 2], &mut wjz, &mut wjp);
            assert_eq!(&wjzs[i * 2..(i + 1) * 2], &wjz, "sample {i}");
            assert_eq!(wjps[i], wjp[0], "sample {i} param row");
        }
    }

    /// An inner dynamics that records which entry points actually ran —
    /// stand-in for a backend whose `eval_batch`/`vjp_batch` overrides are
    /// the fast path (single dispatch) that wrapping must not disable.
    struct BatchMarking {
        inner: Linear,
        batch_evals: std::cell::Cell<usize>,
        scalar_evals: std::cell::Cell<usize>,
        batch_vjps: std::cell::Cell<usize>,
    }
    impl OdeFunc for BatchMarking {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn n_params(&self) -> usize {
            self.inner.n_params()
        }
        fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
            self.scalar_evals.set(self.scalar_evals.get() + 1);
            self.inner.eval(t, z, dz)
        }
        fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
            self.batch_evals.set(self.batch_evals.get() + 1);
            self.inner.eval_batch(ts, zs, dzs)
        }
        fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
            self.inner.vjp(t, z, w, wjz, wjp)
        }
        fn vjp_batch(
            &self,
            ts: &[f64],
            zs: &[f32],
            ws: &[f32],
            wjzs: &mut [f32],
            wjps: &mut [f32],
        ) {
            self.batch_vjps.set(self.batch_vjps.get() + 1);
            self.inner.vjp_batch(ts, zs, ws, wjzs, wjps)
        }
        fn params(&self) -> &[f32] {
            self.inner.params()
        }
    }

    /// Regression: `CountingFunc` must forward `eval_batch`/`vjp_batch` to
    /// the inner dynamics (one batched dispatch, zero scalar calls) while
    /// still counting per sample — previously the trait default looped the
    /// wrapper's scalar `eval`, silently disabling any inner fast path and
    /// making batched-vs-scalar NFE comparisons measure different code.
    #[test]
    fn counting_wrapper_forwards_batch_entry_points() {
        let f = CountingFunc::new(BatchMarking {
            inner: Linear::new(-0.5, 2),
            batch_evals: std::cell::Cell::new(0),
            scalar_evals: std::cell::Cell::new(0),
            batch_vjps: std::cell::Cell::new(0),
        });
        let ts = [0.0f64, 0.5, 1.0];
        let zs = [1.0f32, 2.0, -1.0, 0.5, 4.0, -4.0];
        let mut dzs = [0.0f32; 6];
        f.eval_batch(&ts, &zs, &mut dzs);
        assert_eq!(f.inner.batch_evals.get(), 1, "inner override must run once");
        assert_eq!(f.inner.scalar_evals.get(), 0, "fast path must not fall back to eval");
        assert_eq!(f.evals(), 3, "NFE meter counts per sample");
        // Results are the inner fast path's, bit-identical to scalar.
        let mut expect = [0.0f32; 2];
        for i in 0..3 {
            f.inner.inner.eval(ts[i], &zs[i * 2..(i + 1) * 2], &mut expect);
            assert_eq!(&dzs[i * 2..(i + 1) * 2], &expect, "sample {i}");
        }

        let ws = [0.3f32, -0.7, 1.0, 0.2, -0.1, 0.8];
        let mut wjzs = [0.0f32; 6];
        let mut wjps = [0.0f32; 3];
        f.vjp_batch(&ts, &zs, &ws, &mut wjzs, &mut wjps);
        assert_eq!(f.inner.batch_vjps.get(), 1);
        assert_eq!(f.vjps(), 3, "VJP meter counts per sample");
    }
}
