//! The dynamics interface `f(z, t, θ)` (paper Eq. 1).
//!
//! Implementations are either **analytic** (closed-form Rust, see
//! [`super::analytic`]) or **AOT-compiled neural dynamics** executed through
//! PJRT ([`crate::runtime::hlo_func::HloOdeFunc`]). The gradient methods in
//! [`crate::grad`] only speak this trait, so every method runs unchanged on
//! both kinds of dynamics.

/// Continuous dynamics with parameters, evaluated by the solver hot loop.
///
/// The state is a flat `[f32]` buffer of length [`OdeFunc::dim`] (batch
/// dimensions flattened). Times are `f64` to keep the step-size arithmetic
/// exact; states are `f32` matching the XLA artifacts.
pub trait OdeFunc {
    /// Flat state dimension.
    fn dim(&self) -> usize;

    /// Number of trainable parameters (0 for fixed analytic dynamics).
    fn n_params(&self) -> usize {
        0
    }

    /// `dz = f(t, z)`.
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]);

    /// Evaluate the dynamics for `ts.len()` independent samples packed
    /// row-major in `zs` (`n × dim`), each at its own time `ts[i]`, writing
    /// the derivatives into `dzs` with the same layout.
    ///
    /// Default: one `eval` per sample, bit-identical to the scalar path —
    /// which is what [`crate::ode::integrate_batch`]'s equivalence guarantee
    /// relies on. Backends that can amortize dispatch overhead (a single
    /// batched HLO call through the PJRT engine, SIMD over the batch axis)
    /// override this.
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        let d = self.dim();
        debug_assert_eq!(zs.len(), ts.len() * d);
        debug_assert_eq!(dzs.len(), ts.len() * d);
        for (i, &t) in ts.iter().enumerate() {
            self.eval(t, &zs[i * d..(i + 1) * d], &mut dzs[i * d..(i + 1) * d]);
        }
    }

    /// Vector-Jacobian product: given `w`, compute
    /// `wjz = wᵀ ∂f/∂z` and accumulate `wᵀ ∂f/∂θ` into `wjp` (`+=`).
    ///
    /// `wjp` has length [`OdeFunc::n_params`] and is *accumulated into* so a
    /// backward sweep can sum contributions without temporaries.
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]);

    /// Jacobian-vector product `∂f/∂z · v`. Default: central finite
    /// difference via two `eval` calls — adequate for the naive method's
    /// step-size-chain terms; override for exactness.
    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        let n = self.dim();
        let vnorm = crate::tensor::norm2(v);
        if vnorm == 0.0 {
            out.fill(0.0);
            return;
        }
        let eps = (1e-4 / vnorm).max(1e-7) as f32;
        let mut zp = z.to_vec();
        let mut zm = z.to_vec();
        for i in 0..n {
            zp[i] += eps * v[i];
            zm[i] -= eps * v[i];
        }
        let mut fp = vec![0.0f32; n];
        self.eval(t, &zp, &mut fp);
        self.eval(t, &zm, out);
        for i in 0..n {
            out[i] = (fp[i] - out[i]) / (2.0 * eps);
        }
    }

    /// Current parameter vector (empty for parameterless dynamics).
    fn params(&self) -> &[f32] {
        &[]
    }

    /// Replace the parameter vector. Panics if `p.len() != n_params()`.
    fn set_params(&mut self, p: &[f32]) {
        assert_eq!(p.len(), 0, "dynamics has no parameters");
    }
}

/// Blanket impl so `&F` works wherever `impl OdeFunc` is expected.
impl<F: OdeFunc + ?Sized> OdeFunc for &F {
    fn dim(&self) -> usize {
        (**self).dim()
    }
    fn n_params(&self) -> usize {
        (**self).n_params()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        (**self).eval(t, z, dz)
    }
    fn eval_batch(&self, ts: &[f64], zs: &[f32], dzs: &mut [f32]) {
        (**self).eval_batch(ts, zs, dzs)
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        (**self).vjp(t, z, w, wjz, wjp)
    }
    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        (**self).jvp(t, z, v, out)
    }
    fn params(&self) -> &[f32] {
        (**self).params()
    }
}

/// Wraps any `OdeFunc` and counts evaluations — the paper's NFE metric
/// (`N_f × N_t × m` accounting of Table 1).
pub struct CountingFunc<F> {
    pub inner: F,
    evals: std::cell::Cell<usize>,
    vjps: std::cell::Cell<usize>,
    jvps: std::cell::Cell<usize>,
}

impl<F: OdeFunc> CountingFunc<F> {
    pub fn new(inner: F) -> Self {
        CountingFunc {
            inner,
            evals: std::cell::Cell::new(0),
            vjps: std::cell::Cell::new(0),
            jvps: std::cell::Cell::new(0),
        }
    }

    pub fn evals(&self) -> usize {
        self.evals.get()
    }
    pub fn vjps(&self) -> usize {
        self.vjps.get()
    }
    pub fn jvps(&self) -> usize {
        self.jvps.get()
    }
    pub fn reset(&self) {
        self.evals.set(0);
        self.vjps.set(0);
        self.jvps.set(0);
    }
}

impl<F: OdeFunc> OdeFunc for CountingFunc<F> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn n_params(&self) -> usize {
        self.inner.n_params()
    }
    fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
        self.evals.set(self.evals.get() + 1);
        self.inner.eval(t, z, dz)
    }
    fn vjp(&self, t: f64, z: &[f32], w: &[f32], wjz: &mut [f32], wjp: &mut [f32]) {
        self.vjps.set(self.vjps.get() + 1);
        self.inner.vjp(t, z, w, wjz, wjp)
    }
    fn jvp(&self, t: f64, z: &[f32], v: &[f32], out: &mut [f32]) {
        self.jvps.set(self.jvps.get() + 1);
        self.inner.jvp(t, z, v, out)
    }
    fn params(&self) -> &[f32] {
        self.inner.params()
    }
    fn set_params(&mut self, p: &[f32]) {
        self.inner.set_params(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::Linear;

    #[test]
    fn counting_wrapper_counts() {
        let f = CountingFunc::new(Linear::new(-1.0, 1));
        let mut dz = [0.0f32];
        f.eval(0.0, &[1.0], &mut dz);
        f.eval(0.0, &[1.0], &mut dz);
        let mut wjp = [0.0f32];
        let mut wjz = [0.0f32];
        f.vjp(0.0, &[1.0], &[1.0], &mut wjz, &mut wjp);
        assert_eq!(f.evals(), 2);
        assert_eq!(f.vjps(), 1);
        f.reset();
        assert_eq!(f.evals(), 0);
    }

    #[test]
    fn default_jvp_matches_analytic_for_linear() {
        // f = kz  =>  J v = k v.
        let f = Linear::new(-0.7, 3);
        let z = [1.0f32, -2.0, 0.5];
        let v = [0.3f32, 1.0, -1.0];
        let mut out = [0.0f32; 3];
        // Force the default finite-difference path.
        struct NoJvp(Linear);
        impl OdeFunc for NoJvp {
            fn dim(&self) -> usize {
                self.0.dim()
            }
            fn eval(&self, t: f64, z: &[f32], dz: &mut [f32]) {
                self.0.eval(t, z, dz)
            }
            fn vjp(&self, t: f64, z: &[f32], w: &[f32], a: &mut [f32], b: &mut [f32]) {
                self.0.vjp(t, z, w, a, b)
            }
        }
        NoJvp(f).jvp(0.0, &z, &v, &mut out);
        for i in 0..3 {
            assert!((out[i] - (-0.7 * v[i])).abs() < 1e-3, "{:?}", out);
        }
    }

    #[test]
    fn default_eval_batch_matches_scalar_and_counts() {
        let f = CountingFunc::new(Linear::new(-0.5, 2));
        let ts = [0.0f64, 1.0, 2.0];
        let zs = [1.0f32, 2.0, -1.0, 0.5, 4.0, -4.0];
        let mut dzs = [0.0f32; 6];
        f.eval_batch(&ts, &zs, &mut dzs);
        // The default loops `eval`, so the NFE meter sees every sample.
        assert_eq!(f.evals(), 3);
        let mut expect = [0.0f32; 2];
        for i in 0..3 {
            f.inner.eval(ts[i], &zs[i * 2..(i + 1) * 2], &mut expect);
            assert_eq!(&dzs[i * 2..(i + 1) * 2], &expect, "sample {i}");
        }
    }

    #[test]
    fn default_jvp_zero_vector() {
        let f = Linear::new(2.0, 2);
        let mut out = [9.0f32; 2];
        f.jvp(0.0, &[1.0, 1.0], &[0.0, 0.0], &mut out);
        assert_eq!(out, [0.0, 0.0]);
    }
}
