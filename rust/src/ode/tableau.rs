//! Butcher tableaus for the explicit solvers evaluated in the paper
//! (Table 2: HeunEuler, RK23, RK45 adaptive; Euler, RK2, RK4 fixed-step).
//!
//! A tableau `(A, b, c)` defines the step map
//! `ψ_h(t, z) = z + h Σ_j b_j k_j`, `k_j = f(t + c_j h, z + h Σ_l a_jl k_l)`.
//! Adaptive tableaus carry embedded error weights `e = b − b*` so the local
//! truncation error estimate is `h Σ_j e_j k_j` (paper Eq. 10/13).

/// An explicit Butcher tableau with optional embedded error weights.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Tableau {
    /// Human-readable solver name as used in the paper's tables.
    pub name: &'static str,
    /// Order `p` of the propagating solution.
    pub order: u32,
    /// Number of stages `s`.
    pub stages: usize,
    /// Strictly-lower-triangular stage coefficients; row `j` has `j` entries.
    pub a: &'static [&'static [f64]],
    /// Propagating solution weights (length `s`).
    pub b: &'static [f64],
    /// Embedded error weights `b − b*` (length `s`); `None` for fixed-step-only.
    pub b_err: Option<&'static [f64]>,
    /// Stage abscissae (length `s`).
    pub c: &'static [f64],
    /// First-Same-As-Last: last stage of an accepted step equals `f(t+h, z+h·Σb k)`
    /// and can seed the next step's first stage.
    pub fsal: bool,
}

impl Tableau {
    /// True iff the tableau carries an embedded error estimate and can drive
    /// an adaptive controller.
    pub fn adaptive(&self) -> bool {
        self.b_err.is_some()
    }

    /// Exponent used by the controller: `1 / (q + 1)` where `q` is the order
    /// of the *lower* embedded method (local-extrapolation convention).
    pub fn err_exponent(&self) -> f64 {
        // For p(p-1) embedded pairs the error estimate is O(h^p); stepsize
        // scales with err^(-1/p)... we follow the standard convention
        // err ~ h^(q+1) with q = min(order, embedded order) = order - 1 for
        // our pairs, except HeunEuler where the propagating order is 2 and
        // the embedded is 1. Using the propagating order works uniformly:
        1.0 / self.order as f64
    }

    /// Number of `f` evaluations for one step attempt, accounting for FSAL
    /// reuse on accepted steps.
    pub fn nfe_per_step(&self, fsal_reuse: bool) -> usize {
        if self.fsal && fsal_reuse {
            self.stages - 1
        } else {
            self.stages
        }
    }
}

/// Forward Euler (order 1, fixed step).
pub fn euler() -> &'static Tableau {
    &EULER
}
static EULER: Tableau = Tableau {
    name: "Euler",
    order: 1,
    stages: 1,
    a: &[&[]],
    b: &[1.0],
    b_err: None,
    c: &[0.0],
    fsal: false,
};

/// Explicit midpoint (RK2, order 2, fixed step) — the paper's "RK2".
pub fn rk2() -> &'static Tableau {
    &RK2
}
static RK2: Tableau = Tableau {
    name: "RK2",
    order: 2,
    stages: 2,
    a: &[&[], &[0.5]],
    b: &[0.0, 1.0],
    b_err: None,
    c: &[0.0, 0.5],
    fsal: false,
};

/// Classic RK4 (order 4, fixed step).
pub fn rk4() -> &'static Tableau {
    &RK4
}
static RK4: Tableau = Tableau {
    name: "RK4",
    order: 4,
    stages: 4,
    a: &[&[], &[0.5], &[0.0, 0.5], &[0.0, 0.0, 1.0]],
    b: &[1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
    b_err: None,
    c: &[0.0, 0.5, 0.5, 1.0],
    fsal: false,
};

/// Heun–Euler 2(1) adaptive pair — the paper's training solver for NODE18.
/// Propagates the order-2 (Heun) solution, error against forward Euler.
pub fn heun_euler() -> &'static Tableau {
    &HEUN_EULER
}
static HEUN_EULER: Tableau = Tableau {
    name: "HeunEuler",
    order: 2,
    stages: 2,
    a: &[&[], &[1.0]],
    b: &[0.5, 0.5],
    // b* (Euler) = [1, 0]  =>  e = b − b* = [−1/2, 1/2]
    b_err: Some(&[-0.5, 0.5]),
    c: &[0.0, 1.0],
    fsal: false,
};

/// Bogacki–Shampine 3(2) ("RK23"), FSAL.
pub fn rk23() -> &'static Tableau {
    &BS23
}
static BS23: Tableau = Tableau {
    name: "RK23",
    order: 3,
    stages: 4,
    a: &[
        &[],
        &[0.5],
        &[0.0, 0.75],
        &[2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
    ],
    b: &[2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0],
    // b* = [7/24, 1/4, 1/3, 1/8]
    b_err: Some(&[
        2.0 / 9.0 - 7.0 / 24.0,
        1.0 / 3.0 - 0.25,
        4.0 / 9.0 - 1.0 / 3.0,
        -0.125,
    ]),
    c: &[0.0, 0.5, 0.75, 1.0],
    fsal: true,
};

/// Dormand–Prince 5(4) ("RK45" / Dopri5 / MATLAB ode45), FSAL.
pub fn dopri5() -> &'static Tableau {
    &DOPRI5
}
static DOPRI5: Tableau = Tableau {
    name: "RK45",
    order: 5,
    stages: 7,
    a: &[
        &[],
        &[1.0 / 5.0],
        &[3.0 / 40.0, 9.0 / 40.0],
        &[44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
        &[
            19372.0 / 6561.0,
            -25360.0 / 2187.0,
            64448.0 / 6561.0,
            -212.0 / 729.0,
        ],
        &[
            9017.0 / 3168.0,
            -355.0 / 33.0,
            46732.0 / 5247.0,
            49.0 / 176.0,
            -5103.0 / 18656.0,
        ],
        &[
            35.0 / 384.0,
            0.0,
            500.0 / 1113.0,
            125.0 / 192.0,
            -2187.0 / 6784.0,
            11.0 / 84.0,
        ],
    ],
    b: &[
        35.0 / 384.0,
        0.0,
        500.0 / 1113.0,
        125.0 / 192.0,
        -2187.0 / 6784.0,
        11.0 / 84.0,
        0.0,
    ],
    // b* = [5179/57600, 0, 7571/16695, 393/640, −92097/339200, 187/2100, 1/40]
    b_err: Some(&[
        35.0 / 384.0 - 5179.0 / 57600.0,
        0.0,
        500.0 / 1113.0 - 7571.0 / 16695.0,
        125.0 / 192.0 - 393.0 / 640.0,
        -2187.0 / 6784.0 + 92097.0 / 339200.0,
        11.0 / 84.0 - 187.0 / 2100.0,
        -1.0 / 40.0,
    ]),
    c: &[0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
    fsal: true,
};

/// All tableaus by paper name; used by the CLI and the Table 2/6/7 sweeps.
pub fn by_name(name: &str) -> Option<&'static Tableau> {
    match name.to_ascii_lowercase().as_str() {
        "euler" => Some(euler()),
        "rk2" | "midpoint" => Some(rk2()),
        "rk4" => Some(rk4()),
        "heuneuler" | "heun_euler" | "heun-euler" => Some(heun_euler()),
        "rk23" | "bs23" | "bogacki-shampine" => Some(rk23()),
        "rk45" | "dopri5" | "dormand-prince" | "ode45" => Some(dopri5()),
        _ => None,
    }
}

/// The adaptive solvers of paper Table 2.
pub fn adaptive_solvers() -> [&'static Tableau; 3] {
    [heun_euler(), rk23(), dopri5()]
}

/// The fixed-step solvers of paper Table 2.
pub fn fixed_solvers() -> [&'static Tableau; 3] {
    [euler(), rk2(), rk4()]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_consistency(t: &Tableau) {
        assert_eq!(t.b.len(), t.stages);
        assert_eq!(t.c.len(), t.stages);
        assert_eq!(t.a.len(), t.stages);
        for (j, row) in t.a.iter().enumerate() {
            assert_eq!(row.len(), j, "{}: row {} must have {} entries", t.name, j, j);
            // c_j must equal the row sum (standard consistency condition).
            let row_sum: f64 = row.iter().sum();
            assert!(
                (row_sum - t.c[j]).abs() < 1e-12,
                "{}: c[{}]={} != row sum {}",
                t.name,
                j,
                t.c[j],
                row_sum
            );
        }
        // First order condition: sum b = 1.
        let bs: f64 = t.b.iter().sum();
        assert!((bs - 1.0).abs() < 1e-12, "{}: sum b = {}", t.name, bs);
        if let Some(e) = t.b_err {
            assert_eq!(e.len(), t.stages);
            // The embedded method must also be consistent: sum b* = 1, i.e.
            // sum e = 0.
            let es: f64 = e.iter().sum();
            assert!(es.abs() < 1e-12, "{}: sum e = {}", t.name, es);
        }
    }

    #[test]
    fn all_tableaus_consistent() {
        for t in [euler(), rk2(), rk4(), heun_euler(), rk23(), dopri5()] {
            check_consistency(t);
        }
    }

    /// Second-order condition: b·c = 1/2 for every method of order >= 2.
    #[test]
    fn order2_condition() {
        for t in [rk2(), rk4(), heun_euler(), rk23(), dopri5()] {
            let bc: f64 = t.b.iter().zip(t.c).map(|(b, c)| b * c).sum();
            assert!((bc - 0.5).abs() < 1e-12, "{}: b.c = {}", t.name, bc);
        }
    }

    /// Third-order conditions for methods of order >= 3.
    #[test]
    fn order3_conditions() {
        for t in [rk4(), rk23(), dopri5()] {
            let bc2: f64 = t.b.iter().zip(t.c).map(|(b, c)| b * c * c).sum();
            assert!((bc2 - 1.0 / 3.0).abs() < 1e-12, "{}: b.c^2 = {}", t.name, bc2);
            // sum_j b_j sum_l a_jl c_l = 1/6
            let mut bac = 0.0;
            for j in 0..t.stages {
                let inner: f64 = t.a[j].iter().zip(t.c).map(|(a, c)| a * c).sum();
                bac += t.b[j] * inner;
            }
            assert!((bac - 1.0 / 6.0).abs() < 1e-12, "{}: b.A.c = {}", t.name, bac);
        }
    }

    /// Fourth-order quadrature condition for methods of order >= 4.
    #[test]
    fn order4_condition() {
        for t in [rk4(), dopri5()] {
            let bc3: f64 = t.b.iter().zip(t.c).map(|(b, c)| b * c * c * c).sum();
            assert!((bc3 - 0.25).abs() < 1e-12, "{}: b.c^3 = {}", t.name, bc3);
        }
    }

    /// FSAL: last row of A equals b and c_s = 1.
    #[test]
    fn fsal_structure() {
        for t in [rk23(), dopri5()] {
            assert!(t.fsal);
            let last = t.a[t.stages - 1];
            for (l, (&a, &b)) in last.iter().zip(t.b).enumerate() {
                assert!((a - b).abs() < 1e-12, "{}: a[s][{}]={} b={}", t.name, l, a, b);
            }
            assert!((t.c[t.stages - 1] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("dopri5").unwrap().name, "RK45");
        assert_eq!(by_name("HeunEuler").unwrap().name, "HeunEuler");
        assert_eq!(by_name("euler").unwrap().name, "Euler");
        assert!(by_name("implicit-euler").is_none());
    }

    #[test]
    fn nfe_accounting() {
        assert_eq!(dopri5().nfe_per_step(true), 6);
        assert_eq!(dopri5().nfe_per_step(false), 7);
        assert_eq!(rk4().nfe_per_step(true), 4);
    }
}
