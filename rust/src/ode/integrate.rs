//! The adaptive integration loop — paper **Algorithm 1** — plus the
//! trajectory record that ACA's checkpoint strategy consumes.
//!
//! The loop advances `t → T`, retrying each step with shrinking `h` until the
//! embedded error estimate passes (`m` inner iterations in the paper's
//! notation). Accepted `(t_i, z_i)` pairs are recorded — **values only, no
//! computation graph** — which is exactly the paper's "trajectory checkpoint"
//! (Algo 2, forward pass). Rejected trials can optionally be recorded too;
//! the naive gradient method needs them to rebuild its deep computation graph.

use super::controller::Controller;
use super::func::OdeFunc;
use super::step::{rk_step, StepScratch};
use super::tableau::Tableau;
use crate::ckpt::{CheckpointStore, CkptPolicy, SegmentCache};
use crate::tensor;
use anyhow::{bail, Result};

/// A rejected step attempt (the naive method differentiates through these).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrialRecord {
    /// Step size tried.
    pub h: f64,
    /// Weighted error norm observed.
    pub err: f64,
}

/// Record of one forward integration: the accepted discretization points and
/// state values (paper Algo 2 "trajectory checkpoint"), plus bookkeeping.
///
/// The **spine** — `ts`, `hs`, `errs`, `trials` and the cost counters — is
/// always dense (`O(N_t)` scalars). State storage is delegated to a
/// [`CheckpointStore`] behind a [`CkptPolicy`]: `Dense` keeps every state
/// (bit-for-bit today's behavior); thinned policies keep sparse anchors and
/// regenerate dropped states bit-exactly through a
/// [`SegmentCache`] (see [`crate::ckpt`]).
#[derive(Debug, Clone, Default)]
pub struct Trajectory {
    /// Accepted times `t_0 .. t_{N_t}` (monotone, endpoints exact).
    pub ts: Vec<f64>,
    /// State checkpoint storage for `z_0 .. z_{N_t}` (policy-thinned).
    pub store: CheckpointStore,
    /// Accepted step sizes, stored exactly as used by the stepper (recovering
    /// them from `ts` differences would lose a ulp and break ACA's bit-exact
    /// replay guarantee).
    pub hs: Vec<f64>,
    /// Error norm of each *accepted* step `i -> i+1` (len = N_t).
    pub errs: Vec<f64>,
    /// Rejected trials per accepted step (len = N_t when recorded) — the
    /// failed `h`s in the order tried, ending just before the accepted one.
    pub trials: Vec<Vec<TrialRecord>>,
    /// Total number of `f` evaluations.
    pub nfe: usize,
    /// Total rejected step attempts.
    pub n_rejected: usize,
}

impl Trajectory {
    /// Number of accepted steps `N_t`.
    pub fn len(&self) -> usize {
        self.ts.len().saturating_sub(1)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Final state `z(T)` — the tail anchor, stored under every policy.
    /// `None` only for an empty trajectory (e.g. `Trajectory::default()`),
    /// which used to panic here.
    pub fn last(&self) -> Option<&[f32]> {
        self.store.last()
    }

    /// Checkpoint `z_k` if it is currently stored (`None` means the policy
    /// thinned it — fetch through [`Self::state`] instead).
    pub fn z(&self, k: usize) -> Option<&[f32]> {
        self.store.stored(k)
    }

    /// Checkpoint `z_k`, replaying from the nearest anchor when it was
    /// thinned — bit-identical to the dropped forward state (see
    /// [`crate::ckpt`]). Replay cost accrues in `cache.nfe_replay`.
    pub fn state<'a, F: OdeFunc + ?Sized>(
        &'a self,
        f: &F,
        tab: &Tableau,
        k: usize,
        cache: &'a mut SegmentCache,
    ) -> &'a [f32] {
        cache.state(f, tab, &self.ts, &self.hs, &self.store, k)
    }

    /// Iterate over all stored states `z_0 .. z_{N_t}` in order. Panics if
    /// any state was thinned — callers that tolerate thinned stores should
    /// go through [`Self::state`] with a [`SegmentCache`].
    pub fn states(&self) -> impl Iterator<Item = &[f32]> + '_ {
        (0..self.store.len())
            .map(|k| self.z(k).expect("state thinned; fetch via Trajectory::state"))
    }

    /// Accepted step size `h_i`, exactly as used in the forward pass.
    pub fn h(&self, i: usize) -> f64 {
        self.hs[i]
    }

    /// Bytes held by the checkpoint store (`O(N_f + N_t)` memory column of
    /// paper Table 1 — the `N_t` part; the transient `N_f` part lives in the
    /// step scratch). Full accounting: *stored* state checkpoints, times,
    /// step sizes, error norms, and any recorded trials — earlier versions
    /// omitted the `hs`/`errs`/`trials` vectors and under-reported the
    /// Table 1 column. Under a thinning policy the state term counts the
    /// anchors actually held, which is the point of the budget.
    pub fn checkpoint_bytes(&self) -> usize {
        use std::mem::size_of;
        self.store.bytes()
            + self.ts.len() * size_of::<f64>()
            + self.hs.len() * size_of::<f64>()
            + self.errs.len() * size_of::<f64>()
            + self.trials.iter().map(|t| t.len() * size_of::<TrialRecord>()).sum::<usize>()
    }

    /// Average inner iterations `m` (trials per accepted step, counting the
    /// accepted attempt).
    pub fn avg_m(&self) -> f64 {
        if self.len() == 0 {
            return 0.0;
        }
        (self.len() + self.n_rejected) as f64 / self.len() as f64
    }
}

/// Options for [`integrate`].
#[derive(Debug, Clone)]
pub struct IntegrateOpts {
    pub rtol: f64,
    pub atol: f64,
    /// Initial step size; `None` = auto (Hairer I.7-style heuristic).
    pub h0: Option<f64>,
    /// Fixed step size: forces non-adaptive stepping (used for the Euler /
    /// RK2 / RK4 columns of paper Table 2 and the discrete baseline).
    pub fixed_h: Option<f64>,
    /// Hard cap on accepted + rejected step attempts.
    pub max_steps: usize,
    /// Record rejected trials for the naive method.
    pub record_trials: bool,
    /// Controller overrides; `None` = [`Controller::for_tableau`].
    pub controller: Option<Controller>,
    /// Checkpoint storage policy (see [`crate::ckpt`]). `Dense` keeps every
    /// accepted state — bit-for-bit today's behavior; thinned policies bound
    /// checkpoint memory and replay dropped states bit-exactly on demand.
    pub ckpt: CkptPolicy,
}

impl Default for IntegrateOpts {
    fn default() -> Self {
        IntegrateOpts {
            rtol: 1e-3,
            atol: 1e-6,
            h0: None,
            fixed_h: None,
            max_steps: 100_000,
            record_trials: false,
            controller: None,
            ckpt: CkptPolicy::Dense,
        }
    }
}

impl IntegrateOpts {
    pub fn with_tol(rtol: f64, atol: f64) -> Self {
        IntegrateOpts { rtol, atol, ..Default::default() }
    }

    pub fn fixed(h: f64) -> Self {
        IntegrateOpts { fixed_h: Some(h), ..Default::default() }
    }
}

/// Integrate `dz/dt = f(t, z)` from `(t0, z0)` to `t1` (paper Algo 1).
///
/// Works in both directions (`t1 < t0` integrates backward — used by the
/// adjoint method and the Fig 4/5 reverse-trajectory studies). The returned
/// [`Trajectory`] is the paper's trajectory checkpoint.
pub fn integrate<F: OdeFunc + ?Sized>(
    f: &F,
    t0: f64,
    t1: f64,
    z0: &[f32],
    tab: &Tableau,
    opts: &IntegrateOpts,
) -> Result<Trajectory> {
    assert_eq!(z0.len(), f.dim(), "state length != f.dim()");
    let mut traj =
        Trajectory { store: CheckpointStore::new(f.dim(), opts.ckpt), ..Default::default() };
    traj.ts.push(t0);
    traj.store.push(z0);
    if t0 == t1 {
        return Ok(traj);
    }

    let dir = (t1 - t0).signum();
    let span = (t1 - t0).abs();
    let fixed = opts.fixed_h.is_some() || !tab.adaptive();
    let ctrl = opts.controller.unwrap_or_else(|| Controller::for_tableau(tab));

    let mut t = t0;
    let mut z = z0.to_vec();
    let mut z_next = vec![0.0f32; z.len()];
    let mut scratch = StepScratch::new();
    // Stage-0 derivative reuse: FSAL across accepted steps, and (for every
    // tableau) across retries of the same step, since k_0 = f(t, z) does not
    // depend on h. One persistent buffer — no allocation in the loop
    // (§Perf iteration 1).
    let mut k0_buf = vec![0.0f32; z.len()];
    let mut k0_valid = false;

    // Current trial step size.
    let mut h = if fixed {
        opts.fixed_h.map(|h| h.abs()).unwrap_or(span / 100.0) * dir
    } else {
        match opts.h0 {
            Some(h0) => h0.abs().min(span) * dir,
            None => {
                let h = ctrl.initial_step(f, t0, &z, dir, opts.atol, opts.rtol);
                traj.nfe += 1;
                h.abs().min(span) * dir
            }
        }
    };
    assert!(h.abs() > 0.0, "initial step size must be nonzero");

    let mut attempts = 0usize;
    let mut trial_buf: Vec<TrialRecord> = Vec::new();
    let eps_t = 1e-12 * span.max(1.0);

    while (t1 - t) * dir > eps_t {
        attempts += 1;
        if attempts > opts.max_steps {
            bail!(
                "max_steps ({}) exceeded at t={t} (h={h}); solver may be stiff at these tolerances",
                opts.max_steps
            );
        }
        // Clamp the final step to land exactly on t1.
        let h_try = if (t + h - t1) * dir > 0.0 { t1 - t } else { h };
        if h_try.abs() < 1e-14 * span.max(1.0) {
            bail!("step size underflow at t={t} (h={h_try})");
        }

        let out = rk_step(
            f,
            tab,
            t,
            h_try,
            &z,
            if k0_valid { Some(&k0_buf[..]) } else { None },
            opts.atol,
            opts.rtol,
            &mut z_next,
            None,
            &mut scratch,
        );
        traj.nfe += out.nfe;

        if !tensor::all_finite(&z_next) {
            if fixed {
                bail!("non-finite state in fixed-step integration at t={t}");
            }
            traj.n_rejected += 1;
            if opts.record_trials {
                trial_buf.push(TrialRecord { h: h_try, err: f64::INFINITY });
            }
            h = h_try * 0.5;
            k0_buf.copy_from_slice(&scratch.ks[0]);
            k0_valid = true;
            continue;
        }

        let accepted = fixed || out.err_norm <= 1.0;
        if !accepted {
            let dec = ctrl.decide(h_try, out.err_norm, 0.0);
            traj.n_rejected += 1;
            if opts.record_trials {
                trial_buf.push(TrialRecord { h: h_try, err: out.err_norm });
            }
            h = dec.h_next;
            k0_buf.copy_from_slice(&scratch.ks[0]);
            k0_valid = true;
            continue;
        }

        // Accept: advance state, record the checkpoint (values only).
        let t_new = if h_try == t1 - t { t1 } else { t + h_try };
        std::mem::swap(&mut z, &mut z_next);
        t = t_new;
        traj.ts.push(t);
        traj.store.push(&z);
        traj.hs.push(h_try);
        traj.errs.push(out.err_norm);
        if opts.record_trials {
            traj.trials.push(std::mem::take(&mut trial_buf));
        }

        // Next trial size.
        if !fixed {
            h = ctrl.decide(h_try, out.err_norm, 0.0).h_next;
        }
        // FSAL: seed the next step's first stage.
        if tab.fsal {
            k0_buf.copy_from_slice(&scratch.ks[tab.stages - 1]);
            k0_valid = true;
        } else {
            k0_valid = false;
        }
    }

    Ok(traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::{Linear, VanDerPol};
    use crate::ode::tableau;

    #[test]
    fn exp_decay_accuracy_all_adaptive_solvers() {
        let f = Linear::new(-1.0, 1);
        for tab in tableau::adaptive_solvers() {
            let opts = IntegrateOpts::with_tol(1e-6, 1e-8);
            let traj = integrate(&f, 0.0, 2.0, &[1.0], tab, &opts).unwrap();
            let exact = (-2.0f64).exp();
            let got = traj.last().unwrap()[0] as f64;
            assert!(
                (got - exact).abs() < 5e-5,
                "{}: {} vs {} ({} steps)",
                tab.name,
                got,
                exact,
                traj.len()
            );
            assert_eq!(*traj.ts.last().unwrap(), 2.0, "endpoint must be exact");
            assert_eq!(traj.errs.len(), traj.len());
        }
    }

    #[test]
    fn fixed_step_solvers_converge() {
        let f = Linear::new(-1.0, 1);
        let exact = (-1.0f64).exp();
        for (tab, tol) in [
            (tableau::euler(), 1e-2),
            (tableau::rk2(), 1e-4),
            (tableau::rk4(), 1e-8),
        ] {
            let traj = integrate(&f, 0.0, 1.0, &[1.0], tab, &IntegrateOpts::fixed(0.01)).unwrap();
            assert_eq!(traj.len(), 100);
            let got = traj.last().unwrap()[0] as f64;
            assert!((got - exact).abs() < tol, "{}: {} vs {}", tab.name, got, exact);
        }
    }

    #[test]
    fn backward_integration_inverts_forward() {
        let f = VanDerPol::new(0.15);
        let z0 = [2.0f32, 0.0];
        let opts = IntegrateOpts::with_tol(1e-9, 1e-9);
        let fwd = integrate(&f, 0.0, 5.0, &z0, tableau::dopri5(), &opts).unwrap();
        let bwd =
            integrate(&f, 5.0, 0.0, fwd.last().unwrap(), tableau::dopri5(), &opts).unwrap();
        // At tight tolerance the reverse solve recovers z0 well; at loose
        // tolerance it does NOT (paper Fig 4) — see the fig4 experiment.
        let d = crate::tensor::max_abs_diff(bwd.last().unwrap(), &z0);
        assert!(d < 1e-3, "reverse error {d} too large at tight tol");
    }

    #[test]
    fn tolerance_controls_step_count() {
        let f = VanDerPol::new(0.15);
        let loose = integrate(
            &f,
            0.0,
            10.0,
            &[2.0, 0.0],
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-3, 1e-6),
        )
        .unwrap();
        let tight = integrate(
            &f,
            0.0,
            10.0,
            &[2.0, 0.0],
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-9, 1e-12),
        )
        .unwrap();
        assert!(
            tight.len() > loose.len(),
            "tighter tol must need more steps: {} vs {}",
            tight.len(),
            loose.len()
        );
    }

    #[test]
    fn times_monotone_and_exact_endpoints() {
        let f = VanDerPol::new(1.0);
        let traj = integrate(
            &f,
            0.0,
            7.5,
            &[1.0, 0.5],
            tableau::rk23(),
            &IntegrateOpts::default(),
        )
        .unwrap();
        assert_eq!(traj.ts[0], 0.0);
        assert_eq!(*traj.ts.last().unwrap(), 7.5);
        for w in traj.ts.windows(2) {
            assert!(w[1] > w[0], "times must increase: {:?}", w);
        }
        assert_eq!(traj.store.len(), traj.ts.len());
    }

    #[test]
    fn record_trials_structure() {
        let f = VanDerPol::new(5.0); // moderately stiff: rejections happen
        let mut opts = IntegrateOpts::with_tol(1e-6, 1e-8);
        opts.record_trials = true;
        opts.h0 = Some(1.0); // force initial rejections
        let traj = integrate(&f, 0.0, 3.0, &[2.0, 0.0], tableau::dopri5(), &opts).unwrap();
        assert_eq!(traj.trials.len(), traj.len());
        let total_rej: usize = traj.trials.iter().map(|t| t.len()).sum();
        assert_eq!(total_rej, traj.n_rejected);
        assert!(traj.n_rejected > 0, "expected at least one rejection");
        for trials in &traj.trials {
            for tr in trials {
                assert!(tr.err > 1.0 || !tr.err.is_finite(), "recorded trial must be a rejection");
            }
        }
    }

    #[test]
    fn zero_span_returns_initial() {
        let f = Linear::new(1.0, 2);
        let traj =
            integrate(&f, 1.0, 1.0, &[3.0, 4.0], tableau::dopri5(), &IntegrateOpts::default())
                .unwrap();
        assert_eq!(traj.len(), 0);
        assert_eq!(traj.last().unwrap(), &[3.0, 4.0]);
    }

    /// Bugfix: `last()` used to panic on an empty trajectory (the
    /// zero-states edge a `Trajectory::default()` or a retired zero-span
    /// record hands to generic consumers). It now reports `None`; any
    /// solved trajectory — including a zero-span solve — has its initial
    /// state and reports `Some`.
    #[test]
    fn empty_trajectory_last_is_none() {
        let empty = Trajectory::default();
        assert!(empty.last().is_none());
        assert!(empty.z(0).is_none());
        assert_eq!(empty.len(), 0);
        let f = Linear::new(1.0, 1);
        let traj =
            integrate(&f, 2.0, 2.0, &[7.0], tableau::dopri5(), &IntegrateOpts::default())
                .unwrap();
        assert_eq!(traj.last().unwrap(), &[7.0], "zero-span solve keeps its initial state");
    }

    #[test]
    fn max_steps_errors_out() {
        let f = Linear::new(1.0, 1);
        let mut opts = IntegrateOpts::with_tol(1e-12, 1e-14);
        opts.max_steps = 3;
        let r = integrate(&f, 0.0, 100.0, &[1.0], tableau::heun_euler(), &opts);
        assert!(r.is_err());
    }

    #[test]
    fn nfe_accounting_fixed_step() {
        use crate::ode::func::CountingFunc;
        let f = CountingFunc::new(Linear::new(-1.0, 1));
        let traj =
            integrate(&f, 0.0, 1.0, &[1.0], tableau::rk4(), &IntegrateOpts::fixed(0.1)).unwrap();
        assert_eq!(traj.len(), 10);
        assert_eq!(f.evals(), 40, "RK4 = 4 evals x 10 steps");
        assert_eq!(traj.nfe, f.evals());
    }

    #[test]
    fn fsal_saves_evaluations() {
        use crate::ode::func::CountingFunc;
        let f = CountingFunc::new(Linear::new(-1.0, 1));
        let opts = IntegrateOpts { h0: Some(0.1), ..IntegrateOpts::with_tol(1e-6, 1e-8) };
        let traj = integrate(&f, 0.0, 1.0, &[1.0], tableau::dopri5(), &opts).unwrap();
        // With FSAL + no rejections: 7 evals first step, 6 thereafter.
        let expect = 7 + 6 * (traj.len() - 1) + 6 * traj.n_rejected;
        assert_eq!(
            f.evals(),
            expect,
            "nfe {} != expected {} ({} steps, {} rejected)",
            f.evals(),
            expect,
            traj.len(),
            traj.n_rejected
        );
    }

    #[test]
    fn checkpoint_bytes_scale_with_steps() {
        let f = Linear::new(-1.0, 4);
        let traj = integrate(
            &f,
            0.0,
            1.0,
            &[1.0, 1.0, 1.0, 1.0],
            tableau::rk4(),
            &IntegrateOpts::fixed(0.1),
        )
        .unwrap();
        // 11 checkpoints x 4 f32 + 11 f64 timestamps + 10 f64 step sizes
        // + 10 f64 error norms (no trials recorded on a fixed-step run).
        assert_eq!(traj.checkpoint_bytes(), 11 * 4 * 4 + 11 * 8 + 10 * 8 + 10 * 8);
    }
}
