//! Dense output: evaluate a solved [`Trajectory`] at arbitrary times via
//! cubic Hermite interpolation between checkpoints.
//!
//! Used by the Fig 4/5 trajectory plots and by inference-time decoding. (The
//! time-series *training* path instead integrates segment-wise to the exact
//! observation times so gradients stay exact — see
//! [`crate::train::segmented`].)

use super::func::OdeFunc;
use super::integrate::Trajectory;

/// Cubic-Hermite dense interpolant over a trajectory. Derivatives at the
/// checkpoints are (re)computed with `f` at construction (`N_t + 1` extra
/// evaluations — cheaper than storing all stage values).
pub struct DenseOutput {
    ts: Vec<f64>,
    zs: Vec<Vec<f32>>,
    fs: Vec<Vec<f32>>,
}

impl DenseOutput {
    /// Build an interpolant from a trajectory and its dynamics.
    ///
    /// Requires every knot state to be stored (the default
    /// [`CkptPolicy::Dense`](crate::ckpt::CkptPolicy) — interpolation wants
    /// all knots anyway, so thinning buys nothing here); panics on a
    /// thinned store.
    pub fn new<F: OdeFunc + ?Sized>(f: &F, traj: &Trajectory) -> Self {
        let zs: Vec<Vec<f32>> = traj.states().map(|z| z.to_vec()).collect();
        let dim = zs[0].len();
        let fs = traj
            .ts
            .iter()
            .zip(&zs)
            .map(|(&t, z)| {
                let mut d = vec![0.0f32; dim];
                f.eval(t, z, &mut d);
                d
            })
            .collect();
        DenseOutput { ts: traj.ts.clone(), zs, fs }
    }

    /// Time domain `[t_min, t_max]` covered by the interpolant.
    pub fn domain(&self) -> (f64, f64) {
        let a = self.ts[0];
        let b = *self.ts.last().unwrap();
        (a.min(b), a.max(b))
    }

    /// Locate the segment containing `t` (clamps to the domain).
    fn segment(&self, t: f64) -> usize {
        let n = self.ts.len();
        if n < 2 {
            return 0;
        }
        let increasing = self.ts[n - 1] >= self.ts[0];
        // Binary search over possibly-decreasing knots.
        let mut lo = 0usize;
        let mut hi = n - 2;
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            let before = if increasing { self.ts[mid] <= t } else { self.ts[mid] >= t };
            if before {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        lo
    }

    /// Interpolated state at time `t` (clamped to the trajectory domain).
    pub fn eval(&self, t: f64) -> Vec<f32> {
        let i = self.segment(t);
        if self.ts.len() < 2 {
            return self.zs[0].clone();
        }
        let (t0, t1) = (self.ts[i], self.ts[i + 1]);
        let h = t1 - t0;
        let s = if h == 0.0 { 0.0 } else { ((t - t0) / h).clamp(0.0, 1.0) };
        let (z0, z1) = (&self.zs[i], &self.zs[i + 1]);
        let (f0, f1) = (&self.fs[i], &self.fs[i + 1]);
        // Hermite basis.
        let s2 = s * s;
        let s3 = s2 * s;
        let h00 = (2.0 * s3 - 3.0 * s2 + 1.0) as f32;
        let h10 = ((s3 - 2.0 * s2 + s) * h) as f32;
        let h01 = (-2.0 * s3 + 3.0 * s2) as f32;
        let h11 = ((s3 - s2) * h) as f32;
        z0.iter()
            .zip(z1)
            .zip(f0.iter().zip(f1))
            .map(|((&a, &b), (&fa, &fb))| h00 * a + h10 * fa + h01 * b + h11 * fb)
            .collect()
    }

    /// Evaluate the interpolant at every time in `ts`, in order. This is
    /// the serving-layer entry point for dense-output observation grids:
    /// each grid point is exactly [`DenseOutput::eval`] at that time, so a
    /// served observation is bit-identical to a direct-solve evaluation.
    pub fn eval_grid(&self, ts: &[f64]) -> Vec<Vec<f32>> {
        ts.iter().map(|&t| self.eval(t)).collect()
    }

    /// Sample the interpolant on a uniform grid of `n` points (inclusive).
    pub fn sample(&self, n: usize) -> (Vec<f64>, Vec<Vec<f32>>) {
        let (a, b) = (self.ts[0], *self.ts.last().unwrap());
        let ts: Vec<f64> = (0..n)
            .map(|i| a + (b - a) * i as f64 / (n - 1).max(1) as f64)
            .collect();
        let zs = ts.iter().map(|&t| self.eval(t)).collect();
        (ts, zs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::analytic::Linear;
    use crate::ode::{integrate, tableau, IntegrateOpts};

    fn make() -> (Linear, Trajectory) {
        let f = Linear::new(-1.0, 1);
        let traj = integrate(
            &f,
            0.0,
            2.0,
            &[1.0],
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-8, 1e-10),
        )
        .unwrap();
        (f, traj)
    }

    #[test]
    fn interpolates_knots_exactly() {
        let (f, traj) = make();
        let dense = DenseOutput::new(&f, &traj);
        for (i, &t) in traj.ts.iter().enumerate() {
            let z = dense.eval(t);
            assert!((z[0] - traj.z(i).unwrap()[0]).abs() < 1e-7, "knot {i}");
        }
    }

    #[test]
    fn matches_exact_solution_between_knots() {
        let (f, traj) = make();
        let dense = DenseOutput::new(&f, &traj);
        for k in 0..50 {
            let t = 2.0 * k as f64 / 49.0;
            let got = dense.eval(t)[0] as f64;
            let exact = (-t).exp();
            assert!((got - exact).abs() < 1e-5, "t={t}: {got} vs {exact}");
        }
    }

    #[test]
    fn clamps_outside_domain() {
        let (f, traj) = make();
        let dense = DenseOutput::new(&f, &traj);
        let before = dense.eval(-1.0);
        let after = dense.eval(3.0);
        assert!((before[0] - 1.0).abs() < 1e-6);
        assert!((after[0] as f64 - (-2.0f64).exp()).abs() < 1e-4);
    }

    #[test]
    fn reverse_time_trajectory_interpolation() {
        let f = Linear::new(-1.0, 1);
        let z1 = [(-2.0f64).exp() as f32];
        let traj = integrate(
            &f,
            2.0,
            0.0,
            &z1,
            tableau::dopri5(),
            &IntegrateOpts::with_tol(1e-8, 1e-10),
        )
        .unwrap();
        let dense = DenseOutput::new(&f, &traj);
        let mid = dense.eval(1.0)[0] as f64;
        assert!((mid - (-1.0f64).exp()).abs() < 1e-4, "{mid}");
    }

    #[test]
    fn eval_grid_is_pointwise_eval() {
        let (f, traj) = make();
        let dense = DenseOutput::new(&f, &traj);
        let grid = [0.0, 0.3, 1.1, 1.9, 2.0];
        let zs = dense.eval_grid(&grid);
        assert_eq!(zs.len(), grid.len());
        for (&t, z) in grid.iter().zip(&zs) {
            assert_eq!(z[0].to_bits(), dense.eval(t)[0].to_bits(), "t={t}");
        }
    }

    #[test]
    fn sample_grid_shape() {
        let (f, traj) = make();
        let dense = DenseOutput::new(&f, &traj);
        let (ts, zs) = dense.sample(11);
        assert_eq!(ts.len(), 11);
        assert_eq!(zs.len(), 11);
        assert_eq!(ts[0], 0.0);
        assert_eq!(ts[10], 2.0);
    }
}
