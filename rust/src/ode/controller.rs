//! Adaptive step-size control — the `decay_factor(ê)` of paper Algo 1.
//!
//! Standard I/PI controller (Hairer–Nørsett–Wanner II.4): after a step with
//! weighted error norm `ê` (accept iff `ê <= 1`), the next step size is
//! `h' = h · clamp(safety · ê^(−1/p) [· ê_prev^β], f_min, f_max)`.
//!
//! The controller is an explicit object because the **naive** gradient method
//! differentiates through it (paper Sec 3.3, Eq 23–26): [`Controller::factor`]
//! and [`Controller::dfactor_derr`] expose both the value and the derivative
//! of the decay factor, and the clamped regions have exactly zero derivative.
//!
//! Every decision is a pure function of `(h, err, err_prev)` — the
//! controller keeps no cross-step state. That statelessness is what lets
//! the batched engine ([`crate::ode::integrate_batch_spans`]) drive `B`
//! independent per-sample control loops, each clamping its final step onto
//! its **own** `t1`, through one shared `Controller` value without any
//! per-sample divergence from the scalar path.

/// Accept/reject decision plus the next trial step size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepDecision {
    pub accept: bool,
    /// Next step size to try (for the same step if rejected, the next step if
    /// accepted). Sign follows integration direction.
    pub h_next: f64,
    /// The raw multiplicative factor applied to `h` (after clamping).
    pub factor: f64,
}

/// I-controller with safety factor and factor clamps; optional PI term.
#[derive(Debug, Clone, Copy)]
pub struct Controller {
    pub safety: f64,
    pub min_factor: f64,
    pub max_factor: f64,
    /// `1/p` exponent from the tableau (see [`crate::ode::Tableau::err_exponent`]).
    pub err_exp: f64,
    /// PI coefficient β on the previous error (0 disables the PI term).
    pub beta: f64,
}

impl Controller {
    /// Standard settings used throughout the paper reproduction (matching
    /// torchdiffeq / torch-ACA defaults).
    pub fn new(err_exp: f64) -> Self {
        Controller {
            safety: 0.9,
            min_factor: 0.2,
            max_factor: 10.0,
            err_exp,
            beta: 0.0,
        }
    }

    /// Controller for a given tableau.
    pub fn for_tableau(tab: &super::Tableau) -> Self {
        Self::new(tab.err_exponent())
    }

    /// Unclamped decay factor `safety · err^(−err_exp)` (with optional PI
    /// history term), before clamping.
    fn raw_factor(&self, err: f64, err_prev: f64) -> f64 {
        if err <= 0.0 {
            return self.max_factor;
        }
        let mut f = self.safety * err.powf(-self.err_exp);
        if self.beta != 0.0 && err_prev > 0.0 {
            f *= err_prev.powf(self.beta);
        }
        f
    }

    /// The multiplicative factor on `h` after a step with error `err`.
    pub fn factor(&self, err: f64, err_prev: f64) -> f64 {
        self.raw_factor(err, err_prev).clamp(self.min_factor, self.max_factor)
    }

    /// Derivative `d factor / d err` — zero in the clamped regions. Used by
    /// the naive method's backprop through the step-size search.
    pub fn dfactor_derr(&self, err: f64, err_prev: f64) -> f64 {
        if err <= 0.0 {
            return 0.0;
        }
        let raw = self.raw_factor(err, err_prev);
        if raw <= self.min_factor || raw >= self.max_factor {
            return 0.0; // clamp kills the gradient
        }
        -self.err_exp * raw / err
    }

    /// Decide accept/reject for a step with error norm `err`, and compute the
    /// next trial step size.
    pub fn decide(&self, h: f64, err: f64, err_prev: f64) -> StepDecision {
        let accept = err <= 1.0;
        let mut factor = self.factor(err, err_prev);
        if !accept {
            // A rejected step must shrink.
            factor = factor.min(1.0);
        }
        StepDecision { accept, h_next: h * factor, factor }
    }

    /// Conservative initial step size from the classic algorithm of
    /// Hairer–Nørsett–Wanner I.7 (simplified): based on the scale of `f(t0,z0)`.
    pub fn initial_step<F: super::OdeFunc + ?Sized>(
        &self,
        f: &F,
        t0: f64,
        z0: &[f32],
        direction: f64,
        atol: f64,
        rtol: f64,
    ) -> f64 {
        let mut f0 = vec![0.0f32; z0.len()];
        f.eval(t0, z0, &mut f0);
        let d0 = crate::tensor::wrms_norm(z0, z0, z0, atol, rtol);
        let d1 = crate::tensor::wrms_norm(&f0, z0, z0, atol, rtol);
        let h0 = if d0 < 1e-5 || d1 < 1e-5 { 1e-6 } else { 0.01 * d0 / d1 };
        h0.max(1e-8) * direction.signum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> Controller {
        Controller::new(0.2) // dopri5-like
    }

    #[test]
    fn accepts_small_error_grows_step() {
        let d = c().decide(0.1, 1e-4, 0.0);
        assert!(d.accept);
        assert!(d.h_next > 0.1, "step should grow: {:?}", d);
    }

    #[test]
    fn rejects_large_error_shrinks_step() {
        let d = c().decide(0.1, 100.0, 0.0);
        assert!(!d.accept);
        assert!(d.h_next < 0.1, "step must shrink on reject: {:?}", d);
        assert!(d.h_next > 0.0, "sign preserved");
    }

    #[test]
    fn boundary_error_one_accepts() {
        let d = c().decide(0.1, 1.0, 0.0);
        assert!(d.accept);
        // factor = safety = 0.9 < 1: step shrinks slightly even on accept.
        assert!((d.factor - 0.9).abs() < 1e-12);
    }

    #[test]
    fn factor_clamped() {
        let ctrl = c();
        assert_eq!(ctrl.factor(1e-30, 0.0), 10.0);
        assert_eq!(ctrl.factor(1e30, 0.0), 0.2);
        assert_eq!(ctrl.factor(0.0, 0.0), 10.0);
    }

    #[test]
    fn derivative_zero_when_clamped_nonzero_inside() {
        let ctrl = c();
        assert_eq!(ctrl.dfactor_derr(1e-30, 0.0), 0.0);
        assert_eq!(ctrl.dfactor_derr(1e30, 0.0), 0.0);
        let err = 0.5;
        let d = ctrl.dfactor_derr(err, 0.0);
        // finite-difference check
        let eps = 1e-7;
        let fd = (ctrl.factor(err + eps, 0.0) - ctrl.factor(err - eps, 0.0)) / (2.0 * eps);
        assert!((d - fd).abs() < 1e-5, "analytic {d} vs fd {fd}");
        assert!(d < 0.0, "bigger error => smaller factor");
    }

    #[test]
    fn negative_direction_preserved() {
        let d = c().decide(-0.1, 0.5, 0.0);
        assert!(d.accept);
        assert!(d.h_next < 0.0);
    }

    #[test]
    fn monotone_in_error() {
        let ctrl = c();
        let mut prev = f64::INFINITY;
        for e in [0.01, 0.1, 0.5, 1.0, 2.0, 10.0] {
            let f = ctrl.factor(e, 0.0);
            assert!(f <= prev + 1e-12, "factor must be non-increasing in err");
            prev = f;
        }
    }

    #[test]
    fn initial_step_reasonable() {
        use crate::ode::analytic::Linear;
        let ctrl = c();
        let h = ctrl.initial_step(&Linear::new(-1.0, 1), 0.0, &[1.0], 1.0, 1e-6, 1e-3);
        assert!(h > 0.0 && h < 1.0, "h0 = {h}");
        let hb = ctrl.initial_step(&Linear::new(-1.0, 1), 1.0, &[1.0], -1.0, 1e-6, 1e-3);
        assert!(hb < 0.0);
    }
}
