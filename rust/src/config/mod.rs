//! Experiment configuration: small JSON config files + CLI overrides.
//!
//! Experiments are launched as `nodal repro <id> [--key value ...]`; every
//! knob has a paper-faithful default, and a JSON config (`--config f.json`)
//! can override groups of them. JSON (not TOML) because the offline build
//! vendors no TOML parser — see util::json.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::util::json::Json;

/// Flat key-value config with typed getters; merged from defaults, an
/// optional JSON file, and CLI `--key value` overrides (highest wins).
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        self.values.insert(key.to_string(), value.into());
    }

    /// Merge keys from a JSON object file (scalars only).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        for (k, v) in j.as_obj()? {
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => format!("{n}"),
                Json::Bool(b) => format!("{b}"),
                other => other.to_string(),
            };
            self.values.insert(k.clone(), s);
        }
        Ok(())
    }

    /// Parse trailing CLI args of the form `--key value`.
    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if key == "config" {
                    let path = args
                        .get(i + 1)
                        .ok_or_else(|| anyhow::anyhow!("--config needs a path"))?;
                    self.load_file(path)?;
                    i += 2;
                    continue;
                }
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
                self.set(key, val.clone());
                i += 2;
            } else {
                anyhow::bail!("unexpected argument '{a}' (expected --key value)");
            }
        }
        Ok(())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.values.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.values.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.values.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cli_overrides() {
        let mut c = Config::new();
        c.apply_args(&["--epochs".into(), "12".into(), "--method".into(), "aca".into()])
            .unwrap();
        assert_eq!(c.get_usize("epochs", 0), 12);
        assert_eq!(c.get_str("method", ""), "aca");
        assert_eq!(c.get_f64("rtol", 1e-2), 1e-2);
    }

    #[test]
    fn rejects_malformed() {
        let mut c = Config::new();
        assert!(c.apply_args(&["epochs".into()]).is_err());
        assert!(c.apply_args(&["--epochs".into()]).is_err());
    }

    #[test]
    fn file_merge() {
        let dir = std::env::temp_dir().join(format!("nodal_cfg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"epochs": 5, "verbose": true, "method": "naive"}"#).unwrap();
        let mut c = Config::new();
        c.load_file(p.to_str().unwrap()).unwrap();
        assert_eq!(c.get_usize("epochs", 0), 5);
        assert!(c.get_bool("verbose", false));
        assert_eq!(c.get_str("method", ""), "naive");
        std::fs::remove_dir_all(&dir).ok();
    }
}
